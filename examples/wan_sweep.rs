//! Network-sensitivity sweep: where does CBNN's round-efficiency pay off?
//!
//!   cargo run --release --example wan_sweep
//!
//! Runs MnistNet3 secure inference across a latency sweep from LAN
//! (0.2 ms) to transcontinental WAN (120 ms) and prints time per
//! inference.  Because the protocol suite is round-light (constant-round
//! MSB, fused BN/maxpool), time grows linearly in latency with a small
//! slope = total rounds; the crossover against compute is visible in the
//! printed decomposition.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, SessionConfig};
use cbnn::nn::Model;
use cbnn::transport::NetConfig;

fn main() -> anyhow::Result<()> {
    let art = PathBuf::from(
        std::env::var("CBNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let model = Arc::new(Model::load(
        &art.join("models/mnistnet3.manifest.json"))?);
    let data = EvalSet::load(&art.join("data/mnist.bin"))?;

    println!("== latency sweep: {} ==", model.name);
    println!("{:>12} {:>12} {:>12} {:>10} {:>8}",
             "latency", "bandwidth", "time/img", "rounds", "comm MB");

    let points = [
        (Duration::from_micros(200), 625.0e6, "LAN"),
        (Duration::from_millis(5), 200.0e6, ""),
        (Duration::from_millis(20), 100.0e6, ""),
        (Duration::from_millis(40), 40.0e6, ""),
        (Duration::from_millis(80), 40.0e6, "WAN"),
        (Duration::from_millis(120), 20.0e6, ""),
    ];
    let mut base_time = 0.0f64;
    for (lat, bw, tag) in points {
        let cfg = SessionConfig::new(art.join("hlo"))
            .with_net(NetConfig { latency: lat, bandwidth: bw });
        let rep = run_inference(&model, vec![data.images[0].clone()],
                                &cfg)?;
        let t = rep.online.as_secs_f64();
        if base_time == 0.0 {
            base_time = t;
        }
        println!("{:>9.1}ms {:>9.0}MBps {:>11.3}s {:>10} {:>8.3}  {}",
                 lat.as_secs_f64() * 1e3, bw / 1e6, t, rep.max_rounds(),
                 rep.comm_mb(), tag);
    }
    println!("\nround-trips dominate beyond ~5 ms latency; the constant-\n\
              round online MSB keeps the slope small and flat.");
    Ok(())
}
