//! Quickstart: one secure inference, end to end.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! The data owner (P0) holds one MNIST-like image; the model owner (P1)
//! holds MnistNet3's weights; the helper (P2) holds nothing.  The three
//! parties secret-share everything, run the CBNN protocol stack over a
//! simulated LAN, and only P0 learns the logits.

use std::path::PathBuf;
use std::sync::Arc;

use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, SessionConfig};
use cbnn::metrics::fmt_duration;
use cbnn::nn::Model;
use cbnn::runtime::{BackendKind, KernelVariant};
use cbnn::transport::NetConfig;

fn main() -> anyhow::Result<()> {
    let art = PathBuf::from(
        std::env::var("CBNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let model = Arc::new(Model::load(
        &art.join("models/mnistnet3.manifest.json"))?);
    let data = EvalSet::load(&art.join("data/mnist.bin"))?;

    println!("== CBNN quickstart ==");
    println!("model   : {} ({} secret parameters)", model.name,
             model.param_count());
    println!("program : {} layers", model.ops.len());

    let cfg = SessionConfig::new(art.join("hlo"))
        .with_net(NetConfig::lan())
        .with_backend(BackendKind::Pjrt(KernelVariant::Pallas));

    let image = data.images[0].clone();
    let rep = run_inference(&model, vec![image], &cfg)?;

    println!("\nsecure inference over simulated LAN (0.2 ms, 625 MBps):");
    println!("  setup (model sharing) : {}", fmt_duration(rep.setup));
    println!("  online inference      : {}", fmt_duration(rep.online));
    println!("  communication         : {:.4} MB total, {} rounds",
             rep.comm_mb(), rep.max_rounds());
    println!("\n  logits (revealed to the data owner only): {:?}",
             rep.logits[0]);
    println!("  prediction = {}   (true label = {})", rep.preds[0],
             data.labels[0]);
    Ok(())
}
