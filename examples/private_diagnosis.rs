//! Domain scenario: privacy-preserving medical image triage.
//!
//!   cargo run --release --example private_diagnosis
//!
//! A clinic (data owner) wants a vendor's proprietary classifier (model
//! owner) to triage scans without the vendor seeing patient data and
//! without the clinic seeing the model.  This is the CIFAR-scale
//! customized network (CifarNet2, MPC-friendly separable convolutions);
//! the example walks both the *typical* BNN and the customized one over
//! LAN and WAN, per-layer, showing where the paper's customizations save
//! time and bytes (the Table-2 story on a live workload).

use std::path::PathBuf;
use std::sync::Arc;

use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, SessionConfig};
use cbnn::metrics::fmt_duration;
use cbnn::nn::{Model, Op};
use cbnn::transport::NetConfig;

fn describe(model: &Model) -> (usize, usize, usize) {
    let mut convs = 0;
    let mut seps = 0;
    let mut fcs = 0;
    for op in &model.ops {
        match op {
            Op::Depthwise { .. } => seps += 1,
            Op::Matmul { conv: true, .. } => convs += 1,
            Op::Matmul { conv: false, .. } => fcs += 1,
            _ => {}
        }
    }
    (convs, seps, fcs)
}

fn main() -> anyhow::Result<()> {
    let art = PathBuf::from(
        std::env::var("CBNN_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let data = EvalSet::load(&art.join("data/cifar.bin"))?;

    println!("== private diagnosis: vendor model, clinic data ==\n");
    println!("{:<22} {:>9} {:>12} {:>12} {:>10} {:>8}",
             "model", "params", "LAN/img", "WAN/img", "comm MB", "pred");

    for name in ["cifarnet2_typical", "cifarnet2"] {
        let model = Arc::new(Model::load(
            &art.join(format!("models/{name}.manifest.json")))?);
        let (convs, seps, fcs) = describe(&model);
        let mut row: Vec<String> = Vec::new();
        for net in [NetConfig::lan(), NetConfig::wan()] {
            let cfg = SessionConfig::new(art.join("hlo")).with_net(net);
            let rep = run_inference(&model, vec![data.images[0].clone()],
                                    &cfg)?;
            row.push(fmt_duration(rep.online));
            if row.len() == 2 {
                println!("{:<22} {:>9} {:>12} {:>12} {:>10.3} {:>8}",
                         name, model.param_count(), row[0], row[1],
                         rep.comm_mb(), rep.preds[0]);
                println!("   ({} dense convs, {} depthwise stages, {} fc; \
                          label = {})", convs, seps, fcs, data.labels[0]);
            }
        }
    }

    println!("\nMPC-friendly separable convolutions shrink the vendor's \
              secret parameter count and the per-image communication;\n\
              the clinic sees only the logits, the vendor sees nothing.");
    Ok(())
}
