//! End-to-end serving driver (the EXPERIMENTS.md validation run).
//!
//!   cargo run --release --example e2e_serve [-- --requests 64 --net lan]
//!
//! Loads the trained, quantized MnistNet3, brings up the three-party
//! `Service` + dynamic-batching `Coordinator`, replays a bursty client
//! stream against it, and reports latency percentiles, throughput, and
//! accuracy against the eval labels -- plus the same workload at batch=1
//! to show what the batcher buys.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use cbnn::cli::{parse_net, Args};
use cbnn::coordinator::{BatchPolicy, Coordinator, Service};
use cbnn::datasets::EvalSet;
use cbnn::engine::session::SessionConfig;
use cbnn::metrics::fmt_duration;
use cbnn::nn::Model;
use cbnn::runtime::{BackendKind, KernelVariant};

fn run_stream(model: &Arc<Model>, data: &EvalSet, cfg: &SessionConfig,
              requests: usize, policy: BatchPolicy)
              -> anyhow::Result<(f64, f64, Duration, Duration, f64)> {
    let svc = Service::start(Arc::clone(model), cfg.clone())?;
    let setup = svc.setup_time;
    let coord = Coordinator::start(svc, policy);
    let mut rxs = Vec::new();
    for i in 0..requests {
        rxs.push((i, coord.submit(
            data.images[i % data.images.len()].clone())));
        // bursty arrivals: a short pause every 8 requests
        if i % 8 == 7 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let mut correct = 0usize;
    for (i, rx) in rxs {
        let resp = rx.recv()?;
        if resp.pred == data.labels[i % data.labels.len()] as usize {
            correct += 1;
        }
    }
    let (hist, thr) = coord.finish();
    Ok((thr.per_sec(),
        correct as f64 / requests as f64,
        hist.quantile(0.5),
        hist.quantile(0.99),
        setup.as_secs_f64()))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let art = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let requests = args.get_usize("requests", 64)
        .map_err(anyhow::Error::msg)?;
    let net = parse_net(args.get_or("net", "lan"))
        .map_err(anyhow::Error::msg)?;

    let model = Arc::new(Model::load(
        &art.join("models/mnistnet3.manifest.json"))?);
    let data = EvalSet::load(&art.join("data/mnist.bin"))?;
    let cfg = SessionConfig::new(art.join("hlo"))
        .with_net(net)
        .with_backend(BackendKind::Pjrt(KernelVariant::Pallas));

    println!("== CBNN end-to-end serving: {} x {} requests, net={} ==",
             model.name, requests, args.get_or("net", "lan"));

    let batched = run_stream(&model, &data, &cfg, requests,
                             BatchPolicy { max_batch: 8,
                                           max_wait: Duration::from_millis(10),
                                           ..Default::default() })?;
    let single = run_stream(&model, &data, &cfg, requests,
                            BatchPolicy { max_batch: 1,
                                          max_wait: Duration::ZERO,
                                          ..Default::default() })?;

    println!("\n{:<18} {:>12} {:>10} {:>10} {:>10}",
             "policy", "throughput", "p50", "p99", "accuracy");
    for (label, r) in [("batch<=8", &batched), ("batch=1", &single)] {
        println!("{:<18} {:>9.2}/s {:>10} {:>10} {:>9.1}%",
                 label, r.0, fmt_duration(r.2), fmt_duration(r.3),
                 r.1 * 100.0);
    }
    println!("\nsetup (share model + warm PJRT): {:.2}s", batched.4);
    println!("speedup from dynamic batching: {:.2}x", batched.0 / single.0);
    Ok(())
}
