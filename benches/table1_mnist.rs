//! Table 1 reproduction: MNIST secure inference, LAN/WAN time, total
//! communication, and accuracy for MnistNet1-3, printed against the
//! paper's published rows (labelled `paper`; our measured row is
//! `CBNN(ours)`).
//!
//!   cargo bench --bench table1_mnist
//!
//! Expected shape (not absolute numbers -- our testbed is 3 threads on
//! one core + a simulated network): CBNN(ours) beats the bit-decomposition
//! frameworks on WAN because of the constant-round MSB; communication is
//! within the same order as SecureBiNN/Falcon.

mod common;

use cbnn::baselines::costmodel::{fmt_row, table1};
use cbnn::transport::NetConfig;
use common::*;

fn main() {
    require_artifacts();
    println!("== Table 1: MNIST, batch=1, semi-honest 3PC ==\n");
    for arch in ["mnistnet1", "mnistnet2", "mnistnet3"] {
        let model = load_model(arch);
        let data = eval_data(&model);
        let (lan, rep_l) = measure(&model, &data, NetConfig::lan(), 1, 5);
        let (wan, _) = measure(&model, &data, NetConfig::wan(), 1, 3);
        println!("[{arch}]");
        header();
        for row in table1(arch) {
            println!("{}", fmt_row(&format!("{} (paper)", row.framework),
                                   row.time_lan_s, row.time_wan_s,
                                   row.comm_mb, row.acc_pct));
        }
        println!("{}", fmt_row("CBNN(ours,measured)", Some(lan), Some(wan),
                               Some(rep_l.comm_mb()),
                               exported_accuracy(arch)));
        println!("rounds={} (max over parties)  setup={:.3}s\n",
                 rep_l.max_rounds(), rep_l.setup.as_secs_f64());
    }
    println!("note: accuracy columns are on synth-MNIST (see DESIGN.md \
              substitutions); paper rows are literature values.");
}
