//! Boolean-share representation micro-benchmarks, three tiers:
//!
//!   1. byte-per-bit (the seed's `Vec<u8>`) vs word-packed `BitTensor`
//!      for XOR / AND / B2A-prep (the PR 1 representation change);
//!   2. rolled vs 4-way-unrolled word kernels (`ring::kernel`);
//!   3. concat-based vs strided Kogge-Stone levels: the full 5-level
//!      prefix pass over 32 planes, once with per-level `extend`/`slice`
//!      churn (the PR 1 layout) and once over `BitPlanes` row views
//!      (zero operand copies) -- the acceptance target is >= 2x.
//!   4. (offline split) online-only MSB latency with warm preprocessed
//!      material vs generation inline on the request path -- the number
//!      the `offline::TupleBank` producers buy the serving stack.
//!   5. (fusion) fused vs unfused hidden-layer walk over a fully
//!      binarizable sign -> pool -> +-1 linear chain: end-to-end batch
//!      latency plus the hidden-segment wire bytes (deterministic; the
//!      ISSUE 6 >= 8x reduction claim, recorded so CI can gate it).
//!   6. (wan) LAN vs WAN inference latency under the `transport::shim`
//!      virtual clock: the same inferences priced at 0.2ms and 80ms
//!      one-way latency without sleeping.  The `n` column records the
//!      critical-path round count, so a round added anywhere changes
//!      the row key and the bench gate fails alongside
//!      `tests/budgets.rs`.
//!   7. (obs) traced vs untraced end-to-end inference: the telemetry
//!      spine's overhead when recording every span, plus the
//!      deterministic per-party span and send-flight counts (exact-gate
//!      rows: a count that moved is a choreography change, caught here
//!      alongside `tests/trace.rs`).
//!   8. (zoo) the exported real models from `fixtures/zoo`: fused vs
//!      unfused secure latency on lenet5/vgg7 plus deterministic
//!      per-layer bytes/rounds rows, so the wire cost of every served
//!      layer of the paper's actual workload is pinned exactly.
//!   9. (serve) the async request plane: concurrent multi-tenant
//!      submitters through the dynamic batcher vs the same requests
//!      served serially one at a time, plus exact-gated shed counters
//!      (`serve_shed_counts`): admission decisions are deterministic,
//!      so a changed shed count is an admission-policy change, caught
//!      here alongside `tests/request_plane.rs`.
//!
//! Results are printed as a table and recorded to `BENCH_bitops.json`
//! (tiers 1-3), `BENCH_offline.json` (tier 4), `BENCH_fusion.json`
//! (tier 5), `BENCH_wan.json` (tier 6), `BENCH_obs.json` (tier 7),
//! `BENCH_zoo.json` (tier 8) and `BENCH_serve.json` (tier 9) at the
//! workspace root so the bench trajectory is diffable.
//!
//!   cargo bench --bench bitops

use std::fmt::Write as _;
use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

use cbnn::protocols::preproc::{mint, msb_online, MsbPool};
use cbnn::ring::bits::BitTensor;
use cbnn::ring::kernel;
use cbnn::ring::planes::BitPlanes;
use cbnn::ring::Tensor;
use cbnn::rss::deal;
use cbnn::testutil::threeparty::run3_seeded;
use cbnn::testutil::Rng;

/// Median-of-reps wall time for `f`, in seconds.
fn time(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

/// One recorded comparison row.
struct Row {
    section: &'static str,
    op: String,
    n: usize,
    baseline_ms: f64,
    fast_ms: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        // deterministic counter rows can legitimately carry 0 in both
        // columns (e.g. a zero-cost layer's bytes, a zero underflow
        // count); 0/0 would print NaN and corrupt the JSON record
        if self.fast_ms == 0.0 {
            1.0
        } else {
            self.baseline_ms / self.fast_ms
        }
    }
}

// ---- byte-per-bit reference (exactly the seed's BitShare ops) -----------
fn bytes_xor(a: &[u8], b: &[u8]) -> Vec<u8> {
    a.iter().zip(b).map(|(x, y)| x ^ y).collect()
}

fn bytes_and(a: &[u8], b: &[u8]) -> Vec<u8> {
    a.iter().zip(b).map(|(x, y)| x & y).collect()
}

// ---- rolled word kernels (what the unrolled kernels replaced) -----------
fn rolled_xor(dst: &mut [u64], a: &[u64], b: &[u64]) {
    for ((d, x), y) in dst.iter_mut().zip(a).zip(b) {
        *d = x ^ y;
    }
}

fn rolled_and(dst: &mut [u64], a: &[u64], b: &[u64]) {
    for ((d, x), y) in dst.iter_mut().zip(a).zip(b) {
        *d = x & y;
    }
}

fn representation_tier(rows: &mut Vec<Row>) {
    println!("== tier 1: byte-per-bit vs word-packed ==\n");
    println!("{:<10} {:<10} {:>12} {:>12} {:>9}",
             "op", "elems", "bytes(ms)", "packed(ms)", "speedup");
    println!("{}", "-".repeat(58));

    for &n in &[10_000usize, 100_000, 1_000_000, 10_000_000] {
        let reps = if n >= 1_000_000 { 5 } else { 20 };
        let mut rng = Rng::new(n as u64);
        let xa: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
        let xb: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
        let ta = BitTensor::from_bits(&xa);
        let tb = BitTensor::from_bits(&xb);

        let cases: [(&str, f64, f64); 3] = [
            ("xor",
             time(reps, || {
                 black_box(bytes_xor(black_box(&xa), black_box(&xb)));
             }),
             time(reps, || {
                 black_box(black_box(&ta).xor(black_box(&tb)));
             })),
            ("and",
             time(reps, || {
                 black_box(bytes_and(black_box(&xa), black_box(&xb)));
             }),
             time(reps, || {
                 black_box(black_box(&ta).and(black_box(&tb)));
             })),
            // B2A-prep: the boolean part of the sender's message
            // construction (y12 = y1 ^ y2 + a reduction over the batch)
            ("b2a-prep",
             time(reps, || {
                 let y12 = bytes_xor(&xa, &xb);
                 black_box(y12.iter().map(|&b| b as u64).sum::<u64>());
             }),
             time(reps, || {
                 let y12 = ta.xor(&tb);
                 black_box(y12.popcount());
             })),
        ];
        for (op, t_base, t_fast) in cases {
            println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.1}x",
                     op, n, t_base * 1e3, t_fast * 1e3, t_base / t_fast);
            rows.push(Row { section: "byte_vs_packed", op: op.into(), n,
                            baseline_ms: t_base * 1e3,
                            fast_ms: t_fast * 1e3 });
        }
        println!();
    }
}

fn kernel_tier(rows: &mut Vec<Row>) {
    println!("== tier 2: rolled vs 4-way unrolled word kernels ==\n");
    println!("{:<10} {:<10} {:>12} {:>12} {:>9}",
             "op", "words", "rolled(ms)", "unroll(ms)", "speedup");
    println!("{}", "-".repeat(58));

    for &nw in &[16_384usize, 262_144, 2_097_152] {
        let reps = if nw >= 1_000_000 { 9 } else { 25 };
        let mut rng = Rng::new(nw as u64);
        let a: Vec<u64> = (0..nw).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..nw).map(|_| rng.next_u64()).collect();
        let mut dst = vec![0u64; nw];

        let t_rolled = time(reps, || {
            rolled_xor(black_box(&mut dst), black_box(&a), black_box(&b));
        });
        let t_unrolled = time(reps, || {
            kernel::xor_into(black_box(&mut dst), black_box(&a),
                             black_box(&b));
        });
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.2}x",
                 "xor", nw, t_rolled * 1e3, t_unrolled * 1e3,
                 t_rolled / t_unrolled);
        rows.push(Row { section: "rolled_vs_unrolled", op: "xor".into(),
                        n: nw, baseline_ms: t_rolled * 1e3,
                        fast_ms: t_unrolled * 1e3 });

        let t_rolled = time(reps, || {
            rolled_and(black_box(&mut dst), black_box(&a), black_box(&b));
        });
        let t_unrolled = time(reps, || {
            kernel::and_into(black_box(&mut dst), black_box(&a),
                             black_box(&b));
        });
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.2}x",
                 "and", nw, t_rolled * 1e3, t_unrolled * 1e3,
                 t_rolled / t_unrolled);
        rows.push(Row { section: "rolled_vs_unrolled", op: "and".into(),
                        n: nw, baseline_ms: t_rolled * 1e3,
                        fast_ms: t_unrolled * 1e3 });
        println!();
    }
}

const L: usize = 32;

/// The PR 1 arm: per-level operand concatenation with `extend`, result
/// redistribution with `slice` -- O(L*n) copied bits per level.
fn ks_levels_concat(g0: &[BitTensor], p0: &[BitTensor]) -> BitTensor {
    let mut g: Vec<BitTensor> = g0.to_vec();
    let mut p: Vec<BitTensor> = p0.to_vec();
    let n = g0[0].len();
    let mut dist = 1usize;
    while dist < L {
        let idx: Vec<usize> = (dist..L).collect();
        let mut lhs = BitTensor::zeros(0);
        let mut rhs = BitTensor::zeros(0);
        for &i in &idx {
            lhs.extend(&p[i]);
            rhs.extend(&g[i - dist]);
        }
        for &i in &idx {
            lhs.extend(&p[i]);
            rhs.extend(&p[i - dist]);
        }
        let prod = lhs.and(&rhs); // the local AND of the batched round
        let m = idx.len();
        for (j, &i) in idx.iter().enumerate() {
            g[i] = g[i].xor(&prod.slice(j * n, n));
            p[i] = prod.slice((m + j) * n, n);
        }
        dist *= 2;
    }
    g[30].clone()
}

/// The strided arm: operands are zero-copy row views over `BitPlanes`;
/// the only writes are the AND output and the word-aligned row updates.
fn ks_levels_strided(g0: &BitPlanes, p0: &BitPlanes) -> BitTensor {
    let mut g = g0.clone();
    let mut p = p0.clone();
    let len = g.len();
    let mut dist = 1usize;
    while dist < L {
        let m = L - dist;
        let mut prod = BitPlanes::zeros(2 * m, len);
        for (half, rhs) in [(0usize, &g), (1usize, &p)] {
            for j in 0..m {
                kernel::and_into(prod.plane_words_mut(half * m + j),
                                 p.plane_words(dist + j),
                                 rhs.plane_words(j));
            }
        }
        g.xor_rows_from(dist, &prod, 0..m);
        p.copy_rows_from(dist, &prod, m..2 * m);
        dist *= 2;
    }
    g.plane(30)
}

fn plane_tier(rows: &mut Vec<Row>) {
    println!("== tier 3: Kogge-Stone levels, concat vs strided ==\n");
    println!("{:<10} {:<10} {:>12} {:>12} {:>9}",
             "op", "elems", "concat(ms)", "strided(ms)", "speedup");
    println!("{}", "-".repeat(58));

    for &n in &[10_000usize, 100_000, 1_000_000] {
        let reps = if n >= 1_000_000 { 5 } else { 15 };
        let mut rng = Rng::new(n as u64);
        let planes: Vec<BitTensor> = (0..2 * L)
            .map(|_| BitTensor::from_fn(n, |_| rng.bit()))
            .collect();
        let (gt, pt) = planes.split_at(L);
        let gm = BitPlanes::from_tensors(gt);
        let pm = BitPlanes::from_tensors(pt);

        // equivalence sanity before timing: both arms compute the same
        // carry plane
        assert_eq!(ks_levels_concat(gt, pt), ks_levels_strided(&gm, &pm));

        let t_concat = time(reps, || {
            black_box(ks_levels_concat(black_box(gt), black_box(pt)));
        });
        let t_strided = time(reps, || {
            black_box(ks_levels_strided(black_box(&gm), black_box(&pm)));
        });
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.1}x",
                 "ks-5lvl", n, t_concat * 1e3, t_strided * 1e3,
                 t_concat / t_strided);
        rows.push(Row { section: "ks_concat_vs_strided",
                        op: "ks-5lvl".into(), n,
                        baseline_ms: t_concat * 1e3,
                        fast_ms: t_strided * 1e3 });
        println!();
    }
}

/// Tier 4: the offline/online split.  Per party, over three in-memory
/// parties: online MSB with warm preprocessed material (`msb_online`
/// drawing from a pre-minted reservoir -- what a warm `TupleBank` serves)
/// vs minting that material synchronously on the request path.  The gap
/// is the request-latency the background producers remove.
fn offline_tier(rows: &mut Vec<Row>) {
    println!("== tier 4: online MSB, warm bank vs inline generation ==\n");
    println!("{:<10} {:<10} {:>12} {:>12} {:>9}",
             "op", "elems", "inline(ms)", "warm(ms)", "speedup");
    println!("{}", "-".repeat(58));

    for &n in &[1_000usize, 10_000, 100_000] {
        let reps = if n >= 100_000 { 5 } else { 11 };
        let results = run3_seeded(n as u64, |ctx| {
            let mut rng = Rng::new(n as u64);
            let vals: Vec<i32> =
                (0..n).map(|_| rng.small(1 << 20)).collect();
            let x = Tensor::from_vec(&[n], vals);
            let shares = deal(&x, &mut rng);
            let me = &shares[ctx.id()];
            // warm arm: generation happened off the request path
            let pool = MsbPool::new();
            pool.generate(ctx, n * reps).unwrap();
            let t0 = Instant::now();
            for _ in 0..reps {
                black_box(
                    msb_online(ctx, me, pool.take(n).unwrap()).unwrap());
            }
            let warm = t0.elapsed();
            // inline arm: every request pays the mint
            let t1 = Instant::now();
            for _ in 0..reps {
                let tup = mint(ctx, n).unwrap();
                black_box(msb_online(ctx, me, tup).unwrap());
            }
            let inline = t1.elapsed();
            (warm.as_secs_f64() / reps as f64,
             inline.as_secs_f64() / reps as f64)
        });
        let (warm, inline) = results[0].0;
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.1}x",
                 "msb", n, inline * 1e3, warm * 1e3, inline / warm);
        rows.push(Row { section: "warm_bank_vs_inline", op: "msb".into(),
                        n, baseline_ms: inline * 1e3,
                        fast_ms: warm * 1e3 });
        println!();
    }
}

/// Tier 5: binary-domain fusion.  One three-party session per batch
/// size runs the same fully-binarizable hidden chain twice -- the
/// arithmetic walk (`infer_batch_pooled`) and the fused boolean walk
/// (`infer_batch_fused`) -- over warm tuple pools, so the measured gap
/// is the online representation change, not preprocessing.  The chain
/// is trunc-free, so the two walks must agree bit-for-bit (asserted
/// before timing).  Alongside latency, party 0's per-op cost rows give
/// the hidden-segment bytes (ops 2..=5: pool, pm1, +-1 depthwise, the
/// folded sign) -- a deterministic number CI gates exactly.
fn fusion_tier(rows: &mut Vec<Row>) {
    use cbnn::engine::fusion::{infer_batch_fused, plan_fused};
    use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                       EngineOptions};
    use cbnn::offline::TupleSource;
    use cbnn::protocols::linear::NativeBackend;

    println!("== tier 5: fused vs unfused hidden-layer walk ==\n");
    println!("{:<12} {:<8} {:>12} {:>12} {:>9}",
             "metric", "batch", "unfused", "fused", "ratio");
    println!("{}", "-".repeat(58));

    let model = chain_model();
    let plan = plan_fused(&model).expect("chain must lower");

    for &batch in &[1usize, 4] {
        let reps = 7usize;
        let results = run3_seeded(60 + batch as u64, |ctx| {
            let shared = share_model(ctx, &model, true).unwrap();
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = Rng::new(batch as u64);
                (0..batch).map(|_| rng.tensor_small(&[1, 144], 15))
                    .collect()
            } else {
                vec![]
            };
            let opts = EngineOptions::default();
            let u_demand = msb_demand(&shared, batch);
            let f_demand = plan.msb_demand(batch);
            // warm pools for every rep: preprocessing off the path
            let upool = MsbPool::new();
            upool.generate(ctx, u_demand * (reps + 1)).unwrap();
            let fpool = MsbPool::new();
            fpool.generate(ctx, f_demand * (reps + 1)).unwrap();
            let usrc = TupleSource::Pool(&upool);
            let fsrc = TupleSource::Pool(&fpool);
            // equivalence sanity before timing (trunc-free chain)
            let u0 = infer_batch_pooled(ctx, &shared, &NativeBackend,
                                        opts, &inputs, batch, &usrc)
                .unwrap();
            let f0 = infer_batch_fused(ctx, &shared, &plan,
                                       &NativeBackend, opts, &inputs,
                                       batch, &fsrc)
                .unwrap();
            assert_eq!(u0.logits, f0.logits, "fused walk diverged");
            let seg = |costs: &[cbnn::metrics::OpCost]| costs.iter()
                .filter(|r| (2..=5).contains(&r.index))
                .map(|r| r.bytes_sent)
                .sum::<u64>();
            let t0 = Instant::now();
            for _ in 0..reps {
                black_box(infer_batch_pooled(
                    ctx, &shared, &NativeBackend, opts, &inputs, batch,
                    &usrc).unwrap());
            }
            let unfused = t0.elapsed();
            let t1 = Instant::now();
            for _ in 0..reps {
                black_box(infer_batch_fused(
                    ctx, &shared, &plan, &NativeBackend, opts, &inputs,
                    batch, &fsrc).unwrap());
            }
            let fused = t1.elapsed();
            (unfused.as_secs_f64() / reps as f64,
             fused.as_secs_f64() / reps as f64,
             seg(&u0.op_costs), seg(&f0.op_costs))
        });
        let (u_ms, f_ms, u_bytes, f_bytes) = results[0].0;
        println!("{:<12} {:<8} {:>10.3}ms {:>10.3}ms {:>8.1}x",
                 "latency", batch, u_ms * 1e3, f_ms * 1e3, u_ms / f_ms);
        rows.push(Row { section: "fused_vs_unfused", op: "latency".into(),
                        n: batch, baseline_ms: u_ms * 1e3,
                        fast_ms: f_ms * 1e3 });
        println!("{:<12} {:<8} {:>11}B {:>11}B {:>8.1}x",
                 "hidden-bytes", batch, u_bytes, f_bytes,
                 u_bytes as f64 / f_bytes.max(1) as f64);
        // byte rows ride the same schema (the *_ms columns carry bytes);
        // ci/bench_compare.py gates *_bytes sections exactly, since wire
        // accounting is deterministic
        rows.push(Row { section: "fused_vs_unfused_bytes",
                        op: "hidden-segment".into(), n: batch,
                        baseline_ms: u_bytes as f64,
                        fast_ms: f_bytes as f64 });
        println!();
    }
}

/// The fully-binarizable hidden chain tiers 5 and 6 run: conv -> sign
/// -> OR-pool -> pm1 -> +-1 depthwise with folded sign -> pm1 ->
/// flatten -> +-1 FC (same model `tests/properties.rs` proves
/// bit-identical fused vs unfused).
fn chain_model() -> cbnn::nn::Model {
    let manifest = r#"{
      "name": "bnnchain", "dataset": "synthetic",
      "input": {"c": 1, "h": 12, "w": 12},
      "s_in": 0, "ring_bits": 32,
      "layers": [
        {"op": "matmul", "conv": true, "m": 4, "kdim": 9, "n": 100,
         "k": 3, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 4,
         "w": {"off": 0, "len": 36}, "b": {"off": 36, "len": 4},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 4, "t": {"off": 40, "len": 4},
         "flip": {"off": 44, "len": 4}},
        {"op": "pool_bits", "c": 4, "k": 2, "stride": 2},
        {"op": "pm1"},
        {"op": "depthwise", "cout": 4, "k": 1, "stride": 1,
         "pad_lo": 0, "pad_hi": 0, "w": {"off": 48, "len": 4},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 4, "t": {"off": 52, "len": 4},
         "flip": {"off": 56, "len": 4}},
        {"op": "pm1"},
        {"op": "flatten", "c": 4, "h": 5, "w": 5},
        {"op": "matmul", "conv": false, "m": 3, "kdim": 100, "n": 1,
         "w": {"off": 60, "len": 300}, "s_in": 0, "s_out": 0}
      ]
    }"#;
    let mut pool = vec![0i32; 360];
    for (i, v) in pool.iter_mut().enumerate().take(36) {
        *v = (i as i32 % 5) - 2;
    }
    pool[36..40].copy_from_slice(&[1, -1, 2, 0]);
    pool[40..44].copy_from_slice(&[0, 1, -1, 2]);
    pool[44..48].copy_from_slice(&[1, -1, 2, -2]);
    pool[48..52].copy_from_slice(&[1, -1, 1, -1]);
    pool[52..56].copy_from_slice(&[1, 3, -2, 0]);
    pool[56..60].copy_from_slice(&[2, -1, 1, -3]);
    for (i, v) in pool.iter_mut().enumerate().skip(60) {
        *v = if (i + i / 7) % 2 == 0 { 1 } else { -1 };
    }
    cbnn::nn::Model::from_json(manifest, pool).unwrap()
}

/// Tier 6: LAN vs WAN inference latency under the virtual clock.  The
/// shim prices every flight (latency + serialization) on a
/// deterministic virtual clock, so the recorded numbers are data-flow
/// time, not wall time, and reproduce exactly across machines.  The
/// row key's `n` column is the measured critical-path round count:
/// adding a round anywhere changes the key and the bench gate fails
/// together with `tests/budgets.rs`.
fn wan_tier(rows: &mut Vec<Row>) {
    use cbnn::engine::fusion::{infer_batch_fused, plan_fused};
    use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                       EngineOptions};
    use cbnn::offline::TupleSource;
    use cbnn::protocols::linear::NativeBackend;
    use cbnn::testutil::threeparty::{every_op_model, run3_seeded_net};
    use cbnn::transport::NetConfig;

    println!("== tier 6: LAN vs WAN virtual-clock latency ==\n");
    println!("{:<18} {:<8} {:>12} {:>12} {:>9}",
             "model", "rounds", "wan(ms)", "lan(ms)", "ratio");
    println!("{}", "-".repeat(62));

    let batch = 2usize;
    let measure = |model: &cbnn::nn::Model, flat: usize, fuse: bool,
                   net: NetConfig| -> (f64, u64) {
        let plan = fuse.then(|| plan_fused(model).expect("must lower"));
        let results = run3_seeded_net(6_000 + flat as u64, net, |ctx| {
            let shared = share_model(ctx, model, true).unwrap();
            let demand = match &plan {
                Some(p) => p.msb_demand(batch),
                None => msb_demand(&shared, batch),
            };
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = Rng::new(flat as u64);
                (0..batch).map(|_| rng.tensor_small(&[1, flat], 15))
                    .collect()
            } else {
                vec![]
            };
            let pool = MsbPool::new();
            pool.generate(ctx, demand).unwrap();
            let src = TupleSource::Pool(&pool);
            let t0 = ctx.comm.virtual_now();
            let r0 = ctx.comm.stats().rounds;
            let out = match &plan {
                Some(p) => infer_batch_fused(
                    ctx, &shared, p, &NativeBackend,
                    EngineOptions::default(), &inputs, batch, &src)
                    .unwrap(),
                None => infer_batch_pooled(
                    ctx, &shared, &NativeBackend, EngineOptions::default(),
                    &inputs, batch, &src)
                    .unwrap(),
            };
            black_box(out.logits);
            ((ctx.comm.virtual_now() - t0).as_secs_f64(),
             ctx.comm.stats().rounds - r0)
        });
        let ms = results.iter()
            .map(|(r, _)| r.0 * 1e3)
            .fold(0.0f64, f64::max);
        let rounds = results.iter().map(|(r, _)| r.1).max().unwrap();
        (ms, rounds)
    };

    let everyop = every_op_model();
    let chain = chain_model();
    let cases: [(&str, &cbnn::nn::Model, usize, bool); 3] = [
        ("everyop-unfused", &everyop, 36, false),
        ("everyop-fused", &everyop, 36, true),
        ("bnnchain-fused", &chain, 144, true),
    ];
    for (label, model, flat, fuse) in cases {
        let lan = NetConfig::lan().with_virtual_clock();
        let wan = NetConfig::wan().with_virtual_clock();
        let (lan_ms, lan_rounds) = measure(model, flat, fuse, lan);
        let (wan_ms, wan_rounds) = measure(model, flat, fuse, wan);
        assert_eq!(lan_rounds, wan_rounds,
                   "round count must not depend on the link profile");
        println!("{:<18} {:<8} {:>12.3} {:>12.3} {:>8.1}x",
                 label, wan_rounds, wan_ms, lan_ms, wan_ms / lan_ms);
        rows.push(Row { section: "lan_vs_wan_virtual", op: label.into(),
                        n: wan_rounds as usize, baseline_ms: wan_ms,
                        fast_ms: lan_ms });
    }
    println!();
}

/// Tier 7: the telemetry spine's cost.  The same every-op three-party
/// session runs untraced (sinks installed but disabled -- the
/// production default, one relaxed atomic load per potential span) and
/// traced (every request/op/protocol/flight span recorded), unfused
/// and fused.  Latency rows carry traced as the baseline arm and
/// untraced as the gated arm; the `obs_spans_bytes` rows record party
/// 0's lock-step span count and send-flight count, which are
/// deterministic per walk -- CI gates them exactly, so a span or
/// flight added anywhere in the choreography fails the bench together
/// with `tests/trace.rs`.
fn obs_tier(rows: &mut Vec<Row>) {
    use cbnn::engine::session::{run_inference, SessionConfig};
    use cbnn::testutil::threeparty::every_op_model;
    use cbnn::trace::SpanKind;

    println!("== tier 7: traced vs untraced inference ==\n");
    println!("{:<18} {:<8} {:>12} {:>12} {:>9}",
             "walk", "batch", "traced(ms)", "off(ms)", "overhead");
    println!("{}", "-".repeat(62));

    let model = std::sync::Arc::new(every_op_model());
    let batch = 2usize;
    let inputs = |seed: u64| -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        (0..batch).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
    };

    for fuse in [false, true] {
        let label = if fuse { "everyop-fused" } else { "everyop-unfused" };
        let reps = 7usize;
        let mut cfg = SessionConfig::new("artifacts/hlo");
        cfg.opts.fuse = fuse;
        let t_off = time(reps, || {
            black_box(run_inference(&model, inputs(70), &cfg).unwrap());
        });
        cfg.trace = true;
        let t_on = time(reps, || {
            black_box(run_inference(&model, inputs(70), &cfg).unwrap());
        });
        println!("{:<18} {:<8} {:>12.3} {:>12.3} {:>8.2}x",
                 label, batch, t_on * 1e3, t_off * 1e3, t_on / t_off);
        rows.push(Row { section: "traced_vs_untraced", op: label.into(),
                        n: batch, baseline_ms: t_on * 1e3,
                        fast_ms: t_off * 1e3 });

        // deterministic structure rows: party 0's span counts
        let rep = run_inference(&model, inputs(70), &cfg).unwrap();
        let spans = &rep.traces[0];
        let lockstep = spans.iter()
            .filter(|s| matches!(s.kind, SpanKind::Request | SpanKind::Op
                                 | SpanKind::Protocol))
            .count();
        let flights = spans.iter()
            .filter(|s| s.kind == SpanKind::Flight
                    && s.label.as_str() == "send")
            .count();
        println!("{:<18} {:<8} {:>11} lock-step span(s), {} send \
                  flight(s)",
                 "", "", lockstep, flights);
        rows.push(Row { section: "obs_spans_bytes",
                        op: format!("lockstep-{label}"), n: batch,
                        baseline_ms: lockstep as f64,
                        fast_ms: lockstep as f64 });
        rows.push(Row { section: "obs_spans_bytes",
                        op: format!("flights-{label}"), n: batch,
                        baseline_ms: flights as f64,
                        fast_ms: flights as f64 });
        println!();
    }
}

/// Tier 8: the model zoo -- the paper's real exported workload from
/// the committed fixtures (fixtures/zoo).  Latency rows compare the
/// fused against the unfused secure walk on real test images;
/// `zoo_layer_bytes` rows pin party 0's per-layer wire bytes with the
/// layer's round count in the `n` column, so any change to a served
/// layer's wire shape on the real models fails the exact gate -- the
/// per-layer analogue of `tests/budgets.rs`, priced on the zoo graphs.
fn zoo_tier(rows: &mut Vec<Row>) {
    use cbnn::datasets::EvalSet;
    use cbnn::engine::session::{run_inference, SessionConfig};
    use cbnn::nn::Model;
    use std::sync::Arc;

    println!("== tier 8: model zoo (committed fixtures) ==\n");
    println!("{:<10} {:<8} {:>12} {:>12} {:>9}",
             "model", "batch", "unfused(ms)", "fused(ms)", "speedup");
    println!("{}", "-".repeat(60));

    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent().expect("workspace root")
        .join("fixtures").join("zoo");
    for (name, batch, reps) in [("lenet5", 2usize, 5usize), ("vgg7", 1, 3)]
    {
        let model = Arc::new(
            Model::load(&dir.join(format!("{name}.manifest.json")))
                .expect("zoo fixtures are committed"));
        let set = EvalSet::load(
            &dir.join(format!("{}_subset.bin", model.dataset))).unwrap();
        let inputs: Vec<Tensor> =
            set.images.iter().take(batch).cloned().collect();
        let ucfg = SessionConfig::new("artifacts/hlo");
        let mut fcfg = SessionConfig::new("artifacts/hlo");
        fcfg.opts.fuse = true;
        let u0 = run_inference(&model, inputs.clone(), &ucfg).unwrap();
        let f0 = run_inference(&model, inputs.clone(), &fcfg).unwrap();
        assert_eq!(u0.logits, f0.logits,
                   "{name}: fused walk diverged on the zoo fixture");
        let u_ms = time(reps, || {
            black_box(run_inference(&model, inputs.clone(), &ucfg)
                      .unwrap());
        }) * 1e3;
        let f_ms = time(reps, || {
            black_box(run_inference(&model, inputs.clone(), &fcfg)
                      .unwrap());
        }) * 1e3;
        println!("{:<10} {:<8} {:>12.3} {:>12.3} {:>8.1}x",
                 name, batch, u_ms, f_ms, u_ms / f_ms);
        rows.push(Row { section: "zoo_fused_vs_unfused", op: name.into(),
                        n: batch, baseline_ms: u_ms, fast_ms: f_ms });
        // deterministic wire rows: the unfused walk names every layer,
        // both walks contribute their totals
        for (tag, rep0) in [("unfused", &u0), ("fused", &f0)] {
            let (mut bytes, mut rounds) = (0u64, 0u64);
            for c in &rep0.op_costs {
                if tag == "unfused" {
                    rows.push(Row {
                        section: "zoo_layer_bytes",
                        op: format!("{name}/{:02}-{}", c.index, c.op),
                        n: c.rounds as usize,
                        baseline_ms: c.bytes_sent as f64,
                        fast_ms: c.bytes_sent as f64,
                    });
                }
                bytes += c.bytes_sent;
                rounds += c.rounds;
            }
            rows.push(Row { section: "zoo_layer_bytes",
                            op: format!("{name}/total-{tag}"),
                            n: rounds as usize,
                            baseline_ms: bytes as f64,
                            fast_ms: bytes as f64 });
        }
    }
    println!();
}

/// Tier 9: the request plane.  The same request stream is priced twice
/// over the identical trunc-free model: one sample per `Service::infer`
/// call (the serial arm -- every request pays its own protocol rounds)
/// vs three concurrent tenants through the `RequestPlane`'s dynamic
/// batcher (windows coalesce, rounds amortize across the window).  The
/// `serve_shed_counts` rows then pin the admission-control outcomes of
/// two deterministic overload scenarios exactly: a structurally-dry
/// bank sheds every submit with zero request-path underflows, and an
/// over-capacity queue sheds the excess while `shutdown` still drains
/// everything admitted.
fn serve_tier(rows: &mut Vec<Row>) {
    use cbnn::coordinator::{BatcherPolicy, ModelSpec, PlaneConfig,
                            RegistryError, RequestPlane, Service};
    use cbnn::engine::session::SessionConfig;
    use cbnn::offline::BankConfig;
    use cbnn::testutil::threeparty::sep_chain_model;
    use std::sync::Arc;
    use std::time::Duration;

    println!("== tier 9: request plane, batched vs serial ==\n");
    println!("{:<18} {:<8} {:>12} {:>12} {:>9}",
             "stream", "reqs", "serial(ms)", "batched(ms)", "speedup");
    println!("{}", "-".repeat(62));

    let model = Arc::new(sep_chain_model());
    let flat = {
        let (c, h, w) = model.input;
        c * h * w
    };
    let requests = 24usize;
    let tenants = 3usize;
    let images: Vec<Tensor> = {
        let mut rng = Rng::new(9_000);
        (0..requests).map(|_| rng.tensor_small(&[1, flat], 15)).collect()
    };

    // serial arm: one request per secure batch
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.max_batch = 1;
    let svc = Service::start(Arc::clone(&model), cfg).unwrap();
    let t0 = Instant::now();
    for img in &images {
        black_box(svc.infer(vec![img.clone()]).unwrap());
    }
    let serial_ms = t0.elapsed().as_secs_f64() * 1e3 / requests as f64;
    let _ = svc.shutdown();

    // batched arm: concurrent tenants through the plane
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.max_batch = 8;
    let plane = RequestPlane::start(
        vec![ModelSpec::new("sepchain".to_string(), Arc::clone(&model))],
        &cfg,
        PlaneConfig {
            policy: BatcherPolicy {
                max_batch: 8,
                slo: Duration::from_millis(5),
                max_queue: 64,
                prefetch: 2,
                adaptive: false,
            },
            shards: 1,
        }).unwrap();
    let t1 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..tenants {
            let plane = &plane;
            let images = &images;
            let tenant = format!("t{t}");
            s.spawn(move || {
                let rxs: Vec<_> = (t..requests).step_by(tenants)
                    .map(|k| plane.submit("sepchain", &tenant,
                                          images[k].clone()).unwrap())
                    .collect();
                for rx in rxs {
                    black_box(rx.recv().unwrap().unwrap());
                }
            });
        }
    });
    let batched_ms = t1.elapsed().as_secs_f64() * 1e3 / requests as f64;
    let coalesced = plane.batcher("sepchain").unwrap()
        .stats().plane.coalesced_max;
    let _ = plane.shutdown();
    println!("{:<18} {:<8} {:>12.3} {:>12.3} {:>8.1}x  (max window {})",
             "3-tenant", requests, serial_ms, batched_ms,
             serial_ms / batched_ms, coalesced);
    rows.push(Row { section: "batched_vs_serial",
                    op: "sepchain-3tenant".into(), n: requests,
                    baseline_ms: serial_ms, fast_ms: batched_ms });

    // deterministic admission counters (exact-gated): a structurally
    // dry bank sheds every submit before any mint...
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.max_batch = 4;
    let plane = RequestPlane::start(
        vec![ModelSpec {
            name: "sepchain".to_string(),
            model: Arc::clone(&model),
            bank: Some(BankConfig { low: 1, high: 2, chunk: 1,
                                    capacity: 3 }),
        }],
        &cfg,
        PlaneConfig { policy: BatcherPolicy { max_batch: 4,
                                              ..BatcherPolicy::default() },
                      shards: 1 }).unwrap();
    for img in images.iter().take(6).cloned() {
        assert!(matches!(
            plane.submit("sepchain", "dry", img),
            Err(RegistryError::Overloaded { .. })));
    }
    let b = plane.batcher("sepchain").unwrap();
    let (shed_dry, underflows) =
        (b.stats().plane.shed_dry, b.preproc_metrics().underflow_calls);
    let _ = plane.shutdown();
    println!("{:<18} {:<8} dry-bank sheds={} underflows={}",
             "shed-dry", 6, shed_dry, underflows);
    rows.push(Row { section: "serve_shed_counts",
                    op: "dry-bank-shed".into(), n: 6,
                    baseline_ms: shed_dry as f64,
                    fast_ms: shed_dry as f64 });
    rows.push(Row { section: "serve_shed_counts",
                    op: "dry-bank-underflows".into(), n: 6,
                    baseline_ms: underflows as f64,
                    fast_ms: underflows as f64 });

    // ...and an over-capacity queue sheds the excess, then shutdown
    // drains everything admitted
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.max_batch = 8;
    let plane = RequestPlane::start(
        vec![ModelSpec::new("sepchain".to_string(), Arc::clone(&model))],
        &cfg,
        PlaneConfig {
            policy: BatcherPolicy {
                max_batch: 8,
                slo: Duration::from_secs(30),
                max_queue: 4,
                prefetch: 2,
                adaptive: false,
            },
            shards: 1,
        }).unwrap();
    let mut admitted = Vec::new();
    let mut shed_queue = 0u64;
    for img in images.iter().take(10).cloned() {
        match plane.submit("sepchain", "flood", img) {
            Ok(rx) => admitted.push(rx),
            Err(RegistryError::Overloaded { .. }) => shed_queue += 1,
            Err(e) => panic!("{e}"),
        }
    }
    let drained = std::thread::scope(|s| {
        let h = s.spawn(move || {
            admitted.into_iter()
                .filter(|rx| rx.recv().map(|r| r.is_ok())
                        .unwrap_or(false))
                .count() as u64
        });
        let _ = plane.shutdown();
        h.join().unwrap()
    });
    println!("{:<18} {:<8} queue sheds={} drained={}",
             "shed-queue", 10, shed_queue, drained);
    rows.push(Row { section: "serve_shed_counts",
                    op: "queue-full-shed".into(), n: 10,
                    baseline_ms: shed_queue as f64,
                    fast_ms: shed_queue as f64 });
    rows.push(Row { section: "serve_shed_counts",
                    op: "drain-served".into(), n: 10,
                    baseline_ms: drained as f64,
                    fast_ms: drained as f64 });
    println!();
}

fn write_json(file: &str, bench: &str, acceptance: &[(&str, &str)],
              rows: &[Row]) {
    let mut s = String::from("{\n");
    let _ = writeln!(s, "  \"bench\": \"{bench}\",");
    let _ = writeln!(s,
        "  \"generated_by\": \"cargo bench --bench bitops\",");
    let _ = writeln!(s, "  \"acceptance\": {{");
    for (i, (k, v)) in acceptance.iter().enumerate() {
        let comma = if i + 1 == acceptance.len() { "" } else { "," };
        let _ = writeln!(s, "    \"{k}\": \"{v}\"{comma}");
    }
    let _ = writeln!(s, "  }},");
    let _ = writeln!(s, "  \"results\": [");
    for (i, r) in rows.iter().enumerate() {
        let comma = if i + 1 == rows.len() { "" } else { "," };
        let _ = writeln!(s,
            "    {{\"section\": \"{}\", \"op\": \"{}\", \"n\": {}, \
             \"baseline_ms\": {:.4}, \"fast_ms\": {:.4}, \
             \"speedup\": {:.2}}}{comma}",
            r.section, r.op, r.n, r.baseline_ms, r.fast_ms, r.speedup());
    }
    let _ = writeln!(s, "  ]");
    s.push_str("}\n");
    // the bench target's manifest dir is rust/; the record lives at the
    // workspace root next to DESIGN.md
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.join(file))
        .unwrap_or_else(|| file.into());
    match std::fs::write(&path, &s) {
        Ok(()) => println!("recorded {}", path.display()),
        Err(e) => eprintln!("could not record {}: {e}", path.display()),
    }
}

fn main() {
    let mut rows = Vec::new();
    representation_tier(&mut rows);
    kernel_tier(&mut rows);
    plane_tier(&mut rows);
    let mut offline_rows = Vec::new();
    offline_tier(&mut offline_rows);
    let mut fusion_rows = Vec::new();
    fusion_tier(&mut fusion_rows);
    let mut wan_rows = Vec::new();
    wan_tier(&mut wan_rows);
    let mut obs_rows = Vec::new();
    obs_tier(&mut obs_rows);
    let mut zoo_rows = Vec::new();
    zoo_tier(&mut zoo_rows);
    let mut serve_rows = Vec::new();
    serve_tier(&mut serve_rows);
    println!("(acceptance: packed XOR/AND >= 8x byte-per-bit; strided \
              Kogge-Stone levels >= 2x concat; warm-bank online MSB \
              >= 2x inline generation; fused hidden segment >= 8x fewer \
              bytes than the arithmetic walk; WAN virtual latency <= \
              critical-path rounds x RTT x 1.25; tracing overhead a \
              small constant factor, span counts deterministic)");
    write_json("BENCH_bitops.json", "bitops",
               &[("byte_vs_packed", "xor/and speedup >= 8x"),
                 ("ks_concat_vs_strided", "ks-5lvl speedup >= 2x")],
               &rows);
    write_json("BENCH_offline.json", "offline",
               &[("warm_bank_vs_inline",
                  "online-only msb latency >= 2x faster than inline \
                   generation")],
               &offline_rows);
    write_json("BENCH_fusion.json", "fusion",
               &[("fused_vs_unfused_bytes",
                  "fused hidden segment ships >= 8x fewer online bytes \
                   than the arithmetic walk")],
               &fusion_rows);
    write_json("BENCH_wan.json", "wan",
               &[("lan_vs_wan_virtual",
                  "virtual-clock WAN latency stays within critical-path \
                   rounds x 160ms RTT x 1.25; the n column pins the \
                   round count")],
               &wan_rows);
    write_json("BENCH_obs.json", "obs",
               &[("traced_vs_untraced",
                  "full tracing stays a small constant factor over the \
                   untraced walk; tracing off costs one atomic load per \
                   potential span"),
                 ("obs_spans_bytes",
                  "per-party lock-step span and send-flight counts are \
                   deterministic per walk; any drift is a choreography \
                   change")],
               &obs_rows);
    write_json("BENCH_zoo.json", "zoo",
               &[("zoo_fused_vs_unfused",
                  "fused secure walk no slower than the arithmetic walk \
                   on the exported lenet5/vgg7 fixtures"),
                 ("zoo_layer_bytes",
                  "per-layer bytes and rounds on the zoo graphs are \
                   deterministic; any drift is a wire-format change on \
                   the paper's real workload")],
               &zoo_rows);
    write_json("BENCH_serve.json", "serve",
               &[("batched_vs_serial",
                  "dynamic batching serves the concurrent multi-tenant \
                   stream no slower per request than the serial arm"),
                 ("serve_shed_counts",
                  "admission-control outcomes are deterministic: shed \
                   counts, zero request-path underflows on a dry-bank \
                   burst, and full drain of admitted requests are \
                   pinned exactly")],
               &serve_rows);
}
