//! Boolean-share representation micro-benchmark: byte-per-bit (the seed's
//! `Vec<u8>` representation) vs word-packed `BitTensor`, across the local
//! operations that dominate the non-linear protocol path:
//!
//!   * XOR      -- every share combine / public unmask
//!   * AND      -- the local term of the boolean multiplication
//!   * B2A-prep -- y_1 ^ y_2 followed by the per-element message walk
//!                 (the sender side of the share conversion)
//!
//! At 10^4..10^7 elements the packed path should show >= 8x XOR/AND
//! throughput (64 bits per instruction vs one byte per bit, minus memory
//! effects); the measured ratio is printed so the bench trajectory records
//! the representation change.
//!
//!   cargo bench --bench bitops

use std::hint::black_box;
use std::time::Instant;

use cbnn::ring::bits::BitTensor;
use cbnn::testutil::Rng;

/// Median-of-reps wall time for `f`, in seconds.
fn time(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    times[times.len() / 2]
}

// ---- byte-per-bit reference (exactly the seed's BitShare ops) -----------
fn bytes_xor(a: &[u8], b: &[u8]) -> Vec<u8> {
    a.iter().zip(b).map(|(x, y)| x ^ y).collect()
}

fn bytes_and(a: &[u8], b: &[u8]) -> Vec<u8> {
    a.iter().zip(b).map(|(x, y)| x & y).collect()
}

fn main() {
    println!("== boolean share ops: byte-per-bit vs word-packed ==\n");
    println!("{:<10} {:<10} {:>12} {:>12} {:>9}",
             "op", "elems", "bytes(ms)", "packed(ms)", "speedup");
    println!("{}", "-".repeat(58));

    for &n in &[10_000usize, 100_000, 1_000_000, 10_000_000] {
        let reps = if n >= 1_000_000 { 5 } else { 20 };
        let mut rng = Rng::new(n as u64);
        let xa: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
        let xb: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
        let ta = BitTensor::from_bits(&xa);
        let tb = BitTensor::from_bits(&xb);

        // XOR
        let t_bytes = time(reps, || {
            black_box(bytes_xor(black_box(&xa), black_box(&xb)));
        });
        let t_packed = time(reps, || {
            black_box(black_box(&ta).xor(black_box(&tb)));
        });
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.1}x",
                 "xor", n, t_bytes * 1e3, t_packed * 1e3,
                 t_bytes / t_packed);

        // AND
        let t_bytes = time(reps, || {
            black_box(bytes_and(black_box(&xa), black_box(&xb)));
        });
        let t_packed = time(reps, || {
            black_box(black_box(&ta).and(black_box(&tb)));
        });
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.1}x",
                 "and", n, t_bytes * 1e3, t_packed * 1e3,
                 t_bytes / t_packed);

        // B2A-prep: the boolean part of the sender's message construction
        // (y12 = y1 ^ y2 for the whole batch).  The subsequent per-element
        // ring arithmetic is identical in both representations, so the
        // boolean half is what the refactor buys.
        let t_bytes = time(reps, || {
            let y12 = bytes_xor(&xa, &xb);
            black_box(y12.iter().map(|&b| b as u64).sum::<u64>());
        });
        let t_packed = time(reps, || {
            let y12 = ta.xor(&tb);
            black_box(y12.popcount());
        });
        println!("{:<10} {:<10} {:>12.3} {:>12.3} {:>8.1}x",
                 "b2a-prep", n, t_bytes * 1e3, t_packed * 1e3,
                 t_bytes / t_packed);
        println!();
    }
    println!("(acceptance: packed XOR/AND >= 8x byte-per-bit; 64 bits per \
              word op)");
}
