//! Shared helpers for the custom bench harnesses (criterion is not in the
//! offline crate set; every bench is a `harness = false` binary printing
//! paper-style tables).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, SessionConfig, SessionReport};
use cbnn::jsonio;
use cbnn::nn::Model;
use cbnn::transport::NetConfig;

pub fn art() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn require_artifacts() {
    if !art().join("models").exists() {
        eprintln!("artifacts/ missing -- run `make artifacts` first");
        std::process::exit(0);
    }
}

pub fn load_model(name: &str) -> Arc<Model> {
    Arc::new(Model::load(
        &art().join("models").join(format!("{name}.manifest.json")))
        .unwrap_or_else(|e| panic!("loading {name}: {e}")))
}

pub fn eval_data(model: &Model) -> EvalSet {
    EvalSet::load(&art().join("data").join(format!("{}.bin", model.dataset)))
        .expect("eval data")
}

/// Median online time + the report of the median run.
pub fn measure(model: &Arc<Model>, data: &EvalSet, net: NetConfig,
               batch: usize, reps: usize) -> (f64, SessionReport) {
    let cfg = SessionConfig::new(art().join("hlo")).with_net(net);
    let mut times = Vec::new();
    let mut last = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let rep = run_inference(model, data.images[..batch].to_vec(), &cfg)
            .expect("inference");
        times.push(t0.elapsed().as_secs_f64());
        last = Some(rep);
    }
    times.sort_by(f64::total_cmp);
    (times[times.len() / 2], last.unwrap())
}

/// Per-sample time, amortized over a batch (the paper reports batch-1
/// times; we report both).
pub fn per_sample(t: f64, batch: usize) -> f64 {
    t / batch as f64
}

/// Secure-inference accuracy recorded at export time
/// (artifacts/experiments/secure_acc.json).
pub fn exported_accuracy(name: &str) -> Option<f64> {
    let text = std::fs::read_to_string(
        art().join("experiments/secure_acc.json")).ok()?;
    let j = jsonio::parse(&text).ok()?;
    j.get(name)?.get("fixed_acc")?.as_f64().map(|a| a * 100.0)
}

pub fn exported_params(name: &str) -> Option<i64> {
    let text = std::fs::read_to_string(
        art().join("experiments/secure_acc.json")).ok()?;
    let j = jsonio::parse(&text).ok()?;
    j.get(name)?.get("params")?.as_i64()
}

pub fn header() {
    println!("{:<22} {:>10} {:>10} {:>10} {:>7}",
             "framework", "LAN(s)", "WAN(s)", "Comm(MB)", "Acc(%)");
    println!("{}", "-".repeat(64));
}
