//! Design-choice ablations (exps A1-A4 in DESIGN.md):
//!
//!  A1  MSB: CBNN Algorithm 3 vs SecureBiNN-style bit decomposition
//!  A2  maxpool: Sign-fused (Sec 3.6) vs comparison tree
//!  A3  BN: export-time fusing (Sec 3.5) vs explicit online BN
//!  A4  linear backend: PJRT-pallas vs PJRT-xla vs native rust
//!
//!   cargo bench --bench ablations

mod common;

use std::thread;
use std::time::Instant;

use cbnn::baselines::{bitdecomp::msb_bitdecomp, bn_explicit::bn_online,
                      maxpool_tree::maxpool_tree};
use cbnn::prf::PartySeeds;
use cbnn::protocols::{maxpool::maxpool_bits, msb::msb_extract, Ctx};
use cbnn::rss::deal;
use cbnn::runtime::{BackendKind, KernelVariant};
use cbnn::engine::session::{run_inference, SessionConfig};
use cbnn::testutil::Rng;
use cbnn::transport::{local_trio, NetConfig, Stats};
use common::*;

fn run3<F>(net: NetConfig, f: F) -> (f64, [Stats; 3])
where
    F: Fn(&Ctx) + Send + Sync + Copy + 'static,
{
    let comms = local_trio(net);
    let t0 = Instant::now();
    let handles: Vec<_> = comms.into_iter().map(|c| {
        thread::spawn(move || {
            let seeds = PartySeeds::setup(5, c.id);
            let ctx = Ctx::new(&c, &seeds);
            f(&ctx);
            c.stats()
        })
    }).collect();
    let stats: Vec<Stats> = handles.into_iter().map(|h| h.join().unwrap())
        .collect();
    (t0.elapsed().as_secs_f64(), stats.try_into().expect("three parties"))
}

fn report(label: &str, (t, st): (f64, [Stats; 3])) {
    let bytes: u64 = st.iter().map(|s| s.bytes_sent).sum();
    let rounds = st.iter().map(|s| s.rounds).max().unwrap();
    println!("{:<28} {:>10.2} {:>12.1} {:>8}", label, t * 1e3,
             bytes as f64 / 1e3, rounds);
}

fn main() {
    println!("== ablations ==\n");
    let n = 16_384; // one mid-size activation map

    println!("[A1] MSB extraction, n={n}, WAN");
    println!("{:<28} {:>10} {:>12} {:>8}", "arm", "time(ms)", "KB sent",
             "rounds");
    report("Alg3 (ours, const-round)", run3(NetConfig::wan(),
        move |ctx: &Ctx| {
            let mut rng = Rng::new(1);
            let x = rng.tensor_small(&[n], 1 << 20);
            let xs = deal(&x, &mut rng);
            let _ = msb_extract(ctx, &xs[ctx.id()]).unwrap();
        }));
    report("bit-decomp (SecureBiNN-ish)", run3(NetConfig::wan(),
        move |ctx: &Ctx| {
            let mut rng = Rng::new(1);
            let x = rng.tensor_small(&[n], 1 << 20);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap();
        }));

    println!("\n[A2] 2x2 maxpool over 16x16x16 bits, WAN");
    report("Sign-fused (Sec 3.6)", run3(NetConfig::wan(), |ctx: &Ctx| {
        let mut rng = Rng::new(2);
        let bits = cbnn::ring::Tensor::from_vec(
            &[16, 256], (0..16 * 256).map(|i| i as i32 % 2).collect());
        let xs = deal(&bits, &mut rng);
        let _ = maxpool_bits(ctx, &xs[ctx.id()], 16, 16, 16, 2, 2).unwrap();
    }));
    report("comparison tree", run3(NetConfig::wan(), |ctx: &Ctx| {
        let mut rng = Rng::new(2);
        let x = rng.tensor_small(&[16, 256], 1 << 16);
        let xs = deal(&x, &mut rng);
        let _ = maxpool_tree(ctx, &xs[ctx.id()], 16, 16, 16).unwrap();
    }));

    println!("\n[A3] batch norm over 64x256 activations, WAN");
    report("fused at export (ours)", run3(NetConfig::wan(), |_ctx: &Ctx| {
        // zero online cost -- the threshold add happens inside Sign
    }));
    report("explicit online BN", run3(NetConfig::wan(), |ctx: &Ctx| {
        let mut rng = Rng::new(3);
        let x = rng.tensor_small(&[64, 256], 1 << 12);
        let g = rng.tensor_small(&[64], 1 << 8);
        let b = rng.tensor_small(&[64], 1 << 8);
        let xs = deal(&x, &mut rng);
        let gs = deal(&g, &mut rng);
        let bs = deal(&b, &mut rng);
        let _ = bn_online(ctx, &xs[ctx.id()], &gs[ctx.id()],
                          &bs[ctx.id()], 8).unwrap();
    }));

    require_artifacts();
    println!("\n[A4] linear backend, mnistnet3 end-to-end (LAN, batch=4)");
    println!("{:<28} {:>12} {:>12}", "backend", "online(ms)", "per-img(ms)");
    let model = load_model("mnistnet3");
    let data = eval_data(&model);
    // PJRT arms only when the feature (and a real xla crate) is built in
    let mut arms = vec![("native rust", BackendKind::Native)];
    if cfg!(feature = "pjrt") {
        arms.push(("PJRT + pallas kernel",
                   BackendKind::Pjrt(KernelVariant::Pallas)));
        arms.push(("PJRT + xla lowering",
                   BackendKind::Pjrt(KernelVariant::Xla)));
    }
    for (label, kind) in arms {
        let cfg = SessionConfig::new(art().join("hlo"))
            .with_net(NetConfig::lan()).with_backend(kind);
        // warm once (compile executables), then time
        let _ = run_inference(&model, data.images[..1].to_vec(), &cfg);
        let mut times = Vec::new();
        for _ in 0..3 {
            let rep = run_inference(&model, data.images[..4].to_vec(), &cfg)
                .expect("inference");
            times.push(rep.online.as_secs_f64());
        }
        times.sort_by(f64::total_cmp);
        let t = times[times.len() / 2];
        println!("{:<28} {:>12.2} {:>12.2}", label, t * 1e3, t * 1e3 / 4.0);
    }
    println!("\n(PJRT recompiles per session; the coordinator's Service \
              amortizes that via warmup -- see e2e_serve)");
}
