//! Table 3 reproduction: CIFAR-10 (CifarNet2) against prior frameworks.
//!
//!   cargo bench --bench table3_cifar
//!
//! Shape to reproduce: CBNN in front on LAN among the 3PC frameworks and
//! clearly in front on WAN (constant-round non-linear protocols); the 2PC
//! / HE frameworks (MiniONN..XONN) are orders of magnitude behind.

mod common;

use cbnn::baselines::costmodel::{fmt_row, table3};
use cbnn::transport::NetConfig;
use common::*;

fn main() {
    require_artifacts();
    println!("== Table 3: CIFAR-10, CifarNet2, batch=1 ==\n");
    header();
    for row in table3() {
        println!("{}", fmt_row(&format!("{} (paper)", row.framework),
                               row.time_lan_s, row.time_wan_s, row.comm_mb,
                               row.acc_pct));
    }
    let model = load_model("cifarnet2");
    let data = eval_data(&model);
    let (lan, rep) = measure(&model, &data, NetConfig::lan(), 1, 3);
    let (wan, _) = measure(&model, &data, NetConfig::wan(), 1, 3);
    println!("{}", fmt_row("CBNN(ours,measured)", Some(lan), Some(wan),
                           Some(rep.comm_mb()),
                           exported_accuracy("cifarnet2")));
    println!("\nrounds={}  setup={:.3}s  (batch=8 amortized: see \
              e2e_serve example)", rep.max_rounds(),
             rep.setup.as_secs_f64());
    println!("note: our accuracy is on synth-CIFAR with the quick training \
              budget (DESIGN.md); time/comm columns are shape-comparable.");
}
