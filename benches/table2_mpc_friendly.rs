//! Table 2 reproduction: the effect of MPC-friendly (separable)
//! convolutions -- typical BNN vs CifarNet2 on CIFAR-10 shapes.
//!
//!   cargo bench --bench table2_mpc_friendly
//!
//! Shape to reproduce: the customized network cuts parameters by ~80%,
//! communication by ~35%, and WAN time by a large factor, at a small
//! accuracy cost (paper: -1.99 points).

mod common;

use cbnn::baselines::costmodel::{fmt_row, table2};
use cbnn::transport::NetConfig;
use common::*;

fn main() {
    require_artifacts();
    println!("== Table 2: typical BNN vs MPC-friendly CifarNet2 ==\n");
    let paper = table2();
    println!("{:<22} {:>10} {:>10} {:>10} {:>7} {:>9}",
             "arch", "LAN(s)", "WAN(s)", "Comm(MB)", "Acc(%)", "Params");
    println!("{}", "-".repeat(74));
    println!("{} {:>9}", fmt_row("Typical BNN (paper)",
                                 paper.typical.time_lan_s,
                                 paper.typical.time_wan_s,
                                 paper.typical.comm_mb,
                                 paper.typical.acc_pct), 383_858);
    println!("{} {:>9}", fmt_row("CifarNet2 (paper)",
                                 paper.cifarnet2.time_lan_s,
                                 paper.cifarnet2.time_wan_s,
                                 paper.cifarnet2.comm_mb,
                                 paper.cifarnet2.acc_pct), 67_949);
    println!();

    let mut ours = Vec::new();
    for name in ["cifarnet2_typical", "cifarnet2"] {
        let model = load_model(name);
        let data = eval_data(&model);
        let (lan, rep) = measure(&model, &data, NetConfig::lan(), 1, 3);
        let (wan, _) = measure(&model, &data, NetConfig::wan(), 1, 3);
        let params = exported_params(name).unwrap_or(0);
        println!("{} {:>9}", fmt_row(&format!("{name} (ours)"), Some(lan),
                                     Some(wan), Some(rep.comm_mb()),
                                     exported_accuracy(name)), params);
        ours.push((lan, wan, rep.comm_mb(),
                   exported_accuracy(name).unwrap_or(0.0), params as f64));
    }
    let ch = |a: f64, b: f64| 100.0 * (b - a) / a;
    println!("\n{:<22} {:>9.1}% {:>9.1}% {:>9.1}% {:>6.2} {:>8.1}%",
             "Change (ours)",
             ch(ours[0].0, ours[1].0), ch(ours[0].1, ours[1].1),
             ch(ours[0].2, ours[1].2), ours[1].3 - ours[0].3,
             ch(ours[0].4, ours[1].4));
    println!("{:<22} {:>9}% {:>9}% {:>9}% {:>6} {:>8}%",
             "Change (paper)", -41.5, -72.1, -35.8, -1.99, -82.3);
}
