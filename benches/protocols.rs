//! Protocol microbenchmarks (exp P1): per-op time, bytes, and rounds for
//! every secure primitive at several element counts, on the zero-latency
//! network (pure compute + accounting) and on WAN (round-dominated).
//!
//!   cargo bench --bench protocols

use std::thread;
use std::time::Instant;

use cbnn::prf::PartySeeds;
use cbnn::protocols::{msb::msb_extract, relu::{relu_mul, relu_ot},
                      sign::sign, trunc::trunc, Ctx};
use cbnn::rss::{self, deal, deal_bits};
use cbnn::testutil::Rng;
use cbnn::transport::{local_trio, NetConfig, Stats};

fn run3<F>(net: NetConfig, f: F) -> (f64, [Stats; 3])
where
    F: Fn(&Ctx) + Send + Sync + Copy + 'static,
{
    let comms = local_trio(net);
    let t0 = Instant::now();
    let handles: Vec<_> = comms.into_iter().map(|c| {
        thread::spawn(move || {
            let seeds = PartySeeds::setup(5, c.id);
            let ctx = Ctx::new(&c, &seeds);
            f(&ctx);
            c.stats()
        })
    }).collect();
    let stats: Vec<Stats> = handles.into_iter().map(|h| h.join().unwrap())
        .collect();
    (t0.elapsed().as_secs_f64(), stats.try_into().expect("three parties"))
}

macro_rules! bench_proto {
    ($name:expr, $n:expr, $net:expr, $body:expr) => {{
        let (t, st) = run3($net, $body);
        let bytes: u64 = st.iter().map(|s| s.bytes_sent).sum();
        let rounds = st.iter().map(|s| s.rounds).max().unwrap();
        println!("{:<14} {:>9} {:>11.2} {:>11.1} {:>8}",
                 $name, $n, t * 1e3, bytes as f64 / 1e3, rounds);
    }};
}

fn main() {
    println!("== protocol microbenchmarks ==");
    for (netname, net) in [("zero-net", NetConfig::zero()),
                           ("wan", NetConfig::wan())] {
        println!("\n[{netname}]");
        println!("{:<14} {:>9} {:>11} {:>11} {:>8}",
                 "protocol", "elems", "time(ms)", "KB sent", "rounds");
        println!("{}", "-".repeat(58));
        let sizes: &[usize] = if netname == "wan" {
            &[10_000]
        } else {
            &[1_000, 10_000, 100_000]
        };
        for &n in sizes {
            bench_proto!("reshare", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(1);
                let z = rng.tensor(&[n]);
                let _ = rss::reshare(ctx.comm, ctx.seeds, &z).unwrap();
            });
            bench_proto!("mul", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(2);
                let x = rng.tensor(&[n]);
                let y = rng.tensor(&[n]);
                let xs = deal(&x, &mut rng);
                let ys = deal(&y, &mut rng);
                let _ = rss::mul(ctx.comm, ctx.seeds, &xs[ctx.id()],
                                 &ys[ctx.id()]).unwrap();
            });
            bench_proto!("b2a(3-OT)", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(3);
                let bits: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
                let bs = deal_bits(&bits, &mut rng);
                let _ = cbnn::protocols::b2a::b2a(ctx, &bs[ctx.id()])
                    .unwrap();
            });
            bench_proto!("msb(Alg3)", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(4);
                let x = rng.tensor_small(&[n], 1 << 20);
                let xs = deal(&x, &mut rng);
                let _ = msb_extract(ctx, &xs[ctx.id()]).unwrap();
            });
            bench_proto!("sign(Alg4)", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(5);
                let x = rng.tensor_small(&[n], 1 << 20);
                let xs = deal(&x, &mut rng);
                let _ = sign(ctx, &xs[ctx.id()]).unwrap();
            });
            bench_proto!("relu_ot(Alg5)", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(6);
                let x = rng.tensor_small(&[n], 1 << 20);
                let xs = deal(&x, &mut rng);
                let m = msb_extract(ctx, &xs[ctx.id()]).unwrap();
                let _ = relu_ot(ctx, &xs[ctx.id()], &m).unwrap();
            });
            bench_proto!("relu_mul", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(7);
                let x = rng.tensor_small(&[n], 1 << 20);
                let xs = deal(&x, &mut rng);
                let m = msb_extract(ctx, &xs[ctx.id()]).unwrap();
                let _ = relu_mul(ctx, &xs[ctx.id()], &m).unwrap();
            });
            bench_proto!("trunc", n, net, move |ctx: &Ctx| {
                let mut rng = Rng::new(8);
                let x = rng.tensor_small(&[n], 1 << 20);
                let xs = deal(&x, &mut rng);
                let _ = trunc(ctx, &xs[ctx.id()], 12).unwrap();
            });
        }
    }
    println!("\nDESIGN.md round budgets: reshare 1, mul 1, b2a<=3, \
              msb<=8, trunc 2.");
}
