//! Documentation gate (runs in the CI `docs` job next to the rustdoc
//! `-D warnings` build): OPERATIONS.md must cover every `serve` flag
//! that exists (`cli::SERVE_FLAGS` is the single source of truth -- a
//! flag added there without documentation fails here) plus the operator
//! workflows ISSUE 4 requires it to describe.

use cbnn::cli::SERVE_FLAGS;

fn repo_doc(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{} at the repo root: {e}", name))
}

#[test]
fn operations_covers_every_serve_flag() {
    let ops = repo_doc("OPERATIONS.md");
    for flag in SERVE_FLAGS {
        assert!(ops.contains(&format!("--{flag}")),
                "OPERATIONS.md does not document `--{flag}`");
    }
}

#[test]
fn operations_covers_subcommands_and_operator_workflows() {
    let ops = repo_doc("OPERATIONS.md");
    for sub in ["serve", "infer", "acc", "info"] {
        assert!(ops.contains(&format!("`{sub}`"))
                || ops.contains(&format!("cbnn {sub}")),
                "OPERATIONS.md does not mention the `{sub}` subcommand");
    }
    // the operator topics ISSUE 4 names: party startup & dial retries,
    // metrics reading, and watermark tuning
    for needle in ["DialPolicy", "watermark", "PreprocMetrics",
                   "underflow_calls", "ChanStats"] {
        assert!(ops.contains(needle),
                "OPERATIONS.md does not cover {needle}");
    }
}

#[test]
fn operations_has_a_worked_multi_model_example() {
    let ops = repo_doc("OPERATIONS.md");
    assert!(ops.lines().any(|l| l.matches("--model").count() >= 2),
            "OPERATIONS.md has no invocation with two --model flags");
}

#[test]
fn operations_covers_the_lifecycle_runbook() {
    // ISSUE 5: the lifecycle runbook must document quarantine symptoms,
    // the swap procedure, parked-bytes sizing, and the admin surface --
    // CI-gated like the serve flags so the runbook cannot rot
    let ops = repo_doc("OPERATIONS.md");
    for needle in ["quarantine", "respawn", "Quarantined", "epoch",
                   "parked", "--max-parked-bytes", "--admin", "swap",
                   "free list", "SlotState"] {
        assert!(ops.contains(needle),
                "OPERATIONS.md lifecycle runbook misses {needle}");
    }
    // every admin command is documented
    for cmd in ["status", "add ", "remove ", "quarantine ", "respawn ",
                "infer "] {
        assert!(ops.contains(cmd),
                "OPERATIONS.md does not document admin command `{cmd}`");
    }
}

#[test]
fn design_documents_the_channel_id_space() {
    let design = repo_doc("DESIGN.md");
    for needle in ["Multi-model multiplexing", "slot << 1", "ChanId",
                   "unregistered"] {
        assert!(design.contains(needle),
                "DESIGN.md does not cover {needle}");
    }
}

#[test]
fn design_documents_binary_domain_fusion() {
    // ISSUE 6: the fusion section must state the lowering rules, the
    // XNOR+popcount evaluation, the threshold-folding algebra, and the
    // leakage argument (popcounts stay secret-shared end to end)
    let design = repo_doc("DESIGN.md");
    for needle in ["Binary-domain fusion", "XNOR", "popcount",
                   "threshold folding", "secret-shared", "carry-save",
                   "b2a", "--fuse"] {
        assert!(design.contains(needle),
                "DESIGN.md fusion section misses {needle}");
    }
    let ops = repo_doc("OPERATIONS.md");
    assert!(ops.contains("--fuse on"),
            "OPERATIONS.md does not show `--fuse on`");
}

#[test]
fn design_has_the_normative_round_budget_table() {
    // ISSUE 7: the "Round budgets" section is the normative table that
    // tests/budgets.rs parses and asserts -- gate its machine-readable
    // shape (backticked keys) and the pointer to the executing test so
    // neither can silently rot
    let design = repo_doc("DESIGN.md");
    for needle in ["## Round budgets", "normative", "budgets.rs",
                   "`msb_online`", "`relu_op`", "`b2a_boundary`",
                   "`or_pool_k2`", "`mint`", "max-party",
                   "wan_soak.rs", "virtual_now"] {
        assert!(design.contains(needle),
                "DESIGN.md round-budget section misses {needle}");
    }
}

#[test]
fn operations_documents_the_net_spec_grammar_and_wan_tuning() {
    // ISSUE 7: --net grew a custom-spec grammar and a virtual clock;
    // the operator doc must show the grammar and a WAN-tuning section
    let ops = repo_doc("OPERATIONS.md");
    for needle in ["rtt=", "lat=", "bw=", "jitter=", "`virtual`",
                   "`wall`", "WAN tuning", "BENCH_wan.json",
                   "rtt=40ms,bw=40MBps"] {
        assert!(ops.contains(needle),
                "OPERATIONS.md --net / WAN-tuning docs miss {needle}");
    }
}

#[test]
fn operations_covers_the_telemetry_plane() {
    // ISSUE 8: the telemetry docs must show the export file layout,
    // both merge front ends, the Prometheus names exactly as
    // `metrics::prometheus_text` emits them, the partial-trace caveat,
    // and the desync runbook -- gated so the contract cannot rot
    let ops = repo_doc("OPERATIONS.md");
    for needle in ["--trace-out", "--metrics-out", "trace-p0.jsonl",
                   "stats-p0.json", "cbnn trace", "trace_check.py",
                   "dropped_events", "partial", "trace on",
                   "Debugging a desync"] {
        assert!(ops.contains(needle),
                "OPERATIONS.md telemetry docs miss {needle}");
    }
    for name in ["cbnn_requests_total", "cbnn_request_latency_us",
                 "cbnn_lane_bytes_total", "cbnn_lane_rounds_total",
                 "cbnn_lane_messages_total", "cbnn_bank_minted_total",
                 "cbnn_bank_drawn_total", "cbnn_bank_underflow_total",
                 "cbnn_bank_level", "cbnn_lifecycle_quarantines_total",
                 "cbnn_lifecycle_respawns_total",
                 "cbnn_trace_dropped_events_total"] {
        assert!(ops.contains(name),
                "OPERATIONS.md metric table misses {name}");
    }
    // the new admin commands are documented next to the old ones
    for cmd in ["stats", "trace on"] {
        assert!(ops.contains(cmd),
                "OPERATIONS.md does not document admin `{cmd}`");
    }
}

#[test]
fn design_documents_the_telemetry_spine() {
    // ISSUE 8: span model, the lock-step join key, the overhead
    // argument, and the leakage argument must all be written down
    let design = repo_doc("DESIGN.md");
    for needle in ["Telemetry spine", "TraceSink", "trace_id",
                   "lock-step", "rank", "dropped_events",
                   "atomic load", "lazily allocated", "quiescence",
                   "virt_start_ns", "Leakage"] {
        assert!(design.contains(needle),
                "DESIGN.md telemetry section misses {needle}");
    }
}

#[test]
fn operations_covers_the_model_zoo_runbook() {
    // the zoo runbook must name the fixture layout, the regeneration
    // driver, the serve path, the floors, and the CI gate
    let ops = repo_doc("OPERATIONS.md");
    for needle in ["Model zoo", "fixtures/zoo", "compile.zoo",
                   "lenet5=fixtures/zoo/lenet5.manifest.json",
                   "model-parity", "golden", "accuracy floor",
                   "zoo-divergence", "BENCH_zoo"] {
        assert!(ops.contains(needle),
                "OPERATIONS.md model-zoo runbook misses {needle}");
    }
}

#[test]
fn design_argues_the_parity_tolerance() {
    // the tolerance argument must be stratified: bit-identical on the
    // sign-only zoo graphs, argmax on truncation graphs, with the
    // accuracy floors recorded
    let design = repo_doc("DESIGN.md");
    for needle in ["Parity tolerance", "bit-identical", "Sign-only",
                   "zero", "trunc-free", "argmax",
                   "floor-borrow", "0.98", "0.84"] {
        assert!(design.contains(needle),
                "DESIGN.md parity-tolerance section misses {needle}");
    }
}

#[test]
fn operations_covers_the_request_plane_runbook() {
    // ISSUE 10: the request-plane runbook must document every new serve
    // flag (the SERVE_FLAGS loop above already forces their presence --
    // this test pins the runbook section itself), the shed taxonomy and
    // its typed error, the Prometheus names the plane emits, the
    // shard-sizing guidance, and the bench + soak gates that watch it
    let ops = repo_doc("OPERATIONS.md");
    for needle in ["Request plane", "--slo-ms", "--shards",
                   "--max-queue", "--tenants", "--adaptive-bank",
                   "Overloaded", "queue-full", "bank-dry",
                   "cbnn_queue_depth", "cbnn_shed_total",
                   "cbnn_tenant_requests_total", "coalesc",
                   "BENCH_serve", "request-plane-soak"] {
        assert!(ops.contains(needle),
                "OPERATIONS.md request-plane runbook misses {needle}");
    }
}

#[test]
fn design_documents_the_request_plane() {
    // ISSUE 10: the design section must state the coalescing-window
    // model, the fairness discipline, why shedding precedes minting
    // (overload must never perturb the deterministic credit
    // accounting), the consistent-hash shard router's remap property,
    // and why adaptive watermark retunes ride the broadcast job queue
    let design = repo_doc("DESIGN.md");
    for needle in ["## Request plane", "dispatch window", "round-robin",
                   "can_serve_warm", "underflow", "bit-identical",
                   "consistent-hash", "vnode", "Job::Retune",
                   "last_window", "coalesc"] {
        assert!(design.contains(needle),
                "DESIGN.md request-plane section misses {needle}");
    }
}

#[test]
fn readme_maps_paper_sections_to_modules() {
    let readme = repo_doc("README.md");
    for needle in ["transport", "protocols", "coordinator", "offline",
                   "Algorithm"] {
        assert!(readme.contains(needle),
                "README.md paper-to-module map misses {needle}");
    }
}
