//! End-to-end integration: the rust secure engine must reproduce the
//! python oracle (`model.forward_fixed`) on the exported models --
//! bit-exactly on the Sign-only paths, argmax-exactly on the ReLU path
//! (the truncation protocol's +-1 LSB is the only divergence).
//!
//! Requires `make artifacts`.  Tests skip (with a notice) if the artifact
//! directory is absent so `cargo test` works in a fresh checkout.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, SessionConfig};
use cbnn::jsonio;
use cbnn::nn::Model;
use cbnn::runtime::BackendKind;
#[cfg(feature = "pjrt")]
use cbnn::runtime::KernelVariant;

fn art() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    art().join("models").exists()
}

struct Golden {
    logits: Vec<Vec<i64>>,
    preds: Vec<usize>,
}

fn load_golden(name: &str) -> Golden {
    let text = std::fs::read_to_string(
        art().join("golden").join(format!("{name}.golden.json"))).unwrap();
    let j = jsonio::parse(&text).unwrap();
    let logits = j.get("logits").unwrap().as_arr().unwrap().iter()
        .map(|row| row.as_arr().unwrap().iter()
             .map(|v| v.as_i64().unwrap()).collect())
        .collect();
    let preds = j.get("preds").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_usize().unwrap()).collect();
    Golden { logits, preds }
}

fn load_model(name: &str) -> Arc<Model> {
    Arc::new(Model::load(
        &art().join("models").join(format!("{name}.manifest.json"))).unwrap())
}

fn eval_data(model: &Model) -> EvalSet {
    EvalSet::load(&art().join("data")
                  .join(format!("{}.bin", model.dataset))).unwrap()
}

fn skip() -> bool {
    if !have_artifacts() {
        eprintln!("NOTE: artifacts/ missing -- run `make artifacts`; \
                   skipping integration test");
        return true;
    }
    false
}

fn check_bit_exact(name: &str, backend: BackendKind) {
    let model = load_model(name);
    let golden = load_golden(name);
    let data = eval_data(&model);
    let n = golden.logits.len().min(4); // 4 samples per backend: enough +
                                        // keeps the suite fast
    let cfg = SessionConfig::new(art().join("hlo")).with_backend(backend);
    let rep = run_inference(&model, data.images[..n].to_vec(), &cfg).unwrap();
    for i in 0..n {
        let got: Vec<i64> = rep.logits[i].iter().map(|&v| i64::from(v))
            .collect();
        assert_eq!(got, golden.logits[i],
                   "{name} sample {i} logits mismatch ({backend:?})");
        assert_eq!(rep.preds[i], golden.preds[i]);
    }
}

#[test]
fn mnistnet1_bit_exact_native() {
    if skip() { return; }
    check_bit_exact("mnistnet1", BackendKind::Native);
}

#[cfg(feature = "pjrt")]
#[test]
fn mnistnet1_bit_exact_pjrt_pallas() {
    if skip() { return; }
    check_bit_exact("mnistnet1", BackendKind::Pjrt(KernelVariant::Pallas));
}

#[cfg(feature = "pjrt")]
#[test]
fn mnistnet1_bit_exact_pjrt_xla() {
    if skip() { return; }
    check_bit_exact("mnistnet1", BackendKind::Pjrt(KernelVariant::Xla));
}

#[test]
fn mnistnet3_pool_path_bit_exact() {
    if skip() { return; }
    check_bit_exact("mnistnet3", BackendKind::Native);
}

#[cfg(feature = "pjrt")]
#[test]
fn mnistnet3_pool_path_bit_exact_pjrt() {
    if skip() { return; }
    check_bit_exact("mnistnet3", BackendKind::Pjrt(KernelVariant::Pallas));
}

#[test]
fn cifarnet2_separable_path_bit_exact() {
    if skip() { return; }
    check_bit_exact("cifarnet2", BackendKind::Native);
}

#[cfg(feature = "pjrt")]
#[test]
fn cifarnet2_separable_path_bit_exact_pjrt() {
    if skip() { return; }
    check_bit_exact("cifarnet2", BackendKind::Pjrt(KernelVariant::Pallas));
}

#[test]
fn mnistnet2_relu_path_argmax_exact() {
    if skip() { return; }
    // ReLU path uses the 2-round truncation: +-1 LSB per element, so
    // logits drift by a bounded amount; predictions must still agree.
    let model = load_model("mnistnet2");
    let golden = load_golden("mnistnet2");
    let data = eval_data(&model);
    let n = golden.preds.len().min(6);
    let cfg = SessionConfig::new(art().join("hlo"));
    let rep = run_inference(&model, data.images[..n].to_vec(), &cfg).unwrap();
    let mut agree = 0;
    for i in 0..n {
        if rep.preds[i] == golden.preds[i] {
            agree += 1;
        }
        // logits close in relative terms
        for (g, want) in rep.logits[i].iter().zip(&golden.logits[i]) {
            let diff = (i64::from(*g) - want).abs();
            assert!(diff <= 1 << 12,
                    "sample {i}: logit drift {diff} too large");
        }
    }
    assert!(agree >= n - 1, "only {agree}/{n} predictions agree");
}

#[cfg(feature = "pjrt")]
#[test]
fn pallas_and_xla_backends_agree() {
    if skip() { return; }
    let model = load_model("mnistnet3");
    let data = eval_data(&model);
    let run = |v| {
        let cfg = SessionConfig::new(art().join("hlo"))
            .with_backend(BackendKind::Pjrt(v));
        run_inference(&model, data.images[..2].to_vec(), &cfg).unwrap().logits
    };
    assert_eq!(run(KernelVariant::Pallas), run(KernelVariant::Xla));
}

#[test]
fn batching_does_not_change_results() {
    if skip() { return; }
    let model = load_model("mnistnet1");
    let data = eval_data(&model);
    let cfg = SessionConfig::new(art().join("hlo"));
    let one_by_one: Vec<Vec<i32>> = (0..4).map(|i| {
        run_inference(&model, vec![data.images[i].clone()], &cfg)
            .unwrap().logits.remove(0)
    }).collect();
    let batched = run_inference(&model, data.images[..4].to_vec(), &cfg)
        .unwrap().logits;
    assert_eq!(one_by_one, batched);
}

#[test]
fn batching_amortizes_rounds() {
    if skip() { return; }
    let model = load_model("mnistnet1");
    let data = eval_data(&model);
    let cfg = SessionConfig::new(art().join("hlo"));
    let r1 = run_inference(&model, data.images[..1].to_vec(), &cfg).unwrap();
    let r8 = run_inference(&model, data.images[..8].to_vec(), &cfg).unwrap();
    // rounds must NOT scale with batch (the whole point of the batcher)
    assert_eq!(r1.max_rounds(), r8.max_rounds(),
               "rounds grew with batch size");
    // bytes do scale roughly linearly
    assert!(r8.total_bytes() > 4 * r1.total_bytes());
}

#[test]
fn coordinator_serves_requests() {
    if skip() { return; }
    use cbnn::coordinator::{BatchPolicy, Coordinator, Service};
    let model = load_model("mnistnet1");
    let golden = load_golden("mnistnet1");
    let data = eval_data(&model);
    let cfg = SessionConfig::new(art().join("hlo"));
    let svc = Service::start(Arc::clone(&model), cfg).unwrap();
    let coord = Coordinator::start(svc, BatchPolicy::default());
    let rxs: Vec<_> = (0..6).map(|i| {
        (i, coord.submit(data.images[i].clone()))
    }).collect();
    for (i, rx) in rxs {
        let resp = rx.recv().unwrap();
        if i < golden.preds.len() {
            assert_eq!(resp.pred, golden.preds[i], "request {i}");
        }
    }
    let (hist, thr) = coord.finish();
    assert_eq!(thr.requests, 6);
    assert!(hist.count() == 6);
}

#[test]
fn manifest_files_all_load_and_validate() {
    if skip() { return; }
    let dir = art().join("models");
    let mut checked = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.to_string_lossy().ends_with(".manifest.json") {
            let m = Model::load(&p).unwrap();
            assert!(m.param_count() > 0);
            checked += 1;
        }
    }
    assert!(checked >= 5, "expected >=5 exported models, found {checked}");
}

#[test]
fn hlo_artifacts_exist_for_every_linear_layer() {
    if skip() { return; }
    for name in ["mnistnet1", "mnistnet2", "mnistnet3", "cifarnet2"] {
        let model = load_model(name);
        for op in &model.ops {
            if let cbnn::nn::Op::Matmul { hlo: Some(h), .. }
                 | cbnn::nn::Op::Depthwise { hlo: Some(h), .. } = op {
                for var in ["pallas", "xla"] {
                    let p = art().join("hlo").join(format!(
                        "{h}.{var}.hlo.txt"));
                    assert!(p.exists(), "missing artifact {}", p.display());
                }
            }
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_actually_executes_not_fallback() {
    if skip() { return; }
    use cbnn::protocols::linear::LinearBackend;
    use cbnn::runtime::PjrtRuntime;
    let rt = PjrtRuntime::new(art().join("hlo"), KernelVariant::Pallas)
        .unwrap();
    // mnistnet1 first layer: 128 x 784 x 1
    let wa = cbnn::ring::Tensor::zeros(&[128, 784]);
    let wb = cbnn::ring::Tensor::zeros(&[128, 784]);
    let xa = cbnn::ring::Tensor::zeros(&[784, 1]);
    let xb = cbnn::ring::Tensor::zeros(&[784, 1]);
    let _ = rt.rss_matmul("rss_mm_128x784x1", &wa, &wb, &xa, &xb, None);
    assert_eq!(rt.pjrt_execs.get(), 1);
    assert_eq!(rt.native_fallbacks.get(), 0);
}

#[test]
fn wan_setting_costs_more_time_than_lan() {
    if skip() { return; }
    use cbnn::transport::NetConfig;
    let model = load_model("mnistnet1");
    let data = eval_data(&model);
    let lan_cfg = SessionConfig::new(art().join("hlo"))
        .with_net(NetConfig::lan());
    let wan_cfg = SessionConfig::new(art().join("hlo"))
        .with_net(NetConfig::wan());
    let lan = run_inference(&model, data.images[..1].to_vec(), &lan_cfg)
        .unwrap();
    let wan = run_inference(&model, data.images[..1].to_vec(), &wan_cfg)
        .unwrap();
    assert_eq!(lan.preds, wan.preds);
    assert!(wan.online > lan.online * 3,
            "WAN {:?} should dominate LAN {:?}", wan.online, lan.online);
}

#[test]
fn eval_dataset_loads_with_expected_dims() {
    if skip() { return; }
    let mnist = EvalSet::load(&art().join("data/mnist.bin")).unwrap();
    assert_eq!(mnist.dims, (1, 28, 28));
    assert_eq!(mnist.images.len(), 256);
    let cifar = EvalSet::load(&art().join("data/cifar.bin")).unwrap();
    assert_eq!(cifar.dims, (3, 32, 32));
}

// keep Path import used even when artifacts are absent
#[allow(dead_code)]
fn _touch(_: &Path) {}
