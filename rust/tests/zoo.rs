//! Model-zoo accuracy-parity harness: serve the paper's actual workload
//! (KD-trained LeNet5 / VGG7 with depthwise-separable +-1 convolutions)
//! from the committed fixtures and hold the secure engine to it.
//!
//! Contracts enforced here (CI `model-parity` job):
//!   * manifests load (version 2, binary planes validated at load)
//!   * the rust plaintext reference walk reproduces the exported python
//!     logits EXACTLY (the zoo nets are sign-only -> trunc-free -> no
//!     LSB tolerance needed; see DESIGN.md "Parity tolerance")
//!   * secure logits are bit-identical across unfused-inline,
//!     unfused-pooled, and fused walks, and equal the reference walk
//!   * test-subset accuracy clears the committed floor
//!   * a warm auto-sized bank serves a full zoo batch with zero
//!     request-path mints
//!   * malformed manifests (truncated, non-+-1 planes, shape lies) are
//!     typed load errors, never mid-inference panics
//!
//! Fixtures live in fixtures/zoo/ and are committed -- unlike
//! integration.rs these tests never skip.

use std::path::PathBuf;
use std::sync::Arc;

use cbnn::coordinator::Service;
use cbnn::datasets::EvalSet;
use cbnn::engine::fusion::plan_fused;
use cbnn::engine::msb_demand_for;
use cbnn::engine::session::{run_inference, SessionConfig};
use cbnn::jsonio;
use cbnn::nn::{reference, LoadError, Model, Op};
use cbnn::ring::Tensor;

fn zoo_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
        .join("fixtures").join("zoo")
}

fn load_zoo(name: &str) -> Arc<Model> {
    Arc::new(Model::load(
        &zoo_dir().join(format!("{name}.manifest.json")))
        .unwrap_or_else(|e| panic!("loading zoo model {name}: {e}")))
}

struct Golden {
    floor: f64,
    accuracy: f64,
    labels: Vec<i32>,
    logits: Vec<Vec<i32>>,
}

fn load_golden(name: &str) -> Golden {
    let text = std::fs::read_to_string(
        zoo_dir().join(format!("{name}.golden.json"))).unwrap();
    let j = jsonio::parse(&text).unwrap();
    let logits: Vec<Vec<i32>> = j.get("logits").unwrap().as_arr().unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter()
             .map(|v| i32::try_from(v.as_i64().unwrap()).unwrap())
             .collect())
        .collect();
    let labels = j.get("labels").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_i64().unwrap() as i32).collect();
    Golden {
        floor: j.get("floor").unwrap().as_f64().unwrap(),
        accuracy: j.get("accuracy").unwrap().as_f64().unwrap(),
        labels,
        logits,
    }
}

fn load_subset(model: &Model) -> EvalSet {
    EvalSet::load(&zoo_dir().join(format!("{}_subset.bin", model.dataset)))
        .unwrap()
}

const ZOO: [&str; 2] = ["lenet5", "vgg7"];

#[test]
fn zoo_manifests_load_versioned_with_binary_planes() {
    for name in ZOO {
        let model = load_zoo(name);
        assert_eq!(model.version, 2, "{name}: zoo manifests are v2");
        let binary = model.ops.iter().filter(|op| matches!(
            op, Op::Matmul { binary: true, .. }
                | Op::Depthwise { binary: true, .. })).count();
        assert!(binary >= 3,
                "{name}: expected a binary hidden chain, found {binary}");
        // hidden chain is sign-only: trunc-free -> every walk bit-equal
        assert!(!model.ops.iter().any(|op| matches!(op, Op::Relu { .. })),
                "{name}: zoo nets must be trunc-free for exact parity");
        let set = load_subset(&model);
        assert_eq!(set.dims, model.input, "{name}: subset dims");
        let want = if model.dataset == "mnist" { 256 } else { 128 };
        assert!(set.images.len() >= want,
                "{name}: committed subset holds {} images, need >= {want}",
                set.images.len());
    }
}

/// On divergence, dump the fresh rows next to the committed golden so
/// the CI `model-parity` job can upload them as diffable evidence.
fn dump_divergence(name: &str, what: &str, rows: &[(usize, &[i32])]) {
    let dir = std::env::temp_dir().join("zoo-divergence");
    let _ = std::fs::create_dir_all(&dir);
    let body: Vec<String> = rows.iter()
        .map(|(i, l)| format!("  {{\"sample\": {i}, \"logits\": {l:?}}}"))
        .collect();
    let _ = std::fs::write(
        dir.join(format!("{name}.{what}.json")),
        format!("[\n{}\n]\n", body.join(",\n")));
}

#[test]
fn zoo_reference_matches_exported_python_logits_exactly() {
    for name in ZOO {
        let model = load_zoo(name);
        let golden = load_golden(name);
        let set = load_subset(&model);
        assert_eq!(golden.logits.len(), set.images.len());
        assert_eq!(golden.labels, set.labels, "{name}: label drift");
        let fresh: Vec<Vec<i32>> = set.images.iter()
            .map(|img| reference::forward(&model, &img.data)).collect();
        let bad: Vec<(usize, &[i32])> = fresh.iter().enumerate()
            .filter(|(i, got)| *got != &golden.logits[*i])
            .map(|(i, got)| (i, got.as_slice())).collect();
        if !bad.is_empty() {
            dump_divergence(name, "reference", &bad);
            panic!("{name}: {} of {} samples diverged from the python \
                    oracle (first at sample {}); fresh rows dumped to \
                    $TMPDIR/zoo-divergence", bad.len(), fresh.len(),
                   bad[0].0);
        }
    }
}

#[test]
fn zoo_subset_accuracy_clears_committed_floor() {
    for name in ZOO {
        let model = load_zoo(name);
        let golden = load_golden(name);
        let set = load_subset(&model);
        let acc = reference::accuracy(&model, &set.images, &set.labels);
        assert!(acc >= golden.floor,
                "{name}: accuracy {acc:.4} below committed floor {}",
                golden.floor);
        assert!((acc - golden.accuracy).abs() < 1e-9,
                "{name}: accuracy {acc:.4} != exported {:.4} -- the \
                 oracle and the reference walk disagree", golden.accuracy);
    }
}

/// Secure logits across all three walks must be bit-identical to the
/// reference walk (no trunc in the zoo nets, so no tolerance).  Small
/// slice per model to keep CI wall-clock sane; full-subset coverage is
/// the plaintext accuracy test above.
#[test]
fn zoo_secure_walks_bit_identical_across_inline_pool_fuse() {
    for (name, slice) in [("lenet5", 4usize), ("vgg7", 2)] {
        let model = load_zoo(name);
        let set = load_subset(&model);
        let inputs: Vec<Tensor> =
            set.images.iter().take(slice).cloned().collect();
        let want: Vec<Vec<i32>> = inputs.iter()
            .map(|img| reference::forward(&model, &img.data)).collect();

        let mut inline = SessionConfig::new("artifacts/hlo");
        inline.opts.preprocess = false;
        let mut fused = SessionConfig::new("artifacts/hlo");
        fused.opts.fuse = true;
        let pooled = SessionConfig::new("artifacts/hlo");
        for (walk, cfg) in [("inline", inline), ("pooled", pooled),
                            ("fused", fused)] {
            let rep = run_inference(&model, inputs.clone(), &cfg)
                .unwrap_or_else(|e| panic!("{name}/{walk}: {e}"));
            if rep.logits != want {
                let bad: Vec<(usize, &[i32])> = rep.logits.iter()
                    .enumerate()
                    .filter(|(i, got)| *got != &want[*i])
                    .map(|(i, got)| (i, got.as_slice())).collect();
                dump_divergence(name, walk, &bad);
                panic!("{name}: {walk} walk diverged from reference on \
                        {} of {slice} samples; fresh rows dumped to \
                        $TMPDIR/zoo-divergence", bad.len());
            }
        }
    }
}

#[test]
fn zoo_fused_demand_undercuts_unfused_on_real_graphs() {
    for name in ZOO {
        let model = load_zoo(name);
        let plan = plan_fused(&model)
            .unwrap_or_else(|e| panic!("{name}: plan must lower: {e}"));
        for batch in [1usize, 4] {
            let unfused = msb_demand_for(&model, batch);
            let fused = plan.msb_demand(batch);
            assert!(fused > 0, "{name}: fused demand must be nonzero \
                                (sign still enters the binary domain)");
            assert!(fused < unfused,
                    "{name} batch {batch}: fused demand {fused} must \
                     undercut unfused {unfused}");
        }
    }
}

/// Satellite regression: `BankConfig::auto` sized off the real model's
/// `msb_demand(max_batch)` must leave a warm service able to absorb a
/// full zoo batch without a single request-path mint.  The prefill
/// (high watermark = 3x demand) plus capacity (4x) must dominate the
/// largest single draw; if the watermark math undershoots, the
/// underflow counter trips and this test names the party.
#[test]
fn zoo_warm_bank_serves_full_batch_with_zero_request_path_mints() {
    let model = load_zoo("lenet5");
    let set = load_subset(&model);
    for fuse in [false, true] {
        let mut cfg = SessionConfig::new("artifacts/hlo");
        cfg.max_batch = 4;
        cfg.opts.fuse = fuse;
        let svc = Service::start(Arc::clone(&model), cfg).unwrap();
        let demand = svc.demand_for(4);
        assert!(demand > 0);
        let batch: Vec<Tensor> =
            set.images.iter().take(4).cloned().collect();
        let logits = svc.infer(batch).expect("zoo batch");
        for (i, l) in logits.iter().enumerate() {
            assert_eq!(l, &reference::forward(&model, &set.images[i].data),
                       "served logits diverged at {i} (fuse={fuse})");
        }
        for p in 0..3 {
            let m = svc.bank_handle(p).metrics();
            assert_eq!(m.underflow_calls, 0,
                       "party {p} minted on the request path \
                        (fuse={fuse}): {m:?}");
            assert!(m.drawn as usize >= demand,
                    "party {p} drew {} < batch demand {demand}", m.drawn);
        }
        let _ = svc.shutdown();
    }
}

// ---- adversarial manifests: typed errors at load, never panics ----------

fn lenet_manifest_text() -> String {
    std::fs::read_to_string(zoo_dir().join("lenet5.manifest.json")).unwrap()
}

fn lenet_pool() -> Vec<i32> {
    let raw = std::fs::read(zoo_dir().join("lenet5.weights.bin")).unwrap();
    raw.chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect()
}

#[test]
fn adversarial_truncated_manifest_is_typed_json_error() {
    let text = lenet_manifest_text();
    let pool = lenet_pool();
    for frac in [4usize, 2] {
        let cut = text.len() / frac;
        match Model::from_json(&text[..cut], pool.clone()) {
            Err(LoadError::Json(_)) => {}
            other => panic!("cut at {cut}: expected Json error, got \
                             {other:?}"),
        }
    }
}

#[test]
fn adversarial_out_of_pm1_binary_weight_is_typed() {
    let text = lenet_manifest_text();
    let model = Model::from_json(&text, lenet_pool()).unwrap();
    // find a binary plane and poison one value
    let wr = model.ops.iter().find_map(|op| match op {
        Op::Matmul { binary: true, w, .. }
        | Op::Depthwise { binary: true, w, .. } => Some(*w),
        _ => None,
    }).expect("zoo model has a binary plane");
    let mut pool = lenet_pool();
    pool[wr.off + wr.len / 2] = 2;
    match Model::from_json(&text, pool) {
        Err(LoadError::NonBinaryPlane { value: 2, .. }) => {}
        other => panic!("expected NonBinaryPlane, got {other:?}"),
    }
}

#[test]
fn adversarial_shape_lies_are_typed() {
    let text = lenet_manifest_text();
    let pool = lenet_pool();
    // the manifest declares kdim for each matmul; lie about one
    let lied = text.replacen("\"kdim\": ", "\"kdim\": 9", 1);
    assert_ne!(lied, text, "fixture manifest must declare kdim");
    match Model::from_json(&lied, pool.clone()) {
        Err(LoadError::ShapeChain { .. }) => {}
        other => panic!("expected ShapeChain, got {other:?}"),
    }
    // claim the conv stem is a fully-connected layer (fc before flatten)
    let lied = text.replacen("\"conv\": true", "\"conv\": false", 1);
    assert_ne!(lied, text);
    assert!(matches!(Model::from_json(&lied, pool.clone()),
                     Err(LoadError::ShapeChain { .. })));
    // a future manifest version is refused outright
    let lied = text.replacen("\"version\": 2", "\"version\": 99", 1);
    assert_ne!(lied, text);
    match Model::from_json(&lied, pool) {
        Err(LoadError::Version { found: 99, max: 2 }) => {}
        other => panic!("expected Version, got {other:?}"),
    }
}

#[test]
fn adversarial_truncated_weight_pool_is_typed() {
    let text = lenet_manifest_text();
    let mut pool = lenet_pool();
    pool.truncate(pool.len() / 2);
    match Model::from_json(&text, pool) {
        Err(LoadError::PoolRef { .. }) => {}
        other => panic!("expected PoolRef, got {other:?}"),
    }
}

#[test]
fn adversarial_truncated_eval_subset_rejected() {
    let raw = std::fs::read(zoo_dir().join("mnist_subset.bin")).unwrap();
    let dir = std::env::temp_dir().join("cbnn_zoo_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("truncated_subset.bin");
    std::fs::write(&p, &raw[..raw.len() / 2]).unwrap();
    assert!(EvalSet::load(&p).is_err(), "truncated subset must not load");
}
