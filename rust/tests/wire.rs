//! Adversarial wire-format tests: everything that crosses a party
//! boundary is attacker-controlled, so truncated, oversized, and
//! dirty-padding frames must surface as `WireError` -- never a panic --
//! and padding bits must never reach word-parallel computation.

use std::thread;

use cbnn::ring::bits::BitTensor;
use cbnn::ring::planes::BitPlanes;
use cbnn::testutil::Rng;
use cbnn::transport::{local_trio, ChanId, Comm, Dir, NetConfig, WireError,
                      MAX_MSG_BYTES};

/// Run a crafting closure on P0 and a checking closure on P1 (P2 idles).
fn craft_and_check<C, K, R>(craft: C, check: K) -> R
where
    C: FnOnce(&Comm) + Send,
    K: FnOnce(&Comm) -> R + Send,
    R: Send,
{
    let [c0, c1, _c2] = local_trio(NetConfig::zero());
    thread::scope(|s| {
        let sender = s.spawn(move || craft(&c0));
        let checker = s.spawn(move || check(&c1));
        sender.join().unwrap();
        checker.join().unwrap()
    })
}

// ---- codec-level (no transport) -----------------------------------------

#[test]
fn packed_bytes_codec_rejects_bad_byte_counts() {
    // truncated and oversized payloads for a claimed bit count
    for n in [1usize, 7, 8, 9, 64, 65, 100] {
        let good = n.div_ceil(8);
        assert!(BitTensor::from_packed_bytes(n, &vec![0u8; good]).is_some());
        for bad in [0usize, good - 1, good + 1, good + 8] {
            if bad == good {
                continue;
            }
            assert!(BitTensor::from_packed_bytes(n, &vec![0u8; bad])
                    .is_none(), "n={n} bytes={bad} must be rejected");
        }
    }
}

#[test]
fn packed_bytes_codec_masks_dirty_padding() {
    // attacker sets every padding bit; they must be cleared on decode so
    // popcount/eq/wire stay word-wise safe
    let t = BitTensor::from_packed_bytes(3, &[0xFF]).unwrap();
    assert_eq!(t.popcount(), 3);
    assert_eq!(t, BitTensor::ones(3));
    let t = BitTensor::from_packed_bytes(9, &[0xFF, 0xFF]).unwrap();
    assert_eq!(t.popcount(), 9);
    assert_eq!(t.packed_bytes(), vec![0xFF, 0x01], "re-encode leaked padding");
}

#[test]
fn planes_codec_is_bit_identical_to_tensor_codec() {
    // BitPlanes ships as a reinterpreted BitTensor: the bytes on the wire
    // must match packing the padded tensor directly, bit for bit
    let mut rng = Rng::new(7);
    for (planes, n) in [(1usize, 1usize), (4, 63), (32, 65), (8, 128)] {
        let rows: Vec<BitTensor> =
            (0..planes).map(|_| BitTensor::from_fn(n, |_| rng.bit()))
            .collect();
        let m = BitPlanes::from_tensors(&rows);
        let t = m.clone().into_tensor();
        assert_eq!(t.len(), m.padded_bits());
        // same words, same packed bytes -- no repack happened
        assert_eq!(t.words(), m.words());
        let bytes = t.packed_bytes();
        let back = BitTensor::from_packed_bytes(t.len(), &bytes).unwrap();
        let back = BitPlanes::from_tensor(back, planes, n).unwrap();
        assert_eq!(back, m);
        for (p, row) in rows.iter().enumerate() {
            assert_eq!(&back.plane(p), row, "plane {p}");
        }
    }
}

// ---- transport-level ----------------------------------------------------

#[test]
fn truncated_bit_header_is_malformed() {
    let err = craft_and_check(
        |c| {
            // 3 bytes cannot even hold the 8-byte bit-count header
            c.send_raw(Dir::Next, vec![0u8; 3]).unwrap();
        },
        |c| c.recv_bits(Dir::Prev).unwrap_err(),
    );
    assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
}

#[test]
fn payload_contradicting_bit_header_is_malformed() {
    for (claimed, body) in [(100u64, 1usize), (8, 0), (1, 13)] {
        let err = craft_and_check(
            move |c| {
                let mut lie = Vec::new();
                lie.extend_from_slice(&claimed.to_le_bytes());
                lie.extend(std::iter::repeat(0xFFu8).take(body));
                c.send_raw(Dir::Next, lie).unwrap();
            },
            |c| c.recv_bits(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "claimed={claimed} body={body}: {err:?}");
    }
}

#[test]
fn oversized_bit_count_is_rejected_before_allocation() {
    // headers claiming more than the 1 GiB message cap's worth of bits
    // (incl. u64::MAX) must be rejected without allocating the claim
    for claimed in [MAX_MSG_BYTES * 8 + 1, u64::MAX] {
        let err = craft_and_check(
            move |c| {
                c.send_raw(Dir::Next, claimed.to_le_bytes().to_vec())
                    .unwrap();
            },
            |c| c.recv_bits(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "claimed={claimed}: {err:?}");
    }
}

#[test]
fn ragged_ring_payload_is_malformed() {
    for bytes in [1usize, 5, 7, 9] {
        let err = craft_and_check(
            move |c| c.send_raw(Dir::Next, vec![0u8; bytes]).unwrap(),
            |c| c.recv_elems(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "{bytes} bytes: {err:?}");
    }
}

#[test]
fn wire_padding_never_reaches_computation() {
    // a peer that sets the padding bits of a bit message: decode must
    // mask them so word-parallel XOR/popcount see clean tails
    let got = craft_and_check(
        |c| {
            let mut msg = Vec::new();
            msg.extend_from_slice(&5u64.to_le_bytes()); // 5 bits, 1 byte
            msg.push(0xFF); // 3 dirty padding bits
            c.send_raw(Dir::Next, msg).unwrap();
        },
        |c| c.recv_bits(Dir::Prev).unwrap(),
    );
    assert_eq!(got.len(), 5);
    assert_eq!(got.popcount(), 5);
    assert_eq!(got, BitTensor::ones(5));
}

#[test]
fn plane_padding_never_reaches_computation() {
    // dirty per-plane padding in a planes frame (2 planes of 5 bits,
    // padded to one word each) is cleared by the reinterpret
    let got = craft_and_check(
        |c| {
            let mut msg = Vec::new();
            msg.extend_from_slice(&128u64.to_le_bytes()); // 2*1*64 bits
            msg.extend(std::iter::repeat(0xFFu8).take(16));
            c.send_raw(Dir::Next, msg).unwrap();
        },
        |c| c.recv_planes(Dir::Prev, 2, 5).unwrap(),
    );
    assert_eq!(got.popcount(), 10, "plane padding leaked");
    for p in 0..2 {
        assert_eq!(got.plane(p), BitTensor::ones(5));
    }
}

#[test]
fn planes_frame_with_wrong_geometry_is_malformed() {
    // an honest 2x64 frame received as 3x64 / 2x65 / 1x64 must be
    // rejected as malformed, not mis-sliced
    for (planes, len) in [(3usize, 64usize), (2, 65), (1, 64)] {
        let err = craft_and_check(
            move |c| {
                let m = BitPlanes::zeros(2, 64);
                c.send_planes(Dir::Next, &m).unwrap();
            },
            move |c| c.recv_planes(Dir::Prev, planes, len).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "{planes}x{len}: {err:?}");
    }
}

// ---- tagged channel frames ----------------------------------------------

#[test]
fn unregistered_channel_id_is_malformed() {
    // the tag byte is attacker-controlled like everything else: a frame
    // tagged with a channel id nobody registered -- another model
    // slot's lanes (0x02..), or the far end of the id space -- must be
    // Malformed, not mis-routed and not parked forever
    // includes slot 0's OFFLINE tag: the receiver never derived an
    // offline handle, so even the "default" producer lane is
    // unregistered until someone actually consumes it
    for tag in [ChanId::OFFLINE.tag(), ChanId::online(1).tag(),
                ChanId::offline(1).tag(), ChanId::online(63).tag(),
                0x80, 0xFF] {
        let err = craft_and_check(
            move |c| {
                let mut frame = vec![tag];
                frame.extend_from_slice(&5u64.to_le_bytes());
                frame.push(0x1F);
                c.send_frame(Dir::Next, frame).unwrap();
            },
            |c| c.recv_bits(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)), "tag {tag}: {err:?}");
    }
}

#[test]
fn registering_a_model_lane_turns_malformed_into_parked() {
    // a model-slot-1 frame is Malformed while the lane is unregistered
    // (and consumed by the failing recv), but an identical frame read
    // *after* the receiver registers the lane is parked and delivered
    // -- registration at read time is the demux's source of truth
    let on1_tag = ChanId::online(1).tag();
    let (err_before, ok_after) = craft_and_check(
        move |c| {
            // two slot-1 frames, then a slot-0 frame; all are queued
            // before the checker reads anything
            for v in [1i32, 3] {
                let mut frame = vec![on1_tag];
                frame.extend_from_slice(&v.to_le_bytes());
                c.send_frame(Dir::Next, frame).unwrap();
            }
            c.send_elems(Dir::Next, &[2]).unwrap();
        },
        |c| {
            // NOT registered: the first slot-1 frame errs the slot-0
            // recv (and is dropped with it)
            let err = c.recv_elems(Dir::Prev).unwrap_err();
            // register slot 1: the second slot-1 frame now parks for
            // the new lane while the slot-0 recv skips past it
            let on1 = c.channel(ChanId::online(1));
            let a = c.recv_elems(Dir::Prev).unwrap();
            let b = on1.recv_elems(Dir::Prev).unwrap();
            (err, (a, b))
        },
    );
    assert!(matches!(err_before, WireError::Malformed(_)),
            "{err_before:?}");
    assert_eq!(ok_after, (vec![2], vec![3]));
}

#[test]
fn frame_too_short_for_its_tag_is_malformed() {
    // tag/length mismatch: a zero-length frame cannot even hold the
    // channel tag the header format promises
    let err = craft_and_check(
        |c| c.send_frame(Dir::Next, vec![]).unwrap(),
        |c| c.recv_elems(Dir::Prev).unwrap_err(),
    );
    assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
    // a tag-only frame parses as an empty payload: fine for the elems
    // codec (zero elements), Malformed for the bit codec (no header)
    let got = craft_and_check(
        |c| c.send_frame(Dir::Next, vec![0u8]).unwrap(),
        |c| c.recv_elems(Dir::Prev).unwrap(),
    );
    assert!(got.is_empty());
    let err = craft_and_check(
        |c| c.send_frame(Dir::Next, vec![0u8]).unwrap(),
        |c| c.recv_bits(Dir::Prev).unwrap_err(),
    );
    assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
}

#[test]
fn offline_frame_during_pending_online_recv_is_parked_not_consumed() {
    // the checker's online recv is already pending when the offline
    // frame lands: the demux must park it for the offline handle and
    // keep waiting for the online frame
    let (online, offline) = craft_and_check(
        |c| {
            let off = c.channel(ChanId::OFFLINE);
            off.send_bits(Dir::Next, &BitTensor::ones(9)).unwrap();
            // give the pending online recv a chance to be the thread
            // that reads (and must park) the offline frame
            std::thread::sleep(std::time::Duration::from_millis(20));
            c.send_bits(Dir::Next, &BitTensor::zeros(5)).unwrap();
        },
        |c| {
            // derive (= register) the offline lane up front, as every
            // real producer does before traffic can flow
            let off = c.channel(ChanId::OFFLINE);
            let online = c.recv_bits(Dir::Prev).unwrap();
            let offline = off.recv_bits(Dir::Prev).unwrap();
            (online, offline)
        },
    );
    assert_eq!(online, BitTensor::zeros(5));
    assert_eq!(offline, BitTensor::ones(9));
}

#[test]
fn online_frames_park_symmetrically_for_offline_recv() {
    let (offline, online1, online2) = craft_and_check(
        |c| {
            c.send_elems(Dir::Next, &[1]).unwrap();
            c.send_elems(Dir::Next, &[2]).unwrap();
            c.channel(ChanId::OFFLINE).send_elems(Dir::Next, &[3]).unwrap();
        },
        |c| {
            // the offline recv must skip over (and park, in order) both
            // online frames
            let off = c.channel(ChanId::OFFLINE).recv_elems(Dir::Prev)
                .unwrap();
            (off,
             c.recv_elems(Dir::Prev).unwrap(),
             c.recv_elems(Dir::Prev).unwrap())
        },
    );
    assert_eq!(offline, vec![3]);
    assert_eq!(online1, vec![1]);
    assert_eq!(online2, vec![2]);
}

#[test]
fn two_models_frames_park_across_all_four_lanes() {
    // the multi-model mirror of the PR 3 cross-channel parking tests:
    // two model slots' online+offline lanes over one link, every frame
    // sent before any recv, received in reverse lane order -- each recv
    // must skip (and park, FIFO per lane) every foreign frame
    let lanes_of = |c: &Comm| {
        [c.channel(ChanId::online(1)), c.channel(ChanId::offline(1)),
         c.channel(ChanId::online(2)), c.channel(ChanId::offline(2))]
    };
    let got = craft_and_check(
        move |c| {
            let lanes = lanes_of(c);
            for (i, lane) in lanes.iter().enumerate() {
                // two frames per lane: FIFO order within a lane must
                // survive the cross-lane parking
                lane.send_elems(Dir::Next, &[10 * i as i32]).unwrap();
                lane.send_elems(Dir::Next, &[10 * i as i32 + 1]).unwrap();
            }
        },
        move |c| {
            let lanes = lanes_of(c);
            let mut got = Vec::new();
            for lane in lanes.iter().rev() {
                let a = lane.recv_elems(Dir::Prev).unwrap();
                let b = lane.recv_elems(Dir::Prev).unwrap();
                got.push((a[0], b[0]));
            }
            got
        },
    );
    assert_eq!(got, vec![(30, 31), (20, 21), (10, 11), (0, 1)]);
}

#[test]
fn offline_lane_recv_pending_while_other_models_frames_arrive() {
    // an offline-lane recv of model 1 is already blocked on the link
    // when model 2's frames (and model 1's online frame) land: it must
    // pump and park them, then deliver its own
    let (off1, on1, on2) = craft_and_check(
        |c| {
            let on1 = c.channel(ChanId::online(1));
            let on2 = c.channel(ChanId::online(2));
            let off1 = c.channel(ChanId::offline(1));
            on2.send_bits(Dir::Next, &BitTensor::zeros(3)).unwrap();
            on1.send_bits(Dir::Next, &BitTensor::ones(7)).unwrap();
            // give the pending offline recv a chance to be the reader
            // that routes the foreign frames
            std::thread::sleep(std::time::Duration::from_millis(20));
            off1.send_bits(Dir::Next, &BitTensor::ones(9)).unwrap();
        },
        |c| {
            // register every lane first (frames may arrive before the
            // handles would otherwise exist)
            let on1 = c.channel(ChanId::online(1));
            let on2 = c.channel(ChanId::online(2));
            let off1 = c.channel(ChanId::offline(1));
            let off = off1.recv_bits(Dir::Prev).unwrap();
            (off,
             on1.recv_bits(Dir::Prev).unwrap(),
             on2.recv_bits(Dir::Prev).unwrap())
        },
    );
    assert_eq!(off1, BitTensor::ones(9));
    assert_eq!(on1, BitTensor::ones(7));
    assert_eq!(on2, BitTensor::zeros(3));
}

#[test]
fn parked_cap_flood_is_malformed_and_recoverable() {
    // ISSUE 5 satellite: a peer that floods a registered-but-idle lane
    // must trip the per-lane parked-bytes cap -- surfacing as Malformed
    // on that lane -- without affecting a healthy lane's throughput;
    // retiring and re-deriving the lane recovers it
    let [c0, c1, _c2] = local_trio(NetConfig::zero());
    c1.set_parked_cap(300);
    let flood_lane = ChanId::offline(7);
    thread::scope(|s| {
        let sender = s.spawn(|| {
            let flooder = c0.channel(flood_lane);
            for i in 0..20i32 {
                // 80 B of flood (plus tag) per healthy frame: the idle
                // lane overflows its 300 B cap on the fourth frame
                flooder.send_raw(Dir::Next, vec![0u8; 80]).unwrap();
                c0.send_elems(Dir::Next, &[i]).unwrap();
            }
            // post-flood traffic for the recovered lane
            c0.channel(flood_lane).send_elems(Dir::Next, &[99]).unwrap();
        });
        let checker = s.spawn(|| {
            let idle = c1.channel(flood_lane); // registered, unread
            // every healthy frame arrives, in order, while the flood
            // lands and overflows
            for i in 0..20i32 {
                assert_eq!(c1.recv_elems(Dir::Prev).unwrap(), vec![i],
                           "healthy lane perturbed at frame {i}");
            }
            // bounded memory: the overflow freed the parked flood
            assert!(c1.parked_bytes(flood_lane) <= 300);
            let err = idle.recv_elems(Dir::Prev).unwrap_err();
            assert!(matches!(&err, WireError::Malformed(m)
                             if m.contains("parked cap")), "{err:?}");
            // recovery: retire the poisoned lane, re-derive it, and the
            // post-flood frame (sent after the flood) arrives cleanly
            c1.close_chan(flood_lane);
            let fresh = c1.channel(flood_lane);
            assert_eq!(fresh.recv_elems(Dir::Prev).unwrap(), vec![99]);
        });
        sender.join().unwrap();
        checker.join().unwrap();
    });
}

#[test]
fn hung_up_peer_errors_on_both_paths() {
    let [c0, c1, c2] = local_trio(NetConfig::zero());
    drop(c1);
    drop(c2);
    // send path: both neighbours are gone
    assert!(matches!(c0.send_elems(Dir::Next, &[1]).unwrap_err(),
                     WireError::Closed));
    assert!(matches!(c0.send_bits(Dir::Prev, &BitTensor::ones(4))
                     .unwrap_err(), WireError::Closed));
    assert!(matches!(c0.send_planes(Dir::Next, &BitPlanes::zeros(1, 4))
                     .unwrap_err(), WireError::Closed));
    // receive path: nothing will ever arrive
    assert!(matches!(c0.recv_elems(Dir::Next).unwrap_err(),
                     WireError::Closed));
    assert!(matches!(c0.recv_bits(Dir::Prev).unwrap_err(),
                     WireError::Closed));
}
