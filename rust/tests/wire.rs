//! Adversarial wire-format tests: everything that crosses a party
//! boundary is attacker-controlled, so truncated, oversized, and
//! dirty-padding frames must surface as `WireError` -- never a panic --
//! and padding bits must never reach word-parallel computation.

use std::thread;

use cbnn::ring::bits::BitTensor;
use cbnn::ring::planes::BitPlanes;
use cbnn::testutil::Rng;
use cbnn::transport::{local_trio, Chan, Comm, Dir, NetConfig, WireError,
                      MAX_MSG_BYTES};

/// Run a crafting closure on P0 and a checking closure on P1 (P2 idles).
fn craft_and_check<C, K, R>(craft: C, check: K) -> R
where
    C: FnOnce(&Comm) + Send,
    K: FnOnce(&Comm) -> R + Send,
    R: Send,
{
    let [c0, c1, _c2] = local_trio(NetConfig::zero());
    thread::scope(|s| {
        let sender = s.spawn(move || craft(&c0));
        let checker = s.spawn(move || check(&c1));
        sender.join().unwrap();
        checker.join().unwrap()
    })
}

// ---- codec-level (no transport) -----------------------------------------

#[test]
fn packed_bytes_codec_rejects_bad_byte_counts() {
    // truncated and oversized payloads for a claimed bit count
    for n in [1usize, 7, 8, 9, 64, 65, 100] {
        let good = n.div_ceil(8);
        assert!(BitTensor::from_packed_bytes(n, &vec![0u8; good]).is_some());
        for bad in [0usize, good - 1, good + 1, good + 8] {
            if bad == good {
                continue;
            }
            assert!(BitTensor::from_packed_bytes(n, &vec![0u8; bad])
                    .is_none(), "n={n} bytes={bad} must be rejected");
        }
    }
}

#[test]
fn packed_bytes_codec_masks_dirty_padding() {
    // attacker sets every padding bit; they must be cleared on decode so
    // popcount/eq/wire stay word-wise safe
    let t = BitTensor::from_packed_bytes(3, &[0xFF]).unwrap();
    assert_eq!(t.popcount(), 3);
    assert_eq!(t, BitTensor::ones(3));
    let t = BitTensor::from_packed_bytes(9, &[0xFF, 0xFF]).unwrap();
    assert_eq!(t.popcount(), 9);
    assert_eq!(t.packed_bytes(), vec![0xFF, 0x01], "re-encode leaked padding");
}

#[test]
fn planes_codec_is_bit_identical_to_tensor_codec() {
    // BitPlanes ships as a reinterpreted BitTensor: the bytes on the wire
    // must match packing the padded tensor directly, bit for bit
    let mut rng = Rng::new(7);
    for (planes, n) in [(1usize, 1usize), (4, 63), (32, 65), (8, 128)] {
        let rows: Vec<BitTensor> =
            (0..planes).map(|_| BitTensor::from_fn(n, |_| rng.bit()))
            .collect();
        let m = BitPlanes::from_tensors(&rows);
        let t = m.clone().into_tensor();
        assert_eq!(t.len(), m.padded_bits());
        // same words, same packed bytes -- no repack happened
        assert_eq!(t.words(), m.words());
        let bytes = t.packed_bytes();
        let back = BitTensor::from_packed_bytes(t.len(), &bytes).unwrap();
        let back = BitPlanes::from_tensor(back, planes, n).unwrap();
        assert_eq!(back, m);
        for (p, row) in rows.iter().enumerate() {
            assert_eq!(&back.plane(p), row, "plane {p}");
        }
    }
}

// ---- transport-level ----------------------------------------------------

#[test]
fn truncated_bit_header_is_malformed() {
    let err = craft_and_check(
        |c| {
            // 3 bytes cannot even hold the 8-byte bit-count header
            c.send_raw(Dir::Next, vec![0u8; 3]).unwrap();
        },
        |c| c.recv_bits(Dir::Prev).unwrap_err(),
    );
    assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
}

#[test]
fn payload_contradicting_bit_header_is_malformed() {
    for (claimed, body) in [(100u64, 1usize), (8, 0), (1, 13)] {
        let err = craft_and_check(
            move |c| {
                let mut lie = Vec::new();
                lie.extend_from_slice(&claimed.to_le_bytes());
                lie.extend(std::iter::repeat(0xFFu8).take(body));
                c.send_raw(Dir::Next, lie).unwrap();
            },
            |c| c.recv_bits(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "claimed={claimed} body={body}: {err:?}");
    }
}

#[test]
fn oversized_bit_count_is_rejected_before_allocation() {
    // headers claiming more than the 1 GiB message cap's worth of bits
    // (incl. u64::MAX) must be rejected without allocating the claim
    for claimed in [MAX_MSG_BYTES * 8 + 1, u64::MAX] {
        let err = craft_and_check(
            move |c| {
                c.send_raw(Dir::Next, claimed.to_le_bytes().to_vec())
                    .unwrap();
            },
            |c| c.recv_bits(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "claimed={claimed}: {err:?}");
    }
}

#[test]
fn ragged_ring_payload_is_malformed() {
    for bytes in [1usize, 5, 7, 9] {
        let err = craft_and_check(
            move |c| c.send_raw(Dir::Next, vec![0u8; bytes]).unwrap(),
            |c| c.recv_elems(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "{bytes} bytes: {err:?}");
    }
}

#[test]
fn wire_padding_never_reaches_computation() {
    // a peer that sets the padding bits of a bit message: decode must
    // mask them so word-parallel XOR/popcount see clean tails
    let got = craft_and_check(
        |c| {
            let mut msg = Vec::new();
            msg.extend_from_slice(&5u64.to_le_bytes()); // 5 bits, 1 byte
            msg.push(0xFF); // 3 dirty padding bits
            c.send_raw(Dir::Next, msg).unwrap();
        },
        |c| c.recv_bits(Dir::Prev).unwrap(),
    );
    assert_eq!(got.len(), 5);
    assert_eq!(got.popcount(), 5);
    assert_eq!(got, BitTensor::ones(5));
}

#[test]
fn plane_padding_never_reaches_computation() {
    // dirty per-plane padding in a planes frame (2 planes of 5 bits,
    // padded to one word each) is cleared by the reinterpret
    let got = craft_and_check(
        |c| {
            let mut msg = Vec::new();
            msg.extend_from_slice(&128u64.to_le_bytes()); // 2*1*64 bits
            msg.extend(std::iter::repeat(0xFFu8).take(16));
            c.send_raw(Dir::Next, msg).unwrap();
        },
        |c| c.recv_planes(Dir::Prev, 2, 5).unwrap(),
    );
    assert_eq!(got.popcount(), 10, "plane padding leaked");
    for p in 0..2 {
        assert_eq!(got.plane(p), BitTensor::ones(5));
    }
}

#[test]
fn planes_frame_with_wrong_geometry_is_malformed() {
    // an honest 2x64 frame received as 3x64 / 2x65 / 1x64 must be
    // rejected as malformed, not mis-sliced
    for (planes, len) in [(3usize, 64usize), (2, 65), (1, 64)] {
        let err = craft_and_check(
            move |c| {
                let m = BitPlanes::zeros(2, 64);
                c.send_planes(Dir::Next, &m).unwrap();
            },
            move |c| c.recv_planes(Dir::Prev, planes, len).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)),
                "{planes}x{len}: {err:?}");
    }
}

// ---- tagged channel frames ----------------------------------------------

#[test]
fn unknown_channel_tag_is_malformed() {
    // the tag byte is attacker-controlled like everything else: a frame
    // tagged outside {online, offline} must be Malformed, not mis-routed
    for tag in [2u8, 7, 0x80, 0xFF] {
        let err = craft_and_check(
            move |c| {
                let mut frame = vec![tag];
                frame.extend_from_slice(&5u64.to_le_bytes());
                frame.push(0x1F);
                c.send_frame(Dir::Next, frame).unwrap();
            },
            |c| c.recv_bits(Dir::Prev).unwrap_err(),
        );
        assert!(matches!(err, WireError::Malformed(_)), "tag {tag}: {err:?}");
    }
}

#[test]
fn frame_too_short_for_its_tag_is_malformed() {
    // tag/length mismatch: a zero-length frame cannot even hold the
    // channel tag the header format promises
    let err = craft_and_check(
        |c| c.send_frame(Dir::Next, vec![]).unwrap(),
        |c| c.recv_elems(Dir::Prev).unwrap_err(),
    );
    assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
    // a tag-only frame parses as an empty payload: fine for the elems
    // codec (zero elements), Malformed for the bit codec (no header)
    let got = craft_and_check(
        |c| c.send_frame(Dir::Next, vec![0u8]).unwrap(),
        |c| c.recv_elems(Dir::Prev).unwrap(),
    );
    assert!(got.is_empty());
    let err = craft_and_check(
        |c| c.send_frame(Dir::Next, vec![0u8]).unwrap(),
        |c| c.recv_bits(Dir::Prev).unwrap_err(),
    );
    assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
}

#[test]
fn offline_frame_during_pending_online_recv_is_parked_not_consumed() {
    // the checker's online recv is already pending when the offline
    // frame lands: the demux must park it for the offline handle and
    // keep waiting for the online frame
    let (online, offline) = craft_and_check(
        |c| {
            let off = c.channel(Chan::Offline);
            off.send_bits(Dir::Next, &BitTensor::ones(9)).unwrap();
            // give the pending online recv a chance to be the thread
            // that reads (and must park) the offline frame
            std::thread::sleep(std::time::Duration::from_millis(20));
            c.send_bits(Dir::Next, &BitTensor::zeros(5)).unwrap();
        },
        |c| {
            let online = c.recv_bits(Dir::Prev).unwrap();
            let offline = c.channel(Chan::Offline)
                .recv_bits(Dir::Prev).unwrap();
            (online, offline)
        },
    );
    assert_eq!(online, BitTensor::zeros(5));
    assert_eq!(offline, BitTensor::ones(9));
}

#[test]
fn online_frames_park_symmetrically_for_offline_recv() {
    let (offline, online1, online2) = craft_and_check(
        |c| {
            c.send_elems(Dir::Next, &[1]).unwrap();
            c.send_elems(Dir::Next, &[2]).unwrap();
            c.channel(Chan::Offline).send_elems(Dir::Next, &[3]).unwrap();
        },
        |c| {
            // the offline recv must skip over (and park, in order) both
            // online frames
            let off = c.channel(Chan::Offline).recv_elems(Dir::Prev)
                .unwrap();
            (off,
             c.recv_elems(Dir::Prev).unwrap(),
             c.recv_elems(Dir::Prev).unwrap())
        },
    );
    assert_eq!(offline, vec![3]);
    assert_eq!(online1, vec![1]);
    assert_eq!(online2, vec![2]);
}

#[test]
fn hung_up_peer_errors_on_both_paths() {
    let [c0, c1, c2] = local_trio(NetConfig::zero());
    drop(c1);
    drop(c2);
    // send path: both neighbours are gone
    assert!(matches!(c0.send_elems(Dir::Next, &[1]).unwrap_err(),
                     WireError::Closed));
    assert!(matches!(c0.send_bits(Dir::Prev, &BitTensor::ones(4))
                     .unwrap_err(), WireError::Closed));
    assert!(matches!(c0.send_planes(Dir::Next, &BitPlanes::zeros(1, 4))
                     .unwrap_err(), WireError::Closed));
    // receive path: nothing will ever arrive
    assert!(matches!(c0.recv_elems(Dir::Next).unwrap_err(),
                     WireError::Closed));
    assert!(matches!(c0.recv_bits(Dir::Prev).unwrap_err(),
                     WireError::Closed));
}
