//! Request-plane acceptance tests (ISSUE 10): concurrent submitters
//! through the dynamic-batching front stay bit-identical to serial
//! `Service::infer`, overload sheds typed *before* any request-path
//! mint (`underflow_calls == 0` across a shed burst), a flooding
//! tenant cannot starve a quiet one (per-tenant rollups witness it),
//! consistent-hash sharding spreads a model over several slots, and
//! adaptive watermark resizes run only on the dispatch thread.
//!
//! Bit-identity uses the trunc-free `sep_chain_model`: without a
//! truncation layer the logits are an exact function of each input
//! sample, independent of batch composition and of the masks drawn --
//! so batched-vs-serial equality is exact, not toleranced.

use std::sync::Arc;

use cbnn::coordinator::{BatcherPolicy, ModelSpec, PlaneConfig,
                        RegistryError, RequestPlane, Service, ShedReason};
use cbnn::engine::session::SessionConfig;
use cbnn::nn::Model;
use cbnn::offline::BankConfig;
use cbnn::ring::Tensor;
use cbnn::testutil::threeparty::sep_chain_model;
use cbnn::testutil::Rng;

fn images(n: usize, seed: u64) -> Vec<Tensor> {
    let model = sep_chain_model();
    let (c, h, w) = model.input;
    let flat = c * h * w;
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.tensor_small(&[1, flat], 15)).collect()
}

fn cfg_with_batch(max_batch: usize) -> SessionConfig {
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.max_batch = max_batch;
    cfg
}

/// The serial reference arm: one standalone `Service`, one sample per
/// `infer` call -- no batching, no plane.
fn serial_logits(model: Arc<Model>, cfg: SessionConfig,
                 imgs: &[Tensor]) -> Vec<Vec<i32>> {
    let svc = Service::start(model, cfg).expect("reference service");
    let out = imgs.iter()
        .map(|img| {
            let mut batch = svc.infer(vec![img.clone()])
                .expect("reference sample");
            batch.pop().expect("one logit row")
        })
        .collect();
    let _ = svc.shutdown();
    out
}

fn plane_for(model: Arc<Model>, cfg: &SessionConfig,
             policy: BatcherPolicy, shards: u8) -> RequestPlane {
    RequestPlane::start(
        vec![ModelSpec::new("sepchain".to_string(), model)],
        cfg,
        PlaneConfig { policy, shards },
    ).expect("plane up")
}

#[test]
fn concurrent_submitters_bit_identical_to_serial() {
    const TENANTS: usize = 3;
    const PER_TENANT: usize = 8;
    let model = Arc::new(sep_chain_model());
    let imgs = images(TENANTS * PER_TENANT, 0xA11CE);
    let reference = serial_logits(Arc::clone(&model), cfg_with_batch(1),
                                  &imgs);

    let cfg = cfg_with_batch(4);
    let plane = plane_for(Arc::clone(&model), &cfg, BatcherPolicy {
        max_batch: 4,
        slo: std::time::Duration::from_millis(100),
        max_queue: 64,
        prefetch: 2,
        adaptive: false,
    }, 1);
    // three tenants submit concurrently: requests interleave in the
    // queue, the batcher coalesces them into mixed windows
    let got: Vec<(usize, Vec<i32>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..TENANTS {
            let plane = &plane;
            let imgs = &imgs;
            let tenant = format!("t{t}");
            handles.push(s.spawn(move || {
                let rxs: Vec<_> = (0..PER_TENANT).map(|j| {
                    let k = t * PER_TENANT + j;
                    (k, plane.submit("sepchain", &tenant,
                                     imgs[k].clone())
                        .expect("admitted"))
                }).collect();
                rxs.into_iter().map(|(k, rx)| {
                    let resp = rx.recv().expect("batcher alive")
                        .expect("served");
                    (k, resp.logits)
                }).collect::<Vec<_>>()
            }));
        }
        handles.into_iter()
            .flat_map(|h| h.join().expect("submitter"))
            .collect()
    });

    for (k, logits) in &got {
        assert_eq!(logits, &reference[*k],
                   "request {k}: batched logits diverged from the \
                    serial reference");
    }
    let b = plane.batcher("sepchain").expect("unsharded slot name");
    let s = b.stats();
    assert_eq!(s.plane.served, (TENANTS * PER_TENANT) as u64);
    assert!(s.plane.coalesced_max >= 2,
            "no window coalesced concurrent requests: {:?}", s.plane);
    assert!(s.plane.dispatches < s.plane.served,
            "every request dispatched alone: {:?}", s.plane);
    let pm = b.preproc_metrics();
    assert_eq!(pm.underflow_calls, 0,
               "warm plane minted on the request path: {pm:?}");
    let _ = plane.shutdown();
}

#[test]
fn dry_bank_burst_sheds_before_any_mint() {
    let model = Arc::new(sep_chain_model());
    let cfg = cfg_with_batch(4);
    // a bank that is *structurally* dry: valid (high + chunk <=
    // capacity) but far below the model's smallest batch draw, so
    // `can_serve_warm` is false from the first submit
    let bank = BankConfig { low: 1, high: 2, chunk: 1, capacity: 3 };
    bank.validate().expect("tiny bank is self-consistent");
    let plane = RequestPlane::start(
        vec![ModelSpec {
            name: "sepchain".to_string(),
            model: Arc::clone(&model),
            bank: Some(bank),
        }],
        &cfg,
        PlaneConfig { policy: BatcherPolicy {
            max_batch: 4,
            ..BatcherPolicy::default()
        }, shards: 1 },
    ).expect("plane up");

    let imgs = images(6, 0xD4);
    let mut sheds = 0;
    for (k, img) in imgs.into_iter().enumerate() {
        match plane.submit("sepchain", "burst", img) {
            Err(RegistryError::Overloaded {
                model, reason: ShedReason::BankDry { max_draw, capacity },
            }) => {
                sheds += 1;
                assert_eq!(model, "sepchain");
                assert_eq!(capacity, 3);
                assert!(max_draw + 1 > capacity,
                        "shed reason inconsistent: draw {max_draw} \
                         fits capacity {capacity}");
            }
            other => panic!("request {k}: expected a BankDry shed, \
                             got {other:?}"),
        }
    }
    assert_eq!(sheds, 6);
    let b = plane.batcher("sepchain").unwrap();
    let s = b.stats();
    assert_eq!(s.plane.shed_dry, 6);
    assert_eq!(s.plane.served, 0);
    // the contract under test: shedding decided *before* any mint, so
    // the burst left the deterministic credit accounting untouched
    let pm = b.preproc_metrics();
    assert_eq!(pm.underflow_calls, 0,
               "a shed burst reached try_reserve: {pm:?}");
    assert_eq!(pm.fallback_elems, 0, "{pm:?}");
    // tenant rollup counted every shed
    let t = &s.tenants[0];
    assert_eq!((t.tenant.as_str(), t.submitted, t.served, t.shed),
               ("burst", 6, 0, 6));
    let _ = plane.shutdown();
}

#[test]
fn queue_full_sheds_typed_and_drains_admitted_on_finish() {
    let model = Arc::new(sep_chain_model());
    let cfg = cfg_with_batch(8);
    // max_queue < max_batch and a very long SLO: the first window
    // stays open (it can never fill), so the queue deterministically
    // saturates at max_queue and further submits shed QueueFull
    let plane = plane_for(Arc::clone(&model), &cfg, BatcherPolicy {
        max_batch: 8,
        slo: std::time::Duration::from_secs(30),
        max_queue: 4,
        prefetch: 2,
        adaptive: false,
    }, 1);
    let imgs = images(10, 0x0F);
    let reference = serial_logits(Arc::clone(&model), cfg_with_batch(1),
                                  &imgs);
    let mut admitted = Vec::new();
    let mut sheds = 0;
    for (k, img) in imgs.iter().cloned().enumerate() {
        match plane.submit("sepchain", "t0", img) {
            Ok(rx) => admitted.push((k, rx)),
            Err(RegistryError::Overloaded {
                reason: ShedReason::QueueFull { depth, limit }, ..
            }) => {
                sheds += 1;
                assert_eq!((depth, limit), (4, 4));
            }
            Err(other) => panic!("request {k}: {other}"),
        }
    }
    assert_eq!(admitted.len(), 4);
    assert_eq!(sheds, 6);
    // shutdown closes the window early and drains: every admitted
    // request is still served, bit-identical
    let answers: Vec<(usize, Vec<i32>)> = std::thread::scope(|s| {
        let h = s.spawn(move || {
            admitted.into_iter().map(|(k, rx)| {
                (k, rx.recv().expect("drained").expect("served").logits)
            }).collect()
        });
        // receive concurrently with shutdown: finish() must not drop
        // admitted waiters
        let stats = {
            let b = plane.batcher("sepchain").unwrap();
            b.stats()
        };
        assert_eq!(stats.plane.shed_queue, 6);
        let _ = plane.shutdown();
        h.join().expect("receiver")
    });
    for (k, logits) in &answers {
        assert_eq!(logits, &reference[*k], "drained request {k}");
    }
}

#[test]
fn flood_cannot_starve_quiet_tenant() {
    let model = Arc::new(sep_chain_model());
    let cfg = cfg_with_batch(4);
    let plane = plane_for(Arc::clone(&model), &cfg, BatcherPolicy {
        max_batch: 4,
        // long enough that both tenants' submits land before the first
        // window closes
        slo: std::time::Duration::from_millis(300),
        max_queue: 64,
        prefetch: 2,
        adaptive: false,
    }, 1);
    let flood_imgs = images(20, 0xF100D);
    let quiet_imgs = images(2, 0x0B);
    let flood: Vec<_> = flood_imgs.into_iter()
        .map(|img| plane.submit("sepchain", "flood", img)
            .expect("admitted"))
        .collect();
    let quiet: Vec<_> = quiet_imgs.into_iter()
        .map(|img| plane.submit("sepchain", "quiet", img)
            .expect("admitted"))
        .collect();
    for rx in quiet {
        rx.recv().expect("alive").expect("quiet tenant served");
    }
    for rx in flood {
        rx.recv().expect("alive").expect("flood tenant served");
    }
    let b = plane.batcher("sepchain").unwrap();
    let s = b.stats();
    let find = |name: &str| s.tenants.iter()
        .find(|t| t.tenant == name)
        .unwrap_or_else(|| panic!("no rollup for tenant {name}"))
        .clone();
    let f = find("flood");
    let q = find("quiet");
    assert_eq!(q.served, 2);
    assert_eq!(f.served, 20);
    // the fairness witness: round-robin put the quiet tenant's last
    // request in an EARLIER window than the flood's backlog tail
    assert!(q.last_window > 0 && q.last_window < f.last_window,
            "quiet tenant starved behind the flood: quiet window {} \
             vs flood window {}", q.last_window, f.last_window);
    // the same rows surface through the plane's ModelRollup overlay
    // (what --metrics-out renders as cbnn_tenant_requests_total)
    let rollup = plane.rollups().into_iter()
        .find(|r| r.name == "sepchain").expect("sepchain rollup");
    assert_eq!(rollup.plane.served, 22);
    assert!(rollup.tenants.iter().any(
                |t| t.tenant == "quiet" && t.served == 2),
            "per-tenant rollup missing: {:?}", rollup.tenants);
    let _ = plane.shutdown();
}

#[test]
fn sharded_plane_serves_correctly_across_slots() {
    let model = Arc::new(sep_chain_model());
    let cfg = cfg_with_batch(4);
    let imgs = images(24, 0x54A2D);
    let reference = serial_logits(Arc::clone(&model), cfg_with_batch(1),
                                  &imgs);
    let plane = plane_for(Arc::clone(&model), &cfg, BatcherPolicy {
        max_batch: 4,
        slo: std::time::Duration::from_millis(20),
        max_queue: 64,
        prefetch: 2,
        adaptive: false,
    }, 3);
    let slots = plane.shard_slots("sepchain");
    assert_eq!(slots, vec!["sepchain#0", "sepchain#1", "sepchain#2"]);
    // two tenants' streams spread across the shards by consistent hash
    let rxs: Vec<_> = imgs.iter().cloned().enumerate()
        .map(|(k, img)| {
            let tenant = if k % 2 == 0 { "even" } else { "odd" };
            (k, plane.submit("sepchain", tenant, img)
                .expect("admitted"))
        })
        .collect();
    for (k, rx) in rxs {
        let resp = rx.recv().expect("alive").expect("served");
        // every shard runs the identical (trunc-free) function, so
        // routing is invisible in the logits -- exactly the property
        // that makes sharding safe
        assert_eq!(resp.logits, reference[k],
                   "request {k} diverged on its shard");
    }
    let served_per_shard: Vec<u64> = slots.iter()
        .map(|s| plane.batcher(s).unwrap().stats().plane.served)
        .collect();
    assert_eq!(served_per_shard.iter().sum::<u64>(), 24);
    assert!(served_per_shard.iter().filter(|&&n| n > 0).count() >= 2,
            "consistent hash routed everything to one shard: \
             {served_per_shard:?}");
    for slot in &slots {
        let pm = plane.batcher(slot).unwrap().preproc_metrics();
        assert_eq!(pm.underflow_calls, 0, "shard {slot}: {pm:?}");
    }
    let _ = plane.shutdown();
}

#[test]
fn adaptive_watermarks_resize_only_off_the_request_path() {
    let model = Arc::new(sep_chain_model());
    // arm 1: a plain service driven serially never retunes -- the
    // resize is not wired anywhere near the request path
    let svc = Service::start(Arc::clone(&model), cfg_with_batch(4))
        .expect("service");
    let imgs = images(12, 0xADA);
    for img in &imgs {
        svc.infer(vec![img.clone()]).expect("serial");
    }
    let pm = svc.bank_handle(0).metrics();
    assert_eq!(pm.retunes, 0,
               "serial inference retuned the bank: {pm:?}");
    let _ = svc.shutdown();

    // arm 2: the adaptive plane observes windows of 1 against a bank
    // sized for windows of 8, and shrinks the watermarks from the
    // dispatch thread (counted in PreprocMetrics::retunes)
    let cfg = cfg_with_batch(8);
    let plane = plane_for(Arc::clone(&model), &cfg, BatcherPolicy {
        max_batch: 8,
        slo: std::time::Duration::from_millis(2),
        max_queue: 64,
        prefetch: 2,
        adaptive: true,
    }, 1);
    let reference = serial_logits(Arc::clone(&model), cfg_with_batch(1),
                                  &imgs);
    for round in 0..2 {
        for (k, img) in imgs.iter().cloned().enumerate() {
            // one at a time: every request is its own dispatch window
            let rx = plane.submit("sepchain", "solo", img)
                .expect("admitted");
            let resp = rx.recv().expect("alive").expect("served");
            assert_eq!(resp.logits, reference[k],
                       "round {round} request {k} diverged after a \
                        retune");
        }
    }
    let b = plane.batcher("sepchain").unwrap();
    let pm = b.preproc_metrics();
    assert!(pm.retunes > 0,
            "24 one-request windows never triggered the adaptive \
             sizer: {pm:?}");
    assert_eq!(pm.underflow_calls, 0,
               "a retune pushed draws onto the request path: {pm:?}");
    let _ = plane.shutdown();
}

/// Plane churn soak: repeated build -> multi-tenant flood (with a queue
/// small enough to force sheds) -> drain -> shutdown cycles.  Run with
/// `cargo test -q --test request_plane -- --ignored` (CBNN_PLANE_ITERS
/// scales the run).
#[test]
#[ignore = "long soak; run with --ignored (CBNN_PLANE_ITERS scales the \
            run)"]
fn request_plane_churn_soak() {
    let iters: usize = std::env::var("CBNN_PLANE_ITERS").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(3);
    let model = Arc::new(sep_chain_model());
    for iter in 0..iters {
        let cfg = cfg_with_batch(4);
        let plane = plane_for(Arc::clone(&model), &cfg, BatcherPolicy {
            max_batch: 4,
            slo: std::time::Duration::from_millis(5),
            max_queue: 6,
            prefetch: 2,
            adaptive: iter % 2 == 1,
        }, 2);
        let imgs = images(12, 0x50AC ^ iter as u64);
        let (served, shed) = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..3 {
                let plane = &plane;
                let imgs = &imgs;
                let tenant = format!("t{t}");
                handles.push(s.spawn(move || {
                    let mut rxs = Vec::new();
                    let mut shed = 0u64;
                    for img in imgs.iter().cloned() {
                        match plane.submit("sepchain", &tenant, img) {
                            Ok(rx) => rxs.push(rx),
                            Err(RegistryError::Overloaded { .. }) =>
                                shed += 1,
                            Err(e) => panic!("submit: {e}"),
                        }
                    }
                    let mut served = 0u64;
                    for rx in rxs {
                        match rx.recv().expect("batcher alive") {
                            Ok(_) => served += 1,
                            Err(RegistryError::Overloaded { .. }) =>
                                shed += 1,
                            Err(e) => panic!("request: {e}"),
                        }
                    }
                    (served, shed)
                }));
            }
            handles.into_iter()
                .map(|h| h.join().expect("submitter"))
                .fold((0u64, 0u64), |a, b| (a.0 + b.0, a.1 + b.1))
        });
        assert_eq!(served + shed, 36,
                   "iter {iter}: {served} served + {shed} shed != 36 \
                    submitted");
        assert!(served > 0, "iter {iter}: everything shed");
        for slot in plane.shard_slots("sepchain") {
            let pm = plane.batcher(&slot).unwrap().preproc_metrics();
            assert_eq!(pm.underflow_calls, 0,
                       "iter {iter} shard {slot}: {pm:?}");
        }
        plane.shutdown().expect("clean shutdown");
    }
}
