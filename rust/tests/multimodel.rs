//! Multi-model serving acceptance tests (ISSUE 4): two `Service`s over
//! one process's three links serve interleaved batches with
//! bit-identical logits vs. their single-model reference runs, zero
//! warm-bank request-path mints per model, and per-model `ChanStats`
//! that sum to the link totals.

use std::sync::Arc;

use cbnn::coordinator::{ModelRegistry, ModelSpec, Service};
use cbnn::engine::session::SessionConfig;
use cbnn::nn::Model;
use cbnn::ring::Tensor;
use cbnn::testutil::threeparty::{every_op_model, every_op_model_variant};
use cbnn::testutil::Rng;
use cbnn::transport::ChanId;

const BATCHES: usize = 3;
const BATCH: usize = 2;

fn batches_for(stream_seed: u64) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(stream_seed);
    (0..BATCHES).map(|_| {
        (0..BATCH).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
    }).collect()
}

/// The single-model reference arm: a standalone `Service` pinned to the
/// same channel-id slot runs the identical seed domain, bank schedule,
/// and batch sequence as that slot inside a registry.
fn single_model_run(model: Arc<Model>, slot: u8,
                    inputs: &[Vec<Tensor>]) -> Vec<Vec<Vec<i32>>> {
    let svc = Service::start_at(model, SessionConfig::new("artifacts/hlo"),
                                slot)
        .expect("standalone service");
    let out = inputs.iter()
        .map(|b| svc.infer(b.clone()).expect("reference batch"))
        .collect();
    let _ = svc.shutdown();
    out
}

#[test]
fn two_services_share_links_bit_identically_with_clean_banks() {
    let model_a = Arc::new(every_op_model());
    let model_b = Arc::new(every_op_model_variant("everyop-b", 3));
    let cfg = SessionConfig::new("artifacts/hlo");
    let reg = ModelRegistry::start(vec![
        ModelSpec::new("a", Arc::clone(&model_a)),
        ModelSpec::new("b", Arc::clone(&model_b)),
    ], &cfg).expect("registry up");
    assert_eq!(reg.names(), vec!["a", "b"]);

    let in_a = batches_for(100);
    let in_b = batches_for(200);
    // interleave the two models' batches over the shared links
    let mut out_a = Vec::new();
    let mut out_b = Vec::new();
    for i in 0..BATCHES {
        out_a.push(reg.infer("a", in_a[i].clone()).expect("a batch"));
        out_b.push(reg.infer("b", in_b[i].clone()).expect("b batch"));
    }

    // acceptance: zero request-path mints per model (both banks warm)
    for name in ["a", "b"] {
        let m = reg.service(name).unwrap().bank_handle(0).metrics();
        assert_eq!(m.underflow_calls, 0,
                   "model {name} minted on the request path: {m:?}");
        assert_eq!(m.fallback_elems, 0, "model {name}: {m:?}");
        assert!(m.drawn > 0, "model {name} never drew from its bank");
    }

    // acceptance: per-model ChanStats sum to the link totals, per party
    for p in 0..3 {
        let s = reg.link_stats(p);
        let (mut bytes, mut msgs, mut rounds) = (0u64, 0u64, 0u64);
        for (_, c) in s.channels() {
            bytes += c.bytes_sent;
            msgs += c.messages;
            rounds += c.rounds;
        }
        assert_eq!(bytes, s.bytes_sent, "party {p} byte rows");
        assert_eq!(msgs, s.messages, "party {p} message rows");
        assert_eq!(rounds, s.rounds, "party {p} round rows");
        // all four lanes moved traffic
        for slot in [0u8, 1] {
            assert!(s.chan(ChanId::online(slot)).bytes_sent > 0,
                    "party {p} slot {slot} online lane idle");
            assert!(s.chan(ChanId::offline(slot)).bytes_sent > 0,
                    "party {p} slot {slot} offline lane idle");
        }
    }

    // per-model rollups name the right slots and carry both lanes
    let rollups = reg.rollups();
    assert_eq!(rollups.len(), 2);
    assert_eq!((rollups[0].name.as_str(), rollups[0].slot), ("a", 0));
    assert_eq!((rollups[1].name.as_str(), rollups[1].slot), ("b", 1));
    for r in &rollups {
        assert!(r.online.bytes_sent > 0 && r.offline.bytes_sent > 0,
                "rollup {}: {r:?}", r.name);
        assert!(r.total_bytes() >= r.online.bytes_sent);
    }
    let _ = reg.shutdown();

    // acceptance: bit-identical logits vs. single-model runs at the
    // same slots (same seed domains, same bank chunk schedules)
    let ref_a = single_model_run(model_a, 0, &in_a);
    let ref_b = single_model_run(model_b, 1, &in_b);
    assert_eq!(out_a, ref_a,
               "model a diverged from its single-model run");
    assert_eq!(out_b, ref_b,
               "model b diverged from its single-model run");
    // and the two models really compute different functions
    assert_ne!(out_a, out_b);
}

#[test]
fn registry_slot_seeding_separates_equal_models() {
    // the same model at two slots draws from two PRF domains: both
    // lanes serve correct-but-independent sessions, and the per-slot
    // reference arms reproduce each bit-for-bit
    let model = Arc::new(every_op_model());
    let cfg = SessionConfig::new("artifacts/hlo");
    let reg = ModelRegistry::start(vec![
        ModelSpec::new("first", Arc::clone(&model)),
        ModelSpec::new("second", Arc::clone(&model)),
    ], &cfg).expect("registry up");
    let inputs = batches_for(300);
    let first: Vec<_> = inputs.iter()
        .map(|b| reg.infer("first", b.clone()).unwrap()).collect();
    let second: Vec<_> = inputs.iter()
        .map(|b| reg.infer("second", b.clone()).unwrap()).collect();
    let _ = reg.shutdown();
    // same function: predictions agree (identical model + inputs); the
    // raw logits may each differ from the exact value by the truncation
    // protocol's +-1 LSB, which is mask-dependent and the domains are
    // separated on purpose -- so two independent runs can be up to 2
    // apart (one at exact+1, the other at exact-1)
    for (fb, sb) in first.iter().zip(&second) {
        for (fl, sl) in fb.iter().zip(sb) {
            for (a, b) in fl.iter().zip(sl) {
                assert!((a - b).abs() <= 2,
                        "slot outputs beyond trunc tolerance: {a} vs {b}");
            }
        }
    }
    // each slot is bit-identical to its standalone arm
    let ref0 = single_model_run(Arc::clone(&model), 0, &inputs);
    let ref1 = single_model_run(model, 1, &inputs);
    assert_eq!(first, ref0);
    assert_eq!(second, ref1);
}
