//! Registry lifecycle acceptance tests (ISSUE 5): quarantining one
//! desynchronized model slot leaves its neighbours bit-identical,
//! hot-swap reuses freed slots with bit-identical logits, and a flooded
//! idle lane trips the parked-bytes cap without perturbing a healthy
//! lane.  The `--ignored` churn soak is the CI job
//! (`CBNN_CHURN_ITERS` scales it).

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cbnn::coordinator::{ModelRegistry, ModelSpec, RegistryError, Service,
                        SlotState};
use cbnn::engine::session::SessionConfig;
use cbnn::nn::Model;
use cbnn::ring::Tensor;
use cbnn::testutil::threeparty::{every_op_model, every_op_model_variant};
use cbnn::testutil::Rng;
use cbnn::transport::{local_trio, ChanId, Dir, NetConfig, WireError};

const BATCHES: usize = 3;
const BATCH: usize = 2;

fn batches_for(stream_seed: u64) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(stream_seed);
    (0..BATCHES).map(|_| {
        (0..BATCH).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
    }).collect()
}

/// The single-model reference arm (same shape as multimodel.rs): a
/// standalone `Service` at the same slot runs the identical seed
/// domain, bank schedule, and batch sequence as that slot inside a
/// registry.
fn single_model_run(model: Arc<Model>, slot: u8,
                    inputs: &[Vec<Tensor>]) -> Vec<Vec<Vec<i32>>> {
    let svc = Service::start_at(model, SessionConfig::new("artifacts/hlo"),
                                slot)
        .expect("standalone service");
    let out = inputs.iter()
        .map(|b| svc.infer(b.clone()).expect("reference batch"))
        .collect();
    let _ = svc.shutdown();
    out
}

/// Acceptance (a): killing one model's lane mid-batch quarantines only
/// that slot; a second model's interleaved batches stay bit-identical
/// to its single-model reference, and the slot respawns on a fresh
/// epoch.
#[test]
fn lane_death_quarantines_only_that_slot() {
    let model_a = Arc::new(every_op_model());
    let model_b = Arc::new(every_op_model_variant("everyop-b", 3));
    let cfg = SessionConfig::new("artifacts/hlo");
    let reg = ModelRegistry::start(vec![
        ModelSpec::new("a", Arc::clone(&model_a)),
        ModelSpec::new("b", Arc::clone(&model_b)),
    ], &cfg).expect("registry up");

    let in_a = batches_for(100);
    let in_b = batches_for(200);
    let mut out_b = Vec::new();

    // healthy interleaving first
    assert!(reg.infer("a", in_a[0].clone()).is_ok());
    out_b.push(reg.infer("b", in_b[0].clone()).expect("b batch 0"));

    // retire model a's online lane on party 1 only: the next a-batch
    // dies mid-protocol, leaving a's other party threads blocked on the
    // *shared* links -- the failure shape that used to force a process
    // restart
    reg.service("a").unwrap().sever_lane(1);
    thread::scope(|s| {
        let stuck = s.spawn(|| reg.infer("a", in_a[1].clone()));
        thread::sleep(Duration::from_millis(50));
        // model b keeps serving over the same links while a is stuck
        out_b.push(reg.infer("b", in_b[1].clone()).expect("b batch 1"));
        // quarantine cancels only slot a: its blocked threads unwind,
        // the stuck request errs instead of hanging
        reg.quarantine("a").expect("quarantine a");
        let got = stuck.join().expect("request thread");
        assert!(got.is_err(), "batch on the severed lane must error");
        out_b.push(reg.infer("b", in_b[2].clone()).expect("b batch 2"));
    });
    assert_eq!(reg.state("a").unwrap(), SlotState::Quarantined);
    assert_eq!(reg.state("b").unwrap(), SlotState::Serving);

    // routing to a quarantined slot is a typed error, not a hang
    match reg.infer("a", in_a[2].clone()) {
        Err(RegistryError::SlotUnavailable { state, .. }) =>
            assert_eq!(state, SlotState::Quarantined),
        other => panic!("expected SlotUnavailable, got {other:?}"),
    }

    // respawn: same ChanId lanes, fresh seed epoch
    reg.respawn("a").expect("respawn a");
    assert_eq!(reg.state("a").unwrap(), SlotState::Serving);
    let served = reg.infer("a", in_a[2].clone()).expect("respawned batch");
    assert_eq!(served.len(), BATCH);
    assert_eq!(served[0].len(), 3);
    // the respawned epoch matches its standalone reference arm
    let ref_a1 = {
        let svc = Service::start_at_epoch(
            Arc::clone(&model_a), SessionConfig::new("artifacts/hlo"), 0, 1)
            .expect("epoch-1 reference");
        let out = svc.infer(in_a[2].clone()).expect("reference batch");
        let _ = svc.shutdown();
        out
    };
    assert_eq!(served, ref_a1,
               "respawned slot diverged from its epoch-1 reference");

    // lifecycle counters recorded the churn
    let lc = reg.lifecycle_counters();
    assert_eq!(lc.get(&0).map(|c| (c.quarantines, c.respawns, c.epoch)),
               Some((1, 1, 1)));

    // b never noticed: zero request-path mints, bit-identical logits
    let mb = reg.service("b").unwrap().bank_handle(0).metrics();
    assert_eq!(mb.underflow_calls, 0, "b minted on the request path");
    let _ = reg.shutdown();
    let ref_b = single_model_run(model_b, 1, &in_b);
    assert_eq!(out_b, ref_b, "model b diverged while a churned");
}

/// Acceptance (b): add -> remove -> add on a live registry reuses the
/// freed slot id and serves bit-identical logits to a standalone run at
/// that slot.
#[test]
fn hot_swap_reuses_freed_slot_bit_identically() {
    let model_a = Arc::new(every_op_model());
    let model_b = Arc::new(every_op_model_variant("everyop-b", 3));
    let model_c = Arc::new(every_op_model_variant("everyop-c", 5));
    let cfg = SessionConfig::new("artifacts/hlo");
    let reg = ModelRegistry::start(
        vec![ModelSpec::new("a", Arc::clone(&model_a))], &cfg)
        .expect("registry up");

    // hot-add b onto the live registry: next fresh slot
    let slot_b = reg.add_model(ModelSpec::new("b", Arc::clone(&model_b)))
        .expect("add b");
    assert_eq!(slot_b, 1);
    let in_b = batches_for(200);
    let mut out_b = Vec::new();
    out_b.push(reg.infer("b", in_b[0].clone()).expect("b serves"));
    assert!(reg.infer("a", batches_for(100)[0].clone()).is_ok());

    // remove a: quiesce-then-close, slot 0 joins the free list
    reg.remove_model("a").expect("remove a");
    assert_eq!(reg.names(), vec!["b"]);
    match reg.infer("a", batches_for(100)[0].clone()) {
        Err(RegistryError::UnknownModel(n)) => assert_eq!(n, "a"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }

    // add c: reuses the freed slot 0 (lowest-first)
    let slot_c = reg.add_model(ModelSpec::new("c", Arc::clone(&model_c)))
        .expect("add c");
    assert_eq!(slot_c, 0, "freed slot must be reused");
    assert_eq!(reg.names(), vec!["c", "b"]);

    let in_c = batches_for(400);
    let out_c: Vec<_> = in_c.iter()
        .map(|b| reg.infer("c", b.clone()).expect("c batch"))
        .collect();
    out_b.push(reg.infer("b", in_b[1].clone()).expect("b still serves"));
    out_b.push(reg.infer("b", in_b[2].clone()).expect("b still serves"));

    // swap counters on slot 0: one model out, one in
    let lc = reg.lifecycle_counters();
    assert_eq!(lc.get(&0).map(|c| (c.swaps_in, c.swaps_out)),
               Some((1, 1)));
    let _ = reg.shutdown();

    // the re-added slot is bit-identical to a standalone slot-0 run,
    // and b (slot 1) never deviated from its own reference
    let ref_c = single_model_run(model_c, 0, &in_c);
    assert_eq!(out_c, ref_c, "swapped-in model diverged at slot 0");
    let ref_b = single_model_run(model_b, 1, &in_b);
    assert_eq!(out_b, ref_b, "model b diverged across the swap");
}

/// Acceptance (c): a peer flooding a registered-but-idle lane trips the
/// parked-bytes cap -- the flooded lane's next recv is `Malformed`,
/// its parked storage stays bounded, and a healthy lane's concurrent
/// traffic is untouched.
#[test]
fn flooded_idle_lane_is_capped_without_hurting_healthy_lanes() {
    let [c0, c1, c2] = local_trio(NetConfig::zero());
    c1.set_parked_cap(512);
    let idle = c1.channel(ChanId::online(9)); // registered, never read
    let flooder = c0.channel(ChanId::online(9));
    let healthy_payload = vec![7i32; 4]; // 16 B + tag per frame
    for i in 0..40 {
        // 100 B of flood per healthy frame: the idle lane overflows its
        // 512 B cap early in the run
        flooder.send_raw(Dir::Next, vec![0xAB; 100]).unwrap();
        c0.send_elems(Dir::Next, &healthy_payload).unwrap();
        let got = c1.recv_elems(Dir::Prev).unwrap();
        assert_eq!(got, healthy_payload, "healthy frame {i} perturbed");
        assert!(c1.parked_bytes(ChanId::online(9)) <= 512,
                "parked bytes exceeded the cap at frame {i}");
    }
    // the flood was dropped, not stored: far less than 40 * 101 B parked
    assert!(c1.parked_bytes(ChanId::online(9)) <= 512);
    let err = idle.recv_elems(Dir::Prev).unwrap_err();
    match err {
        WireError::Malformed(m) => assert!(m.contains("parked cap"), "{m}"),
        other => panic!("expected Malformed, got {other:?}"),
    }
    // the healthy lane's stats never saw the flood: exactly 40 frames
    // of 17 bytes each on the sender's ONLINE row
    let s0 = c0.stats();
    assert_eq!(s0.online().messages, 40);
    assert_eq!(s0.online().bytes_sent, 40 * 17);
    drop(c2);
}

/// Auto-quarantine watchdog (ISSUE 6): consecutive `Service::infer`
/// failures on one slot trip the configured threshold, the registry
/// force-quarantines the slot (counted in
/// `LifecycleCounters::watchdog_trips`), and an operator `respawn`
/// restores service with the error streak reset.
#[test]
fn watchdog_quarantines_after_consecutive_infer_errors() {
    let model_a = Arc::new(every_op_model());
    let model_b = Arc::new(every_op_model_variant("everyop-b", 3));
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.max_consecutive_errors = 2;
    let reg = ModelRegistry::start(vec![
        ModelSpec::new("a", Arc::clone(&model_a)),
        ModelSpec::new("b", Arc::clone(&model_b)),
    ], &cfg).expect("registry up");
    let in_a = batches_for(100);

    // a healthy batch first: successes keep the streak at zero
    assert!(reg.infer("a", in_a[0].clone()).is_ok());

    // kill one of a's party threads abruptly: every subsequent infer
    // errors promptly (the dead thread's job queue is closed)
    reg.service("a").unwrap().inject_fault(2);

    // first failure: below the threshold of 2, the slot keeps serving
    assert!(reg.infer("a", in_a[1].clone()).is_err());
    assert_eq!(reg.state("a").unwrap(), SlotState::Serving,
               "one failure must not trip a threshold of 2");
    assert_eq!(reg.lifecycle_counters().get(&0)
                   .map_or(0, |c| c.watchdog_trips), 0);

    // second consecutive failure trips the watchdog
    assert!(reg.infer("a", in_a[2].clone()).is_err());
    assert_eq!(reg.state("a").unwrap(), SlotState::Quarantined,
               "watchdog must force-quarantine at the threshold");
    let lc = reg.lifecycle_counters();
    assert_eq!(lc.get(&0).map(|c| (c.watchdog_trips, c.quarantines)),
               Some((1, 1)));

    // routing to the tripped slot is the typed unavailable error now
    match reg.infer("a", in_a[0].clone()) {
        Err(RegistryError::SlotUnavailable { state, .. }) =>
            assert_eq!(state, SlotState::Quarantined),
        other => panic!("expected SlotUnavailable, got {other:?}"),
    }

    // the neighbour slot never noticed
    assert!(reg.infer("b", batches_for(200)[0].clone()).is_ok());

    // respawn: fresh epoch, streak reset -- one new failure must NOT
    // re-trip (the counter does not carry across the respawn)
    reg.respawn("a").expect("respawn a");
    assert_eq!(reg.state("a").unwrap(), SlotState::Serving);
    assert!(reg.infer("a", in_a[0].clone()).is_ok(),
            "respawned slot must serve again");
    assert_eq!(reg.lifecycle_counters().get(&0)
                   .map_or(99, |c| c.watchdog_trips), 1,
               "trip count must not grow on healthy traffic");
    let _ = reg.shutdown();
}

/// The CI churn soak: add/remove/quarantine/respawn under traffic for N
/// iterations, asserting zero request-path mints and exact `ChanStats`
/// rollups after every churn step.
#[test]
#[ignore = "CI churn soak: run with `cargo test --test lifecycle -- \
            --ignored` (CBNN_CHURN_ITERS scales the run)"]
fn churn_soak_add_remove_quarantine_respawn() {
    let iters: usize = std::env::var("CBNN_CHURN_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let model_a = Arc::new(every_op_model());
    let model_b = Arc::new(every_op_model_variant("everyop-b", 3));
    let cfg = SessionConfig::new("artifacts/hlo");
    let reg = ModelRegistry::start(vec![
        ModelSpec::new("a", Arc::clone(&model_a)),
        ModelSpec::new("b", Arc::clone(&model_b)),
    ], &cfg).expect("registry up");
    let mut rng = Rng::new(55);
    let mut batch =
        || -> Vec<Tensor> { vec![rng.tensor_small(&[1, 36], 15)] };

    let assert_invariants = |step: &str| {
        // exact rollups: per-lane rows sum to the link totals on every
        // party, after every churn step
        for p in 0..3 {
            let s = reg.link_stats(p);
            let (mut bytes, mut msgs, mut rounds) = (0u64, 0u64, 0u64);
            for (_, c) in s.channels() {
                bytes += c.bytes_sent;
                msgs += c.messages;
                rounds += c.rounds;
            }
            assert_eq!(bytes, s.bytes_sent, "party {p} bytes after {step}");
            assert_eq!(msgs, s.messages, "party {p} messages after {step}");
            assert_eq!(rounds, s.rounds, "party {p} rounds after {step}");
        }
        // zero request-path mints on every live bank
        for (name, _, state, _) in reg.status() {
            if state == SlotState::Serving {
                let m = reg.service(&name).unwrap()
                    .bank_handle(0).metrics();
                assert_eq!(m.underflow_calls, 0,
                           "{name} minted on the request path after \
                            {step}: {m:?}");
            }
        }
    };

    for i in 0..iters {
        assert_eq!(reg.infer("a", batch()).expect("a serves")[0].len(), 3);
        assert_eq!(reg.infer("b", batch()).expect("b serves")[0].len(), 3);
        assert_invariants("traffic");

        // hot add -> serve -> remove (slot 2 churns every iteration)
        let slot = reg.add_model(
            ModelSpec::new("tmp", Arc::clone(&model_b))).expect("add tmp");
        assert_eq!(slot, 2, "iteration {i}: tmp must reuse slot 2");
        assert!(reg.infer("tmp", batch()).is_ok());
        assert_invariants("add");
        reg.remove_model("tmp").expect("remove tmp");
        assert_invariants("remove");

        // sever one of a's lanes, quarantine, respawn on a fresh epoch
        reg.service("a").unwrap().sever_lane(i % 3);
        reg.quarantine("a").expect("quarantine a");
        assert_eq!(reg.state("a").unwrap(), SlotState::Quarantined);
        reg.respawn("a").expect("respawn a");
        assert_eq!(reg.infer("a", batch()).expect("a back")[0].len(), 3);
        assert_invariants("respawn");
    }

    let lc = reg.lifecycle_counters();
    let slot0 = lc.get(&0).copied().unwrap_or_default();
    assert_eq!(slot0.quarantines as usize, iters);
    assert_eq!(slot0.respawns as usize, iters);
    assert_eq!(slot0.epoch as usize, iters);
    let slot2 = lc.get(&2).copied().unwrap_or_default();
    assert_eq!(slot2.swaps_in as usize, iters);
    assert_eq!(slot2.swaps_out as usize, iters);
    let _ = reg.shutdown();
}
