//! Trace-integrity acceptance tests (PR 8): a traced three-party
//! inference produces per-party span streams that merge into one
//! timeline with zero cross-party round disagreements, per-channel
//! flight bytes that reconcile *exactly* with `transport::Stats`,
//! fused/unfused span trees that stay consistent modulo folded signs,
//! counted (never silent) ring-buffer overflow, and an on-disk JSONL
//! export that round-trips -- the artifact the `trace-validate` CI job
//! feeds to `ci/trace_check.py`.

use std::sync::Arc;
use std::thread;

use cbnn::engine::session::{run_inference, SessionConfig, SessionReport};
use cbnn::testutil::threeparty::{every_op_model, every_op_model_variant};
use cbnn::testutil::Rng;
use cbnn::trace::{self, merge, SpanKind, TraceSink};
use cbnn::transport::{local_trio, Dir, NetConfig};

fn inputs(seed: u64, n: usize) -> Vec<cbnn::ring::Tensor> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
}

fn traced_run(fuse: bool) -> SessionReport {
    let model = every_op_model();
    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.trace = true;
    cfg.opts.fuse = fuse;
    run_inference(&model, inputs(7, 2), &cfg).expect("traced inference")
}

#[test]
fn traced_inference_merges_with_zero_round_disagreements() {
    let model = every_op_model();
    let rep = traced_run(false);
    assert_eq!(rep.traces.len(), 3);

    for (party, spans) in rep.traces.iter().enumerate() {
        assert!(!spans.is_empty(), "party {party} recorded nothing");
        let count = |k: SpanKind| {
            spans.iter().filter(|s| s.kind == k).count()
        };
        // one request span, one op span per model op, and the
        // protocol + flight detail underneath them
        assert_eq!(count(SpanKind::Request), 1, "party {party}");
        assert_eq!(count(SpanKind::Op), model.ops.len(),
                   "party {party}");
        assert!(count(SpanKind::Protocol) > 0, "party {party}");
        assert!(count(SpanKind::Flight) > 0, "party {party}");
        // every span belongs to the one request minted for this run
        let ids: Vec<u64> = spans.iter().map(|s| s.trace_id).collect();
        assert!(ids.iter().all(|&t| t == ids[0] && t != 0),
                "party {party}: stray trace ids in {ids:?}");
        // the request span carries the model name
        let req = spans.iter()
            .find(|s| s.kind == SpanKind::Request).unwrap();
        assert_eq!(req.label.as_str(), "everyop");
        assert!(req.rounds > 0 && req.bytes_sent > 0);
    }

    // the acceptance criterion: the cross-party merge joins every
    // lock-step span and finds zero disagreements
    let report = merge::merge_check(&rep.traces);
    assert!(report.ok(), "merge problems: {:?}", report.problems);
    assert_eq!(report.traces.len(), 1);
    assert!(report.joined >= 1 + model.ops.len());

    // and every party's traced flight bytes sum per channel exactly
    // to its transport stats (tracing covered the whole post-reset
    // window the report's stats cover)
    for (party, spans) in rep.traces.iter().enumerate() {
        let problems =
            merge::check_flights(party, spans, &rep.stats[party]);
        assert!(problems.is_empty(), "{problems:?}");
    }
}

#[test]
fn fused_and_unfused_span_trees_agree_modulo_folded_signs() {
    let unfused = traced_run(false);
    let fused = traced_run(true);
    for rep in [&unfused, &fused] {
        let report = merge::merge_check(&rep.traces);
        assert!(report.ok(), "merge problems: {:?}", report.problems);
    }
    let ops = |rep: &SessionReport| -> Vec<(u32, String, u64)> {
        rep.traces[0].iter()
            .filter(|s| s.kind == SpanKind::Op)
            .map(|s| (s.index, s.label.as_str().to_string(), s.rounds))
            .collect()
    };
    let (u, f) = (ops(&unfused), ops(&fused));
    // the fused walk folds sign/pool/pm1/flatten layers into their
    // consumers: fewer op spans, each mirroring a fused cost row
    assert!(f.len() < u.len(), "fusion folded nothing: {f:?}");
    assert_eq!(f.len(), fused.op_costs.len());
    assert_eq!(u.len(), unfused.op_costs.len());
    for (span, cost) in f.iter().zip(&fused.op_costs) {
        assert_eq!(span.0 as usize, cost.index);
        assert_eq!(span.1, trace::Label::new(&cost.op).as_str());
    }
    // fused labels carry the `[...]` lowering qualifiers
    assert!(f.iter().any(|(_, l, _)| l.contains('[')), "{f:?}");
    // binary-domain fusion strictly reduces total online rounds
    let rounds = |v: &[(u32, String, u64)]| -> u64 {
        v.iter().map(|(_, _, r)| r).sum()
    };
    assert!(rounds(&f) < rounds(&u),
            "fused {} rounds vs unfused {}", rounds(&f), rounds(&u));
}

#[test]
fn tracing_off_records_nothing() {
    let model = every_op_model();
    let cfg = SessionConfig::new("artifacts/hlo");
    assert!(!cfg.trace, "tracing must be off by default");
    let rep = run_inference(&model, inputs(9, 1), &cfg).unwrap();
    assert!(rep.traces.iter().all(Vec::is_empty),
            "spans recorded with tracing off");
}

#[test]
fn sink_overflow_is_counted_never_silent() {
    // a tiny sink on live links: the transport keeps shipping frames
    // after the buffer fills, and every overflowed span is counted
    let comms = local_trio(NetConfig::zero());
    let sinks: Vec<_> = (0..3)
        .map(|_| Arc::new(TraceSink::with_capacity(4)))
        .collect();
    for (c, s) in comms.iter().zip(&sinks) {
        assert!(c.install_tracer(Arc::clone(s)));
        s.set_enabled(true);
    }
    thread::scope(|sc| {
        for c in &comms {
            sc.spawn(move || {
                for i in 0..8 {
                    let data = vec![i as i32; 4];
                    c.send_elems(Dir::Next, &data).unwrap();
                    c.recv_elems(Dir::Prev).unwrap();
                }
            });
        }
    });
    for (c, s) in comms.iter().zip(&sinks) {
        assert_eq!(s.len(), 4, "party {}", c.id);
        assert!(s.dropped_events() > 0, "party {}: overflow untracked",
                c.id);
        // 8 sends + 8 recvs, 4 kept
        assert_eq!(s.dropped_events(), 16 - 4, "party {}", c.id);
    }
}

/// Exports a traced two-model registry run under `target/traces`
/// (override with `CBNN_TRACE_DIR`) and re-validates the files through
/// the import path -- the same directory the `trace-validate` CI job
/// hands to `ci/trace_check.py`.
#[test]
fn traced_registry_export_roundtrips_on_disk() {
    use cbnn::coordinator::{ModelRegistry, ModelSpec};

    let mut cfg = SessionConfig::new("artifacts/hlo");
    cfg.trace = true;
    let reg = ModelRegistry::start(vec![
        ModelSpec::new("a", Arc::new(every_op_model())),
        ModelSpec::new("b", Arc::new(every_op_model_variant("b", 3))),
    ], &cfg).expect("registry up");
    for i in 0..2u64 {
        reg.infer("a", inputs(40 + i, 2)).expect("a batch");
        reg.infer("b", inputs(60 + i, 2)).expect("b batch");
    }

    // export after shutdown: the last slot's exit stats are the
    // quiesced link totals, so flight bytes reconcile exactly
    let sinks: Vec<_> = (0..3).map(|p| reg.trace_sink(p)).collect();
    let per_model = reg.shutdown().expect("shutdown");
    let stats = &per_model.last().expect("models").1;
    let dir = std::env::var("CBNN_TRACE_DIR")
        .unwrap_or_else(|_| "target/traces".into());
    let dir = std::path::Path::new(&dir);
    for (party, sink) in sinks.iter().enumerate() {
        assert_eq!(sink.dropped_events(), 0, "party {party} overflow");
        trace::write_trace(dir, party, &sink.snapshot(), &stats[party],
                           sink.dropped_events())
            .expect("trace export");
    }

    // import path: parse the files back, merge, and reconcile --
    // exactly what `cbnn trace <DIR>` and ci/trace_check.py do
    let mut parties = Vec::new();
    for party in 0..3 {
        let text = std::fs::read_to_string(
            trace::trace_path(dir, party)).unwrap();
        parties.push(trace::parse_jsonl(&text).unwrap());
    }
    let report = merge::merge_check(&parties);
    assert!(report.ok(), "merge problems: {:?}", report.problems);
    // four request batches, every one joined across all parties
    assert_eq!(report.traces.len(), 4);
    let reqs = parties[0].iter()
        .filter(|s| s.kind == SpanKind::Request).count();
    assert_eq!(reqs, 4);
    // request spans name the routed models
    let labels: Vec<&str> = parties[0].iter()
        .filter(|s| s.kind == SpanKind::Request)
        .map(|s| s.label.as_str()).collect();
    assert!(labels.contains(&"everyop") && labels.contains(&"b"),
            "{labels:?}");
    for party in 0..3 {
        let side = trace::parse_stats(&std::fs::read_to_string(
            trace::stats_path(dir, party)).unwrap()).unwrap();
        assert_eq!(side.party, party);
        assert_eq!(side.dropped_events, 0);
        let problems = merge::check_flight_rows(
            party, &parties[party], &side.chan_bytes);
        assert!(problems.is_empty(), "{problems:?}");
    }
}
