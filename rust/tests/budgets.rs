//! Executable round budgets (ISSUE 7): DESIGN.md "Round budgets" is the
//! normative table; this test parses it and asserts the measured
//! max-party `transport::Stats` round count of every keyed protocol --
//! and of every per-op cost row the engine emits for the every-op model
//! (unfused pooled, unfused inline, fused) -- EQUALS the budget.  Any
//! round added or shaved anywhere in the choreography fails here before
//! it costs a WAN RTT in production (`tests/wan_soak.rs` prices the same
//! numbers under a virtual clock).
//!
//! Also pins the `cost_row` noisy-neighbour fix: per-op rows diff the
//! bound channel's counters, so a concurrent lane flooding the link
//! totals (another model slot, an offline producer) cannot contaminate
//! them.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};

use cbnn::baselines::bitdecomp::msb_bitdecomp;
use cbnn::engine::fusion::plan_fused;
use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                   EngineOptions};
use cbnn::engine::fusion::infer_batch_fused;
use cbnn::metrics::OpCost;
use cbnn::offline::TupleSource;
use cbnn::ot;
use cbnn::protocols::b2a::b2a;
use cbnn::protocols::binlinear::or_planes;
use cbnn::protocols::linear::NativeBackend;
use cbnn::protocols::msb::msb_extract;
use cbnn::protocols::preproc::{mint, msb_online, MsbPool};
use cbnn::protocols::relu::relu_ot;
use cbnn::protocols::trunc::trunc;
use cbnn::ring::bits::BitTensor;
use cbnn::ring::Tensor;
use cbnn::rss::{self, deal, deal_bits, BitShare};
use cbnn::testutil::threeparty::{every_op_model, run3_seeded};
use cbnn::testutil::Rng;
use cbnn::transport::ChanId;

/// Parse the normative table: rows of the "## Round budgets" section
/// shaped `| \`key\` | N | ... |`.
fn design_budgets() -> BTreeMap<String, u64> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("DESIGN.md");
    let text = std::fs::read_to_string(&path)
        .expect("DESIGN.md at the repo root");
    let section = text.split("## Round budgets").nth(1)
        .expect("DESIGN.md must keep a '## Round budgets' section");
    let section = section.split("\n## ").next().unwrap();
    let mut out = BTreeMap::new();
    for line in section.lines() {
        let t = line.trim();
        if !t.starts_with("| `") {
            continue;
        }
        let mut fields = t.split('|').skip(1);
        let (Some(key), Some(rounds)) = (fields.next(), fields.next())
        else {
            continue;
        };
        let key = key.trim().trim_matches('`').to_string();
        if let Ok(r) = rounds.trim().parse::<u64>() {
            out.insert(key, r);
        }
    }
    out
}

const KEYS: [&str; 16] = [
    "share_input", "reveal", "linear", "ot3", "b2a", "msb", "mint",
    "msb_online", "sign", "relu_ot", "trunc", "relu_op",
    "relu_op_inline", "or_pool_k2", "b2a_boundary", "bitdecomp_msb",
];

#[test]
fn design_budget_table_is_machine_readable() {
    let b = design_budgets();
    for key in KEYS {
        assert!(b.contains_key(key),
                "DESIGN.md round-budget table misses `{key}`");
    }
    // composition identities the table must keep (they mirror how the
    // engine assembles ops from primitives)
    assert_eq!(b["sign"], b["msb"], "Algorithm 4 = MSB + 0");
    assert_eq!(b["b2a_boundary"], b["b2a"],
               "the fused exit is one batched b2a");
    assert_eq!(b["relu_op"], b["msb_online"] + b["relu_ot"] + b["trunc"]);
    assert_eq!(b["relu_op_inline"], b["msb"] + b["relu_ot"] + b["trunc"]);
    assert_eq!(b["msb"], b["b2a"] + 2 * b["linear"] + b["reveal"],
               "Algorithm 3 = b2a (r-share overlapped) + 2 mul + reveal");
    assert_eq!(b["mint"], b["b2a"] + b["linear"]);
    assert_eq!(b["msb_online"], b["linear"] + b["reveal"]);
}

/// Measure each keyed primitive standalone on all three parties;
/// returns per-party `key -> rounds` maps in party order.
fn measured_primitive_rounds() -> Vec<BTreeMap<&'static str, u64>> {
    let results = run3_seeded(0xB06E7, |ctx| {
        let me = ctx.id();
        let n = 40usize;
        // every party advances the identical rng sequence, so dealt
        // shares are consistent across the trio
        let mut rng = Rng::new(97);
        let x = rng.tensor_small(&[n], 1 << 20);
        let xs = deal(&x, &mut rng);
        let y = rng.tensor_small(&[n], 1 << 20);
        let ys = deal(&y, &mut rng);
        let bits: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
        let bshares = deal_bits(&bits, &mut rng);
        let mut rec: BTreeMap<&'static str, u64> = BTreeMap::new();

        // share_input (owner P0)
        ctx.comm.reset_stats();
        let plain = if me == 0 { Some(x.clone()) } else { None };
        rss::share_input(ctx.comm, ctx.seeds, 0, plain.as_ref(), &[n])
            .unwrap();
        rec.insert("share_input", ctx.comm.stats().rounds);

        // reveal
        ctx.comm.reset_stats();
        rss::reveal(ctx.comm, &xs[me]).unwrap();
        rec.insert("reveal", ctx.comm.stats().rounds);

        // linear: the interactive cost of a linear layer is one
        // batched reshare; mul = local products + that reshare
        ctx.comm.reset_stats();
        rss::mul(ctx.comm, ctx.seeds, &xs[me], &ys[me]).unwrap();
        rec.insert("linear", ctx.comm.stats().rounds);

        // 3-OT (sender P1, receiver P0, helper P2)
        ctx.comm.reset_stats();
        let cb = BitTensor::from_bits(&bits);
        let m0: Vec<i32> = (0..n as i32).collect();
        let m1: Vec<i32> = (0..n as i32).map(|v| v + 1000).collect();
        let input = match me {
            1 => ot::Input::Sender { m0: &m0, m1: &m1 },
            0 => ot::Input::Receiver { c: &cb },
            _ => ot::Input::Helper { c: &cb },
        };
        ot::run(ctx.comm, ctx.seeds, ot::Roles::new(1, 0, 2), n, input)
            .unwrap();
        rec.insert("ot3", ctx.comm.stats().rounds);

        // b2a (also the fused plan's boundary conversion)
        ctx.comm.reset_stats();
        b2a(ctx, &bshares[me]).unwrap();
        let r = ctx.comm.stats().rounds;
        rec.insert("b2a", r);
        rec.insert("b2a_boundary", r);

        // msb (Algorithm 3; Algorithm 4's sign shares are a free affine
        // of the same run, so `sign` measures identically)
        ctx.comm.reset_stats();
        msb_extract(ctx, &xs[me]).unwrap();
        let r = ctx.comm.stats().rounds;
        rec.insert("msb", r);
        rec.insert("sign", r);

        // mint (the offline prefix)
        ctx.comm.reset_stats();
        mint(ctx, n).unwrap();
        rec.insert("mint", ctx.comm.stats().rounds);

        // msb_online (preprocessed material minted outside the window)
        let pool = MsbPool::new();
        pool.generate(ctx, n).unwrap();
        ctx.comm.reset_stats();
        msb_online(ctx, &xs[me], pool.take(n).unwrap()).unwrap();
        rec.insert("msb_online", ctx.comm.stats().rounds);

        // relu_ot (Algorithm 5) over matching msb bit shares
        let mbits: Vec<u8> =
            x.data.iter().map(|&v| cbnn::ring::msb(v)).collect();
        let ms = deal_bits(&mbits, &mut rng);
        ctx.comm.reset_stats();
        relu_ot(ctx, &xs[me], &ms[me]).unwrap();
        rec.insert("relu_ot", ctx.comm.stats().rounds);

        // trunc
        ctx.comm.reset_stats();
        trunc(ctx, &xs[me], 8).unwrap();
        rec.insert("trunc", ctx.comm.stats().rounds);

        // or_pool_k2: the fused PoolBits lowering ORs k^2 = 4 planes
        let planes: Vec<BitShare> = (0..4).map(|_| {
            let pb: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
            deal_bits(&pb, &mut rng)[me].clone()
        }).collect();
        ctx.comm.reset_stats();
        or_planes(ctx, planes).unwrap();
        rec.insert("or_pool_k2", ctx.comm.stats().rounds);

        // bitdecomp_msb (baseline)
        ctx.comm.reset_stats();
        msb_bitdecomp(ctx, &xs[me].a.data, &xs[me].b.data).unwrap();
        rec.insert("bitdecomp_msb", ctx.comm.stats().rounds);

        rec
    });
    results.into_iter().map(|(r, _)| r).collect()
}

#[test]
fn primitive_rounds_match_design_budgets() {
    let budgets = design_budgets();
    let measured = measured_primitive_rounds();
    // engine-composed rows are asserted by the op-walk tests below
    let composed = ["relu_op", "relu_op_inline"];
    for key in KEYS {
        if composed.contains(&key) {
            continue;
        }
        let budget = budgets[key];
        for (party, rec) in measured.iter().enumerate() {
            let got = rec[key];
            assert!(got <= budget,
                    "{key}: party {party} ran {got} rounds, budget {budget}");
        }
        let max = measured.iter().map(|rec| rec[key]).max().unwrap();
        assert_eq!(max, budget,
                   "{key}: critical-path rounds {max} != budget {budget} \
                    -- update the protocol or DESIGN.md, consciously");
    }
}

// ---------------------------------------------------------------------
// engine per-op cost rows
// ---------------------------------------------------------------------

/// Run the every-op model through one engine walk on all three parties
/// and return each party's per-op cost rows.
fn op_rows(fuse: bool, inline: bool) -> Vec<Vec<OpCost>> {
    let model = every_op_model();
    let batch = 2usize;
    let plan = if fuse {
        Some(plan_fused(&model).expect("every-op model must lower"))
    } else {
        None
    };
    let seed = 0x0B5E55 ^ ((fuse as u64) << 1) ^ inline as u64;
    let results = run3_seeded(seed, |ctx| {
        let shared = share_model(ctx, &model, true).unwrap();
        let demand = match &plan {
            Some(p) => p.msb_demand(batch),
            None => msb_demand(&shared, batch),
        };
        let inputs: Vec<Tensor> = if ctx.id() == 0 {
            let mut rng = Rng::new(11);
            (0..batch).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
        } else {
            vec![]
        };
        let pool = MsbPool::new();
        let src = if inline {
            TupleSource::Inline
        } else {
            pool.generate(ctx, demand).unwrap();
            TupleSource::Pool(&pool)
        };
        let out = match &plan {
            Some(p) => infer_batch_fused(
                ctx, &shared, p, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &src).unwrap(),
            None => infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &src).unwrap(),
        };
        out.op_costs
    });
    results.into_iter().map(|(r, _)| r).collect()
}

/// Every party's row must stay within the budget; the max across
/// parties must EQUAL it (rounds are critical-path counts).
fn assert_rows(rows: &[Vec<OpCost>], want: &[(&str, u64)]) {
    for (party, costs) in rows.iter().enumerate() {
        assert_eq!(costs.len(), want.len(),
                   "party {party}: row count {} != {}", costs.len(),
                   want.len());
        for (row, (name, budget)) in costs.iter().zip(want) {
            assert_eq!(row.op, *name, "party {party} row order");
            assert!(row.rounds <= *budget,
                    "party {party} op {}: {} rounds > budget {budget}",
                    row.op, row.rounds);
        }
    }
    for (j, (name, budget)) in want.iter().enumerate() {
        let max = rows.iter().map(|costs| costs[j].rounds).max().unwrap();
        assert_eq!(max, *budget,
                   "op {name}: critical-path rounds {max} != budget \
                    {budget} -- update the choreography or DESIGN.md, \
                    consciously");
    }
}

fn unfused_pooled_want(b: &BTreeMap<String, u64>) -> Vec<(&'static str, u64)> {
    vec![
        ("matmul", b["linear"]),
        ("sign", b["msb_online"]),
        ("pool_bits", b["msb_online"]),
        ("pm1", 0),
        ("depthwise", b["linear"]),
        ("flatten", 0),
        ("matmul", b["linear"]),
        ("relu", b["relu_op"]),
    ]
}

#[test]
fn every_op_rows_match_budgets_unfused_pooled() {
    let b = design_budgets();
    assert_rows(&op_rows(false, false), &unfused_pooled_want(&b));
}

#[test]
fn every_op_rows_match_budgets_unfused_inline() {
    let b = design_budgets();
    let want = vec![
        ("matmul", b["linear"]),
        ("sign", b["msb"]),
        ("pool_bits", b["msb"]),
        ("pm1", 0),
        ("depthwise", b["linear"]),
        ("flatten", 0),
        ("matmul", b["linear"]),
        ("relu", b["relu_op_inline"]),
    ];
    assert_rows(&op_rows(false, true), &want);
}

#[test]
fn every_op_rows_match_budgets_fused() {
    let b = design_budgets();
    // the planner's row sequence: sign enters the binary domain, the
    // pool lowers to an OR tree, pm1 is a marker, and one b2a boundary
    // re-enters arithmetic before the (non-±1) depthwise
    let want = vec![
        ("matmul", b["linear"]),
        ("sign[bits]", b["msb_online"]),
        ("pool_bits[or]", b["or_pool_k2"]),
        ("pm1[mark]", 0),
        ("b2a[boundary]", b["b2a_boundary"]),
        ("depthwise", b["linear"]),
        ("flatten", 0),
        ("matmul", b["linear"]),
        ("relu", b["relu_op"]),
    ];
    assert_rows(&op_rows(true, false), &want);
}

#[test]
fn concurrent_lane_rounds_do_not_contaminate_op_rows() {
    // regression for the cost_row fix: a thread advancing rounds on the
    // offline lane while inference runs inflates the LINK totals (which
    // the old cost_row diffed) but must leave the per-op rows -- which
    // diff the bound channel -- exactly on budget
    let b = design_budgets();
    let model = every_op_model();
    let batch = 2usize;
    let results = run3_seeded(0xA015E, |ctx| {
        let shared = share_model(ctx, &model, true).unwrap();
        let pool = MsbPool::new();
        pool.generate(ctx, msb_demand(&shared, batch)).unwrap();
        let inputs: Vec<Tensor> = if ctx.id() == 0 {
            let mut rng = Rng::new(13);
            (0..batch).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
        } else {
            vec![]
        };
        let off = ctx.comm.channel(ChanId::offline(0));
        off.round(); // guaranteed noise even if the thread never runs
        let stop = AtomicBool::new(false);
        let out = std::thread::scope(|s| {
            s.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    off.round();
                    std::thread::yield_now();
                }
            });
            let out = infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &TupleSource::Pool(&pool)).unwrap();
            stop.store(true, Ordering::Release);
            out
        });
        let st = ctx.comm.stats();
        (out.op_costs, st.rounds, st.chan(ctx.comm.chan()).rounds)
    });
    let rows: Vec<Vec<OpCost>> =
        results.iter().map(|((c, _, _), _)| c.clone()).collect();
    assert_rows(&rows, &unfused_pooled_want(&b));
    for (party, ((_, total, online), _)) in results.iter().enumerate() {
        assert!(total > online,
                "party {party}: noise lane never advanced a round; \
                 the contamination test is vacuous");
    }
}
