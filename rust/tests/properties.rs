//! Randomized three-party round-trip properties for every boolean
//! protocol: run all three party closures over in-memory channels with a
//! seeded deterministic RNG, reconstruct the outputs, and compare against
//! the plaintext reference.  No golden artifacts required -- nothing here
//! skips.
//!
//! Inputs sweep the edge lengths {1, 63, 64, 65, 1000} (word-boundary
//! stragglers plus a four-digit batch) and the edge values
//! {0, ±1, ±(2^bound_bits − 1)} plus dense bounded randoms.  Seeds are
//! fixed in CI; `randomized_seed_smoke` (`--ignored`) re-runs the sweep
//! with a fresh time-derived seed and prints it for replay.

use cbnn::baselines::bitdecomp::msb_bitdecomp;
use cbnn::protocols::preproc::MsbPool;
use cbnn::protocols::{b2a::b2a, msb::msb_extract, relu::relu, trunc::trunc};
use cbnn::ring::{self, Tensor};
use cbnn::rss::{deal, deal_bits, reconstruct, reconstruct_bits, BitShare,
                Share};
use cbnn::testutil::threeparty::{edge_bits, edge_values, run3_seeded,
                                 EDGE_LENGTHS};
use cbnn::testutil::Rng;

/// One sweep of every protocol property at the given master seed.
fn sweep(seed: u64) {
    for (k, &n) in EDGE_LENGTHS.iter().enumerate() {
        let case = seed.wrapping_add(k as u64).wrapping_mul(0x9E37);
        check_msb(case, n);
        check_bitdecomp(case, n);
        check_b2a(case, n);
        check_relu(case, n);
        check_trunc(case, n);
        check_msb_online(case, n);
    }
}

fn bound_bits() -> u32 {
    cbnn::protocols::ProtoConfig::default().bound_bits
}

fn check_msb(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        (msb_extract(ctx, &shares[ctx.id()]).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [BitShare; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct_bits(&shares);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], ring::msb(v), "msb({v}) at n={n} seed={seed}");
    }
}

fn check_bitdecomp(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0xB17D);
        // bit-decomposition is exact on the whole ring, not just the
        // bounded range: mix full-width randoms in with the edge table
        let mut vals = edge_values(&mut rng, n, 31 - 1);
        for (i, v) in vals.iter_mut().enumerate() {
            if i >= 5 && i % 2 == 0 {
                *v = rng.next_i32();
            }
        }
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        let me = &shares[ctx.id()];
        (msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [BitShare; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct_bits(&shares);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], ring::msb(v),
                   "bitdecomp msb({v}) at n={n} seed={seed}");
    }
}

fn check_b2a(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0xB2A);
        let bits = edge_bits(&mut rng, n);
        let shares = deal_bits(&bits, &mut rng);
        (b2a(ctx, &shares[ctx.id()]).unwrap(), bits)
    });
    let bits = results[0].0 .1.clone();
    let shares: [Share; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct(&shares);
    for i in 0..n {
        assert_eq!(got.data[i], i32::from(bits[i]),
                   "b2a bit {i} at n={n} seed={seed}");
    }
    // replication consistency survives the conversion
    for i in 0..3 {
        assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
    }
}

fn check_relu(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0x3E1);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        (relu(ctx, &shares[ctx.id()]).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [Share; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct(&shares);
    for (g, &v) in got.data.iter().zip(&vals) {
        assert_eq!(*g, v.max(0), "relu({v}) at n={n} seed={seed}");
    }
}

fn check_trunc(seed: u64, n: usize) {
    let f = 8u32;
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0x7C);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        (trunc(ctx, &shares[ctx.id()], f).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [Share; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct(&shares);
    for (g, &v) in got.data.iter().zip(&vals) {
        let want = v >> f;
        assert!((g - want).abs() <= 1,
                "trunc({v}) = {g}, want {want}±1, n={n} seed={seed}");
    }
}

fn check_msb_online(seed: u64, n: usize) {
    // preprocessing pool + 2-round online MSB; draw across a misaligned
    // generate boundary to exercise the word-aligned reservoir
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0x0421);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        let pool = MsbPool::new();
        pool.generate(ctx, n / 2 + 3).unwrap();
        pool.generate(ctx, n).unwrap();
        let _burn = pool.take(3).unwrap(); // misalign the head
        let out = cbnn::protocols::preproc::msb_online(
            ctx, &shares[ctx.id()], pool.take(n).unwrap()).unwrap();
        (out.bits, vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [BitShare; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct_bits(&shares);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], ring::msb(v),
                   "online msb({v}) at n={n} seed={seed}");
    }
}

// ---- offline TupleBank properties ---------------------------------------

mod bank {
    use std::sync::mpsc::channel;
    use std::thread;

    use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                       EngineOptions};
    use cbnn::metrics::PreprocMetrics;
    use cbnn::offline::{offline_seeds, run_producer, BankConfig,
                        TupleBank, TupleSource};
    use cbnn::protocols::linear::NativeBackend;
    use cbnn::protocols::preproc::MsbPool;
    use cbnn::protocols::Ctx;
    use cbnn::ring::Tensor;
    use cbnn::testutil::threeparty::{every_op_model, run3_seeded};
    use cbnn::testutil::Rng;
    use cbnn::transport::ChanId;

    const BATCH: usize = 2;

    fn inputs_for(id: usize) -> Vec<Tensor> {
        if id == 0 {
            let mut rng = Rng::new(5);
            (0..BATCH).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
        } else {
            vec![]
        }
    }

    /// Serve one batched inference drawing from a producer-fed bank: the
    /// producer mints `schedule`-sized chunks over the offline channel
    /// *concurrently* with the online walk (draws block on the condvar
    /// until delivery).  Returns (logits, per-party metrics).
    fn bank_arm(seed: u64, schedule: &[usize], credit: &[usize])
                -> Vec<(Vec<Vec<i32>>, PreprocMetrics)> {
        run3_seeded(seed, |ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            let demand = msb_demand(&shared, BATCH);
            let chunk_max = schedule.iter().copied().max().unwrap_or(1);
            let bank = TupleBank::new(BankConfig {
                low: 0,
                high: demand,
                chunk: chunk_max,
                capacity: demand + chunk_max,
            });
            let (tx, rx) = channel();
            for &c in schedule {
                tx.send(c).unwrap();
            }
            for &c in credit {
                bank.credit(c);
            }
            drop(tx);
            let off_comm = ctx.comm.channel(ChanId::OFFLINE);
            let off_seeds = offline_seeds(seed, ctx.id());
            let proto = ctx.cfg;
            let bank_ref = &bank;
            let logits = thread::scope(|s| {
                s.spawn(move || {
                    let octx = Ctx::with_cfg(&off_comm, &off_seeds, proto);
                    run_producer(&octx, bank_ref, rx).unwrap();
                });
                infer_batch_pooled(ctx, &shared, &NativeBackend,
                                   EngineOptions::default(),
                                   &inputs_for(ctx.id()), BATCH,
                                   &TupleSource::Bank(&bank))
                    .unwrap().logits
            });
            (logits, bank.metrics())
        }).into_iter().map(|(r, _)| r).collect()
    }

    #[test]
    fn prop_bank_logits_bit_identical_to_inline_pool() {
        // concurrent refill/drain equivalence: a bank fed by background
        // producers over the offline channel must compute *bit-identical*
        // logits to an MsbPool minted inline with the same chunk schedule
        // -- possible because producer PRF streams are domain-separated
        // (offline_seeds), so the online trajectory is untouched.
        let seed = 4711u64;
        let schedule = [40usize, 30, 16]; // sums to the demand of 86
        let banked = bank_arm(seed, &schedule, &schedule);
        let pooled = run3_seeded(seed, |ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            // mint inline, but from the same salted seed domain and
            // chunk schedule the producers would use
            let off_seeds = offline_seeds(seed, ctx.id());
            let octx = Ctx::with_cfg(ctx.comm, &off_seeds, ctx.cfg);
            let pool = MsbPool::new();
            for &c in &schedule {
                pool.generate(&octx, c).unwrap();
            }
            infer_batch_pooled(ctx, &shared, &NativeBackend,
                               EngineOptions::default(),
                               &inputs_for(ctx.id()), BATCH,
                               &TupleSource::Pool(&pool))
                .unwrap().logits
        });
        assert!(!banked[0].0.is_empty());
        assert_eq!(banked[0].0, pooled[0].0,
                   "bank-fed and inline-pool logits diverged");
        // non-owners learn nothing either way
        for p in 1..3 {
            assert!(banked[p].0.is_empty() && pooled[p].0.is_empty());
        }
        // the whole demand was served from the bank, nothing fell back
        for (p, (_, m)) in banked.iter().enumerate() {
            assert_eq!(m.underflow_calls, 0, "party {p}: {m:?}");
            assert_eq!(m.drawn, 86, "party {p}: {m:?}");
            assert_eq!(m.minted, 86, "party {p}: {m:?}");
        }
    }

    #[test]
    fn prop_bank_underflow_falls_back_and_counts() {
        // credit only the first MSB invocation's worth: the Sign draw is
        // pooled, the PoolBits and Relu draws under-run the deterministic
        // credit and fall back to synchronous generation -- identically
        // on every party, with correct results and counted underflows
        let seed = 2024u64;
        let banked = bank_arm(seed, &[64], &[64]);
        let inline = run3_seeded(seed, |ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            infer_batch_pooled(ctx, &shared, &NativeBackend,
                               EngineOptions::default(),
                               &inputs_for(ctx.id()), BATCH,
                               &TupleSource::Inline)
                .unwrap().logits
        });
        for (p, (_, m)) in banked.iter().enumerate() {
            assert_eq!(m.drawn, 64, "party {p}: {m:?}");
            assert_eq!(m.underflow_calls, 2, "party {p}: {m:?}");
            assert_eq!(m.fallback_elems, 16 + 6, "party {p}: {m:?}");
        }
        // fallback arm computes the same function (the final Relu's
        // truncation draws different masks, so ±1 LSB on the logits)
        for (br, ir) in banked[0].0.iter().zip(&inline[0].0) {
            for (b, i) in br.iter().zip(ir) {
                assert!((b - i).abs() <= 1,
                        "bank {b} vs inline {i} beyond trunc tolerance");
            }
        }
    }

    #[test]
    fn prop_bank_watermark_invariants_under_churn() {
        // a protocol-free bank: deliveries race draws across threads; the
        // stored level must never exceed capacity, credit accounting must
        // refuse over-draws, and close() must drain cleanly
        use cbnn::protocols::preproc::MsbTuple;
        use cbnn::rss::{BitShare, Share};
        use std::sync::Arc;

        fn tup(n: usize) -> MsbTuple {
            MsbTuple {
                beta: BitShare::zeros(n),
                beta_a: Share { a: Tensor::zeros(&[n]),
                                b: Tensor::zeros(&[n]) },
                rs: Share { a: Tensor::zeros(&[n]),
                            b: Tensor::zeros(&[n]) },
            }
        }

        let cfg = BankConfig { low: 8, high: 16, chunk: 8, capacity: 24 };
        let bank = Arc::new(TupleBank::new(cfg));
        bank.credit(25 * 8);
        let feeder = {
            let b = Arc::clone(&bank);
            thread::spawn(move || {
                for _ in 0..25 {
                    b.deliver(tup(8)); // 200 elems through a 24-cap bank
                }
            })
        };
        let mut drawn = 0usize;
        while drawn < 25 * 8 {
            let n = 8.min(25 * 8 - drawn);
            assert!(bank.try_reserve(n), "credit must cover {n}");
            let t = bank.take(n).unwrap();
            assert_eq!(t.len(), n);
            drawn += n;
            assert!(bank.level() <= cfg.capacity,
                    "level {} exceeded capacity", bank.level());
        }
        feeder.join().unwrap();
        let m = bank.metrics();
        assert_eq!(m.minted, 200);
        assert_eq!(m.drawn, 200);
        assert!(m.max_level as usize <= cfg.capacity, "{m:?}");
        // all credit consumed: the next reserve is a counted underflow
        assert!(!bank.try_reserve(1));
        assert_eq!(bank.metrics().underflow_calls, 1);
        // close drains: blocked draws err instead of hanging
        bank.credit(8);
        assert!(bank.try_reserve(8));
        let waiter = {
            let b = Arc::clone(&bank);
            thread::spawn(move || b.take(8))
        };
        bank.close();
        assert!(waiter.join().unwrap().is_err());
    }
}

// ---- fixed-seed entries (the CI property job) ---------------------------

#[test]
fn prop_msb_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_msb(11, n);
    }
}

#[test]
fn prop_bitdecomp_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_bitdecomp(13, n);
    }
}

#[test]
fn prop_b2a_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_b2a(17, n);
    }
    // degenerate fills
    for (seed, n) in [(19u64, 16usize), (23, 65)] {
        for fill in [0u8, 1u8] {
            let results = run3_seeded(seed + u64::from(fill), |ctx| {
                let mut rng = Rng::new(seed);
                let bits = vec![fill; n];
                let shares = deal_bits(&bits, &mut rng);
                b2a(ctx, &shares[ctx.id()]).unwrap()
            });
            let shares: [Share; 3] =
                std::array::from_fn(|i| results[i].0.clone());
            let got = reconstruct(&shares);
            assert!(got.data.iter().all(|&v| v == i32::from(fill)));
        }
    }
}

#[test]
fn prop_relu_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_relu(29, n);
    }
}

#[test]
fn prop_trunc_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_trunc(31, n);
    }
}

#[test]
fn prop_msb_online_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_msb_online(37, n);
    }
}

#[test]
fn prop_multi_seed_sweep() {
    // a handful of additional master seeds over the full sweep
    for seed in [101u64, 202] {
        sweep(seed);
    }
}

// ---- randomized-seed smoke (the CI --ignored job) -----------------------

#[test]
#[ignore = "randomized smoke: run explicitly (CI nightly job) with \
            `cargo test --test properties -- --ignored`"]
fn randomized_seed_smoke() {
    let seed = match std::env::var("CBNN_PROP_SEED") {
        Ok(s) => s.parse().expect("CBNN_PROP_SEED must be a u64"),
        Err(_) => std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64,
    };
    // printed even on success so a failing CI run is replayable with
    // CBNN_PROP_SEED=<seed>
    println!("randomized_seed_smoke: CBNN_PROP_SEED={seed}");
    sweep(seed);
}

#[test]
fn bound_default_matches_edge_table_assumption() {
    // edge_values' extreme is ±(2^bound_bits − 1); keep the documented
    // sweep honest if the default config ever moves
    assert_eq!(bound_bits(), 24);
}
