//! Randomized three-party round-trip properties for every boolean
//! protocol: run all three party closures over in-memory channels with a
//! seeded deterministic RNG, reconstruct the outputs, and compare against
//! the plaintext reference.  No golden artifacts required -- nothing here
//! skips.
//!
//! Inputs sweep the edge lengths {1, 63, 64, 65, 1000} (word-boundary
//! stragglers plus a four-digit batch) and the edge values
//! {0, ±1, ±(2^bound_bits − 1)} plus dense bounded randoms.  Seeds are
//! fixed in CI; `randomized_seed_smoke` (`--ignored`) re-runs the sweep
//! with a fresh time-derived seed and prints it for replay.

use cbnn::baselines::bitdecomp::msb_bitdecomp;
use cbnn::protocols::preproc::MsbPool;
use cbnn::protocols::{b2a::b2a, msb::msb_extract, relu::relu, trunc::trunc};
use cbnn::ring::{self, Tensor};
use cbnn::rss::{deal, deal_bits, reconstruct, reconstruct_bits, BitShare,
                Share};
use cbnn::testutil::threeparty::{edge_bits, edge_values, run3_seeded,
                                 EDGE_LENGTHS};
use cbnn::testutil::Rng;

/// One sweep of every protocol property at the given master seed.
fn sweep(seed: u64) {
    for (k, &n) in EDGE_LENGTHS.iter().enumerate() {
        let case = seed.wrapping_add(k as u64).wrapping_mul(0x9E37);
        check_msb(case, n);
        check_bitdecomp(case, n);
        check_b2a(case, n);
        check_relu(case, n);
        check_trunc(case, n);
        check_msb_online(case, n);
    }
}

fn bound_bits() -> u32 {
    cbnn::protocols::ProtoConfig::default().bound_bits
}

fn check_msb(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        (msb_extract(ctx, &shares[ctx.id()]).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [BitShare; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct_bits(&shares);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], ring::msb(v), "msb({v}) at n={n} seed={seed}");
    }
}

fn check_bitdecomp(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0xB17D);
        // bit-decomposition is exact on the whole ring, not just the
        // bounded range: mix full-width randoms in with the edge table
        let mut vals = edge_values(&mut rng, n, 31 - 1);
        for (i, v) in vals.iter_mut().enumerate() {
            if i >= 5 && i % 2 == 0 {
                *v = rng.next_i32();
            }
        }
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        let me = &shares[ctx.id()];
        (msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [BitShare; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct_bits(&shares);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], ring::msb(v),
                   "bitdecomp msb({v}) at n={n} seed={seed}");
    }
}

fn check_b2a(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0xB2A);
        let bits = edge_bits(&mut rng, n);
        let shares = deal_bits(&bits, &mut rng);
        (b2a(ctx, &shares[ctx.id()]).unwrap(), bits)
    });
    let bits = results[0].0 .1.clone();
    let shares: [Share; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct(&shares);
    for i in 0..n {
        assert_eq!(got.data[i], i32::from(bits[i]),
                   "b2a bit {i} at n={n} seed={seed}");
    }
    // replication consistency survives the conversion
    for i in 0..3 {
        assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
    }
}

fn check_relu(seed: u64, n: usize) {
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0x3E1);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        (relu(ctx, &shares[ctx.id()]).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [Share; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct(&shares);
    for (g, &v) in got.data.iter().zip(&vals) {
        assert_eq!(*g, v.max(0), "relu({v}) at n={n} seed={seed}");
    }
}

fn check_trunc(seed: u64, n: usize) {
    let f = 8u32;
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0x7C);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        (trunc(ctx, &shares[ctx.id()], f).unwrap(), vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [Share; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct(&shares);
    for (g, &v) in got.data.iter().zip(&vals) {
        let want = v >> f;
        assert!((g - want).abs() <= 1,
                "trunc({v}) = {g}, want {want}±1, n={n} seed={seed}");
    }
}

fn check_msb_online(seed: u64, n: usize) {
    // preprocessing pool + 2-round online MSB; draw across a misaligned
    // generate boundary to exercise the word-aligned reservoir
    let results = run3_seeded(seed, |ctx| {
        let mut rng = Rng::new(seed ^ 0x0421);
        let vals = edge_values(&mut rng, n, ctx.cfg.bound_bits);
        let x = Tensor::from_vec(&[n], vals.clone());
        let shares = deal(&x, &mut rng);
        let pool = MsbPool::new();
        pool.generate(ctx, n / 2 + 3).unwrap();
        pool.generate(ctx, n).unwrap();
        let _burn = pool.take(3).unwrap(); // misalign the head
        let out = cbnn::protocols::preproc::msb_online(
            ctx, &shares[ctx.id()], pool.take(n).unwrap()).unwrap();
        (out.bits, vals)
    });
    let vals = results[0].0 .1.clone();
    let shares: [BitShare; 3] =
        std::array::from_fn(|i| results[i].0 .0.clone());
    let got = reconstruct_bits(&shares);
    for (i, &v) in vals.iter().enumerate() {
        assert_eq!(got[i], ring::msb(v),
                   "online msb({v}) at n={n} seed={seed}");
    }
}

// ---- offline TupleBank properties ---------------------------------------

mod bank {
    use std::sync::mpsc::channel;
    use std::thread;

    use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                       EngineOptions};
    use cbnn::metrics::PreprocMetrics;
    use cbnn::offline::{offline_seeds, run_producer, BankConfig,
                        TupleBank, TupleSource};
    use cbnn::protocols::linear::NativeBackend;
    use cbnn::protocols::preproc::MsbPool;
    use cbnn::protocols::Ctx;
    use cbnn::ring::Tensor;
    use cbnn::testutil::threeparty::{every_op_model, run3_seeded};
    use cbnn::testutil::Rng;
    use cbnn::transport::ChanId;

    const BATCH: usize = 2;

    fn inputs_for(id: usize) -> Vec<Tensor> {
        if id == 0 {
            let mut rng = Rng::new(5);
            (0..BATCH).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
        } else {
            vec![]
        }
    }

    /// Serve one batched inference drawing from a producer-fed bank: the
    /// producer mints `schedule`-sized chunks over the offline channel
    /// *concurrently* with the online walk (draws block on the condvar
    /// until delivery).  Returns (logits, per-party metrics).
    fn bank_arm(seed: u64, schedule: &[usize], credit: &[usize])
                -> Vec<(Vec<Vec<i32>>, PreprocMetrics)> {
        run3_seeded(seed, |ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            let demand = msb_demand(&shared, BATCH);
            let chunk_max = schedule.iter().copied().max().unwrap_or(1);
            let bank = TupleBank::new(BankConfig {
                low: 0,
                high: demand,
                chunk: chunk_max,
                capacity: demand + chunk_max,
            });
            let (tx, rx) = channel();
            for &c in schedule {
                tx.send(c).unwrap();
            }
            for &c in credit {
                bank.credit(c);
            }
            drop(tx);
            let off_comm = ctx.comm.channel(ChanId::OFFLINE);
            let off_seeds = offline_seeds(seed, ctx.id());
            let proto = ctx.cfg;
            let bank_ref = &bank;
            let logits = thread::scope(|s| {
                s.spawn(move || {
                    let octx = Ctx::with_cfg(&off_comm, &off_seeds, proto);
                    run_producer(&octx, bank_ref, rx).unwrap();
                });
                infer_batch_pooled(ctx, &shared, &NativeBackend,
                                   EngineOptions::default(),
                                   &inputs_for(ctx.id()), BATCH,
                                   &TupleSource::Bank(&bank))
                    .unwrap().logits
            });
            (logits, bank.metrics())
        }).into_iter().map(|(r, _)| r).collect()
    }

    #[test]
    fn prop_bank_logits_bit_identical_to_inline_pool() {
        // concurrent refill/drain equivalence: a bank fed by background
        // producers over the offline channel must compute *bit-identical*
        // logits to an MsbPool minted inline with the same chunk schedule
        // -- possible because producer PRF streams are domain-separated
        // (offline_seeds), so the online trajectory is untouched.
        let seed = 4711u64;
        let schedule = [40usize, 30, 16]; // sums to the demand of 86
        let banked = bank_arm(seed, &schedule, &schedule);
        let pooled = run3_seeded(seed, |ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            // mint inline, but from the same salted seed domain and
            // chunk schedule the producers would use
            let off_seeds = offline_seeds(seed, ctx.id());
            let octx = Ctx::with_cfg(ctx.comm, &off_seeds, ctx.cfg);
            let pool = MsbPool::new();
            for &c in &schedule {
                pool.generate(&octx, c).unwrap();
            }
            infer_batch_pooled(ctx, &shared, &NativeBackend,
                               EngineOptions::default(),
                               &inputs_for(ctx.id()), BATCH,
                               &TupleSource::Pool(&pool))
                .unwrap().logits
        });
        assert!(!banked[0].0.is_empty());
        assert_eq!(banked[0].0, pooled[0].0,
                   "bank-fed and inline-pool logits diverged");
        // non-owners learn nothing either way
        for p in 1..3 {
            assert!(banked[p].0.is_empty() && pooled[p].0.is_empty());
        }
        // the whole demand was served from the bank, nothing fell back
        for (p, (_, m)) in banked.iter().enumerate() {
            assert_eq!(m.underflow_calls, 0, "party {p}: {m:?}");
            assert_eq!(m.drawn, 86, "party {p}: {m:?}");
            assert_eq!(m.minted, 86, "party {p}: {m:?}");
        }
    }

    #[test]
    fn prop_bank_underflow_falls_back_and_counts() {
        // credit only the first MSB invocation's worth: the Sign draw is
        // pooled, the PoolBits and Relu draws under-run the deterministic
        // credit and fall back to synchronous generation -- identically
        // on every party, with correct results and counted underflows
        let seed = 2024u64;
        let banked = bank_arm(seed, &[64], &[64]);
        let inline = run3_seeded(seed, |ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            infer_batch_pooled(ctx, &shared, &NativeBackend,
                               EngineOptions::default(),
                               &inputs_for(ctx.id()), BATCH,
                               &TupleSource::Inline)
                .unwrap().logits
        });
        for (p, (_, m)) in banked.iter().enumerate() {
            assert_eq!(m.drawn, 64, "party {p}: {m:?}");
            assert_eq!(m.underflow_calls, 2, "party {p}: {m:?}");
            assert_eq!(m.fallback_elems, 16 + 6, "party {p}: {m:?}");
        }
        // fallback arm computes the same function (the final Relu's
        // truncation draws different masks, so ±1 LSB on the logits)
        for (br, ir) in banked[0].0.iter().zip(&inline[0].0) {
            for (b, i) in br.iter().zip(ir) {
                assert!((b - i).abs() <= 1,
                        "bank {b} vs inline {i} beyond trunc tolerance");
            }
        }
    }

    #[test]
    fn prop_bank_watermark_invariants_under_churn() {
        // a protocol-free bank: deliveries race draws across threads; the
        // stored level must never exceed capacity, credit accounting must
        // refuse over-draws, and close() must drain cleanly
        use cbnn::protocols::preproc::MsbTuple;
        use cbnn::rss::{BitShare, Share};
        use std::sync::Arc;

        fn tup(n: usize) -> MsbTuple {
            MsbTuple {
                beta: BitShare::zeros(n),
                beta_a: Share { a: Tensor::zeros(&[n]),
                                b: Tensor::zeros(&[n]) },
                rs: Share { a: Tensor::zeros(&[n]),
                            b: Tensor::zeros(&[n]) },
            }
        }

        let cfg = BankConfig { low: 8, high: 16, chunk: 8, capacity: 24 };
        let bank = Arc::new(TupleBank::new(cfg));
        bank.credit(25 * 8);
        let feeder = {
            let b = Arc::clone(&bank);
            thread::spawn(move || {
                for _ in 0..25 {
                    b.deliver(tup(8)); // 200 elems through a 24-cap bank
                }
            })
        };
        let mut drawn = 0usize;
        while drawn < 25 * 8 {
            let n = 8.min(25 * 8 - drawn);
            assert!(bank.try_reserve(n), "credit must cover {n}");
            let t = bank.take(n).unwrap();
            assert_eq!(t.len(), n);
            drawn += n;
            assert!(bank.level() <= cfg.capacity,
                    "level {} exceeded capacity", bank.level());
        }
        feeder.join().unwrap();
        let m = bank.metrics();
        assert_eq!(m.minted, 200);
        assert_eq!(m.drawn, 200);
        assert!(m.max_level as usize <= cfg.capacity, "{m:?}");
        // all credit consumed: the next reserve is a counted underflow
        assert!(!bank.try_reserve(1));
        assert_eq!(bank.metrics().underflow_calls, 1);
        // close drains: blocked draws err instead of hanging
        bank.credit(8);
        assert!(bank.try_reserve(8));
        let waiter = {
            let b = Arc::clone(&bank);
            thread::spawn(move || b.take(8))
        };
        bank.close();
        assert!(waiter.join().unwrap().is_err());
    }
}

// ---- binary-domain fusion properties (ISSUE 6) --------------------------

mod fusion {
    use std::sync::Arc;

    use cbnn::coordinator::Service;
    use cbnn::engine::fusion::{infer_batch_fused, plan_fused};
    use cbnn::engine::session::SessionConfig;
    use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                       EngineOptions};
    use cbnn::metrics::OpCost;
    use cbnn::nn::Model;
    use cbnn::offline::TupleSource;
    use cbnn::protocols::binlinear::{or_planes, popcount_ge,
                                     popcount_to_arith};
    use cbnn::protocols::linear::NativeBackend;
    use cbnn::protocols::preproc::MsbPool;
    use cbnn::ring::Tensor;
    use cbnn::rss::{deal_bits, reconstruct, reconstruct_bits, BitShare,
                    Share};
    use cbnn::testutil::threeparty::{edge_bits, every_op_model,
                                     run3_seeded, sep_chain_model,
                                     EDGE_LENGTHS};
    use cbnn::testutil::Rng;

    fn inputs_for(id: usize, batch: usize, flat: usize, seed: u64)
                  -> Vec<Tensor> {
        if id == 0 {
            let mut rng = Rng::new(seed);
            (0..batch).map(|_| rng.tensor_small(&[1, flat], 15)).collect()
        } else {
            vec![]
        }
    }

    /// One pooled inference arm in its own fresh session at `seed`:
    /// fused or unfused walk over the same model and inputs.  Separate
    /// sessions at the same seed see identical TRUNC-lane randomness
    /// (the counter lane advances only on trunc calls, which both walks
    /// issue identically), so logits are comparable bit-for-bit.
    /// Returns party 0's (logits, per-op cost rows, msb demand).
    fn arm(model: &Model, seed: u64, batch: usize, fuse: bool)
           -> (Vec<Vec<i32>>, Vec<OpCost>, usize) {
        let (c, h, w) = model.input;
        let flat = c * h * w;
        let plan = if fuse {
            Some(plan_fused(model).expect("plan must lower"))
        } else {
            None
        };
        let results = run3_seeded(seed, |ctx| {
            let shared = share_model(ctx, model, true).unwrap();
            let demand = match &plan {
                Some(p) => p.msb_demand(batch),
                None => msb_demand(&shared, batch),
            };
            let pool = MsbPool::new();
            pool.generate(ctx, demand).unwrap();
            let src = TupleSource::Pool(&pool);
            let inputs = inputs_for(ctx.id(), batch, flat, seed ^ 0xF00D);
            let out = match &plan {
                Some(p) => infer_batch_fused(
                    ctx, &shared, p, &NativeBackend,
                    EngineOptions::default(), &inputs, batch, &src)
                    .unwrap(),
                None => infer_batch_pooled(
                    ctx, &shared, &NativeBackend, EngineOptions::default(),
                    &inputs, batch, &src)
                    .unwrap(),
            };
            (out.logits, out.op_costs, demand)
        });
        results.into_iter().next().unwrap().0
    }

    /// A fully fusable hidden chain: conv -> sign enters the binary
    /// domain, then OR-pool, pm1, a +-1 depthwise with its sign folded,
    /// pm1, flatten, and a +-1 FC (K=100) leaving via the popcount b2a
    /// boundary.  No ReLU, so the whole program is trunc-free and
    /// fused/unfused logits must match bit-for-bit even across session
    /// interleavings.
    fn bnn_chain_model() -> Model {
        let manifest = r#"{
          "name": "bnnchain", "dataset": "synthetic",
          "input": {"c": 1, "h": 12, "w": 12},
          "s_in": 0, "ring_bits": 32,
          "layers": [
            {"op": "matmul", "conv": true, "m": 4, "kdim": 9, "n": 100,
             "k": 3, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 4,
             "w": {"off": 0, "len": 36}, "b": {"off": 36, "len": 4},
             "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 4, "t": {"off": 40, "len": 4},
             "flip": {"off": 44, "len": 4}},
            {"op": "pool_bits", "c": 4, "k": 2, "stride": 2},
            {"op": "pm1"},
            {"op": "depthwise", "cout": 4, "k": 1, "stride": 1,
             "pad_lo": 0, "pad_hi": 0, "w": {"off": 48, "len": 4},
             "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 4, "t": {"off": 52, "len": 4},
             "flip": {"off": 56, "len": 4}},
            {"op": "pm1"},
            {"op": "flatten", "c": 4, "h": 5, "w": 5},
            {"op": "matmul", "conv": false, "m": 3, "kdim": 100, "n": 1,
             "w": {"off": 60, "len": 300}, "s_in": 0, "s_out": 0}
          ]
        }"#;
        let mut pool = vec![0i32; 360];
        for (i, v) in pool.iter_mut().enumerate().take(36) {
            *v = (i as i32 % 5) - 2; // conv weights, arbitrary small
        }
        pool[36..40].copy_from_slice(&[1, -1, 2, 0]); // conv bias
        pool[40..44].copy_from_slice(&[0, 1, -1, 2]); // sign thresholds
        pool[44..48].copy_from_slice(&[1, -1, 2, -2]); // sign flips
        pool[48..52].copy_from_slice(&[1, -1, 1, -1]); // +-1 depthwise
        // folded sign: thresholds/flips picked to hit the identity,
        // negate, constant-0 and constant-1 fold branches (K = 1)
        pool[52..56].copy_from_slice(&[1, 3, -2, 0]);
        pool[56..60].copy_from_slice(&[2, -1, 1, -3]);
        for (i, v) in pool.iter_mut().enumerate().skip(60) {
            *v = if (i + i / 7) % 2 == 0 { 1 } else { -1 }; // +-1 FC
        }
        Model::from_json(manifest, pool).unwrap()
    }

    #[test]
    fn prop_fused_logits_bit_identical_on_every_op_model() {
        // the every-op program (conv, sign, pool, pm1, depthwise,
        // flatten, fc, relu) crosses the fusion boundary both ways;
        // fused logits must equal the unfused walk bit-for-bit, while
        // drawing strictly fewer MSB tuples
        let model = every_op_model();
        for batch in [1usize, 2, 3] {
            let seed = 0xF5ED + batch as u64;
            let (u_logits, _, u_demand) = arm(&model, seed, batch, false);
            let (f_logits, _, f_demand) = arm(&model, seed, batch, true);
            assert!(!u_logits.is_empty());
            assert_eq!(u_logits, f_logits,
                       "fused logits diverged at batch {batch}");
            assert_eq!(u_demand, 43 * batch, "unfused draws per sample");
            assert_eq!(f_demand, 35 * batch,
                       "fused must skip the pool-bits draw");
        }
    }

    #[test]
    fn prop_fused_hidden_segment_ships_8x_fewer_bytes() {
        // the acceptance claim: across the hidden binary segment
        // (pool -> pm1 -> +-1 depthwise -> folded sign, op indices
        // 2..=5) the fused walk ships word-packed boolean shares where
        // the arithmetic walk ships ring words and MSB extractions
        let model = bnn_chain_model();
        let seg = |costs: &[OpCost]| costs.iter()
            .filter(|r| (2..=5).contains(&r.index))
            .map(|r| r.bytes_sent)
            .sum::<u64>();
        for batch in [1usize, 2] {
            let seed = 0xB17 + batch as u64;
            let (u_logits, u_costs, u_demand) =
                arm(&model, seed, batch, false);
            let (f_logits, f_costs, f_demand) =
                arm(&model, seed, batch, true);
            assert_eq!(u_logits, f_logits,
                       "bnn chain diverged at batch {batch}");
            // only the sign *entering* the binary domain draws tuples
            assert_eq!(u_demand, 600 * batch);
            assert_eq!(f_demand, 400 * batch);
            let (ub, fb) = (seg(&u_costs), seg(&f_costs));
            assert!(fb > 0, "fused segment must still talk");
            assert!(ub >= 8 * fb,
                    "hidden segment: unfused {ub} B vs fused {fb} B -- \
                     need >= 8x reduction (batch {batch})");
            // and the whole walk is cheaper end to end, b2a included
            let total = |costs: &[OpCost]| costs.iter()
                .map(|r| r.bytes_sent).sum::<u64>();
            assert!(total(&f_costs) < total(&u_costs));
        }
    }

    #[test]
    fn prop_sep_chain_fused_bit_identical_and_demand_agrees() {
        // the real zoo layer mix in miniature: fixed-point stem conv,
        // +-1 depthwise + pointwise pair, binary FCs, fixed-point
        // logits.  Fused and unfused walks must agree bit-for-bit, and
        // both must agree with the plaintext reference walk (the chain
        // is sign-only, so there is no trunc LSB to tolerate).
        let model = sep_chain_model();
        let (c, h, w) = model.input;
        let flat = c * h * w;
        for batch in [1usize, 3] {
            let seed = 0x5E9C ^ batch as u64;
            let (u_logits, _, u_demand) = arm(&model, seed, batch, false);
            let (f_logits, _, f_demand) = arm(&model, seed, batch, true);
            assert_eq!(u_logits, f_logits,
                       "sep chain diverged at batch {batch}");
            // per-sample MSB demand: every sign + pool contributes on
            // the unfused walk; fused folds the interior draws away
            assert_eq!(u_demand % batch, 0);
            assert_eq!(f_demand % batch, 0);
            assert!(f_demand < u_demand,
                    "fused demand {f_demand} must undercut {u_demand}");
            // plan and engine must agree on demand given the same graph
            let plan = plan_fused(&model).unwrap();
            assert_eq!(plan.msb_demand(batch), f_demand);
            // the secure walk equals the plaintext reference walk
            let inputs = inputs_for(0, batch, flat, seed ^ 0xF00D);
            for (i, logits) in u_logits.iter().enumerate() {
                let want = cbnn::nn::reference::forward(
                    &model, &inputs[i].data);
                assert_eq!(logits, &want,
                           "sample {i} diverged from reference walk");
            }
        }
    }

    fn check_popcount(seed: u64, n: usize) {
        // the fused comparator primitives over one plane set: secure
        // popcount >= per-element threshold, popcount to arithmetic,
        // and the OR tree, against plaintext references
        const K: usize = 5;
        let results = run3_seeded(seed, |ctx| {
            let mut rng = Rng::new(seed ^ 0x9C0);
            let planes: Vec<Vec<u8>> =
                (0..K).map(|_| edge_bits(&mut rng, n)).collect();
            let thr: Vec<u32> =
                (0..n).map(|i| (i % (K + 2)) as u32).collect();
            let dealt: Vec<[BitShare; 3]> =
                planes.iter().map(|p| deal_bits(p, &mut rng)).collect();
            let mine: Vec<BitShare> =
                dealt.iter().map(|d| d[ctx.id()].clone()).collect();
            let ge = popcount_ge(ctx, mine.clone(), &thr).unwrap();
            let pc = popcount_to_arith(ctx, mine.clone()).unwrap();
            let or = or_planes(ctx, mine).unwrap();
            (ge, pc, or, planes, thr)
        });
        let (_, _, _, planes, thr) = results[0].0.clone();
        let ge: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let pc: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .1.clone());
        let or: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .2.clone());
        let ge = reconstruct_bits(&ge);
        let pc = reconstruct(&pc);
        let or = reconstruct_bits(&or);
        for i in 0..n {
            let count: u32 =
                planes.iter().map(|p| u32::from(p[i])).sum();
            assert_eq!(ge[i], u8::from(count >= thr[i]),
                       "popcount_ge({count} >= {}) at {i} n={n}", thr[i]);
            assert_eq!(pc.data[i], count as i32,
                       "popcount_to_arith at {i} n={n}");
            assert_eq!(or[i], u8::from(count > 0), "or at {i} n={n}");
        }
    }

    #[test]
    fn prop_popcount_primitives_round_trip_across_edge_lengths() {
        for &n in &EDGE_LENGTHS {
            check_popcount(41, n);
        }
    }

    #[test]
    fn prop_fused_service_serves_with_zero_request_path_mints() {
        // coordinator-level: a fused service auto-sizes its tuple bank
        // to the *smaller* fused demand and still never mints on the
        // request path; logits match an unfused service bit-for-bit
        // (same slot/seed domain, so TRUNC-lane draws align)
        let model = Arc::new(every_op_model());
        let mut fcfg = SessionConfig::new("artifacts/hlo");
        fcfg.opts.fuse = true;
        let fused = Service::start(Arc::clone(&model), fcfg).unwrap();
        let unfused = Service::start(
            Arc::clone(&model), SessionConfig::new("artifacts/hlo"))
            .unwrap();
        assert_eq!(unfused.demand_for(2), 86);
        assert_eq!(fused.demand_for(2), 70,
                   "fused bank must auto-size below the unfused demand");
        let mut rng = Rng::new(9);
        for _ in 0..3 {
            let batch: Vec<Tensor> =
                (0..2).map(|_| rng.tensor_small(&[1, 36], 15)).collect();
            let f = fused.infer(batch.clone()).expect("fused batch");
            let u = unfused.infer(batch).expect("unfused batch");
            assert_eq!(f.len(), 2);
            assert_eq!(f[0].len(), 3);
            assert_eq!(f, u, "fused service diverged");
        }
        for p in 0..3 {
            let m = fused.bank_handle(p).metrics();
            assert_eq!(m.underflow_calls, 0,
                       "party {p} minted on the request path: {m:?}");
            assert!(m.drawn > 0, "party {p} never drew from the bank");
        }
        let _ = fused.shutdown();
        let _ = unfused.shutdown();
    }
}

// ---- fixed-seed entries (the CI property job) ---------------------------

#[test]
fn prop_msb_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_msb(11, n);
    }
}

#[test]
fn prop_bitdecomp_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_bitdecomp(13, n);
    }
}

#[test]
fn prop_b2a_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_b2a(17, n);
    }
    // degenerate fills
    for (seed, n) in [(19u64, 16usize), (23, 65)] {
        for fill in [0u8, 1u8] {
            let results = run3_seeded(seed + u64::from(fill), |ctx| {
                let mut rng = Rng::new(seed);
                let bits = vec![fill; n];
                let shares = deal_bits(&bits, &mut rng);
                b2a(ctx, &shares[ctx.id()]).unwrap()
            });
            let shares: [Share; 3] =
                std::array::from_fn(|i| results[i].0.clone());
            let got = reconstruct(&shares);
            assert!(got.data.iter().all(|&v| v == i32::from(fill)));
        }
    }
}

#[test]
fn prop_relu_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_relu(29, n);
    }
}

#[test]
fn prop_trunc_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_trunc(31, n);
    }
}

#[test]
fn prop_msb_online_round_trips_across_edge_lengths() {
    for &n in &EDGE_LENGTHS {
        check_msb_online(37, n);
    }
}

#[test]
fn prop_multi_seed_sweep() {
    // a handful of additional master seeds over the full sweep
    for seed in [101u64, 202] {
        sweep(seed);
    }
}

// ---- randomized-seed smoke (the CI --ignored job) -----------------------

#[test]
#[ignore = "randomized smoke: run explicitly (CI nightly job) with \
            `cargo test --test properties -- --ignored`"]
fn randomized_seed_smoke() {
    let seed = match std::env::var("CBNN_PROP_SEED") {
        Ok(s) => s.parse().expect("CBNN_PROP_SEED must be a u64"),
        Err(_) => std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64,
    };
    // printed even on success so a failing CI run is replayable with
    // CBNN_PROP_SEED=<seed>
    println!("randomized_seed_smoke: CBNN_PROP_SEED={seed}");
    sweep(seed);
}

#[test]
fn bound_default_matches_edge_table_assumption() {
    // edge_values' extreme is ±(2^bound_bits − 1); keep the documented
    // sweep honest if the default config ever moves
    assert_eq!(bound_bits(), 24);
}
