//! Virtual-clock WAN soak (ISSUE 7): run real inferences through the
//! latency shim at `rtt=40ms` in virtual-clock mode and assert the
//! end-to-end latency the clock reports is explained by the round
//! counts -- at most `rounds x RTT x 1.25` on the critical path (each
//! round costs one one-way hop, so this leaves ~2.5x headroom), and at
//! least enough that the shim demonstrably priced every flight.  The
//! tests complete in milliseconds of wall time: nobody sleeps, the
//! clock is data-flow time carried on the frames.
//!
//! `tests/budgets.rs` pins the per-op round counts against DESIGN.md;
//! this file pins that those rounds are what latency is made of.

use std::time::Duration;

use cbnn::engine::fusion::{infer_batch_fused, plan_fused};
use cbnn::engine::{infer_batch_pooled, msb_demand, share_model,
                   EngineOptions};
use cbnn::nn::Model;
use cbnn::offline::TupleSource;
use cbnn::protocols::linear::NativeBackend;
use cbnn::protocols::preproc::MsbPool;
use cbnn::ring::Tensor;
use cbnn::testutil::threeparty::{every_op_model, run3_seeded_net};
use cbnn::testutil::Rng;
use cbnn::transport::shim::parse_net_spec;
use cbnn::transport::NetConfig;

const RTT: Duration = Duration::from_millis(40);

fn wan() -> NetConfig {
    let net = parse_net_spec("rtt=40ms,virtual")
        .expect("the soak spec must parse");
    assert!(net.virtual_clock, "soak must not sleep for real");
    assert_eq!(net.latency, RTT / 2);
    net
}

/// Measured (virtual elapsed, online rounds) of one inference per
/// party, pool warmed outside the window.
fn soak(model: &Model, fuse: bool, flat: usize, seed: u64)
        -> Vec<(Duration, u64)> {
    let batch = 2usize;
    let plan = fuse.then(|| plan_fused(model).expect("model must lower"));
    let results = run3_seeded_net(seed, wan(), |ctx| {
        let shared = share_model(ctx, model, true).unwrap();
        let demand = match &plan {
            Some(p) => p.msb_demand(batch),
            None => msb_demand(&shared, batch),
        };
        let inputs: Vec<Tensor> = if ctx.id() == 0 {
            let mut rng = Rng::new(seed ^ 0x50AC);
            (0..batch).map(|_| rng.tensor_small(&[1, flat], 15)).collect()
        } else {
            vec![]
        };
        let pool = MsbPool::new();
        pool.generate(ctx, demand).unwrap();
        let src = TupleSource::Pool(&pool);
        let t0 = ctx.comm.virtual_now();
        let r0 = ctx.comm.stats().rounds;
        let out = match &plan {
            Some(p) => infer_batch_fused(
                ctx, &shared, p, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &src).unwrap(),
            None => infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &src).unwrap(),
        };
        if ctx.id() == 0 {
            assert!(!out.logits.is_empty(), "soak inference returned \
                     nothing to the data owner");
        }
        (ctx.comm.virtual_now() - t0, ctx.comm.stats().rounds - r0)
    });
    results.into_iter().map(|(r, _)| r).collect()
}

/// Critical-path latency must be explained by the rounds: bounded above
/// by `rounds x RTT x 1.25` and below by a quarter of one hop per round
/// (proves the shim priced the flights -- a zero-latency bug fails).
fn assert_latency_tracks_rounds(parties: &[(Duration, u64)]) {
    let elapsed = parties.iter().map(|p| p.0).max().unwrap();
    let rounds = parties.iter().map(|p| p.1).max().unwrap();
    assert!(rounds > 0, "no rounds measured; the soak is vacuous");
    let budget = RTT.mul_f64(rounds as f64 * 1.25);
    assert!(elapsed <= budget,
            "WAN latency {elapsed:?} exceeds {rounds} rounds x 40ms RTT \
             x 1.25 = {budget:?}: a flight is not coalesced or a round \
             snuck in");
    let floor = (RTT / 2).mul_f64(rounds as f64 * 0.25);
    assert!(elapsed >= floor,
            "WAN latency {elapsed:?} under {floor:?} for {rounds} \
             rounds: the shim stopped pricing flights");
    assert!(elapsed >= 2 * RTT,
            "an inference cannot finish inside {elapsed:?} over a real \
             40ms-RTT link");
}

#[test]
fn every_op_wan_latency_tracks_round_budget() {
    let model = every_op_model();
    let parties = soak(&model, false, 36, 0x3A11);
    // end-to-end pin of the DESIGN.md budget composition: share_input
    // (1) + [linear 1, msb_online 2, msb_online 2, pm1 0, linear 1,
    // flatten 0, linear 1, relu_op 10] on the relu critical-path party
    // (P2, which skips the reveal) = 18
    let rounds = parties.iter().map(|p| p.1).max().unwrap();
    assert_eq!(rounds, 18,
               "every-op pooled walk must cost exactly 18 critical-path \
                rounds (see DESIGN.md 'Round budgets')");
    assert_latency_tracks_rounds(&parties);
}

#[test]
fn every_op_fused_wan_latency_tracks_rounds() {
    let model = every_op_model();
    assert_latency_tracks_rounds(&soak(&model, true, 36, 0x3A12));
}

#[test]
fn fused_bnn_chain_wan_latency_tracks_rounds() {
    // the acceptance soak: the fully fused binary chain (conv -> sign
    // -> OR-pool -> pm1 -> +-1 depthwise + folded sign -> pm1 ->
    // flatten -> +-1 FC) under 40ms RTT; BinLinear rounds are
    // geometry-dependent (CSA levels + Kogge-Stone + b2a), so the
    // budget is the measured critical path, priced by the clock
    let model = bnn_chain_model();
    assert_latency_tracks_rounds(&soak(&model, true, 144, 0x3A13));
}

#[test]
fn unfused_bnn_chain_wan_latency_tracks_rounds() {
    let model = bnn_chain_model();
    assert_latency_tracks_rounds(&soak(&model, false, 144, 0x3A14));
}

/// Same chain `tests/properties.rs` proves bit-identical fused vs
/// unfused; here it is the WAN soak workload.
fn bnn_chain_model() -> Model {
    let manifest = r#"{
      "name": "bnnchain", "dataset": "synthetic",
      "input": {"c": 1, "h": 12, "w": 12},
      "s_in": 0, "ring_bits": 32,
      "layers": [
        {"op": "matmul", "conv": true, "m": 4, "kdim": 9, "n": 100,
         "k": 3, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 4,
         "w": {"off": 0, "len": 36}, "b": {"off": 36, "len": 4},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 4, "t": {"off": 40, "len": 4},
         "flip": {"off": 44, "len": 4}},
        {"op": "pool_bits", "c": 4, "k": 2, "stride": 2},
        {"op": "pm1"},
        {"op": "depthwise", "cout": 4, "k": 1, "stride": 1,
         "pad_lo": 0, "pad_hi": 0, "w": {"off": 48, "len": 4},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 4, "t": {"off": 52, "len": 4},
         "flip": {"off": 56, "len": 4}},
        {"op": "pm1"},
        {"op": "flatten", "c": 4, "h": 5, "w": 5},
        {"op": "matmul", "conv": false, "m": 3, "kdim": 100, "n": 1,
         "w": {"off": 60, "len": 300}, "s_in": 0, "s_out": 0}
      ]
    }"#;
    let mut pool = vec![0i32; 360];
    for (i, v) in pool.iter_mut().enumerate().take(36) {
        *v = (i as i32 % 5) - 2;
    }
    pool[36..40].copy_from_slice(&[1, -1, 2, 0]);
    pool[40..44].copy_from_slice(&[0, 1, -1, 2]);
    pool[44..48].copy_from_slice(&[1, -1, 2, -2]);
    pool[48..52].copy_from_slice(&[1, -1, 1, -1]);
    pool[52..56].copy_from_slice(&[1, 3, -2, 0]);
    pool[56..60].copy_from_slice(&[2, -1, 1, -3]);
    for (i, v) in pool.iter_mut().enumerate().skip(60) {
        *v = if (i + i / 7) % 2 == 0 { 1 } else { -1 };
    }
    Model::from_json(manifest, pool).unwrap()
}
