//! Offline-soak: many small batches against a deliberately low-watermark
//! bank.  The coordinator's refill pump must keep the background
//! producers ahead of the online stream -- zero request-path generation
//! (`underflow_calls == 0`), every response delivered, and the bank's
//! storage bounded by its capacity throughout.
//!
//! The fast entry runs in the default suite; the `--ignored` entry is the
//! CI soak job (`CBNN_SOAK_BATCHES` scales it).

use std::sync::Arc;
use std::time::Duration;

use cbnn::coordinator::{BatchPolicy, Coordinator, Service};
use cbnn::engine::msb_demand_for;
use cbnn::engine::session::SessionConfig;
use cbnn::offline::BankConfig;
use cbnn::testutil::threeparty::every_op_model;
use cbnn::testutil::Rng;

fn soak(batches: usize) {
    let model = Arc::new(every_op_model());
    // per-request demand (the batcher runs batch=1): Sign 32 + Pool 8 +
    // Relu 3 elements on the every-Op model
    let unit = msb_demand_for(&model, 1);
    assert_eq!(unit, 43);
    // low-watermark bank: roughly one request of headroom triggers the
    // pump, chunks are half a request, so refill/drain churn constantly
    let cfg = SessionConfig::new("artifacts/hlo").with_bank(BankConfig {
        low: unit,
        high: 2 * unit,
        chunk: unit.div_ceil(2),
        capacity: 3 * unit,
    });
    let svc = Service::start(Arc::clone(&model), cfg).expect("setup");
    let bank0 = svc.bank_handle(0);
    let capacity = bank0.config().capacity;
    let coord = Coordinator::start(svc, BatchPolicy {
        max_batch: 1,
        max_wait: Duration::ZERO,
        prefetch: 2,
    });
    let mut rng = Rng::new(33);
    for i in 0..batches {
        let img = rng.tensor_small(&[1, 36], 15);
        let resp = coord.submit(img).recv().expect("response");
        assert_eq!(resp.logits.len(), 3, "batch {i}");
        assert!(bank0.level() <= capacity, "batch {i}: bank overflowed");
    }
    let m = coord.preproc_metrics();
    let (hist, thr) = coord.finish();
    assert_eq!(thr.requests, batches as u64);
    assert_eq!(hist.count(), batches as u64);
    assert_eq!(m.underflow_calls, 0,
               "request path minted inline under soak: {m:?}");
    assert_eq!(m.fallback_elems, 0);
    assert_eq!(m.drawn, (unit * batches) as u64);
    assert!(m.max_level as usize <= capacity, "{m:?}");
}

#[test]
fn soak_small_batches_low_watermark() {
    soak(12);
}

#[test]
#[ignore = "CI soak job: run with `cargo test --test offline_soak -- \
            --ignored` (CBNN_SOAK_BATCHES scales the run)"]
fn soak_many_small_batches() {
    let batches = std::env::var("CBNN_SOAK_BATCHES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    soak(batches);
}
