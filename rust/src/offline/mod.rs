//! Offline preprocessing subsystem: watermark-managed tuple banks fed by
//! background producers over tagged offline transport channels.
//!
//! CBNN's protocols split into an offline phase (the β/βᴬ/rs MSB tuples)
//! and a 2-round online phase, but a pool minted inline still pays the
//! offline cost on the request path.  This module decouples them for the
//! serving stack:
//!
//! * each model's party thread spawns one **producer** thread holding a
//!   `Comm::channel(ChanId::offline(slot))` handle and its own PRF seed
//!   domain (`offline_seeds` over the model-scoped session seed), so
//!   producer traffic multiplexes over the same three-party links
//!   without interleaving into online frames and without perturbing the
//!   online PRF counter trajectory.  In a multi-model process every
//!   model slot gets its own producer lane and its own `TupleBank`
//!   (banks are never shared across models: their seed domains differ,
//!   so one model's tuples cannot reconstruct in another's session --
//!   see DESIGN.md §Multi-model multiplexing);
//! * a **`TupleBank`** sits between producer and consumer: a
//!   `Mutex`+condvar reservoir with a hard `capacity` (delivery blocks
//!   when full -- backpressure), low/high watermarks driving the
//!   coordinator's refill pump, and a `close()` drain for shutdown;
//! * draws are decided by **deterministic credit accounting**, not the
//!   racy actual fill level: every party observes the identical
//!   refill/infer command order (the coordinator broadcasts under one
//!   lock), so `credited - reserved` evolves identically on all three
//!   parties and they agree on every pooled-vs-fallback decision even
//!   though their producers run at different speeds.  A committed draw
//!   then *blocks* until the producer delivers; a refused draw falls
//!   back to synchronous generation on the online channel (counted in
//!   `PreprocMetrics`).
//!
//! Deadlock freedom: a delivery blocks only while `level + chunk >
//! capacity`, i.e. a blocked producer guarantees `level > capacity -
//! chunk`; `try_reserve` refuses any draw larger than `capacity -
//! chunk`, so a committed draw is always satisfiable from a
//! backpressured bank -- producer and consumer can never wait on each
//! other.  Online protocol frames never depend on offline frames (and
//! vice versa), so the per-link channel demux cannot cycle either.
//!
//! **Leakage / reuse boundary**: every tuple is consumed exactly once
//! (the FIFO pop is destructive) and a bank is owned by one session's
//! party thread -- tuples are never shared across sessions.  Reusing an
//! MSB tuple would reveal linear relations between the two masked
//! reveals; the single-use FIFO discipline is the security argument, see
//! DESIGN.md §Offline/online split.

use std::sync::mpsc::Receiver;
use std::sync::{Condvar, Mutex, MutexGuard};

use anyhow::Result;

use crate::metrics::PreprocMetrics;
use crate::prf::PartySeeds;
use crate::protocols::preproc::{self, MsbPool, MsbTuple, PreprocError,
                                Reservoir};
use crate::protocols::Ctx;

/// Producer PRF streams are domain-separated from the online session's:
/// minting never advances the online counters, so a served batch is
/// bit-identical whether its tuples came from a warm bank or an inline
/// pool minted with the same chunk schedule.
pub const OFFLINE_SEED_SALT: u64 = 0x0FF1_CE5E_ED00_57A6;

/// The producer-side seed derivation for `session_seed` (identical on
/// all parties, so producer-minted tuples reconstruct consistently).
pub fn offline_seeds(session_seed: u64, party: usize) -> PartySeeds {
    PartySeeds::setup(session_seed ^ OFFLINE_SEED_SALT, party)
}

/// Watermark policy for one `TupleBank`, in tuple elements.
#[derive(Clone, Copy, Debug)]
pub struct BankConfig {
    /// Refill trigger: the pump tops up when deterministic headroom
    /// (`credited - reserved`) falls below this.
    pub low: usize,
    /// Top-up / prefill target.
    pub high: usize,
    /// Elements per refill job (one producer mint).
    pub chunk: usize,
    /// Hard storage cap: deliveries block above it (backpressure).
    pub capacity: usize,
}

impl Default for BankConfig {
    fn default() -> Self {
        BankConfig { low: 1024, high: 2048, chunk: 512, capacity: 2560 }
    }
}

impl BankConfig {
    /// Scale the policy to a model's per-max-batch MSB demand: one batch
    /// of headroom triggers a refill, three are kept warm, chunks are one
    /// batch so a refill never straddles more than one mint.
    pub fn auto(demand_per_batch: usize) -> BankConfig {
        let unit = demand_per_batch.max(1);
        BankConfig { low: unit, high: 3 * unit, chunk: unit,
                     capacity: 4 * unit }
    }

    /// Structural validity: non-empty chunks, ordered watermarks, and a
    /// capacity that leaves one chunk of headroom above `high` (this is
    /// what makes prefill-to-high reachable without tripping
    /// backpressure, and part of the deadlock-freedom argument above).
    /// Every rejection names the offending field and its value, so a
    /// bad `--bank-*` flag combination is diagnosable from the message
    /// alone.
    pub fn validate(&self) -> Result<(), String> {
        if self.chunk == 0 {
            return Err(format!(
                "bank field `chunk` = {}: refill chunks must be a \
                 positive element count",
                self.chunk));
        }
        if self.low > self.high {
            return Err(format!(
                "bank field `low` = {} exceeds field `high` = {}: \
                 watermarks must satisfy low <= high",
                self.low, self.high));
        }
        if self.high + self.chunk > self.capacity {
            return Err(format!(
                "bank field `capacity` = {} is below `high` + `chunk` \
                 = {} + {} = {}: one chunk of headroom above the high \
                 watermark is required (prefill reachability / deadlock \
                 freedom)",
                self.capacity, self.high, self.chunk,
                self.high + self.chunk));
        }
        Ok(())
    }
}

struct BankState {
    /// Live watermark policy.  Mutable under the state lock so the
    /// request plane can retune watermarks from observed dispatch
    /// demand (`TupleBank::retune`); `capacity` never changes after
    /// construction (it is the storage bound backpressure relies on).
    cfg: BankConfig,
    res: Reservoir,
    /// Elements promised by dispatched refill jobs (deterministic:
    /// advanced by the party thread in broadcast order).
    credited: usize,
    /// Elements committed to pooled draws (deterministic: advanced by
    /// the engine walk).
    reserved: usize,
    closed: bool,
    m: PreprocMetrics,
}

/// Per-party reservoir of MSB tuples shared between the party's online
/// thread (draws) and its background producer (deliveries).
pub struct TupleBank {
    st: Mutex<BankState>,
    /// Signalled on delivery / close: wakes blocked draws and prefill.
    data: Condvar,
    /// Signalled on draw / close: wakes backpressured deliveries.
    space: Condvar,
}

impl TupleBank {
    /// `try_new` for callers that already validated (tests, fixed
    /// configs); panics on an invalid config.
    pub fn new(cfg: BankConfig) -> TupleBank {
        match Self::try_new(cfg) {
            Ok(b) => b,
            Err(e) => panic!("invalid BankConfig: {e}"),
        }
    }

    /// Build a bank, surfacing an invalid config as a typed error (the
    /// serving stack routes it through `RegistryError` instead of
    /// panicking a lifecycle operation).
    pub fn try_new(cfg: BankConfig) -> Result<TupleBank, String> {
        cfg.validate()?;
        Ok(TupleBank {
            st: Mutex::new(BankState {
                cfg,
                res: Reservoir::default(),
                credited: 0,
                reserved: 0,
                closed: false,
                m: PreprocMetrics::default(),
            }),
            data: Condvar::new(),
            space: Condvar::new(),
        })
    }

    pub fn config(&self) -> BankConfig {
        self.lock_st().cfg
    }

    /// Retune the watermark policy on a live bank.  `capacity` is
    /// immutable (it is the storage bound deliveries backpressure
    /// against), so the new watermarks are validated against the
    /// existing capacity and an infeasible combination is rejected
    /// whole -- the bank never runs a half-applied policy.  Safe for
    /// determinism only when applied in the service's broadcast job
    /// order (`Job::Retune`): `try_reserve` reads `chunk`/`capacity`,
    /// so all three parties must fold a retune into the job stream at
    /// the same point.  Never called on the request path -- the
    /// batcher's dispatch thread is the only caller (pinned by
    /// `retunes` staying 0 under plain `Service::infer` load).
    pub fn retune(&self, low: usize, high: usize, chunk: usize)
                  -> Result<(), String> {
        let mut st = self.lock_st();
        let next = BankConfig { low, high, chunk,
                                capacity: st.cfg.capacity };
        next.validate()?;
        if next.low != st.cfg.low || next.high != st.cfg.high
            || next.chunk != st.cfg.chunk {
            st.cfg = next;
            st.m.retunes += 1;
        }
        Ok(())
    }

    /// Non-mutating warm-serve probe for the admission controller: can
    /// a draw of `n` elements ever be served from the pool?  `false`
    /// when the bank is closed (producer dead / slot draining) or when
    /// `n` structurally exceeds `capacity - chunk` (such draws always
    /// fall back -- the deadlock-freedom bound `try_reserve` enforces).
    /// Deliberately does NOT check the current credit: the pump can
    /// always extend credit on a healthy bank, so low credit is a
    /// "pump harder" signal, not a shed signal.  Unlike a refused
    /// `try_reserve`, a `false` here counts nothing: shedding happens
    /// *before* the request path, so `underflow_calls` stays 0.
    pub fn can_serve_warm(&self, n: usize) -> bool {
        let st = self.lock_st();
        !st.closed && n + st.cfg.chunk <= st.cfg.capacity
    }

    /// Lock the bank state, absorbing lock poisoning: a producer or
    /// consumer that panicked mid-section leaves counters that are at
    /// worst stale, never unsound (tuples are only popped under the
    /// lock), so instead of cascading the panic into every thread that
    /// touches the bank we mark it closed -- blocked draws err
    /// `PreprocError::Closed` and the inference fails typed, exactly
    /// like a peer-death drain.  Pinned by `poisoned_bank_closes_typed`.
    fn lock_st(&self) -> MutexGuard<'_, BankState> {
        match self.st.lock() {
            Ok(g) => g,
            Err(p) => {
                let mut g = p.into_inner();
                g.closed = true;
                g
            }
        }
    }

    /// `Condvar::wait` with the same poison-means-closed policy.
    fn wait_on<'a>(&self, cv: &Condvar, g: MutexGuard<'a, BankState>)
                   -> MutexGuard<'a, BankState> {
        match cv.wait(g) {
            Ok(g) => g,
            Err(p) => {
                let mut g = p.into_inner();
                g.closed = true;
                g
            }
        }
    }

    /// Record a dispatched refill job of `n` elements.  Called by the
    /// party thread when it forwards the job to its producer, i.e. in
    /// the broadcast order every party observes identically.
    pub fn credit(&self, n: usize) {
        self.lock_st().credited += n;
    }

    /// Deterministic headroom: promised minus committed elements.  This
    /// is the quantity the pump and the draw decision agree on across
    /// parties, independent of producer speed.
    pub fn credited_available(&self) -> usize {
        let st = self.lock_st();
        st.credited - st.reserved
    }

    /// Elements committed to pooled draws so far (monotonic).
    pub fn reserved_elems(&self) -> usize {
        self.lock_st().reserved
    }

    /// Actually stored elements (racy against the producer; use only for
    /// observability and prefill waits, never for draw decisions).
    pub fn level(&self) -> usize {
        self.lock_st().res.len()
    }

    pub fn metrics(&self) -> PreprocMetrics {
        self.lock_st().m
    }

    /// Commit to a pooled draw of `n` elements iff the deterministic
    /// headroom covers it and `n <= capacity - chunk` (a backpressured
    /// producer only guarantees `capacity - chunk` deliverable elements,
    /// so anything larger could deadlock against a blocked delivery --
    /// it falls back instead).  The decision deliberately ignores the
    /// party-local `closed` flag: all inputs are deterministic across
    /// parties, so the trio always agrees; a closed bank surfaces as
    /// `PreprocError::Closed` from the subsequent `take`, which errs the
    /// inference instead of desynchronizing it.  A refusal is the
    /// *underflow* the metrics count: the caller mints synchronously on
    /// the request path.
    pub fn try_reserve(&self, n: usize) -> bool {
        let mut st = self.lock_st();
        if n + st.cfg.chunk <= st.cfg.capacity
            && st.credited - st.reserved >= n {
            st.reserved += n;
            true
        } else {
            st.m.underflow_calls += 1;
            st.m.fallback_elems += n as u64;
            false
        }
    }

    /// Draw `n` elements, blocking until the producer has delivered them.
    /// Only valid after a successful `try_reserve(n)`; errs `Closed` if
    /// the bank is drained out from under the draw.
    pub fn take(&self, n: usize) -> Result<MsbTuple, PreprocError> {
        let mut st = self.lock_st();
        while st.res.len() < n && !st.closed {
            st = self.wait_on(&self.data, st);
        }
        if st.res.len() < n {
            return Err(PreprocError::Closed);
        }
        let t = st.res.pop(n);
        st.m.drawn += n as u64;
        drop(st);
        self.space.notify_all();
        Ok(t)
    }

    /// Producer delivery.  Blocks while the bank is full (backpressure);
    /// a closed bank swallows the tuple so shutdown drains cleanly.
    pub fn deliver(&self, t: MsbTuple) {
        let n = t.len();
        let mut st = self.lock_st();
        while !st.closed && st.res.len() + n > st.cfg.capacity {
            st = self.wait_on(&self.space, st);
        }
        if st.closed {
            return;
        }
        st.res.push(&t);
        st.m.minted += n as u64;
        st.m.refill_chunks += 1;
        st.m.max_level = st.m.max_level.max(st.res.len() as u64);
        drop(st);
        self.data.notify_all();
    }

    /// Stop the bank: wakes every blocked draw (they err `Closed`) and
    /// every backpressured delivery (dropped).  Idempotent.
    pub fn close(&self) {
        self.lock_st().closed = true;
        self.data.notify_all();
        self.space.notify_all();
    }

    /// Close the bank and discard its stored tuples, reporting how many
    /// elements were thrown away.  The quarantine/retire drain path:
    /// typed and assert-free, because a drained bank is an expected
    /// lifecycle outcome, not a programmer error -- discarded epochs
    /// never reconstruct, so dropping their material is safe (and
    /// mandatory: the respawned epoch mints its own).  Idempotent
    /// (subsequent calls report 0).
    pub fn drain(&self) -> usize {
        let mut st = self.lock_st();
        st.closed = true;
        let n = st.res.len();
        if n > 0 {
            let _ = st.res.pop(n);
        }
        drop(st);
        self.data.notify_all();
        self.space.notify_all();
        n
    }

    /// Block until the stored level reaches `target` (prefill barrier).
    pub fn wait_level(&self, target: usize) -> Result<usize, PreprocError> {
        let mut st = self.lock_st();
        while st.res.len() < target && !st.closed {
            st = self.wait_on(&self.data, st);
        }
        if st.res.len() < target {
            return Err(PreprocError::Closed);
        }
        Ok(st.res.len())
    }
}

/// Producer loop: mint one chunk per refill token and deliver it.  Runs
/// on a dedicated thread per party with `ctx` bound to the offline
/// channel and the offline seed domain; exits when the token channel
/// closes (graceful drain: queued tokens are identical on all parties,
/// so the interactive mints complete in lock-step before exit).  A mint
/// failure (peer death) is returned so the caller can close the bank.
pub fn run_producer(ctx: &Ctx, bank: &TupleBank, tokens: Receiver<usize>)
                    -> Result<()> {
    while let Ok(n) = tokens.recv() {
        let t = preproc::mint(ctx, n)?;
        bank.deliver(t);
        // periodic telemetry: one level/credit gauge sample per
        // delivered chunk (the bank's natural cadence)
        if let Some(tr) = ctx.comm.tracer().filter(|tr| tr.enabled()) {
            let (party, chan) = (ctx.id() as u8, ctx.comm.chan().tag());
            tr.gauge(party, chan, "bank_level", bank.level() as u64);
            tr.gauge(party, chan, "bank_credit",
                     bank.credited_available() as u64);
        }
    }
    Ok(())
}

/// Where `infer_batch_pooled` draws MSB correlated material from.
pub enum TupleSource<'a> {
    /// No preprocessing: run full Algorithm 3 inline per invocation.
    Inline,
    /// A pre-minted inline pool (one-shot sessions; errs on exhaustion).
    Pool(&'a MsbPool),
    /// A producer-fed bank (serving): deterministic reserve, blocking
    /// draw, synchronous-generation fallback on genuine underflow.
    Bank(&'a TupleBank),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::Tensor;
    use crate::rss::{BitShare, Share};
    use std::sync::Arc;
    use std::thread;

    fn tup(n: usize) -> MsbTuple {
        MsbTuple {
            beta: BitShare::zeros(n),
            beta_a: Share { a: Tensor::zeros(&[n]), b: Tensor::zeros(&[n]) },
            rs: Share { a: Tensor::zeros(&[n]), b: Tensor::zeros(&[n]) },
        }
    }

    #[test]
    fn config_validation_catches_bad_watermarks() {
        assert!(BankConfig::default().validate().is_ok());
        assert!(BankConfig::auto(86).validate().is_ok());
        assert!(BankConfig { low: 2, high: 1, chunk: 1, capacity: 4 }
                .validate().is_err());
        assert!(BankConfig { low: 0, high: 4, chunk: 0, capacity: 8 }
                .validate().is_err());
        assert!(BankConfig { low: 0, high: 8, chunk: 4, capacity: 8 }
                .validate().is_err(), "no chunk headroom above high");
    }

    #[test]
    fn config_validation_errors_name_field_and_value() {
        // the operator-facing contract: every rejection says which
        // field, with its value, so a bad --bank-* combination is
        // diagnosable from the message alone
        let e = BankConfig { low: 0, high: 4, chunk: 0, capacity: 8 }
            .validate().unwrap_err();
        assert!(e.contains("`chunk` = 0"), "{e}");
        let e = BankConfig { low: 7, high: 3, chunk: 1, capacity: 8 }
            .validate().unwrap_err();
        assert!(e.contains("`low` = 7") && e.contains("`high` = 3"),
                "{e}");
        let e = BankConfig { low: 0, high: 8, chunk: 4, capacity: 11 }
            .validate().unwrap_err();
        assert!(e.contains("`capacity` = 11") && e.contains("8 + 4"),
                "{e}");
    }

    #[test]
    fn warm_probe_counts_nothing_and_tracks_close() {
        let bank = TupleBank::new(BankConfig {
            low: 0, high: 8, chunk: 4, capacity: 16 });
        // structurally servable draws probe true, oversized ones false
        assert!(bank.can_serve_warm(12));
        assert!(!bank.can_serve_warm(13), "above capacity - chunk");
        // the probe is the shed decision, which precedes the request
        // path: unlike a refused try_reserve it must count nothing
        assert_eq!(bank.metrics().underflow_calls, 0);
        assert_eq!(bank.metrics().fallback_elems, 0);
        bank.close();
        assert!(!bank.can_serve_warm(1), "closed bank is dry");
    }

    #[test]
    fn retune_validates_against_fixed_capacity() {
        let bank = TupleBank::new(BankConfig {
            low: 4, high: 8, chunk: 4, capacity: 16 });
        // a feasible retune applies whole and is counted
        bank.retune(2, 10, 6).unwrap();
        let cfg = bank.config();
        assert_eq!((cfg.low, cfg.high, cfg.chunk, cfg.capacity),
                   (2, 10, 6, 16));
        assert_eq!(bank.metrics().retunes, 1);
        // capacity is immutable: high + chunk must still fit under it
        assert!(bank.retune(2, 14, 4).is_err(),
                "14 + 4 > 16 must be rejected whole");
        let cfg = bank.config();
        assert_eq!((cfg.low, cfg.high, cfg.chunk), (2, 10, 6),
                   "rejected retune must not half-apply");
        // a no-op retune is not counted (idempotent pumps don't spam)
        bank.retune(2, 10, 6).unwrap();
        assert_eq!(bank.metrics().retunes, 1);
        // the live chunk governs the reserve bound
        bank.credit(100);
        assert!(bank.try_reserve(10));
        assert!(!bank.try_reserve(11), "11 + chunk 6 > capacity 16");
    }

    #[test]
    fn reserve_is_credit_accounted_not_level_accounted() {
        let bank = TupleBank::new(BankConfig {
            low: 0, high: 8, chunk: 4, capacity: 16 });
        // no credit: refuse (and count the underflow)
        assert!(!bank.try_reserve(1));
        assert_eq!(bank.metrics().underflow_calls, 1);
        assert_eq!(bank.metrics().fallback_elems, 1);
        // credit without delivery: reserve succeeds (the take would
        // block until the producer catches up)
        bank.credit(8);
        assert!(bank.try_reserve(5));
        assert_eq!(bank.credited_available(), 3);
        assert!(!bank.try_reserve(4), "over-reserve must refuse");
        // draws above capacity - chunk always fall back, credit
        // notwithstanding: a backpressured producer only guarantees
        // capacity - chunk deliverable elements (deadlock freedom)
        bank.credit(1000);
        assert!(!bank.try_reserve(13));
        assert!(bank.try_reserve(12));
        assert_eq!(bank.reserved_elems(), 17);
    }

    #[test]
    fn delivery_backpressure_blocks_at_capacity() {
        let cfg = BankConfig { low: 8, high: 24, chunk: 8, capacity: 40 };
        let bank = Arc::new(TupleBank::new(cfg));
        bank.credit(1000);
        let b = Arc::clone(&bank);
        // 10 chunks of 8 = 80 elements into a 40-capacity bank: the
        // producer must block until draws free space
        let producer = thread::spawn(move || {
            for _ in 0..10 {
                b.deliver(tup(8));
            }
        });
        bank.wait_level(cfg.capacity).unwrap();
        assert_eq!(bank.level(), cfg.capacity);
        for _ in 0..2 {
            assert!(bank.try_reserve(24));
            let t = bank.take(24).unwrap();
            assert_eq!(t.len(), 24);
        }
        producer.join().unwrap();
        let m = bank.metrics();
        assert_eq!(m.minted, 80);
        assert_eq!(m.drawn, 48);
        assert_eq!(m.refill_chunks, 10);
        assert!(m.max_level as usize <= cfg.capacity,
                "level exceeded capacity: {m:?}");
        assert_eq!(bank.level(), 32);
    }

    #[test]
    fn close_wakes_blocked_draws_and_deliveries() {
        let bank = Arc::new(TupleBank::new(BankConfig {
            low: 0, high: 8, chunk: 4, capacity: 12 }));
        bank.credit(100);
        assert!(bank.try_reserve(8));
        let b = Arc::clone(&bank);
        let taker = thread::spawn(move || b.take(8));
        bank.close();
        assert_eq!(taker.join().unwrap().unwrap_err(), PreprocError::Closed);
        // delivery into a closed bank is a silent drop (shutdown drain)
        bank.deliver(tup(4));
        assert_eq!(bank.level(), 0);
        // reserve stays deterministic (credit-only, ignores closed);
        // the draw itself surfaces Closed
        assert!(bank.try_reserve(1));
        assert_eq!(bank.take(1).unwrap_err(), PreprocError::Closed);
        assert!(bank.wait_level(1).is_err());
    }

    #[test]
    fn drain_discards_and_reports_then_is_idempotent() {
        let bank = TupleBank::new(BankConfig {
            low: 0, high: 8, chunk: 4, capacity: 16 });
        bank.credit(12);
        bank.deliver(tup(8));
        bank.deliver(tup(4));
        assert_eq!(bank.level(), 12);
        assert_eq!(bank.drain(), 12, "drain reports discarded elements");
        assert_eq!(bank.level(), 0);
        assert_eq!(bank.drain(), 0, "second drain finds nothing");
        // drained == closed: draws err typed, deliveries are swallowed
        assert_eq!(bank.take(1).unwrap_err(), PreprocError::Closed);
        bank.deliver(tup(4));
        assert_eq!(bank.level(), 0);
    }

    #[test]
    fn try_new_surfaces_invalid_configs_as_typed_errors() {
        let err = TupleBank::try_new(BankConfig {
            low: 0, high: 8, chunk: 4, capacity: 8 }).err().unwrap();
        assert!(err.contains("`capacity`"), "{err}");
        assert!(TupleBank::try_new(BankConfig::default()).is_ok());
    }

    #[test]
    fn poisoned_bank_closes_typed() {
        // a thread panicking while holding the bank lock must not turn
        // every later bank call into a panic: poison degrades to the
        // closed state, so draws err `PreprocError::Closed` and the
        // serving stack fails the inference typed (same path as a peer
        // death) instead of aborting party threads
        let bank = Arc::new(TupleBank::new(BankConfig {
            low: 0, high: 8, chunk: 4, capacity: 16 }));
        bank.credit(8);
        let b = Arc::clone(&bank);
        let _ = thread::spawn(move || {
            let _g = b.st.lock().unwrap();
            panic!("injected poison");
        }).join();
        assert!(bank.st.is_poisoned(), "injection failed");
        // every entry point stays panic-free; blocking draws resolve
        assert!(bank.try_reserve(4), "reserve stays credit-accounted");
        assert_eq!(bank.take(4).unwrap_err(), PreprocError::Closed);
        bank.deliver(tup(4)); // swallowed, like any closed bank
        assert_eq!(bank.wait_level(1).unwrap_err(), PreprocError::Closed);
        let _ = bank.metrics();
        let _ = bank.level();
        bank.close();
    }

    #[test]
    fn fifo_splices_across_chunk_boundaries() {
        let bank = TupleBank::new(BankConfig {
            low: 0, high: 16, chunk: 8, capacity: 32 });
        bank.credit(20);
        bank.deliver(tup(8));
        bank.deliver(tup(8));
        assert!(bank.try_reserve(11));
        assert_eq!(bank.take(11).unwrap().len(), 11);
        assert_eq!(bank.level(), 5);
        bank.deliver(tup(4));
        assert!(bank.try_reserve(9));
        assert_eq!(bank.take(9).unwrap().len(), 9);
        assert_eq!(bank.level(), 0);
    }

    #[test]
    fn offline_seeds_are_salted_per_party_consistent() {
        // different domain than the online seeds, same derivation on all
        // parties: producer tuples must reconstruct across the trio
        let a = offline_seeds(7, 0);
        let online = PartySeeds::setup(7, 0);
        assert_ne!(a.zero3(0, 8), online.zero3(0, 8));
        let b = offline_seeds(7, 1);
        // replication: P0's `next` stream is P1's `mine` stream
        let (_, p0b) = a.rand2(0, 16);
        let (p1a, _) = b.rand2(0, 16);
        assert_eq!(p0b, p1a);
    }
}
