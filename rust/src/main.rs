//! cbnn -- leader CLI for the three-party secure BNN inference framework.
//!
//! Subcommands:
//!   infer  -- one batched secure inference, print predictions + cost
//!   serve  -- start the coordinator, replay a synthetic request stream,
//!             print latency/throughput
//!   acc    -- secure accuracy over the exported eval set
//!   info   -- describe a model manifest
//!
//! Common flags: --model <name> --artifacts <dir> --net lan|wan|zero
//!               --backend native|pjrt-pallas|pjrt-xla --batch N

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use cbnn::cli::{parse_backend, parse_bank, parse_net, Args};
use cbnn::coordinator::{BatchPolicy, Coordinator, Service};
use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, secure_accuracy, SessionConfig};
use cbnn::metrics::fmt_duration;
use cbnn::nn::Model;

fn usage() -> &'static str {
    "usage: cbnn <infer|serve|acc|info> --model <name> \
     [--artifacts artifacts] [--net lan|wan|zero] \
     [--backend native|pjrt-pallas|pjrt-xla] [--batch N] [--requests N] \
     [--prefetch N] [--bank-low N] [--bank-high N] [--bank-chunk N] \
     [--bank-capacity N]"
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!("{e}\n{}", usage()))?;
    let sub = args.subcommand.clone()
        .ok_or_else(|| anyhow!("missing subcommand\n{}", usage()))?;

    let art = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let name = args.get_or("model", "mnistnet1").to_string();
    let model = Arc::new(Model::load(
        &art.join("models").join(format!("{name}.manifest.json")))
        .with_context(|| format!("loading model '{name}'"))?);

    let cfg = SessionConfig::new(art.join("hlo"))
        .with_net(parse_net(args.get_or("net", "lan"))
                  .map_err(anyhow::Error::msg)?)
        .with_backend(parse_backend(args.get_or("backend", "pjrt-pallas"))
                      .map_err(anyhow::Error::msg)?);

    let data = EvalSet::load(&art.join("data")
                             .join(format!("{}.bin", model.dataset)))
        .context("eval data (run `make artifacts`)")?;

    match sub.as_str() {
        "info" => {
            println!("model      : {}", model.name);
            println!("dataset    : {}", model.dataset);
            println!("input CHW  : {:?}", model.input);
            println!("layers     : {}", model.ops.len());
            println!("parameters : {}", model.param_count());
            for (i, op) in model.ops.iter().enumerate() {
                println!("  [{i:>2}] {op:?}");
            }
        }
        "infer" => {
            let batch = args.get_usize("batch", 4)
                .map_err(anyhow::Error::msg)?;
            let inputs = data.images[..batch.min(data.images.len())].to_vec();
            let rep = run_inference(&model, inputs, &cfg)?;
            println!("model={} batch={} net={}", model.name, batch,
                     args.get_or("net", "lan"));
            println!("setup  : {}", fmt_duration(rep.setup));
            println!("online : {}  ({} per sample)",
                     fmt_duration(rep.online),
                     fmt_duration(rep.online / batch as u32));
            println!("comm   : {:.3} MB, {} rounds (max over parties)",
                     rep.comm_mb(), rep.max_rounds());
            for (i, (p, l)) in rep.preds.iter()
                .zip(&data.labels).enumerate() {
                println!("  sample {i}: pred={p} label={l}");
            }
        }
        "acc" => {
            let n = args.get_usize("n", 64).map_err(anyhow::Error::msg)?;
            let batch = args.get_usize("batch", 8)
                .map_err(anyhow::Error::msg)?;
            let n = n.min(data.images.len());
            let acc = secure_accuracy(&model, &data.images[..n],
                                      &data.labels[..n], batch, &cfg)?;
            println!("secure accuracy over {n} samples: {:.2}%", acc * 100.0);
        }
        "serve" => {
            let requests = args.get_usize("requests", 32)
                .map_err(anyhow::Error::msg)?;
            let max_batch = args.get_usize("batch", 8)
                .map_err(anyhow::Error::msg)?;
            let prefetch = args.get_usize("prefetch", 2)
                .map_err(anyhow::Error::msg)?;
            let mut cfg = cfg;
            cfg.max_batch = max_batch;
            if let Some(bank) = parse_bank(&args)
                .map_err(anyhow::Error::msg)? {
                cfg.bank = Some(bank);
            }
            let svc = Service::start(Arc::clone(&model), cfg)?;
            println!("service up: model={} setup={}", svc.model_name,
                     fmt_duration(svc.setup_time));
            let coord = Coordinator::start(svc, BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(10),
                prefetch,
            });
            let mut rxs = Vec::new();
            for i in 0..requests {
                rxs.push((i, coord.submit(
                    data.images[i % data.images.len()].clone())));
            }
            let mut correct = 0;
            for (i, rx) in rxs {
                let resp = rx.recv().context("response")?;
                if resp.pred == data.labels[i % data.labels.len()] as usize {
                    correct += 1;
                }
            }
            let pm = coord.preproc_metrics();
            let (hist, thr) = coord.finish();
            println!("served {} requests: {:.1} req/s", thr.requests,
                     thr.per_sec());
            println!("offline bank: minted={} drawn={} request-path \
                      fallbacks={} ({} elems)",
                     pm.minted, pm.drawn, pm.underflow_calls,
                     pm.fallback_elems);
            println!("latency mean={} p50={} p99={} max={}",
                     fmt_duration(hist.mean()),
                     fmt_duration(hist.quantile(0.5)),
                     fmt_duration(hist.quantile(0.99)),
                     fmt_duration(hist.max()));
            println!("accuracy on served stream: {:.1}%",
                     100.0 * f64::from(correct) / requests as f64);
        }
        other => return Err(anyhow!("unknown subcommand '{other}'\n{}",
                                    usage())),
    }
    Ok(())
}
