//! cbnn -- leader CLI for the three-party secure BNN inference framework.
//!
//! Subcommands:
//!   infer  -- one batched secure inference, print predictions + cost
//!   serve  -- start the serving stack behind the async request plane
//!             (dynamic batching + admission control + sharding),
//!             replay a synthetic multi-tenant request stream, print
//!             latency/throughput and shed/fairness counters.
//!             Repeated `--model` flags serve every model from one
//!             process's links; `--shards N` spreads each model over
//!             N registry slots behind a consistent-hash router (see
//!             OPERATIONS.md §7)
//!   acc    -- secure accuracy over the exported eval set
//!   info   -- describe a model manifest
//!   trace  -- merge an exported trace directory (three parties'
//!             JSONL + stats sidecars) into one timeline and check
//!             the cross-party invariants (see OPERATIONS.md §3)
//!
//! Common flags: --model NAME | --model NAME=MANIFEST (repeatable)
//!               --artifacts DIR
//!               --net lan|wan|zero|rtt=40ms,bw=40MBps,jitter=1ms[,virtual]
//!               --backend native|pjrt-pallas|pjrt-xla --batch N
//!               --trace-out DIR --metrics-out PATH (telemetry export)

use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use cbnn::cli::{parse_backend, parse_bank, parse_models, parse_net,
                parse_on_off, Args, SERVE_FLAGS};
use cbnn::coordinator::{BatcherPolicy, ModelRegistry, ModelSpec,
                        PlaneConfig, RegistryError, RequestPlane};
use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, secure_accuracy, SessionConfig};
use cbnn::metrics::{fmt_duration, prometheus_text, MetricsSnapshot,
                    ModelRollup};
use cbnn::nn::Model;
use cbnn::ring::Tensor;
use cbnn::trace::{self, merge, SpanKind};

/// Usage text.  The serve flag list renders from `cli::SERVE_FLAGS`
/// (the same list the OPERATIONS.md CI gate checks), so the help
/// cannot drift from the documented flag surface.
fn usage() -> String {
    let serve: Vec<String> =
        SERVE_FLAGS.iter().map(|f| format!("[--{f} ..]")).collect();
    format!(
        "usage: cbnn <infer|serve|acc|info> --model <name|name=manifest>\n\
         \x20      cbnn trace <DIR>  (merge an exported trace)\n\
         serve flags (--model repeatable): {}\n\
         values: --net lan|wan|zero|rtt=40ms,bw=40MBps,jitter=1ms\
         [,virtual], --backend \
         native|pjrt-pallas|pjrt-xla, --fuse on|off (binary-domain \
         layer fusion), --max-infer-errors N (0 disables the \
         auto-quarantine watchdog), --slo-ms N (dispatch-window \
         latency SLO), --shards N (slots per model behind the \
         consistent-hash router), --max-queue N (admission cap; \
         above it requests shed typed), --tenants N (synthetic \
         tenant streams), --adaptive-bank on|off (size bank \
         watermarks from observed dispatch demand), --trace-out DIR \
         (per-party span JSONL + stats sidecars), --metrics-out PATH \
         (Prometheus text); see OPERATIONS.md",
        serve.join(" "))
}

fn load_model(name: &str, path: &Path) -> Result<Arc<Model>> {
    Ok(Arc::new(Model::load(path)
        .with_context(|| format!("loading model '{name}' from {}",
                                 path.display()))?))
}

fn load_data(art: &Path, model: &Model) -> Result<EvalSet> {
    EvalSet::load(&art.join("data").join(format!("{}.bin", model.dataset)))
        .context("eval data (run `make artifacts`)")
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!("{e}\n{}", usage()))?;
    let sub = args.subcommand.clone()
        .ok_or_else(|| anyhow!("missing subcommand\n{}", usage()))?;

    let art = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let specs = parse_models(&args, &art, "mnistnet1")
        .map_err(anyhow::Error::msg)?;

    let mut cfg = SessionConfig::new(art.join("hlo"))
        .with_net(parse_net(args.get_or("net", "lan"))
                  .map_err(anyhow::Error::msg)?)
        .with_backend(parse_backend(args.get_or("backend", "pjrt-pallas"))
                      .map_err(anyhow::Error::msg)?);
    cfg.max_parked_bytes = args
        .get_usize("max-parked-bytes", cfg.max_parked_bytes)
        .map_err(anyhow::Error::msg)?;
    cfg.opts.fuse = parse_on_off(&args, "fuse", false)
        .map_err(anyhow::Error::msg)?;
    cfg.max_consecutive_errors = args
        .get_usize("max-infer-errors", cfg.max_consecutive_errors as usize)
        .map_err(anyhow::Error::msg)? as u32;
    // tracing is enabled from link birth whenever an export dir is
    // given, so flight bytes reconcile exactly against the link stats
    cfg.trace = args.get("trace-out").is_some();

    // info/infer/acc are single-model commands: last --model wins
    let (name, path) = specs.last().expect("parse_models is non-empty");

    match sub.as_str() {
        "info" => {
            let model = load_model(name, path)?;
            println!("model      : {}", model.name);
            println!("dataset    : {}", model.dataset);
            println!("input CHW  : {:?}", model.input);
            println!("layers     : {}", model.ops.len());
            println!("parameters : {}", model.param_count());
            for (i, op) in model.ops.iter().enumerate() {
                println!("  [{i:>2}] {op:?}");
            }
        }
        "infer" => {
            let model = load_model(name, path)?;
            let data = load_data(&art, &model)?;
            let batch = args.get_usize("batch", 4)
                .map_err(anyhow::Error::msg)?;
            let inputs = data.images[..batch.min(data.images.len())].to_vec();
            let rep = run_inference(&model, inputs, &cfg)?;
            println!("model={} batch={} net={} fuse={}", model.name,
                     batch, args.get_or("net", "lan"),
                     if cfg.opts.fuse { "on" } else { "off" });
            println!("setup  : {}", fmt_duration(rep.setup));
            println!("online : {}  ({} per sample)",
                     fmt_duration(rep.online),
                     fmt_duration(rep.online / batch as u32));
            println!("comm   : {:.3} MB, {} rounds (max over parties)",
                     rep.comm_mb(), rep.max_rounds());
            println!("per-op wire cost (party 0):");
            print!("{}", cbnn::metrics::op_cost_table(&rep.op_costs));
            for (i, (p, l)) in rep.preds.iter()
                .zip(&data.labels).enumerate() {
                println!("  sample {i}: pred={p} label={l}");
            }
            if let Some(dir) = args.get("trace-out") {
                let dir = Path::new(dir);
                for (party, spans) in rep.traces.iter().enumerate() {
                    trace::write_trace(dir, party, spans,
                                       &rep.stats[party], 0)
                        .with_context(|| format!("trace export to {}",
                                                 dir.display()))?;
                }
                println!("trace  : {} spans/party -> {} \
                          (merge: cbnn trace {})",
                         rep.traces.first().map_or(0, Vec::len),
                         dir.display(), dir.display());
            }
        }
        "acc" => {
            let model = load_model(name, path)?;
            let data = load_data(&art, &model)?;
            let n = args.get_usize("n", 64).map_err(anyhow::Error::msg)?;
            let batch = args.get_usize("batch", 8)
                .map_err(anyhow::Error::msg)?;
            let n = n.min(data.images.len());
            let acc = secure_accuracy(&model, &data.images[..n],
                                      &data.labels[..n], batch, &cfg)?;
            println!("secure accuracy over {n} samples: {:.2}%", acc * 100.0);
        }
        "serve" => serve_plane(&args, &art, cfg, &specs)?,
        "trace" => {
            let dir = args.positional.first()
                .ok_or_else(|| anyhow!("usage: cbnn trace <DIR>"))?;
            trace_report(Path::new(dir))?;
        }
        other => return Err(anyhow!("unknown subcommand '{other}'\n{}",
                                    usage())),
    }
    Ok(())
}

/// `cbnn trace <DIR>`: load the three parties' exported JSONL traces
/// and stats sidecars, join them into one timeline, print it, and
/// fail (exit non-zero) on any cross-party disagreement -- the
/// desync-debugging front door (OPERATIONS.md §3 runbook).
fn trace_report(dir: &Path) -> Result<()> {
    let mut parties = Vec::with_capacity(3);
    let mut sidecars = Vec::with_capacity(3);
    for p in 0..3 {
        let tp = trace::trace_path(dir, p);
        let text = std::fs::read_to_string(&tp)
            .with_context(|| format!("reading {}", tp.display()))?;
        parties.push(trace::parse_jsonl(&text)
            .map_err(|e| anyhow!("{}: {e}", tp.display()))?);
        let sp = trace::stats_path(dir, p);
        let text = std::fs::read_to_string(&sp)
            .with_context(|| format!("reading {}", sp.display()))?;
        sidecars.push(trace::parse_stats(&text)
            .map_err(|e| anyhow!("{}: {e}", sp.display()))?);
    }
    let report = merge::merge_check(&parties);
    println!("merged {} parties: {} trace(s), {} lock-step spans \
              joined", parties.len(), report.traces.len(),
             report.joined);
    for &id in &report.traces {
        for s in parties[0].iter()
            .filter(|s| s.trace_id == id && s.kind == SpanKind::Request) {
            println!("trace {id}: request '{}' -- {} rounds, {} B \
                      sent (party 0), {} us wall",
                     s.label, s.rounds, s.bytes_sent,
                     s.wall_end_us - s.wall_start_us);
        }
        for s in parties[0].iter()
            .filter(|s| s.trace_id == id && s.kind == SpanKind::Op) {
            println!("  [{:>2}] {:<24} {:>3} rounds {:>10} B {:>8} us",
                     s.index, s.label.as_str(), s.rounds, s.bytes_sent,
                     s.wall_end_us - s.wall_start_us);
        }
    }
    let mut problems = report.problems;
    for (p, side) in sidecars.iter().enumerate() {
        if side.dropped_events > 0 {
            println!("party {p}: {} spans dropped (sink full) -- \
                      flight-byte reconciliation skipped",
                     side.dropped_events);
            continue;
        }
        problems.extend(merge::check_flight_rows(p, &parties[p],
                                                 &side.chan_bytes));
    }
    if !problems.is_empty() {
        for pr in &problems {
            eprintln!("problem: {pr}");
        }
        return Err(anyhow!("{} cross-party trace problem(s)",
                           problems.len()));
    }
    println!("cross-party invariants hold: rounds agree on every \
              joined span, flight bytes reconcile with link stats");
    Ok(())
}

/// The serve subcommand: every `--model` (times `--shards`) behind the
/// async request plane.  A synthetic multi-tenant request stream
/// (`--tenants` concurrent submitters per model) drives the plane;
/// admission sheds are counted, not fatal -- exactly how a production
/// front should treat `Overloaded`.
fn serve_plane(args: &Args, art: &Path, cfg: SessionConfig,
               specs: &[(String, PathBuf)]) -> Result<()> {
    let requests = args.get_usize("requests", 32)
        .map_err(anyhow::Error::msg)?;
    // clamp like SessionConfig's own max_batch.max(1): --batch 0 would
    // otherwise dispatch empty windows forever
    let batch = args.get_usize("batch", 8)
        .map_err(anyhow::Error::msg)?.max(1);
    let prefetch = args.get_usize("prefetch", 2)
        .map_err(anyhow::Error::msg)?;
    let slo_ms = args.get_usize("slo-ms", 10)
        .map_err(anyhow::Error::msg)?;
    let shards = args.get_usize("shards", 1)
        .map_err(anyhow::Error::msg)?.clamp(1, 16) as u8;
    let tenants = args.get_usize("tenants", 2)
        .map_err(anyhow::Error::msg)?.max(1);
    let max_queue = args
        .get_usize("max-queue", 8 * batch * shards as usize)
        .map_err(anyhow::Error::msg)?.max(1);
    let adaptive = parse_on_off(args, "adaptive-bank", false)
        .map_err(anyhow::Error::msg)?;
    let mut cfg = cfg;
    cfg.max_batch = batch;
    if let Some(bank) = parse_bank(args).map_err(anyhow::Error::msg)? {
        // one explicit bank config applies to every slot; omit the
        // --bank-* flags to auto-scale each bank to its model's demand
        cfg.bank = Some(bank);
    }
    let mut reg_specs = Vec::with_capacity(specs.len());
    let mut data = Vec::with_capacity(specs.len());
    for (name, path) in specs {
        let model = load_model(name, path)?;
        data.push(load_data(art, &model)?);
        reg_specs.push(ModelSpec::new(name.clone(), model));
    }
    let plane_cfg = PlaneConfig {
        policy: BatcherPolicy {
            max_batch: batch,
            slo: Duration::from_millis(slo_ms as u64),
            max_queue,
            prefetch,
            adaptive,
        },
        shards,
    };
    let t0 = Instant::now();
    let plane = RequestPlane::start(reg_specs, &cfg, plane_cfg)
        .map_err(|e| anyhow!("{e}"))?;
    println!("request plane up: {} model(s) x {} shard(s) over one link \
              trio, slo={}ms queue<={} tenants={} adaptive-bank={}, \
              setup={}",
             specs.len(), shards, slo_ms, max_queue, tenants,
             if adaptive { "on" } else { "off" },
             fmt_duration(t0.elapsed()));

    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    let t1 = Instant::now();
    // per model: `tenants` concurrent submitter threads, interleaved
    // request indices -- the concurrency the batcher coalesces
    let mut per_model = Vec::with_capacity(specs.len());
    for (m, (name, _)) in specs.iter().enumerate() {
        let ds = &data[m];
        let outcome = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..tenants {
                let share = requests / tenants
                    + usize::from(t < requests % tenants);
                let plane = &plane;
                let tenant = format!("tenant-{t}");
                handles.push(s.spawn(move || {
                    let mut rxs = Vec::with_capacity(share);
                    let mut shed = 0u64;
                    for j in 0..share {
                        let k = t + j * tenants;
                        let img =
                            ds.images[k % ds.images.len()].clone();
                        match plane.submit(name, &tenant, img) {
                            Ok(rx) => rxs.push((k, rx)),
                            Err(RegistryError::Overloaded {
                                model, reason }) => {
                                shed += 1;
                                eprintln!("shed ({model}): {reason}");
                            }
                            Err(e) => {
                                shed += 1;
                                eprintln!("submit failed: {e}");
                            }
                        }
                    }
                    let (mut served, mut correct) = (0u64, 0u64);
                    for (k, rx) in rxs {
                        match rx.recv() {
                            Ok(Ok(resp)) => {
                                served += 1;
                                let want =
                                    ds.labels[k % ds.labels.len()];
                                if resp.pred == want as usize {
                                    correct += 1;
                                }
                            }
                            Ok(Err(e)) =>
                                eprintln!("request failed: {e}"),
                            Err(_) =>
                                eprintln!("batcher dropped a waiter"),
                        }
                    }
                    (served, shed, correct)
                }));
            }
            handles.into_iter()
                .map(|h| h.join().expect("submitter thread"))
                .fold((0u64, 0u64, 0u64),
                      |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2))
        });
        per_model.push(outcome);
        if let Some(path) = &metrics_out {
            write_plane_metrics(&plane, path)?;
        }
    }
    let wall = t1.elapsed();
    let total_served: u64 = per_model.iter().map(|o| o.0).sum();
    let total_shed: u64 = per_model.iter().map(|o| o.1).sum();
    println!("served {total_served} / {} submitted ({total_shed} shed) \
              across {} model(s) in {} ({:.1} req/s)",
             requests * specs.len(), specs.len(), fmt_duration(wall),
             total_served as f64 / wall.as_secs_f64().max(1e-9));
    for (m, (name, _)) in specs.iter().enumerate() {
        let (served, shed, correct) = per_model[m];
        println!("model {name}: served={served} shed={shed} acc={:.1}%",
                 100.0 * correct as f64 / served.max(1) as f64);
        for slot in plane.shard_slots(name) {
            let Some(b) = plane.batcher(&slot) else { continue };
            let s = b.stats();
            let pm = b.preproc_metrics();
            println!("  shard {slot}: {} windows, {} served, max \
                      coalesce {}, shed queue={} dry={} | bank \
                      minted={} drawn={} fallbacks={}",
                     s.plane.dispatches, s.plane.served,
                     s.plane.coalesced_max, s.plane.shed_queue,
                     s.plane.shed_dry, pm.minted, pm.drawn,
                     pm.underflow_calls);
            for tc in &s.tenants {
                println!("    tenant {}: submitted={} served={} \
                          shed={} last-window={}",
                         tc.tenant, tc.submitted, tc.served, tc.shed,
                         tc.last_window);
            }
        }
    }
    let hist = plane.latency();
    println!("latency (enqueue->response) mean={} p50={} p99={} max={}",
             fmt_duration(hist.mean()),
             fmt_duration(hist.quantile(0.5)),
             fmt_duration(hist.quantile(0.99)),
             fmt_duration(hist.max()));
    let link = plane.registry().link_stats(0);
    println!("link totals (party 0): {} B, {} messages, {} rounds",
             link.bytes_sent, link.messages, link.rounds);
    if args.get_bool("admin") {
        admin_repl(plane.registry(), art,
                   &mut data_by_name(specs, data))?;
    }
    if let Some(path) = &metrics_out {
        write_plane_metrics(&plane, path)?;
        println!("metrics written -> {}", path.display());
    }
    // export traces only after shutdown: the last slot's exit stats are
    // the fully-quiesced link totals, so flight bytes reconcile exactly
    // (a live export could race a background bank refill)
    let trace_sinks: Option<Vec<_>> = args.get("trace-out")
        .map(|_| (0..3).map(|p| plane.registry().trace_sink(p))
            .collect());
    let per_slot = plane.shutdown().map_err(|e| anyhow!("{e}"))?;
    if let (Some(dir), Some(sinks)) =
        (args.get("trace-out"), trace_sinks) {
        let dir = Path::new(dir);
        let stats = per_slot.last()
            .map(|(_, s)| s.clone()).unwrap_or_default();
        for (party, sink) in sinks.iter().enumerate() {
            trace::write_trace(dir, party, &sink.snapshot(),
                               &stats[party], sink.dropped_events())
                .with_context(|| format!("trace export to {}",
                                         dir.display()))?;
        }
        println!("trace exported -> {} (merge: cbnn trace {})",
                 dir.display(), dir.display());
    }
    Ok(())
}

/// Assemble and atomically rewrite the plane's `--metrics-out`
/// snapshot (Prometheus text exposition; the metric names -- including
/// the queue/shed/tenant families -- are part of the operational
/// contract, documented in OPERATIONS.md §3 and §7).
fn write_plane_metrics(plane: &RequestPlane, path: &Path) -> Result<()> {
    let reg = plane.registry();
    let mut bank_levels = Vec::new();
    for name in reg.names() {
        // quarantined/parked slots drop out of the snapshot until they
        // serve again
        if let Ok(svc) = reg.service(&name) {
            bank_levels.push((name.clone(),
                              svc.bank_handle(0).level() as u64));
        }
    }
    let latency = plane.latency();
    let snap = MetricsSnapshot {
        requests: plane.requests_served(),
        latency,
        models: plane.rollups(),
        bank_levels,
        trace_dropped: (0..3)
            .map(|p| reg.trace_sink(p).dropped_events()).collect(),
    };
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, prometheus_text(&snap))
        .and_then(|()| std::fs::rename(&tmp, path))
        .with_context(|| format!("writing {}", path.display()))
}

fn data_by_name(specs: &[(String, PathBuf)], data: Vec<EvalSet>)
                -> BTreeMap<String, EvalSet> {
    specs.iter().map(|(n, _)| n.clone()).zip(data).collect()
}

/// Stdin admin loop for the live-registry demo (`serve --model a
/// --model b --admin`): hot-swap, quarantine, and respawn models while
/// the registry serves.  See OPERATIONS.md §Lifecycle runbook.
fn admin_repl(reg: &ModelRegistry, art: &Path,
              data: &mut BTreeMap<String, EvalSet>) -> Result<()> {
    println!("admin> commands: status | stats | trace on|off | \
              add NAME[=MANIFEST] | remove NAME | quarantine NAME | \
              respawn NAME | infer NAME [N] | quit");
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let mut it = line.split_whitespace();
        let Some(cmd) = it.next() else { continue };
        let arg = it.next().unwrap_or("");
        let res: Result<()> = match cmd {
            "quit" | "exit" => break,
            "status" => {
                let rollups: BTreeMap<u8, ModelRollup> = reg.rollups()
                    .into_iter().map(|r| (r.slot, r)).collect();
                for (name, slot, state, epoch) in reg.status() {
                    println!("  {name} (slot {slot}): {state}, \
                              epoch {epoch}");
                    if let Some(r) = rollups.get(&slot) {
                        println!("    online {} B / {} rounds / {} \
                                  msgs, offline {} B | bank minted={} \
                                  drawn={} fallbacks={}",
                                 r.online.bytes_sent, r.online.rounds,
                                 r.online.messages,
                                 r.offline.bytes_sent,
                                 r.preproc.minted, r.preproc.drawn,
                                 r.preproc.underflow_calls);
                    }
                }
                for (slot, lc) in reg.lifecycle_counters() {
                    println!("  slot {slot} lifecycle: quarantines={} \
                              respawns={} swaps_in={} swaps_out={} \
                              watchdog_trips={}",
                             lc.quarantines, lc.respawns, lc.swaps_in,
                             lc.swaps_out, lc.watchdog_trips);
                }
                Ok(())
            }
            "stats" => admin_stats(reg),
            "trace" => match arg {
                "on" => {
                    reg.set_tracing(true);
                    println!("  tracing on (mid-run: partial trace; \
                              flight bytes will not reconcile against \
                              lifetime link stats)");
                    Ok(())
                }
                "off" => {
                    reg.set_tracing(false);
                    println!("  tracing off");
                    Ok(())
                }
                "" => {
                    println!("  tracing is {}",
                             if reg.tracing() { "on" } else { "off" });
                    Ok(())
                }
                other => Err(anyhow!("trace on|off, got '{other}'")),
            },
            "add" => admin_add(reg, art, data, arg),
            "remove" => reg.remove_model(arg).map_err(|e| anyhow!("{e}"))
                .map(|()| println!("  removed {arg} (slot freed)")),
            "quarantine" => reg.quarantine(arg)
                .map_err(|e| anyhow!("{e}"))
                .map(|()| println!("  {arg} quarantined")),
            "respawn" => reg.respawn(arg).map_err(|e| anyhow!("{e}"))
                .map(|()| println!("  {arg} respawned on a fresh epoch")),
            "infer" => admin_infer(reg, data, arg,
                                   it.next().unwrap_or("1")),
            other => Err(anyhow!("unknown admin command '{other}'")),
        };
        if let Err(e) = res {
            println!("  error: {e}");
        }
    }
    Ok(())
}

/// `admin> stats`: per-model rollup rows plus each serving model's
/// request-latency quantiles and the per-party trace-sink state.
fn admin_stats(reg: &ModelRegistry) -> Result<()> {
    for r in reg.rollups() {
        println!("  {} (slot {}): online {} B / {} rounds / {} msgs, \
                  offline {} B | bank minted={} drawn={} fallbacks={}",
                 r.name, r.slot, r.online.bytes_sent, r.online.rounds,
                 r.online.messages, r.offline.bytes_sent,
                 r.preproc.minted, r.preproc.drawn,
                 r.preproc.underflow_calls);
        if let Ok(svc) = reg.service(&r.name) {
            let h = svc.latency();
            println!("    latency: n={} mean={} p50={} p90={} p99={} \
                      max={}",
                     h.count(), fmt_duration(h.mean()),
                     fmt_duration(h.quantile(0.5)),
                     fmt_duration(h.quantile(0.9)),
                     fmt_duration(h.quantile(0.99)),
                     fmt_duration(h.max()));
        }
    }
    for party in 0..3 {
        let sink = reg.trace_sink(party);
        println!("  trace p{party}: {} span(s), {} dropped, {}",
                 sink.len(), sink.dropped_events(),
                 if sink.enabled() { "recording" } else { "off" });
    }
    Ok(())
}

/// `admin> add NAME[=MANIFEST]`: load the model (and its eval set) and
/// hot-add it to the live registry.
fn admin_add(reg: &ModelRegistry, art: &Path,
             data: &mut BTreeMap<String, EvalSet>, arg: &str)
             -> Result<()> {
    let (name, path) = match arg.split_once('=') {
        Some((n, p)) => (n.to_string(), PathBuf::from(p)),
        None => (arg.to_string(),
                 art.join("models").join(format!("{arg}.manifest.json"))),
    };
    if name.is_empty() {
        return Err(anyhow!("usage: add NAME[=MANIFEST]"));
    }
    let model = load_model(&name, &path)?;
    let ds = load_data(art, &model)?;
    let slot = reg.add_model(ModelSpec::new(name.clone(), model))
        .map_err(|e| anyhow!("{e}"))?;
    data.insert(name.clone(), ds);
    println!("  added {name} at slot {slot}");
    Ok(())
}

/// `admin> infer NAME [N]`: drive N requests at a model from its eval
/// set (demo traffic).
fn admin_infer(reg: &ModelRegistry, data: &BTreeMap<String, EvalSet>,
               name: &str, count: &str) -> Result<()> {
    let n: usize = count.parse()
        .map_err(|_| anyhow!("infer NAME [N]: bad count '{count}'"))?;
    let ds = data.get(name)
        .ok_or_else(|| anyhow!("no eval data loaded for '{name}'"))?;
    let imgs: Vec<Tensor> = (0..n.max(1))
        .map(|j| ds.images[j % ds.images.len()].clone())
        .collect();
    let logits = reg.infer(name, imgs).map_err(|e| anyhow!("{e}"))?;
    let preds: Vec<usize> =
        logits.iter().map(|l| cbnn::engine::argmax(l)).collect();
    println!("  {name}: preds {preds:?}");
    Ok(())
}
