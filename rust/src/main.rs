//! cbnn -- leader CLI for the three-party secure BNN inference framework.
//!
//! Subcommands:
//!   infer  -- one batched secure inference, print predictions + cost
//!   serve  -- start the serving stack, replay a synthetic request
//!             stream, print latency/throughput.  One `--model` serves
//!             through the dynamic-batching Coordinator; repeated
//!             `--model` flags serve every model from one process's
//!             links via the ModelRegistry (see OPERATIONS.md)
//!   acc    -- secure accuracy over the exported eval set
//!   info   -- describe a model manifest
//!
//! Common flags: --model NAME | --model NAME=MANIFEST (repeatable)
//!               --artifacts DIR
//!               --net lan|wan|zero|rtt=40ms,bw=40MBps,jitter=1ms[,virtual]
//!               --backend native|pjrt-pallas|pjrt-xla --batch N

use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use cbnn::cli::{parse_backend, parse_bank, parse_models, parse_net,
                parse_on_off, Args, SERVE_FLAGS};
use cbnn::coordinator::{BatchPolicy, Coordinator, ModelRegistry, ModelSpec,
                        Service};
use cbnn::datasets::EvalSet;
use cbnn::engine::session::{run_inference, secure_accuracy, SessionConfig};
use cbnn::metrics::fmt_duration;
use cbnn::nn::Model;
use cbnn::ring::Tensor;

/// Usage text.  The serve flag list renders from `cli::SERVE_FLAGS`
/// (the same list the OPERATIONS.md CI gate checks), so the help
/// cannot drift from the documented flag surface.
fn usage() -> String {
    let serve: Vec<String> =
        SERVE_FLAGS.iter().map(|f| format!("[--{f} ..]")).collect();
    format!(
        "usage: cbnn <infer|serve|acc|info> --model <name|name=manifest>\n\
         serve flags (--model repeatable): {}\n\
         values: --net lan|wan|zero|rtt=40ms,bw=40MBps,jitter=1ms\
         [,virtual], --backend \
         native|pjrt-pallas|pjrt-xla, --fuse on|off (binary-domain \
         layer fusion), --max-infer-errors N (0 disables the \
         auto-quarantine watchdog); see OPERATIONS.md",
        serve.join(" "))
}

fn load_model(name: &str, path: &Path) -> Result<Arc<Model>> {
    Ok(Arc::new(Model::load(path)
        .with_context(|| format!("loading model '{name}' from {}",
                                 path.display()))?))
}

fn load_data(art: &Path, model: &Model) -> Result<EvalSet> {
    EvalSet::load(&art.join("data").join(format!("{}.bin", model.dataset)))
        .context("eval data (run `make artifacts`)")
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!("{e}\n{}", usage()))?;
    let sub = args.subcommand.clone()
        .ok_or_else(|| anyhow!("missing subcommand\n{}", usage()))?;

    let art = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let specs = parse_models(&args, &art, "mnistnet1")
        .map_err(anyhow::Error::msg)?;

    let mut cfg = SessionConfig::new(art.join("hlo"))
        .with_net(parse_net(args.get_or("net", "lan"))
                  .map_err(anyhow::Error::msg)?)
        .with_backend(parse_backend(args.get_or("backend", "pjrt-pallas"))
                      .map_err(anyhow::Error::msg)?);
    cfg.max_parked_bytes = args
        .get_usize("max-parked-bytes", cfg.max_parked_bytes)
        .map_err(anyhow::Error::msg)?;
    cfg.opts.fuse = parse_on_off(&args, "fuse", false)
        .map_err(anyhow::Error::msg)?;
    cfg.max_consecutive_errors = args
        .get_usize("max-infer-errors", cfg.max_consecutive_errors as usize)
        .map_err(anyhow::Error::msg)? as u32;

    // info/infer/acc are single-model commands: last --model wins
    let (name, path) = specs.last().expect("parse_models is non-empty");

    match sub.as_str() {
        "info" => {
            let model = load_model(name, path)?;
            println!("model      : {}", model.name);
            println!("dataset    : {}", model.dataset);
            println!("input CHW  : {:?}", model.input);
            println!("layers     : {}", model.ops.len());
            println!("parameters : {}", model.param_count());
            for (i, op) in model.ops.iter().enumerate() {
                println!("  [{i:>2}] {op:?}");
            }
        }
        "infer" => {
            let model = load_model(name, path)?;
            let data = load_data(&art, &model)?;
            let batch = args.get_usize("batch", 4)
                .map_err(anyhow::Error::msg)?;
            let inputs = data.images[..batch.min(data.images.len())].to_vec();
            let rep = run_inference(&model, inputs, &cfg)?;
            println!("model={} batch={} net={} fuse={}", model.name,
                     batch, args.get_or("net", "lan"),
                     if cfg.opts.fuse { "on" } else { "off" });
            println!("setup  : {}", fmt_duration(rep.setup));
            println!("online : {}  ({} per sample)",
                     fmt_duration(rep.online),
                     fmt_duration(rep.online / batch as u32));
            println!("comm   : {:.3} MB, {} rounds (max over parties)",
                     rep.comm_mb(), rep.max_rounds());
            println!("per-op wire cost (party 0):");
            print!("{}", cbnn::metrics::op_cost_table(&rep.op_costs));
            for (i, (p, l)) in rep.preds.iter()
                .zip(&data.labels).enumerate() {
                println!("  sample {i}: pred={p} label={l}");
            }
        }
        "acc" => {
            let model = load_model(name, path)?;
            let data = load_data(&art, &model)?;
            let n = args.get_usize("n", 64).map_err(anyhow::Error::msg)?;
            let batch = args.get_usize("batch", 8)
                .map_err(anyhow::Error::msg)?;
            let n = n.min(data.images.len());
            let acc = secure_accuracy(&model, &data.images[..n],
                                      &data.labels[..n], batch, &cfg)?;
            println!("secure accuracy over {n} samples: {:.2}%", acc * 100.0);
        }
        "serve" => {
            if specs.len() == 1 {
                serve_single(&args, &art, cfg, name, path)?;
            } else {
                serve_multi(&args, &art, cfg, &specs)?;
            }
        }
        other => return Err(anyhow!("unknown subcommand '{other}'\n{}",
                                    usage())),
    }
    Ok(())
}

/// One model behind the dynamic-batching `Coordinator` (the PR 3 path).
fn serve_single(args: &Args, art: &Path, cfg: SessionConfig,
                name: &str, path: &Path) -> Result<()> {
    let model = load_model(name, path)?;
    let data = load_data(art, &model)?;
    let requests = args.get_usize("requests", 32)
        .map_err(anyhow::Error::msg)?;
    let max_batch = args.get_usize("batch", 8)
        .map_err(anyhow::Error::msg)?;
    let prefetch = args.get_usize("prefetch", 2)
        .map_err(anyhow::Error::msg)?;
    let mut cfg = cfg;
    cfg.max_batch = max_batch;
    if let Some(bank) = parse_bank(args).map_err(anyhow::Error::msg)? {
        cfg.bank = Some(bank);
    }
    let svc = Service::start(Arc::clone(&model), cfg)?;
    println!("service up: model={} setup={}", svc.model_name,
             fmt_duration(svc.setup_time));
    let coord = Coordinator::start(svc, BatchPolicy {
        max_batch,
        max_wait: Duration::from_millis(10),
        prefetch,
    });
    let mut rxs = Vec::new();
    for i in 0..requests {
        rxs.push((i, coord.submit(
            data.images[i % data.images.len()].clone())));
    }
    let mut correct = 0;
    for (i, rx) in rxs {
        let resp = rx.recv().context("response")?;
        if resp.pred == data.labels[i % data.labels.len()] as usize {
            correct += 1;
        }
    }
    let pm = coord.preproc_metrics();
    let (hist, thr) = coord.finish();
    println!("served {} requests: {:.1} req/s", thr.requests,
             thr.per_sec());
    println!("offline bank: minted={} drawn={} request-path \
              fallbacks={} ({} elems)",
             pm.minted, pm.drawn, pm.underflow_calls,
             pm.fallback_elems);
    println!("latency mean={} p50={} p99={} max={}",
             fmt_duration(hist.mean()),
             fmt_duration(hist.quantile(0.5)),
             fmt_duration(hist.quantile(0.99)),
             fmt_duration(hist.max()));
    println!("accuracy on served stream: {:.1}%",
             100.0 * f64::from(correct) / requests as f64);
    Ok(())
}

/// Every `--model` from one process's three links via the
/// `ModelRegistry`: interleaved round-robin batches, per-model rollups.
/// (`--prefetch` drives the single-model batcher only; registry
/// services keep their own watermarks per request.)
fn serve_multi(args: &Args, art: &Path, cfg: SessionConfig,
               specs: &[(String, PathBuf)]) -> Result<()> {
    let requests = args.get_usize("requests", 32)
        .map_err(anyhow::Error::msg)?;
    // clamp like SessionConfig's own max_batch.max(1): --batch 0 would
    // otherwise loop forever submitting empty batches
    let batch = args.get_usize("batch", 8)
        .map_err(anyhow::Error::msg)?.max(1);
    let mut cfg = cfg;
    cfg.max_batch = batch;
    if let Some(bank) = parse_bank(args).map_err(anyhow::Error::msg)? {
        // one explicit bank config applies to every model; omit the
        // --bank-* flags to auto-scale each bank to its model's demand
        cfg.bank = Some(bank);
    }
    let mut reg_specs = Vec::with_capacity(specs.len());
    let mut data = Vec::with_capacity(specs.len());
    for (name, path) in specs {
        let model = load_model(name, path)?;
        data.push(load_data(art, &model)?);
        reg_specs.push(ModelSpec::new(name.clone(), model));
    }
    let t0 = Instant::now();
    let reg = ModelRegistry::start(reg_specs, &cfg)
        .map_err(|e| anyhow!("{e}"))?;
    println!("registry up: {} models over one link trio ({}), setup={}",
             specs.len(), reg.names().join(", "),
             fmt_duration(t0.elapsed()));

    let n_models = specs.len();
    let mut served = vec![0usize; n_models];
    let mut correct = vec![0usize; n_models];
    let mut remaining = requests;
    let t1 = Instant::now();
    while remaining > 0 {
        for (m, (name, _)) in specs.iter().enumerate() {
            if remaining == 0 {
                break;
            }
            let take = batch.min(remaining);
            let ds = &data[m];
            let imgs: Vec<Tensor> = (0..take).map(|j| {
                ds.images[(served[m] + j) % ds.images.len()].clone()
            }).collect();
            let logits = reg.infer(name, imgs).map_err(|e| anyhow!("{e}"))?;
            for (j, l) in logits.iter().enumerate() {
                let want = ds.labels[(served[m] + j) % ds.labels.len()];
                if cbnn::engine::argmax(l) == want as usize {
                    correct[m] += 1;
                }
            }
            served[m] += take;
            remaining -= take;
        }
    }
    let wall = t1.elapsed();
    println!("served {requests} requests across {n_models} models in {} \
              ({:.1} req/s)",
             fmt_duration(wall),
             requests as f64 / wall.as_secs_f64().max(1e-9));
    for r in reg.rollups() {
        let m = r.slot as usize;
        println!("model {} (slot {}): {} reqs, {:.1}% acc | online {} B \
                  / {} rounds, offline {} B | bank minted={} drawn={} \
                  fallbacks={}",
                 r.name, r.slot, served[m],
                 100.0 * correct[m] as f64 / served[m].max(1) as f64,
                 r.online.bytes_sent, r.online.rounds,
                 r.offline.bytes_sent,
                 r.preproc.minted, r.preproc.drawn,
                 r.preproc.underflow_calls);
    }
    let link = reg.link_stats(0);
    println!("link totals (party 0): {} B, {} messages, {} rounds",
             link.bytes_sent, link.messages, link.rounds);
    if args.get_bool("admin") {
        admin_repl(&reg, art, &mut data_by_name(specs, data))?;
    }
    reg.shutdown().map_err(|e| anyhow!("{e}"))?;
    Ok(())
}

fn data_by_name(specs: &[(String, PathBuf)], data: Vec<EvalSet>)
                -> BTreeMap<String, EvalSet> {
    specs.iter().map(|(n, _)| n.clone()).zip(data).collect()
}

/// Stdin admin loop for the live-registry demo (`serve --model a
/// --model b --admin`): hot-swap, quarantine, and respawn models while
/// the registry serves.  See OPERATIONS.md §Lifecycle runbook.
fn admin_repl(reg: &ModelRegistry, art: &Path,
              data: &mut BTreeMap<String, EvalSet>) -> Result<()> {
    println!("admin> commands: status | add NAME[=MANIFEST] | \
              remove NAME | quarantine NAME | respawn NAME | \
              infer NAME [N] | quit");
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let mut it = line.split_whitespace();
        let Some(cmd) = it.next() else { continue };
        let arg = it.next().unwrap_or("");
        let res: Result<()> = match cmd {
            "quit" | "exit" => break,
            "status" => {
                for (name, slot, state, epoch) in reg.status() {
                    println!("  {name} (slot {slot}): {state}, \
                              epoch {epoch}");
                }
                for (slot, lc) in reg.lifecycle_counters() {
                    println!("  slot {slot} lifecycle: quarantines={} \
                              respawns={} swaps_in={} swaps_out={} \
                              watchdog_trips={}",
                             lc.quarantines, lc.respawns, lc.swaps_in,
                             lc.swaps_out, lc.watchdog_trips);
                }
                Ok(())
            }
            "add" => admin_add(reg, art, data, arg),
            "remove" => reg.remove_model(arg).map_err(|e| anyhow!("{e}"))
                .map(|()| println!("  removed {arg} (slot freed)")),
            "quarantine" => reg.quarantine(arg)
                .map_err(|e| anyhow!("{e}"))
                .map(|()| println!("  {arg} quarantined")),
            "respawn" => reg.respawn(arg).map_err(|e| anyhow!("{e}"))
                .map(|()| println!("  {arg} respawned on a fresh epoch")),
            "infer" => admin_infer(reg, data, arg,
                                   it.next().unwrap_or("1")),
            other => Err(anyhow!("unknown admin command '{other}'")),
        };
        if let Err(e) = res {
            println!("  error: {e}");
        }
    }
    Ok(())
}

/// `admin> add NAME[=MANIFEST]`: load the model (and its eval set) and
/// hot-add it to the live registry.
fn admin_add(reg: &ModelRegistry, art: &Path,
             data: &mut BTreeMap<String, EvalSet>, arg: &str)
             -> Result<()> {
    let (name, path) = match arg.split_once('=') {
        Some((n, p)) => (n.to_string(), PathBuf::from(p)),
        None => (arg.to_string(),
                 art.join("models").join(format!("{arg}.manifest.json"))),
    };
    if name.is_empty() {
        return Err(anyhow!("usage: add NAME[=MANIFEST]"));
    }
    let model = load_model(&name, &path)?;
    let ds = load_data(art, &model)?;
    let slot = reg.add_model(ModelSpec::new(name.clone(), model))
        .map_err(|e| anyhow!("{e}"))?;
    data.insert(name.clone(), ds);
    println!("  added {name} at slot {slot}");
    Ok(())
}

/// `admin> infer NAME [N]`: drive N requests at a model from its eval
/// set (demo traffic).
fn admin_infer(reg: &ModelRegistry, data: &BTreeMap<String, EvalSet>,
               name: &str, count: &str) -> Result<()> {
    let n: usize = count.parse()
        .map_err(|_| anyhow!("infer NAME [N]: bad count '{count}'"))?;
    let ds = data.get(name)
        .ok_or_else(|| anyhow!("no eval data loaded for '{name}'"))?;
    let imgs: Vec<Tensor> = (0..n.max(1))
        .map(|j| ds.images[j % ds.images.len()].clone())
        .collect();
    let logits = reg.infer(name, imgs).map_err(|e| anyhow!("{e}"))?;
    let preds: Vec<usize> =
        logits.iter().map(|l| cbnn::engine::argmax(l)).collect();
    println!("  {name}: preds {preds:?}");
    Ok(())
}
