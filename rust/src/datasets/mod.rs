//! Evaluation data loading (the fixed-point eval sets exported by
//! python/compile/export.py) plus a native synthetic generator for tests
//! that must run without artifacts.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ring::Tensor;

/// Fixed-point eval set: images as flat (C*H*W) ring tensors + labels.
pub struct EvalSet {
    pub images: Vec<Tensor>,
    pub labels: Vec<i32>,
    pub dims: (usize, usize, usize),
}

impl EvalSet {
    /// Load `artifacts/data/<name>.bin` (header [n,c,h,w] i32 LE, then
    /// n*c*h*w image elements, then n labels).
    pub fn load(path: &Path) -> Result<EvalSet> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if raw.len() % 4 != 0 || raw.len() < 16 {
            bail!("malformed eval data");
        }
        let ints: Vec<i32> = raw.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect();
        if ints[..4].iter().any(|&v| v < 0) {
            bail!("negative eval data header {:?}", &ints[..4]);
        }
        let (n, c, h, w) = (ints[0] as usize, ints[1] as usize,
                            ints[2] as usize, ints[3] as usize);
        // checked: a lying header must error, not wrap and mis-slice
        let per = c.checked_mul(h).and_then(|v| v.checked_mul(w))
            .ok_or_else(|| anyhow::anyhow!("eval data header overflow"))?;
        let want = n.checked_mul(per)
            .and_then(|v| v.checked_add(n))
            .and_then(|v| v.checked_add(4))
            .ok_or_else(|| anyhow::anyhow!("eval data header overflow"))?;
        if ints.len() != want {
            bail!("eval data length mismatch: {} vs {}", ints.len(), want);
        }
        let images = (0..n).map(|i| {
            Tensor::from_vec(&[per], ints[4 + i * per..4 + (i + 1) * per]
                             .to_vec())
        }).collect();
        let labels = ints[4 + n * per..].to_vec();
        Ok(EvalSet { images, labels, dims: (c, h, w) })
    }
}

/// Deterministic synthetic ring images for tests: class-conditional
/// patterns (a coarse native mirror of python datasets.py -- NOT
/// bit-identical; the real eval data comes from the artifacts).
pub fn synthetic(n: usize, dims: (usize, usize, usize), s_in: u32,
                 seed: u64) -> EvalSet {
    let (c, h, w) = dims;
    let mut rng = crate::testutil::Rng::new(seed);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let unit = (1i64 << s_in) as f64;
    for _ in 0..n {
        let cls = (rng.next_u64() % 10) as i32;
        let phase = (rng.next_u64() % 628) as f64 / 100.0;
        let mut data = Vec::with_capacity(c * h * w);
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let u = (x as f64 / w as f64 - 0.5)
                        * (cls as f64 / 10.0 * std::f64::consts::PI).cos()
                        + (y as f64 / h as f64 - 0.5)
                        * (cls as f64 / 10.0 * std::f64::consts::PI).sin();
                    let v = 0.5 + 0.5 * (2.0 * std::f64::consts::PI
                                         * (3.0 + (cls % 5) as f64) * u
                                         + phase + ci as f64).sin();
                    data.push((v.clamp(0.0, 1.0) * unit) as i32);
                }
            }
        }
        images.push(Tensor::from_vec(&[c * h * w], data));
        labels.push(cls);
    }
    EvalSet { images, labels, dims }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes_and_range() {
        let s = synthetic(8, (1, 8, 8), 7, 3);
        assert_eq!(s.images.len(), 8);
        assert_eq!(s.images[0].len(), 64);
        assert!(s.images.iter().flat_map(|t| &t.data)
                .all(|&v| (0..=128).contains(&v)));
        assert!(s.labels.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = synthetic(4, (3, 6, 6), 7, 9);
        let b = synthetic(4, (3, 6, 6), 7, 9);
        assert_eq!(a.images[2], b.images[2]);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn rejects_malformed_file() {
        let dir = std::env::temp_dir().join("cbnn_ds_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, [1, 2, 3]).unwrap();
        assert!(EvalSet::load(&p).is_err());
    }
}
