//! Counter-mode PRF (ChaCha20) and the paper's correlated randomness.
//!
//! Section 3.2: each party P_i shares a seed k_i with P_{i+1}, so P_i
//! holds (k_i, k_{i+1}).  From these it derives
//!
//! * 3-out-of-3 randomness: a_i = F(k_{i+1}, cnt) - F(k_i, cnt), which
//!   sums to 0 across parties (additive sharing of zero), and
//! * 2-out-of-3 randomness: (a_i, a_{i+1}) = (F(k_i, cnt), F(k_{i+1}, cnt)),
//!   a valid RSS sharing of the random a = a_0 + a_1 + a_2.
//!
//! No cryptographic crates are vendored, so ChaCha20 (RFC 8439) is
//! implemented here and validated against the RFC test vector.
//!
//! Boolean randomness is emitted *word-packed* (`ring::bits::BitTensor`):
//! one u64 of keystream yields 64 shared bits, instead of the seed's one
//! u32 draw per bit.  All parties derive words identically (little-endian
//! u64s from consecutive u32 draws, pinned by a test in ring::bits), so the
//! replication invariants are unchanged.

use crate::ring::bits::BitTensor;

/// ChaCha20 block function keyed with a 32-byte key.
#[derive(Clone)]
pub struct ChaCha20 {
    key: [u32; 8],
}

#[inline(always)]
fn quarter(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

impl ChaCha20 {
    pub fn new(key: &[u8; 32]) -> Self {
        let mut k = [0u32; 8];
        for (i, w) in k.iter_mut().enumerate() {
            *w = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
        }
        ChaCha20 { key: k }
    }

    /// Derive a key from a u64 seed (test/deployment convenience).
    pub fn from_seed(seed: u64) -> Self {
        let mut key = [0u8; 32];
        for (i, chunk) in key.chunks_mut(8).enumerate() {
            let v = seed.wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(i as u64).rotate_left(17)
                .wrapping_mul(0xBF58476D1CE4E5B9);
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        ChaCha20::new(&key)
    }

    /// One 64-byte keystream block for (counter, nonce96).
    pub fn block(&self, counter: u32, nonce: &[u32; 3]) -> [u32; 16] {
        let mut st = [
            0x61707865, 0x3320646e, 0x79622d32, 0x6b206574,
            self.key[0], self.key[1], self.key[2], self.key[3],
            self.key[4], self.key[5], self.key[6], self.key[7],
            counter, nonce[0], nonce[1], nonce[2],
        ];
        let init = st;
        for _ in 0..10 {
            quarter(&mut st, 0, 4, 8, 12);
            quarter(&mut st, 1, 5, 9, 13);
            quarter(&mut st, 2, 6, 10, 14);
            quarter(&mut st, 3, 7, 11, 15);
            quarter(&mut st, 0, 5, 10, 15);
            quarter(&mut st, 1, 6, 11, 12);
            quarter(&mut st, 2, 7, 8, 13);
            quarter(&mut st, 3, 4, 9, 14);
        }
        for (o, i) in st.iter_mut().zip(init.iter()) {
            *o = o.wrapping_add(*i);
        }
        st
    }
}

/// `F(k, cnt)` expanded to a stream of ring elements.  `cnt` is a 64-bit
/// invocation counter (the paper's `cnt`), mapped into the nonce; the
/// block counter then walks the stream, so one invocation can draw an
/// arbitrary-length tensor of randomness.
pub struct PrfStream<'a> {
    prf: &'a ChaCha20,
    nonce: [u32; 3],
    counter: u32,
    buf: [u32; 16],
    pos: usize,
}

impl<'a> PrfStream<'a> {
    pub fn new(prf: &'a ChaCha20, cnt: u64, domain: u32) -> Self {
        let nonce = [domain, cnt as u32, (cnt >> 32) as u32];
        let buf = prf.block(0, &nonce);
        PrfStream { prf, nonce, counter: 0, buf, pos: 0 }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.pos == 16 {
            self.counter += 1;
            self.buf = self.prf.block(self.counter, &self.nonce);
            self.pos = 0;
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    #[inline]
    pub fn next_elem(&mut self) -> i32 {
        self.next_u32() as i32
    }

    /// One 64-bit word of keystream (two consecutive u32 draws, LE order).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let lo = u64::from(self.next_u32());
        let hi = u64::from(self.next_u32());
        lo | (hi << 32)
    }

    pub fn fill(&mut self, out: &mut [i32]) {
        for v in out {
            *v = self.next_elem();
        }
    }

    /// Bulk word fill for packed boolean randomness.
    pub fn fill_words(&mut self, out: &mut [u64]) {
        for w in out {
            *w = self.next_u64();
        }
    }

    /// `n` random bits, word-packed (64 bits per u64 of keystream).
    pub fn next_bits(&mut self, n: usize) -> BitTensor {
        BitTensor::random(self, n)
    }
}

/// Domain-separation tags so different protocols never reuse a stream.
pub mod domain {
    pub const ZERO3: u32 = 1;   // 3-out-of-3 zero sharing
    pub const RAND2: u32 = 2;   // 2-out-of-3 RSS randomness
    pub const OT_MASK: u32 = 3; // OT pad between sender and receiver
    pub const SHARE: u32 = 4;   // dealer input sharing
    pub const BITS: u32 = 5;    // shared random bits
    pub const TRUNC: u32 = 6;   // truncation masks (own counter lane)
}

/// The seeds party `i` holds: (k_i, k_{i+1}) plus a private key of its own.
pub struct PartySeeds {
    /// PRF keyed with k_i (shared with P_{i-1}: both parties of the edge
    /// (i-1, i) can evaluate it).
    pub mine: ChaCha20,
    /// PRF keyed with k_{i+1} (shared with P_{i+1}).
    pub next: ChaCha20,
    /// Private PRF known only to this party (e.g. the model owner's `r`
    /// sampling in MSB extraction).
    pub private: ChaCha20,
    cnt: std::cell::Cell<u64>,
    trunc_cnt: std::cell::Cell<u64>,
}

impl PartySeeds {
    /// Deterministic setup from a session seed: k_i = H(session, i).
    /// In deployment the seeds would come from a key exchange; the
    /// derivation here is what the tests and the in-process runtime use.
    pub fn setup(session: u64, party: usize) -> Self {
        let k = |i: usize| ChaCha20::from_seed(
            session.wrapping_mul(3).wrapping_add(i as u64));
        PartySeeds {
            mine: k(party),
            next: k((party + 1) % 3),
            private: ChaCha20::from_seed(
                session.wrapping_mul(31).wrapping_add(1000 + party as u64)),
            cnt: std::cell::Cell::new(0),
            trunc_cnt: std::cell::Cell::new(0),
        }
    }

    /// Bump and return the invocation counter (must advance identically
    /// on all parties -- protocols call it in lock-step).
    pub fn next_cnt(&self) -> u64 {
        let c = self.cnt.get();
        self.cnt.set(c + 1);
        c
    }

    /// Truncation masks advance on their own counter lane (with the
    /// `domain::TRUNC` tag).  Truncation is the one protocol whose
    /// *output value* depends on the mask drawn (the floor-borrow LSB),
    /// so its randomness must not shift when surrounding protocols
    /// draw more or less from the shared `cnt` lane -- this is what
    /// makes fused and unfused plans of the same model produce
    /// bit-identical logits (they call `trunc` in the same order even
    /// though everything around it differs).
    pub fn next_trunc_cnt(&self) -> u64 {
        let c = self.trunc_cnt.get();
        self.trunc_cnt.set(c + 1);
        c
    }

    /// 3-out-of-3 zero sharing: a_i = F(k_{i+1}, cnt) - F(k_i, cnt).
    pub fn zero3(&self, cnt: u64, n: usize) -> Vec<i32> {
        let mut a = PrfStream::new(&self.next, cnt, domain::ZERO3);
        let mut b = PrfStream::new(&self.mine, cnt, domain::ZERO3);
        (0..n).map(|_| a.next_elem().wrapping_sub(b.next_elem())).collect()
    }

    /// 2-out-of-3 randomness: party i's RSS pair
    /// (F(k_i, cnt), F(k_{i+1}, cnt)).
    pub fn rand2(&self, cnt: u64, n: usize) -> (Vec<i32>, Vec<i32>) {
        let mut a = PrfStream::new(&self.mine, cnt, domain::RAND2);
        let mut b = PrfStream::new(&self.next, cnt, domain::RAND2);
        ((0..n).map(|_| a.next_elem()).collect(),
         (0..n).map(|_| b.next_elem()).collect())
    }

    /// Shared random *bits* as RSS shares mod 2: a pair of word-packed bit
    /// tensors (this party's y_i, y_{i+1} components).
    pub fn rand_bits2(&self, cnt: u64, n: usize) -> (BitTensor, BitTensor) {
        let mut a = PrfStream::new(&self.mine, cnt, domain::BITS);
        let mut b = PrfStream::new(&self.next, cnt, domain::BITS);
        (a.next_bits(n), b.next_bits(n))
    }

    /// 3-out-of-3 XOR-sharing of zero over bits:
    /// r_i = F(k_{i+1}, cnt) ^ F(k_i, cnt), word-parallel.  XOR across the
    /// three parties cancels (the mod-2 analogue of `zero3`).
    pub fn zero_bits3(&self, cnt: u64, n: usize) -> BitTensor {
        let mut a = PrfStream::new(&self.next, cnt, domain::ZERO3);
        let mut b = PrfStream::new(&self.mine, cnt, domain::ZERO3);
        a.next_bits(n).xor(&b.next_bits(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc8439_vector() {
        // RFC 8439 section 2.3.2 test vector
        let mut key = [0u8; 32];
        for (i, b) in key.iter_mut().enumerate() {
            *b = i as u8;
        }
        let c = ChaCha20::new(&key);
        let nonce = [0x09000000u32, 0x4a000000, 0x00000000];
        let block = c.block(1, &nonce);
        assert_eq!(block[0], 0xe4e7f110);
        assert_eq!(block[1], 0x15593bd1);
        assert_eq!(block[15], 0x4e3c50a2);
    }

    #[test]
    fn streams_are_deterministic_and_domain_separated() {
        let c = ChaCha20::from_seed(5);
        let mut s1 = PrfStream::new(&c, 0, domain::ZERO3);
        let mut s2 = PrfStream::new(&c, 0, domain::ZERO3);
        let mut s3 = PrfStream::new(&c, 0, domain::RAND2);
        let a: Vec<u32> = (0..40).map(|_| s1.next_u32()).collect();
        let b: Vec<u32> = (0..40).map(|_| s2.next_u32()).collect();
        let d: Vec<u32> = (0..40).map(|_| s3.next_u32()).collect();
        assert_eq!(a, b);
        assert_ne!(a, d);
    }

    fn three_parties(session: u64) -> [PartySeeds; 3] {
        [PartySeeds::setup(session, 0),
         PartySeeds::setup(session, 1),
         PartySeeds::setup(session, 2)]
    }

    #[test]
    fn zero3_sums_to_zero() {
        let ps = three_parties(77);
        for cnt in 0..5 {
            let shares: Vec<Vec<i32>> =
                ps.iter().map(|p| p.zero3(cnt, 100)).collect();
            for j in 0..100 {
                let sum = shares[0][j]
                    .wrapping_add(shares[1][j])
                    .wrapping_add(shares[2][j]);
                assert_eq!(sum, 0);
            }
        }
    }

    #[test]
    fn rand2_is_consistent_rss() {
        let ps = three_parties(13);
        let pairs: Vec<_> = ps.iter().map(|p| p.rand2(3, 50)).collect();
        for j in 0..50 {
            // P_i's second element equals P_{i+1}'s first (replication)
            for i in 0..3 {
                assert_eq!(pairs[i].1[j], pairs[(i + 1) % 3].0[j]);
            }
            // and it reconstructs to *some* consistent value
            let v = pairs[0].0[j]
                .wrapping_add(pairs[1].0[j])
                .wrapping_add(pairs[2].0[j]);
            let v2 = pairs[0].1[j]
                .wrapping_add(pairs[1].1[j])
                .wrapping_add(pairs[2].1[j]);
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn rand_bits_replicated() {
        let ps = three_parties(99);
        // an awkward (non-word-aligned) length exercises the tail masking
        let n = 77;
        let pairs: Vec<_> = ps.iter().map(|p| p.rand_bits2(9, n)).collect();
        for i in 0..3 {
            // P_i's second component equals P_{i+1}'s first (replication),
            // word-for-word
            assert_eq!(pairs[i].1, pairs[(i + 1) % 3].0);
        }
        // bits are not constant
        let c = pairs[0].0.popcount();
        assert!(c > 0 && c < n);
    }

    #[test]
    fn zero_bits3_xors_to_zero() {
        let ps = three_parties(123);
        for cnt in 0..4 {
            let n = 100;
            let shares: Vec<_> =
                ps.iter().map(|p| p.zero_bits3(cnt, n)).collect();
            let sum = shares[0].xor(&shares[1]).xor(&shares[2]);
            assert_eq!(sum.popcount(), 0, "cnt {cnt}");
            // and the individual masks are not trivially zero
            assert!(shares[0].popcount() > 0);
        }
    }

    #[test]
    fn different_cnt_different_randomness() {
        let p = PartySeeds::setup(1, 0);
        assert_ne!(p.zero3(0, 32), p.zero3(1, 32));
    }
}
