//! Serving metrics: latency histogram + throughput counters for the
//! coordinator, plus per-model rollups for multi-model serving
//! (criterion is not in the offline crate set; the bench harness and
//! the coordinator share these primitives).

use std::time::Duration;

use crate::transport::ChanStats;

/// Fixed-bucket log-scale latency histogram (microseconds).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i covers [2^i, 2^{i+1}) us; 0..=31
    buckets: [u64; 32],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; 32], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Fold `other`'s samples into this histogram (the metrics export
    /// aggregates every model's per-service histogram into one).
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    /// Approximate quantile from the log buckets: the upper bound of
    /// the bucket containing the q-th sample, clamped to the observed
    /// maximum -- a log bucket's bound can overshoot the largest value
    /// actually recorded into it by nearly 2x, and no quantile may
    /// report a latency larger than `max()` (pinned below).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((self.count as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                let bound = (1u64 << (i + 1)).min(self.max_us);
                return Duration::from_micros(bound);
            }
        }
        self.max()
    }
}

/// Offline-preprocessing counters for one party's `offline::TupleBank`.
/// The acceptance gate for the serving path is `underflow_calls == 0`
/// with a warm bank: zero synchronous mints on the request path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PreprocMetrics {
    /// Elements delivered by the background producer.
    pub minted: u64,
    /// Elements consumed by pooled draws.
    pub drawn: u64,
    /// Producer deliveries (refill chunks completed).
    pub refill_chunks: u64,
    /// MSB invocations that fell back to request-path generation.
    pub underflow_calls: u64,
    /// Elements generated synchronously on the request path.
    pub fallback_elems: u64,
    /// High-water mark of stored elements (≤ bank capacity).
    pub max_level: u64,
    /// Watermark retunes applied (`TupleBank::retune`).  Stays 0 under
    /// plain `Service::infer` load: only the batcher's dispatch thread
    /// resizes, never the request path (pinned by
    /// `tests/request_plane.rs`).
    pub retunes: u64,
}

/// Lifecycle counters for one registry slot, surviving the models that
/// occupy it: how often the slot was quarantined, respawned, or
/// hot-swapped, and which seed epoch it currently serves.  Produced by
/// `ModelRegistry` (rollups and `lifecycle_counters`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LifecycleCounters {
    /// Slot cancellations after a desync/`WireError` (`quarantine`).
    pub quarantines: u64,
    /// Quarantined-slot restarts on a fresh seed epoch (`respawn`).
    pub respawns: u64,
    /// Models hot-added into this slot on a live registry.
    pub swaps_in: u64,
    /// Models retired out of this slot on a live registry.
    pub swaps_out: u64,
    /// Quarantines forced by the consecutive-infer-error watchdog (a
    /// subset of `quarantines`).
    pub watchdog_trips: u64,
    /// Seed epoch currently served (0 = never quarantined).
    pub epoch: u32,
}

/// One engine op's share of the wire cost during an inference walk:
/// rounds and bytes attributed by snapshotting `transport::Stats`
/// around the op.  `index` is the op's position in the model program
/// (fused plans may emit several rows per op, e.g. a `b2a-boundary`
/// row before an arithmetic layer, and zero rows for folded signs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OpCost {
    /// Position in `Model::ops` this row is attributed to.
    pub index: usize,
    /// Human label: the op name, plus a `[...]` qualifier on the
    /// fused binary-domain lowerings.
    pub op: String,
    /// Protocol rounds this op contributed on the critical path.
    pub rounds: u64,
    /// Bytes this party sent for the op (payload + tags).
    pub bytes_sent: u64,
}

/// Render per-op costs as an aligned table (the `infer` subcommand's
/// per-layer budget view; budgets in DESIGN.md are asserted against
/// these rows by the engine tests).
pub fn op_cost_table(rows: &[OpCost]) -> String {
    let mut out = String::from(
        "  op                     rounds      bytes\n");
    for r in rows {
        out.push_str(&format!("  {:2} {:<20} {:>6} {:>10}\n",
                              r.index, r.op, r.rounds, r.bytes_sent));
    }
    let rounds: u64 = rows.iter().map(|r| r.rounds).sum();
    let bytes: u64 = rows.iter().map(|r| r.bytes_sent).sum();
    out.push_str(&format!("  total{:>24} {:>10}\n", rounds, bytes));
    out
}

/// One model's serving rollup in a multi-model process: its two lanes'
/// shares of the link traffic (`transport::Stats::chan` rows, which sum
/// with every other model's rows to the link totals) plus its
/// `TupleBank` counters.  Produced by `ModelRegistry::rollups`.
#[derive(Clone, Debug, Default)]
pub struct ModelRollup {
    /// Registry routing key.
    pub name: String,
    /// Channel-id model slot.
    pub slot: u8,
    /// Request-critical-path traffic (the paper-comparable row).
    pub online: ChanStats,
    /// Amortized background producer traffic.
    pub offline: ChanStats,
    /// The model's bank counters (party 0; identical trajectories on
    /// all parties).
    pub preproc: PreprocMetrics,
    /// The slot's lifecycle history (quarantines, respawns, swaps).
    pub lifecycle: LifecycleCounters,
    /// The slot's request-plane counters (queue depth, sheds, dispatch
    /// windows).  Default (all zero) when no batcher fronts the slot --
    /// `ModelRegistry::rollups` alone cannot fill this; the
    /// `RequestPlane` overlay does.
    pub plane: PlaneStats,
    /// Per-tenant rollups for the slot's batcher front (empty without
    /// one), sorted by tenant tag.
    pub tenants: Vec<TenantCounters>,
}

/// Request-plane counters for one batcher front (`coordinator::
/// batcher::Batcher`): admission, shedding, and coalescing behaviour.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlaneStats {
    /// Requests currently queued (snapshot gauge).
    pub depth: u64,
    /// Requests rejected at admission because the queue was full.
    pub shed_queue: u64,
    /// Requests rejected because the bank could not serve the batch
    /// warm (closed, or the largest draw exceeds `capacity - chunk`).
    pub shed_dry: u64,
    /// Dispatch windows executed (each one secure batch).
    pub dispatches: u64,
    /// Requests served through dispatch windows.
    pub served: u64,
    /// Largest batch one window coalesced.
    pub coalesced_max: u64,
}

/// Per-tenant fairness rollup for one batcher front: how much each
/// tenant submitted, how much was served or shed, and the dispatch
/// window its most recent served request rode in (`last_window` is the
/// starvation witness: a quiet tenant's requests must land in windows
/// that do not trail a flooding tenant's backlog).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantCounters {
    pub tenant: String,
    pub submitted: u64,
    pub served: u64,
    pub shed: u64,
    /// 1-based dispatch-window index of the last served request (0 =
    /// never served).
    pub last_window: u64,
}

impl ModelRollup {
    /// The model's total share of link bytes (both lanes).
    pub fn total_bytes(&self) -> u64 {
        self.online.bytes_sent + self.offline.bytes_sent
    }
}

/// Simple mean/throughput aggregate for a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Throughput {
    pub requests: u64,
    pub wall: Duration,
}

impl Throughput {
    pub fn per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.requests as f64 / self.wall.as_secs_f64()
    }
}

/// Everything one machine-readable metrics export reports: the serving
/// front's request counters and latency histogram, every model's
/// rollup, each model's live bank level, and the per-party trace-sink
/// drop counters.  Assembled by the CLI's serve loops on each
/// `--metrics-out` interval tick.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests served so far.
    pub requests: u64,
    pub latency: Histogram,
    pub models: Vec<ModelRollup>,
    /// Live `TupleBank` level per model name (party 0's bank).
    pub bank_levels: Vec<(String, u64)>,
    /// `trace::TraceSink::dropped_events` per party.
    pub trace_dropped: Vec<u64>,
}

/// Escape a Prometheus label value (backslash, quote, newline).
fn prom_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a snapshot in the Prometheus text exposition format.  Metric
/// names are part of the operational contract -- they are documented in
/// OPERATIONS.md §3 and pinned by `tests/docs.rs`.
pub fn prometheus_text(snap: &MetricsSnapshot) -> String {
    let mut o = String::new();
    o.push_str("# TYPE cbnn_requests_total counter\n");
    o.push_str(&format!("cbnn_requests_total {}\n", snap.requests));
    o.push_str("# TYPE cbnn_request_latency_us gauge\n");
    for (q, l) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
        o.push_str(&format!(
            "cbnn_request_latency_us{{quantile=\"{l}\"}} {}\n",
            snap.latency.quantile(q).as_micros()));
    }
    o.push_str(&format!("cbnn_request_latency_us{{quantile=\"max\"}} {}\n",
                        snap.latency.max().as_micros()));
    let lane_rows = |o: &mut String, name: &str,
                     pick: &dyn Fn(&ChanStats) -> u64| {
        o.push_str(&format!("# TYPE {name} counter\n"));
        for r in &snap.models {
            for (lane, st) in [("online", &r.online),
                               ("offline", &r.offline)] {
                o.push_str(&format!(
                    "{name}{{model=\"{}\",slot=\"{}\",lane=\"{lane}\"}} \
                     {}\n",
                    prom_label(&r.name), r.slot, pick(st)));
            }
        }
    };
    lane_rows(&mut o, "cbnn_lane_bytes_total", &|s| s.bytes_sent);
    lane_rows(&mut o, "cbnn_lane_rounds_total", &|s| s.rounds);
    lane_rows(&mut o, "cbnn_lane_messages_total", &|s| s.messages);
    let bank_rows = |o: &mut String, name: &str,
                     pick: &dyn Fn(&PreprocMetrics) -> u64| {
        o.push_str(&format!("# TYPE {name} counter\n"));
        for r in &snap.models {
            o.push_str(&format!("{name}{{model=\"{}\"}} {}\n",
                                prom_label(&r.name), pick(&r.preproc)));
        }
    };
    bank_rows(&mut o, "cbnn_bank_minted_total", &|p| p.minted);
    bank_rows(&mut o, "cbnn_bank_drawn_total", &|p| p.drawn);
    bank_rows(&mut o, "cbnn_bank_underflow_total",
              &|p| p.underflow_calls);
    o.push_str("# TYPE cbnn_bank_level gauge\n");
    for (model, level) in &snap.bank_levels {
        o.push_str(&format!("cbnn_bank_level{{model=\"{}\"}} {level}\n",
                            prom_label(model)));
    }
    o.push_str("# TYPE cbnn_queue_depth gauge\n");
    for r in &snap.models {
        o.push_str(&format!("cbnn_queue_depth{{model=\"{}\"}} {}\n",
                            prom_label(&r.name), r.plane.depth));
    }
    o.push_str("# TYPE cbnn_shed_total counter\n");
    for r in &snap.models {
        for (reason, v) in [("queue-full", r.plane.shed_queue),
                            ("bank-dry", r.plane.shed_dry)] {
            o.push_str(&format!(
                "cbnn_shed_total{{model=\"{}\",reason=\"{reason}\"}} \
                 {v}\n",
                prom_label(&r.name)));
        }
    }
    o.push_str("# TYPE cbnn_tenant_requests_total counter\n");
    for r in &snap.models {
        for t in &r.tenants {
            for (outcome, v) in [("served", t.served), ("shed", t.shed)] {
                o.push_str(&format!(
                    "cbnn_tenant_requests_total{{model=\"{}\",\
                     tenant=\"{}\",outcome=\"{outcome}\"}} {v}\n",
                    prom_label(&r.name), prom_label(&t.tenant)));
            }
        }
    }
    o.push_str("# TYPE cbnn_lifecycle_quarantines_total counter\n");
    for r in &snap.models {
        o.push_str(&format!(
            "cbnn_lifecycle_quarantines_total{{slot=\"{}\"}} {}\n",
            r.slot, r.lifecycle.quarantines));
    }
    o.push_str("# TYPE cbnn_lifecycle_respawns_total counter\n");
    for r in &snap.models {
        o.push_str(&format!(
            "cbnn_lifecycle_respawns_total{{slot=\"{}\"}} {}\n",
            r.slot, r.lifecycle.respawns));
    }
    o.push_str("# TYPE cbnn_trace_dropped_events_total counter\n");
    for (party, d) in snap.trace_dropped.iter().enumerate() {
        o.push_str(&format!(
            "cbnn_trace_dropped_events_total{{party=\"{party}\"}} {d}\n"));
    }
    o
}

/// Format helper used by benches to print paper-style table rows.
pub fn fmt_duration(d: Duration) -> String {
    if d.as_secs_f64() >= 1.0 {
        format!("{:.3}s", d.as_secs_f64())
    } else {
        format!("{:.1}ms", d.as_secs_f64() * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(20));
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn merge_folds_counts_and_max() {
        let mut a = Histogram::default();
        a.record(Duration::from_millis(1));
        let mut b = Histogram::default();
        b.record(Duration::from_millis(8));
        b.record(Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), Duration::from_millis(8));
        assert!(a.quantile(0.99) <= a.max());
    }

    #[test]
    fn quantile_never_exceeds_the_observed_max() {
        // one 1ms sample: the log bucket [512us, 1024us) used to report
        // its upper bound 1024us > max -- every quantile must clamp to
        // the observed maximum
        let mut h = Histogram::default();
        h.record(Duration::from_millis(1));
        assert_eq!(h.max(), Duration::from_millis(1));
        assert_eq!(h.quantile(0.5), Duration::from_millis(1));
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            assert!(h.quantile(q) <= h.max(),
                    "q{q} = {:?} > max {:?}", h.quantile(q), h.max());
        }
        // a multi-sample histogram keeps the invariant too
        let mut h = Histogram::default();
        for us in [3u64, 700, 999, 77_000] {
            h.record(Duration::from_micros(us));
        }
        for q in [0.25, 0.5, 0.75, 0.99] {
            assert!(h.quantile(q) <= h.max());
        }
    }

    #[test]
    fn prometheus_text_exposes_the_documented_names() {
        let mut latency = Histogram::default();
        latency.record(Duration::from_millis(2));
        let snap = MetricsSnapshot {
            requests: 9,
            latency,
            models: vec![ModelRollup {
                name: "mnist\"a\"".into(),
                slot: 0,
                online: ChanStats { bytes_sent: 10, messages: 2,
                                    rounds: 1 },
                plane: PlaneStats { depth: 2, shed_queue: 5,
                                    shed_dry: 1, dispatches: 4,
                                    served: 7, coalesced_max: 3 },
                tenants: vec![TenantCounters {
                    tenant: "acme".into(), submitted: 8, served: 7,
                    shed: 1, last_window: 4,
                }],
                ..ModelRollup::default()
            }],
            bank_levels: vec![("mnist\"a\"".into(), 4096)],
            trace_dropped: vec![0, 0, 3],
        };
        let text = prometheus_text(&snap);
        for name in ["cbnn_requests_total 9",
                     "cbnn_request_latency_us{quantile=\"0.5\"}",
                     "cbnn_lane_bytes_total",
                     "cbnn_lane_rounds_total",
                     "cbnn_lane_messages_total",
                     "cbnn_bank_minted_total",
                     "cbnn_bank_drawn_total",
                     "cbnn_bank_underflow_total",
                     "cbnn_bank_level",
                     "cbnn_lifecycle_quarantines_total",
                     "cbnn_lifecycle_respawns_total",
                     "cbnn_queue_depth{model=\"mnist\\\"a\\\"\"} 2",
                     "reason=\"queue-full\"} 5",
                     "reason=\"bank-dry\"} 1",
                     "tenant=\"acme\",outcome=\"served\"} 7",
                     "tenant=\"acme\",outcome=\"shed\"} 1",
                     "cbnn_trace_dropped_events_total{party=\"2\"} 3"] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // label values are escaped
        assert!(text.contains("model=\"mnist\\\"a\\\"\""), "{text}");
        // every sample line follows its # TYPE header
        let type_lines = text.lines()
            .filter(|l| l.starts_with("# TYPE")).count();
        assert!(type_lines >= 10, "{type_lines} TYPE headers");
    }

    #[test]
    fn op_cost_table_sums_rows() {
        let rows = vec![
            OpCost { index: 0, op: "matmul".into(), rounds: 1,
                     bytes_sent: 400 },
            OpCost { index: 1, op: "sign[bits]".into(), rounds: 2,
                     bytes_sent: 120 },
        ];
        let t = op_cost_table(&rows);
        assert!(t.contains("matmul"));
        assert!(t.contains("sign[bits]"));
        assert!(t.contains("520"), "{t}");
    }

    #[test]
    fn throughput_math() {
        let t = Throughput { requests: 50, wall: Duration::from_secs(5) };
        assert!((t.per_sec() - 10.0).abs() < 1e-9);
    }
}
