//! Cross-party trace merge: join the three parties' span streams into
//! one timeline and check the lock-step invariants.
//!
//! Join key: within one `(trace_id, kind)` group, every party emits
//! its spans in the same program order (the walks and protocol phases
//! are lock-step), so the k-th span on one party corresponds to the
//! k-th on every other -- that pair `(trace_id, span path)` is the
//! join.  Checks:
//!
//! * **Span counts** per `(trace_id, kind)` agree across parties (a
//!   desync shows up as one party missing or growing a span).
//! * **Labels** agree position-by-position (a misaligned join is a
//!   label diff, not a silent mis-pair).
//! * **Rounds** agree position-by-position -- the core protocol
//!   invariant: every party advances its round counter at the same
//!   phase boundaries.
//! * **Flight bytes** per channel sum exactly to the party's
//!   `transport::Stats` rows (only checkable when the sink dropped
//!   nothing).
//!
//! `ci/trace_check.py` re-implements the same checks over the exported
//! JSONL so CI can validate traces without a Rust toolchain; the
//! `cbnn trace <DIR>` subcommand drives this module directly.

use std::collections::BTreeMap;

use super::{Span, SpanKind};
use crate::transport::Stats;

/// Outcome of a cross-party merge: the joined timeline plus every
/// invariant violation found (empty = the traces are consistent).
#[derive(Debug, Default)]
pub struct MergeReport {
    /// Distinct trace ids seen across all parties (0 excluded).
    pub traces: Vec<u64>,
    /// Lock-step spans joined across all three parties.
    pub joined: usize,
    /// Human-readable invariant violations.
    pub problems: Vec<String>,
}

impl MergeReport {
    pub fn ok(&self) -> bool {
        self.problems.is_empty()
    }
}

/// The kinds that are lock-step across parties (flights and gauges
/// are per-party).
const LOCKSTEP: [SpanKind; 3] =
    [SpanKind::Request, SpanKind::Op, SpanKind::Protocol];

fn group<'a>(spans: &'a [Span], kind: SpanKind)
             -> BTreeMap<u64, Vec<&'a Span>> {
    let mut out: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
    for s in spans {
        if s.kind == kind {
            out.entry(s.trace_id).or_default().push(s);
        }
    }
    out
}

/// Join the parties' spans and check the lock-step invariants (span
/// counts, labels, rounds).  `parties[i]` is party i's spans in
/// record order.
pub fn merge_check(parties: &[Vec<Span>]) -> MergeReport {
    let mut report = MergeReport::default();
    let mut traces: Vec<u64> = parties
        .iter()
        .flat_map(|p| p.iter().map(|s| s.trace_id))
        .filter(|&t| t != 0)
        .collect();
    traces.sort_unstable();
    traces.dedup();
    report.traces = traces;

    for kind in LOCKSTEP {
        let grouped: Vec<BTreeMap<u64, Vec<&Span>>> =
            parties.iter().map(|p| group(p, kind)).collect();
        let mut ids: Vec<u64> =
            grouped.iter().flat_map(|g| g.keys().copied()).collect();
        ids.sort_unstable();
        ids.dedup();
        for id in ids {
            let lists: Vec<&[&Span]> = grouped
                .iter()
                .map(|g| g.get(&id).map(|v| v.as_slice()).unwrap_or(&[]))
                .collect();
            let counts: Vec<usize> = lists.iter().map(|l| l.len()).collect();
            if counts.windows(2).any(|w| w[0] != w[1]) {
                report.problems.push(format!(
                    "trace {id}: {} span counts differ across parties: \
                     {counts:?}",
                    kind.as_str()));
                continue;
            }
            for k in 0..counts[0] {
                let first = lists[0][k];
                for (party, l) in lists.iter().enumerate().skip(1) {
                    let s = l[k];
                    if s.label != first.label {
                        report.problems.push(format!(
                            "trace {id}: {} span {k}: label '{}' on \
                             party 0 vs '{}' on party {party}",
                            kind.as_str(), first.label, s.label));
                    } else if s.rounds != first.rounds {
                        report.problems.push(format!(
                            "trace {id}: {} span {k} ('{}'): {} rounds \
                             on party 0 vs {} on party {party}",
                            kind.as_str(), first.label, first.rounds,
                            s.rounds));
                    }
                }
                report.joined += 1;
            }
        }
    }
    report
}

/// Sum of sent-flight bytes per channel tag.
pub fn flight_bytes_by_chan(spans: &[Span]) -> BTreeMap<u8, u64> {
    let mut out: BTreeMap<u8, u64> = BTreeMap::new();
    for s in spans {
        if s.kind == SpanKind::Flight && s.label.as_str() == "send" {
            *out.entry(s.chan).or_default() += s.bytes_sent;
        }
    }
    out
}

/// Reconcile one party's sent-flight bytes against its transport
/// stats: every channel's traced bytes must equal the `Stats` row
/// exactly.  Only meaningful when the sink dropped nothing and
/// tracing covered the links' whole lifetime.
pub fn check_flights(party: usize, spans: &[Span], stats: &Stats)
                     -> Vec<String> {
    let mut expected: BTreeMap<u8, u64> = BTreeMap::new();
    for (c, s) in stats.channels() {
        if s.bytes_sent > 0 {
            expected.insert(c.tag(), s.bytes_sent);
        }
    }
    check_flight_rows(party, spans, &expected)
}

/// [`check_flights`] against a parsed sidecar's per-channel byte rows
/// -- the JSONL-import path (`cbnn trace <DIR>`), where no live
/// `Stats` exists.  Zero-byte rows are ignored on both sides.
pub fn check_flight_rows(party: usize, spans: &[Span],
                         expected: &BTreeMap<u8, u64>) -> Vec<String> {
    let mut problems = Vec::new();
    let traced = flight_bytes_by_chan(spans);
    let mut tags: Vec<u8> = traced
        .keys()
        .chain(expected.keys())
        .copied()
        .collect();
    tags.sort_unstable();
    tags.dedup();
    for tag in tags {
        let got = traced.get(&tag).copied().unwrap_or(0);
        let want = expected.get(&tag).copied().unwrap_or(0);
        if got != want {
            problems.push(format!(
                "party {party} chan {tag}: traced {got} bytes but \
                 transport::Stats says {want}"));
        }
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Label;

    fn span(party: u8, trace_id: u64, kind: SpanKind, label: &str,
            rounds: u64) -> Span {
        Span {
            trace_id,
            kind,
            party,
            chan: 0,
            index: 0,
            label: Label::new(label),
            wall_start_us: 0,
            wall_end_us: 0,
            virt_start_ns: 0,
            virt_end_ns: 0,
            rounds,
            bytes_sent: 0,
            value: 0,
        }
    }

    fn three(f: impl Fn(u8) -> Vec<Span>) -> Vec<Vec<Span>> {
        (0..3u8).map(f).collect()
    }

    #[test]
    fn agreeing_traces_merge_clean() {
        let parties = three(|p| vec![
            span(p, 1, SpanKind::Request, "model", 8),
            span(p, 1, SpanKind::Op, "sign", 2),
            span(p, 1, SpanKind::Protocol, "msb", 6),
            // flights differ per party and are not joined
            span(p, 1, SpanKind::Flight, "send", 0),
        ]);
        let r = merge_check(&parties);
        assert!(r.ok(), "{:?}", r.problems);
        assert_eq!(r.traces, vec![1]);
        assert_eq!(r.joined, 3);
    }

    #[test]
    fn round_disagreement_is_reported() {
        let parties = three(|p| vec![span(
            p, 1, SpanKind::Op, "sign", if p == 2 { 3 } else { 2 })]);
        let r = merge_check(&parties);
        assert_eq!(r.problems.len(), 1);
        assert!(r.problems[0].contains("rounds"), "{}", r.problems[0]);
    }

    #[test]
    fn count_mismatch_is_reported() {
        let parties = three(|p| {
            let mut v = vec![span(p, 1, SpanKind::Op, "sign", 2)];
            if p == 1 {
                v.push(span(p, 1, SpanKind::Op, "b2a", 3));
            }
            v
        });
        let r = merge_check(&parties);
        assert_eq!(r.problems.len(), 1);
        assert!(r.problems[0].contains("span counts differ"),
                "{}", r.problems[0]);
    }

    #[test]
    fn label_mismatch_is_reported() {
        let parties = three(|p| vec![span(
            p, 1, SpanKind::Protocol,
            if p == 0 { "msb" } else { "b2a" }, 3)]);
        let r = merge_check(&parties);
        assert_eq!(r.problems.len(), 2);
        assert!(r.problems[0].contains("label"), "{}", r.problems[0]);
    }

    #[test]
    fn flight_bytes_sum_per_chan() {
        let mut spans = vec![
            span(0, 1, SpanKind::Flight, "send", 0),
            span(0, 1, SpanKind::Flight, "send", 0),
            span(0, 1, SpanKind::Flight, "recv", 0),
        ];
        spans[0].bytes_sent = 10;
        spans[1].bytes_sent = 5;
        spans[1].chan = 1;
        spans[2].bytes_sent = 99; // recv flights don't count
        let sums = flight_bytes_by_chan(&spans);
        assert_eq!(sums.get(&0), Some(&10));
        assert_eq!(sums.get(&1), Some(&5));
    }
}
