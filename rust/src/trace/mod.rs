//! Per-party structured tracing spine (the telemetry plane).
//!
//! Every party owns one [`TraceSink`]: a bounded, pre-allocated span
//! buffer.  Layers above record [`Span`]s into it -- the coordinator a
//! `Request` span per inference job, the engine walks an `Op` span per
//! model op (reusing the `cost_row` Stats-snapshot diffing), the
//! protocol layer a `Protocol` span per phase (msb / b2a / relu / trunc
//! / binlinear), the transport a `Flight` span per shipped or received
//! frame (with PR 7's virtual-clock stamps), and the offline bank
//! periodic `Gauge` samples of its level and credit.
//!
//! Design rules:
//!
//! * **Off means off.**  With tracing disabled, every hook is a single
//!   atomic load and an early return: no span is built, nothing
//!   allocates on the request path (see the tier-7 bench).
//! * **Bounded, never silent.**  The buffer is sized up front
//!   ([`TraceSink::with_capacity`]) and never reallocates; once full,
//!   further spans are counted in [`TraceSink::dropped_events`]
//!   instead of wedging or silently truncating.  The oldest spans are
//!   kept (a trace's setup prefix is the part the merge tool needs).
//! * **Spans are `Copy`.**  Labels are fixed-width inline strings
//!   ([`Label`]), so recording a span never touches the heap.
//! * **Cross-party joinable.**  All three parties emit `Op` /
//!   `Protocol` / `Request` spans in lock-step program order, so the
//!   k-th span of a `(trace_id, kind)` group on one party corresponds
//!   to the k-th on every other -- the join key [`merge`] uses.  Round
//!   counts must agree across parties; byte counts are per-party (the
//!   roles send different amounts) and instead reconcile against
//!   `transport::Stats` per channel.
//!
//! Trace ids are minted process-globally ([`next_trace_id`]) and
//! carried to party threads out of band (the coordinator's job queue);
//! each party thread parks its active id in a thread-local
//! ([`set_current_trace`]) so the transport can attribute flights
//! without widening every send signature.

pub mod merge;

use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::jsonio::{self, Json};
use crate::transport::{ChanStats, Comm, Stats};

/// Default per-party span capacity: ~100 bytes a span, a few MB a
/// party, comfortably above a soak run's span volume.
pub const DEFAULT_CAPACITY: usize = 65_536;

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
}

/// Mint a process-globally monotone trace id (never 0; 0 means "no
/// active request" -- setup and background traffic).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// Park `id` as this thread's active trace (0 clears it).  Set by the
/// party thread around each inference job; read by the transport to
/// attribute flight spans.
pub fn set_current_trace(id: u64) {
    CURRENT_TRACE.with(|c| c.set(id));
}

/// This thread's active trace id (0 when none).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

/// What a span measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// One inference job end to end (per party).
    Request,
    /// One engine op (fused or unfused walk).
    Op,
    /// One protocol phase (msb / b2a / relu / trunc / binlinear /
    /// mint).
    Protocol,
    /// One transport frame, sent (`label == "send"`) or received
    /// (`label == "recv"`).
    Flight,
    /// A sampled value (offline bank level / credit); `value` carries
    /// the sample, the counter fields stay 0.
    Gauge,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Op => "op",
            SpanKind::Protocol => "protocol",
            SpanKind::Flight => "flight",
            SpanKind::Gauge => "gauge",
        }
    }

    pub fn from_str(s: &str) -> Option<SpanKind> {
        Some(match s {
            "request" => SpanKind::Request,
            "op" => SpanKind::Op,
            "protocol" => SpanKind::Protocol,
            "flight" => SpanKind::Flight,
            "gauge" => SpanKind::Gauge,
            _ => return None,
        })
    }
}

/// Fixed-width inline span label: recording never allocates.  Longer
/// labels are truncated at a char boundary (op names fit; see the
/// unit test).
#[derive(Clone, Copy)]
pub struct Label {
    buf: [u8; 24],
    len: u8,
}

impl Label {
    pub fn new(s: &str) -> Label {
        let mut len = s.len().min(24);
        while !s.is_char_boundary(len) {
            len -= 1;
        }
        let mut buf = [0u8; 24];
        buf[..len].copy_from_slice(&s.as_bytes()[..len]);
        Label { buf, len: len as u8 }
    }

    pub fn as_str(&self) -> &str {
        std::str::from_utf8(&self.buf[..self.len as usize]).unwrap_or("?")
    }
}

/// Label for a Request span dispatched by the request plane: the model
/// name, its shard slot, and the tenant tags riding the window, as
/// `model#slot|tenantA,tenantB`.  Built once per dispatch window (off
/// the per-party hot path) and carried in the broadcast job, so all
/// three parties close the Request span under the identical label --
/// the merge's label-agreement check extends to tenant and shard
/// attribution.  Truncated at the 24-byte inline limit like any label.
pub fn request_label(model: &str, slot: u8, tenants: &str) -> Label {
    Label::new(&format!("{model}#{slot}|{tenants}"))
}

impl PartialEq for Label {
    fn eq(&self, other: &Label) -> bool {
        self.as_str() == other.as_str()
    }
}

impl Eq for Label {}

impl std::fmt::Debug for Label {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.as_str())
    }
}

impl std::fmt::Display for Label {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One recorded event.  `Copy` on purpose: the hot path moves it into
/// the pre-allocated buffer without touching the heap.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// The request this span belongs to (0 = background / setup).
    pub trace_id: u64,
    pub kind: SpanKind,
    pub party: u8,
    /// Wire tag of the channel the span's traffic moved on.
    pub chan: u8,
    /// Op index (engine ops) or 0.
    pub index: u32,
    pub label: Label,
    /// Wall-clock stamps, microseconds since the sink's origin.
    pub wall_start_us: u64,
    pub wall_end_us: u64,
    /// Virtual-clock stamps, nanoseconds (0 outside virtual-clock
    /// mode) -- flight spans carry the frame's send/arrival stamps.
    pub virt_start_ns: u64,
    pub virt_end_ns: u64,
    /// Rounds this span advanced on its channel (agrees across
    /// parties for lock-step kinds).
    pub rounds: u64,
    /// Bytes this party sent inside the span (per-party; reconciled
    /// against `transport::Stats` per channel, not across parties).
    pub bytes_sent: u64,
    /// Gauge sample value (0 for non-gauge spans).
    pub value: u64,
}

impl Span {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace_id", Json::Int(self.trace_id as i64)),
            ("kind", Json::Str(self.kind.as_str().to_string())),
            ("party", Json::Int(self.party as i64)),
            ("chan", Json::Int(self.chan as i64)),
            ("index", Json::Int(self.index as i64)),
            ("label", Json::Str(self.label.as_str().to_string())),
            ("wall_start_us", Json::Int(self.wall_start_us as i64)),
            ("wall_end_us", Json::Int(self.wall_end_us as i64)),
            ("virt_start_ns", Json::Int(self.virt_start_ns as i64)),
            ("virt_end_ns", Json::Int(self.virt_end_ns as i64)),
            ("rounds", Json::Int(self.rounds as i64)),
            ("bytes_sent", Json::Int(self.bytes_sent as i64)),
            ("value", Json::Int(self.value as i64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Span, String> {
        let int = |key: &str| -> Result<u64, String> {
            v.field(key)?
                .as_i64()
                .and_then(|i| u64::try_from(i).ok())
                .ok_or_else(|| format!("field '{key}' is not a u64"))
        };
        let kind_str = v
            .field("kind")?
            .as_str()
            .ok_or_else(|| "field 'kind' is not a string".to_string())?;
        let kind = SpanKind::from_str(kind_str)
            .ok_or_else(|| format!("unknown span kind '{kind_str}'"))?;
        let label = v
            .field("label")?
            .as_str()
            .ok_or_else(|| "field 'label' is not a string".to_string())?;
        Ok(Span {
            trace_id: int("trace_id")?,
            kind,
            party: int("party")? as u8,
            chan: int("chan")? as u8,
            index: int("index")? as u32,
            label: Label::new(label),
            wall_start_us: int("wall_start_us")?,
            wall_end_us: int("wall_end_us")?,
            virt_start_ns: int("virt_start_ns")?,
            virt_end_ns: int("virt_end_ns")?,
            rounds: int("rounds")?,
            bytes_sent: int("bytes_sent")?,
            value: int("value")?,
        })
    }
}

/// Snapshot taken at a span's start; `TraceSink::close` diffs the
/// bound channel's counters against it -- the same Stats-snapshot
/// diffing `engine::cost_row` uses, so a span's rounds/bytes are
/// exactly the channel delta across its body.
#[derive(Clone, Copy, Debug)]
pub struct Cursor {
    pub wall_us: u64,
    pub virt_ns: u64,
    pub chan: ChanStats,
}

fn recover<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>)
              -> MutexGuard<'_, T> {
    r.unwrap_or_else(PoisonError::into_inner)
}

/// Bounded per-party span recorder.  The buffer is allocated in full
/// on the *first* record (so an installed-but-disabled sink costs a
/// few machine words, not megabytes) and never grows; a record into a
/// full sink increments `dropped_events` and keeps the existing spans
/// (no silent truncation, no wedge).
pub struct TraceSink {
    enabled: AtomicBool,
    origin: Instant,
    dropped: AtomicU64,
    ring: Mutex<Vec<Span>>,
    capacity: usize,
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> TraceSink {
        TraceSink {
            enabled: AtomicBool::new(false),
            origin: Instant::now(),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(Vec::new()),
            capacity,
        }
    }

    /// The single gate every hook checks first: one atomic load.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    /// Microseconds since this sink's origin (its construction).
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Spans dropped because the buffer was full.
    pub fn dropped_events(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        recover(self.ring.lock()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record one span (no-op unless enabled; counted, not stored,
    /// when full).
    pub fn record(&self, span: Span) {
        if !self.enabled() {
            return;
        }
        let mut ring = recover(self.ring.lock());
        if ring.len() >= self.capacity {
            drop(ring);
            self.dropped.fetch_add(1, Ordering::SeqCst);
            return;
        }
        if ring.capacity() == 0 {
            // one-time full reservation; pushes below never reallocate
            ring.reserve_exact(self.capacity);
        }
        ring.push(span);
    }

    /// Copy of every recorded span, in record order.
    pub fn snapshot(&self) -> Vec<Span> {
        recover(self.ring.lock()).clone()
    }

    /// Drop every recorded span and reset the dropped counter.
    pub fn clear(&self) {
        recover(self.ring.lock()).clear();
        self.dropped.store(0, Ordering::SeqCst);
    }

    /// Open a span over `comm`'s bound channel: snapshot the wall /
    /// virtual clocks and the channel counters.  Callers gate on
    /// [`TraceSink::enabled`] first.
    pub fn cursor(&self, comm: &Comm) -> Cursor {
        Cursor {
            wall_us: self.now_us(),
            virt_ns: comm.virtual_now().as_nanos() as u64,
            chan: comm.chan_stats(),
        }
    }

    /// Close a span opened with [`TraceSink::cursor`]: the span's
    /// rounds/bytes are the channel deltas across the body.
    pub fn close(&self, comm: &Comm, kind: SpanKind, index: u32,
                 label: &str, cur: &Cursor) {
        let now = comm.chan_stats();
        self.record(Span {
            trace_id: current_trace(),
            kind,
            party: comm.id as u8,
            chan: comm.chan().tag(),
            index,
            label: Label::new(label),
            wall_start_us: cur.wall_us,
            wall_end_us: self.now_us(),
            virt_start_ns: cur.virt_ns,
            virt_end_ns: comm.virtual_now().as_nanos() as u64,
            rounds: now.rounds - cur.chan.rounds,
            bytes_sent: now.bytes_sent - cur.chan.bytes_sent,
            value: 0,
        });
    }

    /// Record one transport frame (an instantaneous event span).
    /// Called from the transport send/receive paths with the frame's
    /// virtual-clock stamps.
    pub fn flight(&self, party: u8, chan: u8, label: &str, bytes: u64,
                  virt_start_ns: u64, virt_end_ns: u64) {
        let now = self.now_us();
        self.record(Span {
            trace_id: current_trace(),
            kind: SpanKind::Flight,
            party,
            chan,
            index: 0,
            label: Label::new(label),
            wall_start_us: now,
            wall_end_us: now,
            virt_start_ns,
            virt_end_ns,
            rounds: 0,
            bytes_sent: bytes,
            value: 0,
        });
    }

    /// Record one gauge sample (offline bank level / credit).
    pub fn gauge(&self, party: u8, chan: u8, label: &str, value: u64) {
        let now = self.now_us();
        self.record(Span {
            trace_id: current_trace(),
            kind: SpanKind::Gauge,
            party,
            chan,
            index: 0,
            label: Label::new(label),
            wall_start_us: now,
            wall_end_us: now,
            virt_start_ns: 0,
            virt_end_ns: 0,
            rounds: 0,
            bytes_sent: 0,
            value,
        });
    }
}

impl Default for TraceSink {
    fn default() -> Self {
        TraceSink::new()
    }
}

// ---------------------------------------------------------------------
// export plane: JSONL per party + a stats sidecar the merge tool
// reconciles flight bytes against
// ---------------------------------------------------------------------

/// Serialize spans as JSON Lines (one span object per line).
pub fn to_jsonl(spans: &[Span]) -> String {
    let mut out = String::new();
    for s in spans {
        out.push_str(&jsonio::to_string(&s.to_json()));
        out.push('\n');
    }
    out
}

/// Parse a JSONL trace file's contents (blank lines skipped).
pub fn parse_jsonl(text: &str) -> Result<Vec<Span>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = jsonio::parse(line)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.push(Span::from_json(&v)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?);
    }
    Ok(out)
}

/// The stats sidecar (`stats-p<N>.json`): the party's link totals,
/// per-channel rows, and the sink's dropped-span count -- everything
/// `ci/trace_check.py` needs to reconcile traced flight bytes.
pub fn stats_json(party: usize, stats: &Stats, dropped: u64) -> Json {
    let channels: Vec<Json> = stats
        .channels()
        .map(|(c, s)| {
            Json::obj(vec![
                ("chan", Json::Int(c.tag() as i64)),
                ("bytes_sent", Json::Int(s.bytes_sent as i64)),
                ("messages", Json::Int(s.messages as i64)),
                ("rounds", Json::Int(s.rounds as i64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("party", Json::Int(party as i64)),
        ("dropped_events", Json::Int(dropped as i64)),
        ("bytes_sent", Json::Int(stats.bytes_sent as i64)),
        ("messages", Json::Int(stats.messages as i64)),
        ("rounds", Json::Int(stats.rounds as i64)),
        ("channels", Json::Arr(channels)),
    ])
}

/// A parsed stats sidecar: what `cbnn trace <DIR>` reconciles an
/// imported JSONL trace against (the Rust-side mirror of what
/// `ci/trace_check.py` reads).
#[derive(Clone, Debug, Default)]
pub struct Sidecar {
    pub party: usize,
    pub dropped_events: u64,
    pub bytes_sent: u64,
    pub messages: u64,
    pub rounds: u64,
    /// Per-channel sent bytes, keyed by wire tag.
    pub chan_bytes: std::collections::BTreeMap<u8, u64>,
}

/// Parse a stats sidecar written by [`stats_json`].
pub fn parse_stats(text: &str) -> Result<Sidecar, String> {
    let v = jsonio::parse(text)?;
    let int = |key: &str| -> Result<u64, String> {
        v.field(key)?
            .as_i64()
            .and_then(|i| u64::try_from(i).ok())
            .ok_or_else(|| format!("field '{key}' is not a u64"))
    };
    let mut out = Sidecar {
        party: int("party")? as usize,
        dropped_events: int("dropped_events")?,
        bytes_sent: int("bytes_sent")?,
        messages: int("messages")?,
        rounds: int("rounds")?,
        chan_bytes: Default::default(),
    };
    let rows = v
        .field("channels")?
        .as_arr()
        .ok_or_else(|| "field 'channels' is not an array".to_string())?;
    for row in rows {
        let int = |key: &str| -> Result<u64, String> {
            row.field(key)?
                .as_i64()
                .and_then(|i| u64::try_from(i).ok())
                .ok_or_else(|| format!("channel row: '{key}' is not \
                                        a u64"))
        };
        out.chan_bytes.insert(int("chan")? as u8, int("bytes_sent")?);
    }
    Ok(out)
}

/// Path of party `party`'s trace file under `dir`.
pub fn trace_path(dir: &Path, party: usize) -> PathBuf {
    dir.join(format!("trace-p{party}.jsonl"))
}

/// Path of party `party`'s stats sidecar under `dir`.
pub fn stats_path(dir: &Path, party: usize) -> PathBuf {
    dir.join(format!("stats-p{party}.json"))
}

/// Write one party's already-snapshotted spans plus its stats sidecar
/// under `dir`, creating the directory if needed (the
/// `SessionReport::traces` export path, where no live sink remains).
pub fn write_trace(dir: &Path, party: usize, spans: &[Span],
                   stats: &Stats, dropped: u64) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(trace_path(dir, party), to_jsonl(spans))?;
    let sidecar = stats_json(party, stats, dropped);
    let mut text = jsonio::to_string(&sidecar);
    text.push('\n');
    std::fs::write(stats_path(dir, party), text)
}

/// Write one party's trace (`trace-p<N>.jsonl`) and stats sidecar
/// (`stats-p<N>.json`) under `dir`, creating it if needed.
pub fn write_party_trace(dir: &Path, party: usize, sink: &TraceSink,
                         stats: &Stats) -> std::io::Result<()> {
    write_trace(dir, party, &sink.snapshot(), stats,
                sink.dropped_events())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_label_carries_tenant_and_shard_and_truncates() {
        let l = request_label("lenet5", 3, "acme,beta");
        assert_eq!(l.as_str(), "lenet5#3|acme,beta");
        // over the 24-byte inline limit: truncated, never panics
        let l = request_label("averylongmodelname", 120,
                              "tenant-with-long-name");
        assert_eq!(l.as_str().len(), 24);
        assert!(l.as_str().starts_with("averylongmodelname#120|"));
    }

    fn span(trace_id: u64, kind: SpanKind, label: &str, rounds: u64)
            -> Span {
        Span {
            trace_id,
            kind,
            party: 0,
            chan: 0,
            index: 0,
            label: Label::new(label),
            wall_start_us: 1,
            wall_end_us: 2,
            virt_start_ns: 0,
            virt_end_ns: 0,
            rounds,
            bytes_sent: 10,
            value: 0,
        }
    }

    #[test]
    fn labels_truncate_on_char_boundaries() {
        assert_eq!(Label::new("msb").as_str(), "msb");
        let long = "a-very-long-operation-label-indeed";
        assert_eq!(Label::new(long).as_str(), &long[..24]);
        // multibyte char straddling the cut is dropped, not split
        let uni = format!("{}é", "x".repeat(23));
        assert_eq!(Label::new(&uni).as_str(), &"x".repeat(23));
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let sink = TraceSink::with_capacity(8);
        sink.record(span(1, SpanKind::Op, "sign", 2));
        assert!(sink.is_empty());
        assert_eq!(sink.dropped_events(), 0);
    }

    #[test]
    fn overflow_counts_dropped_events_instead_of_wedging() {
        let sink = TraceSink::with_capacity(4);
        sink.set_enabled(true);
        for i in 0..10 {
            sink.record(span(i, SpanKind::Flight, "send", 0));
        }
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped_events(), 6);
        // the oldest spans are the ones kept
        let kept: Vec<u64> =
            sink.snapshot().iter().map(|s| s.trace_id).collect();
        assert_eq!(kept, vec![0, 1, 2, 3]);
        sink.clear();
        assert!(sink.is_empty());
        assert_eq!(sink.dropped_events(), 0);
    }

    #[test]
    fn jsonl_roundtrips() {
        let spans = vec![
            span(7, SpanKind::Request, "mnistnet1", 21),
            span(7, SpanKind::Op, "matmul[xnor]", 5),
            span(0, SpanKind::Gauge, "bank_level", 0),
        ];
        let text = to_jsonl(&spans);
        assert_eq!(text.lines().count(), 3);
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back.len(), 3);
        for (a, b) in spans.iter().zip(&back) {
            assert_eq!(a.trace_id, b.trace_id);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.label, b.label);
            assert_eq!(a.rounds, b.rounds);
            assert_eq!(a.bytes_sent, b.bytes_sent);
        }
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        assert!(parse_jsonl("{\"kind\":\"op\"}\n").is_err());
        assert!(parse_jsonl("not json\n").is_err());
    }

    #[test]
    fn stats_sidecar_roundtrips() {
        let text = jsonio::to_string(&stats_json(2, &Stats::default(), 3));
        let side = parse_stats(&text).unwrap();
        assert_eq!(side.party, 2);
        assert_eq!(side.dropped_events, 3);
        assert!(side.chan_bytes.is_empty());
        // channel rows come back keyed by wire tag
        let side = parse_stats(
            "{\"party\":0,\"dropped_events\":0,\"bytes_sent\":7,\
             \"messages\":1,\"rounds\":2,\"channels\":[{\"chan\":4,\
             \"bytes_sent\":7,\"messages\":1,\"rounds\":2}]}").unwrap();
        assert_eq!(side.chan_bytes.get(&4), Some(&7));
        assert!(parse_stats("{\"party\":0}").is_err());
    }

    #[test]
    fn trace_ids_are_monotone() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(b > a);
    }

    #[test]
    fn current_trace_is_thread_local() {
        set_current_trace(42);
        assert_eq!(current_trace(), 42);
        std::thread::spawn(|| {
            assert_eq!(current_trace(), 0);
        })
        .join()
        .unwrap();
        set_current_trace(0);
        assert_eq!(current_trace(), 0);
    }
}
