//! Input-independent preprocessing for MSB extraction (the perf-pass
//! online/offline split, EXPERIMENTS.md §Perf).
//!
//! Algorithm 3 consumes, per element: a shared random bit `[beta]^B`,
//! its arithmetic conversion `[beta]^A`, and the masked multiplier
//! `[rs] = [r * (1 - 2*beta)]` with r a small positive secret.  None of
//! these depend on x, so a session mints them ahead of time (a flat
//! per-element reservoir, so any batch size can draw) and the *online*
//! MSB collapses to
//!
//! ```text
//!     u = mul(2x+1, rs)   -- 1 round
//!     reveal u            -- 1 round
//! ```
//!
//! i.e. 2 online rounds instead of 7.  Same offline/online trick as
//! Beaver triples.  `mint` is the interactive generation step; where the
//! material *lives* is the caller's choice: the inline `MsbPool`
//! reservoir (one-shot sessions, tests) or the serving stack's
//! watermark-managed `offline::TupleBank`, whose background producers
//! call `mint` over the offline transport channel so generation never
//! touches the request path.
//!
//! Every reservoir component is a head-indexed FIFO: the beta bits are
//! two word-packed `ring::planes::BitQueue`s (the strided layout's
//! 1-plane case), the arithmetic components are `ElemQueue`s.  Minting
//! appends; a draw advances a head *index* and copies only what it
//! returns -- O(n) per take instead of re-shifting/`split_off`-copying
//! the whole remaining pool -- so a reservoir holding millions of
//! tuples costs megabytes and its draws stay off the hot path.

use std::cell::RefCell;

use anyhow::Result;

use crate::prf::{domain, PrfStream};
use crate::ring::bits::BitTensor;
use crate::ring::planes::BitQueue;
use crate::ring::{Elem, Tensor};
use crate::rss::{self, BitShare, Share};

use super::{b2a::b2a, Ctx};

/// A slice of correlated material for one MSB invocation.
pub struct MsbTuple {
    pub beta: BitShare,
    pub beta_a: Share,
    /// [r * (1 - 2*beta)]
    pub rs: Share,
}

impl MsbTuple {
    /// Elements covered by this tuple slice.
    pub fn len(&self) -> usize {
        self.beta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.beta.is_empty()
    }
}

/// Typed preprocessing failure.  Draws validate availability and return
/// this instead of asserting, so an undersized reservoir surfaces as a
/// `Result` through `msb_via`/the coordinator rather than aborting a
/// party thread mid-session.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PreprocError {
    /// The reservoir cannot cover the draw.
    Exhausted { need: usize, have: usize },
    /// The serving bank was closed (producer death or shutdown drain)
    /// while a draw was outstanding.
    Closed,
}

impl std::fmt::Display for PreprocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PreprocError::Exhausted { need, have } => write!(
                f, "MSB preprocessing exhausted: need {need}, have {have}"),
            PreprocError::Closed => write!(
                f, "preprocessing bank closed mid-draw"),
        }
    }
}

impl std::error::Error for PreprocError {}

/// Head-indexed FIFO of ring elements: the arithmetic analogue of
/// `BitQueue` -- a draw copies only the `n` elements it returns and
/// advances the head; consumed storage is reclaimed lazily.  (The old
/// `split_off`-based draw copied the entire remaining pool each take.)
#[derive(Default)]
struct ElemQueue {
    data: Vec<Elem>,
    head: usize,
}

/// Reclaim consumed storage once this many elements are stale.
const ELEM_RECLAIM: usize = 1 << 16;

impl ElemQueue {
    fn push(&mut self, v: &[Elem]) {
        self.data.extend_from_slice(v);
    }

    fn len(&self) -> usize {
        self.data.len() - self.head
    }

    fn pop_front(&mut self, n: usize) -> Vec<Elem> {
        assert!(n <= self.len(), "element queue underflow: need {n}, \
                                  have {}", self.len());
        let out = self.data[self.head..self.head + n].to_vec();
        self.head += n;
        if self.head >= ELEM_RECLAIM {
            self.data.drain(..self.head);
            self.head = 0;
        }
        if self.len() == 0 {
            self.data.clear();
            self.head = 0;
        }
        out
    }
}

/// FIFO storage of minted MSB material.  Shared by the inline `MsbPool`
/// (single-thread, `RefCell`) and the serving `offline::TupleBank`
/// (`Mutex` + condvars); all methods take `&mut self` so the wrapper
/// chooses the synchronization.
#[derive(Default)]
pub(crate) struct Reservoir {
    beta_a_bits: BitQueue,
    beta_b_bits: BitQueue,
    beta_a: (ElemQueue, ElemQueue),
    rs: (ElemQueue, ElemQueue),
}

impl Reservoir {
    pub(crate) fn len(&self) -> usize {
        self.beta_a_bits.len()
    }

    /// Append a minted tuple slice (FIFO: draws splice across push
    /// boundaries exactly like one contiguous mint).
    pub(crate) fn push(&mut self, t: &MsbTuple) {
        self.beta_a_bits.push(&t.beta.a);
        self.beta_b_bits.push(&t.beta.b);
        self.beta_a.0.push(&t.beta_a.a.data);
        self.beta_a.1.push(&t.beta_a.b.data);
        self.rs.0.push(&t.rs.a.data);
        self.rs.1.push(&t.rs.b.data);
    }

    /// Draw the front `n` elements.  Callers validate `n <= len()` first
    /// (and surface `PreprocError`); this only asserts the internal
    /// invariant.
    pub(crate) fn pop(&mut self, n: usize) -> MsbTuple {
        debug_assert!(n <= self.len());
        MsbTuple {
            beta: BitShare {
                a: self.beta_a_bits.pop_front(n),
                b: self.beta_b_bits.pop_front(n),
            },
            beta_a: Share {
                a: Tensor::from_vec(&[n], self.beta_a.0.pop_front(n)),
                b: Tensor::from_vec(&[n], self.beta_a.1.pop_front(n)),
            },
            rs: Share {
                a: Tensor::from_vec(&[n], self.rs.0.pop_front(n)),
                b: Tensor::from_vec(&[n], self.rs.1.pop_front(n)),
            },
        }
    }
}

/// Mint `n` elements of MSB correlated material: the input-independent
/// prefix of Algorithm 3 (B2A of beta with the r-share flight overlapped,
/// one multiplication -- 4 rounds).  Interactive: all parties call it in
/// lock-step with the same `n`, over whichever transport channel
/// `ctx.comm` is bound to -- the inline pool mints on the online channel
/// during setup, the serving producers on the offline channel
/// concurrently with inference.
pub fn mint(ctx: &Ctx, n: usize) -> Result<MsbTuple> {
    ctx.span("mint", || mint_inner(ctx, n))
}

fn mint_inner(ctx: &Ctx, n: usize) -> Result<MsbTuple> {
    let me = ctx.id();
    let cnt = ctx.seeds.next_cnt();
    let (ba, bb) = ctx.seeds.rand_bits2(cnt, n);
    let beta = BitShare { a: ba, b: bb };

    // r-share first so its flight overlaps the B2A choreography (same
    // ordering argument as msb_extract_full)
    let rcnt = ctx.seeds.next_cnt();
    let r_plain = if me == 1 {
        let mut s = PrfStream::new(&ctx.seeds.private, rcnt,
                                   domain::SHARE);
        let max = 1i64 << ctx.cfg.mask_bits;
        Some(Tensor::from_vec(&[n], (0..n).map(|_| {
            ((s.next_u32() as i64 & (max - 1)) + 1) as Elem
        }).collect()))
    } else {
        None
    };
    let r = rss::share_input_overlapped(ctx.comm, ctx.seeds, 1,
                                        r_plain.as_ref(), &[n])?;
    let beta_a = b2a(ctx, &beta)?;
    let s = beta_a.scale(-2).add_const(me, 1);
    let rs = rss::mul(ctx.comm, ctx.seeds, &r, &s)?;
    Ok(MsbTuple { beta, beta_a, rs })
}

/// Flat per-element reservoir of MSB correlated material.  All parties
/// generate and consume identical element counts in lock-step (the
/// engine derives counts from the public model program).
#[derive(Default)]
pub struct MsbPool {
    r: RefCell<Reservoir>,
}

impl MsbPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mint `n` more elements into the reservoir (see `mint`).
    pub fn generate(&self, ctx: &Ctx, n: usize) -> Result<()> {
        let t = mint(ctx, n)?;
        self.r.borrow_mut().push(&t);
        Ok(())
    }

    /// Draw `n` elements; `PreprocError::Exhausted` if the reservoir is
    /// short (protocol desync / undersized preprocessing) -- the caller
    /// decides whether that is fatal or a fallback trigger.  O(n) per
    /// draw for every component (head-indexed queues).
    pub fn take(&self, n: usize) -> Result<MsbTuple, PreprocError> {
        let mut res = self.r.borrow_mut();
        if res.len() < n {
            return Err(PreprocError::Exhausted { need: n,
                                                 have: res.len() });
        }
        Ok(res.pop(n))
    }

    pub fn available(&self) -> usize {
        self.r.borrow().len()
    }
}

/// Online MSB with preprocessed material: 2 rounds.
pub fn msb_online(ctx: &Ctx, x: &Share, tup: MsbTuple)
                  -> Result<super::msb::MsbOut> {
    ctx.span("msb_online", || msb_online_inner(ctx, x, tup))
}

fn msb_online_inner(ctx: &Ctx, x: &Share, tup: MsbTuple)
                    -> Result<super::msb::MsbOut> {
    let me = ctx.id();
    let n = x.len();
    let xp = x.scale(2).add_const(me, 1).reshape(&[n]);
    let u_sh = rss::mul(ctx.comm, ctx.seeds, &xp, &tup.rs)?;
    let u = rss::reveal(ctx.comm, &u_sh)?;
    let beta_pub: Vec<u8> = u.data.iter().map(|&v| crate::ring::msb(v))
        .collect();
    let bits = tup.beta.xor_const(me, &BitTensor::from_bits(&beta_pub));
    let mut sign_a = tup.beta_a;
    let apply = |t: &mut Tensor, slot_owner: bool| {
        for (i, v) in t.data.iter_mut().enumerate() {
            let c = Elem::from(1 ^ beta_pub[i]);
            *v = (1 - 2 * c).wrapping_mul(*v);
            if slot_owner {
                *v = v.wrapping_add(c);
            }
        }
    };
    apply(&mut sign_a.a, me == 0);
    apply(&mut sign_a.b, me == 2);
    Ok(super::msb::MsbOut { bits, sign_a })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring;
    use crate::rss::{deal, reconstruct, reconstruct_bits};
    use crate::testutil::Rng;

    #[test]
    fn online_msb_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(19);
            let vals: Vec<i32> = (0..120).map(|_| rng.small(1 << 22))
                .collect();
            let x = Tensor::from_vec(&[120], vals.clone());
            let xs = deal(&x, &mut rng);
            let pool = MsbPool::new();
            pool.generate(ctx, 200).unwrap();
            let out = msb_online(ctx, &xs[ctx.id()],
                                 pool.take(120).unwrap()).unwrap();
            assert_eq!(pool.available(), 80);
            (out.bits, out.sign_a, vals)
        });
        let vals = results[0].0 .2.clone();
        let bits: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let arith: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .1.clone());
        let got_bits = reconstruct_bits(&bits);
        let got_arith = reconstruct(&arith);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(got_bits[i], ring::msb(v), "msb of {v}");
            assert_eq!(got_arith.data[i], i32::from(ring::sign_bit(v)),
                       "sign of {v}");
        }
    }

    #[test]
    fn online_phase_is_two_rounds() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[32], 1 << 20);
            let xs = deal(&x, &mut rng);
            let pool = MsbPool::new();
            pool.generate(ctx, 32).unwrap();
            ctx.comm.reset_stats();
            let _ = msb_online(ctx, &xs[ctx.id()],
                               pool.take(32).unwrap()).unwrap();
        });
        for (_, st) in &results {
            assert_eq!(st.rounds, 2, "online rounds = {}", st.rounds);
        }
    }

    #[test]
    fn multiple_generates_accumulate_fifo() {
        // the word-packed reservoir must splice across non-aligned
        // boundaries exactly like the old Vec<u8> split_off did
        let results = run3(|ctx| {
            let pool = MsbPool::new();
            pool.generate(ctx, 10).unwrap();
            pool.generate(ctx, 5).unwrap();
            assert_eq!(pool.available(), 15);
            let t = pool.take(12).unwrap();
            assert_eq!(t.beta.len(), 12);
            assert_eq!(t.beta_a.len(), 12);
            assert_eq!(pool.available(), 3);
            let rest = pool.take(3).unwrap();
            assert_eq!(rest.beta.len(), 3);
            assert_eq!(pool.available(), 0);
        });
        assert_eq!(results.len(), 3);
    }

    #[test]
    fn pooled_beta_is_consistent_with_its_conversion() {
        // drawing across generate() boundaries must keep beta^B and
        // beta^A describing the same bits: reconstruct both and compare.
        let results = run3(|ctx| {
            let pool = MsbPool::new();
            pool.generate(ctx, 70).unwrap();
            pool.generate(ctx, 70).unwrap();
            let _burn = pool.take(33).unwrap(); // misalign the boundary
            let t = pool.take(90).unwrap();
            (t.beta, t.beta_a)
        });
        let bits: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let arith: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .1.clone());
        let b = reconstruct_bits(&bits);
        let a = reconstruct(&arith);
        for i in 0..90 {
            assert_eq!(i32::from(b[i]), a.data[i], "element {i}");
        }
    }

    #[test]
    fn empty_pool_is_typed_error_not_abort() {
        // the satellite hardening: exhaustion propagates as PreprocError
        // instead of asserting the party thread away
        let pool = MsbPool::new();
        let err = pool.take(4).unwrap_err();
        assert_eq!(err, PreprocError::Exhausted { need: 4, have: 0 });
        assert!(err.to_string().contains("exhausted"));
    }

    #[test]
    fn partial_pool_error_reports_counts() {
        let results = run3(|ctx| {
            let pool = MsbPool::new();
            pool.generate(ctx, 6).unwrap();
            let err = pool.take(10).unwrap_err();
            assert_eq!(err, PreprocError::Exhausted { need: 10, have: 6 });
            // the failed draw must not consume anything
            assert_eq!(pool.available(), 6);
            let ok = pool.take(6).unwrap();
            assert_eq!(ok.len(), 6);
        });
        assert_eq!(results.len(), 3);
    }
}
