//! Binary-domain linear layers: secure XNOR + popcount over replicated
//! boolean shares (the fused hot path of the customized BNNs).
//!
//! With ±1 activations encoded as bits (x = 2b - 1) and *public* ±1
//! weights, a dot product over K positions is
//!
//!     dot = 2 * popcount(XNOR(b, wbit)) - K
//!
//! XNOR against a public weight is local: `xnor = b ^ [w == -1]`
//! (`BitShare::xor_const`).  Only the popcount is interactive, and it
//! stays *secret-shared* throughout: the bit planes feed a carry-save
//! adder tree (one batched AND round per level -- `maj(a,b,c) =
//! ((a^b)&(b^c))^b`), finished by a Kogge-Stone carry-propagate add.
//! No popcount, partial sum, or comparison result is ever revealed.
//!
//! The next layer's sign `t`/`flip` folds into a popcount threshold
//! (see `engine::fusion` for the algebra): comparison against a public
//! per-element threshold t' is done by adding the public constant
//! `2^B - t'` into the same adder tree and reading the carry bit B --
//! no extra protocol, just more public addend planes.
//!
//! Round/byte costs (n output elements, K reduction width, B = bits of
//! K): `popcount_ge` ~ (CSA levels + 1 + log2(B+1)) AND rounds of O(n)
//! bits each; `popcount_to_arith` the same CSA plus ONE batched `b2a`
//! of B*n bits; `or_planes` log2(k) AND rounds.  Versus the arithmetic
//! path's 4 bytes per element per reshare/mul/reveal, every message
//! here is bits.

use anyhow::Result;

use crate::baselines::bitdecomp::and_bits;
use crate::protocols::b2a::b2a;
use crate::ring::bits::BitTensor;
use crate::ring::Tensor;
use crate::rss::{BitShare, Share};

use super::Ctx;

/// Boolean share of a PUBLIC bit vector: folded into the y_0 component
/// (held by P0 as `a`, P2 as `b`), the same convention as `xor_const`.
pub fn public_bits(me: usize, bits: &BitTensor) -> BitShare {
    BitShare::zeros(bits.len()).xor_const(me, bits)
}

/// Gather both components of a share by index (bit-level im2col).
pub fn gather_share(x: &BitShare, idx: &[usize]) -> BitShare {
    BitShare { a: x.a.gather(idx), b: x.b.gather(idx) }
}

/// Smallest B with 2^B > k (the adder width that holds a popcount of k).
pub fn width_for(k: usize) -> usize {
    (usize::BITS - k.leading_zeros()) as usize
}

/// Carry-save adder tree over weighted bit planes, mod 2^width.
///
/// `addends` are (bit position, plane) pairs; all planes share one
/// element length.  Returns `width` sum planes, little-endian.  Each
/// CSA level compresses every column with >= 3 planes through full
/// adders (`sum = a^b^c` local, `carry = maj` = one AND), with ALL the
/// level's ANDs batched into a single `and_bits` round; the remaining
/// two-plane columns go through a Kogge-Stone carry-propagate add.
pub fn csa_tree(ctx: &Ctx, addends: Vec<(usize, BitShare)>, width: usize)
                -> Result<Vec<BitShare>> {
    let n = addends.first().map_or(0, |(_, p)| p.len());
    let mut cols: Vec<Vec<BitShare>> = vec![Vec::new(); width];
    for (pos, p) in addends {
        assert_eq!(p.len(), n, "addend plane lengths differ");
        assert!(pos < width, "addend past the adder width");
        cols[pos].push(p);
    }

    // carry-save levels: run until every column is <= 2 planes high
    loop {
        let mut triples: Vec<(usize, BitShare, BitShare, BitShare)> =
            Vec::new();
        for (j, col) in cols.iter_mut().enumerate() {
            while col.len() >= 3 {
                let a = col.pop().unwrap();
                let b = col.pop().unwrap();
                let c = col.pop().unwrap();
                triples.push((j, a, b, c));
            }
        }
        if triples.is_empty() {
            break;
        }
        let mut lhs = BitShare::empty();
        let mut rhs = BitShare::empty();
        for (_, a, b, c) in &triples {
            lhs.extend(&a.xor(b));
            rhs.extend(&b.xor(c));
        }
        let anded = and_bits(ctx, &lhs, &rhs)?;
        for (t, (j, a, b, c)) in triples.into_iter().enumerate() {
            let maj = anded.slice(t * n, n).xor(&b);
            cols[j].push(a.xor(&b).xor(&c)); // full-adder sum, local
            if j + 1 < width {
                cols[j + 1].push(maj); // carry; top-column carry drops
            }
        }
    }

    // two remaining numbers A, B per column; Kogge-Stone add
    let zero = || BitShare::zeros(n);
    let av: Vec<BitShare> = (0..width)
        .map(|j| cols[j].first().cloned().unwrap_or_else(zero)).collect();
    let bv: Vec<BitShare> = (0..width)
        .map(|j| cols[j].get(1).cloned().unwrap_or_else(zero)).collect();
    kogge_stone_add(ctx, &av, &bv)
}

/// Kogge-Stone addition of two plane vectors (mod 2^width): one AND
/// round for the generate bits, then log2(width) prefix rounds.  The
/// XOR-for-OR merge is sound because `G` and `P & G'` are never both
/// set (a fully-propagating span cannot also generate).
fn kogge_stone_add(ctx: &Ctx, a: &[BitShare], b: &[BitShare])
                   -> Result<Vec<BitShare>> {
    let width = a.len();
    assert_eq!(b.len(), width);
    if width == 0 {
        return Ok(Vec::new());
    }
    let n = a[0].len();
    let psum: Vec<BitShare> =
        (0..width).map(|j| a[j].xor(&b[j])).collect();
    // g_j = a_j & b_j, one batched round
    let mut lhs = BitShare::empty();
    let mut rhs = BitShare::empty();
    for j in 0..width {
        lhs.extend(&a[j]);
        rhs.extend(&b[j]);
    }
    let anded = and_bits(ctx, &lhs, &rhs)?;
    let mut g: Vec<BitShare> =
        (0..width).map(|j| anded.slice(j * n, n)).collect();
    let mut p = psum.clone();

    let mut dist = 1;
    while dist < width {
        // batched: for j >= dist, G_j ^= P_j & G_{j-dist}; P_j &= P_{j-dist}
        let mut lhs = BitShare::empty();
        let mut rhs = BitShare::empty();
        for j in dist..width {
            lhs.extend(&p[j]);
            rhs.extend(&g[j - dist]);
        }
        for j in dist..width {
            lhs.extend(&p[j]);
            rhs.extend(&p[j - dist]);
        }
        let anded = and_bits(ctx, &lhs, &rhs)?;
        let m = width - dist;
        for (t, j) in (dist..width).enumerate() {
            g[j] = g[j].xor(&anded.slice(t * n, n));
        }
        for (t, j) in (dist..width).enumerate() {
            p[j] = anded.slice((m + t) * n, n);
        }
        dist *= 2;
    }

    // sum_j = p_j ^ carry_in_j, carry_in_j = G_{j-1}
    Ok((0..width).map(|j| {
        if j == 0 { psum[0].clone() } else { psum[j].xor(&g[j - 1]) }
    }).collect())
}

/// Secret-shared popcount compared against a public per-element
/// threshold: `out[e] = [popcount_e >= thresh[e]]`, over `planes.len()`
/// = K bit planes of shared bits.  Thresholds must lie in [0, K+1]
/// (callers clamp; 0 gives constant 1, K+1 constant 0 -- both fall out
/// of the adder arithmetic, no special cases).  The comparison adds the
/// public constant `2^B - thresh` into the CSA and reads carry bit B.
pub fn popcount_ge(ctx: &Ctx, planes: Vec<BitShare>, thresh: &[u32])
                   -> Result<BitShare> {
    ctx.span("popcount_ge", || popcount_ge_inner(ctx, planes, thresh))
}

fn popcount_ge_inner(ctx: &Ctx, planes: Vec<BitShare>, thresh: &[u32])
                     -> Result<BitShare> {
    let k = planes.len();
    assert!(k > 0, "popcount over zero planes");
    let n = planes[0].len();
    assert_eq!(thresh.len(), n, "one threshold per output element");
    debug_assert!(thresh.iter().all(|&t| t as usize <= k + 1),
                  "thresholds must be clamped to [0, K+1]");
    let b = width_for(k);
    let width = b + 1; // max sum = K + 2^B < 2^{B+1}
    let me = ctx.id();

    let mut addends: Vec<(usize, BitShare)> =
        planes.into_iter().map(|p| (0, p)).collect();
    // constant addend C_e = 2^B - thresh[e], one public plane per bit
    for j in 0..width {
        let plane = BitTensor::from_fn(n, |e| {
            let c = (1u64 << b) - u64::from(thresh[e]);
            ((c >> j) & 1) as u8
        });
        if plane.popcount() > 0 {
            addends.push((j, public_bits(me, &plane)));
        }
    }
    let sum = csa_tree(ctx, addends, width)?;
    Ok(sum[b].clone())
}

/// Secret-shared popcount materialized as arithmetic shares (the
/// binary -> arithmetic boundary at unfoldable layers / final logits):
/// CSA-reduce the planes, then ONE batched `b2a` over the B result
/// planes and a local power-of-two fold.
pub fn popcount_to_arith(ctx: &Ctx, planes: Vec<BitShare>)
                         -> Result<Share> {
    ctx.span("popcount_b2a", || popcount_to_arith_inner(ctx, planes))
}

fn popcount_to_arith_inner(ctx: &Ctx, planes: Vec<BitShare>)
                           -> Result<Share> {
    let k = planes.len();
    assert!(k > 0, "popcount over zero planes");
    let n = planes[0].len();
    let b = width_for(k);
    let addends: Vec<(usize, BitShare)> =
        planes.into_iter().map(|p| (0, p)).collect();
    let sum = csa_tree(ctx, addends, b)?;

    let mut cat = BitShare::empty();
    for plane in &sum {
        cat.extend(plane);
    }
    let ar = b2a(ctx, &cat)?;
    let mut out = Share::zeros(&[n]);
    for j in 0..b {
        for e in 0..n {
            let w = |t: &Tensor| t.data[j * n + e].wrapping_shl(j as u32);
            out.a.data[e] = out.a.data[e].wrapping_add(w(&ar.a));
            out.b.data[e] = out.b.data[e].wrapping_add(w(&ar.b));
        }
    }
    Ok(out)
}

/// Boolean OR across planes: `out[e] = OR_i planes[i][e]`, via
/// De Morgan (`NOT(AND of NOTs)`) with a log-depth AND tree -- the
/// binary-domain lowering of `PoolBits` (max of bits = OR), costing
/// zero MSB tuples.
pub fn or_planes(ctx: &Ctx, planes: Vec<BitShare>) -> Result<BitShare> {
    ctx.span("or_pool", || or_planes_inner(ctx, planes))
}

fn or_planes_inner(ctx: &Ctx, planes: Vec<BitShare>) -> Result<BitShare> {
    assert!(!planes.is_empty(), "or over zero planes");
    let me = ctx.id();
    let n = planes[0].len();
    let mut cur: Vec<BitShare> =
        planes.iter().map(|p| p.not(me)).collect();
    while cur.len() > 1 {
        let mut lhs = BitShare::empty();
        let mut rhs = BitShare::empty();
        let pairs = cur.len() / 2;
        for t in 0..pairs {
            lhs.extend(&cur[2 * t]);
            rhs.extend(&cur[2 * t + 1]);
        }
        let anded = and_bits(ctx, &lhs, &rhs)?;
        let mut next: Vec<BitShare> =
            (0..pairs).map(|t| anded.slice(t * n, n)).collect();
        if cur.len() % 2 == 1 {
            next.push(cur.pop().unwrap());
        }
        cur = next;
    }
    Ok(cur.pop().unwrap().not(me))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::rss::{deal_bits, reconstruct, reconstruct_bits, Share};
    use crate::testutil::threeparty::EDGE_LENGTHS;
    use crate::testutil::Rng;

    fn bit_matrix(rng: &mut Rng, k: usize, n: usize) -> Vec<Vec<u8>> {
        (0..k).map(|_| (0..n).map(|_| rng.bit()).collect()).collect()
    }

    fn deal_planes(rows: &[Vec<u8>], rng: &mut Rng)
                   -> Vec<[crate::rss::BitShare; 3]> {
        rows.iter().map(|r| deal_bits(r, rng)).collect()
    }

    #[test]
    fn popcount_ge_matches_plaintext_across_edge_lengths() {
        for (case, &n) in EDGE_LENGTHS.iter().enumerate() {
            for k in [1usize, 3, 9] {
                let mut rng = Rng::new((case * 10 + k) as u64);
                let rows = bit_matrix(&mut rng, k, n);
                let thresh: Vec<u32> = (0..n)
                    .map(|_| rng.range(0, k + 2) as u32).collect();
                let shares = deal_planes(&rows, &mut rng);
                let results = run3(|ctx| {
                    let planes: Vec<_> = shares.iter()
                        .map(|s| s[ctx.id()].clone()).collect();
                    popcount_ge(ctx, planes, &thresh).unwrap()
                });
                let out: [crate::rss::BitShare; 3] =
                    std::array::from_fn(|i| results[i].0.clone());
                let got = reconstruct_bits(&out);
                for e in 0..n {
                    let pc: u32 = rows.iter().map(|r| u32::from(r[e])).sum();
                    let want = u8::from(pc >= thresh[e]);
                    assert_eq!(got[e], want,
                               "n={n} k={k} e={e} pc={pc} t={}", thresh[e]);
                }
            }
        }
    }

    #[test]
    fn popcount_ge_handles_always_and_never_thresholds() {
        // t' = 0 -> constant 1, t' = K+1 -> constant 0: the clamped
        // fold edge cases ride the adder arithmetic, no special path
        let n = 70;
        let k = 5;
        let mut rng = Rng::new(77);
        let rows = bit_matrix(&mut rng, k, n);
        let thresh: Vec<u32> = (0..n)
            .map(|e| if e % 2 == 0 { 0 } else { (k + 1) as u32 }).collect();
        let shares = deal_planes(&rows, &mut rng);
        let results = run3(|ctx| {
            let planes: Vec<_> = shares.iter()
                .map(|s| s[ctx.id()].clone()).collect();
            popcount_ge(ctx, planes, &thresh).unwrap()
        });
        let out: [crate::rss::BitShare; 3] =
            std::array::from_fn(|i| results[i].0.clone());
        let got = reconstruct_bits(&out);
        for e in 0..n {
            assert_eq!(got[e], u8::from(e % 2 == 0), "e={e}");
        }
    }

    #[test]
    fn popcount_to_arith_matches_plaintext() {
        for &n in &[1usize, 64, 65, 200] {
            for k in [1usize, 4, 100] {
                let mut rng = Rng::new((n + k) as u64);
                let rows = bit_matrix(&mut rng, k, n);
                let shares = deal_planes(&rows, &mut rng);
                let results = run3(|ctx| {
                    let planes: Vec<_> = shares.iter()
                        .map(|s| s[ctx.id()].clone()).collect();
                    popcount_to_arith(ctx, planes).unwrap()
                });
                let out: [Share; 3] =
                    std::array::from_fn(|i| results[i].0.clone());
                let got = reconstruct(&out);
                for e in 0..n {
                    let pc: i32 = rows.iter().map(|r| i32::from(r[e])).sum();
                    assert_eq!(got.data[e], pc, "n={n} k={k} e={e}");
                }
            }
        }
    }

    #[test]
    fn or_planes_matches_plaintext() {
        for k in [1usize, 2, 4, 9] {
            let n = 130;
            let mut rng = Rng::new(k as u64);
            let rows = bit_matrix(&mut rng, k, n);
            let shares = deal_planes(&rows, &mut rng);
            let results = run3(|ctx| {
                let planes: Vec<_> = shares.iter()
                    .map(|s| s[ctx.id()].clone()).collect();
                or_planes(ctx, planes).unwrap()
            });
            let out: [crate::rss::BitShare; 3] =
                std::array::from_fn(|i| results[i].0.clone());
            let got = reconstruct_bits(&out);
            for e in 0..n {
                let want = rows.iter().map(|r| r[e]).max().unwrap();
                assert_eq!(got[e], want, "k={k} e={e}");
            }
        }
    }

    #[test]
    fn xnor_against_public_mask_is_local_and_correct() {
        // xnor(x, w) for ±1 values = x_bit ^ [w == -1]; with the mask
        // public the op is share-local (xor_const), zero rounds
        let n = 100;
        let mut rng = Rng::new(3);
        let bits: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
        let mask = BitTensor::from_fn(n, |_| rng.bit());
        let shares = deal_bits(&bits, &mut rng);
        let results = run3(|ctx| {
            let out = shares[ctx.id()].xor_const(ctx.id(), &mask);
            (out, ctx.comm.stats().rounds)
        });
        let out: [crate::rss::BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&out);
        for e in 0..n {
            assert_eq!(got[e], bits[e] ^ mask.get(e));
            assert_eq!(results[0].0 .1, 0, "xnor must be local");
        }
    }

    #[test]
    fn round_budget_is_logarithmic() {
        // K = 9 planes + threshold constants: CSA compresses to 2 rows
        // in <= 5 levels, KS adds 1 + ceil(log2(B+1)) = 3 more; assert
        // the whole popcount_ge stays inside 10 rounds
        let n = 64;
        let k = 9;
        let mut rng = Rng::new(11);
        let rows = bit_matrix(&mut rng, k, n);
        let thresh = vec![5u32; n];
        let shares = deal_planes(&rows, &mut rng);
        let results = run3(|ctx| {
            let planes: Vec<_> = shares.iter()
                .map(|s| s[ctx.id()].clone()).collect();
            popcount_ge(ctx, planes, &thresh).unwrap();
            ctx.comm.stats().rounds
        });
        for (rounds, _) in &results {
            assert!(*rounds <= 10, "popcount_ge rounds = {rounds}");
        }
    }

    #[test]
    fn gather_share_rearranges_both_components() {
        let mut rng = Rng::new(6);
        let bits: Vec<u8> = (0..50).map(|_| rng.bit()).collect();
        let shares = deal_bits(&bits, &mut rng);
        let idx: Vec<usize> = (0..80).map(|_| rng.range(0, 50)).collect();
        let out: [crate::rss::BitShare; 3] =
            std::array::from_fn(|i| gather_share(&shares[i], &idx));
        let got = reconstruct_bits(&out);
        for (j, &i) in idx.iter().enumerate() {
            assert_eq!(got[j], bits[i]);
        }
    }
}
