//! Secure ReLU (Algorithm 5): `[ReLU(x)]^A = [(1 XOR MSB(x)) * x]^A`.
//!
//! Two implementations with identical outputs:
//!
//! * `relu_ot` -- the paper's Algorithm 5: two role-switched 3-party OTs
//!   select (1 XOR MSB) * (x_1 + x_2) and (1 XOR MSB) * x_0 under additive
//!   masks; the masked selections and PRF masks form RSS shares directly.
//! * `relu_mul` -- ablation arm: B2A the NOT-MSB bit then one RSS
//!   multiplication.  One round fewer on some paths, but a full extra
//!   ring-element conversion; the benches compare the two (exp A1).
//!
//! MSB shares arrive word-packed; the sender-side message construction is
//! the only per-element walk (it builds ring elements anyway), and the OT
//! choice bits are passed as `BitTensor` components directly.

use anyhow::Result;

use crate::ot;
use crate::prf::{domain, PrfStream};
use crate::ring::{Elem, Tensor};
use crate::rss::{self, BitShare, Share};
use crate::transport::Dir;

use super::{b2a::b2a, expect_elems, msb::msb_extract, sign::sign_bits, Ctx};

/// Algorithm 5.  `x` arithmetic shares, `msb` the matching MSB bit shares.
pub fn relu_ot(ctx: &Ctx, x: &Share, msb: &BitShare) -> Result<Share> {
    ctx.span("relu_ot", || relu_ot_inner(ctx, x, msb))
}

fn relu_ot_inner(ctx: &Ctx, x: &Share, msb: &BitShare) -> Result<Share> {
    let n = x.len();
    let me = ctx.id();
    let shape = [n];

    // ---- OT 1: sender P1 supplies (1^i^msb_1^msb_2)*(x_1+x_2) ---------
    let cnt1 = ctx.seeds.next_cnt();
    let roles1 = ot::Roles::new(1, 0, 2);
    // ---- OT 2: roles switched; sender P0 supplies (..)*x_0 ------------
    let cnt2 = ctx.seeds.next_cnt();
    let roles2 = ot::Roles::new(0, 2, 1);

    match me {
        1 => {
            // alpha_1 = PRF(k_1) (free with P0), alpha_2 private -> P2
            let mut s1 = PrfStream::new(&ctx.seeds.mine, cnt1, domain::SHARE);
            let a1: Vec<Elem> = (0..n).map(|_| s1.next_elem()).collect();
            let mut sp = PrfStream::new(&ctx.seeds.private, cnt1,
                                        domain::SHARE);
            let a2: Vec<Elem> = (0..n).map(|_| sp.next_elem()).collect();
            let nots = msb.a.xor(&msb.b); // msb_1 ^ msb_2, word-parallel
            let (m0, m1): (Vec<Elem>, Vec<Elem>) = (0..n).map(|i| {
                let x12 = x.a.data[i].wrapping_add(x.b.data[i]);
                let base = 1 ^ nots.get(i); // 1^msb_1^msb_2
                let mask = a1[i].wrapping_add(a2[i]);
                let v0 = (Elem::from(base)).wrapping_mul(x12)
                    .wrapping_sub(mask);
                let v1 = (Elem::from(base ^ 1)).wrapping_mul(x12)
                    .wrapping_sub(mask);
                (v0, v1)
            }).unzip();
            // alpha_2 rides the OT payload frame: one frame P1->P2
            ot::run_piggybacked(ctx.comm, ctx.seeds, roles1, n,
                                ot::Input::Sender { m0: &m0, m1: &m1 },
                                ot::Extra::Send(&a2))?;
            // A-shares for P1: (A_1, A_2) = (alpha_1, alpha_2)
            let a_share = Share {
                a: Tensor::from_vec(&shape, a1),
                b: Tensor::from_vec(&shape, a2),
            };
            // OT 2: P1 is helper with choice bit msb_2 (= its b component)
            ot::run(ctx.comm, ctx.seeds, roles2, n,
                    ot::Input::Helper { c: &msb.b })?;
            // B-shares for P1: (B_1, B_2) = (gamma_b, forwarded from P2)
            let mut sg = PrfStream::new(&ctx.seeds.mine, cnt2, domain::SHARE);
            let gb: Vec<Elem> = (0..n).map(|_| sg.next_elem()).collect();
            let b2v = expect_elems(ctx.comm.recv_elems(Dir::Next)?, n)?;
            ctx.comm.round();
            let b_share = Share {
                a: Tensor::from_vec(&shape, gb),
                b: Tensor::from_vec(&shape, b2v),
            };
            Ok(a_share.add(&b_share))
        }
        0 => {
            // OT 1: receiver with choice bit msb_0 (= a component)
            let mut s1 = PrfStream::new(&ctx.seeds.next, cnt1, domain::SHARE);
            let a1: Vec<Elem> = (0..n).map(|_| s1.next_elem()).collect();
            let a0 = ot::run(ctx.comm, ctx.seeds, roles1, n,
                             ot::Input::Receiver { c: &msb.a })?
                .expect("ot1 output");
            ctx.comm.send_elems(Dir::Prev, &a0)?; // replicate A_0 to P2
            ctx.comm.round();
            let a_share = Share {
                a: Tensor::from_vec(&shape, a0),
                b: Tensor::from_vec(&shape, a1),
            };
            // OT 2: P0 is sender; gamma_a = PRF(k_0) free with P2,
            // gamma_b = PRF(k_1) free with P1.
            let mut sga = PrfStream::new(&ctx.seeds.mine, cnt2, domain::SHARE);
            let ga: Vec<Elem> = (0..n).map(|_| sga.next_elem()).collect();
            let mut sgb = PrfStream::new(&ctx.seeds.next, cnt2, domain::SHARE);
            let gb: Vec<Elem> = (0..n).map(|_| sgb.next_elem()).collect();
            let nots = msb.a.xor(&msb.b); // msb_0 ^ msb_1 on P0
            let (m0, m1): (Vec<Elem>, Vec<Elem>) = (0..n).map(|i| {
                let x0 = x.a.data[i];
                let base = 1 ^ nots.get(i); // 1^msb_0^msb_1
                let mask = ga[i].wrapping_add(gb[i]);
                ((Elem::from(base)).wrapping_mul(x0).wrapping_sub(mask),
                 (Elem::from(base ^ 1)).wrapping_mul(x0).wrapping_sub(mask))
            }).unzip();
            ot::run(ctx.comm, ctx.seeds, roles2, n,
                    ot::Input::Sender { m0: &m0, m1: &m1 })?;
            let b_share = Share {
                a: Tensor::from_vec(&shape, ga),
                b: Tensor::from_vec(&shape, gb),
            };
            Ok(a_share.add(&b_share))
        }
        2 => {
            // OT 1: helper with choice msb_0 (= b component on P2);
            // alpha_2 arrives prepended to the OT payload frame
            let (_, rider) = ot::run_piggybacked(
                ctx.comm, ctx.seeds, roles1, n,
                ot::Input::Helper { c: &msb.b }, ot::Extra::Recv(n))?;
            let a2 = rider.expect("piggybacked alpha_2");
            let a0 = expect_elems(ctx.comm.recv_elems(Dir::Next)?, n)?;
            ctx.comm.round();
            let a_share = Share {
                a: Tensor::from_vec(&shape, a2),
                b: Tensor::from_vec(&shape, a0),
            };
            // OT 2: receiver with choice msb_2 (= a component on P2)
            let b2v = ot::run(ctx.comm, ctx.seeds, roles2, n,
                              ot::Input::Receiver { c: &msb.a })?
                .expect("ot2 output");
            ctx.comm.send_elems(Dir::Prev, &b2v)?; // replicate B_2 to P1
            ctx.comm.round();
            let mut sga = PrfStream::new(&ctx.seeds.next, cnt2, domain::SHARE);
            let ga: Vec<Elem> = (0..n).map(|_| sga.next_elem()).collect();
            let b_share = Share {
                a: Tensor::from_vec(&shape, b2v),
                b: Tensor::from_vec(&shape, ga),
            };
            Ok(a_share.add(&b_share))
        }
        _ => unreachable!(),
    }
}

/// Ablation arm: ReLU as B2A(NOT msb) then one RSS multiplication.
pub fn relu_mul(ctx: &Ctx, x: &Share, msb: &BitShare) -> Result<Share> {
    let bits = sign_bits(ctx, msb);
    let b = b2a(ctx, &bits)?;
    let flat = x.clone().reshape(&[x.len()]);
    Ok(rss::mul(ctx.comm, ctx.seeds, &b, &flat)?)
}

/// Full ReLU from arithmetic shares (MSB + Algorithm 5).
pub fn relu(ctx: &Ctx, x: &Share) -> Result<Share> {
    let flat = x.clone().reshape(&[x.len()]);
    let msb = msb_extract(ctx, &flat)?;
    relu_ot(ctx, &flat, &msb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::rss::{deal, deal_bits, reconstruct};
    use crate::testutil::Rng;

    fn plain_relu(v: i32) -> i32 {
        if v >= 0 { v } else { 0 }
    }

    #[test]
    fn relu_ot_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(31);
            let vals: Vec<i32> = (0..80).map(|_| rng.small(1 << 20)).collect();
            let msb_bits: Vec<u8> = vals.iter().map(|&v| crate::ring::msb(v))
                .collect();
            let x = Tensor::from_vec(&[80], vals.clone());
            let xs = deal(&x, &mut rng);
            let ms = deal_bits(&msb_bits, &mut rng);
            (relu_ot(ctx, &xs[ctx.id()], &ms[ctx.id()]).unwrap(), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for (g, v) in got.data.iter().zip(&vals) {
            assert_eq!(*g, plain_relu(*v));
        }
        // replication consistency of the assembled shares
        for i in 0..3 {
            assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
        }
    }

    #[test]
    fn relu_mul_equals_relu_ot() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(5);
            let vals: Vec<i32> = (0..40).map(|_| rng.small(1 << 18)).collect();
            let msb_bits: Vec<u8> = vals.iter().map(|&v| crate::ring::msb(v))
                .collect();
            let x = Tensor::from_vec(&[40], vals.clone());
            let xs = deal(&x, &mut rng);
            let ms = deal_bits(&msb_bits, &mut rng);
            let a = relu_ot(ctx, &xs[ctx.id()], &ms[ctx.id()]).unwrap();
            let b = relu_mul(ctx, &xs[ctx.id()], &ms[ctx.id()]).unwrap();
            (a, b)
        });
        let ots: [Share; 3] = std::array::from_fn(|i| results[i].0 .0.clone());
        let muls: [Share; 3] = std::array::from_fn(|i| results[i].0 .1.clone());
        assert_eq!(reconstruct(&ots), reconstruct(&muls));
    }

    #[test]
    fn full_relu_end_to_end() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(77);
            let vals = vec![5, -5, 0, 1 << 20, -(1 << 20), 1, -1, 123456];
            let x = Tensor::from_vec(&[8], vals.clone());
            let xs = deal(&x, &mut rng);
            (relu(ctx, &xs[ctx.id()]).unwrap(), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        let want: Vec<i32> = vals.iter().map(|&v| plain_relu(v)).collect();
        assert_eq!(got.data, want);
    }
}
