//! Fixed-point truncation (the paper adopts a two-round protocol; ours is
//! the helper-assisted masked reveal, also two rounds).
//!
//! Preconditions (enforced by the AOT exporter): |x| < 2^bound_bits.
//!
//! 1. P0 and P1 jointly sample r from PRF(k_1), uniform in
//!    [0, 2^31 - 2^{bound+1}), and add it (plus the positivity shift
//!    2^bound) into the x_1 component -- local.
//! 2. P1 reveals the masked x_1 component to the helper P2 (round 1);
//!    P2 reconstructs y = x + 2^bound + r in [0, 2^31): no wrap.
//! 3. P2 truncates t = y >> f and secret-shares it (round 2).
//! 4. All parties subtract the public-to-(P0,P1) correction
//!    (r >> f) + 2^{bound-f}, folded into the x_1 component -- local.
//!
//! Result error is the usual off-by-one LSB (floor borrow).  P2 sees
//! y = x + shift + r: since r's range exceeds the shifted x's range by
//! 2^{31 - bound - 1}, the statistical leakage is ~(bound+1) - 31 bits
//! (sigma ~ 6 at the default bound of 24).  Documented in DESIGN.md.

use anyhow::Result;

use crate::prf::{domain, PrfStream};
use crate::ring::{Elem, Tensor};
use crate::rss::{self, Share};
use crate::transport::Dir;

use super::{expect_elems, Ctx};

/// Truncate shared values by `f` fractional bits.
pub fn trunc(ctx: &Ctx, x: &Share, f: u32) -> Result<Share> {
    ctx.span("trunc", || trunc_inner(ctx, x, f))
}

fn trunc_inner(ctx: &Ctx, x: &Share, f: u32) -> Result<Share> {
    let n = x.len();
    let me = ctx.id();
    let bound = ctx.cfg.bound_bits;
    let shift: Elem = 1 << bound;
    let r_range: i64 = (1i64 << 31) - (1i64 << (bound + 1));
    // dedicated counter lane: see `PartySeeds::next_trunc_cnt`
    let cnt = ctx.seeds.next_trunc_cnt();

    // r known to P0 (seeds.next = k_1) and P1 (seeds.mine = k_1)
    let r: Option<Vec<Elem>> = match me {
        0 => Some(stream_range(&ctx.seeds.next, cnt, n, r_range)),
        1 => Some(stream_range(&ctx.seeds.mine, cnt, n, r_range)),
        _ => None,
    };

    match me {
        1 => {
            let r = r.unwrap();
            // masked x_1 component: x_1 + shift + r, revealed to P2
            let masked: Vec<Elem> = (0..n).map(|i| {
                x.a.data[i].wrapping_add(shift).wrapping_add(r[i])
            }).collect();
            ctx.comm.send_elems(Dir::Next, &masked)?; // P2 = P1.next
            ctx.comm.round();
            let t = rss::share_input(ctx.comm, ctx.seeds, 2, None,
                                     x.shape())?;
            // correction: subtract (r>>f) + 2^{bound-f} from x_1 (P1.a)
            let mut out = t;
            for i in 0..n {
                let corr = (r[i] >> f).wrapping_add(1 << (bound - f));
                out.a.data[i] = out.a.data[i].wrapping_sub(corr);
            }
            Ok(out)
        }
        0 => {
            let r = r.unwrap();
            ctx.comm.round(); // P1 -> P2 reveal happens this round
            let t = rss::share_input(ctx.comm, ctx.seeds, 2, None,
                                     x.shape())?;
            // x_1 is P0's b component
            let mut out = t;
            for i in 0..n {
                let corr = (r[i] >> f).wrapping_add(1 << (bound - f));
                out.b.data[i] = out.b.data[i].wrapping_sub(corr);
            }
            Ok(out)
        }
        2 => {
            let masked =
                expect_elems(ctx.comm.recv_elems(Dir::Prev)?, n)?; // from P1
            ctx.comm.round();
            // y = (x_1 + shift + r) + x_2 + x_0 ; P2 holds (x_2, x_0)
            let y: Vec<Elem> = (0..n).map(|i| {
                masked[i].wrapping_add(x.a.data[i]).wrapping_add(x.b.data[i])
            }).collect();
            let t: Vec<Elem> = y.iter().map(|&v| {
                debug_assert!(v >= 0, "trunc mask wrapped: bound violated");
                v >> f
            }).collect();
            let t = Tensor::from_vec(x.shape(), t);
            Ok(rss::share_input(ctx.comm, ctx.seeds, 2, Some(&t),
                                x.shape())?)
        }
        _ => unreachable!(),
    }
}

fn stream_range(prf: &crate::prf::ChaCha20, cnt: u64, n: usize,
                range: i64) -> Vec<Elem> {
    let mut s = PrfStream::new(prf, cnt, domain::TRUNC);
    (0..n).map(|_| ((u64::from(s.next_u32()) * range as u64) >> 32) as Elem)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::rss::{deal, reconstruct};
    use crate::testutil::Rng;

    #[test]
    fn trunc_within_one_lsb() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(8);
            let vals: Vec<i32> = (0..200).map(|_| rng.small(1 << 23))
                .collect();
            let x = Tensor::from_vec(&[200], vals.clone());
            let shares = deal(&x, &mut rng);
            (trunc(ctx, &shares[ctx.id()], 12).unwrap(), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for (g, v) in got.data.iter().zip(&vals) {
            let want = v >> 12;
            assert!((g - want).abs() <= 1, "got {g}, want {want} (x={v})");
        }
    }

    #[test]
    fn trunc_round_budget() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(9);
            let x = rng.tensor_small(&[16], 1 << 20);
            let shares = deal(&x, &mut rng);
            let _ = trunc(ctx, &shares[ctx.id()], 8).unwrap();
        });
        for (_, st) in &results {
            assert!(st.rounds <= 2, "rounds = {}", st.rounds);
        }
    }

    #[test]
    fn trunc_preserves_sign() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(10);
            let vals = vec![-4096, 4096, -1, 1, 0, -(1 << 22), 1 << 22];
            let x = Tensor::from_vec(&[7], vals.clone());
            let shares = deal(&x, &mut rng);
            (trunc(ctx, &shares[ctx.id()], 8).unwrap(), vals)
        });
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        let vals = &results[0].0 .1;
        for (g, v) in got.data.iter().zip(vals) {
            assert!((g - (v >> 8)).abs() <= 1);
        }
    }
}
