//! Linear layer inference (Algorithm 2) over a pluggable local-compute
//! backend.
//!
//! Each party locally evaluates the three-term contraction
//!
//! ```text
//!     Z_i = W_i X_i + W_{i+1} X_i + W_i X_{i+1} (+ b_i)
//! ```
//!
//! then masks with 3-out-of-3 zero randomness and reshares (one round).
//! The contraction itself runs either on the native rust tensors or on the
//! AOT-compiled PJRT executable (runtime::PjrtBackend) -- the protocol is
//! agnostic, which is what the A4 ablation exploits.

use anyhow::Result;

use crate::ring::{tensor::im2col_chw, Tensor};
use crate::rss::{self, Share};

use super::Ctx;

/// Local three-term RSS contraction provider.
pub trait LinearBackend {
    /// Pre-compile / pre-load any artifacts for the given layer keys
    /// (no-op for the native backend).  Called during session setup so
    /// compilation never lands on the online path.
    fn warmup(&self, keys: &[String]) {
        let _ = keys;
    }

    /// Z_i = Wa·Xa + Wb·Xa + Wa·Xb (+ ba column-broadcast), all (m,k)x(k,n).
    /// `key` identifies the AOT artifact for this layer shape (ignored by
    /// the native backend).
    fn rss_matmul(&self, key: &str, wa: &Tensor, wb: &Tensor, xa: &Tensor,
                  xb: &Tensor, ba: Option<&Tensor>) -> Tensor;

    /// Depthwise variant: w (C, k*k), x (C, H*W) in CHW; geometry packed
    /// in `geom` = (c, h, w, k, stride, pad_lo, pad_hi).
    fn rss_depthwise(&self, key: &str, wa: &Tensor, wb: &Tensor,
                     xa: &Tensor, xb: &Tensor,
                     geom: (usize, usize, usize, usize, usize, usize, usize))
                     -> Tensor {
        let _ = key;
        native_depthwise(wa, wb, xa, xb, geom)
    }
}

/// Pure-rust reference backend.
pub struct NativeBackend;

impl LinearBackend for NativeBackend {
    fn rss_matmul(&self, _key: &str, wa: &Tensor, wb: &Tensor, xa: &Tensor,
                  xb: &Tensor, ba: Option<&Tensor>) -> Tensor {
        // (Wa + Wb)·Xa + Wa·Xb -- same two-contraction identity as the
        // Pallas kernel
        let wsum = wa.add(wb);
        let mut z = wsum.matmul(xa);
        z.add_assign(&wa.matmul(xb));
        match ba {
            Some(b) => z.add_col(b),
            None => z,
        }
    }
}

/// Direct depthwise three-term contraction in CHW layout.
pub fn native_depthwise(wa: &Tensor, wb: &Tensor, xa: &Tensor, xb: &Tensor,
                        geom: (usize, usize, usize, usize, usize, usize,
                               usize)) -> Tensor {
    let (c, h, w, k, stride, pad_lo, pad_hi) = geom;
    let hp = h + pad_lo + pad_hi;
    let wp = w + pad_lo + pad_hi;
    let oh = (hp - k) / stride + 1;
    let ow = (wp - k) / stride + 1;
    let mut out = Tensor::zeros(&[c, oh * ow]);
    let xa3 = Tensor { shape: vec![c, h, w], data: xa.data.clone() };
    let xb3 = Tensor { shape: vec![c, h, w], data: xb.data.clone() };
    for ci in 0..c {
        let (xa_c, _) = im2col_chw(
            &Tensor::from_vec(&[1, h, w],
                              xa3.data[ci * h * w..(ci + 1) * h * w].to_vec()),
            k, stride, pad_lo, pad_hi);
        let (xb_c, _) = im2col_chw(
            &Tensor::from_vec(&[1, h, w],
                              xb3.data[ci * h * w..(ci + 1) * h * w].to_vec()),
            k, stride, pad_lo, pad_hi);
        let wa_row = Tensor::from_vec(&[1, k * k],
                                      wa.data[ci * k * k..(ci + 1) * k * k]
                                      .to_vec());
        let wb_row = Tensor::from_vec(&[1, k * k],
                                      wb.data[ci * k * k..(ci + 1) * k * k]
                                      .to_vec());
        let wsum = wa_row.add(&wb_row);
        let mut z = wsum.matmul(&xa_c);
        z.add_assign(&wa_row.matmul(&xb_c));
        out.data[ci * oh * ow..(ci + 1) * oh * ow].copy_from_slice(&z.data);
    }
    out
}

/// Algorithm 2: secure matmul layer.  `w`, `b` are the model's RSS shares;
/// `x` the activation shares (k, n).  One reshare round.
pub fn linear(ctx: &Ctx, backend: &dyn LinearBackend, key: &str, w: &Share,
              x: &Share, b: Option<&Share>) -> Result<Share> {
    let zi = backend.rss_matmul(key, &w.a, &w.b, &x.a, &x.b,
                                b.map(|bb| &bb.a));
    Ok(rss::reshare(ctx.comm, ctx.seeds, &zi)?)
}

/// Algorithm 2, depthwise-convolution form.
pub fn depthwise(ctx: &Ctx, backend: &dyn LinearBackend, key: &str,
                 w: &Share, x: &Share,
                 geom: (usize, usize, usize, usize, usize, usize, usize))
                 -> Result<Share> {
    let zi = backend.rss_depthwise(key, &w.a, &w.b, &x.a, &x.b, geom);
    Ok(rss::reshare(ctx.comm, ctx.seeds, &zi)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::rss::{deal, reconstruct};
    use crate::testutil::{prop, Rng};

    #[test]
    fn secure_matmul_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(21);
            let (m, k, n) = (6, 10, 4);
            let w = rng.tensor_small(&[m, k], 1000);
            let x = rng.tensor_small(&[k, n], 1000);
            let b = rng.tensor_small(&[m], 1000);
            let ws = deal(&w, &mut rng);
            let xs = deal(&x, &mut rng);
            let bs = deal(&b, &mut rng);
            let z = linear(ctx, &NativeBackend, "t", &ws[ctx.id()],
                           &xs[ctx.id()], Some(&bs[ctx.id()])).unwrap();
            (z, w.matmul(&x).add_col(&b))
        });
        let want = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        assert_eq!(reconstruct(&shares), want);
        for i in 0..3 {
            assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
        }
    }

    #[test]
    fn secure_matmul_single_round() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(2);
            let w = rng.tensor(&[3, 3]);
            let x = rng.tensor(&[3, 2]);
            let ws = deal(&w, &mut rng);
            let xs = deal(&x, &mut rng);
            let _ = linear(ctx, &NativeBackend, "t", &ws[ctx.id()],
                           &xs[ctx.id()], None).unwrap();
        });
        for (_, st) in &results {
            assert_eq!(st.rounds, 1);
        }
    }

    #[test]
    fn native_depthwise_matches_dense_blockdiag() {
        prop(20, |rng: &mut Rng| {
            let (c, h, w, k) = (rng.range(1, 4), rng.range(3, 7),
                                rng.range(3, 7), rng.range(1, 3));
            let wa = rng.tensor_small(&[c, k * k], 50);
            let wb = rng.tensor_small(&[c, k * k], 50);
            let xa = rng.tensor_small(&[c, h * w], 50);
            let xb = rng.tensor_small(&[c, h * w], 50);
            let z = native_depthwise(&wa, &wb, &xa, &xb,
                                     (c, h, w, k, 1, 0, 0));
            // oracle: per-channel explicit loops
            let (oh, ow) = (h - k + 1, w - k + 1);
            for ci in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0i32;
                        for ky in 0..k {
                            for kx in 0..k {
                                let wi = wa.data[ci * k * k + ky * k + kx];
                                let wi1 = wb.data[ci * k * k + ky * k + kx];
                                let xi = xa.data[ci * h * w + (oy + ky) * w
                                                 + ox + kx];
                                let xi1 = xb.data[ci * h * w + (oy + ky) * w
                                                  + ox + kx];
                                acc = acc
                                    .wrapping_add(wi.wrapping_add(wi1)
                                                  .wrapping_mul(xi))
                                    .wrapping_add(wi.wrapping_mul(xi1));
                            }
                        }
                        assert_eq!(z.data[ci * oh * ow + oy * ow + ox], acc);
                    }
                }
            }
        });
    }

    #[test]
    fn secure_depthwise_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(33);
            let (c, h, w, k) = (2, 5, 5, 3);
            let wt = rng.tensor_small(&[c, k * k], 100);
            let x = rng.tensor_small(&[c, h * w], 100);
            let ws = deal(&wt, &mut rng);
            let xs = deal(&x, &mut rng);
            let z = depthwise(ctx, &NativeBackend, "t", &ws[ctx.id()],
                              &xs[ctx.id()], (c, h, w, k, 1, 1, 1)).unwrap();
            (z, wt, x)
        });
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        // oracle via native_depthwise on plaintext (wb = xb = 0)
        let wt = &results[0].0 .1;
        let x = &results[0].0 .2;
        let zero_w = Tensor::zeros(&[2, 9]);
        let zero_x = Tensor::zeros(&[2, 25]);
        let mut want = native_depthwise(wt, &zero_w, x, &zero_x,
                                        (2, 5, 5, 3, 1, 1, 1));
        // native_depthwise(w,0,x,0) computes w·x exactly
        want.shape = got.shape.clone();
        assert_eq!(got, want);
    }
}
