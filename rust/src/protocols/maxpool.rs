//! Sign-fused maxpooling (paper Section 3.6).
//!
//! After a Sign activation the feature map holds arithmetic shares of bits
//! in {0,1}; the max over a window equals the OR of the bits, and
//!
//! ```text
//!     OR(b_1..b_k) = Sign( sum(b) - 1 )
//! ```
//!
//! so pooling costs one *local* windowed sum plus one Sign evaluation on
//! the (4x smaller) pooled map -- no secure pairwise comparisons.  The
//! non-fused comparison-tree alternative lives in baselines:: for the A2
//! ablation.

use anyhow::Result;

use crate::rss::Share;

use super::{sign::sign, Ctx};

/// Windowed local sum over a (C, H, W)-shaped share laid out as
/// `[C, H*W]`; returns the `[C, OH*OW]` share of (sum - 1).
pub fn window_sum_minus_one(ctx: &Ctx, bits: &Share, c: usize, h: usize,
                            w: usize, k: usize, stride: usize) -> Share {
    assert_eq!(bits.len(), c * h * w);
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let mut out = Share::zeros(&[c, oh * ow]);
    let acc = |src: &crate::ring::Tensor, dst: &mut crate::ring::Tensor| {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut s = 0i32;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = oy * stride + ky;
                            let ix = ox * stride + kx;
                            s = s.wrapping_add(src.data[ci * h * w + iy * w + ix]);
                        }
                    }
                    dst.data[ci * oh * ow + oy * ow + ox] = s;
                }
            }
        }
    };
    acc(&bits.a, &mut out.a);
    acc(&bits.b, &mut out.b);
    // subtract the public constant 1 (one additive component only)
    out.add_const(ctx.id(), -1)
}

/// Fused maxpool over sign-bit shares: returns `[C, OH*OW]` arithmetic
/// shares of the pooled bits, plus the output spatial dims.
pub fn maxpool_bits(ctx: &Ctx, bits: &Share, c: usize, h: usize, w: usize,
                    k: usize, stride: usize)
                    -> Result<(Share, (usize, usize))> {
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let summed = window_sum_minus_one(ctx, bits, c, h, w, k, stride);
    let flat = summed.reshape(&[c * oh * ow]);
    let (pooled, _) = sign(ctx, &flat)?;
    Ok((pooled.reshape(&[c, oh * ow]), (oh, ow)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::Tensor;
    use crate::rss::{deal, reconstruct};
    use crate::testutil::Rng;

    fn plain_pool(bits: &[i32], c: usize, h: usize, w: usize) -> Vec<i32> {
        let (oh, ow) = (h / 2, w / 2);
        let mut out = vec![0; c * oh * ow];
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = 0;
                    for ky in 0..2 {
                        for kx in 0..2 {
                            m = m.max(bits[ci * h * w + (2 * oy + ky) * w
                                           + 2 * ox + kx]);
                        }
                    }
                    out[ci * oh * ow + oy * ow + ox] = m;
                }
            }
        }
        out
    }

    #[test]
    fn fused_pool_equals_plaintext_or() {
        let results = run3(|ctx| {
            let (c, h, w) = (3, 6, 6);
            let mut rng = Rng::new(12);
            let bits: Vec<i32> = (0..c * h * w).map(|_| rng.bit() as i32)
                .collect();
            let x = Tensor::from_vec(&[c, h * w], bits.clone());
            let shares = deal(&x, &mut rng);
            let (pooled, dims) =
                maxpool_bits(ctx, &shares[ctx.id()], c, h, w, 2, 2).unwrap();
            (pooled, dims, bits)
        });
        let (_, dims, bits) = results[0].0.clone();
        assert_eq!(dims, (3, 3));
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        assert_eq!(got.data, plain_pool(&bits, 3, 6, 6));
    }

    #[test]
    fn all_zero_window_pools_to_zero() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(1);
            let x = Tensor::from_vec(&[1, 16], vec![0; 16]);
            let shares = deal(&x, &mut rng);
            maxpool_bits(ctx, &shares[ctx.id()], 1, 4, 4, 2, 2).unwrap().0
        });
        let shares: [Share; 3] = std::array::from_fn(|i| results[i].0.clone());
        assert_eq!(reconstruct(&shares).data, vec![0; 4]);
    }
}
