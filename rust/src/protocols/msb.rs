//! MSB extraction (Algorithm 3) -- constant rounds, no bit decomposition.
//!
//! The printed protocol masks x with a random sign flip beta and a random
//! positive multiplier r, reveals u = (-1)^beta * x * r, and unmasks
//! MSB(u) with beta.  As printed (r in Z_2^{l-1}) the product wraps and
//! correctness breaks; we implement the corrected bounded-input variant:
//!
//! * inputs are guaranteed |x| < 2^bound_bits by the AOT exporter;
//! * x' = 2x + 1 removes the x = 0 tie (MSB(2x+1) = MSB(x), never zero);
//! * r is drawn privately by the model owner P1 from [1, 2^mask_bits] and
//!   secret-shared, so |x' * r| < 2^31 never wraps;
//! * u = x' * r * (1 - 2*beta) is revealed; MSB(x) = MSB(u) XOR beta.
//!
//! Leakage note (documented, inherent to the paper's design): the revealed
//! u exposes |x| up to the multiplicative smudging of r (mask_bits of
//! uncertainty); beta perfectly hides the sign.  See DESIGN.md.
//!
//! Rounds: B2A(beta) 3 + two multiplications 2 + reveal 1 = 6, constant
//! in l (vs log l + 2 for bit-decomposition adders).  The r-share is
//! data-independent of beta, so its single flight is sent before B2A and
//! overlaps the OT choreography instead of costing a round of its own
//! (P1 ships r first; P2 drains it from the same FIFO stream before the
//! OT payload, P0 reads it from the opposite direction).
//!
//! beta is drawn word-packed (64 bits per PRF word) and the final unmask
//! is one word-parallel XOR folded into the y_0 slot.

use anyhow::Result;

use crate::prf::{domain, PrfStream};
use crate::ring::bits::BitTensor;
use crate::ring::{Elem, Tensor};
use crate::rss::{self, BitShare, Share};

use super::{b2a::b2a, Ctx};

/// MSB extraction output: the bit shares, plus *free* arithmetic shares
/// of Sign(x) = 1 ^ MSB(x).
///
/// The protocol reveals beta' = MSB(u) publicly and already holds
/// `[beta]^A` from the B2A step; since MSB(x) = beta' ^ beta and beta'
/// is public, Sign(x) = (1 ^ beta') ^ beta = c ^ beta = (1-2c)*beta + c
/// is a local affine map of `[beta]^A`.  Algorithm 4 therefore costs
/// zero extra rounds on top of Algorithm 3.
pub struct MsbOut {
    pub bits: BitShare,
    /// `[Sign(x)]^A = [1 ^ MSB(x)]^A`, in {0,1}.
    pub sign_a: Share,
}

/// Extract `[MSB(x)]^B` from `[x]^A`.  All parties call in lock-step.
pub fn msb_extract(ctx: &Ctx, x: &Share) -> Result<BitShare> {
    Ok(msb_extract_full(ctx, x)?.bits)
}

/// Full MSB extraction returning both share forms (see MsbOut).
pub fn msb_extract_full(ctx: &Ctx, x: &Share) -> Result<MsbOut> {
    ctx.span("msb", || msb_extract_inner(ctx, x))
}

fn msb_extract_inner(ctx: &Ctx, x: &Share) -> Result<MsbOut> {
    let n = x.len();
    let me = ctx.id();

    // 1. shared random bit vector [beta]^B (2-out-of-3 randomness,
    //    word-packed straight from the PRF)
    let cnt = ctx.seeds.next_cnt();
    let (ba, bb) = ctx.seeds.rand_bits2(cnt, n);
    let beta = BitShare { a: ba, b: bb };

    // 2. model owner P1 samples r in [1, 2^mask_bits] and shares it.
    //    The flight overlaps B2A: P1 sends r before the OT starts, and
    //    the receives either precede the OT stream (P2, same direction)
    //    or come from a direction B2A never uses (P0), so no round is
    //    counted -- see share_input_overlapped.
    let rcnt = ctx.seeds.next_cnt();
    let r_plain = if me == 1 {
        let mut s = PrfStream::new(&ctx.seeds.private, rcnt, domain::SHARE);
        let max = 1i64 << ctx.cfg.mask_bits;
        Some(Tensor::from_vec(&[n], (0..n).map(|_| {
            ((s.next_u32() as i64 & (max - 1)) + 1) as Elem
        }).collect()))
    } else {
        None
    };
    let r = rss::share_input_overlapped(ctx.comm, ctx.seeds, 1,
                                        r_plain.as_ref(), &[n])?;

    // 3. [beta]^A via the 3-OT conversion
    let beta_a = b2a(ctx, &beta)?;

    // 4. x' = 2x + 1 (tie-break), s = 1 - 2*beta (sign flip), all local
    let xp = x.scale(2).add_const(me, 1).reshape(&[n]);
    let s = beta_a.scale(-2).add_const(me, 1);

    // 5. u = x' * r * s  (two multiplication rounds), then reveal
    let m = rss::mul(ctx.comm, ctx.seeds, &xp, &r)?;
    let u_sh = rss::mul(ctx.comm, ctx.seeds, &m, &s)?;
    let u = rss::reveal(ctx.comm, &u_sh)?;

    // 6. MSB(x) = MSB(u) XOR beta  (public XOR folded into the y_0 slot;
    //    the only per-bit walk is packing the revealed plaintext once)
    let beta_pub: Vec<u8> = u.data.iter().map(|&v| crate::ring::msb(v))
        .collect();
    let bits = beta.xor_const(me, &BitTensor::from_bits(&beta_pub));
    // 7. free Sign shares: c = 1 ^ beta' public; sign = (1-2c)*beta + c
    let mut sign_a = Share {
        a: beta_a.a.clone(),
        b: beta_a.b.clone(),
    };
    let apply = |t: &mut crate::ring::Tensor, slot_owner: bool| {
        for (i, v) in t.data.iter_mut().enumerate() {
            let c = Elem::from(1 ^ beta_pub[i]);
            *v = (1 - 2 * c).wrapping_mul(*v);
            if slot_owner {
                *v = v.wrapping_add(c);
            }
        }
    };
    // constant c sits in the x_0 component: P0's a, P2's b
    apply(&mut sign_a.a, me == 0);
    apply(&mut sign_a.b, me == 2);
    Ok(MsbOut { bits, sign_a })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring;
    use crate::rss::{deal, reconstruct_bits};
    use crate::testutil::Rng;

    fn check_msb(values: &'static [i32], seed: u64) {
        let results = run3(move |ctx| {
            let mut rng = Rng::new(seed);
            let x = Tensor::from_vec(&[values.len()], values.to_vec());
            let shares = deal(&x, &mut rng);
            (msb_extract(ctx, &shares[ctx.id()]).unwrap(), values.to_vec())
        });
        let want: Vec<u8> = results[0].0 .1.iter().map(|&v| ring::msb(v))
            .collect();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        assert_eq!(reconstruct_bits(&shares), want);
    }

    #[test]
    fn msb_exact_on_bounded_inputs() {
        let mut rng = Rng::new(3);
        let vals: Vec<i32> = (0..200).map(|_| rng.small((1 << 24) - 1))
            .collect();
        check_msb(Box::leak(vals.into_boxed_slice()), 17);
    }

    #[test]
    fn msb_matches_plaintext_across_seeds() {
        // equivalence pin: the protocol's reconstructed output equals the
        // plaintext oracle for several fixed dealer/PRF seeds (the same
        // invariant the byte-per-bit seed implementation satisfied).
        for seed in [1u64, 2, 3] {
            let mut rng = Rng::new(seed);
            let vals: Vec<i32> = (0..97).map(|_| rng.small(1 << 20))
                .collect();
            check_msb(Box::leak(vals.into_boxed_slice()), 40 + seed);
        }
    }

    #[test]
    fn msb_edge_cases() {
        // zero maps to MSB 0 (sign_bit 1) thanks to the 2x+1 tie-break
        check_msb(&[0, 1, -1, (1 << 24) - 1, -(1 << 24) + 1, 2, -2], 5);
    }

    #[test]
    fn msb_round_budget_constant() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(1);
            let x = rng.tensor_small(&[16], 1 << 20);
            let shares = deal(&x, &mut rng);
            let _ = msb_extract(ctx, &shares[ctx.id()]).unwrap();
        });
        // B2A 3 + 2 mul + reveal = 6; the r-share flight is overlapped
        for (_, st) in &results {
            assert!(st.rounds <= 6, "rounds = {}", st.rounds);
        }
        let max = results.iter().map(|(_, st)| st.rounds).max().unwrap();
        assert_eq!(max, 6, "critical-path rounds moved off the budget");
    }
}
