//! CBNN's secure-inference protocol suite (paper Sections 3.3-3.6).
//!
//! Every protocol is written against a `Ctx` bundling the party's
//! transport endpoint and correlated-randomness seeds.  All parties call
//! the same function in lock-step with their own shares; tests reconstruct
//! the outputs and compare to the plaintext oracle.
//!
//! Round budgets: the normative per-protocol table lives in DESIGN.md
//! ("Round budgets") and is executable -- `tests/budgets.rs` parses it
//! and asserts the measured `transport::Stats` round counts against it,
//! so this summary is informational only:
//!
//! | protocol               | rounds (critical path) |
//! |------------------------|------------------------|
//! | linear + reshare       | 1                      |
//! | 3-OT                   | 2                      |
//! | B2A (via 3-OT)         | 3                      |
//! | MSB extraction         | 6 (B2A ∥ r-share, 2 mul, reveal) |
//! | Sign (Alg 4)           | MSB + 0 (sign_a is free, see MsbOut) |
//! | ReLU select (Alg 5)    | 6 (two role-switched OTs + replications) |
//! | truncation             | 2                      |
//! | maxpool (Sign-fused)   | 0 extra linear rounds (reuses Sign) |
//! | binary linear (fused)  | CSA levels + 1 + ceil(log2(B+1)) AND rounds, bit-width wires |
//! | OR-pool (fused)        | ceil(log2(k^2)) AND rounds, 0 tuples |

pub mod b2a;
pub mod binlinear;
pub mod linear;
pub mod maxpool;
pub mod msb;
pub mod preproc;
pub mod relu;
pub mod sign;
pub mod trunc;

use crate::prf::PartySeeds;
use crate::ring::Elem;
use crate::transport::Comm;

/// Validate a peer-sent element count (protocol-layer wire hardening; the
/// transport already validated framing, this checks protocol-level shape).
/// Delegates to the rss-layer validator and lifts the error to anyhow.
pub(crate) fn expect_elems(v: Vec<Elem>, n: usize)
                           -> anyhow::Result<Vec<Elem>> {
    Ok(crate::rss::expect_len(v, n)?)
}

/// Security / correctness knobs for the masked protocols.
#[derive(Clone, Copy, Debug)]
pub struct ProtoConfig {
    /// Guaranteed bound on |x| for MSB inputs: |x| < 2^bound_bits.
    /// The AOT exporter enforces this on every linear-layer output
    /// (export.py `_SAFE_BITS`).
    pub bound_bits: u32,
    /// Multiplicative-mask width for MSB: r is drawn from [1, 2^mask_bits].
    /// Constraint: bound_bits + 1 + mask_bits <= 31 (no overflow in u).
    pub mask_bits: u32,
    /// Statistical-mask headroom for truncation.
    pub trunc_sigma: u32,
}

impl Default for ProtoConfig {
    fn default() -> Self {
        ProtoConfig { bound_bits: 24, mask_bits: 5, trunc_sigma: 6 }
    }
}

impl ProtoConfig {
    pub fn validate(&self) {
        assert!(self.bound_bits + 1 + self.mask_bits <= 31,
                "MSB mask would overflow the ring");
    }
}

/// Per-party protocol context.
pub struct Ctx<'a> {
    pub comm: &'a Comm,
    pub seeds: &'a PartySeeds,
    pub cfg: ProtoConfig,
}

impl<'a> Ctx<'a> {
    pub fn new(comm: &'a Comm, seeds: &'a PartySeeds) -> Self {
        let cfg = ProtoConfig::default();
        cfg.validate();
        Ctx { comm, seeds, cfg }
    }

    pub fn with_cfg(comm: &'a Comm, seeds: &'a PartySeeds,
                    cfg: ProtoConfig) -> Self {
        cfg.validate();
        Ctx { comm, seeds, cfg }
    }

    pub fn id(&self) -> usize {
        self.comm.id
    }

    /// Run `f` under a `Protocol` trace span labelled `label`: the
    /// span's rounds/bytes are the bound channel's counter deltas
    /// across the body (the `cost_row` snapshot-diff pattern).  With
    /// no sink installed or tracing off this is one atomic load and a
    /// direct call -- the protocol hot path stays allocation-free.
    pub fn span<R>(&self, label: &str, f: impl FnOnce() -> R) -> R {
        match self.comm.tracer().filter(|t| t.enabled()) {
            None => f(),
            Some(tr) => {
                let cur = tr.cursor(self.comm);
                let out = f();
                tr.close(self.comm, crate::trace::SpanKind::Protocol, 0,
                         label, &cur);
                out
            }
        }
    }
}

#[cfg(test)]
pub(crate) mod testsupport {
    //! Shared three-party test harness for protocol tests (backed by
    //! `testutil::threeparty`, which integration tests use directly).
    use super::*;
    use crate::transport::Stats;

    /// Run the same closure on three party threads and collect results in
    /// party order (fixed legacy session seed 4242).
    pub fn run3<F, R>(f: F) -> Vec<(R, Stats)>
    where
        F: Fn(&Ctx) -> R + Send + Sync,
        R: Send,
    {
        crate::testutil::threeparty::run3_seeded(4242, f)
    }
}
