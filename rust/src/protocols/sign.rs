//! Secure Sign (Algorithm 4): Sign(x) = 1 XOR MSB(x) in {0,1}.
//!
//! Produces the activation bit both as binary shares (free local NOT on
//! the MSB shares) and, via the B2A conversion, as arithmetic shares the
//! next linear layer / maxpool consumes.

use crate::rss::{BitShare, Share};

use super::{msb::msb_extract_full, Ctx};

/// [Sign(x)]^B = NOT [MSB(x)]^B -- local once the MSB shares exist.
pub fn sign_bits(ctx: &Ctx, msb: &BitShare) -> BitShare {
    let ones = vec![1u8; msb.len()];
    msb.xor_const(ctx.id(), &ones)
}

/// Full secure Sign from arithmetic input shares.  The arithmetic output
/// shares come for free from the MSB protocol's revealed mask (see
/// msb::MsbOut): Algorithm 4 adds zero rounds to Algorithm 3.
/// Returns (arithmetic bit shares, msb bit shares); the caller reuses the
/// MSB shares for ReLU-style selections.
pub fn sign(ctx: &Ctx, x: &Share) -> (Share, BitShare) {
    let out = msb_extract_full(ctx, x);
    (out.sign_a, out.bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::{self, Tensor};
    use crate::rss::{deal, reconstruct};
    use crate::testutil::Rng;

    #[test]
    fn sign_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(23);
            let vals: Vec<i32> = (0..100).map(|_| rng.small(1 << 20))
                .collect();
            let x = Tensor::from_vec(&[100], vals.clone());
            let shares = deal(&x, &mut rng);
            let (arith, _) = sign(ctx, &shares[ctx.id()]);
            (arith, vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for i in 0..vals.len() {
            assert_eq!(got.data[i], ring::sign_bit(vals[i]) as i32,
                       "x = {}", vals[i]);
        }
    }

    #[test]
    fn sign_of_zero_is_one() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = Tensor::from_vec(&[4], vec![0, 0, 5, -5]);
            let shares = deal(&x, &mut rng);
            sign(ctx, &shares[ctx.id()]).0
        });
        let shares: [Share; 3] = std::array::from_fn(|i| results[i].0.clone());
        assert_eq!(reconstruct(&shares).data, vec![1, 1, 1, 0]);
    }
}
