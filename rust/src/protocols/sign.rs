//! Secure Sign (Algorithm 4): Sign(x) = 1 XOR MSB(x) in {0,1}.
//!
//! Produces the activation bit both as binary shares (free local NOT on
//! the word-packed MSB shares) and, via the B2A conversion, as arithmetic
//! shares the next linear layer / maxpool consumes.

use anyhow::Result;

use crate::rss::{BitShare, Share};

use super::{msb::msb_extract_full, Ctx};

/// `[Sign(x)]^B = NOT [MSB(x)]^B` -- local (one word-parallel XOR with the
/// public all-ones vector, folded into the y_0 slot).
pub fn sign_bits(ctx: &Ctx, msb: &BitShare) -> BitShare {
    msb.not(ctx.id())
}

/// Full secure Sign from arithmetic input shares.  The arithmetic output
/// shares come for free from the MSB protocol's revealed mask (see
/// msb::MsbOut): Algorithm 4 adds zero rounds to Algorithm 3.
/// Returns (arithmetic bit shares, msb bit shares); the caller reuses the
/// MSB shares for ReLU-style selections.
pub fn sign(ctx: &Ctx, x: &Share) -> Result<(Share, BitShare)> {
    let out = msb_extract_full(ctx, x)?;
    Ok((out.sign_a, out.bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::{self, Tensor};
    use crate::rss::{deal, deal_bits, reconstruct, reconstruct_bits};
    use crate::testutil::Rng;

    #[test]
    fn sign_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(23);
            let vals: Vec<i32> = (0..100).map(|_| rng.small(1 << 20))
                .collect();
            let x = Tensor::from_vec(&[100], vals.clone());
            let shares = deal(&x, &mut rng);
            let (arith, _) = sign(ctx, &shares[ctx.id()]).unwrap();
            (arith, vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for i in 0..vals.len() {
            assert_eq!(got.data[i], ring::sign_bit(vals[i]) as i32,
                       "x = {}", vals[i]);
        }
    }

    #[test]
    fn sign_of_zero_is_one() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = Tensor::from_vec(&[4], vec![0, 0, 5, -5]);
            let shares = deal(&x, &mut rng);
            sign(ctx, &shares[ctx.id()]).unwrap().0
        });
        let shares: [Share; 3] = std::array::from_fn(|i| results[i].0.clone());
        assert_eq!(reconstruct(&shares).data, vec![1, 1, 1, 0]);
    }

    #[test]
    fn sign_bits_is_local_not() {
        // free NOT: no communication, word-packed end to end
        let results = run3(|ctx| {
            let mut rng = Rng::new(3);
            let bits: Vec<u8> = (0..130).map(|_| rng.bit()).collect();
            let shares = deal_bits(&bits, &mut rng);
            ctx.comm.reset_stats();
            let s = sign_bits(ctx, &shares[ctx.id()]);
            assert_eq!(ctx.comm.stats().bytes_sent, 0);
            (s, bits)
        });
        let bits = results[0].0 .1.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let want: Vec<u8> = bits.iter().map(|&b| 1 ^ b).collect();
        assert_eq!(reconstruct_bits(&shares), want);
    }
}
