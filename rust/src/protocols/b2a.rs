//! Binary-to-arithmetic share conversion via the 3-party OT (paper
//! Section 3.3 "Share Conversion").
//!
//! Given RSS bit shares `[y]^B` with components (y_0, y_1, y_2):
//!
//! * P1 knows (y_1, y_2) and acts as OT *sender* with messages
//!   m_i = (i XOR y_1 XOR y_2) - a, where the mask a = a_1 + a_2,
//!   a_1 = PRF(k_1) (free with P0), a_2 sent to P2 (Alg. 3 step 3's
//!   "P1 generates alpha_1, alpha_2 and sends alpha_2 to P2").
//! * P0 (receiver) and P2 (helper) input the choice bit y_0, so P0 learns
//!   m_{y_0} = y - a.
//! * P0 forwards m_{y_0} to P2, establishing the RSS component layout
//!   x_0 = y - a (P0, P2), x_1 = a_1 (P0, P1), x_2 = a_2 (P1, P2).
//!
//! Critical path: OT (2 rounds) + the P0->P2 forward (1 round); the
//! a_2 distribution is piggybacked on the OT's sender->helper payload
//! frame, so P1 ships exactly one frame to P2 per conversion.
//!
//! The bit shares stay word-packed end to end: the sender's y_1 ^ y_2 is
//! one word-parallel XOR, and the choice bits feed the OT as `BitTensor`s.

use anyhow::Result;

use crate::ot;
use crate::prf::{domain, PrfStream};
use crate::ring::{Elem, Tensor};
use crate::rss::{BitShare, Share};
use crate::transport::Dir;

use super::{expect_elems, Ctx};

/// Convert RSS bit shares into RSS arithmetic shares of the same bits.
pub fn b2a(ctx: &Ctx, y: &BitShare) -> Result<Share> {
    ctx.span("b2a", || b2a_inner(ctx, y))
}

fn b2a_inner(ctx: &Ctx, y: &BitShare) -> Result<Share> {
    let n = y.len();
    let me = ctx.id();
    let cnt = ctx.seeds.next_cnt();
    let roles = ot::Roles::new(1, 0, 2);
    let shape = [n];

    match me {
        1 => {
            // a_1 from PRF(k_1) -- P1.mine = k_1, shared with P0
            let mut s1 = PrfStream::new(&ctx.seeds.mine, cnt, domain::SHARE);
            let a1: Vec<Elem> = (0..n).map(|_| s1.next_elem()).collect();
            // a_2 private, sent to P2
            let mut sp = PrfStream::new(&ctx.seeds.private, cnt, domain::SHARE);
            let a2: Vec<Elem> = (0..n).map(|_| sp.next_elem()).collect();
            let y12 = y.a.xor(&y.b); // y_1 ^ y_2, word-parallel (kernel)
            // message walk iterates the packed words directly: one shift
            // per bit instead of a div/mod-indexed get() per element
            let mut m0: Vec<Elem> = Vec::with_capacity(n);
            let mut m1: Vec<Elem> = Vec::with_capacity(n);
            let mut i = 0;
            for &word in y12.words() {
                let mut w = word;
                let lim = (n - i).min(64);
                for _ in 0..lim {
                    let bit = (w & 1) as Elem;
                    let mask = a1[i].wrapping_add(a2[i]);
                    m0.push(bit.wrapping_sub(mask));
                    m1.push((bit ^ 1).wrapping_sub(mask));
                    w >>= 1;
                    i += 1;
                }
            }
            // a_2 rides the OT payload frame: one frame P1->P2
            ot::run_piggybacked(ctx.comm, ctx.seeds, roles, n,
                                ot::Input::Sender { m0: &m0, m1: &m1 },
                                ot::Extra::Send(&a2))?;
            // P1 holds (x_1, x_2) = (a_1, a_2)
            Ok(Share {
                a: Tensor::from_vec(&shape, a1),
                b: Tensor::from_vec(&shape, a2),
            })
        }
        0 => {
            let mut s1 = PrfStream::new(&ctx.seeds.next, cnt, domain::SHARE);
            let a1: Vec<Elem> = (0..n).map(|_| s1.next_elem()).collect();
            let x0 = ot::run(ctx.comm, ctx.seeds, roles, n,
                             ot::Input::Receiver { c: &y.a })?
                .expect("receiver output");
            // forward x_0 to P2 (replication)
            ctx.comm.send_elems(Dir::Prev, &x0)?;
            ctx.comm.round();
            // P0 holds (x_0, x_1) = (y - a, a_1)
            Ok(Share {
                a: Tensor::from_vec(&shape, x0),
                b: Tensor::from_vec(&shape, a1),
            })
        }
        2 => {
            // helper input: choice bit y_0 = this party's `b` component;
            // a_2 arrives prepended to the OT payload frame
            let (_, rider) = ot::run_piggybacked(
                ctx.comm, ctx.seeds, roles, n,
                ot::Input::Helper { c: &y.b }, ot::Extra::Recv(n))?;
            let a2 = rider.expect("piggybacked a_2");
            let x0 = expect_elems(ctx.comm.recv_elems(Dir::Next)?, n)?;
            ctx.comm.round();
            // P2 holds (x_2, x_0) = (a_2, y - a)
            Ok(Share {
                a: Tensor::from_vec(&shape, a2),
                b: Tensor::from_vec(&shape, x0),
            })
        }
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::rss::{deal_bits, reconstruct};
    use crate::testutil::Rng;

    #[test]
    fn b2a_preserves_bits() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(11);
            let bits: Vec<u8> = (0..100).map(|_| rng.bit()).collect();
            let shares = deal_bits(&bits, &mut rng);
            (b2a(ctx, &shares[ctx.id()]).unwrap(), bits)
        });
        let bits = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for i in 0..bits.len() {
            assert_eq!(got.data[i], bits[i] as i32, "i={i}");
        }
        // replication consistency
        for i in 0..3 {
            assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
        }
    }

    #[test]
    fn b2a_handles_all_zero_and_all_one() {
        for fill in [0u8, 1u8] {
            let results = run3(move |ctx| {
                let mut rng = Rng::new(5 + fill as u64);
                let bits = vec![fill; 16];
                let shares = deal_bits(&bits, &mut rng);
                b2a(ctx, &shares[ctx.id()]).unwrap()
            });
            let shares: [Share; 3] =
                std::array::from_fn(|i| results[i].0.clone());
            let got = reconstruct(&shares);
            assert!(got.data.iter().all(|&v| v == fill as i32));
        }
    }

    #[test]
    fn b2a_round_budget() {
        // P0 (receiver + forward) must stay within 3 critical-path rounds.
        let results = run3(|ctx| {
            let mut rng = Rng::new(2);
            let bits: Vec<u8> = (0..8).map(|_| rng.bit()).collect();
            let shares = deal_bits(&bits, &mut rng);
            let _ = b2a(ctx, &shares[ctx.id()]).unwrap();
        });
        assert!(results[0].1.rounds <= 3,
                "P0 rounds = {}", results[0].1.rounds);
    }
}
