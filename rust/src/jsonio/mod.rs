//! Minimal JSON parser/serializer (serde_json is not in the offline crate
//! set -- see DESIGN.md substitutions).  Covers everything the manifests
//! and experiment files use: objects, arrays, strings (with escapes),
//! integers, floats, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Typed parse failure: what went wrong and the byte offset it went
/// wrong at.  Manifest loading (`nn::LoadError::Json`) and the trace
/// importer surface this instead of a bare string so tests can assert
/// on the failure class, not on message wording.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub kind: JsonErrorKind,
}

#[derive(Clone, Debug, PartialEq)]
pub enum JsonErrorKind {
    /// Input ended mid-value (truncated file).
    Truncated,
    /// A malformed token (bad literal, bad number, bad escape).
    BadToken(String),
    /// Structural violation (missing `:`/`,`, unterminated string...).
    Syntax(String),
    /// Bytes left over after the top-level value.
    TrailingData,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.kind {
            JsonErrorKind::Truncated => {
                write!(f, "unexpected end of input at byte {}", self.pos)
            }
            JsonErrorKind::BadToken(m) | JsonErrorKind::Syntax(m) => {
                write!(f, "{m} at byte {}", self.pos)
            }
            JsonErrorKind::TrailingData => {
                write!(f, "trailing data at byte {}", self.pos)
            }
        }
    }
}

impl std::error::Error for JsonError {}

impl From<JsonError> for String {
    fn from(e: JsonError) -> String {
        e.to_string()
    }
}

fn err<T>(pos: usize, kind: JsonErrorKind) -> Result<T, JsonError> {
    Err(JsonError { pos, kind })
}

fn syntax<T>(pos: usize, msg: impl Into<String>) -> Result<T, JsonError> {
    err(pos, JsonErrorKind::Syntax(msg.into()))
}

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            Json::Float(v) => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(v) => Some(*v as f64),
            Json::Float(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `obj.field(k)` with a descriptive error.
    pub fn field(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

// ---------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------
pub fn parse(s: &str) -> Result<Json, JsonError> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return err(pos, JsonErrorKind::TrailingData);
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return err(*pos, JsonErrorKind::Truncated);
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_number(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json)
       -> Result<Json, JsonError> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        err(*pos, JsonErrorKind::BadToken("invalid literal".into()))
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if *pos < b.len() && (b[*pos] == b'-' || b[*pos] == b'+') {
        *pos += 1;
    }
    let mut is_float = false;
    while *pos < b.len() {
        match b[*pos] {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'-' | b'+' => {
                is_float = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let Ok(text) = std::str::from_utf8(&b[start..*pos]) else {
        return err(start, JsonErrorKind::BadToken("bad utf8 in number".into()));
    };
    if is_float {
        text.parse::<f64>().map(Json::Float).map_err(|e| JsonError {
            pos: start,
            kind: JsonErrorKind::BadToken(format!("bad float '{text}': {e}")),
        })
    } else {
        text.parse::<i64>().map(Json::Int).map_err(|e| JsonError {
            pos: start,
            kind: JsonErrorKind::BadToken(format!("bad int '{text}': {e}")),
        })
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    if b[*pos] != b'"' {
        return syntax(*pos, "expected string");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return err(*pos, JsonErrorKind::Truncated);
                        }
                        let cp = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok());
                        let Some(cp) = cp else {
                            return err(*pos, JsonErrorKind::BadToken(
                                "bad \\u escape".into()));
                        };
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => {
                        return err(*pos, JsonErrorKind::BadToken(
                            format!("bad escape \\{}", c as char)));
                    }
                }
                *pos += 1;
            }
            c => {
                // copy raw utf8 bytes through
                let len = utf8_len(c);
                let Ok(frag) = std::str::from_utf8(
                    &b[*pos..(*pos + len).min(b.len())]) else {
                    return err(*pos, JsonErrorKind::BadToken(
                        "bad utf8".into()));
                };
                out.push_str(frag);
                *pos += len;
            }
        }
    }
    err(b.len(), JsonErrorKind::Truncated)
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            None => return err(*pos, JsonErrorKind::Truncated),
            _ => return syntax(*pos, "expected , or ]"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '{'
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return syntax(*pos, "expected :");
        }
        *pos += 1;
        out.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            None => return err(*pos, JsonErrorKind::Truncated),
            _ => return syntax(*pos, "expected , or }"),
        }
    }
}

// ---------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Int(i) => {
            let _ = write!(out, "{i}");
        }
        Json::Float(f) => {
            if f.is_finite() {
                let _ = write!(out, "{f}");
            } else {
                out.push_str("null");
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(e, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(e, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{
  "name": "mnistnet1",
  "s_in": 7, "ring_bits": 32,
  "layers": [
    {"op": "flatten", "c": 1},
    {"op": "matmul", "conv": false, "w": {"off": 0, "len": 100352},
     "m": 128, "n": 1}
  ]
}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "mnistnet1");
        assert_eq!(v.get("s_in").unwrap().as_i64().unwrap(), 7);
        let layers = v.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[1].get("w").unwrap().get("len").unwrap()
                   .as_usize().unwrap(), 100_352);
        assert_eq!(layers[1].get("conv").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Int(-5)),
            ("b", Json::Arr(vec![Json::Float(1.5), Json::Null,
                                 Json::Bool(true)])),
            ("s", Json::Str("he\"llo\nworld".into())),
        ]);
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(parse("2.5e3").unwrap(), Json::Float(2500.0));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""Ab""#).unwrap(), Json::Str("Ab".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{bad}").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_error_kinds() {
        // a manifest cut off mid-stream is Truncated, not generic syntax
        let full = r#"{"name": "m", "layers": [{"op": "sign"}]}"#;
        for cut in [5, 12, 20, full.len() - 1] {
            let e = parse(&full[..cut]).unwrap_err();
            assert_eq!(e.kind, JsonErrorKind::Truncated, "cut at {cut}: {e}");
        }
        assert_eq!(parse("{}x").unwrap_err().kind,
                   JsonErrorKind::TrailingData);
        assert!(matches!(parse("{bad}").unwrap_err().kind,
                         JsonErrorKind::Syntax(_)));
        assert!(matches!(parse("trne").unwrap_err().kind,
                         JsonErrorKind::BadToken(_)));
        // errors carry the byte position for operator diagnostics
        assert_eq!(parse("{}x").unwrap_err().pos, 2);
    }

    #[test]
    fn nested_depth() {
        let mut s = String::new();
        for _ in 0..50 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..50 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }
}
