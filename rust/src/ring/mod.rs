//! Arithmetic over the ring Z_{2^32} and fixed-point encoding.
//!
//! All secret shares live in `Z_{2^32}`, represented as `i32` with
//! two's-complement wrap-around (`wrapping_*` ops).  This matches both the
//! paper's `l = 32` setting and the XLA `s32` semantics of the AOT
//! artifacts, so the PJRT path and the native path are bit-identical.

pub mod bits;
pub mod kernel;
pub mod planes;
pub mod tensor;

pub use bits::BitTensor;
pub use planes::{BitPlanes, BitQueue, PlanesView};
pub use tensor::Tensor;

/// Ring element (alias to make intent explicit at API boundaries).
pub type Elem = i32;

/// Wrapping addition in Z_{2^32}.
#[inline(always)]
pub fn add(a: Elem, b: Elem) -> Elem {
    a.wrapping_add(b)
}

/// Wrapping subtraction in Z_{2^32}.
#[inline(always)]
pub fn sub(a: Elem, b: Elem) -> Elem {
    a.wrapping_sub(b)
}

/// Wrapping multiplication in Z_{2^32}.
#[inline(always)]
pub fn mul(a: Elem, b: Elem) -> Elem {
    a.wrapping_mul(b)
}

/// Wrapping negation.
#[inline(always)]
pub fn neg(a: Elem) -> Elem {
    a.wrapping_neg()
}

/// Most significant bit (the paper's `MSB`): 1 iff `a < 0` as two's
/// complement, i.e. `a in [2^31, 2^32)` unsigned.
#[inline(always)]
pub fn msb(a: Elem) -> u8 {
    (a < 0) as u8
}

/// The paper's Sign activation bit: `1 ^ MSB(a)`, i.e. 1 iff `a >= 0`.
#[inline(always)]
pub fn sign_bit(a: Elem) -> u8 {
    (a >= 0) as u8
}

/// Arithmetic-shift truncation by `f` fractional bits (signed division by
/// 2^f rounding toward negative infinity) -- the local step of the
/// truncation protocol.
#[inline(always)]
pub fn trunc(a: Elem, f: u32) -> Elem {
    a >> f
}

/// Encode a float into fixed point with `f` fractional bits (wrapping).
#[inline]
pub fn encode(v: f64, f: u32) -> Elem {
    let scaled = (v * f64::from(1u32 << f)).round();
    // wrap into i32 range like numpy int64 -> int32 cast
    (scaled as i64) as Elem
}

/// Decode a fixed-point ring element back to a float.
#[inline]
pub fn decode(a: Elem, f: u32) -> f64 {
    f64::from(a) / f64::from(1u32 << f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn wrapping_semantics() {
        assert_eq!(add(i32::MAX, 1), i32::MIN);
        assert_eq!(mul(1 << 30, 4), 0);
        assert_eq!(sub(i32::MIN, 1), i32::MAX);
        assert_eq!(neg(i32::MIN), i32::MIN);
    }

    #[test]
    fn msb_and_sign() {
        assert_eq!(msb(-1), 1);
        assert_eq!(msb(0), 0);
        assert_eq!(msb(i32::MIN), 1);
        assert_eq!(sign_bit(0), 1);
        assert_eq!(sign_bit(-5), 0);
        assert_eq!(sign_bit(7), 1);
        // sign_bit == 1 ^ msb, always
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let x = rng.next_i32();
            assert_eq!(sign_bit(x), 1 ^ msb(x));
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let v = (rng.next_i32() % 10_000) as f64 / 100.0;
            let e = encode(v, 12);
            assert!((decode(e, 12) - v).abs() < 1.0 / 4096.0 + 1e-9);
        }
    }

    #[test]
    fn trunc_matches_float_division() {
        for &(v, f) in &[(4096i32, 12u32), (-4096, 12), (12345, 8), (-777, 4)] {
            let t = trunc(v, f);
            let expect = (f64::from(v) / f64::from(1u32 << f)).floor();
            assert_eq!(f64::from(t), expect);
        }
    }

    #[test]
    fn ring_is_commutative_and_associative() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let (a, b, c) = (rng.next_i32(), rng.next_i32(), rng.next_i32());
            assert_eq!(add(a, b), add(b, a));
            assert_eq!(add(add(a, b), c), add(a, add(b, c)));
            assert_eq!(mul(a, b), mul(b, a));
            assert_eq!(mul(mul(a, b), c), mul(a, mul(b, c)));
            assert_eq!(mul(a, add(b, c)), add(mul(a, b), mul(a, c)));
        }
    }
}
