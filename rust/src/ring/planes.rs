//! Strided bit-plane matrices: the batch representation for boolean
//! adder circuits.
//!
//! The bit-decomposition baseline (and any future boolean circuit over
//! ring elements) works on L = 32 *bit-planes* of n elements each.  PR 1
//! stored each plane as its own `BitTensor` and stitched the per-level
//! Kogge-Stone operands together with `extend`/`slice`, copying O(L*n)
//! bits per adder level.  `BitPlanes` removes those copies structurally:
//!
//! * **plane-major, equal stride** -- plane `p` occupies words
//!   `[p*W, (p+1)*W)` of one contiguous allocation, `W = ceil(len/64)`.
//!   A *range of planes* is therefore a contiguous word slice, and every
//!   Kogge-Stone operand (`p[dist..L]`, `g[0..L-dist]`, ...) is a
//!   zero-copy row selection;
//! * **`shift_planes(dist)`** remaps row indices instead of moving bits:
//!   row `r` of the shifted view reads row `r - dist` of the source
//!   (all-zero below the shift) -- the carry wire `t = (maj ^ b) << 1`
//!   costs pointer arithmetic, not a 32n-bit copy;
//! * **whole-matrix ops** (XOR/AND/NOT/popcount) run over the backing
//!   words through `ring::kernel`'s unrolled loops;
//! * **wire reinterpret** -- the word buffer *is* a valid `BitTensor`
//!   word buffer of `planes * W * 64` bits, so transport ships a
//!   `BitPlanes` verbatim (`into_tensor`/`from_tensor`, no repack).
//!   Each plane keeps the `BitTensor` tail invariant (bits past `len`
//!   zero), which `from_tensor` re-establishes against dirty peer
//!   padding.
//!
//! The module also hosts `BitQueue`, the 1-plane degenerate case of the
//! same idea: a FIFO bit reservoir that advances a *head index* on draw
//! instead of re-shifting the whole pool (`protocols::preproc`).

use std::ops::Range;

use crate::ring::bits::{BitTensor, WORD_BITS};
use crate::ring::kernel;

/// A `planes x len` bit matrix, plane-major, every plane padded to the
/// same word width.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitPlanes {
    planes: usize,
    /// Bits per plane.
    len: usize,
    /// Words per plane: `len.div_ceil(64)`.
    width: usize,
    /// `planes * width` words, plane-major.
    words: Vec<u64>,
}

impl BitPlanes {
    // ---- constructors ---------------------------------------------------
    pub fn zeros(planes: usize, len: usize) -> Self {
        let width = len.div_ceil(WORD_BITS);
        BitPlanes { planes, len, width, words: vec![0u64; planes * width] }
    }

    /// Copy equal-length tensors in as planes (plaintext/test boundary).
    pub fn from_tensors(rows: &[BitTensor]) -> Self {
        let len = rows.first().map_or(0, BitTensor::len);
        let mut out = Self::zeros(rows.len(), len);
        for (p, t) in rows.iter().enumerate() {
            assert_eq!(t.len(), len, "plane length mismatch");
            out.plane_words_mut(p).copy_from_slice(t.words());
        }
        out
    }

    /// The arithmetic -> boolean packing boundary: plane `p`, bit `i` is
    /// bit `p` of `vals[i]`.  Writes straight into the strided buffer --
    /// one allocation for all `planes` planes, no per-plane tensors.
    pub fn from_elem_bits(vals: &[i32], planes: usize) -> Self {
        assert!(planes <= 32, "i32 has 32 bit-planes");
        let mut out = Self::zeros(planes, vals.len());
        let width = out.width;
        for (w, chunk) in vals.chunks(WORD_BITS).enumerate() {
            for p in 0..planes {
                let mut word = 0u64;
                for (b, &v) in chunk.iter().enumerate() {
                    word |= u64::from((v as u32 >> p) & 1) << b;
                }
                out.words[p * width + w] = word;
            }
        }
        out
    }

    // ---- accessors ------------------------------------------------------
    pub fn planes(&self) -> usize {
        self.planes
    }

    /// Bits per plane.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.planes == 0 || self.len == 0
    }

    /// Words per plane (the row stride).
    pub fn width_words(&self) -> usize {
        self.width
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn plane_words(&self, p: usize) -> &[u64] {
        &self.words[p * self.width..(p + 1) * self.width]
    }

    /// Mutable words of one plane (kernel write target; the caller keeps
    /// the per-plane tail invariant, e.g. via `mask_tails`).
    pub fn plane_words_mut(&mut self, p: usize) -> &mut [u64] {
        &mut self.words[p * self.width..(p + 1) * self.width]
    }

    /// Copy one plane out as a `BitTensor` (share/wire boundary for
    /// single-plane results; `W` words, tail already clean).
    pub fn plane(&self, p: usize) -> BitTensor {
        BitTensor::from_words(self.len, self.plane_words(p).to_vec())
    }

    #[inline]
    pub fn get(&self, p: usize, i: usize) -> u8 {
        debug_assert!(p < self.planes && i < self.len);
        let w = self.words[p * self.width + i / WORD_BITS];
        ((w >> (i % WORD_BITS)) & 1) as u8
    }

    /// Total padded bit count (`planes * width * 64`): the length of the
    /// reinterpreted wire tensor.
    pub fn padded_bits(&self) -> usize {
        self.planes * self.width * WORD_BITS
    }

    // ---- whole-matrix word-parallel ops ---------------------------------
    fn assert_shape(&self, rhs: &BitPlanes) {
        assert!(self.planes == rhs.planes && self.len == rhs.len,
                "plane shape mismatch: {}x{} vs {}x{}",
                self.planes, self.len, rhs.planes, rhs.len);
    }

    pub fn xor(&self, rhs: &BitPlanes) -> BitPlanes {
        self.assert_shape(rhs);
        let mut out = self.clone();
        kernel::xor_in_place(&mut out.words, &rhs.words);
        out
    }

    pub fn xor_assign(&mut self, rhs: &BitPlanes) {
        self.assert_shape(rhs);
        kernel::xor_in_place(&mut self.words, &rhs.words);
    }

    pub fn and(&self, rhs: &BitPlanes) -> BitPlanes {
        self.assert_shape(rhs);
        let mut out = Self::zeros(self.planes, self.len);
        kernel::and_into(&mut out.words, &self.words, &rhs.words);
        out
    }

    /// Complement every plane (per-plane tails re-masked).
    pub fn not(&self) -> BitPlanes {
        let mut out = Self::zeros(self.planes, self.len);
        kernel::not_into(&mut out.words, &self.words);
        out.mask_tails();
        out
    }

    pub fn popcount(&self) -> usize {
        kernel::popcount(&self.words)
    }

    // ---- zero-copy views ------------------------------------------------
    /// View of all planes.
    pub fn view(&self) -> PlanesView<'_> {
        PlanesView { src: self, start: 0, count: self.planes }
    }

    /// View of a contiguous plane range (zero-copy row selection).
    pub fn rows(&self, r: Range<usize>) -> PlanesView<'_> {
        assert!(r.start <= r.end && r.end <= self.planes,
                "plane range out of bounds");
        PlanesView { src: self, start: r.start as isize,
                     count: r.end - r.start }
    }

    /// The level-shift trick: a view of the same plane count whose row
    /// `r` reads source row `r - dist` (all-zero for `r < dist`).  This is
    /// `matrix << dist` along the plane axis by *index remap* -- no bits
    /// move.
    pub fn shift_planes(&self, dist: usize) -> PlanesView<'_> {
        PlanesView { src: self, start: -(dist as isize), count: self.planes }
    }

    // ---- word-aligned row mutation (the Kogge-Stone update step) --------
    /// `self[dst_start + j] = src[src_rows.start + j]` for each row of the
    /// range: one contiguous word-level memcpy (rows are adjacent in both
    /// matrices), never a bit-granular shift.
    pub fn copy_rows_from(&mut self, dst_start: usize, src: &BitPlanes,
                          src_rows: Range<usize>) {
        assert_eq!(self.len, src.len, "row length mismatch");
        let k = src_rows.end - src_rows.start;
        assert!(dst_start + k <= self.planes && src_rows.end <= src.planes,
                "row range out of bounds");
        let w = self.width;
        self.words[dst_start * w..(dst_start + k) * w]
            .copy_from_slice(&src.words[src_rows.start * w..src_rows.end * w]);
    }

    /// `self[dst_start + j] ^= src[src_rows.start + j]`, word-parallel
    /// over the whole contiguous row block.
    pub fn xor_rows_from(&mut self, dst_start: usize, src: &BitPlanes,
                         src_rows: Range<usize>) {
        assert_eq!(self.len, src.len, "row length mismatch");
        let k = src_rows.end - src_rows.start;
        assert!(dst_start + k <= self.planes && src_rows.end <= src.planes,
                "row range out of bounds");
        let w = self.width;
        kernel::xor_in_place(
            &mut self.words[dst_start * w..(dst_start + k) * w],
            &src.words[src_rows.start * w..src_rows.end * w]);
    }

    // ---- wire reinterpret (no repack) -----------------------------------
    /// Reinterpret as a `BitTensor` of `padded_bits()` bits: the word
    /// buffer moves, nothing is repacked.  The padded length is a
    /// multiple of 64, so the tensor's tail invariant holds trivially;
    /// per-plane tails were already zero.
    pub fn into_tensor(self) -> BitTensor {
        let bits = self.padded_bits();
        BitTensor::from_words(bits, self.words)
    }

    /// Inverse reinterpret: adopt a received tensor's word buffer as a
    /// `planes x len` matrix.  Returns `None` when the tensor's bit count
    /// is not exactly the padded size -- the caller treats that as a
    /// malformed message.  Per-plane tail bits (wire padding a malicious
    /// peer controls) are cleared.
    pub fn from_tensor(t: BitTensor, planes: usize, len: usize)
                       -> Option<BitPlanes> {
        let width = len.div_ceil(WORD_BITS);
        if t.len() != planes * width * WORD_BITS {
            return None;
        }
        let mut out = BitPlanes { planes, len, width, words: t.into_words() };
        out.mask_tails();
        Some(out)
    }

    // ---- internal -------------------------------------------------------
    pub(crate) fn mask_tails(&mut self) {
        let off = self.len % WORD_BITS;
        if off == 0 || self.width == 0 {
            return;
        }
        let mask = (1u64 << off) - 1;
        let w = self.width;
        for p in 0..self.planes {
            self.words[p * w + w - 1] &= mask;
        }
    }
}

/// A zero-copy row-remapped window over a `BitPlanes`: row `r` reads
/// source row `start + r`, and rows that fall outside the source
/// (`shift_planes`) read as all-zero.
#[derive(Clone, Copy)]
pub struct PlanesView<'a> {
    src: &'a BitPlanes,
    /// Source row of view row 0 (negative for a shifted-in zero prefix).
    start: isize,
    count: usize,
}

impl<'a> PlanesView<'a> {
    pub fn count(&self) -> usize {
        self.count
    }

    /// Bits per plane.
    pub fn len(&self) -> usize {
        self.src.len
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn width_words(&self) -> usize {
        self.src.width
    }

    /// The words of view row `r`, or `None` for an all-zero (shifted-in)
    /// row.
    pub fn row_words(&self, r: usize) -> Option<&'a [u64]> {
        assert!(r < self.count, "view row out of bounds");
        let s = self.start + r as isize;
        if s < 0 || s as usize >= self.src.planes {
            None
        } else {
            Some(self.src.plane_words(s as usize))
        }
    }

    /// Materialize the view (copies; boundary/test use only -- protocol
    /// code consumes views directly).
    pub fn materialize(&self) -> BitPlanes {
        let mut out = BitPlanes::zeros(self.count, self.src.len);
        for r in 0..self.count {
            if let Some(row) = self.row_words(r) {
                out.plane_words_mut(r).copy_from_slice(row);
            }
        }
        out
    }

    /// `self ^ rhs`, materialized (zero rows XOR as zero).
    pub fn xor(&self, rhs: &PlanesView<'_>) -> BitPlanes {
        assert!(self.count == rhs.count && self.src.len == rhs.src.len,
                "view shape mismatch");
        let mut out = BitPlanes::zeros(self.count, self.src.len);
        for r in 0..self.count {
            let dst = &mut out.words
                [r * self.src.width..(r + 1) * self.src.width];
            match (self.row_words(r), rhs.row_words(r)) {
                (Some(a), Some(b)) => kernel::xor_into(dst, a, b),
                (Some(a), None) | (None, Some(a)) => dst.copy_from_slice(a),
                (None, None) => {}
            }
        }
        out
    }
}

/// Word-aligned FIFO bit reservoir: `push` appends word-packed bits,
/// `pop_front` draws from the head by advancing an *index* -- O(drawn)
/// per draw instead of the O(pool) re-shift that `BitTensor::take_front`
/// pays.  Consumed whole words are reclaimed lazily.
#[derive(Clone, Debug, Default)]
pub struct BitQueue {
    words: Vec<u64>,
    /// Bits consumed from the front of `words` (stale storage before the
    /// head is reclaimed once it exceeds `RECLAIM_WORDS`).
    head: usize,
    /// Live bits.
    len: usize,
}

/// Reclaim consumed storage once this many whole words are stale.
const RECLAIM_WORDS: usize = 1024;

impl BitQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append a word-packed tensor's bits at the tail (shared splice
    /// arithmetic: `kernel::append_bits`).
    pub fn push(&mut self, bits: &BitTensor) {
        if bits.is_empty() {
            return;
        }
        let end = self.head + self.len;
        kernel::append_bits(&mut self.words, end, bits.words(), bits.len());
        self.len += bits.len();
    }

    /// Draw the first `n` bits (FIFO).  Panics on underflow -- a protocol
    /// desync, not a runtime state (mirrors the preprocessing contract).
    pub fn pop_front(&mut self, n: usize) -> BitTensor {
        assert!(n <= self.len, "bit queue underflow: need {n}, have {}",
                self.len);
        let out = kernel::copy_bits(&self.words, self.head, n);
        let t = BitTensor::from_words(n, out); // masks the tail
        self.head += n;
        self.len -= n;
        if self.head >= RECLAIM_WORDS * WORD_BITS {
            let stale = self.head / WORD_BITS;
            self.words.drain(..stale);
            self.head %= WORD_BITS;
        }
        if self.len == 0 {
            self.words.clear();
            self.head = 0;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    fn rand_tensor(rng: &mut Rng, n: usize) -> BitTensor {
        BitTensor::from_fn(n, |_| rng.bit())
    }

    #[test]
    fn plane_roundtrip_and_strides() {
        prop(40, |rng: &mut Rng| {
            let planes = rng.range(1, 33);
            let n = rng.range(1, 200);
            let rows: Vec<BitTensor> =
                (0..planes).map(|_| rand_tensor(rng, n)).collect();
            let m = BitPlanes::from_tensors(&rows);
            assert_eq!(m.planes(), planes);
            assert_eq!(m.len(), n);
            assert_eq!(m.width_words(), n.div_ceil(64));
            assert_eq!(m.words().len(), planes * m.width_words());
            for (p, row) in rows.iter().enumerate() {
                assert_eq!(&m.plane(p), row, "plane {p}");
                for i in 0..n {
                    assert_eq!(m.get(p, i), row.get(i));
                }
            }
        });
    }

    #[test]
    fn from_elem_bits_matches_per_plane_extraction() {
        prop(40, |rng: &mut Rng| {
            let n = rng.range(1, 150);
            let vals: Vec<i32> = (0..n).map(|_| rng.next_i32()).collect();
            let m = BitPlanes::from_elem_bits(&vals, 32);
            for p in 0..32u32 {
                let want = BitTensor::from_fn(n, |i| {
                    ((vals[i] as u32 >> p) & 1) as u8
                });
                assert_eq!(m.plane(p as usize), want, "plane {p}");
            }
        });
    }

    #[test]
    fn whole_matrix_ops_match_per_plane_ops() {
        prop(40, |rng: &mut Rng| {
            let planes = rng.range(1, 8);
            let n = rng.range(1, 130);
            let a: Vec<BitTensor> =
                (0..planes).map(|_| rand_tensor(rng, n)).collect();
            let b: Vec<BitTensor> =
                (0..planes).map(|_| rand_tensor(rng, n)).collect();
            let ma = BitPlanes::from_tensors(&a);
            let mb = BitPlanes::from_tensors(&b);
            let x = ma.xor(&mb);
            let y = ma.and(&mb);
            let z = ma.not();
            let mut pc = 0;
            for p in 0..planes {
                assert_eq!(x.plane(p), a[p].xor(&b[p]));
                assert_eq!(y.plane(p), a[p].and(&b[p]));
                assert_eq!(z.plane(p), a[p].not());
                pc += a[p].popcount();
            }
            assert_eq!(ma.popcount(), pc);
            let mut acc = ma.clone();
            acc.xor_assign(&mb);
            assert_eq!(acc, x);
        });
    }

    #[test]
    fn shift_planes_is_row_remap() {
        let mut rng = Rng::new(3);
        let rows: Vec<BitTensor> =
            (0..8).map(|_| rand_tensor(&mut rng, 77)).collect();
        let m = BitPlanes::from_tensors(&rows);
        for dist in 0..9 {
            let v = m.shift_planes(dist);
            assert_eq!(v.count(), 8);
            let mat = v.materialize();
            for r in 0..8 {
                if r < dist {
                    assert_eq!(mat.plane(r), BitTensor::zeros(77),
                               "zero row {r} at dist {dist}");
                    assert!(v.row_words(r).is_none());
                } else {
                    assert_eq!(mat.plane(r), rows[r - dist],
                               "row {r} at dist {dist}");
                    assert_eq!(v.row_words(r).unwrap(),
                               m.plane_words(r - dist));
                }
            }
        }
    }

    #[test]
    fn rows_view_is_zero_copy_selection() {
        let mut rng = Rng::new(5);
        let rows: Vec<BitTensor> =
            (0..10).map(|_| rand_tensor(&mut rng, 65)).collect();
        let m = BitPlanes::from_tensors(&rows);
        let v = m.rows(3..7);
        assert_eq!(v.count(), 4);
        for r in 0..4 {
            // the view hands back the *same* backing words, not a copy
            let got = v.row_words(r).unwrap();
            assert!(std::ptr::eq(got.as_ptr(), m.plane_words(3 + r).as_ptr()));
            assert_eq!(v.materialize().plane(r), rows[3 + r]);
        }
    }

    #[test]
    fn view_xor_handles_zero_rows() {
        let mut rng = Rng::new(9);
        let a: Vec<BitTensor> =
            (0..6).map(|_| rand_tensor(&mut rng, 100)).collect();
        let b: Vec<BitTensor> =
            (0..6).map(|_| rand_tensor(&mut rng, 100)).collect();
        let ma = BitPlanes::from_tensors(&a);
        let mb = BitPlanes::from_tensors(&b);
        let x = ma.view().xor(&mb.shift_planes(2));
        for r in 0..6 {
            let want = if r < 2 {
                a[r].clone()
            } else {
                a[r].xor(&b[r - 2])
            };
            assert_eq!(x.plane(r), want, "row {r}");
        }
    }

    #[test]
    fn row_mutation_matches_per_plane_ops() {
        let mut rng = Rng::new(11);
        let a: Vec<BitTensor> =
            (0..8).map(|_| rand_tensor(&mut rng, 90)).collect();
        let s: Vec<BitTensor> =
            (0..8).map(|_| rand_tensor(&mut rng, 90)).collect();
        let src = BitPlanes::from_tensors(&s);
        let mut m = BitPlanes::from_tensors(&a);
        m.copy_rows_from(3, &src, 1..5);
        for r in 0..8 {
            let want = if (3..7).contains(&r) { &s[r - 2] } else { &a[r] };
            assert_eq!(&m.plane(r), want, "copy row {r}");
        }
        let mut m = BitPlanes::from_tensors(&a);
        m.xor_rows_from(2, &src, 0..4);
        for r in 0..8 {
            let want = if (2..6).contains(&r) {
                a[r].xor(&s[r - 2])
            } else {
                a[r].clone()
            };
            assert_eq!(m.plane(r), want, "xor row {r}");
        }
    }

    #[test]
    fn tensor_reinterpret_roundtrips_without_repack() {
        prop(40, |rng: &mut Rng| {
            let planes = rng.range(1, 10);
            let n = rng.range(1, 150);
            let rows: Vec<BitTensor> =
                (0..planes).map(|_| rand_tensor(rng, n)).collect();
            let m = BitPlanes::from_tensors(&rows);
            let words = m.words().to_vec();
            let t = m.clone().into_tensor();
            assert_eq!(t.len(), planes * n.div_ceil(64) * 64);
            assert_eq!(t.words(), &words[..], "reinterpret moved bits");
            let back = BitPlanes::from_tensor(t, planes, n).unwrap();
            assert_eq!(back, m);
        });
    }

    #[test]
    fn from_tensor_rejects_wrong_geometry_and_masks_padding() {
        // wrong padded size -> None (malformed message, not a panic)
        let t = BitTensor::zeros(128);
        assert!(BitPlanes::from_tensor(t.clone(), 3, 64).is_none());
        assert!(BitPlanes::from_tensor(t.clone(), 2, 65).is_none());
        assert!(BitPlanes::from_tensor(t, 2, 64).is_some());
        // dirty per-plane padding from the wire is cleared
        let dirty = BitTensor::ones(128);
        let m = BitPlanes::from_tensor(dirty, 2, 5).unwrap();
        assert_eq!(m.popcount(), 10, "padding leaked into planes");
        for p in 0..2 {
            assert_eq!(m.plane(p), BitTensor::ones(5));
        }
    }

    #[test]
    fn bit_queue_is_fifo_across_misaligned_pushes() {
        prop(40, |rng: &mut Rng| {
            let mut q = BitQueue::new();
            let mut oracle: Vec<u8> = Vec::new();
            for _ in 0..rng.range(1, 8) {
                let n = rng.range(0, 200);
                let t = rand_tensor(rng, n);
                oracle.extend(t.to_bits());
                q.push(&t);
                assert_eq!(q.len(), oracle.len());
                if !oracle.is_empty() {
                    let k = rng.range(0, oracle.len() + 1);
                    let got = q.pop_front(k);
                    let want: Vec<u8> = oracle.drain(..k).collect();
                    assert_eq!(got.to_bits(), want);
                    assert_eq!(q.len(), oracle.len());
                }
            }
        });
    }

    #[test]
    fn bit_queue_reclaims_consumed_words() {
        let mut q = BitQueue::new();
        let mut rng = Rng::new(4);
        let big = rand_tensor(&mut rng, 80_000);
        q.push(&big);
        let mut drawn = Vec::new();
        while q.len() > 0 {
            let k = q.len().min(977);
            drawn.extend(q.pop_front(k).to_bits());
        }
        assert_eq!(drawn, big.to_bits());
        // everything consumed: storage reset, further pushes start clean
        assert_eq!(q.len(), 0);
        let t = rand_tensor(&mut rng, 65);
        q.push(&t);
        assert_eq!(q.pop_front(65).to_bits(), t.to_bits());
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn bit_queue_underflow_panics() {
        let mut q = BitQueue::new();
        q.push(&BitTensor::ones(3));
        let _ = q.pop_front(4);
    }
}
