//! Dense int32 ring tensors (row-major) with the handful of ops the
//! secure engine needs: elementwise ring arithmetic, matmul, and the CHW
//! im2col used to express convolutions as the Algorithm-2 contraction.

use super::Elem;

/// Row-major dense tensor over Z_{2^32}.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<Elem>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<Elem>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: Elem) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// (rows, cols) view of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    // ---- elementwise ring ops (wrapping) -------------------------------
    pub fn add(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a.wrapping_add(b))
    }

    pub fn sub(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a.wrapping_sub(b))
    }

    pub fn mul_elem(&self, rhs: &Tensor) -> Tensor {
        self.zip(rhs, |a, b| a.wrapping_mul(b))
    }

    fn zip(&self, rhs: &Tensor, f: impl Fn(Elem, Elem) -> Elem) -> Tensor {
        assert_eq!(self.shape, rhs.shape, "shape mismatch");
        let data = self.data.iter().zip(&rhs.data)
            .map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add_assign(&mut self, rhs: &Tensor) {
        assert_eq!(self.shape, rhs.shape);
        for (a, &b) in self.data.iter_mut().zip(&rhs.data) {
            *a = a.wrapping_add(b);
        }
    }

    pub fn sub_assign(&mut self, rhs: &Tensor) {
        assert_eq!(self.shape, rhs.shape);
        for (a, &b) in self.data.iter_mut().zip(&rhs.data) {
            *a = a.wrapping_sub(b);
        }
    }

    pub fn map(&self, f: impl Fn(Elem) -> Elem) -> Tensor {
        Tensor { shape: self.shape.clone(),
                 data: self.data.iter().map(|&a| f(a)).collect() }
    }

    pub fn scale(&self, c: Elem) -> Tensor {
        self.map(|a| a.wrapping_mul(c))
    }

    pub fn neg(&self) -> Tensor {
        self.map(|a| a.wrapping_neg())
    }

    /// Add a constant to every element (used for "one party adds c").
    pub fn add_const(&self, c: Elem) -> Tensor {
        self.map(|a| a.wrapping_add(c))
    }

    // ---- contractions ---------------------------------------------------
    /// Wrapping matmul: (m,k) x (k,n) -> (m,n).  i32 wrapping mul-add is
    /// exactly Z_{2^32}; blocked over k for locality.
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = rhs.dims2();
        assert_eq!(k, k2, "inner dim mismatch");
        let mut out = vec![0i32; m * n];
        // ikj loop order: stream rhs rows, accumulate into out rows
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0 {
                    continue;
                }
                let brow = &rhs.data[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o = o.wrapping_add(a.wrapping_mul(b));
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Broadcast-add a column vector (m,1) across an (m,n) tensor.
    pub fn add_col(&self, col: &Tensor) -> Tensor {
        let (m, n) = self.dims2();
        assert_eq!(col.len(), m);
        let mut out = self.clone();
        for i in 0..m {
            let c = col.data[i];
            for v in &mut out.data[i * n..(i + 1) * n] {
                *v = v.wrapping_add(c);
            }
        }
        out
    }
}

/// CHW im2col: (C,H,W) -> (K*K*C, OH*OW) with K-index `((ky*k)+kx)*C + c`
/// -- must match python/compile/model.py::_im2col_chw exactly.
pub fn im2col_chw(x: &Tensor, k: usize, stride: usize,
                  pad_lo: usize, pad_hi: usize) -> (Tensor, (usize, usize)) {
    assert_eq!(x.shape.len(), 3, "im2col expects CHW");
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let hp = h + pad_lo + pad_hi;
    let wp = w + pad_lo + pad_hi;
    let oh = (hp - k) / stride + 1;
    let ow = (wp - k) / stride + 1;
    let mut out = vec![0i32; k * k * c * oh * ow];
    let ncols = oh * ow;
    for ky in 0..k {
        for kx in 0..k {
            for ci in 0..c {
                let row = ((ky * k) + kx) * c + ci;
                let dst = &mut out[row * ncols..(row + 1) * ncols];
                for oy in 0..oh {
                    let iy = oy * stride + ky;
                    if iy < pad_lo || iy >= pad_lo + h {
                        continue; // zero padding
                    }
                    let sy = iy - pad_lo;
                    for ox in 0..ow {
                        let ix = ox * stride + kx;
                        if ix < pad_lo || ix >= pad_lo + w {
                            continue;
                        }
                        dst[oy * ow + ox] = x.data[ci * h * w + sy * w + (ix - pad_lo)];
                    }
                }
            }
        }
    }
    (Tensor::from_vec(&[k * k * c, ncols], out), (oh, ow))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    #[test]
    fn matmul_small_exact() {
        let a = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let b = Tensor::from_vec(&[3, 2], vec![7, 8, 9, 10, 11, 12]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58, 64, 139, 154]);
    }

    #[test]
    fn matmul_wraps() {
        let a = Tensor::from_vec(&[1, 2], vec![1 << 30, 1 << 30]);
        let b = Tensor::from_vec(&[2, 1], vec![4, 4]);
        assert_eq!(a.matmul(&b).data, vec![0]);
    }

    #[test]
    fn prop_matmul_distributes_over_add() {
        prop(200, |rng: &mut Rng| {
            let (m, k, n) = (rng.range(1, 6), rng.range(1, 6), rng.range(1, 6));
            let a = rng.tensor(&[m, k]);
            let b = rng.tensor(&[k, n]);
            let c = rng.tensor(&[k, n]);
            let left = a.matmul(&b.add(&c));
            let right = a.matmul(&b).add(&a.matmul(&c));
            assert_eq!(left, right);
        });
    }

    #[test]
    fn im2col_identity_1x1() {
        let x = Tensor::from_vec(&[2, 2, 2], (0..8).collect());
        let (cols, (oh, ow)) = im2col_chw(&x, 1, 1, 0, 0);
        assert_eq!((oh, ow), (2, 2));
        // row for c=0 then c=1, columns scan HW row-major
        assert_eq!(cols.data, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn im2col_3x3_same_padding_counts_zeros() {
        let x = Tensor::from_vec(&[1, 3, 3], vec![1; 9]);
        let (cols, (oh, ow)) = im2col_chw(&x, 3, 1, 1, 1);
        assert_eq!((oh, ow), (3, 3));
        // center tap row is all ones; corner tap row has 4 zeros (padding)
        let center = &cols.data[4 * 9..5 * 9];
        assert!(center.iter().all(|&v| v == 1));
        let corner: i32 = cols.data[0..9].iter().sum();
        assert_eq!(corner, 4);
    }

    #[test]
    fn prop_conv_as_im2col_matches_direct() {
        prop(50, |rng: &mut Rng| {
            let (c, h, w, k) = (rng.range(1, 4), rng.range(3, 8),
                                rng.range(3, 8), rng.range(1, 4));
            let co = rng.range(1, 4);
            let x = rng.tensor(&[c, h, w]);
            let wt = rng.tensor(&[co, k * k * c]);
            let (cols, (oh, ow)) = im2col_chw(&x, k, 1, 0, 0);
            let z = wt.matmul(&cols);
            // direct convolution
            for o in 0..co {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0i32;
                        for ky in 0..k {
                            for kx in 0..k {
                                for ci in 0..c {
                                    let wv = wt.data[o * k * k * c
                                        + ((ky * k) + kx) * c + ci];
                                    let xv = x.data[ci * h * w
                                        + (oy + ky) * w + (ox + kx)];
                                    acc = acc.wrapping_add(wv.wrapping_mul(xv));
                                }
                            }
                        }
                        assert_eq!(z.data[o * oh * ow + oy * ow + ox], acc);
                    }
                }
            }
        });
    }
}
