//! Word-parallel bit kernels shared by `BitTensor` and `BitPlanes`.
//!
//! Every boolean-share hot loop in the framework bottoms out here: XOR
//! (share combine / public unmask), AND (the local term of the boolean
//! multiplication), NOT, popcount, and the fused 4-term local product of
//! the RSS AND protocol.  The loops are 4-way unrolled over `u64` words
//! (`u64x4`-style): on x86-64 the compiler lowers each unrolled body to a
//! pair of 256-bit loads + one vector op when AVX2 is available, and to
//! four scalar ops otherwise -- either way the dependency chains are
//! broken up, which is what the rolled `zip` loops left on the table.
//!
//! Callers guarantee equal slice lengths (asserted here once, so the
//! unrolled bodies index without per-element bounds checks).  Tail
//! invariants (bits past `len`) are the callers' concern: kernels operate
//! on raw words.

/// Unroll factor of the word loops (4 u64s = one 256-bit vector).
pub const UNROLL: usize = 4;

macro_rules! unrolled_binop {
    ($name:ident, $doc:literal, $op:tt) => {
        #[doc = $doc]
        pub fn $name(dst: &mut [u64], a: &[u64], b: &[u64]) {
            let n = dst.len();
            assert!(a.len() == n && b.len() == n, "kernel length mismatch");
            let mut i = 0;
            while i + UNROLL <= n {
                dst[i] = a[i] $op b[i];
                dst[i + 1] = a[i + 1] $op b[i + 1];
                dst[i + 2] = a[i + 2] $op b[i + 2];
                dst[i + 3] = a[i + 3] $op b[i + 3];
                i += UNROLL;
            }
            while i < n {
                dst[i] = a[i] $op b[i];
                i += 1;
            }
        }
    };
}

unrolled_binop!(xor_into, "dst = a ^ b, word-parallel.", ^);
unrolled_binop!(and_into, "dst = a & b, word-parallel.", &);
unrolled_binop!(or_into, "dst = a | b, word-parallel.", |);

/// dst ^= src, word-parallel.
pub fn xor_in_place(dst: &mut [u64], src: &[u64]) {
    let n = dst.len();
    assert_eq!(src.len(), n, "kernel length mismatch");
    let mut i = 0;
    while i + UNROLL <= n {
        dst[i] ^= src[i];
        dst[i + 1] ^= src[i + 1];
        dst[i + 2] ^= src[i + 2];
        dst[i + 3] ^= src[i + 3];
        i += UNROLL;
    }
    while i < n {
        dst[i] ^= src[i];
        i += 1;
    }
}

/// dst = !src, word-parallel (tail bits are the caller's to re-mask).
pub fn not_into(dst: &mut [u64], src: &[u64]) {
    let n = dst.len();
    assert_eq!(src.len(), n, "kernel length mismatch");
    let mut i = 0;
    while i + UNROLL <= n {
        dst[i] = !src[i];
        dst[i + 1] = !src[i + 1];
        dst[i + 2] = !src[i + 2];
        dst[i + 3] = !src[i + 3];
        i += UNROLL;
    }
    while i < n {
        dst[i] = !src[i];
        i += 1;
    }
}

/// dst = a ^ b ^ c, word-parallel (the carry-save sum row).
pub fn xor3_into(dst: &mut [u64], a: &[u64], b: &[u64], c: &[u64]) {
    let n = dst.len();
    assert!(a.len() == n && b.len() == n && c.len() == n,
            "kernel length mismatch");
    let mut i = 0;
    while i + UNROLL <= n {
        dst[i] = a[i] ^ b[i] ^ c[i];
        dst[i + 1] = a[i + 1] ^ b[i + 1] ^ c[i + 1];
        dst[i + 2] = a[i + 2] ^ b[i + 2] ^ c[i + 2];
        dst[i + 3] = a[i + 3] ^ b[i + 3] ^ c[i + 3];
        i += UNROLL;
    }
    while i < n {
        dst[i] = a[i] ^ b[i] ^ c[i];
        i += 1;
    }
}

/// Total set bits, 4 accumulators to keep the popcnt units busy.
pub fn popcount(words: &[u64]) -> usize {
    let n = words.len();
    let (mut c0, mut c1, mut c2, mut c3) = (0usize, 0usize, 0usize, 0usize);
    let mut i = 0;
    while i + UNROLL <= n {
        c0 += words[i].count_ones() as usize;
        c1 += words[i + 1].count_ones() as usize;
        c2 += words[i + 2].count_ones() as usize;
        c3 += words[i + 3].count_ones() as usize;
        i += UNROLL;
    }
    while i < n {
        c0 += words[i].count_ones() as usize;
        i += 1;
    }
    c0 + c1 + c2 + c3
}

/// The fused local term of the RSS boolean AND:
///
/// ```text
///     dst = (xa & ya) ^ (xa & yb) ^ (xb & ya) ^ mask
/// ```
///
/// i.e. party i's 3-of-3 share of x & y, already masked with its
/// zero-sharing row.  Fusing the three ANDs and three XORs into one pass
/// reads each input word once instead of materializing intermediates.
pub fn and_local_into(dst: &mut [u64], xa: &[u64], xb: &[u64], ya: &[u64],
                      yb: &[u64], mask: &[u64]) {
    let n = dst.len();
    assert!(xa.len() == n && xb.len() == n && ya.len() == n
            && yb.len() == n && mask.len() == n,
            "kernel length mismatch");
    #[inline(always)]
    fn term(xa: u64, xb: u64, ya: u64, yb: u64, m: u64) -> u64 {
        (xa & ya) ^ (xa & yb) ^ (xb & ya) ^ m
    }
    let mut i = 0;
    while i + UNROLL <= n {
        dst[i] = term(xa[i], xb[i], ya[i], yb[i], mask[i]);
        dst[i + 1] = term(xa[i + 1], xb[i + 1], ya[i + 1], yb[i + 1],
                          mask[i + 1]);
        dst[i + 2] = term(xa[i + 2], xb[i + 2], ya[i + 2], yb[i + 2],
                          mask[i + 2]);
        dst[i + 3] = term(xa[i + 3], xb[i + 3], ya[i + 3], yb[i + 3],
                          mask[i + 3]);
        i += UNROLL;
    }
    while i < n {
        dst[i] = term(xa[i], xb[i], ya[i], yb[i], mask[i]);
        i += 1;
    }
}

// ---- bit-granular splice helpers (the ONE home of the straddled-word
// ---- shift arithmetic; BitTensor extend/slice and BitQueue push/pop all
// ---- route here) ---------------------------------------------------------

/// Append `src_len` bits (word-packed, LSB-first in `src`) after bit
/// `end` of a word buffer.  Precondition: `dst.len() == end.div_ceil(64)`
/// and bits past `end` in the last word are zero.  Postcondition:
/// `dst.len() == (end + src_len).div_ceil(64)`; bits past the new end
/// are whatever `src`'s tail held shifted in -- callers re-mask their
/// own tail invariant.
pub fn append_bits(dst: &mut Vec<u64>, end: usize, src: &[u64],
                   src_len: usize) {
    debug_assert_eq!(dst.len(), end.div_ceil(64));
    let off = end % 64;
    if off == 0 {
        dst.extend_from_slice(src);
    } else {
        for &w in src {
            // tail of the last word is zero, so OR is safe
            *dst.last_mut().unwrap() |= w << off;
            dst.push(w >> (64 - off));
        }
    }
    dst.truncate((end + src_len).div_ceil(64));
}

/// Copy `n` bits starting at bit `start` of a word buffer into fresh
/// words.  Bits past `n` in the last output word are NOT masked --
/// callers re-establish their tail invariant (`BitTensor::from_words`
/// does).
pub fn copy_bits(src: &[u64], start: usize, n: usize) -> Vec<u64> {
    let woff = start / 64;
    let boff = start % 64;
    let nw = n.div_ceil(64);
    let mut out = Vec::with_capacity(nw);
    for k in 0..nw {
        let lo = src[woff + k] >> boff;
        let hi = if boff > 0 && woff + k + 1 < src.len() {
            src[woff + k + 1] << (64 - boff)
        } else {
            0
        };
        out.push(lo | hi);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    fn words(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn unrolled_ops_match_rolled_reference() {
        // lengths straddle every unroll remainder (0..=3 leftover words)
        prop(50, |rng: &mut Rng| {
            let n = rng.range(0, 23);
            let a = words(rng, n);
            let b = words(rng, n);
            let c = words(rng, n);
            let mut dst = vec![0u64; n];

            xor_into(&mut dst, &a, &b);
            let want: Vec<u64> =
                a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
            assert_eq!(dst, want);

            and_into(&mut dst, &a, &b);
            let want: Vec<u64> =
                a.iter().zip(&b).map(|(x, y)| x & y).collect();
            assert_eq!(dst, want);

            or_into(&mut dst, &a, &b);
            let want: Vec<u64> =
                a.iter().zip(&b).map(|(x, y)| x | y).collect();
            assert_eq!(dst, want);

            not_into(&mut dst, &a);
            let want: Vec<u64> = a.iter().map(|x| !x).collect();
            assert_eq!(dst, want);

            xor3_into(&mut dst, &a, &b, &c);
            let want: Vec<u64> = (0..n).map(|i| a[i] ^ b[i] ^ c[i]).collect();
            assert_eq!(dst, want);

            let mut acc = a.clone();
            xor_in_place(&mut acc, &b);
            let want: Vec<u64> =
                a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
            assert_eq!(acc, want);

            let want: usize =
                a.iter().map(|w| w.count_ones() as usize).sum();
            assert_eq!(popcount(&a), want);
        });
    }

    #[test]
    fn splice_helpers_match_bit_oracle() {
        prop(60, |rng: &mut Rng| {
            // build two bit strings, append word-wise, then copy random
            // windows back out and compare against a Vec<u8> oracle
            let n1 = rng.range(0, 200);
            let n2 = rng.range(0, 200);
            let bits1: Vec<u8> = (0..n1).map(|_| rng.bit()).collect();
            let bits2: Vec<u8> = (0..n2).map(|_| rng.bit()).collect();
            let pack = |bits: &[u8]| -> Vec<u64> {
                let mut w = vec![0u64; bits.len().div_ceil(64)];
                for (i, &b) in bits.iter().enumerate() {
                    w[i / 64] |= u64::from(b) << (i % 64);
                }
                w
            };
            let mut words = pack(&bits1);
            append_bits(&mut words, n1, &pack(&bits2), n2);
            let mut oracle = bits1;
            oracle.extend_from_slice(&bits2);
            let total = oracle.len();
            assert_eq!(words.len(), total.div_ceil(64));
            for (i, &b) in oracle.iter().enumerate() {
                assert_eq!(((words[i / 64] >> (i % 64)) & 1) as u8, b,
                           "bit {i} after append");
            }
            if total > 0 {
                let start = rng.range(0, total);
                let len = rng.range(0, total - start + 1);
                let got = copy_bits(&words, start, len);
                for (j, &b) in oracle[start..start + len].iter().enumerate()
                {
                    assert_eq!(((got[j / 64] >> (j % 64)) & 1) as u8, b,
                               "bit {j} of window [{start}; {len})");
                }
            }
        });
    }

    #[test]
    fn fused_and_local_matches_composition() {
        prop(50, |rng: &mut Rng| {
            let n = rng.range(1, 19);
            let xa = words(rng, n);
            let xb = words(rng, n);
            let ya = words(rng, n);
            let yb = words(rng, n);
            let mask = words(rng, n);
            let mut dst = vec![0u64; n];
            and_local_into(&mut dst, &xa, &xb, &ya, &yb, &mask);
            let want: Vec<u64> = (0..n).map(|i| {
                (xa[i] & ya[i]) ^ (xa[i] & yb[i]) ^ (xb[i] & ya[i]) ^ mask[i]
            }).collect();
            assert_eq!(dst, want);
        });
    }
}
