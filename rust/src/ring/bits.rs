//! Word-packed boolean tensors: the one in-memory representation for every
//! boolean share in the framework.
//!
//! CBNN's whole pitch is that binarized values make 3PC cheap: a boolean
//! share costs one bit on the wire and XOR/AND locally.  The seed honored
//! that on the wire but stored bits as one `u8` per bit in memory, making
//! every local boolean op a per-element loop and every send/recv a repack.
//! `BitTensor` packs bits into `u64` words (LSB-first within each word), so
//! XOR/AND/NOT/popcount run word-parallel -- 64 shares per instruction --
//! and the wire codec is a plain truncated copy of the word buffer.
//!
//! Layout contract (load-bearing, asserted in tests):
//!
//! * bit `i` lives at `words[i / 64] >> (i % 64) & 1`;
//! * `words.len() == len.div_ceil(64)` always;
//! * bits beyond `len` in the last word are ZERO (the tail invariant).
//!   Every constructor and mutator restores it, which is what makes
//!   `popcount`, `PartialEq`, and the packed wire codec word-wise safe.
//!
//! The byte packing this induces -- byte `j` holds bits `8j..8j+8`,
//! LSB-first -- is bit-identical to the seed's per-bit wire packer, so the
//! B-share wire format (and the paper's communication tables) is unchanged.
//!
//! Packing/unpacking to `Vec<u8>`-of-bits exists only for the plaintext
//! boundary (dealing, reconstruction, oracles in tests); protocol code
//! operates on words.

use crate::prf::PrfStream;
use crate::ring::kernel;

/// Bits per storage word.
pub const WORD_BITS: usize = 64;

/// A length-tagged, u64-word-packed bit vector.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitTensor {
    len: usize,
    words: Vec<u64>,
}

impl BitTensor {
    // ---- constructors ---------------------------------------------------
    pub fn zeros(len: usize) -> Self {
        BitTensor { len, words: vec![0u64; len.div_ceil(WORD_BITS)] }
    }

    pub fn ones(len: usize) -> Self {
        let mut t = BitTensor {
            len,
            words: vec![u64::MAX; len.div_ceil(WORD_BITS)],
        };
        t.mask_tail();
        t
    }

    /// Adopt a word buffer; `words.len()` must match `len`, the tail is
    /// cleared to restore the invariant.
    pub fn from_words(len: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), len.div_ceil(WORD_BITS),
                   "word count does not match bit length");
        let mut t = BitTensor { len, words };
        t.mask_tail();
        t
    }

    /// Pack a plaintext bit slice (one u8 in {0,1} per bit).  Plaintext
    /// boundary only.
    pub fn from_bits(bits: &[u8]) -> Self {
        let mut t = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            debug_assert!(b <= 1, "from_bits expects bits");
            t.words[i / WORD_BITS] |= u64::from(b & 1) << (i % WORD_BITS);
        }
        t
    }

    /// Build from a per-index bit function.  Plaintext/arithmetic boundary
    /// only (e.g. extracting a bit-plane of ring elements).
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> u8) -> Self {
        let mut t = Self::zeros(len);
        for i in 0..len {
            t.words[i / WORD_BITS] |= u64::from(f(i) & 1) << (i % WORD_BITS);
        }
        t
    }

    /// Bulk-fill from a PRF stream: whole words at a time, no per-bit
    /// draws.  Consumes exactly `len.div_ceil(64)` u64s of keystream.
    pub fn random(stream: &mut PrfStream<'_>, len: usize) -> Self {
        let mut words = vec![0u64; len.div_ceil(WORD_BITS)];
        stream.fill_words(&mut words);
        Self::from_words(len, words)
    }

    // ---- accessors ------------------------------------------------------
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Surrender the word buffer (the `BitPlanes` reinterpret boundary --
    /// the words move, no bits are repacked).
    pub fn into_words(self) -> Vec<u64> {
        self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        ((self.words[i / WORD_BITS] >> (i % WORD_BITS)) & 1) as u8
    }

    pub fn set(&mut self, i: usize, b: u8) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / WORD_BITS];
        let mask = 1u64 << (i % WORD_BITS);
        if b & 1 == 1 {
            *w |= mask;
        } else {
            *w &= !mask;
        }
    }

    /// Unpack to one u8 per bit.  Plaintext boundary only.
    pub fn to_bits(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Number of set bits (word-parallel thanks to the tail invariant).
    pub fn popcount(&self) -> usize {
        kernel::popcount(&self.words)
    }

    // ---- word-parallel boolean ops (ring::kernel, 4-way unrolled) -------
    pub fn xor(&self, rhs: &BitTensor) -> BitTensor {
        assert_eq!(self.len, rhs.len, "xor length mismatch");
        let mut words = vec![0u64; self.words.len()];
        kernel::xor_into(&mut words, &self.words, &rhs.words);
        BitTensor { len: self.len, words }
    }

    pub fn xor_assign(&mut self, rhs: &BitTensor) {
        assert_eq!(self.len, rhs.len, "xor length mismatch");
        kernel::xor_in_place(&mut self.words, &rhs.words);
    }

    pub fn and(&self, rhs: &BitTensor) -> BitTensor {
        assert_eq!(self.len, rhs.len, "and length mismatch");
        let mut words = vec![0u64; self.words.len()];
        kernel::and_into(&mut words, &self.words, &rhs.words);
        BitTensor { len: self.len, words }
    }

    /// Bitwise complement (tail bits stay zero).
    pub fn not(&self) -> BitTensor {
        let mut words = vec![0u64; self.words.len()];
        kernel::not_into(&mut words, &self.words);
        let mut t = BitTensor { len: self.len, words };
        t.mask_tail();
        t
    }

    // ---- concatenation / slicing (bit-granular, via the shared splice
    // ---- helpers in ring::kernel) ---------------------------------------
    /// Append `other`'s bits after this tensor's.
    pub fn extend(&mut self, other: &BitTensor) {
        kernel::append_bits(&mut self.words, self.len, &other.words,
                            other.len);
        self.len += other.len;
        self.mask_tail();
    }

    /// Copy out bits `[start, start + len)` as a fresh tensor.
    pub fn slice(&self, start: usize, len: usize) -> BitTensor {
        assert!(start + len <= self.len, "slice out of range");
        let mut t = BitTensor {
            len,
            words: kernel::copy_bits(&self.words, start, len),
        };
        t.mask_tail();
        t
    }

    /// Gather bits by index: `out[j] = self[idx[j]]`.  The bit-level
    /// im2col used by the binary linear layers -- rearrangement only,
    /// each output bit is a copy of an input bit, so applying it to both
    /// components of a replicated share preserves the sharing.
    pub fn gather(&self, idx: &[usize]) -> BitTensor {
        let mut t = Self::zeros(idx.len());
        for (j, &i) in idx.iter().enumerate() {
            debug_assert!(i < self.len, "gather index out of range");
            t.words[j / WORD_BITS] |=
                u64::from(self.get(i)) << (j % WORD_BITS);
        }
        t
    }

    /// Remove and return the first `n` bits (FIFO draw, used by the
    /// preprocessing reservoir).
    pub fn take_front(&mut self, n: usize) -> BitTensor {
        assert!(n <= self.len, "take_front past the end");
        let front = self.slice(0, n);
        *self = self.slice(n, self.len - n);
        front
    }

    // ---- wire codec ------------------------------------------------------
    /// `ceil(len/8)` bytes, LSB-first within each byte -- the B-share wire
    /// format (identical to the seed's per-bit packer, now a word copy).
    pub fn packed_bytes(&self) -> Vec<u8> {
        let nbytes = self.len.div_ceil(8);
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out.truncate(nbytes);
        out
    }

    /// Decode the wire format; `None` when the byte count does not match
    /// the claimed bit length.  Padding bits the peer may have set are
    /// cleared (tail invariant), so a malicious tail cannot leak into
    /// word-wise ops.
    pub fn from_packed_bytes(len: usize, bytes: &[u8]) -> Option<BitTensor> {
        if bytes.len() != len.div_ceil(8) {
            return None;
        }
        let mut words = vec![0u64; len.div_ceil(WORD_BITS)];
        for (i, &b) in bytes.iter().enumerate() {
            words[i / 8] |= u64::from(b) << (8 * (i % 8));
        }
        let mut t = BitTensor { len, words };
        t.mask_tail();
        Some(t)
    }

    // ---- internal -------------------------------------------------------
    fn mask_tail(&mut self) {
        debug_assert_eq!(self.words.len(), self.len.div_ceil(WORD_BITS));
        let off = self.len % WORD_BITS;
        if off != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << off) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prf::{domain, ChaCha20, PrfStream};
    use crate::testutil::{prop, Rng};

    // ---- byte-per-bit reference (the seed representation), used to pin
    // ---- old-vs-new equivalence exactly ---------------------------------
    fn ref_xor(a: &[u8], b: &[u8]) -> Vec<u8> {
        a.iter().zip(b).map(|(x, y)| x ^ y).collect()
    }

    fn ref_and(a: &[u8], b: &[u8]) -> Vec<u8> {
        a.iter().zip(b).map(|(x, y)| x & y).collect()
    }

    /// The seed's wire packer (transport::send_bits body pre-refactor).
    fn seed_pack(bits: &[u8]) -> Vec<u8> {
        let mut bytes = vec![0u8; bits.len().div_ceil(8)];
        for (i, &b) in bits.iter().enumerate() {
            bytes[i / 8] |= b << (i % 8);
        }
        bytes
    }

    fn rand_bits(rng: &mut Rng, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.bit()).collect()
    }

    #[test]
    fn roundtrip_and_get_across_word_boundaries() {
        prop(50, |rng: &mut Rng| {
            let n = rng.range(0, 200);
            let bits = rand_bits(rng, n);
            let t = BitTensor::from_bits(&bits);
            assert_eq!(t.len(), n);
            assert_eq!(t.words().len(), n.div_ceil(64));
            assert_eq!(t.to_bits(), bits);
            for (i, &b) in bits.iter().enumerate() {
                assert_eq!(t.get(i), b);
            }
        });
        // exact boundary lengths
        for n in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            let mut rng = Rng::new(n as u64);
            let bits = rand_bits(&mut rng, n);
            assert_eq!(BitTensor::from_bits(&bits).to_bits(), bits);
        }
    }

    #[test]
    fn word_ops_match_bytewise_reference() {
        prop(100, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let a = rand_bits(rng, n);
            let b = rand_bits(rng, n);
            let ta = BitTensor::from_bits(&a);
            let tb = BitTensor::from_bits(&b);
            assert_eq!(ta.xor(&tb).to_bits(), ref_xor(&a, &b));
            assert_eq!(ta.and(&tb).to_bits(), ref_and(&a, &b));
            let not_a: Vec<u8> = a.iter().map(|&x| 1 ^ x).collect();
            assert_eq!(ta.not().to_bits(), not_a);
            let ones: usize = a.iter().map(|&x| x as usize).sum();
            assert_eq!(ta.popcount(), ones);
            let mut tc = ta.clone();
            tc.xor_assign(&tb);
            assert_eq!(tc, ta.xor(&tb));
        });
    }

    #[test]
    fn tail_invariant_survives_not_and_ones() {
        for n in [1usize, 7, 63, 65, 100] {
            let t = BitTensor::ones(n);
            assert_eq!(t.popcount(), n);
            let z = t.not();
            assert_eq!(z.popcount(), 0);
            assert_eq!(z, BitTensor::zeros(n));
        }
    }

    #[test]
    fn set_and_from_fn_agree() {
        let mut rng = Rng::new(5);
        let bits = rand_bits(&mut rng, 130);
        let via_fn = BitTensor::from_fn(130, |i| bits[i]);
        let mut via_set = BitTensor::zeros(130);
        for (i, &b) in bits.iter().enumerate() {
            via_set.set(i, b);
        }
        assert_eq!(via_fn, via_set);
        via_set.set(7, 0);
        assert_eq!(via_set.get(7), 0);
    }

    #[test]
    fn extend_matches_vec_concat() {
        prop(100, |rng: &mut Rng| {
            let n1 = rng.range(0, 150);
            let n2 = rng.range(0, 150);
            let a = rand_bits(rng, n1);
            let b = rand_bits(rng, n2);
            let mut t = BitTensor::from_bits(&a);
            t.extend(&BitTensor::from_bits(&b));
            let mut want = a;
            want.extend_from_slice(&b);
            assert_eq!(t.len(), want.len());
            assert_eq!(t.to_bits(), want);
            assert_eq!(t.words().len(), want.len().div_ceil(64));
        });
    }

    #[test]
    fn slice_matches_vec_slice() {
        prop(100, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let bits = rand_bits(rng, n);
            let t = BitTensor::from_bits(&bits);
            let start = rng.range(0, n + 1);
            let len = rng.range(0, n - start + 1);
            assert_eq!(t.slice(start, len).to_bits(),
                       bits[start..start + len].to_vec());
        });
    }

    #[test]
    fn gather_matches_index_map() {
        prop(50, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let bits = rand_bits(rng, n);
            let t = BitTensor::from_bits(&bits);
            let m = rng.range(0, 200);
            let idx: Vec<usize> = (0..m).map(|_| rng.range(0, n)).collect();
            let want: Vec<u8> = idx.iter().map(|&i| bits[i]).collect();
            assert_eq!(t.gather(&idx).to_bits(), want);
        });
    }

    #[test]
    fn take_front_is_fifo() {
        prop(50, |rng: &mut Rng| {
            let n = rng.range(2, 250);
            let bits = rand_bits(rng, n);
            let mut t = BitTensor::from_bits(&bits);
            let k = rng.range(1, n);
            let front = t.take_front(k);
            assert_eq!(front.to_bits(), bits[..k].to_vec());
            assert_eq!(t.to_bits(), bits[k..].to_vec());
        });
    }

    #[test]
    fn wire_codec_is_bit_identical_to_seed_packer() {
        prop(100, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let bits = rand_bits(rng, n);
            let t = BitTensor::from_bits(&bits);
            let packed = t.packed_bytes();
            assert_eq!(packed, seed_pack(&bits), "wire bytes changed!");
            assert_eq!(packed.len(), n.div_ceil(8));
            let back = BitTensor::from_packed_bytes(n, &packed).unwrap();
            assert_eq!(back, t);
        });
    }

    #[test]
    fn from_packed_bytes_validates_and_masks_padding() {
        // wrong byte count is rejected, not panicked on
        assert!(BitTensor::from_packed_bytes(9, &[0u8; 1]).is_none());
        assert!(BitTensor::from_packed_bytes(9, &[0u8; 3]).is_none());
        // attacker-set padding bits beyond `len` are cleared
        let t = BitTensor::from_packed_bytes(3, &[0b1111_1111]).unwrap();
        assert_eq!(t.to_bits(), vec![1, 1, 1]);
        assert_eq!(t.popcount(), 3);
        assert_eq!(t, BitTensor::ones(3));
    }

    #[test]
    fn prf_fill_matches_u32_pair_reference() {
        // BitTensor::random consumes the keystream as little-endian u64s
        // built from consecutive u32 draws -- pin that equivalence so the
        // shared-randomness derivation stays reproducible across parties.
        let key = ChaCha20::from_seed(9);
        let mut s1 = PrfStream::new(&key, 3, domain::BITS);
        let mut s2 = PrfStream::new(&key, 3, domain::BITS);
        let t = BitTensor::random(&mut s1, 130);
        assert_eq!(t.len(), 130);
        for w in 0..3 {
            let lo = u64::from(s2.next_u32());
            let hi = u64::from(s2.next_u32());
            let mut want = lo | (hi << 32);
            if w == 2 {
                want &= (1u64 << (130 % 64)) - 1; // tail invariant
            }
            assert_eq!(t.words()[w], want, "word {w}");
        }
    }

    #[test]
    fn random_is_deterministic_per_stream_and_nondegenerate() {
        let key = ChaCha20::from_seed(4);
        let a = BitTensor::random(&mut PrfStream::new(&key, 0, domain::BITS),
                                  256);
        let b = BitTensor::random(&mut PrfStream::new(&key, 0, domain::BITS),
                                  256);
        let c = BitTensor::random(&mut PrfStream::new(&key, 1, domain::BITS),
                                  256);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.popcount() > 0 && a.popcount() < 256);
    }
}
