//! Per-party secure inference engine.
//!
//! Executes the quantized layer program (nn::Model) over RSS shares,
//! dispatching to the protocol suite.  Non-linear protocols are *batched
//! across the request batch*: one MSB/Sign/ReLU invocation covers every
//! sample's elements, so communication rounds do not grow with batch size
//! -- this is what the coordinator's dynamic batcher buys.
//!
//! The model owner is P1: it loads the plaintext weight pool and
//! secret-shares every tensor at session setup (`share_model`).  The data
//! owner is P0: it shares inputs and is the only party that learns the
//! revealed logits.

use anyhow::{anyhow, Result};

use crate::nn::{Model, Op};
use crate::offline::TupleSource;
use crate::protocols::linear::LinearBackend;
use crate::protocols::relu::{relu_mul, relu_ot};
use crate::protocols::trunc::trunc;
use crate::protocols::Ctx;
use crate::ring::{tensor::im2col_chw, Tensor};
use crate::rss::{self, Share};
use crate::transport::Dir;

/// Engine options (ablation arms).
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Use the paper's two-OT ReLU (Alg 5) or the mul-based arm.
    pub relu_via_ot: bool,
    /// Sign-fused maxpool (paper 3.6) vs comparison-tree baseline.
    pub fused_pool: bool,
    /// Mint MSB correlated material during setup so the online MSB is
    /// 2 rounds (EXPERIMENTS.md §Perf); off = run Algorithm 3 inline.
    pub preprocess: bool,
    /// Binary-domain layer fusion: keep hidden activations as boolean
    /// shares across `Sign -> {Matmul|Depthwise|PoolBits|Flatten}`
    /// chains (`engine::fusion`), converting to arithmetic only where
    /// the plan demands it.  Off by default: fused plans additionally
    /// require the planner to accept the model (`plan_fused`).
    pub fuse: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions { relu_via_ot: true, fused_pool: true,
                        preprocess: true, fuse: false }
    }
}

/// Element counts of every MSB invocation `infer_batch` will make, in
/// order -- used to size the preprocessing pool.  Must mirror the op walk
/// in `infer_batch` exactly (asserted by the pool's size checks).
pub fn msb_sizes(model: &SharedModel, batch: usize) -> Vec<usize> {
    msb_sizes_of(&model.ops, model.input, batch)
}

/// `msb_sizes` over the public program structure alone: the op list and
/// input geometry are in every party's manifest (and in the coordinator's
/// plaintext `Model`), so demand can be computed without a shared model.
pub fn msb_sizes_of(ops: &[Op], input: (usize, usize, usize),
                    batch: usize) -> Vec<usize> {
    let (c0, h0, w0) = input;
    let (mut c, mut h, mut w) = (c0, h0, w0);
    let mut sizes = Vec::new();
    for op in ops {
        match op {
            Op::Matmul { conv: true, geom, cout, .. } => {
                let (k, s, pl, ph) = *geom;
                h = (h + pl + ph - k) / s + 1;
                w = (w + pl + ph - k) / s + 1;
                c = *cout;
            }
            Op::Matmul { conv: false, m, .. } => {
                c = *m;
                h = 1;
                w = 1;
            }
            Op::Depthwise { geom, .. } => {
                let (k, s, pl, ph) = *geom;
                h = (h + pl + ph - k) / s + 1;
                w = (w + pl + ph - k) / s + 1;
            }
            Op::Sign { .. } | Op::Relu { .. } => {
                sizes.push(batch * c * h * w);
            }
            Op::PoolBits { k, stride, .. } => {
                h = (h - k) / stride + 1;
                w = (w - k) / stride + 1;
                sizes.push(batch * c * h * w);
            }
            Op::Flatten { .. } => {
                c = c * h * w;
                h = 1;
                w = 1;
            }
            Op::Pm1 => {}
        }
    }
    sizes
}

/// Total MSB elements one batched inference consumes.
pub fn msb_demand(model: &SharedModel, batch: usize) -> usize {
    msb_sizes(model, batch).iter().sum()
}

/// `msb_demand` from the plaintext model manifest (the coordinator's
/// refill pump sizes watermarks before any session exists).
pub fn msb_demand_for(model: &Model, batch: usize) -> usize {
    msb_sizes_of(&model.ops, model.input, batch).iter().sum()
}

/// AOT artifact keys of every linear layer -- the set a backend should
/// precompile at session setup (see LinearBackend::warmup).
pub fn hlo_keys(model: &Model) -> Vec<String> {
    model.ops.iter().filter_map(|o| match o {
        Op::Matmul { hlo, .. } | Op::Depthwise { hlo, .. } => hlo.clone(),
        _ => None,
    }).collect()
}

/// Fill a preprocessing pool for one upcoming `infer_batch` call.
pub fn preprocess_for(ctx: &Ctx, model: &SharedModel, batch: usize)
                      -> Result<crate::protocols::preproc::MsbPool> {
    let pool = crate::protocols::preproc::MsbPool::new();
    pool.generate(ctx, msb_demand(model, batch))?;
    Ok(pool)
}

/// MSB through the configured tuple source.
///
/// * `Inline` -- full Algorithm 3, no preprocessing.
/// * `Pool` -- a pre-minted reservoir; exhaustion is a hard error
///   (protocol desync / undersized preprocessing for one-shot sessions).
/// * `Bank` -- the serving path.  The pooled-vs-fallback decision uses
///   the bank's *deterministic* credit accounting, so all three parties
///   agree on it regardless of producer speed; a committed draw blocks
///   until the producer delivers, a refusal (genuine underflow, counted
///   in `PreprocMetrics`) mints synchronously on the online channel --
///   also lock-step, because the decision was.
fn msb_via(ctx: &Ctx, src: &TupleSource<'_>, x: &Share)
           -> Result<crate::protocols::msb::MsbOut> {
    use crate::protocols::preproc;
    match src {
        TupleSource::Inline => crate::protocols::msb::msb_extract_full(ctx, x),
        TupleSource::Pool(p) => preproc::msb_online(ctx, x, p.take(x.len())?),
        TupleSource::Bank(b) => {
            let n = x.len();
            let tup = if b.try_reserve(n) {
                b.take(n)?
            } else {
                preproc::mint(ctx, n)?
            };
            preproc::msb_online(ctx, x, tup)
        }
    }
}

/// The per-party view of the secret-shared model.
pub struct SharedModel {
    /// Public program structure (every party has the manifest).
    pub ops: Vec<Op>,
    pub input: (usize, usize, usize),
    /// Shares of each linear layer's weights/biases and sign thresholds,
    /// indexed by op position.
    pub weights: Vec<Option<Share>>,
    pub biases: Vec<Option<Share>>,
    pub thresholds: Vec<Option<Share>>,
    /// Public per-channel orientation flips for sign ops.
    pub flips: Vec<Option<Vec<i32>>>,
}

/// Session setup: P1 (model owner) shares every secret tensor.  All
/// parties pass the *manifest-only* model (public structure); only P1's
/// copy needs the weight pool.
pub fn share_model(ctx: &Ctx, model: &Model, has_pool: bool)
                   -> Result<SharedModel> {
    let me = ctx.id();
    let n_ops = model.ops.len();
    let mut weights = Vec::with_capacity(n_ops);
    let mut biases = Vec::with_capacity(n_ops);
    let mut thresholds = Vec::with_capacity(n_ops);
    let mut flips = Vec::with_capacity(n_ops);
    if me == 1 && !has_pool {
        return Err(anyhow!("model owner needs the weight pool"));
    }
    let plain = |r: crate::nn::PoolRef, shape: &[usize]| -> Option<Tensor> {
        if me == 1 { Some(model.tensor(r, shape)) } else { None }
    };
    for op in &model.ops {
        match op {
            Op::Matmul { m, kdim, w, b, .. } => {
                let wt = plain(*w, &[*m, *kdim]);
                weights.push(Some(rss::share_input(
                    ctx.comm, ctx.seeds, 1, wt.as_ref(), &[*m, *kdim])?));
                if let Some(br) = b {
                    let bt = plain(*br, &[*m]);
                    biases.push(Some(rss::share_input(
                        ctx.comm, ctx.seeds, 1, bt.as_ref(), &[*m])?));
                } else {
                    biases.push(None);
                }
                thresholds.push(None);
                flips.push(None);
            }
            Op::Depthwise { c, geom, w, .. } => {
                let kk = geom.0 * geom.0;
                let wt = plain(*w, &[*c, kk]);
                weights.push(Some(rss::share_input(
                    ctx.comm, ctx.seeds, 1, wt.as_ref(), &[*c, kk])?));
                biases.push(None);
                thresholds.push(None);
                flips.push(None);
            }
            Op::Sign { c, t, flip } => {
                let tt = plain(*t, &[*c]);
                weights.push(None);
                biases.push(None);
                thresholds.push(Some(rss::share_input(
                    ctx.comm, ctx.seeds, 1, tt.as_ref(), &[*c])?));
                // flips are public metadata: P1 broadcasts them
                let f = if me == 1 {
                    let f = model.tensor(*flip, &[*c]).data;
                    ctx.comm.send_elems(Dir::Next, &f)?;
                    ctx.comm.send_elems(Dir::Prev, &f)?;
                    ctx.comm.round();
                    f
                } else if me == 2 {
                    let f = crate::protocols::expect_elems(
                        ctx.comm.recv_elems(Dir::Prev)?, *c)?;
                    ctx.comm.round();
                    f
                } else {
                    let f = crate::protocols::expect_elems(
                        ctx.comm.recv_elems(Dir::Next)?, *c)?;
                    ctx.comm.round();
                    f
                };
                flips.push(Some(f));
            }
            _ => {
                weights.push(None);
                biases.push(None);
                thresholds.push(None);
                flips.push(None);
            }
        }
    }
    Ok(SharedModel {
        ops: model.ops.clone(),
        input: model.input,
        weights,
        biases,
        thresholds,
        flips,
    })
}

// --------------------------------------------------------------------
// batched share plumbing
// --------------------------------------------------------------------
fn concat(shares: &[Share]) -> Share {
    let total: usize = shares.iter().map(Share::len).sum();
    let mut a = Vec::with_capacity(total);
    let mut b = Vec::with_capacity(total);
    for s in shares {
        a.extend_from_slice(&s.a.data);
        b.extend_from_slice(&s.b.data);
    }
    Share {
        a: Tensor::from_vec(&[total], a),
        b: Tensor::from_vec(&[total], b),
    }
}

fn split(joined: Share, shapes: &[Vec<usize>]) -> Vec<Share> {
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for sh in shapes {
        let n: usize = sh.iter().product();
        out.push(Share {
            a: Tensor::from_vec(sh, joined.a.data[off..off + n].to_vec()),
            b: Tensor::from_vec(sh, joined.b.data[off..off + n].to_vec()),
        });
        off += n;
    }
    out
}

/// Reshare a batch of per-sample 3-of-3 additive results with a single
/// round: concatenate, mask + exchange once, split back.
fn reshare_batched(ctx: &Ctx, zis: Vec<Tensor>, shapes: &[Vec<usize>])
                   -> Result<Vec<Share>> {
    let total: usize = zis.iter().map(Tensor::len).sum();
    let mut flat = Vec::with_capacity(total);
    for z in &zis {
        flat.extend_from_slice(&z.data);
    }
    let joined = rss::reshare(ctx.comm, ctx.seeds,
                              &Tensor::from_vec(&[total], flat))?;
    Ok(split(joined, shapes))
}

/// Broadcast-subtract a per-channel shared threshold and apply the public
/// flip: `d[c][j] = (z[c][j] - t[c]) * flip[c]`  (local).
fn sub_thresh_flip(z: &Share, t: &Share, flip: &[i32]) -> Share {
    let (c, n) = z.a.dims2();
    let apply = |zc: &Tensor, tc: &Tensor| {
        let mut out = zc.clone();
        for ci in 0..c {
            let tv = tc.data[ci];
            let f = flip[ci];
            for v in &mut out.data[ci * n..(ci + 1) * n] {
                *v = v.wrapping_sub(tv).wrapping_mul(f);
            }
        }
        out
    };
    Share { a: apply(&z.a, &t.a), b: apply(&z.b, &t.b) }
}

/// Result of one batched secure inference.
pub struct InferenceOutput {
    /// Revealed logits -- only populated on the data owner (P0).
    pub logits: Vec<Vec<i32>>,
    /// Per-op wire cost rows for this party's walk (DESIGN.md round
    /// budgets made executable; see `metrics::op_cost_table`).
    pub op_costs: Vec<crate::metrics::OpCost>,
}

/// Run the full layer program for a batch.  `inputs` is non-empty only on
/// the data owner (P0); every party must pass the same `batch` count.
pub fn infer_batch(ctx: &Ctx, model: &SharedModel,
                   backend: &dyn LinearBackend, opts: EngineOptions,
                   inputs: &[Tensor], batch: usize)
                   -> Result<InferenceOutput> {
    infer_batch_pooled(ctx, model, backend, opts, inputs, batch,
                       &TupleSource::Inline)
}

/// Share the request batch (one round): P0 concatenates its samples,
/// everyone splits the dealt share back per sample.
fn share_inputs(ctx: &Ctx, input: (usize, usize, usize),
                inputs: &[Tensor], batch: usize) -> Result<Vec<Share>> {
    let (c0, h0, w0) = input;
    let joined = if ctx.id() == 0 {
        assert_eq!(inputs.len(), batch);
        let mut all = Vec::with_capacity(batch * c0 * h0 * w0);
        for x in inputs {
            assert_eq!(x.len(), c0 * h0 * w0, "input shape mismatch");
            all.extend_from_slice(&x.data);
        }
        Some(Tensor::from_vec(&[batch * c0 * h0 * w0], all))
    } else {
        None
    };
    let shared = rss::share_input(ctx.comm, ctx.seeds, 0, joined.as_ref(),
                                  &[batch * c0 * h0 * w0])?;
    let shapes = vec![vec![c0, h0 * w0]; batch];
    Ok(split(shared, &shapes))
}

/// Attribute the wire delta since `before` to one op-cost row.
///
/// Diffs the *bound channel's* counters, not the link totals: a serving
/// party's other model slots and offline lanes move traffic concurrently
/// on the same links, and diffing totals silently billed their rounds
/// and bytes to whatever op happened to be running here (the budget
/// tests in `tests/budgets.rs` pin the fix under a noisy neighbour).
fn cost_row(ctx: &Ctx, index: usize, op: String,
            before: &crate::transport::Stats) -> crate::metrics::OpCost {
    let chan = ctx.comm.chan();
    let now = ctx.comm.stats().chan(chan);
    let before = before.chan(chan);
    crate::metrics::OpCost {
        index,
        op,
        rounds: now.rounds - before.rounds,
        bytes_sent: now.bytes_sent - before.bytes_sent,
    }
}

/// `infer_batch` drawing MSB correlated material from `tuples` (an
/// inline pool, a producer-fed `offline::TupleBank`, or nothing).
pub fn infer_batch_pooled(
    ctx: &Ctx, model: &SharedModel, backend: &dyn LinearBackend,
    opts: EngineOptions, inputs: &[Tensor], batch: usize,
    tuples: &TupleSource<'_>)
    -> Result<InferenceOutput> {
    let me = ctx.id();
    let mut acts = share_inputs(ctx, model.input, inputs, batch)?;
    let mut geom: Vec<(usize, usize, usize)> =
        vec![model.input; batch];
    let mut op_costs = Vec::with_capacity(model.ops.len());
    // ---- walk the program ----------------------------------------------
    for (i, op) in model.ops.iter().enumerate() {
        let before = ctx.comm.stats();
        let cur = ctx.comm.tracer().filter(|t| t.enabled())
            .map(|t| t.cursor(ctx.comm));
        run_arith_op(ctx, model, backend, opts, tuples, i, op,
                     &mut acts, &mut geom)?;
        op_costs.push(cost_row(ctx, i, op.name().to_string(), &before));
        if let Some(cur) = cur {
            if let Some(tr) = ctx.comm.tracer() {
                tr.close(ctx.comm, crate::trace::SpanKind::Op, i as u32,
                         op.name(), &cur);
            }
        }
    }

    // ---- reveal logits to the data owner only --------------------------
    let joined = concat(&acts);
    let logits = reveal_to_p0(ctx, &joined)?;
    if me == 0 {
        let v = logits.unwrap();
        let per = v.len() / batch;
        Ok(InferenceOutput {
            logits: v.chunks(per).map(<[i32]>::to_vec).collect(),
            op_costs,
        })
    } else {
        Ok(InferenceOutput { logits: vec![], op_costs })
    }
}

/// Execute one arithmetic-domain op over the per-sample share batch.
/// The unfused walk runs every op through this; fused plans
/// (`engine::fusion`) call it for the segments the planner left in the
/// arithmetic domain, so the two walks cannot drift apart.
fn run_arith_op(ctx: &Ctx, model: &SharedModel,
                backend: &dyn LinearBackend, opts: EngineOptions,
                tuples: &TupleSource<'_>, i: usize, op: &Op,
                acts: &mut Vec<Share>,
                geom: &mut Vec<(usize, usize, usize)>) -> Result<()> {
    let me = ctx.id();
    let batch = acts.len();
    {
        match op {
            Op::Matmul { conv, m, kdim, geom: g, cout, hlo, .. } => {
                let w = model.weights[i].as_ref().unwrap();
                let b = model.biases[i].as_ref();
                let key = hlo.clone().unwrap_or_default();
                // local contraction per sample, then ONE batched reshare
                let mut zis = Vec::with_capacity(batch);
                let mut shapes = Vec::with_capacity(batch);
                for (s, gm) in acts.iter().zip(geom.iter_mut()) {
                    let x = if *conv {
                        let (k, st, pl, ph) = *g;
                        let (cc, hh, ww) = *gm;
                        let a3 = s.a.clone().reshape(&[cc, hh, ww]);
                        let b3 = s.b.clone().reshape(&[cc, hh, ww]);
                        let (xa, (oh, ow)) = im2col_chw(&a3, k, st, pl, ph);
                        let (xb, _) = im2col_chw(&b3, k, st, pl, ph);
                        *gm = (*cout, oh, ow);
                        Share { a: xa, b: xb }
                    } else {
                        *gm = (*m, 1, 1);
                        s.clone().reshape(&[*kdim, 1])
                    };
                    let zi = backend.rss_matmul(&key, &w.a, &w.b, &x.a, &x.b,
                                                b.map(|bb| &bb.a));
                    shapes.push(zi.shape.clone());
                    zis.push(zi);
                }
                *acts = reshare_batched(ctx, zis, &shapes)?;
            }
            Op::Depthwise { geom: g, hlo, .. } => {
                let w = model.weights[i].as_ref().unwrap();
                let key = hlo.clone().unwrap_or_default();
                let (k, st, pl, ph) = *g;
                let mut zis = Vec::with_capacity(batch);
                let mut shapes = Vec::with_capacity(batch);
                for (s, gm) in acts.iter().zip(geom.iter_mut()) {
                    let (cc, hh, ww) = *gm;
                    let zi = backend.rss_depthwise(
                        &key, &w.a, &w.b, &s.a, &s.b,
                        (cc, hh, ww, k, st, pl, ph));
                    let oh = (hh + pl + ph - k) / st + 1;
                    let ow = (ww + pl + ph - k) / st + 1;
                    *gm = (cc, oh, ow);
                    shapes.push(zi.shape.clone());
                    zis.push(zi);
                }
                *acts = reshare_batched(ctx, zis, &shapes)?;
            }
            Op::Sign { .. } => {
                let t = model.thresholds[i].as_ref().unwrap();
                let flip = model.flips[i].as_ref().unwrap();
                // local threshold + flip, then ONE batched sign protocol
                let d: Vec<Share> = acts.iter().zip(geom.iter()).map(|(s, gm)| {
                    let (cc, hh, ww) = *gm;
                    let z = s.clone().reshape(&[cc, hh * ww]);
                    sub_thresh_flip(&z, t, flip)
                }).collect();
                let shapes: Vec<Vec<usize>> =
                    d.iter().map(|s| s.shape().to_vec()).collect();
                let joined = concat(&d);
                let bits = msb_via(ctx, tuples, &joined)?.sign_a;
                *acts = split(bits, &shapes);
            }
            Op::Relu { trunc: f } => {
                let shapes: Vec<Vec<usize>> =
                    acts.iter().map(|s| s.shape().to_vec()).collect();
                let joined = concat(&acts);
                let m = msb_via(ctx, tuples, &joined)?.bits;
                let r = if opts.relu_via_ot {
                    relu_ot(ctx, &joined, &m)?
                } else {
                    relu_mul(ctx, &joined, &m)?
                };
                let truncated = trunc(ctx, &r, *f)?;
                *acts = split(truncated, &shapes);
            }
            Op::PoolBits { k, stride, .. } => {
                // local window sums per sample, one batched Sign
                let mut sums = Vec::with_capacity(batch);
                let mut shapes = Vec::with_capacity(batch);
                for (s, gm) in acts.iter().zip(geom.iter_mut()) {
                    let (cc, hh, ww) = *gm;
                    let summed = crate::protocols::maxpool::
                        window_sum_minus_one(ctx, s, cc, hh, ww, *k, *stride);
                    let oh = (hh - k) / stride + 1;
                    let ow = (ww - k) / stride + 1;
                    *gm = (cc, oh, ow);
                    shapes.push(vec![cc, oh * ow]);
                    sums.push(summed);
                }
                let joined = concat(&sums);
                let bits = msb_via(ctx, tuples, &joined)?.sign_a;
                *acts = split(bits, &shapes);
            }
            Op::Pm1 => {
                for s in acts.iter_mut() {
                    *s = s.pm1(me);
                }
            }
            Op::Flatten { .. } => {
                for (s, gm) in acts.iter_mut().zip(geom.iter_mut()) {
                    let (cc, hh, ww) = *gm;
                    *s = s.clone().reshape(&[cc * hh * ww, 1]);
                    *gm = (cc * hh * ww, 1, 1);
                }
            }
        }
    }
    Ok(())
}

/// Reveal a share to P0 only: P1 sends its x_2 component to P0.
fn reveal_to_p0(ctx: &Ctx, s: &Share) -> Result<Option<Vec<i32>>> {
    match ctx.id() {
        1 => {
            ctx.comm.send_elems(Dir::Prev, &s.b.data)?; // x_2 -> P0
            ctx.comm.round();
            Ok(None)
        }
        0 => {
            let x2 = crate::protocols::expect_elems(
                ctx.comm.recv_elems(Dir::Next)?, s.len())?;
            ctx.comm.round();
            Ok(Some((0..s.len()).map(|i| {
                s.a.data[i].wrapping_add(s.b.data[i]).wrapping_add(x2[i])
            }).collect()))
        }
        _ => Ok(None),
    }
}

/// Convenience: argmax per logit row.
pub fn argmax(logits: &[i32]) -> usize {
    logits.iter().enumerate()
        .max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap_or(0)
}

pub mod fusion;
pub mod session;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::linear::NativeBackend;
    use crate::protocols::testsupport::run3;
    use crate::testutil::threeparty::every_op_model;

    #[test]
    fn msb_sizes_mirrors_infer_batch_pool_drain() {
        // Contract: `msb_sizes` must predict the engine's MSB walk exactly.
        // Over-prediction leaves material in the pool (asserted to be zero
        // below); under-prediction would err inside `MsbPool::take`.
        let results = run3(|ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            let batch = 2;
            let sizes = msb_sizes(&shared, batch);
            // one entry per non-linear op, sized at its activation geometry:
            // Sign on (2,4,4), PoolBits to (2,2,2), Relu on the 3 logits
            assert_eq!(sizes, vec![64, 16, 6]);
            assert_eq!(msb_demand(&shared, batch), 86);
            // the manifest-only variant agrees (the coordinator pump
            // sizes watermarks from the plaintext model)
            assert_eq!(msb_demand_for(&model, batch), 86);
            assert_eq!(msb_sizes_of(&model.ops, model.input, batch), sizes);
            let pool = crate::protocols::preproc::MsbPool::new();
            pool.generate(ctx, msb_demand(&shared, batch)).unwrap();
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = crate::testutil::Rng::new(5);
                (0..batch).map(|_| rng.tensor_small(&[1, 36], 15)).collect()
            } else {
                vec![]
            };
            let pooled = infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &TupleSource::Pool(&pool)).unwrap();
            // fully drained: zero remaining, zero over-take
            assert_eq!(pool.available(), 0,
                       "msb_sizes over-estimated the engine's MSB walk");
            // and the pooled path computes the same function as inline
            // Algorithm 3
            let inline = infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, batch, &TupleSource::Inline).unwrap();
            (pooled.logits, inline.logits)
        });
        let (pooled, inline) = results[0].0.clone();
        assert_eq!(pooled.len(), 2);
        assert_eq!(pooled[0].len(), 3);
        // pooled vs inline MSB compute the same function; the final Relu's
        // truncation draws different masks in the two runs, so logits may
        // differ by the protocol's +-1 LSB
        for (pr, ir) in pooled.iter().zip(&inline) {
            for (p, i) in pr.iter().zip(ir) {
                assert!((p - i).abs() <= 1,
                        "pooled {p} vs inline {i} beyond trunc tolerance");
            }
        }
        // non-owners learn nothing
        assert!(results[1].0 .0.is_empty() && results[2].0 .0.is_empty());
    }

    #[test]
    fn undersized_pool_surfaces_typed_error_not_abort() {
        // satellite hardening: exhaustion propagates as a Result through
        // msb_via and infer_batch_pooled -- every party errs at the same
        // lock-step point, nobody panics, nobody hangs
        let results = run3(|ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, true).unwrap();
            let pool = crate::protocols::preproc::MsbPool::new();
            pool.generate(ctx, 10).unwrap(); // first Sign needs 64
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = crate::testutil::Rng::new(8);
                vec![rng.tensor_small(&[1, 36], 15)]
            } else {
                vec![]
            };
            infer_batch_pooled(ctx, &shared, &NativeBackend,
                               EngineOptions::default(), &inputs, 1,
                               &TupleSource::Pool(&pool))
                .map(|_| ()).map_err(|e| e.to_string())
        });
        for (r, _) in &results {
            let err = r.as_ref().expect_err("undersized pool must err");
            assert!(err.contains("exhausted"), "unexpected error: {err}");
        }
    }

    #[test]
    fn peer_drop_mid_inference_surfaces_wire_error() {
        // party 2 completes setup, then dies before the online phase; its
        // neighbours' sends/recvs must surface WireError (Closed) through
        // infer_batch instead of panicking the party threads
        let results = run3(|ctx| {
            let model = every_op_model();
            let shared = share_model(ctx, &model, ctx.id() == 1).unwrap();
            if ctx.id() == 2 {
                return None; // drops this party's Comm on thread exit
            }
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = crate::testutil::Rng::new(9);
                vec![rng.tensor_small(&[1, 36], 15)]
            } else {
                vec![]
            };
            let r = infer_batch(ctx, &shared, &NativeBackend,
                                EngineOptions::default(), &inputs, 1);
            Some(r.map(|_| ()).map_err(|e| e.to_string()))
        });
        for id in [0usize, 1] {
            let out = results[id].0.as_ref().expect("survivor output");
            let err = out.as_ref().expect_err("inference must fail");
            assert!(err.contains("hung up") || err.contains("transport")
                    || err.contains("desync"),
                    "party {id} error not a wire failure: {err}");
        }
        assert!(results[2].0.is_none());
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[1, 5, 3]), 1);
        assert_eq!(argmax(&[-10, -2, -5]), 1);
        assert_eq!(argmax(&[7]), 0);
    }

    #[test]
    fn concat_split_roundtrip() {
        let mut rng = crate::testutil::Rng::new(2);
        let shares: Vec<Share> = (0..3).map(|_| {
            let t = rng.tensor(&[2, 5]);
            Share { a: t.clone(), b: t }
        }).collect();
        let shapes: Vec<Vec<usize>> =
            shares.iter().map(|s| s.shape().to_vec()).collect();
        let joined = concat(&shares);
        assert_eq!(joined.len(), 30);
        let back = split(joined, &shapes);
        assert_eq!(back, shares);
    }
}
