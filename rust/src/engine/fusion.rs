//! Binary-domain graph fusion: pattern-match `Sign -> {Matmul |
//! Depthwise | PoolBits | Flatten}` chains in the op plan and lower
//! them so hidden activations cross layer boundaries as word-packed
//! boolean shares instead of 32-bit arithmetic shares.
//!
//! The planner (`plan_fused`) walks the plaintext program tracking the
//! activation domain:
//!
//! * `Sign` enters the binary domain: the MSB protocol's boolean output
//!   is kept (`MsbOut::bits`, complemented locally) instead of being
//!   converted to arithmetic.
//! * `PoolBits` over bits lowers to an OR tree (max of bits = OR) --
//!   zero MSB tuples, log2(k^2) AND rounds.
//! * `Pm1`/`Flatten` over bits are pure metadata (an encoding flag and
//!   a geometry change; the packed bits never move).
//! * `Matmul`/`Depthwise` with all-±1 weights, no bias, and no padding
//!   lower to XNOR + secret-shared popcount (`protocols::binlinear`).
//!   A directly following `Sign` folds into the popcount threshold:
//!   with `dot = 2*pc - K`, `sign((dot - t) * flip)` becomes
//!   `pc >= ceil((K + t)/2)` (flip > 0), `NOT(pc >= floor((K + t)/2)
//!   + 1)` (flip < 0), or constant 1 (flip = 0); thresholds clamp to
//!   [0, K+1], where the adder arithmetic realizes the constant cases.
//! * Everything else ends the binary region: one batched `b2a` (plus
//!   the local ±1 affine if `Pm1` was applied) re-enters arithmetic,
//!   and the op runs through the same `run_arith_op` as the unfused
//!   walk.
//!
//! Sequences with no consistent lowering are rejected with a typed
//! `FusionError` at *plan* time (never a panic mid-protocol): `Pm1`
//! over arithmetic or already-±1 activations, and `PoolBits` over ±1
//! bits (an OR there would silently change the function -- the
//! arithmetic path computes a majority, not a max).
//!
//! Secrecy: fused ±1 weight masks and folded thresholds are treated as
//! public model metadata (the paper's customized BNNs publish their
//! binarized structure); activations -- the XNOR inputs, every CSA
//! partial sum, and the popcounts -- stay secret-shared throughout.
//! The arithmetic entry/exit layers keep their secret-shared weights.
//! DESIGN.md "Binary-domain fusion" has the full argument.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::nn::{Model, Op};
use crate::offline::TupleSource;
use crate::protocols::b2a::b2a;
use crate::protocols::binlinear::{gather_share, or_planes, popcount_ge,
                                  popcount_to_arith};
use crate::protocols::linear::LinearBackend;
use crate::protocols::Ctx;
use crate::ring::bits::BitTensor;
use crate::ring::Tensor;
use crate::rss::{BitShare, Share};

use super::{concat, cost_row, msb_via, reveal_to_p0, run_arith_op,
            share_inputs, split, sub_thresh_flip, EngineOptions,
            InferenceOutput, SharedModel};

/// Typed planner rejection: the op at `index` cannot be lowered into a
/// consistent fused plan.  Surfaced before any share or protocol state
/// exists, so `serve --fuse on` fails fast at model-start time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FusionError {
    pub index: usize,
    pub op: &'static str,
    pub reason: &'static str,
}

impl std::fmt::Display for FusionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fusion: op {} ({}) cannot be lowered: {}",
               self.index, self.op, self.reason)
    }
}

impl std::error::Error for FusionError {}

/// One step of a fused plan.  Indices refer to `Model::ops`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FusedOp {
    /// Run the op unchanged in the arithmetic domain.
    Arith(usize),
    /// `Sign` entering the binary domain (keep the MSB bits).
    SignEnter(usize),
    /// `PoolBits` lowered to an OR tree over window bit planes.
    OrPool(usize),
    /// `Pm1` lowered to an encoding flag (no share op).
    Pm1Bits(usize),
    /// `Flatten` lowered to a geometry change (bits never move).
    FlattenBits(usize),
    /// `Matmul`/`Depthwise` lowered to XNOR + popcount; the spec in
    /// `FusedPlan::bins` says whether a following `Sign` is folded in.
    BinLinear(usize),
    /// Leave the binary domain (batched b2a + optional ±1 affine)
    /// before op `before` (or before the final reveal).
    ToArith { before: usize },
}

/// Threshold fold of the `Sign` directly after a binary linear layer.
#[derive(Clone, Debug, PartialEq, Eq)]
struct FoldSpec {
    /// The folded sign op's index (cost attribution).
    sign_index: usize,
    /// Per-output-row popcount threshold, clamped to [0, K+1].
    thresh: Vec<u32>,
    /// Per-output-row output complement (flip < 0 rows).
    negate: Vec<bool>,
}

/// Public lowering data for one binary linear layer.
#[derive(Clone, Debug, PartialEq, Eq)]
struct BinSpec {
    depthwise: bool,
    /// Spatial conv (vs FC); always true for depthwise.
    conv: bool,
    /// Output rows: `m` (matmul) or channels (depthwise).
    rows: usize,
    /// Reduction width K.
    kdim: usize,
    /// (k, stride, pad_lo, pad_hi); pads are 0 by construction.
    geom: (usize, usize, usize, usize),
    /// Per-row XNOR mask: bit r set iff `w[row][r] == -1`.
    neg: Vec<BitTensor>,
    fold: Option<FoldSpec>,
}

/// A lowered program: the fused op list plus per-layer lowering data
/// and the plan's (shrunken) MSB tuple demand.
#[derive(Clone, Debug)]
pub struct FusedPlan {
    pub fops: Vec<FusedOp>,
    bins: BTreeMap<usize, BinSpec>,
    /// Per-sample element counts of every MSB draw, in order.
    msb_units: Vec<usize>,
}

impl FusedPlan {
    /// Element counts of every MSB invocation the fused walk makes for
    /// `batch` samples (the fused analogue of `engine::msb_sizes`).
    pub fn msb_sizes(&self, batch: usize) -> Vec<usize> {
        self.msb_units.iter().map(|u| u * batch).collect()
    }

    /// Total MSB elements one fused batched inference consumes.
    pub fn msb_demand(&self, batch: usize) -> usize {
        self.msb_sizes(batch).iter().sum()
    }
}

/// Fused-plan tuple demand straight from the plaintext model (the
/// coordinator sizes `TupleBank` watermarks with this when fusion is
/// on; folded signs and OR-pools consume zero tuples, so the demand is
/// strictly no larger than `msb_demand_for`).
pub fn msb_demand_fused(model: &Model, batch: usize)
                        -> Result<usize, FusionError> {
    Ok(plan_fused(model)?.msb_demand(batch))
}

/// `msb_demand_fused`'s per-invocation sizes.
pub fn msb_sizes_fused(model: &Model, batch: usize)
                       -> Result<Vec<usize>, FusionError> {
    Ok(plan_fused(model)?.msb_sizes(batch))
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Dom {
    Arith,
    Bits { pm1: bool },
}

/// Can this linear layer run as XNOR + popcount?  Requires all-±1
/// weights, no bias, and zero padding (zero is not representable in
/// the ±1 encoding).
fn bin_spec(model: &Model, op: &Op) -> Option<BinSpec> {
    match op {
        Op::Matmul { conv, m, kdim, geom, w, b, .. } => {
            if b.is_some() {
                return None;
            }
            let (_k, _s, pl, ph) = *geom;
            if *conv && (pl != 0 || ph != 0) {
                return None;
            }
            let vals = model.pool_slice(*w);
            if !vals.iter().all(|&v| v == 1 || v == -1) {
                return None;
            }
            let neg = (0..*m).map(|o| {
                BitTensor::from_fn(*kdim,
                                   |r| u8::from(vals[o * kdim + r] == -1))
            }).collect();
            Some(BinSpec { depthwise: false, conv: *conv, rows: *m,
                           kdim: *kdim, geom: *geom, neg, fold: None })
        }
        Op::Depthwise { c, geom, w, .. } => {
            let (k, _s, pl, ph) = *geom;
            if pl != 0 || ph != 0 {
                return None;
            }
            let vals = model.pool_slice(*w);
            if !vals.iter().all(|&v| v == 1 || v == -1) {
                return None;
            }
            let kk = k * k;
            let neg = (0..*c).map(|ci| {
                BitTensor::from_fn(kk,
                                   |r| u8::from(vals[ci * kk + r] == -1))
            }).collect();
            Some(BinSpec { depthwise: true, conv: true, rows: *c,
                           kdim: kk, geom: *geom, neg, fold: None })
        }
        _ => None,
    }
}

/// Fold a sign threshold into a popcount threshold (see module doc for
/// the algebra; thresholds clamp to [0, K+1] so the constant cases
/// fall out of the adder).
fn fold_spec(model: &Model, sign_index: usize, t: crate::nn::PoolRef,
             flip: crate::nn::PoolRef, kdim: usize) -> FoldSpec {
    let ts = model.pool_slice(t);
    let fs = model.pool_slice(flip);
    let k = kdim as i64;
    let mut thresh = Vec::with_capacity(ts.len());
    let mut negate = Vec::with_capacity(ts.len());
    for (tv, fv) in ts.iter().zip(fs) {
        let (thr, neg) = if *fv > 0 {
            ((k + i64::from(*tv) + 1).div_euclid(2), false)
        } else if *fv < 0 {
            ((k + i64::from(*tv)).div_euclid(2) + 1, true)
        } else {
            (0, false) // sign(0 * flip) = 1, constant
        };
        thresh.push(thr.clamp(0, k + 1) as u32);
        negate.push(neg);
    }
    FoldSpec { sign_index, thresh, negate }
}

/// Lower a model into a fused plan, or reject it with a typed error.
pub fn plan_fused(model: &Model) -> Result<FusedPlan, FusionError> {
    let mut fops = Vec::new();
    let mut bins = BTreeMap::new();
    let mut msb_units = Vec::new();
    let mut dom = Dom::Arith;
    let (mut c, mut h, mut w) = model.input;
    let err = |i: usize, op: &Op, reason: &'static str| FusionError {
        index: i, op: op.name(), reason,
    };

    let n_ops = model.ops.len();
    let mut i = 0;
    while i < n_ops {
        let op = &model.ops[i];
        match op {
            Op::Sign { .. } => {
                // an unfolded sign over bits re-enters arithmetic first
                if matches!(dom, Dom::Bits { .. }) {
                    fops.push(FusedOp::ToArith { before: i });
                }
                fops.push(FusedOp::SignEnter(i));
                msb_units.push(c * h * w);
                dom = Dom::Bits { pm1: false };
            }
            Op::PoolBits { k, stride, .. } => {
                match dom {
                    Dom::Bits { pm1: false } => fops.push(FusedOp::OrPool(i)),
                    Dom::Bits { pm1: true } => {
                        return Err(err(i, op, "pool over ±1-encoded bits: \
                                               OR-pool needs the 0/1 \
                                               encoding (the arithmetic \
                                               path computes a majority \
                                               here, not a max)"));
                    }
                    Dom::Arith => fops.push(FusedOp::Arith(i)),
                }
                h = (h - k) / stride + 1;
                w = (w - k) / stride + 1;
                if dom == Dom::Arith {
                    msb_units.push(c * h * w);
                }
            }
            Op::Pm1 => match dom {
                Dom::Bits { pm1: false } => {
                    fops.push(FusedOp::Pm1Bits(i));
                    dom = Dom::Bits { pm1: true };
                }
                Dom::Bits { pm1: true } => {
                    return Err(err(i, op, "pm1 applied to already \
                                           ±1-encoded activations"));
                }
                Dom::Arith => {
                    return Err(err(i, op, "pm1 assumes bit-encoded \
                                           activations; none are live in \
                                           the fused plan here"));
                }
            },
            Op::Flatten { .. } => {
                fops.push(match dom {
                    Dom::Bits { .. } => FusedOp::FlattenBits(i),
                    Dom::Arith => FusedOp::Arith(i),
                });
                c *= h * w;
                h = 1;
                w = 1;
            }
            Op::Relu { .. } => {
                if matches!(dom, Dom::Bits { .. }) {
                    fops.push(FusedOp::ToArith { before: i });
                    dom = Dom::Arith;
                }
                fops.push(FusedOp::Arith(i));
                msb_units.push(c * h * w);
            }
            Op::Matmul { .. } | Op::Depthwise { .. } => {
                let spec = if dom == (Dom::Bits { pm1: true }) {
                    bin_spec(model, op)
                } else {
                    None
                };
                // geometry after the layer
                let (oc, oh, ow) = match op {
                    Op::Matmul { conv: true, geom, cout, .. } => {
                        let (k, s, pl, ph) = *geom;
                        (*cout, (h + pl + ph - k) / s + 1,
                         (w + pl + ph - k) / s + 1)
                    }
                    Op::Matmul { conv: false, m, .. } => (*m, 1, 1),
                    Op::Depthwise { geom, .. } => {
                        let (k, s, pl, ph) = *geom;
                        (c, (h + pl + ph - k) / s + 1,
                         (w + pl + ph - k) / s + 1)
                    }
                    _ => unreachable!(),
                };
                match spec {
                    Some(mut spec) => {
                        // fold a directly following matching Sign
                        let folded = match model.ops.get(i + 1) {
                            Some(Op::Sign { c: sc, t, flip })
                                if *sc == spec.rows => {
                                spec.fold = Some(fold_spec(
                                    model, i + 1, *t, *flip, spec.kdim));
                                true
                            }
                            _ => false,
                        };
                        dom = if folded {
                            Dom::Bits { pm1: false }
                        } else {
                            Dom::Arith // popcount materializes via b2a
                        };
                        bins.insert(i, spec);
                        fops.push(FusedOp::BinLinear(i));
                        if folded {
                            i += 1; // the sign op is consumed
                        }
                    }
                    None => {
                        if matches!(dom, Dom::Bits { .. }) {
                            fops.push(FusedOp::ToArith { before: i });
                            dom = Dom::Arith;
                        }
                        fops.push(FusedOp::Arith(i));
                    }
                }
                (c, h, w) = (oc, oh, ow);
            }
        }
        i += 1;
    }
    if matches!(dom, Dom::Bits { .. }) {
        fops.push(FusedOp::ToArith { before: n_ops });
    }
    Ok(FusedPlan { fops, bins, msb_units })
}

// ------------------------------------------------------------------
// the fused walk
// ------------------------------------------------------------------

/// Batched activation state: per-sample arithmetic shares, or one
/// batch-concatenated boolean share (sample-major, (c, h, w)
/// row-major within a sample -- the same element order as the
/// arithmetic `[c, h*w]` layout, so domain crossings never permute).
enum Acts {
    Arith(Vec<Share>),
    Bits { bs: BitShare, pm1: bool },
}

/// Build the XNOR'd bit planes of one binary linear layer: plane `r`
/// holds, for every output element (sample, row, window), the input
/// bit at reduction index `r` XORed with the public `w == -1` mask.
/// Returns (planes, element count, output geometry).
fn xnor_planes(me: usize, bs: &BitShare, spec: &BinSpec, batch: usize,
               cin: (usize, usize, usize))
               -> (Vec<BitShare>, usize, (usize, usize, usize)) {
    let (cc, hh, ww) = cin;
    let (k, st, _, _) = spec.geom;
    let (oh, ow) = if spec.conv {
        ((hh - k) / st + 1, (ww - k) / st + 1)
    } else {
        (1, 1)
    };
    let rows = spec.rows;
    let nwin = oh * ow;
    let nout = batch * rows * nwin;
    let per = cc * hh * ww;
    let mut planes = Vec::with_capacity(spec.kdim);
    for r in 0..spec.kdim {
        // source coordinates of reduction index r (im2col row order
        // for conv: ((ky*k)+kx)*c + ci; w[ci][ky*k+kx] for depthwise)
        let (ci, ky, kx) = if spec.depthwise {
            (0, r / k, r % k) // channel follows the output row
        } else if spec.conv {
            (r % cc, (r / cc) / k, (r / cc) % k)
        } else {
            (r, 0, 0)
        };
        let mut idx = Vec::with_capacity(nout);
        for s in 0..batch {
            for o in 0..rows {
                let src_c = if spec.depthwise { o } else { ci };
                for oy in 0..oh {
                    for ox in 0..ow {
                        let iy = oy * st + ky;
                        let ix = ox * st + kx;
                        idx.push(s * per + src_c * hh * ww + iy * ww + ix);
                    }
                }
            }
        }
        let mask = BitTensor::from_fn(nout, |e| {
            spec.neg[(e / nwin) % rows].get(r)
        });
        planes.push(gather_share(bs, &idx).xor_const(me, &mask));
    }
    (planes, nout, (rows, oh, ow))
}

/// Run a fused plan for a batch.  The contract mirrors
/// `infer_batch_pooled` (same sharing, same reveal, same tuple
/// sources); logits are bit-identical to the unfused walk because the
/// only value-affecting randomness -- truncation masks -- advances on
/// its own PRF counter lane (`PartySeeds::next_trunc_cnt`).
pub fn infer_batch_fused(
    ctx: &Ctx, model: &SharedModel, plan: &FusedPlan,
    backend: &dyn LinearBackend, opts: EngineOptions, inputs: &[Tensor],
    batch: usize, tuples: &TupleSource<'_>)
    -> Result<InferenceOutput> {
    let me = ctx.id();
    let mut acts = Acts::Arith(share_inputs(ctx, model.input, inputs,
                                            batch)?);
    let mut geoms: Vec<(usize, usize, usize)> = vec![model.input; batch];
    let mut op_costs = Vec::with_capacity(plan.fops.len());
    let drift = || anyhow!("fused plan drift: activation domain does \
                            not match the plan");

    for fop in &plan.fops {
        let before = ctx.comm.stats();
        let cur = ctx.comm.tracer().filter(|t| t.enabled())
            .map(|t| t.cursor(ctx.comm));
        let mut label: Option<(usize, String)> = None;
        match fop {
            FusedOp::Arith(i) => {
                let Acts::Arith(ref mut v) = acts else {
                    return Err(drift());
                };
                let op = &model.ops[*i];
                run_arith_op(ctx, model, backend, opts, tuples, *i, op,
                             v, &mut geoms)?;
                label = Some((*i, op.name().to_string()));
            }
            FusedOp::SignEnter(i) => {
                let Acts::Arith(ref v) = acts else {
                    return Err(drift());
                };
                let t = model.thresholds[*i].as_ref().unwrap();
                let flip = model.flips[*i].as_ref().unwrap();
                let d: Vec<Share> = v.iter().zip(geoms.iter())
                    .map(|(s, gm)| {
                        let (cc, hh, ww) = *gm;
                        let z = s.clone().reshape(&[cc, hh * ww]);
                        sub_thresh_flip(&z, t, flip)
                    }).collect();
                let joined = concat(&d);
                let m = msb_via(ctx, tuples, &joined)?;
                // sign = NOT msb, local on the boolean share
                acts = Acts::Bits { bs: m.bits.not(me), pm1: false };
                label = Some((*i, "sign[bits]".to_string()));
            }
            FusedOp::OrPool(i) => {
                let Acts::Bits { ref bs, pm1: false } = acts else {
                    return Err(drift());
                };
                let Op::PoolBits { k, stride, .. } = &model.ops[*i] else {
                    return Err(drift());
                };
                let (cc, hh, ww) = geoms[0];
                let oh = (hh - k) / stride + 1;
                let ow = (ww - k) / stride + 1;
                let nout = batch * cc * oh * ow;
                let mut planes = Vec::with_capacity(k * k);
                for ky in 0..*k {
                    for kx in 0..*k {
                        let mut idx = Vec::with_capacity(nout);
                        for s in 0..batch {
                            for ci in 0..cc {
                                for oy in 0..oh {
                                    for ox in 0..ow {
                                        let iy = oy * stride + ky;
                                        let ix = ox * stride + kx;
                                        idx.push(s * cc * hh * ww
                                                 + ci * hh * ww
                                                 + iy * ww + ix);
                                    }
                                }
                            }
                        }
                        planes.push(gather_share(bs, &idx));
                    }
                }
                let out = or_planes(ctx, planes)?;
                acts = Acts::Bits { bs: out, pm1: false };
                geoms = vec![(cc, oh, ow); batch];
                label = Some((*i, "pool_bits[or]".to_string()));
            }
            FusedOp::Pm1Bits(i) => {
                let Acts::Bits { ref mut pm1, .. } = acts else {
                    return Err(drift());
                };
                *pm1 = true; // encoding flag only; the bits never move
                label = Some((*i, "pm1[mark]".to_string()));
            }
            FusedOp::FlattenBits(i) => {
                if !matches!(acts, Acts::Bits { .. }) {
                    return Err(drift());
                }
                let (cc, hh, ww) = geoms[0];
                geoms = vec![(cc * hh * ww, 1, 1); batch];
                label = Some((*i, "flatten[bits]".to_string()));
            }
            FusedOp::BinLinear(i) => {
                let Acts::Bits { ref bs, pm1: true } = acts else {
                    return Err(drift());
                };
                let spec = &plan.bins[i];
                let (planes, nout, out_geom) =
                    xnor_planes(me, bs, spec, batch, geoms[0]);
                let (rows, oh, ow) = out_geom;
                let nwin = oh * ow;
                let base = if spec.depthwise { "depthwise" } else { "matmul" };
                match &spec.fold {
                    Some(f) => {
                        let thresh: Vec<u32> = (0..nout)
                            .map(|e| f.thresh[(e / nwin) % rows]).collect();
                        let mut out = popcount_ge(ctx, planes, &thresh)?;
                        let negpat = BitTensor::from_fn(nout, |e| {
                            u8::from(f.negate[(e / nwin) % rows])
                        });
                        if negpat.popcount() > 0 {
                            out = out.xor_const(me, &negpat);
                        }
                        acts = Acts::Bits { bs: out, pm1: false };
                        label = Some((*i, format!("{base}[xnor+sign]")));
                    }
                    None => {
                        // dot = 2*pc - K, materialized via one b2a
                        let pc = popcount_to_arith(ctx, planes)?;
                        let dot = pc.scale(2)
                            .add_const(me, -(spec.kdim as i32));
                        let shapes = vec![vec![rows, nwin]; batch];
                        acts = Acts::Arith(split(dot, &shapes));
                        label = Some((*i, format!("{base}[xnor]")));
                    }
                }
                geoms = vec![out_geom; batch];
            }
            FusedOp::ToArith { before } => {
                let Acts::Bits { ref bs, pm1 } = acts else {
                    return Err(drift());
                };
                let ar = b2a(ctx, bs)?;
                let ar = if pm1 { ar.pm1(me) } else { ar };
                let (cc, hh, ww) = geoms[0];
                let shapes = vec![vec![cc, hh * ww]; batch];
                acts = Acts::Arith(split(ar, &shapes));
                label = Some((*before, "b2a[boundary]".to_string()));
            }
        }
        let (index, op) = label.unwrap();
        if let Some(cur) = cur {
            if let Some(tr) = ctx.comm.tracer() {
                tr.close(ctx.comm, crate::trace::SpanKind::Op,
                         index as u32, &op, &cur);
            }
        }
        op_costs.push(cost_row(ctx, index, op, &before));
    }

    let Acts::Arith(ref v) = acts else {
        return Err(drift()); // plan always ends arithmetic
    };
    let joined = concat(v);
    let logits = reveal_to_p0(ctx, &joined)?;
    if me == 0 {
        let lv = logits.unwrap();
        let per = lv.len() / batch;
        Ok(InferenceOutput {
            logits: lv.chunks(per).map(<[i32]>::to_vec).collect(),
            op_costs,
        })
    } else {
        Ok(InferenceOutput { logits: vec![], op_costs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{infer_batch_pooled, msb_demand_for, share_model};
    use crate::protocols::linear::NativeBackend;
    use crate::protocols::preproc::MsbPool;
    use crate::protocols::testsupport::run3;
    use crate::testutil::threeparty::every_op_model;

    fn model_json(layers: &str, input: (usize, usize, usize),
                  pool: Vec<i32>) -> Model {
        let manifest = format!(r#"{{
          "name": "t", "dataset": "synthetic",
          "input": {{"c": {}, "h": {}, "w": {}}},
          "s_in": 0, "ring_bits": 32,
          "layers": [{}]
        }}"#, input.0, input.1, input.2, layers);
        Model::from_json(&manifest, pool).unwrap()
    }

    /// flatten -> fc(+bias) -> sign -> pm1 -> fc(±1, no bias): the
    /// canonical Sign -> Matmul chain with a binary linear tail.
    fn sign_matmul_chain() -> Model {
        let layers = r#"
            {"op": "flatten", "c": 1, "h": 2, "w": 2},
            {"op": "matmul", "conv": false, "m": 4, "kdim": 4, "n": 1,
             "w": {"off": 0, "len": 16}, "b": {"off": 16, "len": 4},
             "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 4, "t": {"off": 20, "len": 4},
             "flip": {"off": 24, "len": 4}},
            {"op": "pm1"},
            {"op": "matmul", "conv": false, "m": 3, "kdim": 4, "n": 1,
             "w": {"off": 28, "len": 12}, "s_in": 0, "s_out": 0}"#;
        let mut pool: Vec<i32> = (0..28).map(|v| (v % 5) - 2).collect();
        pool[24..28].copy_from_slice(&[1, -1, 1, 1]); // non-zero flips
        // ±1 weights for the binary fc
        pool.extend((0..12).map(|v| if v % 3 == 0 { -1 } else { 1 }));
        model_json(layers, (1, 2, 2), pool)
    }

    #[test]
    fn planner_lowers_the_every_op_model() {
        let model = every_op_model();
        let plan = plan_fused(&model).unwrap();
        // conv stays arithmetic; sign enters bits; pool_bits -> OR;
        // pm1 -> flag; depthwise weights are {0,1} (not ±1) so the
        // region ends there; the tail runs arithmetic
        assert_eq!(plan.fops, vec![
            FusedOp::Arith(0),
            FusedOp::SignEnter(1),
            FusedOp::OrPool(2),
            FusedOp::Pm1Bits(3),
            FusedOp::ToArith { before: 4 },
            FusedOp::Arith(4),
            FusedOp::Arith(5),
            FusedOp::Arith(6),
            FusedOp::Arith(7),
        ]);
        // tuple demand shrinks: the pooled sign disappears (OR-pool
        // draws nothing), only the entry sign and the relu remain
        assert_eq!(plan.msb_sizes(2), vec![64, 6]);
        assert_eq!(plan.msb_demand(2), 70);
        assert_eq!(msb_demand_fused(&model, 2).unwrap(), 70);
        assert!(plan.msb_demand(2) < msb_demand_for(&model, 2));
    }

    #[test]
    fn planner_folds_sign_into_binary_linear() {
        let layers = r#"
            {"op": "flatten", "c": 1, "h": 2, "w": 2},
            {"op": "matmul", "conv": false, "m": 4, "kdim": 4, "n": 1,
             "w": {"off": 0, "len": 16}, "b": {"off": 16, "len": 4},
             "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 4, "t": {"off": 20, "len": 4},
             "flip": {"off": 24, "len": 4}},
            {"op": "pm1"},
            {"op": "matmul", "conv": false, "m": 2, "kdim": 4, "n": 1,
             "w": {"off": 28, "len": 8}, "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 2, "t": {"off": 36, "len": 2},
             "flip": {"off": 38, "len": 2}}"#;
        let mut pool: Vec<i32> = (0..28).map(|v| (v % 5) - 2).collect();
        pool[24..28].copy_from_slice(&[1, 1, -1, 1]);
        pool.extend([1, -1, -1, 1, 1, 1, -1, -1]); // ±1 fc
        pool.extend([1, -3]); // t
        pool.extend([1, -1]); // flip
        let model = model_json(layers, (1, 2, 2), pool);
        let plan = plan_fused(&model).unwrap();
        assert_eq!(plan.fops, vec![
            FusedOp::Arith(0),
            FusedOp::Arith(1),
            FusedOp::SignEnter(2),
            FusedOp::Pm1Bits(3),
            FusedOp::BinLinear(4),
            FusedOp::ToArith { before: 6 },
        ]);
        let spec = &plan.bins[&4];
        let fold = spec.fold.as_ref().expect("sign must fold");
        assert_eq!(fold.sign_index, 5);
        // K=4: flip=+1, t=1 -> ceil(5/2) = 3; flip=-1, t=-3 ->
        // floor(1/2)+1 = 1, negated
        assert_eq!(fold.thresh, vec![3, 1]);
        assert_eq!(fold.negate, vec![false, true]);
        // only the entry sign draws tuples
        assert_eq!(plan.msb_sizes(1), vec![4]);
    }

    #[test]
    fn planner_rejects_inconsistent_sequences_with_typed_errors() {
        // pm1 over arithmetic activations (no live bits)
        let layers = r#"
            {"op": "flatten", "c": 1, "h": 2, "w": 2},
            {"op": "matmul", "conv": false, "m": 2, "kdim": 4, "n": 1,
             "w": {"off": 0, "len": 8}, "s_in": 0, "s_out": 0},
            {"op": "pm1"}"#;
        let model = model_json(layers, (1, 2, 2), (0..8).collect());
        let e = plan_fused(&model).unwrap_err();
        assert_eq!((e.index, e.op), (2, "pm1"));
        assert!(e.to_string().contains("cannot be lowered"), "{e}");

        // double pm1
        let layers = r#"
            {"op": "matmul", "conv": true, "m": 2, "kdim": 4, "n": 9,
             "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
             "w": {"off": 0, "len": 8}, "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 2, "t": {"off": 8, "len": 2},
             "flip": {"off": 10, "len": 2}},
            {"op": "pm1"},
            {"op": "pm1"}"#;
        let mut pool: Vec<i32> = (0..10).map(|v| (v % 3) - 1).collect();
        pool.extend([1, 1]);
        let model = model_json(layers, (1, 4, 4), pool.clone());
        let e = plan_fused(&model).unwrap_err();
        assert_eq!((e.index, e.op), (3, "pm1"));

        // pool over ±1-encoded bits (an OR would change the function)
        let layers = r#"
            {"op": "matmul", "conv": true, "m": 2, "kdim": 4, "n": 9,
             "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
             "w": {"off": 0, "len": 8}, "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 2, "t": {"off": 8, "len": 2},
             "flip": {"off": 10, "len": 2}},
            {"op": "pm1"},
            {"op": "pool_bits", "c": 2, "k": 3, "stride": 1}"#;
        let model = model_json(layers, (1, 4, 4), pool);
        let e = plan_fused(&model).unwrap_err();
        assert_eq!((e.index, e.op), (3, "pool_bits"));
    }

    #[test]
    fn fused_and_unfused_meet_design_round_budgets() {
        // DESIGN.md budgets, made executable via the per-op cost rows:
        // linear+reshare = 1 round, online Sign (pooled MSB) = 2, B2A
        // boundary = 3; the fused binary fc stays inside the CSA+KS
        // bound.  Logits are bit-identical (no truncation in this
        // model, and trunc randomness has its own lane anyway).
        let results = run3(|ctx| {
            let model = sign_matmul_chain();
            let shared = share_model(ctx, &model, true).unwrap();
            let plan = plan_fused(&model).unwrap();
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = crate::testutil::Rng::new(12);
                vec![rng.tensor_small(&[1, 4], 15),
                     rng.tensor_small(&[1, 4], 15)]
            } else {
                vec![]
            };
            let pool = MsbPool::new();
            pool.generate(ctx, msb_demand_for(&model, 2)).unwrap();
            let unfused = infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, 2, &TupleSource::Pool(&pool)).unwrap();
            let fpool = MsbPool::new();
            fpool.generate(ctx, plan.msb_demand(2)).unwrap();
            let fused = infer_batch_fused(
                ctx, &shared, &plan, &NativeBackend,
                EngineOptions::default(), &inputs, 2,
                &TupleSource::Pool(&fpool)).unwrap();
            assert_eq!(fpool.available(), 0,
                       "plan.msb_sizes must mirror the fused walk");
            (unfused.logits, fused.logits,
             unfused.op_costs, fused.op_costs)
        });
        let (u_logits, f_logits, u_costs, f_costs) = results[0].0.clone();
        assert_eq!(u_logits, f_logits, "fused logits must be identical");
        let row = |costs: &[crate::metrics::OpCost], op: &str|
            costs.iter().find(|r| r.op == op).cloned()
                .unwrap_or_else(|| panic!("no {op} row"));
        // unfused: Sign = 2 rounds (pooled MSB), fc matmul = 1
        assert_eq!(row(&u_costs, "sign").rounds, 2);
        assert_eq!(row(&u_costs, "matmul").rounds, 1);
        // fused: the entry sign keeps the 2-round budget; pm1 is free;
        // the binary fc (K=4, B=3) fits CSA levels + 1 + log2(B) + B2A
        assert_eq!(row(&f_costs, "sign[bits]").rounds, 2);
        assert_eq!(row(&f_costs, "pm1[mark]").rounds, 0);
        assert_eq!(row(&f_costs, "pm1[mark]").bytes_sent, 0);
        let bin = row(&f_costs, "matmul[xnor]");
        assert!(bin.rounds >= 4 && bin.rounds <= 9,
                "binary fc rounds = {}", bin.rounds);
        // every party agrees on the cost rows (lock-step protocols)
        for p in 1..3 {
            assert_eq!(results[p].0 .3.iter().map(|r| r.rounds)
                       .collect::<Vec<_>>(),
                       f_costs.iter().map(|r| r.rounds)
                       .collect::<Vec<_>>());
        }
    }

    #[test]
    fn b2a_boundary_meets_the_design_budget() {
        // a model that ends in the binary domain exercises the final
        // ToArith: DESIGN's B2A budget is 3 rounds
        let layers = r#"
            {"op": "flatten", "c": 1, "h": 2, "w": 2},
            {"op": "matmul", "conv": false, "m": 3, "kdim": 4, "n": 1,
             "w": {"off": 0, "len": 12}, "b": {"off": 12, "len": 3},
             "s_in": 0, "s_out": 0},
            {"op": "sign", "c": 3, "t": {"off": 15, "len": 3},
             "flip": {"off": 18, "len": 3}}"#;
        let mut pool: Vec<i32> = (0..18).map(|v| (v % 5) - 2).collect();
        pool.extend([1, -1, 1]);
        let model = model_json(layers, (1, 2, 2), pool);
        let results = run3(|ctx| {
            let shared = share_model(ctx, &model, true).unwrap();
            let plan = plan_fused(&model).unwrap();
            assert!(matches!(plan.fops.last(),
                             Some(FusedOp::ToArith { before: 3 })));
            let inputs: Vec<Tensor> = if ctx.id() == 0 {
                let mut rng = crate::testutil::Rng::new(13);
                vec![rng.tensor_small(&[1, 4], 15)]
            } else {
                vec![]
            };
            let fpool = MsbPool::new();
            fpool.generate(ctx, plan.msb_demand(1)).unwrap();
            let fused = infer_batch_fused(
                ctx, &shared, &plan, &NativeBackend,
                EngineOptions::default(), &inputs, 1,
                &TupleSource::Pool(&fpool)).unwrap();
            let unfused = infer_batch_pooled(
                ctx, &shared, &NativeBackend, EngineOptions::default(),
                &inputs, 1, &TupleSource::Inline).unwrap();
            (fused.logits, unfused.logits, fused.op_costs)
        });
        let (f_logits, u_logits, costs) = results[0].0.clone();
        assert_eq!(f_logits, u_logits);
        let b2a_row = costs.iter().find(|r| r.op == "b2a[boundary]")
            .expect("b2a row");
        assert_eq!(b2a_row.rounds, 3, "B2A budget (DESIGN.md)");
    }
}
