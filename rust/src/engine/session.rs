//! Three-party session orchestration: spawns the party threads, wires the
//! simulated network, runs setup (model sharing) and online inference,
//! and aggregates the cost report.  Used by the coordinator, the examples,
//! and every bench.

use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::nn::Model;
use crate::prf::PartySeeds;
use crate::protocols::{Ctx, ProtoConfig};
use crate::ring::Tensor;
use crate::runtime::{make_backend, BackendKind};
use crate::transport::{local_trio, NetConfig, Stats};

use super::{argmax, share_model, EngineOptions};

/// Per-model seed-domain separator for multi-model serving (see
/// `model_seed`).  An odd multiplier (the 64-bit golden-ratio constant)
/// so every slot lands in a distinct domain.
pub const MODEL_SEED_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// The model-scoped session seed for model slot `slot`: every model
/// served over shared links derives its PRF streams (online *and*
/// offline, see `offline::offline_seeds`) from its own seed domain, so
/// no two lanes ever share counters and no correlated-randomness stream
/// is consumed by two models.  Slot 0 is the identity -- single-model
/// sessions are bit-for-bit unchanged.  Distinctness of all 2x128 lane
/// domains for a fixed session seed is pinned by a test.
pub fn model_seed(session_seed: u64, slot: u8) -> u64 {
    session_seed ^ (slot as u64).wrapping_mul(MODEL_SEED_SALT)
}

/// Per-respawn seed-domain separator (see `epoch_seed`).  A different
/// odd constant than `MODEL_SEED_SALT` so epoch and slot displacements
/// cannot cancel for small indices.
pub const EPOCH_SEED_SALT: u64 = 0xC2B2_AE3D_27D4_EB4F;

/// The epoch-scoped seed for one model slot: every quarantine/respawn
/// cycle serves from a fresh PRF domain, so a respawned slot can never
/// resume (or collide with) the desynchronized epoch's correlated
/// randomness streams.  Epoch 0 is the identity -- a slot that never
/// quarantined is bit-for-bit the PR 4 seed domain.  Distinctness
/// across slots x epochs x lanes is pinned by a test below.
pub fn epoch_seed(model_seed: u64, epoch: u32) -> u64 {
    model_seed ^ u64::from(epoch).wrapping_mul(EPOCH_SEED_SALT)
}

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub net: NetConfig,
    pub backend: BackendKind,
    pub opts: EngineOptions,
    pub proto: ProtoConfig,
    pub hlo_dir: PathBuf,
    pub session_seed: u64,
    /// Serving-bank watermarks (`coordinator::Service` only); `None`
    /// auto-scales to the model's demand at `max_batch`.
    pub bank: Option<crate::offline::BankConfig>,
    /// Largest batch the serving front will form (`BatchPolicy::
    /// max_batch`); sizes the auto bank so its capacity always admits a
    /// full batch's largest MSB draw.
    pub max_batch: usize,
    /// Per-lane, per-direction cap on parked demux frames at the
    /// transport (`Comm::set_parked_cap`; the CLI's
    /// `serve --max-parked-bytes`).  Bounds what a malicious peer can
    /// park on a registered-but-idle lane.
    pub max_parked_bytes: usize,
    /// Consecutive `Service::infer` failures a registry slot tolerates
    /// before the watchdog force-quarantines it (`ModelRegistry`;
    /// counted in `LifecycleCounters::watchdog_trips`).  0 disables the
    /// watchdog; the CLI's `serve --max-infer-errors`.
    pub max_consecutive_errors: u32,
    /// Record per-party trace spans (`trace::TraceSink`): one Request
    /// span per inference plus the Op/Protocol/Flight spans underneath.
    /// Off by default -- with tracing off no sink is even installed, so
    /// the request path pays one `OnceLock::get` returning `None`.
    pub trace: bool,
}

impl SessionConfig {
    pub fn new(hlo_dir: impl Into<PathBuf>) -> Self {
        SessionConfig {
            net: NetConfig::zero(),
            backend: BackendKind::Native,
            opts: EngineOptions::default(),
            proto: ProtoConfig::default(),
            hlo_dir: hlo_dir.into(),
            session_seed: 7,
            bank: None,
            max_batch: 8,
            max_parked_bytes: crate::transport::DEFAULT_PARKED_CAP,
            max_consecutive_errors: 3,
            trace: false,
        }
    }

    pub fn with_net(mut self, net: NetConfig) -> Self {
        self.net = net;
        self
    }

    pub fn with_backend(mut self, b: BackendKind) -> Self {
        self.backend = b;
        self
    }

    pub fn with_bank(mut self, bank: crate::offline::BankConfig) -> Self {
        self.bank = Some(bank);
        self
    }
}

/// Cost + accuracy report for one batched inference session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    pub preds: Vec<usize>,
    pub logits: Vec<Vec<i32>>,
    /// Online (inference) wall time, as seen by the data owner.
    pub online: Duration,
    /// Model-sharing setup wall time.
    pub setup: Duration,
    pub stats: [Stats; 3],
    /// Party 0's per-op wire-cost rows for the online walk (the CLI's
    /// `infer` table; see `metrics::op_cost_table`).
    pub op_costs: Vec<crate::metrics::OpCost>,
    /// Per-party recorded spans (empty unless `SessionConfig::trace`).
    pub traces: Vec<Vec<crate::trace::Span>>,
}

impl SessionReport {
    pub fn total_bytes(&self) -> u64 {
        self.stats.iter().map(|s| s.bytes_sent).sum()
    }

    pub fn max_rounds(&self) -> u64 {
        self.stats.iter().map(|s| s.rounds).max().unwrap_or(0)
    }

    pub fn comm_mb(&self) -> f64 {
        self.total_bytes() as f64 / 1.0e6
    }
}

/// Run one batched secure inference over a fresh 3-party session.
/// `inputs` are the data owner's plaintext ring images (C*H*W flat).
pub fn run_inference(model: &Arc<Model>, inputs: Vec<Tensor>,
                     cfg: &SessionConfig) -> Result<SessionReport> {
    let batch = inputs.len();
    if batch == 0 {
        return Err(anyhow!("empty batch"));
    }
    let comms = local_trio(cfg.net);
    // one trace id covers all three parties' Request spans, so the
    // cross-party merge joins them (`trace::merge`)
    let trace_id = if cfg.trace { crate::trace::next_trace_id() } else { 0 };
    let mut handles = Vec::new();
    for comm in comms {
        let model = Arc::clone(model);
        let cfg = cfg.clone();
        let inputs = if comm.id == 0 { inputs.clone() } else { vec![] };
        handles.push(thread::spawn(move || -> Result<(
            Vec<Vec<i32>>, Duration, Duration, Stats,
            Vec<crate::metrics::OpCost>, Vec<crate::trace::Span>)> {
            // installed now, enabled only after `reset_stats` below so
            // the recorded flights reconcile exactly with the online
            // Stats the report carries
            let sink = if cfg.trace {
                let s = Arc::new(crate::trace::TraceSink::new());
                comm.install_tracer(Arc::clone(&s));
                crate::trace::set_current_trace(trace_id);
                Some(s)
            } else {
                None
            };
            let seeds = PartySeeds::setup(cfg.session_seed, comm.id);
            let ctx = Ctx::with_cfg(&comm, &seeds, cfg.proto);
            let backend = make_backend(cfg.backend, &cfg.hlo_dir)?;
            let t0 = Instant::now();
            // compile the layer executables during setup, never online
            backend.warmup(&super::hlo_keys(&model));
            // fused plans are public structure: every party lowers the
            // manifest identically (or rejects it identically, at setup)
            let plan = if cfg.opts.fuse {
                Some(super::fusion::plan_fused(&model)?)
            } else {
                None
            };
            let shared = share_model(&ctx, &model, true)?;
            // offline phase: mint the MSB correlated material (fused
            // plans demand strictly less -- folded signs and OR-pools
            // draw nothing)
            let pool = if cfg.opts.preprocess {
                let demand = match &plan {
                    Some(p) => p.msb_demand(batch),
                    None => super::msb_demand(&shared, batch),
                };
                let pool = crate::protocols::preproc::MsbPool::new();
                pool.generate(&ctx, demand)?;
                Some(pool)
            } else {
                None
            };
            let tuples = match &pool {
                Some(p) => crate::offline::TupleSource::Pool(p),
                None => crate::offline::TupleSource::Inline,
            };
            let setup = t0.elapsed();
            comm.reset_stats(); // report online cost separately
            if let Some(s) = &sink {
                s.set_enabled(true);
            }
            let cur = sink.as_ref().map(|s| s.cursor(&comm));
            let t1 = Instant::now();
            let out = match &plan {
                Some(p) => super::fusion::infer_batch_fused(
                    &ctx, &shared, p, backend.as_ref(), cfg.opts, &inputs,
                    batch, &tuples)?,
                None => super::infer_batch_pooled(
                    &ctx, &shared, backend.as_ref(), cfg.opts, &inputs,
                    batch, &tuples)?,
            };
            let online = t1.elapsed();
            let spans = match (&sink, cur) {
                (Some(s), Some(cur)) => {
                    s.close(&comm, crate::trace::SpanKind::Request, 0,
                            &model.name, &cur);
                    s.snapshot()
                }
                _ => vec![],
            };
            Ok((out.logits, online, setup, comm.stats(), out.op_costs,
                spans))
        }));
    }
    let mut results = Vec::new();
    for h in handles {
        results.push(h.join().map_err(|_| anyhow!("party panicked"))??);
    }
    let logits = results[0].0.clone();
    let preds = logits.iter().map(|l| argmax(l)).collect();
    let stats: Vec<Stats> = results.iter().map(|r| r.3.clone()).collect();
    Ok(SessionReport {
        preds,
        logits,
        online: results[0].1,
        setup: results[0].2,
        stats: stats.try_into().expect("three parties"),
        op_costs: results[0].4.clone(),
        traces: results.iter().map(|r| r.5.clone()).collect(),
    })
}

/// Accuracy helper: run `inputs` through the secure engine in batches and
/// compare predictions against labels.
pub fn secure_accuracy(model: &Arc<Model>, inputs: &[Tensor], labels: &[i32],
                       batch: usize, cfg: &SessionConfig) -> Result<f64> {
    let mut correct = 0usize;
    let mut done = 0usize;
    for chunk in inputs.chunks(batch) {
        let rep = run_inference(model, chunk.to_vec(), cfg)?;
        for (p, &l) in rep.preds.iter().zip(&labels[done..]) {
            if *p == l as usize {
                correct += 1;
            }
        }
        done += chunk.len();
    }
    Ok(correct as f64 / done as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_seed_domains_are_distinct_across_all_lanes() {
        // one session seed spans up to 128 model slots x 2 lanes; every
        // lane's PRF seed domain must be distinct, or two lanes could
        // share counters / reuse correlated randomness
        for session in [0u64, 7, u64::MAX, 0x1234_5678_9ABC_DEF0] {
            let mut seen = std::collections::BTreeSet::new();
            for slot in 0..128u8 {
                let online = model_seed(session, slot);
                let offline = online ^ crate::offline::OFFLINE_SEED_SALT;
                assert!(seen.insert(online),
                        "online domain collision at slot {slot}");
                assert!(seen.insert(offline),
                        "offline domain collision at slot {slot}");
            }
            assert_eq!(seen.len(), 256);
        }
        // slot 0 is the identity: single-model sessions are unchanged
        assert_eq!(model_seed(42, 0), 42);
        assert_ne!(model_seed(42, 1), 42);
    }

    #[test]
    fn epoch_seed_domains_are_distinct_across_slots_and_lanes() {
        // a quarantined slot respawns into a fresh domain: for a fixed
        // session seed, every (slot, epoch, lane) triple must map to a
        // distinct PRF seed over the ranges a long-lived registry can
        // realistically visit
        for session in [0u64, 7, u64::MAX] {
            let mut seen = std::collections::BTreeSet::new();
            for slot in 0..16u8 {
                for epoch in 0..16u32 {
                    let online = epoch_seed(model_seed(session, slot),
                                            epoch);
                    let offline =
                        online ^ crate::offline::OFFLINE_SEED_SALT;
                    assert!(seen.insert(online),
                            "online collision at slot {slot} epoch \
                             {epoch}");
                    assert!(seen.insert(offline),
                            "offline collision at slot {slot} epoch \
                             {epoch}");
                }
            }
            assert_eq!(seen.len(), 16 * 16 * 2);
        }
        // epoch 0 is the identity: a never-quarantined slot is
        // bit-for-bit the PR 4 seed domain
        assert_eq!(epoch_seed(99, 0), 99);
        assert_ne!(epoch_seed(99, 1), 99);
    }
}
