//! Link conditioning: named network profiles and custom WAN specs.
//!
//! Every link in this crate is already "shimmed" -- [`super::NetConfig`]
//! models one-way latency, bandwidth serialization, and per-frame jitter
//! on every local link, in either wall-clock mode (receives sleep; for
//! benches) or deterministic virtual-clock mode (each party advances a
//! virtual nanosecond clock instead; for tests -- WAN timing without WAN
//! wall time, see [`super::Comm::virtual_now`]).  This module is the
//! operator surface: it parses the `--net` flag grammar shared by
//! `serve`, `infer`, and the bench harness into a `NetConfig`, and owns
//! the deterministic jitter draw.
//!
//! Grammar (case-sensitive keys, case-insensitive named profiles):
//!
//! ```text
//!   lan | wan | zero | none          named profiles (paper settings)
//!   key=value[,key=value...]         custom spec
//!       rtt=DUR      round-trip time (one-way latency = rtt/2)
//!       lat=DUR      one-way latency (alternative to rtt)
//!       bw=RATE      bandwidth: 40MBps, 1GBps, 625KBps, inf
//!       jitter=DUR   max extra per-frame delay, drawn deterministically
//!       virtual      deterministic virtual clock (no sleeping)
//!       wall         wall-clock simulation (default)
//!   DUR: float + ns|us|ms|s          e.g. 40ms, 1.5s, 200us
//! ```
//!
//! Examples: `--net wan`, `--net rtt=40ms,bw=40MBps`,
//! `--net rtt=40ms,jitter=1ms,virtual`.

use std::time::Duration;

use super::NetConfig;

/// Parse a `--net` network spec (see the module docs for the grammar).
pub fn parse_net_spec(s: &str) -> Result<NetConfig, String> {
    match s.to_ascii_lowercase().as_str() {
        "lan" => return Ok(NetConfig::lan()),
        "wan" => return Ok(NetConfig::wan()),
        "zero" | "none" => return Ok(NetConfig::zero()),
        _ => {}
    }
    if !s.contains('=') && s != "virtual" && s != "wall" {
        return Err(format!(
            "unknown network spec '{s}': expected lan|wan|zero|none or a \
             custom spec like rtt=40ms,bw=40MBps,jitter=1ms[,virtual]"));
    }
    let mut net = NetConfig::zero();
    for field in s.split(',') {
        let field = field.trim();
        match field.split_once('=') {
            None => match field {
                "virtual" => net.virtual_clock = true,
                "wall" => net.virtual_clock = false,
                _ => return Err(format!(
                    "unknown network spec field '{field}' (expected \
                     rtt=, lat=, bw=, jitter=, virtual, or wall)")),
            },
            Some(("rtt", v)) => net.latency = parse_duration(v)? / 2,
            Some(("lat", v)) => net.latency = parse_duration(v)?,
            Some(("bw", v)) => net.bandwidth = parse_bandwidth(v)?,
            Some(("jitter", v)) => net.jitter = parse_duration(v)?,
            Some((k, _)) => return Err(format!(
                "unknown network spec key '{k}' (expected rtt, lat, bw, \
                 or jitter)")),
        }
    }
    Ok(net)
}

/// Parse a duration literal: float value + ns/us/ms/s suffix (bare `0`
/// is accepted).
pub fn parse_duration(s: &str) -> Result<Duration, String> {
    if s == "0" {
        return Ok(Duration::ZERO);
    }
    let (num, scale) = if let Some(v) = s.strip_suffix("ns") {
        (v, 1e-9)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1e-6)
    } else if let Some(v) = s.strip_suffix("ms") {
        (v, 1e-3)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1.0)
    } else {
        return Err(format!(
            "duration '{s}' needs a ns/us/ms/s suffix (e.g. 40ms)"));
    };
    let v: f64 = num.parse().map_err(|_| {
        format!("bad duration value '{num}' in '{s}'")
    })?;
    if !(v >= 0.0) || !v.is_finite() {
        return Err(format!("duration '{s}' must be finite and >= 0"));
    }
    Ok(Duration::from_secs_f64(v * scale))
}

/// Parse a bandwidth literal: float value + Bps/KBps/MBps/GBps suffix,
/// or `inf` for an unconstrained link.
pub fn parse_bandwidth(s: &str) -> Result<f64, String> {
    if s.eq_ignore_ascii_case("inf") {
        return Ok(f64::INFINITY);
    }
    let (num, scale) = if let Some(v) = s.strip_suffix("GBps") {
        (v, 1e9)
    } else if let Some(v) = s.strip_suffix("MBps") {
        (v, 1e6)
    } else if let Some(v) = s.strip_suffix("KBps") {
        (v, 1e3)
    } else if let Some(v) = s.strip_suffix("Bps") {
        (v, 1.0)
    } else {
        return Err(format!(
            "bandwidth '{s}' needs a Bps/KBps/MBps/GBps suffix or 'inf'"));
    };
    let v: f64 = num.parse().map_err(|_| {
        format!("bad bandwidth value '{num}' in '{s}'")
    })?;
    if !(v > 0.0) || !v.is_finite() {
        return Err(format!("bandwidth '{s}' must be finite and > 0"));
    }
    Ok(v * scale)
}

/// Deterministic per-frame jitter draw in `[0, max]`: a splitmix64 hash
/// of the lane identity and the lane's frame counter, so every run of
/// the same spec produces the same timeline (virtual-clock tests stay
/// reproducible) while frames still spread across the jitter window.
pub(crate) fn jitter(lane_seed: u64, frame: u64, max: Duration)
                     -> Duration {
    if max.is_zero() {
        return Duration::ZERO;
    }
    let h = splitmix64(lane_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                       ^ frame);
    Duration::from_nanos(h % (max.as_nanos() as u64 + 1))
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_profiles_parse() {
        assert_eq!(parse_net_spec("lan").unwrap(), NetConfig::lan());
        assert_eq!(parse_net_spec("wan").unwrap(), NetConfig::wan());
        assert_eq!(parse_net_spec("WAN").unwrap(), NetConfig::wan());
        assert_eq!(parse_net_spec("zero").unwrap(), NetConfig::zero());
        assert_eq!(parse_net_spec("none").unwrap(), NetConfig::zero());
    }

    #[test]
    fn custom_specs_parse() {
        let net = parse_net_spec("rtt=40ms,bw=40MBps,jitter=1ms,virtual")
            .unwrap();
        assert_eq!(net.latency, Duration::from_millis(20));
        assert_eq!(net.bandwidth, 40.0e6);
        assert_eq!(net.jitter, Duration::from_millis(1));
        assert!(net.virtual_clock);

        let net = parse_net_spec("lat=5ms").unwrap();
        assert_eq!(net.latency, Duration::from_millis(5));
        assert_eq!(net.bandwidth, f64::INFINITY);
        assert!(!net.virtual_clock);

        let net = parse_net_spec("rtt=1.5s,bw=inf").unwrap();
        assert_eq!(net.latency, Duration::from_millis(750));

        let net = parse_net_spec("lat=200us,bw=625KBps").unwrap();
        assert_eq!(net.latency, Duration::from_micros(200));
        assert_eq!(net.bandwidth, 625.0e3);
    }

    #[test]
    fn bad_specs_are_errors() {
        assert!(parse_net_spec("dsl").is_err());
        assert!(parse_net_spec("rtt=40").is_err()); // missing unit
        assert!(parse_net_spec("rtt=-4ms").is_err());
        assert!(parse_net_spec("bw=0MBps").is_err());
        assert!(parse_net_spec("speed=1MBps").is_err());
        assert!(parse_net_spec("rtt=40ms,warp").is_err());
        assert!(parse_net_spec("").is_err());
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let max = Duration::from_millis(3);
        for lane in 0..4u64 {
            for frame in 0..100u64 {
                let a = jitter(lane, frame, max);
                let b = jitter(lane, frame, max);
                assert_eq!(a, b);
                assert!(a <= max);
            }
        }
        // not constant across frames
        let draws: Vec<_> = (0..50).map(|f| jitter(1, f, max)).collect();
        assert!(draws.windows(2).any(|w| w[0] != w[1]));
        assert_eq!(jitter(1, 1, Duration::ZERO), Duration::ZERO);
    }
}
