//! Party-to-party transport with network simulation and cost accounting.
//!
//! The three parties run as threads (in-process, `Link::Local`) or as
//! separate processes (`Link::Tcp`).  Every link models the paper's
//! LAN/WAN settings: each message arrives after `latency + bytes /
//! bandwidth`, with link serialization (back-to-back messages queue behind
//! each other).  Byte, message, and round counts are recorded per party --
//! the round counter is advanced explicitly by the protocol layer so the
//! per-protocol round budgets in DESIGN.md are testable.

use std::cell::{Cell, RefCell};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use crate::ring::bits::BitTensor;
use crate::ring::planes::BitPlanes;

/// Upper bound on a single wire message; a claimed length beyond this is
/// rejected before any allocation (attacker-controlled length hardening).
pub const MAX_MSG_BYTES: u64 = 1 << 30;

/// Wire-level failure.  Receive paths return this instead of panicking the
/// party thread: lengths and structure arrive from the peer and must be
/// treated as untrusted input (see DESIGN.md §wire format).
#[derive(Debug)]
pub enum WireError {
    /// The peer's channel/socket closed mid-protocol.
    Closed,
    /// Socket-level I/O failure.
    Io(std::io::Error),
    /// The message failed structural validation (bad length, bad header).
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "peer hung up"),
            WireError::Io(e) => write!(f, "transport i/o: {e}"),
            WireError::Malformed(m) => write!(f, "malformed message: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// One-way network model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetConfig {
    pub latency: Duration,
    /// Bytes per second; `f64::INFINITY` disables the bandwidth term.
    pub bandwidth: f64,
}

impl NetConfig {
    /// Paper LAN: 0.2 ms RTT-ish latency, 625 MBps.
    pub fn lan() -> Self {
        NetConfig { latency: Duration::from_micros(200),
                    bandwidth: 625.0e6 }
    }

    /// Paper WAN: 80 ms latency, 40 MBps.
    pub fn wan() -> Self {
        NetConfig { latency: Duration::from_millis(80), bandwidth: 40.0e6 }
    }

    /// No simulation (unit tests).
    pub fn zero() -> Self {
        NetConfig { latency: Duration::ZERO, bandwidth: f64::INFINITY }
    }

    /// Time the link is *occupied* transmitting (serialization).
    fn serialize(&self, bytes: usize) -> Duration {
        if self.bandwidth.is_finite() {
            Duration::from_secs_f64(bytes as f64 / self.bandwidth)
        } else {
            Duration::ZERO
        }
    }
}

/// Communication statistics for one party.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub bytes_sent: u64,
    pub messages: u64,
    pub rounds: u64,
}

struct Msg {
    payload: Vec<u8>,
    arrival: Instant,
}

enum LinkTx {
    Local(Sender<Msg>),
    Tcp(RefCell<TcpStream>),
}

enum LinkRx {
    Local(Receiver<Msg>),
    Tcp(RefCell<TcpStream>),
}

/// A party's endpoints to its two neighbours plus accounting.
pub struct Comm {
    pub id: usize,
    tx_next: LinkTx,
    tx_prev: LinkTx,
    rx_next: LinkRx,
    rx_prev: LinkRx,
    net: NetConfig,
    busy_next: Cell<Instant>,
    busy_prev: Cell<Instant>,
    stats: RefCell<Stats>,
}

/// Which neighbour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dir {
    Next,
    Prev,
}

impl Comm {
    /// Ship one framed message.  A hung-up peer surfaces as
    /// `WireError::Closed` (local links) or `WireError::Io` (TCP) so the
    /// party thread retires cleanly instead of panicking mid-protocol --
    /// the send path is hardened to match the receive path.  Public so
    /// wire-format tests can craft adversarial frames.
    pub fn send_raw(&self, dir: Dir, payload: Vec<u8>)
                    -> Result<(), WireError> {
        let now = Instant::now();
        let busy = match dir {
            Dir::Next => &self.busy_next,
            Dir::Prev => &self.busy_prev,
        };
        // serialization occupies the link; propagation (latency) overlaps
        // across back-to-back messages
        let start = busy.get().max(now);
        let sent = start + self.net.serialize(payload.len());
        busy.set(sent);
        let arrival = sent + self.net.latency;
        {
            let mut st = self.stats.borrow_mut();
            st.bytes_sent += payload.len() as u64;
            st.messages += 1;
        }
        match (dir, &self.tx_next, &self.tx_prev) {
            (Dir::Next, LinkTx::Local(tx), _) | (Dir::Prev, _, LinkTx::Local(tx)) => {
                tx.send(Msg { payload, arrival })
                    .map_err(|_| WireError::Closed)
            }
            (Dir::Next, LinkTx::Tcp(s), _) | (Dir::Prev, _, LinkTx::Tcp(s)) => {
                let mut s = s.borrow_mut();
                let len = (payload.len() as u64).to_le_bytes();
                s.write_all(&len)?;
                s.write_all(&payload)?;
                Ok(())
            }
        }
    }

    fn recv_raw(&self, dir: Dir) -> Result<Vec<u8>, WireError> {
        match (dir, &self.rx_next, &self.rx_prev) {
            (Dir::Next, LinkRx::Local(rx), _) | (Dir::Prev, _, LinkRx::Local(rx)) => {
                let msg = rx.recv().map_err(|_| WireError::Closed)?;
                let now = Instant::now();
                if msg.arrival > now {
                    std::thread::sleep(msg.arrival - now);
                }
                Ok(msg.payload)
            }
            (Dir::Next, LinkRx::Tcp(s), _) | (Dir::Prev, _, LinkRx::Tcp(s)) => {
                let mut s = s.borrow_mut();
                let mut len = [0u8; 8];
                s.read_exact(&mut len)?;
                let n = u64::from_le_bytes(len);
                if n > MAX_MSG_BYTES {
                    return Err(WireError::Malformed(format!(
                        "claimed length {n} exceeds the {MAX_MSG_BYTES}-byte \
                         cap")));
                }
                let mut buf = vec![0u8; n as usize];
                s.read_exact(&mut buf)?;
                // latency simulation applies on the sender side only for
                // local links; real TCP has real latency.
                Ok(buf)
            }
        }
    }

    // ---- typed helpers --------------------------------------------------
    pub fn send_elems(&self, dir: Dir, data: &[i32])
                      -> Result<(), WireError> {
        let mut bytes = Vec::with_capacity(4 * data.len());
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.send_raw(dir, bytes)
    }

    pub fn recv_elems(&self, dir: Dir) -> Result<Vec<i32>, WireError> {
        let bytes = self.recv_raw(dir)?;
        if bytes.len() % 4 != 0 {
            return Err(WireError::Malformed(format!(
                "ring payload of {} bytes is not a multiple of 4",
                bytes.len())));
        }
        Ok(bytes.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Binary shares travel bit-packed: n bits cost ceil(n/8) bytes (plus
    /// the 8-byte bit-count header), which is what makes the B-share
    /// protocols cheap on the wire.  The payload is the `BitTensor` word
    /// buffer shipped verbatim (truncated to ceil(n/8) bytes) -- no per-bit
    /// repack loop; the format is bit-identical to the seed's packer.
    pub fn send_bits(&self, dir: Dir, bits: &BitTensor)
                     -> Result<(), WireError> {
        let mut bytes = Vec::with_capacity(8 + bits.len().div_ceil(8));
        bytes.extend_from_slice(&(bits.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&bits.packed_bytes());
        self.send_raw(dir, bytes)
    }

    pub fn recv_bits(&self, dir: Dir) -> Result<BitTensor, WireError> {
        let bytes = self.recv_raw(dir)?;
        if bytes.len() < 8 {
            return Err(WireError::Malformed(format!(
                "bit message of {} bytes is shorter than its header",
                bytes.len())));
        }
        let n = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        if n > MAX_MSG_BYTES.saturating_mul(8) {
            return Err(WireError::Malformed(format!(
                "claimed bit count {n} exceeds the message cap")));
        }
        let n = n as usize;
        BitTensor::from_packed_bytes(n, &bytes[8..]).ok_or_else(|| {
            WireError::Malformed(format!(
                "bit payload of {} bytes does not match the claimed {n} bits",
                bytes.len() - 8))
        })
    }

    /// A `BitPlanes` travels as its reinterpreted `BitTensor`: the word
    /// buffer verbatim, bit count = `padded_bits()` (a multiple of 64).
    /// No repack on either end -- this is the `BitPlanes ⇄ BitTensor`
    /// reinterpret applied at the wire.
    pub fn send_planes(&self, dir: Dir, p: &BitPlanes)
                       -> Result<(), WireError> {
        let nbytes = p.words().len() * 8;
        let mut bytes = Vec::with_capacity(8 + nbytes);
        bytes.extend_from_slice(&(p.padded_bits() as u64).to_le_bytes());
        for w in p.words() {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        self.send_raw(dir, bytes)
    }

    /// Receive a `planes x len` matrix: the frame is validated as a bit
    /// message, then the claimed bit count must be exactly the padded
    /// size of the expected geometry; per-plane padding a malicious peer
    /// set is cleared by the reinterpret.
    pub fn recv_planes(&self, dir: Dir, planes: usize, len: usize)
                       -> Result<BitPlanes, WireError> {
        let t = self.recv_bits(dir)?;
        let got = t.len();
        BitPlanes::from_tensor(t, planes, len).ok_or_else(|| {
            WireError::Malformed(format!(
                "plane payload of {got} bits does not match the expected \
                 {planes}x{len} matrix"))
        })
    }

    /// Advance the round counter -- called by the protocol layer at each
    /// communication phase boundary.
    pub fn round(&self) {
        self.stats.borrow_mut().rounds += 1;
    }

    pub fn stats(&self) -> Stats {
        *self.stats.borrow()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = Stats::default();
    }

    pub fn net(&self) -> NetConfig {
        self.net
    }
}

/// Build the three in-process parties' endpoints for one session.
pub fn local_trio(net: NetConfig) -> [Comm; 3] {
    // channels[i][j] carries i -> j
    let mut txs: Vec<Vec<Option<Sender<Msg>>>> =
        (0..3).map(|_| (0..3).map(|_| None).collect()).collect();
    let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> =
        (0..3).map(|_| (0..3).map(|_| None).collect()).collect();
    for i in 0..3 {
        for j in 0..3 {
            if i != j {
                let (tx, rx) = channel();
                txs[i][j] = Some(tx);
                rxs[i][j] = Some(rx);
            }
        }
    }
    let mut out = Vec::new();
    for i in (0..3).rev() {
        let next = (i + 1) % 3;
        let prev = (i + 2) % 3;
        out.push(Comm {
            id: i,
            tx_next: LinkTx::Local(txs[i][next].take().unwrap()),
            tx_prev: LinkTx::Local(txs[i][prev].take().unwrap()),
            rx_next: LinkRx::Local(rxs[next][i].take().unwrap()),
            rx_prev: LinkRx::Local(rxs[prev][i].take().unwrap()),
            net,
            busy_next: Cell::new(Instant::now()),
            busy_prev: Cell::new(Instant::now()),
            stats: RefCell::new(Stats::default()),
        });
    }
    out.reverse();
    let arr: [Comm; 3] = out.try_into().map_err(|_| ()).unwrap();
    arr
}

/// TCP deployment: party `id` listens for its inbound links and dials its
/// outbound ones.  `addrs[i]` is the base address of party i; port+0
/// accepts from next, port+1 accepts from prev.
pub fn tcp_party(id: usize, addrs: &[String; 3], net: NetConfig)
                 -> std::io::Result<Comm> {
    let next = (id + 1) % 3;
    let prev = (id + 2) % 3;
    let (base_host, base_port) = split_addr(&addrs[id])?;
    // deterministic connection order avoids deadlock: lower id listens
    // first on each pairwise link.
    let connect = |host: &str, port: u16| -> std::io::Result<TcpStream> {
        loop {
            match TcpStream::connect((host, port)) {
                Ok(s) => return Ok(s),
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    };
    let accept = |port: u16| -> std::io::Result<TcpStream> {
        let l = TcpListener::bind((base_host.as_str(), port))?;
        Ok(l.accept()?.0)
    };
    // link to next: lower id accepts
    let (tx_next, rx_next) = if id < next {
        let a = accept(base_port)?;
        (a.try_clone()?, a)
    } else {
        let (h, p) = split_addr(&addrs[next])?;
        let c = connect(&h, p)?;
        (c.try_clone()?, c)
    };
    let (tx_prev, rx_prev) = if id < prev {
        let a = accept(base_port + 1)?;
        (a.try_clone()?, a)
    } else {
        let (h, p) = split_addr(&addrs[prev])?;
        let c = connect(&h, p + 1)?;
        (c.try_clone()?, c)
    };
    Ok(Comm {
        id,
        tx_next: LinkTx::Tcp(RefCell::new(tx_next)),
        tx_prev: LinkTx::Tcp(RefCell::new(tx_prev)),
        rx_next: LinkRx::Tcp(RefCell::new(rx_next)),
        rx_prev: LinkRx::Tcp(RefCell::new(rx_prev)),
        net,
        busy_next: Cell::new(Instant::now()),
        busy_prev: Cell::new(Instant::now()),
        stats: RefCell::new(Stats::default()),
    })
}

fn split_addr(a: &str) -> std::io::Result<(String, u16)> {
    let (h, p) = a.rsplit_once(':').ok_or_else(|| std::io::Error::new(
        std::io::ErrorKind::InvalidInput, "addr must be host:port"))?;
    Ok((h.to_string(), p.parse().map_err(|_| std::io::Error::new(
        std::io::ErrorKind::InvalidInput, "bad port"))?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run3<F>(net: NetConfig, f: F) -> Vec<Stats>
    where
        F: Fn(&Comm) + Send + Sync + Copy + 'static,
    {
        let comms = local_trio(net);
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                f(&c);
                c.stats()
            })
        }).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn ring_pass_delivers() {
        let stats = run3(NetConfig::zero(), |c| {
            let data = vec![c.id as i32; 8];
            c.send_elems(Dir::Next, &data).unwrap();
            let got = c.recv_elems(Dir::Prev).unwrap();
            let prev = (c.id + 2) % 3;
            assert_eq!(got, vec![prev as i32; 8]);
            c.round();
        });
        for s in stats {
            assert_eq!(s.bytes_sent, 32);
            assert_eq!(s.messages, 1);
            assert_eq!(s.rounds, 1);
        }
    }

    #[test]
    fn bits_pack_tightly() {
        let stats = run3(NetConfig::zero(), |c| {
            let bits = BitTensor::ones(100);
            c.send_bits(Dir::Next, &bits).unwrap();
            let got = c.recv_bits(Dir::Prev).unwrap();
            assert_eq!(got, bits);
        });
        // 100 bits -> 13 bytes + 8 length header
        for s in stats {
            assert_eq!(s.bytes_sent, 21);
        }
    }

    #[test]
    fn bit_wire_cost_is_ceil_n_over_8_plus_header() {
        // Stats-verified wire format: n bits cost exactly ceil(n/8) + 8
        // bytes, for lengths straddling byte and word boundaries.
        for n in [1usize, 7, 8, 9, 63, 64, 65, 100, 128, 1000] {
            let comms = local_trio(NetConfig::zero());
            let handles: Vec<_> = comms.into_iter().map(|c| {
                thread::spawn(move || {
                    let mut rng = crate::testutil::Rng::new(n as u64);
                    let bits = BitTensor::from_fn(n, |_| rng.bit());
                    c.send_bits(Dir::Next, &bits).unwrap();
                    let got = c.recv_bits(Dir::Prev).unwrap();
                    assert_eq!(got.len(), n);
                    c.stats()
                })
            }).collect();
            for h in handles {
                let s = h.join().unwrap();
                assert_eq!(s.bytes_sent, (n.div_ceil(8) + 8) as u64,
                           "wire bytes for {n} bits");
            }
        }
    }

    #[test]
    fn bit_roundtrip_preserves_exact_patterns() {
        let stats = run3(NetConfig::zero(), |c| {
            let mut rng = crate::testutil::Rng::new(7 + c.id as u64);
            let bits = BitTensor::from_fn(77, |_| rng.bit());
            c.send_bits(Dir::Next, &bits).unwrap();
            c.send_bits(Dir::Prev, &bits).unwrap();
            let from_prev = c.recv_bits(Dir::Prev).unwrap();
            let from_next = c.recv_bits(Dir::Next).unwrap();
            let mut prev_rng =
                crate::testutil::Rng::new(7 + ((c.id + 2) % 3) as u64);
            let want_prev = BitTensor::from_fn(77, |_| prev_rng.bit());
            assert_eq!(from_prev, want_prev);
            let mut next_rng =
                crate::testutil::Rng::new(7 + ((c.id + 1) % 3) as u64);
            let want_next = BitTensor::from_fn(77, |_| next_rng.bit());
            assert_eq!(from_next, want_next);
        });
        assert_eq!(stats.len(), 3);
    }

    #[test]
    fn malformed_lengths_are_errors_not_panics() {
        // a ring payload whose length is not a multiple of 4 must surface
        // as WireError::Malformed on the receiver
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                if c.id == 0 {
                    c.send_raw(Dir::Next, vec![0u8; 5]).unwrap();
                    // undersized bit message (no full header)
                    c.send_raw(Dir::Next, vec![0u8; 3]).unwrap();
                    // bit message whose payload contradicts its header
                    let mut lie = Vec::new();
                    lie.extend_from_slice(&100u64.to_le_bytes());
                    lie.push(0xFF); // 1 byte instead of 13
                    c.send_raw(Dir::Next, lie).unwrap();
                    None
                } else if c.id == 1 {
                    let a = c.recv_elems(Dir::Prev);
                    let b = c.recv_bits(Dir::Prev);
                    let d = c.recv_bits(Dir::Prev);
                    Some((a.is_err(), b.is_err(), d.is_err()))
                } else {
                    None
                }
            })
        }).collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(results[1], Some((true, true, true)));
    }

    #[test]
    fn latency_is_simulated() {
        let net = NetConfig { latency: Duration::from_millis(20),
                              bandwidth: f64::INFINITY };
        let t0 = Instant::now();
        run3(net, |c| {
            c.send_elems(Dir::Next, &[1]).unwrap();
            let _ = c.recv_elems(Dir::Prev).unwrap();
        });
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn bandwidth_term_applies() {
        let net = NetConfig { latency: Duration::ZERO, bandwidth: 1e6 };
        let t0 = Instant::now();
        run3(net, |c| {
            // 400 KB at 1 MB/s ~ 400 ms
            let data = vec![0i32; 100_000];
            c.send_elems(Dir::Next, &data).unwrap();
            let _ = c.recv_elems(Dir::Prev).unwrap();
        });
        assert!(t0.elapsed() >= Duration::from_millis(300));
    }

    #[test]
    fn bidirectional_same_round() {
        run3(NetConfig::zero(), |c| {
            c.send_elems(Dir::Next, &[c.id as i32]).unwrap();
            c.send_elems(Dir::Prev, &[c.id as i32]).unwrap();
            let a = c.recv_elems(Dir::Prev).unwrap();
            let b = c.recv_elems(Dir::Next).unwrap();
            assert_eq!(a[0] as usize, (c.id + 2) % 3);
            assert_eq!(b[0] as usize, (c.id + 1) % 3);
        });
    }

    #[test]
    fn send_to_hung_up_peer_is_error_not_panic() {
        // drop party 2's endpoints entirely; its neighbours' sends must
        // surface WireError::Closed (the ROADMAP send-path hardening gap)
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        drop(c2);
        assert!(c0.send_elems(Dir::Next, &[1]).is_ok()); // P1 still alive
        let err = c0.send_elems(Dir::Prev, &[1]).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        let err = c1.send_bits(Dir::Next, &BitTensor::ones(9)).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        let err = c1.send_raw(Dir::Next, vec![0u8; 4]).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
    }

    #[test]
    fn planes_travel_as_reinterpreted_tensors() {
        let stats = run3(NetConfig::zero(), |c| {
            let mut rng = crate::testutil::Rng::new(13);
            let rows: Vec<BitTensor> =
                (0..4).map(|_| BitTensor::from_fn(70, |_| rng.bit()))
                .collect();
            let m = BitPlanes::from_tensors(&rows);
            c.send_planes(Dir::Next, &m).unwrap();
            let got = c.recv_planes(Dir::Prev, 4, 70).unwrap();
            assert_eq!(got, m);
            for (p, row) in rows.iter().enumerate() {
                assert_eq!(&got.plane(p), row);
            }
        });
        // 4 planes x 2 words x 8 bytes + 8-byte header, per party
        for s in stats {
            assert_eq!(s.bytes_sent, (4 * 2 * 8 + 8) as u64);
        }
    }

    #[test]
    fn recv_planes_rejects_geometry_lies() {
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                if c.id == 0 {
                    // an honest 2x64 matrix received as 4x32 is fine
                    // (same padded words) -- but a 3-plane claim is not
                    let m = BitPlanes::zeros(2, 64);
                    c.send_planes(Dir::Next, &m).unwrap();
                    None
                } else if c.id == 1 {
                    Some(c.recv_planes(Dir::Prev, 3, 64).is_err())
                } else {
                    None
                }
            })
        }).collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(results[1], Some(true));
    }
}
