//! Party-to-party transport with network simulation, cost accounting,
//! and tagged logical channels.
//!
//! The three parties run as threads (in-process, `Link::Local`) or as
//! separate processes (`Link::Tcp`).  Every link models the paper's
//! LAN/WAN settings: each message arrives after `latency + bytes /
//! bandwidth`, with link serialization (back-to-back messages queue behind
//! each other).  Byte, message, and round counts are recorded per party --
//! the round counter is advanced explicitly by the protocol layer so the
//! per-protocol round budgets in DESIGN.md are testable.
//!
//! **Logical channels.**  Every frame carries a one-byte channel id
//! (`ChanId`: an online or offline *lane* of one *model slot*), so the
//! serving stack can run many protocol threads over the *same* three
//! links concurrently without their frames interleaving: background
//! tuple producers next to online inference (PR 3), and several models'
//! lanes next to each other (multi-model serving, see DESIGN.md
//! §Multi-model multiplexing).  A receive bound to one channel demuxes
//! frames for any *other* registered channel into a per-link queue
//! instead of consuming them; a frame tagged with an id nobody
//! registered is `Malformed`.  `Comm::channel` derives (and registers) a
//! handle bound to another channel over the shared links; `Stats`
//! reports aggregate totals plus a per-channel-id breakdown.
//!
//! **Lane lifecycle.**  A registered lane can be *retired*
//! ([`Comm::close_chan`]): its parked frames are purged, pending and
//! future receives on it return `WireError::Closed` (blocked receivers
//! are woken, including a receiver holding the link read -- the read
//! polls at frame boundaries), and frames that still arrive for it are
//! silently dropped instead of poisoning a healthy lane's receive.
//! Re-deriving the lane (`Comm::channel`) re-opens it for a fresh
//! epoch, purging anything stale first.  This is what lets the
//! coordinator quarantine and respawn one model slot -- or hot-swap a
//! model -- without touching the other lanes sharing the links.
//!
//! **Bounded demux memory.**  Parked frames are capped per lane and
//! direction (`Comm::set_parked_cap`, default [`DEFAULT_PARKED_CAP`]):
//! a peer flooding a registered-but-idle lane trips the cap, which
//! frees that lane's parked frames and marks it poisoned -- its next
//! receive is `Malformed` -- while every other lane's traffic is
//! untouched.  This closes the queue-growth hole that permanent
//! registration would otherwise hand a malicious peer.

pub mod shim;

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError,
                Weak};
use std::time::{Duration, Instant};

use crate::ring::bits::BitTensor;
use crate::ring::planes::BitPlanes;

/// Upper bound on a single wire message; a claimed length beyond this is
/// rejected before any allocation (attacker-controlled length hardening).
pub const MAX_MSG_BYTES: u64 = 1 << 30;

/// Default per-lane, per-direction cap on parked demux frames (bytes).
/// Sized for dozens of in-flight batches of the largest layer messages;
/// override per deployment with `Comm::set_parked_cap` (the CLI's
/// `serve --max-parked-bytes`).
pub const DEFAULT_PARKED_CAP: usize = 64 << 20;

/// How often a blocked link read re-checks lane retirement.  Receives
/// with traffic in flight never wait on this; it only bounds how long a
/// cancelled lane's receiver can stay blocked on an idle link.
const READ_POLL: Duration = Duration::from_millis(10);

/// Wire-level failure.  Receive paths return this instead of panicking the
/// party thread: lengths and structure arrive from the peer and must be
/// treated as untrusted input (see DESIGN.md §wire format).
#[derive(Debug)]
pub enum WireError {
    /// The peer's channel/socket closed mid-protocol.
    Closed,
    /// Socket-level I/O failure.
    Io(std::io::Error),
    /// The message failed structural validation (bad length, bad header).
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "peer hung up"),
            WireError::Io(e) => write!(f, "transport i/o: {e}"),
            WireError::Malformed(m) => write!(f, "malformed message: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Logical channel id multiplexed over one physical link: one byte
/// encoding a **lane** (online / offline) and a **model slot**, so every
/// model served by a process gets its own pair of non-interleaving
/// streams over the shared links.
///
/// Wire encoding (the first byte of every frame):
///
/// ```text
///     tag = slot << 1 | lane        lane 0 = online, 1 = offline
/// ```
///
/// so `0x00`/`0x01` are model slot 0's lanes -- byte-identical to the
/// PR 3 two-channel format, which keeps single-model deployments'
/// frames unchanged.  A slot is at most [`ChanId::MAX_MODELS`]` - 1`.
/// Ids are *registered* per party (deriving a handle with
/// [`Comm::channel`] registers its id; only the default-bound
/// `ChanId::ONLINE` is pre-registered at construction); an arriving
/// frame whose tag was never registered is `WireError::Malformed` --
/// the tag byte is peer-controlled input like everything else, and a
/// registered id nobody reads would be an unbounded parking queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ChanId(u8);

impl ChanId {
    /// Size of the model-slot space: tags are one byte, one bit names
    /// the lane, leaving 7 bits of slot.
    pub const MAX_MODELS: usize = 128;

    /// Model slot 0's request critical path (the PR 3 `Chan::Online`).
    pub const ONLINE: ChanId = ChanId(0);

    /// Model slot 0's background preprocessing lane (the PR 3
    /// `Chan::Offline`).
    pub const OFFLINE: ChanId = ChanId(1);

    /// The online (request critical path) lane of model slot `slot`.
    pub fn online(slot: u8) -> ChanId {
        assert!((slot as usize) < Self::MAX_MODELS,
                "model slot {slot} outside the {}-slot channel id space",
                Self::MAX_MODELS);
        ChanId(slot << 1)
    }

    /// The offline (background producer) lane of model slot `slot`.
    pub fn offline(slot: u8) -> ChanId {
        assert!((slot as usize) < Self::MAX_MODELS,
                "model slot {slot} outside the {}-slot channel id space",
                Self::MAX_MODELS);
        ChanId((slot << 1) | 1)
    }

    /// The model slot this id belongs to.
    pub fn model(self) -> u8 {
        self.0 >> 1
    }

    /// Whether this is an offline (background producer) lane.
    pub fn is_offline(self) -> bool {
        self.0 & 1 == 1
    }

    /// The one-byte wire tag.
    pub fn tag(self) -> u8 {
        self.0
    }

    /// The id a wire tag names.  Every byte is structurally a `ChanId`;
    /// whether it is *accepted* is decided by per-party registration in
    /// the receive path.
    pub fn from_tag(tag: u8) -> ChanId {
        ChanId(tag)
    }
}

impl std::fmt::Display for ChanId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}",
               if self.is_offline() { "offline" } else { "online" },
               self.model())
    }
}

/// One-way network model (the link-conditioning shim; parse specs with
/// [`shim::parse_net_spec`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetConfig {
    pub latency: Duration,
    /// Bytes per second; `f64::INFINITY` disables the bandwidth term.
    pub bandwidth: f64,
    /// Maximum extra per-frame propagation delay, drawn deterministically
    /// per frame (see `shim::jitter`); `ZERO` disables it.
    pub jitter: Duration,
    /// Deterministic virtual-clock mode: instead of sleeping, each party
    /// advances a virtual nanosecond clock ([`Comm::virtual_now`]) by the
    /// same latency/bandwidth/jitter model.  Tests get WAN timing
    /// without WAN wall time.  Local links only.
    pub virtual_clock: bool,
}

impl NetConfig {
    /// Paper LAN: 0.2 ms RTT-ish latency, 625 MBps.
    pub fn lan() -> Self {
        NetConfig { latency: Duration::from_micros(200),
                    bandwidth: 625.0e6, ..NetConfig::zero() }
    }

    /// Paper WAN: 80 ms latency, 40 MBps.
    pub fn wan() -> Self {
        NetConfig { latency: Duration::from_millis(80), bandwidth: 40.0e6,
                    ..NetConfig::zero() }
    }

    /// No simulation (unit tests).
    pub fn zero() -> Self {
        NetConfig { latency: Duration::ZERO, bandwidth: f64::INFINITY,
                    jitter: Duration::ZERO, virtual_clock: false }
    }

    /// This config with the deterministic virtual clock enabled.
    pub fn with_virtual_clock(self) -> Self {
        NetConfig { virtual_clock: true, ..self }
    }

    /// Time the link is *occupied* transmitting (serialization).
    fn serialize(&self, bytes: usize) -> Duration {
        if self.bandwidth.is_finite() {
            Duration::from_secs_f64(bytes as f64 / self.bandwidth)
        } else {
            Duration::ZERO
        }
    }
}

/// Per-channel communication counters (one logical lane's share of the
/// link totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChanStats {
    pub bytes_sent: u64,
    pub messages: u64,
    pub rounds: u64,
}

impl ChanStats {
    fn add(&mut self, other: &ChanStats) {
        self.bytes_sent += other.bytes_sent;
        self.messages += other.messages;
        self.rounds += other.rounds;
    }
}

/// Communication statistics for one party: totals across every logical
/// channel of its links, plus a per-channel-id breakdown.  An online
/// row is the paper-comparable request cost of that model; an offline
/// row is its amortized producer cost.  The breakdown always sums to
/// the totals (asserted in `transport::tests`), so per-model rollups
/// are exact.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub bytes_sent: u64,
    pub messages: u64,
    pub rounds: u64,
    /// Per-channel counters, keyed by wire tag.  Only channels that
    /// actually moved traffic (or advanced a round) have an entry.
    channels: BTreeMap<u8, ChanStats>,
}

impl Stats {
    /// Counters of one channel id (all-zero if it never moved traffic).
    pub fn chan(&self, c: ChanId) -> ChanStats {
        self.channels.get(&c.tag()).copied().unwrap_or_default()
    }

    /// Model slot 0's online row (single-model sessions' request cost).
    pub fn online(&self) -> ChanStats {
        self.chan(ChanId::ONLINE)
    }

    /// Model slot 0's offline row (single-model producer cost).
    pub fn offline(&self) -> ChanStats {
        self.chan(ChanId::OFFLINE)
    }

    /// Both lanes of one model slot combined: the slot's total share of
    /// the link traffic.
    pub fn model(&self, slot: u8) -> ChanStats {
        let mut out = self.chan(ChanId::online(slot));
        out.add(&self.chan(ChanId::offline(slot)));
        out
    }

    /// Every channel that moved traffic, in tag order.
    pub fn channels(&self) -> impl Iterator<Item = (ChanId, ChanStats)> + '_ {
        self.channels.iter().map(|(&t, &s)| (ChanId::from_tag(t), s))
    }

    fn chan_mut(&mut self, c: ChanId) -> &mut ChanStats {
        self.channels.entry(c.tag()).or_default()
    }
}

struct Msg {
    /// Tagged frame: channel byte + payload.
    body: Vec<u8>,
    arrival: Instant,
    /// Virtual-clock arrival stamp in nanoseconds (0 in wall-clock
    /// mode): the sender's virtual send-completion time plus latency and
    /// jitter.  The receiver advances its own virtual clock to at least
    /// this when it pulls the frame off the link.
    varrival: u64,
}

enum LinkTx {
    Local(Sender<Msg>),
    Tcp(TcpStream),
}

enum LinkRx {
    Local(Receiver<Msg>),
    Tcp(TcpStream),
}

struct TxLane {
    link: LinkTx,
    busy: Instant,
    /// Virtual-clock analogue of `busy`: when this direction's link
    /// finishes serializing its last frame, in virtual nanoseconds.
    vbusy: u64,
    /// Frames shipped on this direction so far; seeds the deterministic
    /// per-frame jitter draw.
    sent_frames: u64,
}

/// One lane's parked frames on one receive direction, with their byte
/// total (the quantity the parked cap bounds).
#[derive(Default)]
struct LaneQ {
    frames: VecDeque<Vec<u8>>,
    bytes: usize,
}

/// Demux bookkeeping for one receive direction.  `reading` is a reader
/// token: at most one thread reads the underlying link at a time, and it
/// does so *without* holding the state lock, so the other channel's
/// thread can wait on the condvar and be handed its frame the moment the
/// reader routes it.  The reader therefore pumps frames for both
/// channels while it waits for its own -- which is what makes the
/// two-channel protocols deadlock-free even when one channel's thread
/// races ahead of the other's (see DESIGN.md §Offline/online split).
struct RxState {
    /// Frames parked per channel tag, FIFO.  A dynamic table (entries
    /// appear as channels actually park traffic) instead of the PR 3
    /// fixed two-queue array, so one link carries any number of
    /// registered model lanes.
    queues: BTreeMap<u8, LaneQ>,
    /// Lanes that overflowed the parked cap: the next receive on a
    /// poisoned lane is `Malformed` (with this reason).  Cleared when
    /// the lane is retired or re-registered.
    poisoned: BTreeMap<u8, String>,
    /// A thread currently owns the link read.
    reading: bool,
}

struct RxLane {
    link: Mutex<LinkRx>,
    state: Mutex<RxState>,
    cv: Condvar,
}

/// The shared state behind every channel handle of one party: both link
/// directions plus accounting.  Lanes are independently locked so the
/// online thread and the offline producer serialize per direction, never
/// against each other's opposite-direction traffic.
struct Core {
    net: NetConfig,
    tx: [Mutex<TxLane>; 2],
    rx: [RxLane; 2],
    stats: Mutex<Stats>,
    /// Bitmap over the 256 tag values: which channel ids this party has
    /// registered (derived a handle for).  A received frame with an
    /// unregistered tag is `Malformed` -- it cannot belong to any
    /// protocol thread of this process.  Registration happens before
    /// the owning threads spawn (handles are derived first), so a plain
    /// SeqCst bitmap suffices.
    registered: [AtomicU64; 4],
    /// Bitmap of *retired* lanes (`close_chan`): still registered --
    /// stale in-flight frames must not poison a healthy lane's recv as
    /// "unregistered" -- but receives on them fail `Closed` and
    /// arriving frames are dropped, until the lane is re-derived.
    retired: [AtomicU64; 4],
    /// Per-lane, per-direction cap on parked frame bytes.
    parked_cap: AtomicUsize,
    /// This party's virtual clock (nanoseconds since session start),
    /// advanced by frame arrival stamps in virtual-clock mode.
    vnow: AtomicU64,
    /// This party's trace sink, installed once at service start
    /// ([`Comm::install_tracer`]).  The send/receive paths record a
    /// `Flight` span per frame when the sink is enabled; absent or
    /// disabled, the hook is one load and an early return.
    trace: OnceLock<Arc<crate::trace::TraceSink>>,
}

/// Recover a mutex guard from a peer thread's panic.  Used only on
/// counter/lifecycle state whose invariants hold field-by-field (stats,
/// demux bookkeeping on admin paths); request-path locks map poisoning
/// to `WireError::Closed` instead so one panicking party thread degrades
/// into a typed wire error, not a cross-thread panic cascade.
fn recover<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>)
              -> MutexGuard<'_, T> {
    r.unwrap_or_else(PoisonError::into_inner)
}

fn bit_set(map: &[AtomicU64; 4], tag: usize) {
    map[tag / 64].fetch_or(1u64 << (tag % 64), Ordering::SeqCst);
}

fn bit_clear(map: &[AtomicU64; 4], tag: usize) {
    map[tag / 64].fetch_and(!(1u64 << (tag % 64)), Ordering::SeqCst);
}

fn bit_get(map: &[AtomicU64; 4], tag: usize) -> bool {
    map[tag / 64].load(Ordering::SeqCst) & (1u64 << (tag % 64)) != 0
}

/// Park `body` for lane `tag`, enforcing the parked-bytes cap: an
/// overflow frees the lane's parked frames and poisons it (its next
/// recv is `Malformed`) instead of growing without bound -- the frame
/// and the queue memory are the attacker's loss, not the process's.
fn park_frame(st: &mut RxState, cap: usize, tag: u8, body: Vec<u8>) {
    if st.poisoned.contains_key(&tag) {
        // the lane already overflowed: keep dropping until it is
        // retired or re-registered (its consumer sees the Malformed)
        return;
    }
    let lane = st.queues.entry(tag).or_default();
    if lane.bytes + body.len() > cap {
        lane.frames.clear();
        lane.bytes = 0;
        st.poisoned.insert(tag, format!(
            "channel {} overflowed the {cap}-byte parked cap",
            ChanId::from_tag(tag)));
    } else {
        lane.bytes += body.len();
        lane.frames.push_back(body);
    }
}

impl Core {
    fn register(&self, c: ChanId) {
        let tag = c.tag() as usize;
        if bit_get(&self.retired, tag) {
            // re-opening a retired lane (slot respawn / hot-swap): purge
            // anything stale from the previous epoch before frames for
            // the new one can be confused with it
            self.purge(c.tag());
            bit_clear(&self.retired, tag);
        }
        bit_set(&self.registered, tag);
    }

    fn is_registered(&self, tag: u8) -> bool {
        bit_get(&self.registered, tag as usize)
    }

    fn is_retired(&self, tag: u8) -> bool {
        bit_get(&self.retired, tag as usize)
    }

    /// Drop every parked frame (and any poison mark) of `tag`, both
    /// directions.
    fn purge(&self, tag: u8) {
        for lane in &self.rx {
            let mut st = recover(lane.state.lock());
            st.queues.remove(&tag);
            st.poisoned.remove(&tag);
        }
    }

    /// Retire a lane: purge its parked frames and wake every blocked
    /// receiver on both directions (they observe the retirement and
    /// return `Closed`).  Arriving frames for a retired lane are
    /// silently dropped.  Idempotent; `register` re-opens.
    fn close_chan(&self, c: ChanId) {
        bit_set(&self.retired, c.tag() as usize);
        self.purge(c.tag());
        for lane in &self.rx {
            lane.cv.notify_all();
        }
    }

    /// Best-effort non-blocking drain of one receive direction: every
    /// frame already queued on the link is routed -- parked for its
    /// (healthy) lane, dropped if its lane is retired or unknown.  The
    /// coordinator calls this before re-opening a quarantined slot's
    /// lanes so a stale frame of the dead epoch is not delivered into
    /// the new one (best-effort; see `Comm::sweep` for the residual
    /// race).  Returns `false` when another lane's receiver holds the
    /// reader token and nothing could be drained.  Local links only (a
    /// TCP deployment drains via its active readers); latency
    /// simulation is skipped for swept frames -- an admin-path
    /// tradeoff, not a protocol one.
    fn sweep(&self, dir: usize) -> bool {
        let lane = &self.rx[dir];
        let mut st = recover(lane.state.lock());
        if st.reading {
            // an active reader is pumping this link; it drops retired
            // lanes' frames as it encounters them
            return false;
        }
        st.reading = true;
        drop(st);
        let mut drained = Vec::new();
        {
            let mut link = recover(lane.link.lock());
            if let LinkRx::Local(rx) = &mut *link {
                while let Ok(msg) = rx.try_recv() {
                    self.vnow.fetch_max(msg.varrival, Ordering::SeqCst);
                    drained.push(msg.body);
                }
            }
        }
        let cap = self.parked_cap.load(Ordering::SeqCst);
        st = recover(lane.state.lock());
        for body in drained {
            if body.is_empty() {
                continue;
            }
            let tag = body[0];
            if self.is_retired(tag) || !self.is_registered(tag) {
                continue;
            }
            park_frame(&mut st, cap, tag, body);
        }
        st.reading = false;
        drop(st);
        lane.cv.notify_all();
        true
    }
}

/// A weak lifecycle lever on one party's links: lets the coordinator
/// retire a model slot's lanes (waking its blocked party threads)
/// without keeping the links alive -- if every strong handle is gone,
/// the peers already observe `Closed` and there is nothing to cancel.
#[derive(Clone)]
pub struct ChanControl {
    core: Weak<Core>,
}

impl ChanControl {
    /// Retire `c` on this party (see [`Comm::close_chan`]).  A no-op
    /// once the links are dropped.
    pub fn close_chan(&self, c: ChanId) {
        if let Some(core) = self.core.upgrade() {
            core.close_chan(c);
        }
    }

    /// This party's link-wide stats, if the links are still alive --
    /// the trace exporter's stats-sidecar source for a service that
    /// (by design) holds no strong link handle of its own.
    pub fn stats(&self) -> Option<Stats> {
        self.core.upgrade()
            .map(|core| recover(core.stats.lock()).clone())
    }
}

/// A party's endpoints to its two neighbours plus accounting, bound to one
/// logical channel.  `channel()` derives (and registers) a handle for
/// another channel over the same links; `clone()` duplicates a handle on
/// its existing channel.  Handles are `Send + Sync` and cheap -- they
/// share one core.
#[derive(Clone)]
pub struct Comm {
    core: Arc<Core>,
    pub id: usize,
    chan: ChanId,
}

/// Which neighbour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dir {
    Next,
    Prev,
}

impl Dir {
    fn index(self) -> usize {
        match self {
            Dir::Next => 0,
            Dir::Prev => 1,
        }
    }
}

impl Comm {
    /// A handle over the same links bound to `chan`: sends tag frames with
    /// `chan`, receives demux to `chan`, rounds/bytes account to `chan`.
    /// Deriving a handle *registers* `chan` on this party -- do it
    /// before the first peer frame for that channel can arrive (in
    /// practice: before spawning the threads that serve it), or the
    /// receive path rejects the frame as an unregistered id.
    pub fn channel(&self, chan: ChanId) -> Comm {
        self.core.register(chan);
        Comm { core: Arc::clone(&self.core), id: self.id, chan }
    }

    /// The logical channel this handle is bound to.
    pub fn chan(&self) -> ChanId {
        self.chan
    }

    /// Retire lane `c` on this party: purge its parked frames, turn its
    /// pending and future receives into `WireError::Closed` (blocked
    /// receivers are woken), and silently drop frames that still arrive
    /// for it.  Other lanes are untouched.  Re-deriving the lane with
    /// [`Comm::channel`] re-opens it (purging anything stale first) --
    /// the quarantine/respawn and hot-swap primitive.
    pub fn close_chan(&self, c: ChanId) {
        self.core.close_chan(c);
    }

    /// A weak lifecycle handle on this party's links (does not keep
    /// them alive).
    pub fn control(&self) -> ChanControl {
        ChanControl { core: Arc::downgrade(&self.core) }
    }

    /// Set the per-lane, per-direction cap on parked demux bytes
    /// (default [`DEFAULT_PARKED_CAP`]).  A lane that overflows it is
    /// poisoned: its parked frames are freed and its next receive is
    /// `Malformed`.
    pub fn set_parked_cap(&self, bytes: usize) {
        self.core.parked_cap.store(bytes, Ordering::SeqCst);
    }

    /// The active parked-bytes cap.
    pub fn parked_cap(&self) -> usize {
        self.core.parked_cap.load(Ordering::SeqCst)
    }

    /// Bytes currently parked for lane `c` across both receive
    /// directions (observability; bounded by `2 * parked_cap`).
    pub fn parked_bytes(&self, c: ChanId) -> usize {
        self.core.rx.iter().map(|lane| {
            recover(lane.state.lock()).queues.get(&c.tag())
                .map_or(0, |q| q.bytes)
        }).sum()
    }

    /// Drain frames already queued on both receive directions, parking
    /// healthy lanes' frames and dropping retired ones (see
    /// `Core::sweep`).  Retries briefly when another lane's receiver
    /// holds a link's reader token, since that reader may be busy
    /// routing rather than draining.
    ///
    /// Best-effort, not a guarantee: a reader that is *blocked* on an
    /// idle link (or mid latency-sleep holding one pulled frame) keeps
    /// the token for the whole retry budget, so a stale frame of a
    /// retired lane can in principle survive the sweep and be parked
    /// into that lane's *next* epoch once it re-registers.  The
    /// misdelivery is contained -- the new epoch desyncs and is
    /// quarantined again -- and the structural fix (an epoch byte in
    /// the frame header) is a ROADMAP item.
    pub fn sweep(&self) {
        for dir in 0..2 {
            for attempt in 0..5u64 {
                if self.core.sweep(dir) {
                    break;
                }
                // token held: give the reader a beat to finish routing
                std::thread::sleep(Duration::from_millis(2 * attempt + 1));
            }
        }
    }

    /// A frame buffer pre-seeded with this handle's channel tag; the
    /// typed send helpers append their payload directly so the tag costs
    /// no extra pass over the data.
    fn tagged_body(&self, payload_cap: usize) -> Vec<u8> {
        let mut body = Vec::with_capacity(1 + payload_cap);
        body.push(self.chan.tag());
        body
    }

    /// Ship one framed message on this handle's channel.  A hung-up peer
    /// surfaces as `WireError::Closed` (local links) or `WireError::Io`
    /// (TCP) so the party thread retires cleanly instead of panicking
    /// mid-protocol.  Public so wire-format tests can craft adversarial
    /// payloads (the channel tag is still prepended; see `send_frame` for
    /// tag-level adversarial frames).
    pub fn send_raw(&self, dir: Dir, payload: Vec<u8>)
                    -> Result<(), WireError> {
        let mut body = self.tagged_body(payload.len());
        body.extend_from_slice(&payload);
        self.ship(dir, body)
    }

    /// Ship a raw frame *without* prepending the channel tag: the first
    /// byte of `frame` travels as the tag.  Only for adversarial
    /// wire-format tests (unknown tags, tagless frames).
    pub fn send_frame(&self, dir: Dir, frame: Vec<u8>)
                      -> Result<(), WireError> {
        self.ship(dir, frame)
    }

    fn ship(&self, dir: Dir, body: Vec<u8>) -> Result<(), WireError> {
        // a poisoned tx lane means a sibling thread died mid-send: the
        // stream may hold a truncated frame, so fail typed, not recover
        let mut lane = self.core.tx[dir.index()].lock()
            .map_err(|_| WireError::Closed)?;
        let net = &self.core.net;
        let jit = shim::jitter(
            (self.id as u64) << 32 | (dir.index() as u64) << 16
                | self.chan.tag() as u64,
            lane.sent_frames, net.jitter);
        lane.sent_frames += 1;
        let now = Instant::now();
        let (arrival, varrival, vstart) = if net.virtual_clock {
            // same model, virtual time: serialization queues behind the
            // lane's backlog, propagation (+jitter) overlaps
            let vnow = self.core.vnow.load(Ordering::SeqCst);
            let vstart = lane.vbusy.max(vnow);
            let vsent = vstart
                + net.serialize(body.len()).as_nanos() as u64;
            lane.vbusy = vsent;
            (now, vsent + net.latency.as_nanos() as u64
                 + jit.as_nanos() as u64, vstart)
        } else {
            // serialization occupies the link; propagation (latency)
            // overlaps across back-to-back messages
            let start = lane.busy.max(now);
            let sent = start + net.serialize(body.len());
            lane.busy = sent;
            (sent + net.latency + jit, 0, 0)
        };
        {
            let mut st = recover(self.core.stats.lock());
            st.bytes_sent += body.len() as u64;
            st.messages += 1;
            let c = st.chan_mut(self.chan);
            c.bytes_sent += body.len() as u64;
            c.messages += 1;
        }
        if let Some(tr) = self.core.trace.get() {
            if tr.enabled() {
                // the recorded bytes are exactly what Stats accounted
                // above, so per-channel flight sums reconcile to the
                // Stats rows (the merge tool's byte check)
                tr.flight(self.id as u8, self.chan.tag(), "send",
                          body.len() as u64, vstart, varrival);
            }
        }
        match &mut lane.link {
            LinkTx::Local(tx) => tx.send(Msg { body, arrival, varrival })
                .map_err(|_| WireError::Closed),
            LinkTx::Tcp(s) => {
                let len = (body.len() as u64).to_le_bytes();
                s.write_all(&len)?;
                s.write_all(&body)?;
                Ok(())
            }
        }
    }

    /// Receive the next frame for this handle's channel.  Frames tagged
    /// for the *other* channel are parked in the lane's demux queue (they
    /// belong to the other channel's thread); an unknown tag or a frame
    /// too short to hold one is `Malformed`.  One thread at a time owns
    /// the link read (the `reading` token) and it routes every frame it
    /// pulls -- parked frames are queued *before* waiters are woken, so a
    /// woken thread either finds its frame or takes over the read.
    /// Receive one frame body for this handle's channel, tag byte still
    /// in place at `body[0]` (typed helpers slice past it -- stripping
    /// in place would memmove the whole payload).
    fn recv_body(&self, dir: Dir) -> Result<Vec<u8>, WireError> {
        let body = self.recv_body_inner(dir)?;
        if let Some(tr) = self.core.trace.get() {
            if tr.enabled() {
                // arrival flight: the virtual stamp is the party clock
                // after observing the frame (PR 7's varrival advanced it)
                let vnow = self.core.vnow.load(Ordering::SeqCst);
                tr.flight(self.id as u8, self.chan.tag(), "recv",
                          body.len() as u64, vnow, vnow);
            }
        }
        Ok(body)
    }

    fn recv_body_inner(&self, dir: Dir) -> Result<Vec<u8>, WireError> {
        let lane = &self.core.rx[dir.index()];
        let my_tag = self.chan.tag();
        // a poisoned demux lock means a sibling receiver thread died
        // mid-route; surface a typed Closed instead of cascading panics
        let mut st = lane.state.lock().map_err(|_| WireError::Closed)?;
        loop {
            // lane lifecycle first: a retired lane's receives fail
            // `Closed` (quarantine/hot-swap cancellation), a poisoned
            // one's fail `Malformed` (parked-cap overflow)
            if self.core.is_retired(my_tag) {
                return Err(WireError::Closed);
            }
            if let Some(reason) = st.poisoned.get(&my_tag) {
                return Err(WireError::Malformed(reason.clone()));
            }
            if let Some(q) = st.queues.get_mut(&my_tag) {
                if let Some(p) = q.frames.pop_front() {
                    q.bytes -= p.len();
                    return Ok(p);
                }
            }
            if st.reading {
                // someone else is on the link; they will queue our frame
                // (then notify) or relinquish the token
                st = lane.cv.wait(st).map_err(|_| WireError::Closed)?;
                continue;
            }
            st.reading = true;
            drop(st);
            let stop = || self.core.is_retired(my_tag);
            let got = {
                let mut link = lane.link.lock()
                    .map_err(|_| WireError::Closed)?;
                read_frame(&mut link, &stop)
            };
            if let Ok((_, varrival)) = &got {
                // virtual clock: pulling the frame observes its arrival
                self.core.vnow.fetch_max(*varrival, Ordering::SeqCst);
            }
            st = lane.state.lock().map_err(|_| WireError::Closed)?;
            let routed = got.and_then(|(body, _)| {
                if body.is_empty() {
                    return Err(WireError::Malformed(
                        "empty frame cannot hold a channel tag".into()));
                }
                let tag = body[0];
                if self.core.is_retired(tag) {
                    // stale frame of a retired lane: drop it (it cannot
                    // have a consumer, and it must not err a healthy
                    // lane's recv)
                    return Ok(None);
                }
                if !self.core.is_registered(tag) {
                    return Err(WireError::Malformed(format!(
                        "unregistered channel id {tag:#04x} ({})",
                        ChanId::from_tag(tag))));
                }
                Ok(Some((ChanId::from_tag(tag), body)))
            });
            match routed {
                Err(e) => {
                    st.reading = false;
                    lane.cv.notify_all();
                    return Err(e);
                }
                Ok(None) => {
                    st.reading = false;
                    lane.cv.notify_all();
                }
                Ok(Some((chan, body))) if chan == self.chan => {
                    st.reading = false;
                    lane.cv.notify_all();
                    return Ok(body);
                }
                Ok(Some((chan, body))) => {
                    // park for the other channel FIRST, then wake it
                    let cap = self.core.parked_cap.load(Ordering::SeqCst);
                    park_frame(&mut st, cap, chan.tag(), body);
                    st.reading = false;
                    lane.cv.notify_all();
                }
            }
        }
    }

    // ---- typed helpers --------------------------------------------------
    pub fn send_elems(&self, dir: Dir, data: &[i32])
                      -> Result<(), WireError> {
        let mut body = self.tagged_body(4 * data.len());
        for v in data {
            body.extend_from_slice(&v.to_le_bytes());
        }
        self.ship(dir, body)
    }

    pub fn recv_elems(&self, dir: Dir) -> Result<Vec<i32>, WireError> {
        let body = self.recv_body(dir)?;
        let bytes = &body[1..];
        if bytes.len() % 4 != 0 {
            return Err(WireError::Malformed(format!(
                "ring payload of {} bytes is not a multiple of 4",
                bytes.len())));
        }
        Ok(bytes.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Binary shares travel bit-packed: n bits cost ceil(n/8) bytes (plus
    /// the 8-byte bit-count header and the channel tag), which is what
    /// makes the B-share protocols cheap on the wire.  The payload is the
    /// `BitTensor` word buffer shipped verbatim (truncated to ceil(n/8)
    /// bytes) -- no per-bit repack loop; the packed bytes are bit-identical
    /// to the seed's packer.
    pub fn send_bits(&self, dir: Dir, bits: &BitTensor)
                     -> Result<(), WireError> {
        let mut body = self.tagged_body(8 + bits.len().div_ceil(8));
        body.extend_from_slice(&(bits.len() as u64).to_le_bytes());
        body.extend_from_slice(&bits.packed_bytes());
        self.ship(dir, body)
    }

    pub fn recv_bits(&self, dir: Dir) -> Result<BitTensor, WireError> {
        let body = self.recv_body(dir)?;
        let bytes = &body[1..];
        if bytes.len() < 8 {
            return Err(WireError::Malformed(format!(
                "bit message of {} bytes is shorter than its header",
                bytes.len())));
        }
        let n = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        if n > MAX_MSG_BYTES.saturating_mul(8) {
            return Err(WireError::Malformed(format!(
                "claimed bit count {n} exceeds the message cap")));
        }
        let n = n as usize;
        BitTensor::from_packed_bytes(n, &bytes[8..]).ok_or_else(|| {
            WireError::Malformed(format!(
                "bit payload of {} bytes does not match the claimed {n} bits",
                bytes.len() - 8))
        })
    }

    /// A `BitPlanes` travels as its reinterpreted `BitTensor`: the word
    /// buffer verbatim, bit count = `padded_bits()` (a multiple of 64).
    /// No repack on either end -- this is the `BitPlanes ⇄ BitTensor`
    /// reinterpret applied at the wire.
    pub fn send_planes(&self, dir: Dir, p: &BitPlanes)
                       -> Result<(), WireError> {
        let nbytes = p.words().len() * 8;
        let mut body = self.tagged_body(8 + nbytes);
        body.extend_from_slice(&(p.padded_bits() as u64).to_le_bytes());
        for w in p.words() {
            body.extend_from_slice(&w.to_le_bytes());
        }
        self.ship(dir, body)
    }

    /// Receive a `planes x len` matrix: the frame is validated as a bit
    /// message, then the claimed bit count must be exactly the padded
    /// size of the expected geometry; per-plane padding a malicious peer
    /// set is cleared by the reinterpret.
    pub fn recv_planes(&self, dir: Dir, planes: usize, len: usize)
                       -> Result<BitPlanes, WireError> {
        let t = self.recv_bits(dir)?;
        let got = t.len();
        BitPlanes::from_tensor(t, planes, len).ok_or_else(|| {
            WireError::Malformed(format!(
                "plane payload of {got} bits does not match the expected \
                 {planes}x{len} matrix"))
        })
    }

    /// Advance the round counter -- called by the protocol layer at each
    /// communication phase boundary.  Accounted to this handle's channel
    /// (the link total and the channel row move under one lock, so the
    /// per-channel breakdown always sums to the totals, rounds included).
    pub fn round(&self) {
        let mut st = recover(self.core.stats.lock());
        st.rounds += 1;
        st.chan_mut(self.chan).rounds += 1;
    }

    pub fn stats(&self) -> Stats {
        recover(self.core.stats.lock()).clone()
    }

    /// This handle's bound-channel counters only.  Cheaper than
    /// [`Comm::stats`] (no per-channel map clone); the trace spine's
    /// span open/close snapshots use it so an enabled trace still
    /// allocates nothing per span.
    pub fn chan_stats(&self) -> ChanStats {
        recover(self.core.stats.lock()).chan(self.chan)
    }

    /// Install this party's trace sink (shared by every channel handle
    /// of these links; first installation wins).  Returns whether this
    /// call installed it.
    pub fn install_tracer(&self,
                          sink: Arc<crate::trace::TraceSink>) -> bool {
        self.core.trace.set(sink).is_ok()
    }

    /// The installed trace sink, if any.
    pub fn tracer(&self) -> Option<&crate::trace::TraceSink> {
        self.core.trace.get().map(|a| a.as_ref())
    }

    /// An owning handle on the installed trace sink (registry slots
    /// sharing one link trio adopt the first installation this way).
    pub fn tracer_handle(&self) -> Option<Arc<crate::trace::TraceSink>> {
        self.core.trace.get().map(Arc::clone)
    }

    pub fn reset_stats(&self) {
        *recover(self.core.stats.lock()) = Stats::default();
    }

    /// This party's virtual clock (virtual-clock mode only; stuck at
    /// zero otherwise).  Monotone: advanced to each pulled frame's
    /// arrival stamp.  The difference across a protocol run is the
    /// simulated network critical path through this party.
    pub fn virtual_now(&self) -> Duration {
        Duration::from_nanos(self.core.vnow.load(Ordering::SeqCst))
    }

    pub fn net(&self) -> NetConfig {
        self.core.net
    }
}

/// Pull one raw frame off the link.  Called only by the thread holding
/// the lane's reader token; the state lock is NOT held, so the other
/// channel's thread stays responsive on the condvar.  `stop` is checked
/// at frame boundaries every `READ_POLL` while the link is idle (never
/// mid-frame -- a partially consumed frame would desynchronize every
/// lane of the link): a reader whose own lane was retired relinquishes
/// the token with `Closed` instead of blocking forever.
fn read_frame(link: &mut LinkRx, stop: &dyn Fn() -> bool)
              -> Result<(Vec<u8>, u64), WireError> {
    match link {
        LinkRx::Local(rx) => loop {
            match rx.recv_timeout(READ_POLL) {
                Ok(msg) => {
                    let now = Instant::now();
                    if msg.arrival > now {
                        std::thread::sleep(msg.arrival - now);
                    }
                    return Ok((msg.body, msg.varrival));
                }
                Err(RecvTimeoutError::Timeout) => {
                    if stop() {
                        return Err(WireError::Closed);
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(WireError::Closed);
                }
            }
        },
        LinkRx::Tcp(s) => {
            let mut len = [0u8; 8];
            read_full(s, &mut len, stop, true)?;
            let n = u64::from_le_bytes(len);
            if n > MAX_MSG_BYTES {
                return Err(WireError::Malformed(format!(
                    "claimed length {n} exceeds the {MAX_MSG_BYTES}-byte \
                     cap")));
            }
            let mut buf = vec![0u8; n as usize];
            read_full(s, &mut buf, stop, false)?;
            // latency simulation applies on the sender side only for
            // local links; real TCP has real latency.
            Ok((buf, 0))
        }
    }
}

/// `read_exact` over a socket with a `READ_POLL` read timeout (set at
/// session setup), honouring `stop` only before the first byte of the
/// buffer (`at_boundary`) -- once a frame is partially consumed it must
/// be finished or the whole link desynchronizes.
fn read_full(s: &mut TcpStream, buf: &mut [u8], stop: &dyn Fn() -> bool,
             at_boundary: bool) -> Result<(), WireError> {
    let mut off = 0;
    while off < buf.len() {
        match s.read(&mut buf[off..]) {
            Ok(0) => return Err(WireError::Closed),
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut => {
                if at_boundary && off == 0 && stop() {
                    return Err(WireError::Closed);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn make_comm(id: usize, net: NetConfig,
             tx_next: LinkTx, tx_prev: LinkTx,
             rx_next: LinkRx, rx_prev: LinkRx) -> Comm {
    let now = Instant::now();
    let lane_tx = |link| Mutex::new(TxLane {
        link, busy: now, vbusy: 0, sent_frames: 0,
    });
    let lane_rx = |link| RxLane {
        link: Mutex::new(link),
        state: Mutex::new(RxState {
            queues: BTreeMap::new(),
            poisoned: BTreeMap::new(),
            reading: false,
        }),
        cv: Condvar::new(),
    };
    let core = Core {
        net,
        tx: [lane_tx(tx_next), lane_tx(tx_prev)],
        rx: [lane_rx(rx_next), lane_rx(rx_prev)],
        stats: Mutex::new(Stats::default()),
        registered: [AtomicU64::new(0), AtomicU64::new(0),
                     AtomicU64::new(0), AtomicU64::new(0)],
        retired: [AtomicU64::new(0), AtomicU64::new(0),
                  AtomicU64::new(0), AtomicU64::new(0)],
        parked_cap: AtomicUsize::new(DEFAULT_PARKED_CAP),
        vnow: AtomicU64::new(0),
        trace: OnceLock::new(),
    };
    // only the default-bound online lane is pre-registered (this handle
    // IS its consumer); every other channel, slot 0's offline lane
    // included, registers when a handle is derived.  An id stays
    // registered until explicitly retired (`close_chan`) -- an
    // unregister on handle drop would make a *stale* in-flight frame of
    // a retired lane kill a healthy lane's recv as "unregistered";
    // retirement instead drops such frames silently and the parked cap
    // bounds what an idle registered lane can accumulate.  See
    // DESIGN.md §Multi-model multiplexing.
    core.register(ChanId::ONLINE);
    Comm { core: Arc::new(core), id, chan: ChanId::ONLINE }
}

/// Build the three in-process parties' endpoints for one session.  The
/// returned handles are bound to `ChanId::ONLINE`; derive further lane
/// handles with `Comm::channel` (e.g. `ChanId::OFFLINE`, or another
/// model slot's lanes for multi-model serving).
pub fn local_trio(net: NetConfig) -> [Comm; 3] {
    // channels[i][j] carries i -> j
    let mut txs: Vec<Vec<Option<Sender<Msg>>>> =
        (0..3).map(|_| (0..3).map(|_| None).collect()).collect();
    let mut rxs: Vec<Vec<Option<Receiver<Msg>>>> =
        (0..3).map(|_| (0..3).map(|_| None).collect()).collect();
    for i in 0..3 {
        for j in 0..3 {
            if i != j {
                let (tx, rx) = channel();
                txs[i][j] = Some(tx);
                rxs[i][j] = Some(rx);
            }
        }
    }
    let mut out = Vec::new();
    for i in (0..3).rev() {
        let next = (i + 1) % 3;
        let prev = (i + 2) % 3;
        out.push(make_comm(
            i, net,
            LinkTx::Local(txs[i][next].take().unwrap()),
            LinkTx::Local(txs[i][prev].take().unwrap()),
            LinkRx::Local(rxs[next][i].take().unwrap()),
            LinkRx::Local(rxs[prev][i].take().unwrap()),
        ));
    }
    out.reverse();
    let arr: [Comm; 3] = out.try_into().map_err(|_| ()).unwrap();
    arr
}

/// Bounded-retry dial policy for TCP session setup: party start order is
/// no longer fragile (a peer that is not up yet is retried with
/// exponential backoff), but a peer that never comes up surfaces as
/// `TimedOut` instead of spinning forever (the first slice of the ROADMAP
/// "TCP session recovery" item).
#[derive(Clone, Copy, Debug)]
pub struct DialPolicy {
    /// Give up once this much wall time has elapsed.
    pub deadline: Duration,
    /// First retry delay; doubles per attempt up to `max_backoff`.
    pub initial_backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for DialPolicy {
    fn default() -> Self {
        DialPolicy {
            deadline: Duration::from_secs(10),
            initial_backoff: Duration::from_millis(25),
            max_backoff: Duration::from_millis(400),
        }
    }
}

/// Dial `host:port`, retrying with exponential backoff until the policy's
/// deadline.  Each attempt is itself bounded by the *remaining* deadline
/// budget (`connect_timeout`), so a blackholed peer cannot stretch one
/// attempt past the policy (the OS default connect timeout is minutes).
/// Returns the last connect error wrapped as `TimedOut` when the deadline
/// passes.
pub fn connect_with_retry(host: &str, port: u16, policy: DialPolicy)
                          -> std::io::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let start = Instant::now();
    let mut backoff = policy.initial_backoff;
    let attempt = || -> std::io::Result<TcpStream> {
        let remaining = policy.deadline.saturating_sub(start.elapsed());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut, "deadline exhausted"));
        }
        let addr = (host, port).to_socket_addrs()?.next().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput,
                                "address resolved to nothing")
        })?;
        TcpStream::connect_timeout(&addr, remaining)
    };
    loop {
        match attempt() {
            Ok(s) => return Ok(s),
            Err(e) => {
                if start.elapsed() + backoff >= policy.deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!("dialing {host}:{port}: no answer within \
                                 {:?} (last error: {e})", policy.deadline)));
                }
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(policy.max_backoff);
            }
        }
    }
}

/// TCP deployment: party `id` listens for its inbound links and dials its
/// outbound ones with the default `DialPolicy`.  `addrs[i]` is the base
/// address of party i; port+0 accepts from next, port+1 accepts from prev.
pub fn tcp_party(id: usize, addrs: &[String; 3], net: NetConfig)
                 -> std::io::Result<Comm> {
    tcp_party_with(id, addrs, net, DialPolicy::default())
}

/// `tcp_party` with an explicit dial-retry policy.
pub fn tcp_party_with(id: usize, addrs: &[String; 3], net: NetConfig,
                      dial: DialPolicy) -> std::io::Result<Comm> {
    if net.virtual_clock {
        // virtual stamps don't travel over TCP frames; a real deployment
        // has real latency anyway
        return Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "the virtual clock is for in-process (local) links only"));
    }
    let next = (id + 1) % 3;
    let prev = (id + 2) % 3;
    let (base_host, base_port) = split_addr(&addrs[id])?;
    let accept = |port: u16| -> std::io::Result<TcpStream> {
        let l = TcpListener::bind((base_host.as_str(), port))?;
        Ok(l.accept()?.0)
    };
    // deterministic connection order avoids deadlock: lower id listens
    // first on each pairwise link.
    // link to next: lower id accepts
    let (tx_next, rx_next) = if id < next {
        let a = accept(base_port)?;
        (a.try_clone()?, a)
    } else {
        let (h, p) = split_addr(&addrs[next])?;
        let c = connect_with_retry(&h, p, dial)?;
        (c.try_clone()?, c)
    };
    let (tx_prev, rx_prev) = if id < prev {
        let a = accept(base_port + 1)?;
        (a.try_clone()?, a)
    } else {
        let (h, p) = split_addr(&addrs[prev])?;
        let c = connect_with_retry(&h, p + 1, dial)?;
        (c.try_clone()?, c)
    };
    // receive paths poll at READ_POLL so a retired lane's blocked
    // reader can observe the cancellation (local links poll via
    // recv_timeout); read_full hides the timeouts from frame reads.  A
    // failure here would silently void close_chan's wakeup guarantee,
    // so it fails session setup instead.
    rx_next.set_read_timeout(Some(READ_POLL))?;
    rx_prev.set_read_timeout(Some(READ_POLL))?;
    Ok(make_comm(id, net,
                 LinkTx::Tcp(tx_next), LinkTx::Tcp(tx_prev),
                 LinkRx::Tcp(rx_next), LinkRx::Tcp(rx_prev)))
}

fn split_addr(a: &str) -> std::io::Result<(String, u16)> {
    let (h, p) = a.rsplit_once(':').ok_or_else(|| std::io::Error::new(
        std::io::ErrorKind::InvalidInput, "addr must be host:port"))?;
    Ok((h.to_string(), p.parse().map_err(|_| std::io::Error::new(
        std::io::ErrorKind::InvalidInput, "bad port"))?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run3<F>(net: NetConfig, f: F) -> Vec<Stats>
    where
        F: Fn(&Comm) + Send + Sync + Copy + 'static,
    {
        let comms = local_trio(net);
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                f(&c);
                c.stats()
            })
        }).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn ring_pass_delivers() {
        let stats = run3(NetConfig::zero(), |c| {
            let data = vec![c.id as i32; 8];
            c.send_elems(Dir::Next, &data).unwrap();
            let got = c.recv_elems(Dir::Prev).unwrap();
            let prev = (c.id + 2) % 3;
            assert_eq!(got, vec![prev as i32; 8]);
            c.round();
        });
        // 32 payload bytes + 1 channel tag
        for s in stats {
            assert_eq!(s.bytes_sent, 33);
            assert_eq!(s.messages, 1);
            assert_eq!(s.rounds, 1);
            assert_eq!(s.online().bytes_sent, 33);
            assert_eq!(s.offline().bytes_sent, 0);
        }
    }

    #[test]
    fn traced_flights_reconcile_with_stats() {
        // every shipped frame leaves a "send" flight span whose bytes
        // sum (per channel) to the transport::Stats row exactly
        let comms = local_trio(NetConfig::zero());
        let sinks: Vec<_> = (0..3)
            .map(|_| Arc::new(crate::trace::TraceSink::new()))
            .collect();
        for (c, s) in comms.iter().zip(&sinks) {
            assert!(c.install_tracer(Arc::clone(s)));
            s.set_enabled(true);
        }
        thread::scope(|sc| {
            for c in &comms {
                sc.spawn(move || {
                    let data = vec![c.id as i32; 8];
                    c.send_elems(Dir::Next, &data).unwrap();
                    c.send_elems(Dir::Prev, &data).unwrap();
                    c.recv_elems(Dir::Prev).unwrap();
                    c.recv_elems(Dir::Next).unwrap();
                });
            }
        });
        for (c, s) in comms.iter().zip(&sinks) {
            let spans = s.snapshot();
            let sends = spans.iter()
                .filter(|sp| sp.label.as_str() == "send").count();
            let recvs = spans.iter()
                .filter(|sp| sp.label.as_str() == "recv").count();
            assert_eq!((sends, recvs), (2, 2));
            let problems = crate::trace::merge::check_flights(
                c.id, &spans, &c.stats());
            assert!(problems.is_empty(), "{problems:?}");
            assert_eq!(s.dropped_events(), 0);
        }
    }

    #[test]
    fn bits_pack_tightly() {
        let stats = run3(NetConfig::zero(), |c| {
            let bits = BitTensor::ones(100);
            c.send_bits(Dir::Next, &bits).unwrap();
            let got = c.recv_bits(Dir::Prev).unwrap();
            assert_eq!(got, bits);
        });
        // 100 bits -> 13 bytes + 8 length header + 1 channel tag
        for s in stats {
            assert_eq!(s.bytes_sent, 22);
        }
    }

    #[test]
    fn bit_wire_cost_is_ceil_n_over_8_plus_framing() {
        // Stats-verified wire format: n bits cost exactly ceil(n/8) + 8
        // header + 1 tag bytes, for lengths straddling byte and word
        // boundaries.
        for n in [1usize, 7, 8, 9, 63, 64, 65, 100, 128, 1000] {
            let comms = local_trio(NetConfig::zero());
            let handles: Vec<_> = comms.into_iter().map(|c| {
                thread::spawn(move || {
                    let mut rng = crate::testutil::Rng::new(n as u64);
                    let bits = BitTensor::from_fn(n, |_| rng.bit());
                    c.send_bits(Dir::Next, &bits).unwrap();
                    let got = c.recv_bits(Dir::Prev).unwrap();
                    assert_eq!(got.len(), n);
                    c.stats()
                })
            }).collect();
            for h in handles {
                let s = h.join().unwrap();
                assert_eq!(s.bytes_sent, (n.div_ceil(8) + 9) as u64,
                           "wire bytes for {n} bits");
            }
        }
    }

    #[test]
    fn bit_roundtrip_preserves_exact_patterns() {
        let stats = run3(NetConfig::zero(), |c| {
            let mut rng = crate::testutil::Rng::new(7 + c.id as u64);
            let bits = BitTensor::from_fn(77, |_| rng.bit());
            c.send_bits(Dir::Next, &bits).unwrap();
            c.send_bits(Dir::Prev, &bits).unwrap();
            let from_prev = c.recv_bits(Dir::Prev).unwrap();
            let from_next = c.recv_bits(Dir::Next).unwrap();
            let mut prev_rng =
                crate::testutil::Rng::new(7 + ((c.id + 2) % 3) as u64);
            let want_prev = BitTensor::from_fn(77, |_| prev_rng.bit());
            assert_eq!(from_prev, want_prev);
            let mut next_rng =
                crate::testutil::Rng::new(7 + ((c.id + 1) % 3) as u64);
            let want_next = BitTensor::from_fn(77, |_| next_rng.bit());
            assert_eq!(from_next, want_next);
        });
        assert_eq!(stats.len(), 3);
    }

    #[test]
    fn malformed_lengths_are_errors_not_panics() {
        // a ring payload whose length is not a multiple of 4 must surface
        // as WireError::Malformed on the receiver
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                if c.id == 0 {
                    c.send_raw(Dir::Next, vec![0u8; 5]).unwrap();
                    // undersized bit message (no full header)
                    c.send_raw(Dir::Next, vec![0u8; 3]).unwrap();
                    // bit message whose payload contradicts its header
                    let mut lie = Vec::new();
                    lie.extend_from_slice(&100u64.to_le_bytes());
                    lie.push(0xFF); // 1 byte instead of 13
                    c.send_raw(Dir::Next, lie).unwrap();
                    None
                } else if c.id == 1 {
                    let a = c.recv_elems(Dir::Prev);
                    let b = c.recv_bits(Dir::Prev);
                    let d = c.recv_bits(Dir::Prev);
                    Some((a.is_err(), b.is_err(), d.is_err()))
                } else {
                    None
                }
            })
        }).collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(results[1], Some((true, true, true)));
    }

    #[test]
    fn latency_is_simulated() {
        let net = NetConfig { latency: Duration::from_millis(20),
                              ..NetConfig::zero() };
        let t0 = Instant::now();
        run3(net, |c| {
            c.send_elems(Dir::Next, &[1]).unwrap();
            let _ = c.recv_elems(Dir::Prev).unwrap();
        });
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn bandwidth_term_applies() {
        let net = NetConfig { bandwidth: 1e6, ..NetConfig::zero() };
        let t0 = Instant::now();
        run3(net, |c| {
            // 400 KB at 1 MB/s ~ 400 ms
            let data = vec![0i32; 100_000];
            c.send_elems(Dir::Next, &data).unwrap();
            let _ = c.recv_elems(Dir::Prev).unwrap();
        });
        assert!(t0.elapsed() >= Duration::from_millis(300));
    }

    #[test]
    fn virtual_clock_advances_without_sleeping() {
        // 20 ms one-way latency under the virtual clock: the receiver's
        // virtual clock crosses the latency while wall time stays
        // loopback-fast (the whole point of the deterministic shim)
        let net = NetConfig { latency: Duration::from_millis(20),
                              ..NetConfig::zero() }.with_virtual_clock();
        let t0 = Instant::now();
        let comms = local_trio(net);
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                for _ in 0..10 {
                    c.send_elems(Dir::Next, &[1]).unwrap();
                    let _ = c.recv_elems(Dir::Prev).unwrap();
                    c.round();
                }
                c.virtual_now()
            })
        }).collect();
        for h in handles {
            let vt = h.join().unwrap();
            // 10 serial flights x 20 ms = 200 ms of virtual time
            assert!(vt >= Duration::from_millis(200), "virtual {vt:?}");
            assert!(vt < Duration::from_secs(2), "virtual {vt:?}");
        }
        assert!(t0.elapsed() < Duration::from_millis(150),
                "virtual mode must not sleep ({:?})", t0.elapsed());
    }

    #[test]
    fn virtual_clock_includes_the_bandwidth_term() {
        // 1 MB at 1 MBps = 1 s of virtual serialization; latency zero
        let net = NetConfig { bandwidth: 1e6, ..NetConfig::zero() }
            .with_virtual_clock();
        let comms = local_trio(net);
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let data = vec![0i32; 250_000]; // 1 MB payload
                c.send_elems(Dir::Next, &data).unwrap();
                let _ = c.recv_elems(Dir::Prev).unwrap();
                c.virtual_now()
            })
        }).collect();
        for h in handles {
            let vt = h.join().unwrap();
            assert!(vt >= Duration::from_millis(990), "virtual {vt:?}");
        }
    }

    #[test]
    fn virtual_clock_and_jitter_are_deterministic() {
        let net = NetConfig { latency: Duration::from_millis(5),
                              jitter: Duration::from_millis(2),
                              ..NetConfig::zero() }.with_virtual_clock();
        let run = || {
            let comms = local_trio(net);
            let handles: Vec<_> = comms.into_iter().map(|c| {
                thread::spawn(move || {
                    for i in 0..7i32 {
                        c.send_elems(Dir::Next, &[i]).unwrap();
                        let _ = c.recv_elems(Dir::Prev).unwrap();
                    }
                    c.virtual_now()
                })
            }).collect();
            handles.into_iter().map(|h| h.join().unwrap())
                .collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same spec, same virtual timeline");
        // jitter actually perturbs the timeline beyond pure latency
        assert!(a.iter().any(|vt| *vt > Duration::from_millis(35)),
                "jitter never drew above zero: {a:?}");
    }

    // ---- poison containment ---------------------------------------------

    /// Poison `m` by panicking a thread while it holds the lock.
    fn poison<T: Send>(m: &Mutex<T>) {
        // the mutex lives inside an Arc'd Core that outlives the thread;
        // scoped threads keep the borrow checker satisfied
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let _g = m.lock().unwrap();
                panic!("injected poison");
            });
            assert!(h.join().is_err());
        });
    }

    #[test]
    fn poisoned_stats_lock_recovers_instead_of_cascading() {
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        poison(&c0.core.stats);
        // counters stay serviceable: round/stats/reset must not panic
        c0.round();
        assert_eq!(c0.stats().rounds, 1);
        c0.reset_stats();
        assert_eq!(c0.stats().rounds, 0);
        drop((c1, c2));
    }

    #[test]
    fn poisoned_tx_lane_fails_closed_not_panics() {
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        poison(&c0.core.tx[Dir::Next.index()]);
        let err = c0.send_elems(Dir::Next, &[1]).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        // the other direction is untouched
        c0.send_elems(Dir::Prev, &[2]).unwrap();
        assert_eq!(c2.recv_elems(Dir::Next).unwrap(), vec![2]);
        drop(c1);
    }

    #[test]
    fn poisoned_demux_state_fails_closed_not_panics() {
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        poison(&c1.core.rx[Dir::Prev.index()].state);
        let err = c1.recv_elems(Dir::Prev).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        drop((c0, c2));
    }

    #[test]
    fn bidirectional_same_round() {
        run3(NetConfig::zero(), |c| {
            c.send_elems(Dir::Next, &[c.id as i32]).unwrap();
            c.send_elems(Dir::Prev, &[c.id as i32]).unwrap();
            let a = c.recv_elems(Dir::Prev).unwrap();
            let b = c.recv_elems(Dir::Next).unwrap();
            assert_eq!(a[0] as usize, (c.id + 2) % 3);
            assert_eq!(b[0] as usize, (c.id + 1) % 3);
        });
    }

    #[test]
    fn send_to_hung_up_peer_is_error_not_panic() {
        // drop party 2's endpoints entirely; its neighbours' sends must
        // surface WireError::Closed (the ROADMAP send-path hardening gap)
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        drop(c2);
        assert!(c0.send_elems(Dir::Next, &[1]).is_ok()); // P1 still alive
        let err = c0.send_elems(Dir::Prev, &[1]).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        let err = c1.send_bits(Dir::Next, &BitTensor::ones(9)).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        let err = c1.send_raw(Dir::Next, vec![0u8; 4]).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
    }

    #[test]
    fn planes_travel_as_reinterpreted_tensors() {
        let stats = run3(NetConfig::zero(), |c| {
            let mut rng = crate::testutil::Rng::new(13);
            let rows: Vec<BitTensor> =
                (0..4).map(|_| BitTensor::from_fn(70, |_| rng.bit()))
                .collect();
            let m = BitPlanes::from_tensors(&rows);
            c.send_planes(Dir::Next, &m).unwrap();
            let got = c.recv_planes(Dir::Prev, 4, 70).unwrap();
            assert_eq!(got, m);
            for (p, row) in rows.iter().enumerate() {
                assert_eq!(&got.plane(p), row);
            }
        });
        // 4 planes x 2 words x 8 bytes + 8-byte header + 1 tag, per party
        for s in stats {
            assert_eq!(s.bytes_sent, (4 * 2 * 8 + 9) as u64);
        }
    }

    #[test]
    fn recv_planes_rejects_geometry_lies() {
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                if c.id == 0 {
                    // an honest 2x64 matrix received as 4x32 is fine
                    // (same padded words) -- but a 3-plane claim is not
                    let m = BitPlanes::zeros(2, 64);
                    c.send_planes(Dir::Next, &m).unwrap();
                    None
                } else if c.id == 1 {
                    Some(c.recv_planes(Dir::Prev, 3, 64).is_err())
                } else {
                    None
                }
            })
        }).collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(results[1], Some(true));
    }

    // ---- tagged-channel behaviour --------------------------------------

    #[test]
    fn channel_handles_split_stats_per_channel() {
        let stats = run3(NetConfig::zero(), |c| {
            let off = c.channel(ChanId::OFFLINE);
            assert_eq!(off.chan(), ChanId::OFFLINE);
            c.send_elems(Dir::Next, &[1, 2]).unwrap(); // 8 + 1 bytes
            off.send_elems(Dir::Next, &[3]).unwrap(); // 4 + 1 bytes
            let on = c.recv_elems(Dir::Prev).unwrap();
            let of = off.recv_elems(Dir::Prev).unwrap();
            assert_eq!(on.len(), 2);
            assert_eq!(of, vec![3]);
            c.round();
            off.round();
            off.round();
        });
        for s in stats {
            assert_eq!(s.online().bytes_sent, 9);
            assert_eq!(s.offline().bytes_sent, 5);
            assert_eq!(s.bytes_sent, 14);
            assert_eq!(s.online().messages, 1);
            assert_eq!(s.offline().messages, 1);
            assert_eq!(s.online().rounds, 1);
            assert_eq!(s.offline().rounds, 2);
            assert_eq!(s.rounds, 3);
        }
    }

    #[test]
    fn chan_id_encoding_round_trips() {
        assert_eq!(ChanId::ONLINE, ChanId::online(0));
        assert_eq!(ChanId::OFFLINE, ChanId::offline(0));
        for slot in [0u8, 1, 2, 63, 127] {
            let on = ChanId::online(slot);
            let off = ChanId::offline(slot);
            assert_ne!(on, off);
            assert_eq!(on.model(), slot);
            assert_eq!(off.model(), slot);
            assert!(!on.is_offline());
            assert!(off.is_offline());
            assert_eq!(ChanId::from_tag(on.tag()), on);
            assert_eq!(ChanId::from_tag(off.tag()), off);
        }
        assert_eq!(format!("{}", ChanId::online(3)), "online/3");
        assert_eq!(format!("{}", ChanId::offline(3)), "offline/3");
    }

    #[test]
    #[should_panic(expected = "model slot 128")]
    fn chan_id_rejects_slots_past_the_space() {
        let _ = ChanId::online(128);
    }

    #[test]
    fn per_channel_stats_sum_to_link_totals() {
        // the acceptance invariant the multi-model rollups rely on: the
        // per-channel breakdown is exhaustive, so summing every
        // channel's row reproduces the totals exactly
        let stats = run3(NetConfig::zero(), |c| {
            let lanes = [
                c.channel(ChanId::online(0)),
                c.channel(ChanId::offline(0)),
                c.channel(ChanId::online(1)),
                c.channel(ChanId::offline(1)),
                c.channel(ChanId::online(5)),
            ];
            for (i, lane) in lanes.iter().enumerate() {
                for _ in 0..=i {
                    lane.send_elems(Dir::Next, &[i as i32]).unwrap();
                    let got = lane.recv_elems(Dir::Prev).unwrap();
                    assert_eq!(got, vec![i as i32]);
                }
                lane.round();
            }
        });
        for s in stats {
            let mut sum = ChanStats::default();
            let mut seen = 0;
            for (_, cs) in s.channels() {
                sum.add(&cs);
                seen += 1;
            }
            assert_eq!(seen, 5, "five lanes moved traffic");
            assert_eq!(sum.bytes_sent, s.bytes_sent);
            assert_eq!(sum.messages, s.messages);
            assert_eq!(sum.rounds, s.rounds);
            // model() combines a slot's two lanes
            let m0 = s.model(0);
            assert_eq!(m0.bytes_sent,
                       s.chan(ChanId::online(0)).bytes_sent
                       + s.chan(ChanId::offline(0)).bytes_sent);
            // lanes 2 and 3 (model slot 1) sent 3 and 4 messages
            assert_eq!(s.model(1).messages, 3 + 4);
            assert_eq!(s.model(1).rounds, 2);
        }
    }

    #[test]
    fn concurrent_lane_rounds_sum_to_link_totals() {
        // rounds obey the same exhaustive-breakdown invariant as bytes
        // even when two lanes' threads advance them concurrently (the
        // total and the channel row move under one lock): the rollup
        // regression pinned alongside per_channel_stats_sum_to_link_totals
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let off = c.channel(ChanId::OFFLINE);
                let on = c.clone();
                let t = thread::spawn(move || {
                    for _ in 0..500 {
                        on.round();
                    }
                });
                for _ in 0..300 {
                    off.round();
                }
                t.join().unwrap();
                c.stats()
            })
        }).collect();
        for h in handles {
            let s = h.join().unwrap();
            assert_eq!(s.online().rounds, 500);
            assert_eq!(s.offline().rounds, 300);
            assert_eq!(s.rounds, 800);
            let sum: u64 = s.channels().map(|(_, cs)| cs.rounds).sum();
            assert_eq!(sum, s.rounds);
        }
    }

    #[test]
    fn model_lanes_demux_independently_over_one_link() {
        // two model slots' four lanes exchange disjoint streams over the
        // same links; frames sent out of order park per lane and arrive
        // intact (the multi-model generalization of the PR 3 two-channel
        // parking test)
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let lanes = [
                    c.channel(ChanId::online(1)),
                    c.channel(ChanId::offline(1)),
                    c.channel(ChanId::online(2)),
                    c.channel(ChanId::offline(2)),
                ];
                // send every lane's frame before receiving any: each
                // recv must skip (and park) up to three foreign frames
                for (i, lane) in lanes.iter().enumerate() {
                    lane.send_elems(Dir::Next,
                                    &[100 * i as i32 + c.id as i32])
                        .unwrap();
                }
                let prev = ((c.id + 2) % 3) as i32;
                for (i, lane) in lanes.iter().enumerate().rev() {
                    let got = lane.recv_elems(Dir::Prev).unwrap();
                    assert_eq!(got, vec![100 * i as i32 + prev]);
                }
            })
        }).collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn demux_parks_other_channels_frames() {
        // an offline frame sent *first* must not satisfy an online recv;
        // it is parked and later consumed by the offline handle, in FIFO
        // order per channel
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let off = c.channel(ChanId::OFFLINE);
                let prev = ((c.id + 2) % 3) as i32;
                off.send_elems(Dir::Next, &[100 + c.id as i32]).unwrap();
                c.send_elems(Dir::Next, &[c.id as i32]).unwrap();
                off.send_elems(Dir::Next, &[200 + c.id as i32]).unwrap();
                assert_eq!(c.recv_elems(Dir::Prev).unwrap(), vec![prev]);
                assert_eq!(off.recv_elems(Dir::Prev).unwrap(),
                           vec![100 + prev]);
                assert_eq!(off.recv_elems(Dir::Prev).unwrap(),
                           vec![200 + prev]);
            })
        }).collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn concurrent_channel_threads_share_one_link() {
        // two threads per party -- one per channel -- exchange disjoint
        // streams over the same links concurrently; each stream arrives
        // intact on its own channel
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let off = c.channel(ChanId::OFFLINE);
                let online = thread::spawn(move || {
                    for i in 0..50i32 {
                        c.send_elems(Dir::Next, &[i]).unwrap();
                        let got = c.recv_elems(Dir::Prev).unwrap();
                        assert_eq!(got, vec![i]);
                    }
                });
                for i in 0..50i32 {
                    off.send_elems(Dir::Next, &[1000 + i]).unwrap();
                    let got = off.recv_elems(Dir::Prev).unwrap();
                    assert_eq!(got, vec![1000 + i]);
                }
                online.join().unwrap();
            })
        }).collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    // ---- lane lifecycle -------------------------------------------------

    #[test]
    fn close_chan_wakes_a_blocked_recv_with_closed() {
        // a receiver blocked on an idle link (it holds the reader token)
        // must observe the retirement within the poll interval instead
        // of blocking forever -- the quarantine primitive
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        let ctl = c1.control();
        let waiter = thread::spawn(move || c1.recv_elems(Dir::Prev));
        thread::sleep(Duration::from_millis(30)); // let it block
        let t0 = Instant::now();
        ctl.close_chan(ChanId::ONLINE);
        let err = waiter.join().unwrap().unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        assert!(t0.elapsed() < Duration::from_secs(2),
                "retirement took too long to observe");
        drop((c0, c2));
    }

    #[test]
    fn close_chan_purges_parked_frames_and_register_reopens() {
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        let lane = ChanId::online(4);
        let c0l = c0.channel(lane);
        let c1l = c1.channel(lane);
        // park two lane-4 frames at c1 by receiving an ONLINE frame
        // sent after them
        c0l.send_elems(Dir::Next, &[1]).unwrap();
        c0l.send_elems(Dir::Next, &[2]).unwrap();
        c0.send_elems(Dir::Next, &[0]).unwrap();
        assert_eq!(c1.recv_elems(Dir::Prev).unwrap(), vec![0]);
        assert!(c1.parked_bytes(lane) > 0);
        // retire: parked frames purged, recv on the lane fails Closed
        c1.close_chan(lane);
        assert_eq!(c1.parked_bytes(lane), 0);
        let err = c1l.recv_elems(Dir::Prev).unwrap_err();
        assert!(matches!(err, WireError::Closed), "{err:?}");
        // frames arriving while retired are dropped, not Malformed and
        // not delivered: a healthy recv skips straight past them
        c0l.send_elems(Dir::Next, &[3]).unwrap();
        c0.send_elems(Dir::Next, &[9]).unwrap();
        assert_eq!(c1.recv_elems(Dir::Prev).unwrap(), vec![9]);
        // re-derive = re-open for a fresh epoch: only frames sent after
        // the reopen arrive
        let c1l = c1.channel(lane);
        c0l.send_elems(Dir::Next, &[7]).unwrap();
        assert_eq!(c1l.recv_elems(Dir::Prev).unwrap(), vec![7]);
        drop(c2);
    }

    #[test]
    fn parked_cap_poisons_the_flooded_lane_only() {
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        c1.set_parked_cap(256);
        assert_eq!(c1.parked_cap(), 256);
        let idle = c1.channel(ChanId::online(3)); // registered, unread
        let tag = ChanId::online(3).tag();
        // interleave flood frames (64 B each, 10x = 640 B > 256) with
        // healthy ONLINE traffic; every healthy recv must succeed while
        // the flood overflows the idle lane's parked queue
        for i in 0..10i32 {
            let mut frame = vec![tag];
            frame.extend_from_slice(&[0u8; 64]);
            c0.send_frame(Dir::Next, frame).unwrap();
            c0.send_elems(Dir::Next, &[i]).unwrap();
            assert_eq!(c1.recv_elems(Dir::Prev).unwrap(), vec![i],
                       "healthy lane perturbed at frame {i}");
        }
        // the flooded lane's storage is bounded (freed at overflow) and
        // its next recv reports the overflow as Malformed
        assert!(c1.parked_bytes(ChanId::online(3)) <= 256);
        let err = idle.recv_elems(Dir::Prev).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
        drop(c2);
    }

    #[test]
    fn sweep_drops_retired_frames_and_parks_healthy_ones() {
        let [c0, c1, c2] = local_trio(NetConfig::zero());
        let lane = ChanId::online(5);
        let c0l = c0.channel(lane);
        let c1l = c1.channel(lane);
        // a stale lane-5 frame and a healthy ONLINE frame sit unread on
        // the link when the lane is retired
        c0l.send_elems(Dir::Next, &[1]).unwrap();
        c0.send_elems(Dir::Next, &[2]).unwrap();
        // frames are in flight; wait for the local link to hold them
        thread::sleep(Duration::from_millis(10));
        c1.close_chan(lane);
        c1.sweep();
        // the stale frame is gone; the healthy frame was parked
        assert_eq!(c1.parked_bytes(lane), 0);
        assert_eq!(c1.recv_elems(Dir::Prev).unwrap(), vec![2]);
        // reopen: a fresh frame arrives cleanly (the stale one cannot)
        let c1l2 = c1.channel(lane);
        c0l.send_elems(Dir::Next, &[4]).unwrap();
        assert_eq!(c1l2.recv_elems(Dir::Prev).unwrap(), vec![4]);
        drop((c1l, c2));
    }

    #[test]
    fn dial_retry_gives_up_at_the_deadline() {
        // a port with nothing listening: connect_with_retry must retry
        // with backoff, then surface TimedOut once the deadline passes
        let port = {
            let l = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
            // listener dropped: the port now refuses connections
        };
        let policy = DialPolicy {
            deadline: Duration::from_millis(120),
            initial_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_millis(40),
        };
        let t0 = Instant::now();
        let err = connect_with_retry("127.0.0.1", port, policy).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        // it did retry (at least one backoff sleep), and did not spin
        // forever past the deadline
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
