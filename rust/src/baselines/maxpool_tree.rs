//! Non-fused maxpooling baseline: pairwise secure max over arithmetic
//! shares via comparison trees (the cost Section 3.6's Sign-fusion
//! avoids).  max(a, b) = b + ReLU(a - b): each level costs a full MSB
//! extraction + ReLU selection; a 2x2 window needs two levels (3 maxes).

use anyhow::Result;

use crate::protocols::msb::msb_extract;
use crate::protocols::relu::relu_ot;
use crate::protocols::Ctx;
use crate::rss::Share;

/// Elementwise secure max over two equal-shape shares.
pub fn secure_max(ctx: &Ctx, a: &Share, b: &Share) -> Result<Share> {
    let d = a.sub(b);
    let flat = d.clone().reshape(&[d.len()]);
    let m = msb_extract(ctx, &flat)?;
    let r = relu_ot(ctx, &flat, &m)?; // ReLU(a - b)
    Ok(b.clone().reshape(&[b.len()]).add(&r))
}

/// 2x2/stride-2 maxpool over a (C,H,W) share via a two-level comparison
/// tree.  Returns ([C, OH*OW], (OH, OW)).
pub fn maxpool_tree(ctx: &Ctx, x: &Share, c: usize, h: usize, w: usize)
                    -> Result<(Share, (usize, usize))> {
    let (oh, ow) = (h / 2, w / 2);
    let gather = |dy: usize, dx: usize| -> Share {
        let pick = |t: &crate::ring::Tensor| {
            let mut out = Vec::with_capacity(c * oh * ow);
            for ci in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        out.push(t.data[ci * h * w + (2 * oy + dy) * w
                                        + 2 * ox + dx]);
                    }
                }
            }
            crate::ring::Tensor::from_vec(&[c * oh * ow], out)
        };
        Share { a: pick(&x.a), b: pick(&x.b) }
    };
    let (q00, q01, q10, q11) = (gather(0, 0), gather(0, 1), gather(1, 0),
                                gather(1, 1));
    let top = secure_max(ctx, &q00, &q01)?;
    let bot = secure_max(ctx, &q10, &q11)?;
    let m = secure_max(ctx, &top, &bot)?;
    Ok((m.reshape(&[c, oh * ow]), (oh, ow)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::Tensor;
    use crate::rss::{deal, reconstruct};
    use crate::testutil::Rng;

    #[test]
    fn secure_max_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(6);
            let a: Vec<i32> = (0..30).map(|_| rng.small(1 << 20)).collect();
            let b: Vec<i32> = (0..30).map(|_| rng.small(1 << 20)).collect();
            let ta = Tensor::from_vec(&[30], a.clone());
            let tb = Tensor::from_vec(&[30], b.clone());
            let sa = deal(&ta, &mut rng);
            let sb = deal(&tb, &mut rng);
            (secure_max(ctx, &sa[ctx.id()], &sb[ctx.id()]).unwrap(), a, b)
        });
        let (_, a, b) = results[0].0.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for i in 0..a.len() {
            assert_eq!(got.data[i], a[i].max(b[i]), "max({}, {})", a[i], b[i]);
        }
    }

    #[test]
    fn tree_pool_matches_plaintext_max() {
        let results = run3(|ctx| {
            let (c, h, w) = (2, 4, 4);
            let mut rng = Rng::new(9);
            let vals: Vec<i32> = (0..c * h * w).map(|_| rng.small(1 << 16))
                .collect();
            let x = Tensor::from_vec(&[c, h * w], vals.clone());
            let xs = deal(&x, &mut rng);
            (maxpool_tree(ctx, &xs[ctx.id()], c, h, w).unwrap(), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0 .0.clone());
        let got = reconstruct(&shares);
        let (c, h, w) = (2usize, 4usize, 4usize);
        for ci in 0..c {
            for oy in 0..2 {
                for ox in 0..2 {
                    let vals = &vals;
                    let m = (0..2).flat_map(|dy| (0..2).map(move |dx| {
                        vals[ci * h * w + (2 * oy + dy) * w + 2 * ox + dx]
                    })).max().unwrap();
                    assert_eq!(got.data[ci * 4 + oy * 2 + ox], m);
                }
            }
        }
    }

    #[test]
    fn tree_pool_costs_more_rounds_than_fused() {
        let tree = run3(|ctx| {
            let mut rng = Rng::new(4);
            let x = rng.tensor_small(&[1, 16], 1);
            let xs = deal(&x, &mut rng);
            let _ = maxpool_tree(ctx, &xs[ctx.id()], 1, 4, 4).unwrap();
        });
        let fused = run3(|ctx| {
            let mut rng = Rng::new(4);
            let bits = Tensor::from_vec(&[1, 16],
                                        (0..16).map(|i| i % 2).collect());
            let xs = deal(&bits, &mut rng);
            let _ = crate::protocols::maxpool::maxpool_bits(
                ctx, &xs[ctx.id()], 1, 4, 4, 2, 2).unwrap();
        });
        let max_rounds = |r: &[((), crate::transport::Stats)]| {
            r.iter().map(|(_, s)| s.rounds).max().unwrap()
        };
        assert!(max_rounds(&tree) > max_rounds(&fused),
                "tree {} <= fused {}", max_rounds(&tree), max_rounds(&fused));
    }
}
