//! Baseline arms and literature cost rows for the comparison tables.
//!
//! * `bitdecomp` -- SecureBiNN/ABY3-style MSB extraction through a
//!   Kogge-Stone boolean adder over RSS bit shares (log l AND rounds).
//!   This is the protocol CBNN's Algorithm 3 is designed to beat; both
//!   run on the identical simulated network in the A1 ablation.
//! * `maxpool_tree` -- non-fused maxpooling via pairwise secure max
//!   (comparison trees), the cost the Sign-fusion of Section 3.6 avoids.
//! * `bn_explicit` -- BN as an online secure multiply + truncate + add,
//!   the cost the adaptive fusing of Section 3.5 removes.
//! * `costmodel` -- published numbers from the paper's Tables 1 and 3 for
//!   frameworks we do not re-implement (clearly labelled literature rows).

pub mod bitdecomp;
pub mod bn_explicit;
pub mod costmodel;
pub mod maxpool_tree;
