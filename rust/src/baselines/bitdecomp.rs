//! SecureBiNN/ABY3-style MSB via boolean share conversion + Kogge-Stone
//! adder -- the bit-decomposition baseline that CBNN's Algorithm 3
//! replaces.
//!
//! x = x_0 + x_1 + x_2 (mod 2^32), each additive component known to two
//! parties, so its *bits* inject into RSS boolean sharing locally.  A
//! carry-save step reduces the three 32-bit vectors to two (1 AND round),
//! then a Kogge-Stone prefix adder produces the carry into bit 31
//! (log2(32) = 5 AND rounds).  Total: 6 communication rounds, each moving
//! O(l) bits per element -- versus Algorithm 3's constant ~7 rounds with
//! O(1) ring elements.  On WAN the round counts are comparable, but the
//! adder's rounds are *serial levels of a circuit over every element's 32
//! bits*, so its bytes and local work are ~an order of magnitude higher.
//!
//! The circuit state lives in strided `BitPlanes` matrices (32 planes of
//! n bits, one allocation, equal row stride).  Every Kogge-Stone operand
//! -- `p[dist..L]`, `g[0..L-dist]`, the carry wire `t = (maj ^ b) << 1`
//! -- is a zero-copy row selection or index-remapped view; the level
//! loop performs **no per-level bit copies** (no `extend`/`slice`), only
//! the word-aligned row writes of each AND round's fresh output.  The
//! wire ships each round's matrix as a reinterpreted `BitTensor`
//! (`transport::send_planes`), padded to whole words per plane.
//!
//! `msb_bitdecomp_concat` keeps the PR 1 concatenation-based
//! implementation as the equivalence reference and the bench's
//! copy-churn arm.

use anyhow::Result;

use crate::ring::bits::BitTensor;
use crate::ring::planes::BitPlanes;
use crate::rss::{BitShare, PlaneShare, PlaneShareView};
use crate::transport::Dir;

use crate::protocols::Ctx;

/// Adder width: one plane per bit of the ring element.
const L: usize = 32;

/// RSS boolean AND, batched: z = x & y with one reshare round (the mod-2
/// analogue of rss::mul).  Entirely word-parallel locally.
pub fn and_bits(ctx: &Ctx, x: &BitShare, y: &BitShare) -> Result<BitShare> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let cnt = ctx.seeds.next_cnt();
    // zero-sharing mod 2: r_i = F(k_{i+1}) ^ F(k_i), word-filled
    let mask = ctx.seeds.zero_bits3(cnt, n);
    let zi = x.a.and(&y.a)
        .xor(&x.a.and(&y.b))
        .xor(&x.b.and(&y.a))
        .xor(&mask);
    ctx.comm.send_bits(Dir::Prev, &zi)?;
    let from_next = ctx.comm.recv_bits(Dir::Next)?;
    if from_next.len() != n {
        anyhow::bail!("wire desync: peer sent {} bits, expected {n}",
                      from_next.len());
    }
    ctx.comm.round();
    Ok(BitShare { a: zi, b: from_next })
}

/// One RSS boolean AND round over whole plane matrices:
/// `out[part][row] = x[part][row] & y[part][row]` for every
/// `(x, y)` operand pair in `parts`, all batched into a *single*
/// communication round.  Operands are zero-copy views (row selections /
/// level shifts); the only writes are the fused local term of each
/// output row (`kernel::and_local_into`) straight into the one output
/// allocation.
pub fn and_planes(ctx: &Ctx, parts: &[(PlaneShareView<'_>,
                                       PlaneShareView<'_>)])
                  -> Result<PlaneShare> {
    let len = parts.first().map_or(0, |(x, _)| x.len());
    let rows: usize = parts.iter().map(|(x, y)| {
        assert_eq!(x.count(), y.count(), "operand plane counts differ");
        assert!(x.len() == len && y.len() == len,
                "operand plane lengths differ");
        x.count()
    }).sum();
    let mut zi = BitPlanes::zeros(rows, len);
    let w = zi.width_words();
    let cnt = ctx.seeds.next_cnt();
    // zero-sharing mod 2 over the padded matrix, row r masked by words
    // [r*w, (r+1)*w) -- all parties derive the identical padded length
    let mask = ctx.seeds.zero_bits3(cnt, rows * w * 64);
    let zero_row = vec![0u64; w];
    let mut r = 0;
    for (x, y) in parts {
        for pr in 0..x.count() {
            let xa = x.a.row_words(pr).unwrap_or(&zero_row);
            let xb = x.b.row_words(pr).unwrap_or(&zero_row);
            let ya = y.a.row_words(pr).unwrap_or(&zero_row);
            let yb = y.b.row_words(pr).unwrap_or(&zero_row);
            crate::ring::kernel::and_local_into(
                zi.plane_words_mut(r), xa, xb, ya, yb,
                &mask.words()[r * w..(r + 1) * w]);
            r += 1;
        }
    }
    // the zero-sharing put mask bits into the per-plane padding; clear it
    // before the words hit the wire (tail invariant)
    zi.mask_tails();
    ctx.comm.send_planes(Dir::Prev, &zi)?;
    let from_next = ctx.comm.recv_planes(Dir::Next, rows, len)?;
    ctx.comm.round();
    Ok(PlaneShare { a: zi, b: from_next })
}

/// Boolean shares of the bits of one additive component, as a 32-plane
/// matrix.  `slot` is which additive component (0, 1, 2); in RSS P_i
/// holds components (i, i+1), so component `slot` is P_slot's `a` and
/// P_{slot-1}'s `b`.  Packing the planes is the arithmetic/boolean
/// boundary: one strided matrix per component, no per-plane tensors.
fn inject_planes(me: usize, slot: usize, xa: &[i32], xb: &[i32])
                 -> PlaneShare {
    let n = xa.len();
    PlaneShare {
        a: if me == slot {
            BitPlanes::from_elem_bits(xa, L)
        } else {
            BitPlanes::zeros(L, n)
        },
        b: if (me + 1) % 3 == slot {
            BitPlanes::from_elem_bits(xb, L)
        } else {
            BitPlanes::zeros(L, n)
        },
    }
}

/// Full bit-decomposition MSB: returns [MSB(x)]^B.
/// `x` is the party's RSS arithmetic share (a = x_me, b = x_{me+1}).
pub fn msb_bitdecomp(ctx: &Ctx, xa: &[i32], xb: &[i32])
                     -> Result<BitShare> {
    let me = ctx.id();
    assert_eq!(xa.len(), xb.len());

    // Carry-save: s = a^b^c, carry t = maj(a,b,c) = (a&b)^(a&c)^(b&c)
    // = ((a^b)&(b^c)) ^ b   [1 AND round over all 32 planes at once]
    let ca = inject_planes(me, 0, xa, xb);
    let cb = inject_planes(me, 1, xa, xb);
    let cc = inject_planes(me, 2, xa, xb);
    let s = ca.xor(&cb).xor(&cc);
    let ab = ca.xor(&cb);
    let bc = cb.xor(&cc);
    let maj = and_planes(ctx, &[(ab.view(), bc.view())])?; // 1 round
    // carry wire: t = (maj ^ b) << 1 along the plane axis -- an index
    // remap (shifted view), not a 32n-bit copy
    let mb = maj.xor(&cb);
    let t = mb.shifted(1);

    // Kogge-Stone prefix over (g, p): g = s&t, p = s^t
    let g0 = and_planes(ctx, &[(s.view(), t)])?; // 1 round
    let p0 = s.view().xor(&t);
    // sum bit 31 = (s ^ t)[31] ^ carry_in(31); save it before the prefix
    // pass mutates plane 31 of p
    let sum31_no_carry = p0.plane(31);
    let mut g = g0;
    let mut p = p0;
    let mut dist = 1usize;
    while dist < L {
        // combine (g,p)[i] with (g,p)[i-dist] for i >= dist:
        // [p_i & g_{i-dist}, p_i & p_{i-dist}], one AND round per level.
        // All four operands are zero-copy row selections into g and p.
        let m = L - dist;
        let prod = and_planes(ctx, &[
            (p.rows(dist..L), g.rows(0..m)),
            (p.rows(dist..L), p.rows(0..m)),
        ])?;
        // g[i] ^= p_i & g_{i-dist}; p[i] = p_i & p_{i-dist}: word-aligned
        // row-block writes of the round's fresh output, nothing re-packed
        g.xor_rows_from(dist, &prod, 0..m);
        p.copy_rows_from(dist, &prod, m..2 * m);
        dist *= 2;
    }
    // carry into bit 31 = G[30] (prefix generate over bits 0..30)
    Ok(sum31_no_carry.xor(&g.plane(30)))
}

/// The PR 1 implementation: identical circuit, but every level operand is
/// stitched together with `extend` and split back with `slice`, copying
/// O(L*n) bits per level.  Kept as the bit-exactness reference for
/// `msb_bitdecomp` and as the copy-churn arm of `benches/bitops.rs`.
pub fn msb_bitdecomp_concat(ctx: &Ctx, xa: &[i32], xb: &[i32])
                            -> Result<BitShare> {
    let me = ctx.id();
    let n = xa.len();

    let xor3 = |a: &BitShare, b: &BitShare, c: &BitShare| -> BitShare {
        a.xor(b).xor(c)
    };
    // Boolean shares of each additive component's bit-planes, one
    // `BitTensor` pair per plane (the pre-planes representation).
    let inject = |slot: usize, bit: u32| -> BitShare {
        let mut out = BitShare::zeros(n);
        if me == slot {
            out.a = BitTensor::from_fn(n, |i| {
                ((xa[i] as u32 >> bit) & 1) as u8
            });
        }
        if (me + 1) % 3 == slot {
            out.b = BitTensor::from_fn(n, |i| {
                ((xb[i] as u32 >> bit) & 1) as u8
            });
        }
        out
    };

    let mut s_bits: Vec<BitShare> = Vec::with_capacity(L);
    let mut ab_all = BitShare::empty();
    let mut bc_all = BitShare::empty();
    let mut b_planes: Vec<BitShare> = Vec::with_capacity(L);
    for bit in 0..L as u32 {
        let a = inject(0, bit);
        let b = inject(1, bit);
        let c = inject(2, bit);
        s_bits.push(xor3(&a, &b, &c));
        ab_all.extend(&a.xor(&b));
        bc_all.extend(&b.xor(&c));
        b_planes.push(b);
    }
    let maj_raw = and_bits(ctx, &ab_all, &bc_all)?; // one round, 32n bits
    // t[bit] = maj ^ b, shifted left by one (carry feeds the next bit)
    let mut t_bits: Vec<BitShare> = Vec::with_capacity(L);
    t_bits.push(BitShare::zeros(n)); // t << 1
    for bit in 0..L - 1 {
        let maj = maj_raw.slice(bit * n, n);
        t_bits.push(maj.xor(&b_planes[bit]));
    }

    // Kogge-Stone prefix over (g, p): g = s&t, p = s^t
    let cat = |v: &[BitShare]| -> BitShare {
        let mut out = BitShare::empty();
        for s in v {
            out.extend(s);
        }
        out
    };
    let s_all = cat(&s_bits);
    let t_all = cat(&t_bits);
    let g0 = and_bits(ctx, &s_all, &t_all)?; // one round
    let p0 = s_all.xor(&t_all);
    let mut g: Vec<BitShare> = (0..L).map(|i| g0.slice(i * n, n)).collect();
    let mut p: Vec<BitShare> = (0..L).map(|i| p0.slice(i * n, n)).collect();
    let sum31_no_carry = p0.slice(31 * n, n);
    let mut dist = 1usize;
    while dist < L {
        let idx: Vec<usize> = (dist..L).collect();
        let mut lhs = BitShare::empty();
        let mut rhs = BitShare::empty();
        for &i in &idx {
            lhs.extend(&p[i]);
            rhs.extend(&g[i - dist]);
        }
        for &i in &idx {
            lhs.extend(&p[i]);
            rhs.extend(&p[i - dist]);
        }
        let prod = and_bits(ctx, &lhs, &rhs)?; // one round per level
        let m = idx.len();
        for (j, &i) in idx.iter().enumerate() {
            let pg = prod.slice(j * n, n);
            let pp = prod.slice((m + j) * n, n);
            g[i] = g[i].xor(&pg);
            p[i] = pp;
        }
        dist *= 2;
    }
    Ok(sum31_no_carry.xor(&g[30]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::{self, Tensor};
    use crate::rss::{deal, deal_bits, reconstruct_bits};
    use crate::testutil::Rng;

    #[test]
    fn and_bits_is_boolean_mul() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(3);
            // non-word-aligned length exercises the packed tail
            let x: Vec<u8> = (0..77).map(|_| rng.bit()).collect();
            let y: Vec<u8> = (0..77).map(|_| rng.bit()).collect();
            let xs = deal_bits(&x, &mut rng);
            let ys = deal_bits(&y, &mut rng);
            (and_bits(ctx, &xs[ctx.id()], &ys[ctx.id()]).unwrap(), x, y)
        });
        let (_, x, y) = results[0].0.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&shares);
        for i in 0..x.len() {
            assert_eq!(got[i], x[i] & y[i]);
        }
    }

    #[test]
    fn and_planes_is_planewise_boolean_mul() {
        // one AND round over [x&y ; x&z] stacked views, non-aligned length
        let results = run3(|ctx| {
            let mut rng = Rng::new(17);
            let planes = 5;
            let n = 70;
            let mk = |rng: &mut Rng| -> Vec<Vec<u8>> {
                (0..planes).map(|_| (0..n).map(|_| rng.bit()).collect())
                    .collect()
            };
            let (x, y, z) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
            let deal_planes = |bits: &[Vec<u8>], rng: &mut Rng|
                              -> [PlaneShare; 3] {
                let per: Vec<[BitShare; 3]> =
                    bits.iter().map(|row| deal_bits(row, rng)).collect();
                std::array::from_fn(|p| PlaneShare {
                    a: BitPlanes::from_tensors(&per.iter()
                        .map(|s| s[p].a.clone()).collect::<Vec<_>>()),
                    b: BitPlanes::from_tensors(&per.iter()
                        .map(|s| s[p].b.clone()).collect::<Vec<_>>()),
                })
            };
            let xs = deal_planes(&x, &mut rng);
            let ys = deal_planes(&y, &mut rng);
            let zs = deal_planes(&z, &mut rng);
            ctx.comm.reset_stats();
            let me = ctx.id();
            let out = and_planes(ctx, &[
                (xs[me].view(), ys[me].view()),
                (xs[me].view(), zs[me].view()),
            ]).unwrap();
            (out, x, y, z, ctx.comm.stats().rounds)
        });
        let (_, x, y, z, rounds) = results[0].0.clone();
        assert_eq!(rounds, 1, "stacked AND must be a single round");
        for pr in 0..5 {
            for (half, rhs) in [(0usize, &y), (1usize, &z)] {
                let shares: [BitShare; 3] = std::array::from_fn(|i| {
                    results[i].0 .0.plane(half * 5 + pr)
                });
                let got = reconstruct_bits(&shares);
                for i in 0..70 {
                    assert_eq!(got[i], x[pr][i] & rhs[pr][i],
                               "half {half} plane {pr} bit {i}");
                }
            }
        }
    }

    #[test]
    fn bitdecomp_msb_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(7);
            let vals: Vec<i32> = (0..50).map(|_| rng.next_i32()).collect();
            let x = Tensor::from_vec(&[50], vals.clone());
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            (msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap(), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&shares);
        for (g, v) in got.iter().zip(&vals) {
            assert_eq!(*g, ring::msb(*v), "x = {v}");
        }
    }

    #[test]
    fn strided_equals_concat_reference_bit_for_bit() {
        // the zero-copy rewrite must reconstruct to exactly the bits the
        // PR 1 concat implementation produced, across awkward lengths
        for n in [1usize, 63, 64, 65, 200] {
            let results = run3(move |ctx| {
                let mut rng = Rng::new(1000 + n as u64);
                let vals: Vec<i32> =
                    (0..n).map(|_| rng.next_i32()).collect();
                let x = Tensor::from_vec(&[n], vals);
                let xs = deal(&x, &mut rng);
                let me = &xs[ctx.id()];
                let strided =
                    msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap();
                let concat = msb_bitdecomp_concat(ctx, &me.a.data,
                                                  &me.b.data).unwrap();
                (strided, concat)
            });
            let strided: [BitShare; 3] =
                std::array::from_fn(|i| results[i].0 .0.clone());
            let concat: [BitShare; 3] =
                std::array::from_fn(|i| results[i].0 .1.clone());
            assert_eq!(reconstruct_bits(&strided),
                       reconstruct_bits(&concat), "n = {n}");
        }
    }

    #[test]
    fn bitdecomp_round_count_is_logarithmic() {
        // 1 (carry-save) + 1 (g0) + 5 (prefix levels) = 7 rounds
        let results = run3(|ctx| {
            let mut rng = Rng::new(1);
            let x = rng.tensor(&[8]);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap();
        });
        for (_, st) in &results {
            assert_eq!(st.rounds, 7, "rounds = {}", st.rounds);
        }
    }

    #[test]
    fn bitdecomp_moves_more_bytes_than_msb() {
        // the A1 ablation's headline: bytes(bit-decomp) >> bytes(Alg 3)
        let bd = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[256], 1 << 20);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap();
        });
        let ours = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[256], 1 << 20);
            let xs = deal(&x, &mut rng);
            let _ = crate::protocols::msb::msb_extract(ctx, &xs[ctx.id()])
                .unwrap();
        });
        let bytes = |r: &[( (), crate::transport::Stats)]| -> u64 {
            r.iter().map(|(_, s)| s.bytes_sent).sum()
        };
        assert!(bytes(&bd) > bytes(&ours),
                "bitdecomp {} <= ours {}", bytes(&bd), bytes(&ours));
    }
}
