//! SecureBiNN/ABY3-style MSB via boolean share conversion + Kogge-Stone
//! adder -- the bit-decomposition baseline that CBNN's Algorithm 3
//! replaces.
//!
//! x = x_0 + x_1 + x_2 (mod 2^32), each additive component known to two
//! parties, so its *bits* inject into RSS boolean sharing locally.  A
//! carry-save step reduces the three 32-bit vectors to two (1 AND round),
//! then a Kogge-Stone prefix adder produces the carry into bit 31
//! (log2(32) = 5 AND rounds).  Total: 6 communication rounds, each moving
//! O(l) bits per element -- versus Algorithm 3's constant ~7 rounds with
//! O(1) ring elements.  On WAN the round counts are comparable, but the
//! adder's rounds are *serial levels of a circuit over every element's 32
//! bits*, so its bytes and local work are ~an order of magnitude higher.
//!
//! With `BitTensor` shares the adder is word-parallel: every XOR/AND over
//! a 32n-bit plane batch is a loop over u64 words, and `and_bits` masks
//! with word-filled zero randomness -- this keeps the Table-2 baseline
//! comparison honest (the baseline is not handicapped by a byte-per-bit
//! representation CBNN itself no longer uses).

use anyhow::Result;

use crate::ring::bits::BitTensor;
use crate::rss::BitShare;
use crate::transport::Dir;

use crate::protocols::Ctx;

/// RSS boolean AND, batched: z = x & y with one reshare round (the mod-2
/// analogue of rss::mul).  Entirely word-parallel locally.
pub fn and_bits(ctx: &Ctx, x: &BitShare, y: &BitShare) -> Result<BitShare> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let cnt = ctx.seeds.next_cnt();
    // zero-sharing mod 2: r_i = F(k_{i+1}) ^ F(k_i), word-filled
    let mask = ctx.seeds.zero_bits3(cnt, n);
    let zi = x.a.and(&y.a)
        .xor(&x.a.and(&y.b))
        .xor(&x.b.and(&y.a))
        .xor(&mask);
    ctx.comm.send_bits(Dir::Prev, &zi);
    let from_next = ctx.comm.recv_bits(Dir::Next)?;
    if from_next.len() != n {
        anyhow::bail!("wire desync: peer sent {} bits, expected {n}",
                      from_next.len());
    }
    ctx.comm.round();
    Ok(BitShare { a: zi, b: from_next })
}

fn xor3(a: &BitShare, b: &BitShare, c: &BitShare) -> BitShare {
    a.xor(b).xor(c)
}

/// Inject the bits of an additive component known to two parties into RSS
/// boolean sharing (local).  `slot` is which additive component (0, 1, 2)
/// the values occupy; `vals` is Some on the two parties that know it.
/// Packing the bit-plane is the arithmetic/boolean boundary.
fn inject_bits(me: usize, slot: usize, vals: Option<&[i32]>, n: usize,
               bit: u32) -> BitShare {
    let mut out = BitShare::zeros(n);
    if let Some(v) = vals {
        let plane =
            BitTensor::from_fn(n, |i| ((v[i] as u32 >> bit) & 1) as u8);
        // P_me holds components (me, me+1): fill whichever matches `slot`
        if me == slot {
            out.a = plane.clone();
        }
        if (me + 1) % 3 == slot {
            out.b = plane;
        }
    }
    out
}

/// Full bit-decomposition MSB: returns [MSB(x)]^B.
/// `x` is the party's RSS arithmetic share (a = x_me, b = x_{me+1}).
pub fn msb_bitdecomp(ctx: &Ctx, xa: &[i32], xb: &[i32])
                     -> Result<BitShare> {
    let me = ctx.id();
    let n = xa.len();
    const L: usize = 32;

    // Boolean shares of each additive component's bit-planes.
    // component `me` known to (me, me-1)... in RSS P_i holds (x_i, x_{i+1}),
    // so component j is known to P_j (as a) and P_{j-1} (as b).
    let comp = |slot: usize, bit: u32| -> BitShare {
        let vals: Option<&[i32]> = if me == slot {
            Some(xa)
        } else if (me + 1) % 3 == slot {
            Some(xb)
        } else {
            None
        };
        inject_bits(me, slot, vals, n, bit)
    };

    // Carry-save: s = a^b^c, carry t = maj(a,b,c) = (a&b)^(a&c)^(b&c)
    // = ((a^b)&(b^c)) ^ b   [1 AND round, batched across all 32 bit-planes
    // into one word-packed 32n-bit share]
    let mut s_bits: Vec<BitShare> = Vec::with_capacity(L);
    let mut ab_all = BitShare::empty();
    let mut bc_all = BitShare::empty();
    let mut b_planes: Vec<BitShare> = Vec::with_capacity(L);
    for bit in 0..L as u32 {
        let a = comp(0, bit);
        let b = comp(1, bit);
        let c = comp(2, bit);
        s_bits.push(xor3(&a, &b, &c));
        ab_all.extend(&a.xor(&b));
        bc_all.extend(&b.xor(&c));
        b_planes.push(b);
    }
    let maj_raw = and_bits(ctx, &ab_all, &bc_all)?; // one round, 32n bits
    // t[bit] = maj ^ b, shifted left by one (carry feeds the next bit)
    let mut t_bits: Vec<BitShare> = Vec::with_capacity(L);
    t_bits.push(BitShare::zeros(n)); // t << 1
    for bit in 0..L - 1 {
        let maj = maj_raw.slice(bit * n, n);
        t_bits.push(maj.xor(&b_planes[bit]));
    }

    // Kogge-Stone prefix over (g, p): g = s&t, p = s^t
    let cat = |v: &[BitShare]| -> BitShare {
        let mut out = BitShare::empty();
        for s in v {
            out.extend(s);
        }
        out
    };
    let s_all = cat(&s_bits);
    let t_all = cat(&t_bits);
    let g0 = and_bits(ctx, &s_all, &t_all)?; // one round
    let p0 = s_all.xor(&t_all);
    let mut g: Vec<BitShare> = (0..L).map(|i| g0.slice(i * n, n)).collect();
    let mut p: Vec<BitShare> = (0..L).map(|i| p0.slice(i * n, n)).collect();
    // sum bit 31 = (s ^ t')[31] ^ carry_in(31); save it before the prefix
    // pass mutates p[31]
    let sum31_no_carry = p0.slice(31 * n, n);
    let mut dist = 1usize;
    while dist < L {
        // combine (g,p)[i] with (g,p)[i-dist] for i >= dist, batched into
        // a single AND round per level: [p_i & g_{i-dist}, p_i & p_{i-dist}]
        let idx: Vec<usize> = (dist..L).collect();
        let mut lhs = BitShare::empty();
        let mut rhs = BitShare::empty();
        for &i in &idx {
            lhs.extend(&p[i]);
            rhs.extend(&g[i - dist]);
        }
        for &i in &idx {
            lhs.extend(&p[i]);
            rhs.extend(&p[i - dist]);
        }
        let prod = and_bits(ctx, &lhs, &rhs)?; // one round per level
        let m = idx.len();
        for (j, &i) in idx.iter().enumerate() {
            let pg = prod.slice(j * n, n);
            let pp = prod.slice((m + j) * n, n);
            g[i] = g[i].xor(&pg);
            p[i] = pp;
        }
        dist *= 2;
    }
    // carry into bit 31 = G[30] (prefix generate over bits 0..30)
    Ok(sum31_no_carry.xor(&g[30]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::{self, Tensor};
    use crate::rss::{deal, deal_bits, reconstruct_bits};
    use crate::testutil::Rng;

    #[test]
    fn and_bits_is_boolean_mul() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(3);
            // non-word-aligned length exercises the packed tail
            let x: Vec<u8> = (0..77).map(|_| rng.bit()).collect();
            let y: Vec<u8> = (0..77).map(|_| rng.bit()).collect();
            let xs = deal_bits(&x, &mut rng);
            let ys = deal_bits(&y, &mut rng);
            (and_bits(ctx, &xs[ctx.id()], &ys[ctx.id()]).unwrap(), x, y)
        });
        let (_, x, y) = results[0].0.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&shares);
        for i in 0..x.len() {
            assert_eq!(got[i], x[i] & y[i]);
        }
    }

    #[test]
    fn bitdecomp_msb_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(7);
            let vals: Vec<i32> = (0..50).map(|_| rng.next_i32()).collect();
            let x = Tensor::from_vec(&[50], vals.clone());
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            (msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap(), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&shares);
        for (g, v) in got.iter().zip(&vals) {
            assert_eq!(*g, ring::msb(*v), "x = {v}");
        }
    }

    #[test]
    fn bitdecomp_round_count_is_logarithmic() {
        // 1 (carry-save) + 1 (g0) + 5 (prefix levels) = 7 rounds
        let results = run3(|ctx| {
            let mut rng = Rng::new(1);
            let x = rng.tensor(&[8]);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap();
        });
        for (_, st) in &results {
            assert_eq!(st.rounds, 7, "rounds = {}", st.rounds);
        }
    }

    #[test]
    fn bitdecomp_moves_more_bytes_than_msb() {
        // the A1 ablation's headline: bytes(bit-decomp) >> bytes(Alg 3)
        let bd = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[256], 1 << 20);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data).unwrap();
        });
        let ours = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[256], 1 << 20);
            let xs = deal(&x, &mut rng);
            let _ = crate::protocols::msb::msb_extract(ctx, &xs[ctx.id()])
                .unwrap();
        });
        let bytes = |r: &[( (), crate::transport::Stats)]| -> u64 {
            r.iter().map(|(_, s)| s.bytes_sent).sum()
        };
        assert!(bytes(&bd) > bytes(&ours),
                "bitdecomp {} <= ours {}", bytes(&bd), bytes(&ours));
    }
}
