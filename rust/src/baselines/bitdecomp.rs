//! SecureBiNN/ABY3-style MSB via boolean share conversion + Kogge-Stone
//! adder -- the bit-decomposition baseline that CBNN's Algorithm 3
//! replaces.
//!
//! x = x_0 + x_1 + x_2 (mod 2^32), each additive component known to two
//! parties, so its *bits* inject into RSS boolean sharing locally.  A
//! carry-save step reduces the three 32-bit vectors to two (1 AND round),
//! then a Kogge-Stone prefix adder produces the carry into bit 31
//! (log2(32) = 5 AND rounds).  Total: 6 communication rounds, each moving
//! O(l) bits per element -- versus Algorithm 3's constant ~7 rounds with
//! O(1) ring elements.  On WAN the round counts are comparable, but the
//! adder's rounds are *serial levels of a circuit over every element's 32
//! bits*, so its bytes and local work are ~an order of magnitude higher.

use crate::prf::{domain, PrfStream};
use crate::rss::BitShare;
use crate::transport::Dir;

use crate::protocols::Ctx;

/// RSS boolean AND, batched: z = x & y with one reshare round (the mod-2
/// analogue of rss::mul).
pub fn and_bits(ctx: &Ctx, x: &BitShare, y: &BitShare) -> BitShare {
    let n = x.len();
    let cnt = ctx.seeds.next_cnt();
    // zero-sharing mod 2: r_i = F(k_{i+1}) ^ F(k_i)
    let mut s_next = PrfStream::new(&ctx.seeds.next, cnt, domain::ZERO3);
    let mut s_mine = PrfStream::new(&ctx.seeds.mine, cnt, domain::ZERO3);
    let zi: Vec<u8> = (0..n).map(|i| {
        let mask = ((s_next.next_u32() ^ s_mine.next_u32()) & 1) as u8;
        (x.a[i] & y.a[i]) ^ (x.a[i] & y.b[i]) ^ (x.b[i] & y.a[i]) ^ mask
    }).collect();
    ctx.comm.send_bits(Dir::Prev, &zi);
    let from_next = ctx.comm.recv_bits(Dir::Next);
    ctx.comm.round();
    BitShare { a: zi, b: from_next }
}

fn xor3(a: &BitShare, b: &BitShare, c: &BitShare) -> BitShare {
    a.xor(b).xor(c)
}

/// Inject the bits of an additive component known to two parties into RSS
/// boolean sharing (local).  `slot` is which additive component (0, 1, 2)
/// the values occupy; `vals` is Some on the two parties that know it.
fn inject_bits(me: usize, slot: usize, vals: Option<&[i32]>, n: usize,
               bit: u32) -> BitShare {
    let mut a = vec![0u8; n];
    let mut b = vec![0u8; n];
    if let Some(v) = vals {
        let bits: Vec<u8> = v.iter()
            .map(|&x| ((x as u32 >> bit) & 1) as u8).collect();
        // P_me holds components (me, me+1): fill whichever matches `slot`
        if me == slot {
            a.copy_from_slice(&bits);
        }
        if (me + 1) % 3 == slot {
            b.copy_from_slice(&bits);
        }
    }
    BitShare { a, b }
}

/// Full bit-decomposition MSB: returns [MSB(x)]^B.
/// `x` is the party's RSS arithmetic share (a = x_me, b = x_{me+1}).
pub fn msb_bitdecomp(ctx: &Ctx, xa: &[i32], xb: &[i32]) -> BitShare {
    let me = ctx.id();
    let n = xa.len();
    const L: u32 = 32;

    // Boolean shares of each additive component's bit-planes.
    // component `me` known to (me, me-1)... in RSS P_i holds (x_i, x_{i+1}),
    // so component j is known to P_j (as a) and P_{j-1} (as b).
    let comp = |slot: usize, bit: u32| -> BitShare {
        let vals: Option<&[i32]> = if me == slot {
            Some(xa)
        } else if (me + 1) % 3 == slot {
            Some(xb)
        } else {
            None
        };
        inject_bits(me, slot, vals, n, bit)
    };

    // Carry-save: s = a^b^c, carry t = maj(a,b,c) = (a&b)^(a&c)^(b&c)
    // = (a^b)&(a^c) ^ a ... use ((a^b)&(b^c)) ^ b   [1 AND round, batched
    // across all 32 bit-planes]
    let mut s_bits: Vec<BitShare> = Vec::with_capacity(L as usize);
    let mut ab_all = BitShare { a: Vec::new(), b: Vec::new() };
    let mut bc_all = BitShare { a: Vec::new(), b: Vec::new() };
    let mut b_planes: Vec<BitShare> = Vec::with_capacity(L as usize);
    for bit in 0..L {
        let a = comp(0, bit);
        let b = comp(1, bit);
        let c = comp(2, bit);
        s_bits.push(xor3(&a, &b, &c));
        let ab = a.xor(&b);
        let bc = b.xor(&c);
        ab_all.a.extend_from_slice(&ab.a);
        ab_all.b.extend_from_slice(&ab.b);
        bc_all.a.extend_from_slice(&bc.a);
        bc_all.b.extend_from_slice(&bc.b);
        b_planes.push(b);
    }
    let maj_raw = and_bits(ctx, &ab_all, &bc_all); // one round, 32n bits
    // t[bit] = maj ^ b, shifted left by one (carry feeds the next bit)
    let mut t_bits: Vec<BitShare> = Vec::with_capacity(L as usize);
    t_bits.push(BitShare { a: vec![0; n], b: vec![0; n] }); // t << 1
    for bit in 0..(L - 1) {
        let off = bit as usize * n;
        let maj = BitShare {
            a: maj_raw.a[off..off + n].to_vec(),
            b: maj_raw.b[off..off + n].to_vec(),
        };
        t_bits.push(maj.xor(&b_planes[bit as usize]));
    }

    // Kogge-Stone prefix over (g, p): g = s&t, p = s^t
    let cat = |v: &[BitShare]| -> BitShare {
        let mut a = Vec::with_capacity(v.len() * n);
        let mut b = Vec::with_capacity(v.len() * n);
        for s in v {
            a.extend_from_slice(&s.a);
            b.extend_from_slice(&s.b);
        }
        BitShare { a, b }
    };
    let s_all = cat(&s_bits);
    let t_all = cat(&t_bits);
    let g0 = and_bits(ctx, &s_all, &t_all); // one round
    let p0 = s_all.xor(&t_all);
    let slice = |bs: &BitShare, i: usize| BitShare {
        a: bs.a[i * n..(i + 1) * n].to_vec(),
        b: bs.b[i * n..(i + 1) * n].to_vec(),
    };
    let mut g: Vec<BitShare> = (0..L as usize).map(|i| slice(&g0, i))
        .collect();
    let mut p: Vec<BitShare> = (0..L as usize).map(|i| slice(&p0, i))
        .collect();
    // sum bit 31 = (s ^ t')[31] ^ carry_in(31); save it before the prefix
    // pass mutates p[31]
    let sum31_no_carry = slice(&p0, 31);
    let mut dist = 1usize;
    while dist < L as usize {
        // combine (g,p)[i] with (g,p)[i-dist] for i >= dist, batched into
        // a single AND round per level: [p_i & g_{i-dist}, p_i & p_{i-dist}]
        let idx: Vec<usize> = (dist..L as usize).collect();
        let mut lhs = BitShare { a: Vec::new(), b: Vec::new() };
        let mut rhs = BitShare { a: Vec::new(), b: Vec::new() };
        for &i in &idx {
            lhs.a.extend_from_slice(&p[i].a);
            lhs.b.extend_from_slice(&p[i].b);
            rhs.a.extend_from_slice(&g[i - dist].a);
            rhs.b.extend_from_slice(&g[i - dist].b);
        }
        for &i in &idx {
            lhs.a.extend_from_slice(&p[i].a);
            lhs.b.extend_from_slice(&p[i].b);
            rhs.a.extend_from_slice(&p[i - dist].a);
            rhs.b.extend_from_slice(&p[i - dist].b);
        }
        let prod = and_bits(ctx, &lhs, &rhs); // one round per level
        let m = idx.len();
        for (j, &i) in idx.iter().enumerate() {
            let pg = BitShare {
                a: prod.a[j * n..(j + 1) * n].to_vec(),
                b: prod.b[j * n..(j + 1) * n].to_vec(),
            };
            let pp = BitShare {
                a: prod.a[(m + j) * n..(m + j + 1) * n].to_vec(),
                b: prod.b[(m + j) * n..(m + j + 1) * n].to_vec(),
            };
            g[i] = g[i].xor(&pg);
            p[i] = pp;
        }
        dist *= 2;
    }
    // carry into bit 31 = G[30] (prefix generate over bits 0..30)
    sum31_no_carry.xor(&g[30])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::{self, Tensor};
    use crate::rss::{deal, deal_bits, reconstruct_bits};
    use crate::testutil::Rng;

    #[test]
    fn and_bits_is_boolean_mul() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(3);
            let x: Vec<u8> = (0..64).map(|_| rng.bit()).collect();
            let y: Vec<u8> = (0..64).map(|_| rng.bit()).collect();
            let xs = deal_bits(&x, &mut rng);
            let ys = deal_bits(&y, &mut rng);
            (and_bits(ctx, &xs[ctx.id()], &ys[ctx.id()]), x, y)
        });
        let (_, x, y) = results[0].0.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&shares);
        for i in 0..x.len() {
            assert_eq!(got[i], x[i] & y[i]);
        }
    }

    #[test]
    fn bitdecomp_msb_matches_plaintext() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(7);
            let vals: Vec<i32> = (0..50).map(|_| rng.next_i32()).collect();
            let x = Tensor::from_vec(&[50], vals.clone());
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            (msb_bitdecomp(ctx, &me.a.data, &me.b.data), vals)
        });
        let vals = results[0].0 .1.clone();
        let shares: [BitShare; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct_bits(&shares);
        for (g, v) in got.iter().zip(&vals) {
            assert_eq!(*g, ring::msb(*v), "x = {v}");
        }
    }

    #[test]
    fn bitdecomp_round_count_is_logarithmic() {
        // 1 (carry-save) + 1 (g0) + 5 (prefix levels) = 7 rounds
        let results = run3(|ctx| {
            let mut rng = Rng::new(1);
            let x = rng.tensor(&[8]);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data);
        });
        for (_, st) in &results {
            assert_eq!(st.rounds, 7, "rounds = {}", st.rounds);
        }
    }

    #[test]
    fn bitdecomp_moves_more_bytes_than_msb() {
        // the A1 ablation's headline: bytes(bit-decomp) >> bytes(Alg 3)
        let bd = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[256], 1 << 20);
            let xs = deal(&x, &mut rng);
            let me = &xs[ctx.id()];
            let _ = msb_bitdecomp(ctx, &me.a.data, &me.b.data);
        });
        let ours = run3(|ctx| {
            let mut rng = Rng::new(2);
            let x = rng.tensor_small(&[256], 1 << 20);
            let xs = deal(&x, &mut rng);
            let _ = crate::protocols::msb::msb_extract(ctx, &xs[ctx.id()]);
        });
        let bytes = |r: &[( (), crate::transport::Stats)]| -> u64 {
            r.iter().map(|(_, s)| s.bytes_sent).sum()
        };
        assert!(bytes(&bd) > bytes(&ours),
                "bitdecomp {} <= ours {}", bytes(&bd), bytes(&ours));
    }
}
