//! Explicit (non-fused) batch normalization baseline for the A3 ablation.
//!
//! Section 3.5 folds BN into the Sign threshold or the linear layer's
//! (W, b) at export time (zero online cost).  The baseline evaluates
//! y = gamma' * x + beta' online: one RSS multiplication round plus one
//! truncation (gamma' is fixed-point) plus a local add.

use anyhow::Result;

use crate::protocols::trunc::trunc;
use crate::protocols::Ctx;
use crate::rss::{self, Share};

/// Online BN: y = (gamma' * x) >> f + beta', with gamma'/beta' secret
/// shares scaled by 2^f.  `x` is (C, N); gamma/beta are per-channel (C).
pub fn bn_online(ctx: &Ctx, x: &Share, gamma: &Share, beta: &Share,
                 f: u32) -> Result<Share> {
    let (c, n) = x.a.dims2();
    // broadcast gamma to the full shape, multiply, truncate, add beta
    let expand = |t: &crate::ring::Tensor| {
        let mut out = Vec::with_capacity(c * n);
        for ci in 0..c {
            out.extend(std::iter::repeat_n(t.data[ci], n));
        }
        crate::ring::Tensor::from_vec(&[c * n], out)
    };
    let g = Share { a: expand(&gamma.a), b: expand(&gamma.b) };
    let flat = x.clone().reshape(&[c * n]);
    let prod = rss::mul(ctx.comm, ctx.seeds, &g, &flat)?;
    let scaled = trunc(ctx, &prod, f)?;
    let b = Share { a: expand(&beta.a), b: expand(&beta.b) };
    Ok(scaled.add(&b).reshape(&[c, n]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::testsupport::run3;
    use crate::ring::Tensor;
    use crate::rss::{deal, reconstruct};
    use crate::testutil::Rng;

    #[test]
    fn bn_online_matches_plaintext() {
        let results = run3(|ctx| {
            let (c, n, f) = (3usize, 10usize, 8u32);
            let mut rng = Rng::new(14);
            let x: Vec<i32> = (0..c * n).map(|_| rng.small(1 << 12)).collect();
            let g: Vec<i32> = (0..c).map(|_| rng.small(1 << 9).abs() + 1)
                .collect();
            let b: Vec<i32> = (0..c).map(|_| rng.small(1 << 10)).collect();
            let xs = deal(&Tensor::from_vec(&[c, n], x.clone()), &mut rng);
            let gs = deal(&Tensor::from_vec(&[c], g.clone()), &mut rng);
            let bs = deal(&Tensor::from_vec(&[c], b.clone()), &mut rng);
            let y = bn_online(ctx, &xs[ctx.id()], &gs[ctx.id()],
                              &bs[ctx.id()], f).unwrap();
            (y, x, g, b)
        });
        let (_, x, g, b) = results[0].0.clone();
        let shares: [Share; 3] =
            std::array::from_fn(|i| results[i].0 .0.clone());
        let got = reconstruct(&shares);
        for ci in 0..3 {
            for j in 0..10 {
                let want = ((i64::from(g[ci]) * i64::from(x[ci * 10 + j]))
                            >> 8) as i32 + b[ci];
                let diff = (got.data[ci * 10 + j] - want).abs();
                assert!(diff <= 1, "got {} want {}", got.data[ci * 10 + j],
                        want);
            }
        }
    }

    #[test]
    fn bn_online_costs_rounds_fusion_avoids() {
        let results = run3(|ctx| {
            let mut rng = Rng::new(3);
            let xs = deal(&rng.tensor_small(&[2, 4], 100), &mut rng);
            let gs = deal(&rng.tensor_small(&[2], 50), &mut rng);
            let bs = deal(&rng.tensor_small(&[2], 50), &mut rng);
            let _ = bn_online(ctx, &xs[ctx.id()], &gs[ctx.id()],
                              &bs[ctx.id()], 4).unwrap();
        });
        // fused BN costs zero online rounds; explicit BN costs >= 3
        for (_, st) in &results {
            assert!(st.rounds >= 3);
        }
    }
}
