//! Literature rows for the comparison tables -- the paper's reported
//! numbers for frameworks we do not re-implement (Tables 1 and 3).  The
//! bench harness prints these alongside our measured rows, clearly
//! labelled `paper`; "-" entries in the paper are None here.

/// One framework row as the paper reports it.
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    pub framework: &'static str,
    pub time_lan_s: Option<f64>,
    pub time_wan_s: Option<f64>,
    pub comm_mb: Option<f64>,
    pub acc_pct: Option<f64>,
}

/// Table 1 (MNIST): rows grouped by architecture.
pub fn table1(arch: &str) -> &'static [PaperRow] {
    match arch {
        "mnistnet1" => &[
            PaperRow { framework: "ABNN2", time_lan_s: Some(1.008),
                       time_wan_s: Some(2.44), comm_mb: Some(4.33),
                       acc_pct: Some(97.6) },
            PaperRow { framework: "XONN", time_lan_s: Some(0.13),
                       time_wan_s: None, comm_mb: Some(4.29),
                       acc_pct: Some(97.6) },
            PaperRow { framework: "SecureNN", time_lan_s: Some(0.043),
                       time_wan_s: Some(2.43), comm_mb: Some(2.1),
                       acc_pct: Some(93.4) },
            PaperRow { framework: "Falcon", time_lan_s: Some(0.011),
                       time_wan_s: Some(0.99), comm_mb: Some(0.012),
                       acc_pct: Some(97.4) },
            PaperRow { framework: "SecureBiNN", time_lan_s: Some(0.010),
                       time_wan_s: Some(0.248), comm_mb: Some(0.005),
                       acc_pct: Some(97.3) },
            PaperRow { framework: "CBNN(paper)", time_lan_s: Some(0.010),
                       time_wan_s: Some(0.21), comm_mb: Some(0.010),
                       acc_pct: Some(98.11) },
        ],
        "mnistnet2" => &[
            PaperRow { framework: "XONN", time_lan_s: Some(0.16),
                       time_wan_s: None, comm_mb: Some(38.3),
                       acc_pct: Some(98.6) },
            PaperRow { framework: "SecureNN", time_lan_s: Some(0.076),
                       time_wan_s: Some(3.06), comm_mb: Some(4.05),
                       acc_pct: Some(98.8) },
            PaperRow { framework: "Falcon", time_lan_s: Some(0.009),
                       time_wan_s: Some(0.76), comm_mb: Some(0.049),
                       acc_pct: Some(97.8) },
            PaperRow { framework: "SecureBiNN", time_lan_s: Some(0.007),
                       time_wan_s: Some(0.44), comm_mb: Some(0.032),
                       acc_pct: Some(97.2) },
            PaperRow { framework: "CBNN(paper)", time_lan_s: Some(0.010),
                       time_wan_s: Some(0.32), comm_mb: Some(0.033),
                       acc_pct: Some(98.3) },
        ],
        "mnistnet3" => &[
            PaperRow { framework: "XONN", time_lan_s: Some(0.15),
                       time_wan_s: None, comm_mb: Some(32.1),
                       acc_pct: Some(99.0) },
            PaperRow { framework: "SecureNN", time_lan_s: Some(0.13),
                       time_wan_s: Some(3.93), comm_mb: Some(8.86),
                       acc_pct: Some(99.0) },
            PaperRow { framework: "Falcon", time_lan_s: Some(0.042),
                       time_wan_s: Some(3.0), comm_mb: Some(0.51),
                       acc_pct: Some(98.6) },
            PaperRow { framework: "SecureBiNN", time_lan_s: Some(0.020),
                       time_wan_s: Some(1.15), comm_mb: Some(0.357),
                       acc_pct: Some(98.4) },
            PaperRow { framework: "CBNN(paper)", time_lan_s: Some(0.015),
                       time_wan_s: Some(0.97), comm_mb: Some(0.370),
                       acc_pct: Some(99.0) },
        ],
        _ => &[],
    }
}

/// Table 3 (CIFAR-10, CifarNet2).
pub fn table3() -> &'static [PaperRow] {
    &[
        PaperRow { framework: "MiniONN", time_lan_s: Some(544.0),
                   time_wan_s: None, comm_mb: Some(9272.0),
                   acc_pct: Some(81.61) },
        PaperRow { framework: "Chameleon", time_lan_s: Some(52.67),
                   time_wan_s: None, comm_mb: Some(2650.0),
                   acc_pct: Some(81.61) },
        PaperRow { framework: "EzPC", time_lan_s: Some(265.6),
                   time_wan_s: None, comm_mb: Some(40683.0),
                   acc_pct: Some(81.61) },
        PaperRow { framework: "Gazelle", time_lan_s: Some(15.48),
                   time_wan_s: None, comm_mb: Some(1236.0),
                   acc_pct: Some(81.61) },
        PaperRow { framework: "XONN", time_lan_s: Some(5.79),
                   time_wan_s: None, comm_mb: Some(2599.0),
                   acc_pct: Some(81.85) },
        PaperRow { framework: "Falcon", time_lan_s: Some(0.79),
                   time_wan_s: Some(1.27), comm_mb: Some(13.51),
                   acc_pct: Some(81.61) },
        PaperRow { framework: "SecureBiNN", time_lan_s: Some(0.527),
                   time_wan_s: Some(3.447), comm_mb: Some(16.609),
                   acc_pct: Some(81.50) },
        PaperRow { framework: "CBNN(paper)", time_lan_s: Some(0.311),
                   time_wan_s: Some(0.871), comm_mb: Some(8.291),
                   acc_pct: Some(81.53) },
    ]
}

/// Table 2 (paper's typical-BNN vs CifarNet2 deltas).
pub struct Table2Paper {
    pub typical: PaperRow,
    pub cifarnet2: PaperRow,
    pub param_change_pct: f64,
}

pub fn table2() -> Table2Paper {
    Table2Paper {
        typical: PaperRow { framework: "Typical BNN",
                            time_lan_s: Some(0.532),
                            time_wan_s: Some(3.12), comm_mb: Some(12.58),
                            acc_pct: Some(83.52) },
        cifarnet2: PaperRow { framework: "CifarNet2",
                              time_lan_s: Some(0.311),
                              time_wan_s: Some(0.871), comm_mb: Some(8.29),
                              acc_pct: Some(81.53) },
        param_change_pct: -82.3,
    }
}

pub fn fmt_row(label: &str, lan: Option<f64>, wan: Option<f64>,
               comm: Option<f64>, acc: Option<f64>) -> String {
    let f = |v: Option<f64>, p: usize| v
        .map(|x| format!("{x:.p$}"))
        .unwrap_or_else(|| "-".to_string());
    format!("{label:<22} {:>10} {:>10} {:>10} {:>7}",
            f(lan, 3), f(wan, 3), f(comm, 3), f(acc, 2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_are_populated() {
        assert_eq!(table1("mnistnet1").len(), 6);
        assert_eq!(table1("mnistnet2").len(), 5);
        assert_eq!(table1("mnistnet3").len(), 5);
        assert_eq!(table3().len(), 8);
        assert!(table1("unknown").is_empty());
    }

    #[test]
    fn paper_claims_cbnn_wins_wan() {
        // shape check we bench against: CBNN beats SecureBiNN on WAN
        let rows = table3();
        let sb = rows.iter().find(|r| r.framework == "SecureBiNN").unwrap();
        let us = rows.iter().find(|r| r.framework == "CBNN(paper)").unwrap();
        assert!(us.time_wan_s.unwrap() < sb.time_wan_s.unwrap());
    }

    #[test]
    fn fmt_row_handles_missing() {
        let s = fmt_row("XONN", Some(0.13), None, Some(4.29), Some(97.6));
        assert!(s.contains('-') && s.contains("0.130"));
    }
}
