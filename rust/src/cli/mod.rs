//! Minimal command-line parsing (clap is not in the offline crate set).
//!
//! Supports `subcommand --flag value --bool-flag positional` style, with
//! repeatable flags (every occurrence is kept; `get` returns the last):
//!
//! ```text
//!   cbnn infer --model mnistnet3 --net wan --batch 8
//!   cbnn serve --model mnistnet1 --model tiny=path/to/tiny.manifest.json
//!   cbnn bench --table 1
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Every flag the `serve` subcommand accepts.  The single source of
/// truth for the usage string and for the OPERATIONS.md coverage check
/// (`rust/tests/docs.rs`): a flag added here without documentation
/// fails CI.
pub const SERVE_FLAGS: &[&str] = &[
    "model", "artifacts", "net", "backend", "batch", "requests",
    "prefetch", "bank-low", "bank-high", "bank-chunk", "bank-capacity",
    "max-parked-bytes", "admin", "fuse", "max-infer-errors",
    "trace-out", "metrics-out", "slo-ms", "shards", "max-queue",
    "tenants", "adaptive-bank",
];

/// Resolve an `on|off` toggle flag (`--fuse on`); absent -> `default`.
pub fn parse_on_off(args: &Args, key: &str, default: bool)
                    -> Result<bool, String> {
    match args.get(key) {
        None => Ok(default),
        Some("on") | Some("true") | Some("1") => Ok(true),
        Some("off") | Some("false") | Some("0") => Ok(false),
        Some(v) => Err(format!("--{key} expects on|off, got '{v}'")),
    }
}

/// Parsed argv: one optional subcommand, `--flag [value]` pairs (a flag
/// may repeat -- all values are kept in order), and positional tokens.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the binary name).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.push_flag(k, v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--"))
                    .unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.push_flag(name, v);
                } else {
                    out.push_flag(name, "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    fn push_flag(&mut self, key: &str, value: String) {
        self.flags.entry(key.to_string()).or_default().push(value);
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// The last occurrence of `--key` (the usual single-value accessor;
    /// last-wins matches common CLI conventions).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key)
            .and_then(|v| v.last())
            .map(String::as_str)
    }

    /// Every occurrence of `--key`, in argv order (repeatable flags
    /// like `serve --model`).
    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Resolve the shared network / backend flags into engine config pieces.
/// Accepts the named profiles (`lan|wan|zero|none`) plus the custom
/// `--net` spec grammar (`rtt=40ms,bw=40MBps,jitter=1ms[,virtual]`) --
/// see `transport::shim::parse_net_spec` for the full grammar.
pub fn parse_net(name: &str) -> Result<crate::transport::NetConfig, String> {
    crate::transport::shim::parse_net_spec(name)
}

pub fn parse_backend(name: &str) -> Result<crate::runtime::BackendKind, String> {
    use crate::runtime::{BackendKind, KernelVariant};
    match name {
        "native" => Ok(BackendKind::Native),
        "pjrt" | "pjrt-pallas" => Ok(BackendKind::Pjrt(KernelVariant::Pallas)),
        "pjrt-xla" => Ok(BackendKind::Pjrt(KernelVariant::Xla)),
        other => Err(format!(
            "unknown backend '{other}' (native|pjrt-pallas|pjrt-xla)")),
    }
}

/// Resolve the serving-bank watermark flags (`--bank-low`, `--bank-high`,
/// `--bank-chunk`, `--bank-capacity`; tuple-element counts).  `None` when
/// no flag is present -- the Service then auto-scales the bank to the
/// model's per-max-batch demand.  Omitted flags default *relative to
/// whichever flags were given* (any single flag anchors a consistent
/// config: low = high/2, chunk = high - low, capacity = high + chunk).
pub fn parse_bank(args: &Args)
                  -> Result<Option<crate::offline::BankConfig>, String> {
    let get = |k: &str| -> Result<Option<usize>, String> {
        match args.get(k) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                format!("--{k} expects an integer, got '{v}'")
            }),
        }
    };
    let low_f = get("bank-low")?;
    let high_f = get("bank-high")?;
    let chunk_f = get("bank-chunk")?;
    let cap_f = get("bank-capacity")?;
    if low_f.is_none() && high_f.is_none() && chunk_f.is_none()
        && cap_f.is_none() {
        return Ok(None);
    }
    // anchor the high watermark on whichever flag was given, then derive
    // the rest relative to it
    let high = high_f
        .or(low_f.map(|l| 2 * l.max(1)))
        .or(cap_f.map(|c| c / 2))
        .or(chunk_f.map(|c| 4 * c))
        .unwrap_or(0);
    let low = low_f.unwrap_or(high / 2);
    let chunk = chunk_f.unwrap_or_else(|| (high - low.min(high)).max(1));
    let capacity = cap_f.unwrap_or(high + chunk);
    let cfg = crate::offline::BankConfig { low, high, chunk, capacity };
    cfg.validate().map_err(|e| format!("bank flags: {e}"))?;
    Ok(Some(cfg))
}

/// Resolve the repeatable `--model` flag into `(name, manifest path)`
/// pairs, in flag order (flag order is registry slot order).  Each
/// occurrence is either
///
/// * a bare model name `NAME` -- resolved to
///   `<artifacts>/models/NAME.manifest.json`, or
/// * `NAME=PATH` -- an explicit manifest path served under alias `NAME`
///   (multi-model serving; see OPERATIONS.md).
///
/// No `--model` flag defaults to the single model `default_model`.
/// Name uniqueness is *not* checked here -- the `ModelRegistry` owns
/// that rule and reports duplicates with a typed error.
pub fn parse_models(args: &Args, artifacts: &Path, default_model: &str)
                    -> Result<Vec<(String, PathBuf)>, String> {
    let from_name = |name: &str| {
        artifacts.join("models").join(format!("{name}.manifest.json"))
    };
    let given = args.get_all("model");
    if given.is_empty() {
        return Ok(vec![(default_model.to_string(),
                        from_name(default_model))]);
    }
    let mut out = Vec::with_capacity(given.len());
    for spec in given {
        let (name, path) = match spec.split_once('=') {
            Some((n, p)) => (n, PathBuf::from(p)),
            None => (spec.as_str(), from_name(spec)),
        };
        if name.is_empty() {
            return Err(format!(
                "--model '{spec}': model name must be non-empty"));
        }
        if path.as_os_str().is_empty() {
            return Err(format!(
                "--model '{spec}': manifest path must be non-empty"));
        }
        out.push((name.to_string(), path));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = parse(&["infer", "extra", "--model", "mnistnet3",
                        "--net=wan", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("infer"));
        assert_eq!(a.get("model"), Some("mnistnet3"));
        assert_eq!(a.get("net"), Some("wan"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
        // a flag immediately followed by a non-flag token consumes it
        let b = parse(&["x", "--flag", "value"]);
        assert_eq!(b.get("flag"), Some("value"));
    }

    #[test]
    fn repeated_flags_keep_every_occurrence_in_order() {
        let a = parse(&["serve", "--model", "a", "--batch", "4",
                        "--model=b=path/b.json", "--model", "c"]);
        assert_eq!(a.get_all("model"),
                   &["a".to_string(), "b=path/b.json".into(), "c".into()]);
        // single-value accessors see the last occurrence
        assert_eq!(a.get("model"), Some("c"));
        assert_eq!(a.get_all("batch"), &["4".to_string()]);
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn model_specs_resolve_names_and_paths() {
        let art = Path::new("arts");
        // default when no flag is given
        let specs = parse_models(&parse(&["serve"]), art, "mnistnet1")
            .unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].0, "mnistnet1");
        assert_eq!(specs[0].1,
                   Path::new("arts/models/mnistnet1.manifest.json"));
        // bare names and name=path aliases, in flag order
        let specs = parse_models(
            &parse(&["serve", "--model", "mnistnet3",
                     "--model", "tiny=custom/tiny.json"]),
            art, "mnistnet1").unwrap();
        assert_eq!(specs[0].0, "mnistnet3");
        assert_eq!(specs[0].1,
                   Path::new("arts/models/mnistnet3.manifest.json"));
        assert_eq!(specs[1], ("tiny".to_string(),
                              PathBuf::from("custom/tiny.json")));
        // malformed occurrences are rejected with the offending spec
        for bad in ["=path.json", "name="] {
            let err = parse_models(
                &parse(&["serve", "--model", bad]), art, "m")
                .unwrap_err();
            assert!(err.contains(bad), "{err}");
        }
    }

    #[test]
    fn on_off_flags_resolve() {
        let a = parse(&["serve", "--fuse", "on"]);
        assert!(parse_on_off(&a, "fuse", false).unwrap());
        let b = parse(&["serve", "--fuse", "off"]);
        assert!(!parse_on_off(&b, "fuse", true).unwrap());
        let c = parse(&["serve"]);
        assert!(!parse_on_off(&c, "fuse", false).unwrap());
        assert!(parse_on_off(&c, "fuse", true).unwrap());
        let bad = parse(&["serve", "--fuse", "sideways"]);
        assert!(parse_on_off(&bad, "fuse", false).is_err());
    }

    #[test]
    fn usize_parsing() {
        let a = parse(&["x", "--batch", "16"]);
        assert_eq!(a.get_usize("batch", 1).unwrap(), 16);
        assert_eq!(a.get_usize("missing", 4).unwrap(), 4);
        let bad = parse(&["x", "--batch", "soup"]);
        assert!(bad.get_usize("batch", 1).is_err());
    }

    #[test]
    fn net_and_backend_resolution() {
        assert!(parse_net("lan").is_ok());
        assert!(parse_net("dsl").is_err());
        // custom WAN specs route through transport::shim
        let net = parse_net("rtt=40ms,bw=40MBps,virtual").unwrap();
        assert_eq!(net.latency, std::time::Duration::from_millis(20));
        assert!(net.virtual_clock);
        assert!(parse_net("rtt=40").is_err());
        assert!(parse_backend("pjrt-pallas").is_ok());
        assert!(parse_backend("gpu").is_err());
    }

    #[test]
    fn bank_flags_resolve_with_relative_defaults() {
        // no flags: auto-scaling (None)
        assert_eq!(parse_bank(&parse(&["serve"])).unwrap().map(|_| ()),
                   None);
        // one flag: the rest default relative to it and validate
        let cfg = parse_bank(&parse(&["serve", "--bank-low", "100"]))
            .unwrap().unwrap();
        assert_eq!(cfg.low, 100);
        assert_eq!(cfg.high, 200);
        assert_eq!(cfg.chunk, 100);
        assert_eq!(cfg.capacity, 300);
        assert!(cfg.validate().is_ok());
        // every single-flag anchor yields a valid config (the defaults
        // are relative, not absolute)
        for flags in [["serve", "--bank-high", "500"],
                      ["serve", "--bank-capacity", "2000"],
                      ["serve", "--bank-chunk", "50"]] {
            let cfg = parse_bank(&parse(&flags)).unwrap().unwrap();
            assert!(cfg.validate().is_ok(), "{flags:?} -> {cfg:?}");
        }
        let cfg = parse_bank(&parse(&["serve", "--bank-high", "500"]))
            .unwrap().unwrap();
        assert_eq!((cfg.low, cfg.high), (250, 500));
        // explicit contradiction is rejected
        let bad = parse_bank(&parse(&["serve", "--bank-low", "10",
                                      "--bank-high", "5"]));
        assert!(bad.is_err());
        // non-integers are rejected
        assert!(parse_bank(&parse(&["serve", "--bank-chunk", "soup"]))
                .is_err());
    }
}
