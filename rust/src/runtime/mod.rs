//! PJRT runtime: loads the AOT artifacts (HLO text lowered from the L1
//! Pallas kernels by `python/compile/aot.py`) and executes them on the
//! request path.
//!
//! One `PjrtRuntime` per party thread (the PJRT CPU client is not shared
//! across parties); executables are compiled once per (layer-shape,
//! variant) and cached.  When an artifact is missing the backend falls
//! back to the native rust contraction, so unit tests run without
//! `make artifacts` -- the integration tests assert the artifacts are
//! actually exercised.

//! When built without the `pjrt` feature (the default, registry-free
//! build), only the kind/variant types and `make_backend` are compiled;
//! requesting a PJRT backend then fails with a clear error and callers
//! keep the native path.

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::Result;

#[cfg(feature = "pjrt")]
use crate::protocols::linear::NativeBackend;
use crate::protocols::linear::LinearBackend;
#[cfg(feature = "pjrt")]
use crate::ring::Tensor;

/// Which lowering of the RSS contraction to execute (ablation A4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// Lowered from the Pallas kernel (interpret=True -> plain HLO).
    Pallas,
    /// Lowered from the jnp reference ops.
    Xla,
}

impl KernelVariant {
    pub fn suffix(&self) -> &'static str {
        match self {
            KernelVariant::Pallas => "pallas",
            KernelVariant::Xla => "xla",
        }
    }
}

/// Cached-executable PJRT backend for the Algorithm-2 local contraction.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    hlo_dir: PathBuf,
    variant: KernelVariant,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    native: NativeBackend,
    /// count of layer executions that went through PJRT vs fell back
    pub pjrt_execs: std::cell::Cell<u64>,
    pub native_fallbacks: std::cell::Cell<u64>,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    pub fn new(hlo_dir: impl Into<PathBuf>, variant: KernelVariant)
               -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(PjrtRuntime {
            client,
            hlo_dir: hlo_dir.into(),
            variant,
            cache: RefCell::new(HashMap::new()),
            native: NativeBackend,
            pjrt_execs: std::cell::Cell::new(0),
            native_fallbacks: std::cell::Cell::new(0),
        })
    }

    fn executable(&self, key: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(e.clone());
        }
        let path = self.hlo_dir
            .join(format!("{key}.{}.hlo.txt", self.variant.suffix()));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)
            .with_context(|| format!("compiling {key}"))?);
        self.cache.borrow_mut().insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every HLO the model references (avoids first-request
    /// latency spikes; called by the coordinator at session setup).
    pub fn precompile(&self, keys: impl IntoIterator<Item = String>)
                      -> Result<()> {
        for k in keys {
            let _ = self.executable(&k)?;
        }
        Ok(())
    }

    fn lit(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
    }

    fn run(&self, key: &str, args: &[xla::Literal], out_shape: &[usize])
           -> Result<Tensor> {
        let exe = self.executable(key)?;
        let result = exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let data = out.to_vec::<i32>()?;
        self.pjrt_execs.set(self.pjrt_execs.get() + 1);
        Ok(Tensor::from_vec(out_shape, data))
    }
}

#[cfg(feature = "pjrt")]
impl LinearBackend for PjrtRuntime {
    fn warmup(&self, keys: &[String]) {
        let _ = self.precompile(keys.iter().cloned());
    }

    fn rss_matmul(&self, key: &str, wa: &Tensor, wb: &Tensor, xa: &Tensor,
                  xb: &Tensor, ba: Option<&Tensor>) -> Tensor {
        let (m, _k) = wa.dims2();
        let (_, n) = xa.dims2();
        let zero_b;
        let b2 = match ba {
            Some(b) => b.clone().reshape(&[m, 1]),
            None => {
                zero_b = Tensor::zeros(&[m, 1]);
                zero_b.clone()
            }
        };
        let attempt = (|| -> Result<Tensor> {
            let args = [Self::lit(wa)?, Self::lit(wb)?, Self::lit(xa)?,
                        Self::lit(xb)?, Self::lit(&b2)?];
            self.run(key, &args, &[m, n])
        })();
        match attempt {
            Ok(t) => t,
            Err(_) => {
                self.native_fallbacks.set(self.native_fallbacks.get() + 1);
                self.native.rss_matmul(key, wa, wb, xa, xb, ba)
            }
        }
    }

    fn rss_depthwise(&self, key: &str, wa: &Tensor, wb: &Tensor,
                     xa: &Tensor, xb: &Tensor,
                     geom: (usize, usize, usize, usize, usize, usize, usize))
                     -> Tensor {
        let (c, h, w, k, stride, pad_lo, pad_hi) = geom;
        let oh = (h + pad_lo + pad_hi - k) / stride + 1;
        let ow = (w + pad_lo + pad_hi - k) / stride + 1;
        let attempt = (|| -> Result<Tensor> {
            // HLO expects w as HWIO (k,k,1,C) and x as NCHW (1,C,H,W);
            // our pool layout is w (C, k*k) row-major and x (C, H*W).
            let to_hwio = |t: &Tensor| {
                let mut d = vec![0i32; k * k * c];
                for ci in 0..c {
                    for kk in 0..k * k {
                        d[kk * c + ci] = t.data[ci * k * k + kk];
                    }
                }
                Tensor::from_vec(&[k, k, 1, c], d)
            };
            let args = [
                Self::lit(&to_hwio(wa))?,
                Self::lit(&to_hwio(wb))?,
                Self::lit(&xa.clone().reshape(&[1, c, h, w]))?,
                Self::lit(&xb.clone().reshape(&[1, c, h, w]))?,
            ];
            self.run(key, &args, &[c, oh * ow])
        })();
        match attempt {
            Ok(t) => t,
            Err(_) => {
                self.native_fallbacks.set(self.native_fallbacks.get() + 1);
                crate::protocols::linear::native_depthwise(
                    wa, wb, xa, xb, geom)
            }
        }
    }
}

/// Backend selection for a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Pjrt(KernelVariant),
}

/// Instantiate the backend for one party thread.
pub fn make_backend(kind: BackendKind, hlo_dir: &std::path::Path)
                    -> Result<Box<dyn LinearBackend>> {
    let _ = hlo_dir;
    Ok(match kind {
        BackendKind::Native =>
            Box::new(crate::protocols::linear::NativeBackend),
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt(v) => Box::new(PjrtRuntime::new(hlo_dir, v)?),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt(_) => anyhow::bail!(
            "cbnn was built without the `pjrt` feature; rebuild with \
             --features pjrt (and a real vendor/xla) or use the native \
             backend"),
    })
}

#[cfg(test)]
mod tests {
    #[allow(unused_imports)]
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_backend_requires_the_feature() {
        let err = make_backend(BackendKind::Pjrt(KernelVariant::Pallas),
                               std::path::Path::new("/nonexistent"))
            .unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        // the native path is unaffected
        assert!(make_backend(BackendKind::Native,
                             std::path::Path::new("/nonexistent")).is_ok());
    }

    #[cfg(feature = "pjrt")]
    use crate::testutil::Rng;

    #[cfg(feature = "pjrt")]
    #[test]
    fn missing_artifact_falls_back_to_native() {
        let rt = PjrtRuntime::new("/nonexistent", KernelVariant::Xla)
            .expect("client");
        let mut rng = Rng::new(1);
        let wa = rng.tensor_small(&[3, 4], 100);
        let wb = rng.tensor_small(&[3, 4], 100);
        let xa = rng.tensor_small(&[4, 2], 100);
        let xb = rng.tensor_small(&[4, 2], 100);
        let z = rt.rss_matmul("nope", &wa, &wb, &xa, &xb, None);
        let want = NativeBackend.rss_matmul("nope", &wa, &wb, &xa, &xb, None);
        assert_eq!(z, want);
        assert_eq!(rt.native_fallbacks.get(), 1);
        assert_eq!(rt.pjrt_execs.get(), 0);
    }
}
