//! Three-party property-test harness: run the same protocol closure on
//! three in-memory party threads with deterministic seeds, collect every
//! party's output and comm stats, and let the caller reconstruct against
//! a plaintext reference.
//!
//! Unlike the old `protocols::testsupport::run3` (which this now backs),
//! the harness uses scoped threads, so closures may borrow test-local
//! state (lengths, value tables) instead of being `'static + Copy` --
//! which is what makes table-driven property tests over edge lengths
//! ergonomic.

use crate::prf::PartySeeds;
use crate::protocols::Ctx;
use crate::testutil::Rng;
use crate::transport::{local_trio, NetConfig, Stats};

/// Run `f` as all three parties of one session over in-memory channels.
/// `session` seeds the correlated PRF randomness deterministically;
/// results come back in party order with each party's comm stats.
pub fn run3_seeded<F, R>(session: u64, f: F) -> Vec<(R, Stats)>
where
    F: Fn(&Ctx) -> R + Send + Sync,
    R: Send,
{
    run3_seeded_net(session, NetConfig::zero(), f)
}

/// `run3_seeded` over a conditioned network (see `transport::shim`):
/// the WAN-soak tests pass a virtual-clock `NetConfig` and read each
/// party's `Comm::virtual_now` inside the closure.
pub fn run3_seeded_net<F, R>(session: u64, net: NetConfig, f: F)
                             -> Vec<(R, Stats)>
where
    F: Fn(&Ctx) -> R + Send + Sync,
    R: Send,
{
    let comms = local_trio(net);
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = comms.into_iter().map(|c| {
            scope.spawn(move || {
                let seeds = PartySeeds::setup(session, c.id);
                let ctx = Ctx::new(&c, &seeds);
                let r = f(&ctx);
                (r, c.stats())
            })
        }).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// The lengths every randomized protocol test sweeps: word-boundary
/// stragglers plus a four-digit batch.
pub const EDGE_LENGTHS: [usize; 5] = [1, 63, 64, 65, 1000];

/// A bounded-input value table for the masked protocols: the edge cases
/// {0, 1, -1, 2^bound_bits - 1, -(2^bound_bits - 1)} up front, dense
/// seeded-random filler (within the bound) behind them.
pub fn edge_values(rng: &mut Rng, n: usize, bound_bits: u32) -> Vec<i32> {
    let max = (1i32 << bound_bits) - 1;
    let specials = [0, 1, -1, max, -max];
    (0..n).map(|i| {
        if i < specials.len() {
            specials[i]
        } else {
            rng.small(max)
        }
    }).collect()
}

/// A bit table with forced all-zero/all-one prefixes plus random filler.
pub fn edge_bits(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|i| match i {
        0 => 0,
        1 => 1,
        _ => rng.bit(),
    }).collect()
}

/// A tiny model manifest exercising every `Op` variant: Matmul(conv),
/// Sign, PoolBits, Pm1, Depthwise, Flatten, Matmul(fc), Relu.  Used by
/// the engine/coordinator tests that need a real layer program without
/// exported artifacts.
pub fn every_op_model() -> crate::nn::Model {
    every_op_model_variant("everyop", 0)
}

/// `every_op_model` with a distinct name and weight pool (values
/// rotated by `shift`): a cheap *second* model for multi-model serving
/// tests -- same program structure and demand, different parameters, so
/// two registry entries compute visibly different functions.  `shift`
/// must keep every sign-flip entry non-zero; 3 does (asserted by a
/// test).
pub fn every_op_model_variant(name: &str, shift: usize)
                              -> crate::nn::Model {
    let manifest = r#"{
      "name": "everyop", "dataset": "synthetic",
      "input": {"c": 1, "h": 6, "w": 6},
      "s_in": 0, "ring_bits": 32,
      "layers": [
        {"op": "matmul", "conv": true, "m": 2, "kdim": 9, "n": 16,
         "k": 3, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
         "w": {"off": 0, "len": 18}, "b": {"off": 18, "len": 2},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 2, "t": {"off": 20, "len": 2},
         "flip": {"off": 22, "len": 2}},
        {"op": "pool_bits", "c": 2, "k": 2, "stride": 2},
        {"op": "pm1"},
        {"op": "depthwise", "cout": 2, "k": 1, "stride": 1,
         "pad_lo": 0, "pad_hi": 0, "w": {"off": 24, "len": 2},
         "s_in": 0, "s_out": 0},
        {"op": "flatten", "c": 2, "h": 2, "w": 2},
        {"op": "matmul", "conv": false, "m": 3, "kdim": 8, "n": 1,
         "w": {"off": 26, "len": 24}, "b": {"off": 50, "len": 3},
         "s_in": 0, "s_out": 0},
        {"op": "relu", "trunc": 2}
      ]
    }"#.replace("\"everyop\"", &format!("{name:?}"));
    // small deterministic weights; values only need to stay inside the
    // MSB bound
    let pool: Vec<i32> =
        (0..53).map(|v| ((v + shift as i32) % 7) - 3).collect();
    crate::nn::Model::from_json(&manifest, pool).unwrap()
}

/// A zoo-shaped depthwise-separable binary chain (manifest v2): a
/// fixed-point stem conv, then sign -> pool -> pm1 -> depthwise(+-1) ->
/// pointwise(+-1) -> sign -> pm1 -> flatten -> binary fc -> sign -> pm1
/// -> fixed-point logits fc.  Miniature of the exported lenet5/vgg7
/// layer mix: every hidden linear layer is binary (fusable to
/// XNOR+popcount), first and last stay fixed point.  Used by the
/// fusion property tests and the zoo bench tier.
pub fn sep_chain_model() -> crate::nn::Model {
    let manifest = r#"{
      "name": "sepchain", "dataset": "synthetic", "version": 2,
      "input": {"c": 1, "h": 8, "w": 8},
      "s_in": 0, "ring_bits": 32,
      "layers": [
        {"op": "matmul", "conv": true, "m": 3, "kdim": 9, "n": 36,
         "k": 3, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 3,
         "w": {"off": 0, "len": 27}, "b": {"off": 27, "len": 3},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 3, "t": {"off": 30, "len": 3},
         "flip": {"off": 33, "len": 3}},
        {"op": "pool_bits", "c": 3, "k": 2, "stride": 2},
        {"op": "pm1"},
        {"op": "depthwise", "cout": 3, "k": 2, "stride": 1,
         "pad_lo": 0, "pad_hi": 0, "binary": true,
         "w": {"off": 36, "len": 12}, "s_in": 0, "s_out": 0},
        {"op": "matmul", "conv": true, "m": 4, "kdim": 3, "n": 4,
         "k": 1, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 4,
         "binary": true, "w": {"off": 48, "len": 12},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 4, "t": {"off": 60, "len": 4},
         "flip": {"off": 64, "len": 4}},
        {"op": "pm1"},
        {"op": "flatten", "c": 4, "h": 2, "w": 2},
        {"op": "matmul", "conv": false, "m": 6, "kdim": 16, "n": 1,
         "binary": true, "w": {"off": 68, "len": 96},
         "s_in": 0, "s_out": 0},
        {"op": "sign", "c": 6, "t": {"off": 164, "len": 6},
         "flip": {"off": 170, "len": 6}},
        {"op": "pm1"},
        {"op": "matmul", "conv": false, "m": 4, "kdim": 6, "n": 1,
         "w": {"off": 176, "len": 24}, "b": {"off": 200, "len": 4},
         "s_in": 0, "s_out": 0}
      ]
    }"#;
    let mut pool: Vec<i32> = (0..204).map(|v| (v % 7) - 3).collect();
    // binary weight planes must be exact {-1,+1}
    for i in (36..60).chain(68..164) {
        pool[i] = if (i * 7 + 3) % 3 == 0 { -1 } else { 1 };
    }
    // sign flips are +-1 orientation bits
    for i in (33..36).chain(64..68).chain(170..176) {
        pool[i] = if i % 2 == 0 { 1 } else { -1 };
    }
    crate::nn::Model::from_json(manifest, pool).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::Dir;

    #[test]
    fn harness_borrows_and_orders_parties() {
        // closures may borrow test-local state (no 'static bound)
        let table: Vec<i32> = vec![10, 20, 30];
        let results = run3_seeded(1, |ctx| {
            ctx.comm.send_elems(Dir::Next, &[table[ctx.id()]]).unwrap();
            let got = ctx.comm.recv_elems(Dir::Prev).unwrap();
            ctx.comm.round();
            (ctx.id(), got[0])
        });
        for (i, ((id, from_prev), stats)) in results.iter().enumerate() {
            assert_eq!(*id, i, "party order");
            assert_eq!(*from_prev, table[(i + 2) % 3]);
            assert_eq!(stats.rounds, 1);
        }
    }

    #[test]
    fn edge_tables_hit_the_corners() {
        let mut rng = Rng::new(0);
        let v = edge_values(&mut rng, 100, 24);
        let max = (1 << 24) - 1;
        assert_eq!(&v[..5], &[0, 1, -1, max, -max]);
        assert!(v.iter().all(|&x| x.abs() <= max));
        let b = edge_bits(&mut rng, 10);
        assert_eq!(b[0], 0);
        assert_eq!(b[1], 1);
        assert!(b.iter().all(|&x| x <= 1));
    }

    #[test]
    fn every_op_model_loads() {
        let m = every_op_model();
        assert_eq!(m.ops.len(), 8);
    }

    #[test]
    fn model_variant_renames_and_reweights() {
        let a = every_op_model();
        let b = every_op_model_variant("everyop-b", 3);
        assert_eq!(a.name, "everyop");
        assert_eq!(b.name, "everyop-b");
        assert_eq!(a.ops.len(), b.ops.len(), "same program structure");
        // the shift-3 pool keeps the sign flips (pool[22..24]) non-zero
        let flips = b.tensor(crate::nn::PoolRef { off: 22, len: 2 },
                             &[2]);
        assert!(flips.data.iter().all(|&f| f != 0), "{:?}", flips.data);
    }
}
