//! Deterministic test RNG + a tiny property-testing harness.
//!
//! proptest is not in the offline crate set (see DESIGN.md substitutions),
//! so invariants are exercised with a seeded xoshiro generator and a
//! `prop(n, |rng| ...)` loop that reports the failing iteration's seed.
//! `threeparty` adds the secure-protocol harness: the same closure run as
//! all three parties over in-memory channels, with edge-case input
//! tables for the randomized round-trip tests.

pub mod threeparty;

use crate::ring::Tensor;

/// xoshiro256** -- small, fast, deterministic; NOT cryptographic (the
/// protocol randomness uses prf::ChaCha20 instead).
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_i32(&mut self) -> i32 {
        self.next_u64() as i32
    }

    /// Uniform in [lo, hi) -- panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + (self.next_u64() as usize) % (hi - lo)
    }

    /// Small signed value in [-bound, bound].
    pub fn small(&mut self, bound: i32) -> i32 {
        (self.next_u64() % (2 * bound as u64 + 1)) as i32 - bound
    }

    pub fn bit(&mut self) -> u8 {
        (self.next_u64() & 1) as u8
    }

    pub fn tensor(&mut self, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        let data = (0..n).map(|_| self.next_i32()).collect();
        Tensor::from_vec(shape, data)
    }

    pub fn tensor_small(&mut self, shape: &[usize], bound: i32) -> Tensor {
        let n = shape.iter().product();
        let data = (0..n).map(|_| self.small(bound)).collect();
        Tensor::from_vec(shape, data)
    }
}

/// Run `f` against `n` independently-seeded RNGs; on panic the failing
/// seed is printed so the case can be replayed.
pub fn prop(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds_hold() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let v = rng.range(3, 9);
            assert!((3..9).contains(&v));
        }
    }
}
