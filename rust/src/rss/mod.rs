//! Replicated secret sharing (Araki et al.) over ring tensors.
//!
//! Party `P_i` holds the pair `(x_i, x_{i+1})` of the additive
//! decomposition `x = x_0 + x_1 + x_2 (mod 2^32)`.  Local operations
//! (addition, constant ops, the Algorithm-2 linear contraction) never
//! communicate; multiplication and resharing use one ring message to the
//! previous party, masked by 3-out-of-3 zero randomness.
//!
//! Boolean shares (`BitShare`) use the same replication structure mod 2,
//! with both components stored as word-packed `ring::bits::BitTensor`s:
//! XOR/AND/NOT are word-parallel, and pack/unpack to per-bit vectors
//! happens only at the plaintext boundary (dealing and reconstruction).
//!
//! Interactive pieces return `Result` -- received lengths come from the
//! peer and are validated, never asserted (transport hardening).

use crate::prf::PartySeeds;
use crate::ring::bits::BitTensor;
use crate::ring::planes::{BitPlanes, PlanesView};
use crate::ring::{Elem, Tensor};
use crate::transport::{Comm, Dir, WireError};

/// One party's RSS share of a tensor: `a = x_i`, `b = x_{i+1}`.
#[derive(Clone, Debug, PartialEq)]
pub struct Share {
    pub a: Tensor,
    pub b: Tensor,
}

/// One party's RSS share of a bit tensor (mod 2): `a = y_i`, `b = y_{i+1}`,
/// both word-packed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitShare {
    pub a: BitTensor,
    pub b: BitTensor,
}

impl Share {
    pub fn zeros(shape: &[usize]) -> Self {
        Share { a: Tensor::zeros(shape), b: Tensor::zeros(shape) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.a.shape
    }

    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    // ---- local ring ops -------------------------------------------------
    pub fn add(&self, rhs: &Share) -> Share {
        Share { a: self.a.add(&rhs.a), b: self.b.add(&rhs.b) }
    }

    pub fn sub(&self, rhs: &Share) -> Share {
        Share { a: self.a.sub(&rhs.a), b: self.b.sub(&rhs.b) }
    }

    pub fn neg(&self) -> Share {
        Share { a: self.a.neg(), b: self.b.neg() }
    }

    /// Multiply by a public constant.
    pub fn scale(&self, c: Elem) -> Share {
        Share { a: self.a.scale(c), b: self.b.scale(c) }
    }

    /// Add a public constant to the shared value: the constant is folded
    /// into the `x_0` component, held by P0 (as `a`) and P2 (as `b`).
    pub fn add_const(&self, party: usize, c: Elem) -> Share {
        let mut out = self.clone();
        if party == 0 {
            out.a = out.a.add_const(c);
        }
        if party == 2 {
            out.b = out.b.add_const(c);
        }
        out
    }

    /// Elementwise affine map 2x - 1 (bits -> {-1,+1}), local.
    pub fn pm1(&self, party: usize) -> Share {
        self.scale(2).add_const(party, -1)
    }

    pub fn reshape(self, shape: &[usize]) -> Share {
        Share { a: self.a.reshape(shape), b: self.b.reshape(shape) }
    }
}

impl BitShare {
    /// The zero-length share (concatenation identity).
    pub fn empty() -> BitShare {
        BitShare { a: BitTensor::zeros(0), b: BitTensor::zeros(0) }
    }

    pub fn zeros(n: usize) -> BitShare {
        BitShare { a: BitTensor::zeros(n), b: BitTensor::zeros(n) }
    }

    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Word-parallel share XOR (local).
    pub fn xor(&self, rhs: &BitShare) -> BitShare {
        BitShare { a: self.a.xor(&rhs.a), b: self.b.xor(&rhs.b) }
    }

    /// XOR with a public bit vector (folded into the y_0 component).
    pub fn xor_const(&self, party: usize, bits: &BitTensor) -> BitShare {
        let mut out = self.clone();
        if party == 0 {
            out.a.xor_assign(bits);
        }
        if party == 2 {
            out.b.xor_assign(bits);
        }
        out
    }

    /// Local NOT of the shared bits: XOR with the public all-ones vector.
    pub fn not(&self, party: usize) -> BitShare {
        self.xor_const(party, &BitTensor::ones(self.len()))
    }

    /// Append `other`'s bits after this share's (both components).
    pub fn extend(&mut self, other: &BitShare) {
        self.a.extend(&other.a);
        self.b.extend(&other.b);
    }

    /// Copy out bits `[start, start + len)` of both components.
    pub fn slice(&self, start: usize, len: usize) -> BitShare {
        BitShare { a: self.a.slice(start, len), b: self.b.slice(start, len) }
    }
}

/// One party's RSS share of a whole bit-plane matrix: both components are
/// strided `BitPlanes`, so plane-range operands of the boolean adder
/// circuits are zero-copy row selections (see `ring::planes`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlaneShare {
    pub a: BitPlanes,
    pub b: BitPlanes,
}

/// A borrowed, row-remapped window over a `PlaneShare` (both components
/// share the same remap).  Copy-cheap: two pointers + a range.
#[derive(Clone, Copy)]
pub struct PlaneShareView<'a> {
    pub a: PlanesView<'a>,
    pub b: PlanesView<'a>,
}

impl PlaneShare {
    pub fn zeros(planes: usize, len: usize) -> PlaneShare {
        PlaneShare {
            a: BitPlanes::zeros(planes, len),
            b: BitPlanes::zeros(planes, len),
        }
    }

    pub fn planes(&self) -> usize {
        self.a.planes()
    }

    /// Bits per plane.
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// Whole-matrix share XOR (local, word-parallel).
    pub fn xor(&self, rhs: &PlaneShare) -> PlaneShare {
        PlaneShare { a: self.a.xor(&rhs.a), b: self.b.xor(&rhs.b) }
    }

    /// Copy one plane out as a 1-plane `BitShare` (the wire/share type).
    pub fn plane(&self, p: usize) -> BitShare {
        BitShare { a: self.a.plane(p), b: self.b.plane(p) }
    }

    pub fn view(&self) -> PlaneShareView<'_> {
        PlaneShareView { a: self.a.view(), b: self.b.view() }
    }

    /// Zero-copy contiguous plane-range selection.
    pub fn rows(&self, r: std::ops::Range<usize>) -> PlaneShareView<'_> {
        PlaneShareView { a: self.a.rows(r.clone()), b: self.b.rows(r) }
    }

    /// Zero-copy level shift: row `r` reads row `r - dist` (zero below).
    pub fn shifted(&self, dist: usize) -> PlaneShareView<'_> {
        PlaneShareView {
            a: self.a.shift_planes(dist),
            b: self.b.shift_planes(dist),
        }
    }

    /// `self[dst_start..][..k] ^= src[src_rows]`, both components,
    /// word-parallel over the contiguous row blocks.
    pub fn xor_rows_from(&mut self, dst_start: usize, src: &PlaneShare,
                         src_rows: std::ops::Range<usize>) {
        self.a.xor_rows_from(dst_start, &src.a, src_rows.clone());
        self.b.xor_rows_from(dst_start, &src.b, src_rows);
    }

    /// `self[dst_start..][..k] = src[src_rows]`, both components (one
    /// word-aligned memcpy each).
    pub fn copy_rows_from(&mut self, dst_start: usize, src: &PlaneShare,
                          src_rows: std::ops::Range<usize>) {
        self.a.copy_rows_from(dst_start, &src.a, src_rows.clone());
        self.b.copy_rows_from(dst_start, &src.b, src_rows);
    }
}

impl<'a> PlaneShareView<'a> {
    pub fn count(&self) -> usize {
        self.a.count()
    }

    /// Bits per plane.
    pub fn len(&self) -> usize {
        self.a.len()
    }

    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }

    /// `self ^ rhs`, materialized into a fresh share.
    pub fn xor(&self, rhs: &PlaneShareView<'_>) -> PlaneShare {
        PlaneShare { a: self.a.xor(&rhs.a), b: self.b.xor(&rhs.b) }
    }
}

// -------------------------------------------------------------------------
// dealer-style sharing (tests, model loading on the owner)
// -------------------------------------------------------------------------
/// Split a plaintext tensor into the three parties' shares using a seeded
/// RNG (trusted-dealer form used by tests; the engine's input sharing
/// protocol produces the same structure interactively).
pub fn deal(x: &Tensor, rng: &mut crate::testutil::Rng) -> [Share; 3] {
    let n = x.len();
    let x1: Vec<Elem> = (0..n).map(|_| rng.next_i32()).collect();
    let x2: Vec<Elem> = (0..n).map(|_| rng.next_i32()).collect();
    let x0: Vec<Elem> = (0..n).map(|i| {
        x.data[i].wrapping_sub(x1[i]).wrapping_sub(x2[i])
    }).collect();
    let t = |v: &Vec<Elem>| Tensor::from_vec(&x.shape, v.clone());
    [
        Share { a: t(&x0), b: t(&x1) },
        Share { a: t(&x1), b: t(&x2) },
        Share { a: t(&x2), b: t(&x0) },
    ]
}

/// Deal a plaintext bit vector into RSS bit shares (plaintext boundary:
/// packs once, then all share structure is word-wise).
pub fn deal_bits(bits: &[u8], rng: &mut crate::testutil::Rng)
                 -> [BitShare; 3] {
    let y1 = BitTensor::from_fn(bits.len(), |_| rng.bit());
    let y2 = BitTensor::from_fn(bits.len(), |_| rng.bit());
    let y0 = BitTensor::from_bits(bits).xor(&y1).xor(&y2);
    [
        BitShare { a: y0.clone(), b: y1.clone() },
        BitShare { a: y1, b: y2.clone() },
        BitShare { a: y2, b: y0 },
    ]
}

/// Reconstruct from all three shares (test helper).
pub fn reconstruct(shares: &[Share; 3]) -> Tensor {
    let mut out = shares[0].a.clone();
    out.add_assign(&shares[1].a);
    out.add_assign(&shares[2].a);
    out
}

/// Reconstruct a shared bit vector (plaintext boundary: one word-wise XOR,
/// then a single unpack).
pub fn reconstruct_bits(shares: &[BitShare; 3]) -> Vec<u8> {
    shares[0].a.xor(&shares[1].a).xor(&shares[2].a).to_bits()
}

// -------------------------------------------------------------------------
// interactive pieces
// -------------------------------------------------------------------------
/// Validate a peer-sent element count (shared by the protocol layer's
/// `protocols::expect_elems`, which converts the error to anyhow).
pub(crate) fn expect_len(v: Vec<Elem>, n: usize)
                         -> Result<Vec<Elem>, WireError> {
    if v.len() == n {
        Ok(v)
    } else {
        Err(WireError::Malformed(format!(
            "wire desync: peer sent {} ring elements, expected {n}",
            v.len())))
    }
}

/// Reshare a 3-out-of-3 additive share `z_i` into RSS: mask with zero
/// randomness, send to P_{i-1}, receive from P_{i+1}.  One round, one ring
/// message (Algorithm 2, steps 3-5).
pub fn reshare(comm: &Comm, seeds: &PartySeeds, zi: &Tensor)
               -> Result<Share, WireError> {
    let cnt = seeds.next_cnt();
    let mask = seeds.zero3(cnt, zi.len());
    let masked: Vec<Elem> = zi.data.iter().zip(&mask)
        .map(|(&z, &m)| z.wrapping_add(m)).collect();
    comm.send_elems(Dir::Prev, &masked)?;
    let from_next = expect_len(comm.recv_elems(Dir::Next)?, zi.len())?;
    comm.round();
    Ok(Share {
        a: Tensor::from_vec(&zi.shape, masked),
        b: Tensor::from_vec(&zi.shape, from_next),
    })
}

/// RSS multiplication `[z] = [x] * [y]` (elementwise): local 3-term
/// product plus one reshare round.
pub fn mul(comm: &Comm, seeds: &PartySeeds, x: &Share, y: &Share)
           -> Result<Share, WireError> {
    assert_eq!(x.shape(), y.shape());
    let zi: Vec<Elem> = (0..x.len()).map(|i| {
        let (xi, xi1) = (x.a.data[i], x.b.data[i]);
        let (yi, yi1) = (y.a.data[i], y.b.data[i]);
        xi.wrapping_mul(yi)
            .wrapping_add(xi.wrapping_mul(yi1))
            .wrapping_add(xi1.wrapping_mul(yi))
    }).collect();
    reshare(comm, seeds, &Tensor::from_vec(x.shape(), zi))
}

/// Reveal the shared value to all parties: each sends its `a` component to
/// the next party (so everyone gains the one missing additive term).
/// One round, one ring message per party.
pub fn reveal(comm: &Comm, x: &Share) -> Result<Tensor, WireError> {
    comm.send_elems(Dir::Next, &x.a.data)?;
    // x_{i-1} = the missing term
    let x_prev = expect_len(comm.recv_elems(Dir::Prev)?, x.len())?;
    comm.round();
    let mut out = x.a.clone();
    out.add_assign(&x.b);
    for (o, &v) in out.data.iter_mut().zip(&x_prev) {
        *o = o.wrapping_add(v);
    }
    Ok(out)
}

/// Input sharing: `owner` holds plaintext `x` and distributes RSS shares.
/// The owner samples x_{o+1}, x_{o+2} from PRF randomness it shares with
/// each neighbour (so those travel for free) and sends only the remaining
/// component; cost is one ring message to one neighbour.
pub fn share_input(comm: &Comm, seeds: &PartySeeds, owner: usize,
                   x: Option<&Tensor>, shape: &[usize])
                   -> Result<Share, WireError> {
    share_input_inner(comm, seeds, owner, x, shape, true)
}

/// `share_input` whose flight the caller overlaps with a concurrent
/// protocol's first round (the owner sends before entering it, the
/// receivers' frames are already in flight when they get here): identical
/// wire traffic, but no round of its own is counted.  Callers must keep
/// the per-direction frame order identical on both ends (the MSB path
/// calls this *before* B2A on every party for exactly that reason).
pub fn share_input_overlapped(comm: &Comm, seeds: &PartySeeds, owner: usize,
                              x: Option<&Tensor>, shape: &[usize])
                              -> Result<Share, WireError> {
    share_input_inner(comm, seeds, owner, x, shape, false)
}

fn share_input_inner(comm: &Comm, seeds: &PartySeeds, owner: usize,
                     x: Option<&Tensor>, shape: &[usize], count_round: bool)
                     -> Result<Share, WireError> {
    use crate::prf::{domain, PrfStream};
    let cnt = seeds.next_cnt();
    let n: usize = shape.iter().product();
    let me = comm.id;
    if me == owner {
        let x = x.expect("owner must supply the plaintext");
        // x_{me} stays 0; x_{me+1} = F(k_{me+1}) known to next party;
        // x_{me+2} = x - x_{me+1} sent to prev (and next needs it too as
        // its `b` component).
        let mut s = PrfStream::new(&seeds.next, cnt, domain::SHARE);
        let x_next: Vec<Elem> = (0..n).map(|_| s.next_elem()).collect();
        let x_prev: Vec<Elem> = (0..n).map(|i| {
            x.data[i].wrapping_sub(x_next[i])
        }).collect();
        comm.send_elems(Dir::Prev, &x_prev)?;
        comm.send_elems(Dir::Next, &x_prev)?;
        if count_round {
            comm.round();
        }
        Ok(Share {
            a: Tensor::zeros(shape),
            b: Tensor::from_vec(shape, x_next),
        })
    } else if me == (owner + 1) % 3 {
        // holds (x_{me} = PRF, x_{me+1} = x_prev received)
        let mut s = PrfStream::new(&seeds.mine, cnt, domain::SHARE);
        let x_mine: Vec<Elem> = (0..n).map(|_| s.next_elem()).collect();
        let x_prev = expect_len(comm.recv_elems(Dir::Prev)?, n)?;
        if count_round {
            comm.round();
        }
        Ok(Share {
            a: Tensor::from_vec(shape, x_mine),
            b: Tensor::from_vec(shape, x_prev),
        })
    } else {
        // me == owner + 2: holds (x_{me} = received, x_{me+1} = 0 (owner's))
        let x_mine = expect_len(comm.recv_elems(Dir::Next)?, n)?;
        if count_round {
            comm.round();
        }
        Ok(Share {
            a: Tensor::from_vec(shape, x_mine),
            b: Tensor::zeros(shape),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};
    use crate::transport::{local_trio, NetConfig};
    use std::thread;

    #[test]
    fn deal_reconstruct_roundtrip() {
        prop(100, |rng: &mut Rng| {
            let n = rng.range(1, 20);
            let x = rng.tensor(&[n]);
            let shares = deal(&x, rng);
            assert_eq!(reconstruct(&shares), x);
            // replication consistency: P_i.b == P_{i+1}.a
            for i in 0..3 {
                assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
            }
        });
    }

    #[test]
    fn local_ops_preserve_semantics() {
        prop(100, |rng: &mut Rng| {
            let x = rng.tensor(&[8]);
            let y = rng.tensor(&[8]);
            let xs = deal(&x, rng);
            let ys = deal(&y, rng);
            let sum: [Share; 3] =
                std::array::from_fn(|i| xs[i].add(&ys[i]));
            assert_eq!(reconstruct(&sum), x.add(&y));
            let scaled: [Share; 3] = std::array::from_fn(|i| xs[i].scale(7));
            assert_eq!(reconstruct(&scaled), x.scale(7));
            let shifted: [Share; 3] =
                std::array::from_fn(|i| xs[i].add_const(i, 42));
            assert_eq!(reconstruct(&shifted), x.add_const(42));
        });
    }

    #[test]
    fn bit_shares_roundtrip_and_xor() {
        prop(100, |rng: &mut Rng| {
            // straddle word boundaries to exercise the packed layout
            let n = rng.range(1, 200);
            let bits: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
            let cs: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
            let shares = deal_bits(&bits, rng);
            assert_eq!(reconstruct_bits(&shares), bits);
            // replication consistency mod 2
            for i in 0..3 {
                assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
            }
            let cs_t = BitTensor::from_bits(&cs);
            let xored: [BitShare; 3] =
                std::array::from_fn(|i| shares[i].xor_const(i, &cs_t));
            let want: Vec<u8> = bits.iter().zip(&cs).map(|(a, b)| a ^ b)
                .collect();
            assert_eq!(reconstruct_bits(&xored), want);
        });
    }

    #[test]
    fn packed_bitshare_ops_match_bytewise_reference() {
        // old-vs-new equivalence: the word-packed share algebra must agree
        // bit-for-bit with the seed's byte-per-bit implementation.
        prop(100, |rng: &mut Rng| {
            let n = rng.range(1, 300);
            let x: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
            let y: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
            let xs = deal_bits(&x, rng);
            let ys = deal_bits(&y, rng);
            // share XOR == plaintext XOR
            let xored: [BitShare; 3] =
                std::array::from_fn(|i| xs[i].xor(&ys[i]));
            let want: Vec<u8> =
                x.iter().zip(&y).map(|(a, b)| a ^ b).collect();
            assert_eq!(reconstruct_bits(&xored), want);
            // local NOT == plaintext NOT
            let notted: [BitShare; 3] =
                std::array::from_fn(|i| xs[i].not(i));
            let want_not: Vec<u8> = x.iter().map(|&a| 1 ^ a).collect();
            assert_eq!(reconstruct_bits(&notted), want_not);
            // extend/slice mirror Vec concat/split on every component
            let mut cat = xs[0].clone();
            cat.extend(&ys[0]);
            assert_eq!(cat.len(), 2 * n);
            assert_eq!(cat.slice(0, n), xs[0]);
            assert_eq!(cat.slice(n, n), ys[0]);
        });
    }

    fn run3<F, R>(f: F) -> Vec<R>
    where
        F: Fn(&Comm, &PartySeeds) -> R + Send + Sync + Copy + 'static,
        R: Send + 'static,
    {
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let seeds = PartySeeds::setup(42, c.id);
                f(&c, &seeds)
            })
        }).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn interactive_mul_is_correct() {
        let results = run3(|c, s| {
            let mut rng = Rng::new(9);
            let x = rng.tensor_small(&[32], 1000);
            let y = rng.tensor_small(&[32], 1000);
            let xs = deal(&x, &mut rng);
            let ys = deal(&y, &mut rng);
            let z = mul(c, s, &xs[c.id], &ys[c.id]).unwrap();
            (z, x.mul_elem(&y))
        });
        let want = results[0].1.clone();
        let shares: [Share; 3] = std::array::from_fn(|i| results[i].0.clone());
        assert_eq!(reconstruct(&shares), want);
        // replication consistency after reshare
        for i in 0..3 {
            assert_eq!(shares[i].b, shares[(i + 1) % 3].a);
        }
    }

    #[test]
    fn reveal_gives_everyone_the_value() {
        let results = run3(|c, _s| {
            let mut rng = Rng::new(4);
            let x = rng.tensor(&[16]);
            let xs = deal(&x, &mut rng);
            (reveal(c, &xs[c.id]).unwrap(), x)
        });
        for (got, want) in &results {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn share_input_from_each_owner() {
        for owner in 0..3usize {
            let results = run3(move |c, s| {
                let mut rng = Rng::new(100 + owner as u64);
                let x = rng.tensor(&[24]);
                let share = share_input(
                    c, s, owner,
                    if c.id == owner { Some(&x) } else { None }, &[24])
                    .unwrap();
                (share, x)
            });
            let want = results[0].1.clone();
            let shares: [Share; 3] =
                std::array::from_fn(|i| results[i].0.clone());
            assert_eq!(reconstruct(&shares), want, "owner {owner}");
            for i in 0..3 {
                assert_eq!(shares[i].b, shares[(i + 1) % 3].a,
                           "replication, owner {owner}");
            }
        }
    }
}
