//! CBNN: a three-party secure computation framework for customized binary
//! neural network inference (Dong et al., 2024), reproduced as a
//! rust + JAX + Pallas three-layer stack.
//!
//! * `ring`, `prf`, `rss`, `transport`, `ot` -- the 3PC substrate:
//!   Z_{2^32} tensors, correlated randomness, replicated secret sharing,
//!   simulated LAN/WAN links, the 3-party OT.
//! * `protocols` -- the paper's contributions: Algorithm 2 linear layers,
//!   Algorithm 3 MSB extraction, Algorithm 4/5 Sign and ReLU, truncation,
//!   Sign-fused maxpooling, BN folding (done at export time).
//! * `offline` -- the offline/online split as a serving subsystem:
//!   watermark-managed `TupleBank`s fed by background producers over the
//!   tagged offline transport channel, so preprocessing never rides the
//!   request path.
//! * `nn`, `engine` -- the quantized layer IR and the per-party secure
//!   executor.
//! * `runtime` -- PJRT client loading the AOT artifacts lowered from the
//!   L1 Pallas kernels (HLO text interchange).
//! * `coordinator` -- serving front: request queue, dynamic batcher,
//!   session management, metrics, and the multi-model `ModelRegistry`
//!   (N models over one process's links, one channel-id lane pair and
//!   tuple bank per model).
//! * `trace` -- the telemetry plane: per-party span recording (requests,
//!   ops, protocol phases, transport flights, bank gauges), JSONL export,
//!   Prometheus text metrics, and the cross-party timeline merge.
//! * `baselines` -- SecureBiNN-/Falcon-style protocol arms and published
//!   cost-model rows for the comparison tables.
//!
//! Python (`python/compile`) runs only at build time: it trains the
//! customized BNNs (knowledge distillation + separable convolutions),
//! quantizes and folds them, and AOT-lowers every linear layer to HLO.

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod datasets;
pub mod engine;
pub mod jsonio;
pub mod metrics;
pub mod nn;
pub mod offline;
pub mod ot;
pub mod prf;
pub mod protocols;
pub mod ring;
pub mod rss;
pub mod runtime;
pub mod testutil;
pub mod trace;
pub mod transport;
