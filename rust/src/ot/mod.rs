//! Three-party oblivious transfer (Algorithm 1).
//!
//! Sender holds (m0, m1); Receiver and Helper both hold the choice bit c;
//! Receiver learns m_c, nobody else learns anything:
//!
//! 1. Sender and Receiver expand common PRF randomness into masks
//!    (mask0, mask1)   -- free, no message.
//! 2. Sender sends (s0, s1) = (m0 + mask0, m1 + mask1) to Helper.
//! 3. Helper forwards s_c to Receiver.
//! 4. Receiver unmasks m_c = s_c - mask_c.
//!
//! Masking is additive in Z_{2^32} (equivalent to the paper's XOR mask for
//! uniform masks, and composes directly with arithmetic-share payloads).
//! Cost: 2 messages of n elements, 2 rounds on the critical path.
//!
//! Every unordered pair of parties in the 3-cycle shares a PRF seed
//! (prf::PartySeeds), so any role assignment works.

use crate::prf::{domain, ChaCha20, PartySeeds, PrfStream};
use crate::ring::bits::BitTensor;
use crate::ring::Elem;
use crate::transport::{Comm, Dir, WireError};

/// Role assignment for one OT execution (party ids).
#[derive(Clone, Copy, Debug)]
pub struct Roles {
    pub sender: usize,
    pub receiver: usize,
    pub helper: usize,
}

impl Roles {
    pub fn new(sender: usize, receiver: usize, helper: usize) -> Self {
        assert_eq!([sender, receiver, helper].iter().map(|v| 1 << v)
                   .fold(0, |a, b| a | b), 0b111, "roles must be a permutation");
        Roles { sender, receiver, helper }
    }
}

/// The PRF shared by `sender` and `receiver`: in the 3-cycle, the pair
/// (i, i+1) shares k_{i+1}.
fn pair_prf<'a>(seeds: &'a PartySeeds, me: usize, other: usize) -> &'a ChaCha20 {
    if other == (me + 1) % 3 {
        &seeds.next // k_{me+1}, also held by P_{me+1}
    } else {
        &seeds.mine // k_me, also held by P_{me-1}
    }
}

/// Per-party input to one OT batch.  Choice bits arrive word-packed (the
/// B-share components are `BitTensor`s already, no unpacking needed).
pub enum Input<'a> {
    /// Sender provides the two message vectors (equal length).
    Sender { m0: &'a [Elem], m1: &'a [Elem] },
    /// Receiver provides the per-element choice bits.
    Receiver { c: &'a BitTensor },
    /// Helper provides the same choice bits.
    Helper { c: &'a BitTensor },
}

/// Elements piggybacked on the sender->helper payload frame.  Callers
/// that would otherwise send a separate mask-distribution message to the
/// helper in the same flight (B2A's `a_2`, ReLU's `alpha_2`) ride it on
/// the OT's first frame instead: one frame per peer per flight, not one
/// frame per operand.
pub enum Extra<'a> {
    /// Nothing piggybacked.
    None,
    /// Sender side: prepend these elements to the payload frame.
    Send(&'a [Elem]),
    /// Helper side: expect this many prepended elements (returned).
    Recv(usize),
}

/// Direction from `me` to `to` along the ring.
fn dir_to(me: usize, to: usize) -> Dir {
    if to == (me + 1) % 3 { Dir::Next } else { Dir::Prev }
}

/// Execute a batched 3-party OT.  Every party must call this with the same
/// `roles` and element count `n`; the receiver gets `Ok(Some(m_c))`, others
/// `Ok(None)`.  Advances the shared PRF counter once on all parties.
/// Received lengths are validated (peer input is untrusted).
pub fn run(comm: &Comm, seeds: &PartySeeds, roles: Roles, n: usize,
           input: Input<'_>) -> Result<Option<Vec<Elem>>, WireError> {
    Ok(run_piggybacked(comm, seeds, roles, n, input, Extra::None)?.0)
}

/// `run` with an optional rider on the sender->helper frame.  Returns
/// `(receiver_output, helper_rider)`; the rider is `Some` only on the
/// helper when `Extra::Recv(k)` was passed.  Round counts are identical
/// to `run` -- the rider merges a would-be separate frame, not a round.
pub fn run_piggybacked(comm: &Comm, seeds: &PartySeeds, roles: Roles,
                       n: usize, input: Input<'_>, extra: Extra<'_>)
    -> Result<(Option<Vec<Elem>>, Option<Vec<Elem>>), WireError> {
    let me = comm.id;
    let cnt = seeds.next_cnt();
    match input {
        Input::Sender { m0, m1 } => {
            assert_eq!(me, roles.sender);
            assert_eq!(m0.len(), n);
            assert_eq!(m1.len(), n);
            let rider: &[Elem] = match extra {
                Extra::None => &[],
                Extra::Send(r) => r,
                Extra::Recv(_) => panic!("Extra::Recv is helper-side"),
            };
            let prf = pair_prf(seeds, me, roles.receiver);
            let mut s = PrfStream::new(prf, cnt, domain::OT_MASK);
            let mut payload = Vec::with_capacity(rider.len() + 2 * n);
            payload.extend_from_slice(rider);
            // masks drawn pairwise: (mask0, mask1) per element
            let mut masked1 = Vec::with_capacity(n);
            for i in 0..n {
                let k0 = s.next_elem();
                let k1 = s.next_elem();
                payload.push(m0[i].wrapping_add(k0));
                masked1.push(m1[i].wrapping_add(k1));
            }
            payload.extend_from_slice(&masked1);
            comm.send_elems(dir_to(me, roles.helper), &payload)?;
            comm.round();
            Ok((None, None))
        }
        Input::Helper { c } => {
            assert_eq!(me, roles.helper);
            assert_eq!(c.len(), n);
            let want = match extra {
                Extra::None => 0,
                Extra::Recv(k) => k,
                Extra::Send(_) => panic!("Extra::Send is sender-side"),
            };
            let payload = crate::rss::expect_len(
                comm.recv_elems(dir_to(me, roles.sender))?, want + 2 * n)?;
            comm.round();
            let rider = if want > 0 { Some(payload[..want].to_vec()) }
                        else { None };
            let sel: Vec<Elem> = (0..n).map(|i| {
                payload[want + if c.get(i) == 0 { i } else { n + i }]
            }).collect();
            comm.send_elems(dir_to(me, roles.receiver), &sel)?;
            comm.round();
            Ok((None, rider))
        }
        Input::Receiver { c } => {
            assert_eq!(me, roles.receiver);
            assert_eq!(c.len(), n);
            let prf = pair_prf(seeds, me, roles.sender);
            let mut s = PrfStream::new(prf, cnt, domain::OT_MASK);
            let masks: Vec<(Elem, Elem)> =
                (0..n).map(|_| (s.next_elem(), s.next_elem())).collect();
            // sender and helper both advance a round before we receive
            comm.round();
            comm.round();
            let sel = crate::rss::expect_len(
                comm.recv_elems(dir_to(me, roles.helper))?, n)?;
            let out = (0..n).map(|i| {
                let mask = if c.get(i) == 0 { masks[i].0 } else { masks[i].1 };
                sel[i].wrapping_sub(mask)
            }).collect();
            Ok((Some(out), None))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;
    use crate::transport::{local_trio, NetConfig};
    use std::thread;

    fn ot_roundtrip(roles: Roles, seed: u64) {
        let comms = local_trio(NetConfig::zero());
        let handles: Vec<_> = comms.into_iter().map(|c| {
            thread::spawn(move || {
                let seeds = PartySeeds::setup(7, c.id);
                let mut rng = Rng::new(seed);
                let n = 64;
                let m0: Vec<i32> = (0..n).map(|_| rng.next_i32()).collect();
                let m1: Vec<i32> = (0..n).map(|_| rng.next_i32()).collect();
                let cbits: Vec<u8> = (0..n).map(|_| rng.bit()).collect();
                let cpacked = BitTensor::from_bits(&cbits);
                let input = if c.id == roles.sender {
                    Input::Sender { m0: &m0, m1: &m1 }
                } else if c.id == roles.receiver {
                    Input::Receiver { c: &cpacked }
                } else {
                    Input::Helper { c: &cpacked }
                };
                let out = run(&c, &seeds, roles, n, input).unwrap();
                (c.id, out, m0, m1, cbits, c.stats())
            })
        }).collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap())
            .collect();
        let (_, recv_out, m0, m1, cbits, _) = results.iter()
            .find(|r| r.0 == roles.receiver).unwrap().clone();
        let got = recv_out.unwrap();
        for i in 0..m0.len() {
            let want = if cbits[i] == 0 { m0[i] } else { m1[i] };
            assert_eq!(got[i], want, "i={i}");
        }
        // only sender and helper transmit
        for (id, _, _, _, _, st) in &results {
            if *id == roles.receiver {
                assert_eq!(st.bytes_sent, 0);
            } else {
                assert!(st.bytes_sent > 0);
            }
        }
    }

    #[test]
    fn all_role_permutations() {
        let perms = [(0, 1, 2), (0, 2, 1), (1, 0, 2),
                     (1, 2, 0), (2, 0, 1), (2, 1, 0)];
        for (i, (s, r, h)) in perms.iter().enumerate() {
            ot_roundtrip(Roles::new(*s, *r, *h), i as u64);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_bad_roles() {
        Roles::new(0, 0, 1);
    }
}
