//! Plaintext fixed-point reference walk: runs a loaded [`Model`] over a
//! ring image exactly the way the secure engine does -- wrapping i32
//! arithmetic, CHW-major tensors, the same im2col and sign/pool
//! semantics -- but without shares or communication.
//!
//! This is the rust mirror of `python/compile/model.py::forward_fixed`
//! (the exporter's oracle).  On sign-only networks (the zoo models) the
//! secure walks are *bit-identical* to this function; on ReLU-bearing
//! networks the truncation protocol may differ by one LSB per trunc
//! (see DESIGN.md "Parity tolerance").  `rust/tests/zoo.rs` holds the
//! engine to those contracts on the committed fixtures.

use crate::ring::{im2col_chw, Tensor};

use super::{Model, Op};

/// Run the full layer program on one input image (flat C*H*W ring
/// values, already scaled by `2^s_in`).  Returns the logits vector.
///
/// The model must have passed [`Model::validate`] (every loaded model
/// has); shapes are then guaranteed to chain, so this walk is
/// panic-free on adversarial *data* -- bad values can only produce bad
/// logits, never out-of-bounds access.
pub fn forward(model: &Model, image: &[i32]) -> Vec<i32> {
    let (c0, h0, w0) = model.input;
    assert_eq!(image.len(), c0 * h0 * w0, "input length mismatch");
    let mut x = Tensor::from_vec(&[c0, h0, w0], image.to_vec());
    let (mut c, mut h, mut w) = model.input;
    let mut spatial = true;
    for op in &model.ops {
        match op {
            Op::Matmul { conv, m, geom, cout, w: wr, b, .. } => {
                let (k, s, pl, ph) = *geom;
                let wt = model.tensor(*wr, &[*m, wr.len / *m]);
                let mut z = if *conv {
                    let (cols, (oh, ow)) = im2col_chw(&x, k, s, pl, ph);
                    h = oh;
                    w = ow;
                    c = *cout;
                    wt.matmul(&cols)
                } else {
                    c = *m;
                    wt.matmul(&x.reshape(&[wt.shape[1], 1]))
                };
                if let Some(br) = b {
                    z = z.add_col(&model.tensor(*br, &[br.len]));
                }
                x = if *conv {
                    z.reshape(&[c, h, w])
                } else {
                    z.reshape(&[c])
                };
            }
            Op::Depthwise { geom, w: wr, .. } => {
                let (k, s, pl, ph) = *geom;
                let wt = model.pool_slice(*wr); // (C, k*k) row-major
                let mut out = Vec::with_capacity(c * 1);
                let mut oh = h;
                let mut ow = w;
                for ci in 0..c {
                    let chan = Tensor::from_vec(
                        &[1, h, w],
                        x.data[ci * h * w..(ci + 1) * h * w].to_vec());
                    let (cols, (zh, zw)) = im2col_chw(&chan, k, s, pl, ph);
                    let wrow = Tensor::from_vec(
                        &[1, k * k], wt[ci * k * k..(ci + 1) * k * k].to_vec());
                    out.push(wrow.matmul(&cols));
                    oh = zh;
                    ow = zw;
                }
                h = oh;
                w = ow;
                let data: Vec<i32> =
                    out.into_iter().flat_map(|t| t.data).collect();
                x = Tensor::from_vec(&[c, h, w], data);
            }
            Op::Sign { t, flip, .. } => {
                let tv = model.pool_slice(*t);
                let fv = model.pool_slice(*flip);
                let per = if spatial { h * w } else { 1 };
                for (i, v) in x.data.iter_mut().enumerate() {
                    let ch = i / per;
                    let d = v.wrapping_sub(tv[ch]).wrapping_mul(fv[ch]);
                    *v = (d >= 0) as i32;
                }
            }
            Op::Pm1 => {
                for v in &mut x.data {
                    *v = 2 * *v - 1;
                }
            }
            Op::Relu { trunc } => {
                for v in &mut x.data {
                    *v = (*v).max(0) >> trunc;
                }
            }
            Op::PoolBits { k, stride, .. } => {
                let (oh, ow) = ((h - k) / stride + 1, (w - k) / stride + 1);
                let mut out = vec![0i32; c * oh * ow];
                for ci in 0..c {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            let mut acc = 0i32;
                            for ky in 0..*k {
                                for kx in 0..*k {
                                    acc += x.data[ci * h * w
                                        + (oy * stride + ky) * w
                                        + ox * stride + kx];
                                }
                            }
                            out[ci * oh * ow + oy * ow + ox] =
                                (acc >= 1) as i32;
                        }
                    }
                }
                h = oh;
                w = ow;
                x = Tensor::from_vec(&[c, h, w], out);
            }
            Op::Flatten { .. } => {
                c *= h * w;
                h = 1;
                w = 1;
                spatial = false;
                x = x.reshape(&[c]);
            }
        }
    }
    x.data
}

/// Top-1 accuracy of the reference walk over an eval set.
pub fn accuracy(model: &Model, images: &[Tensor], labels: &[i32]) -> f64 {
    let correct = images.iter().zip(labels).filter(|(img, &lbl)| {
        crate::engine::argmax(&forward(model, &img.data)) == lbl as usize
    }).count();
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::threeparty::every_op_model;

    #[test]
    fn walks_the_every_op_model() {
        let model = every_op_model();
        let (c, h, w) = model.input;
        let img: Vec<i32> = (0..(c * h * w) as i32)
            .map(|v| (v % 255) - 127).collect();
        let logits = forward(&model, &img);
        let last_c = model.shapes().last().unwrap().0;
        assert_eq!(logits.len(), last_c);
        // deterministic: same input, same logits
        assert_eq!(logits, forward(&model, &img));
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn rejects_wrong_input_length() {
        let model = every_op_model();
        forward(&model, &[0; 3]);
    }
}
