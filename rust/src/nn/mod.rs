//! Quantized layer IR: the manifest + weight pool emitted by
//! `python/compile/aot.py`.
//!
//! A model is a flat program of ops over CHW-major ring tensors:
//!
//! * `Matmul`    -- FC / pointwise / im2col'd convolution (Algorithm 2)
//! * `Depthwise` -- depthwise half of an MPC-friendly separable conv
//! * `Sign`      -- BN-folded threshold + orientation flip (Eq. 8)
//! * `Relu`      -- ReLU followed by truncation (BN folded into W, b)
//! * `PoolBits`  -- Sign-fused 2x2 maxpool over activation bits
//! * `Pm1`       -- bits -> {-1,+1} (local affine)
//! * `Flatten`   -- CHW -> column vector
//!
//! Thresholds, weights, and biases are *secret* (model owner's) and are
//! loaded here as plaintext only on the model owner; the engine secret-
//! shares them at session setup.  The `flip` vector is public metadata
//! (the paper treats gamma' as positive; we surface the orientation bit
//! instead of assuming it -- see DESIGN.md).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{self, Json};
use crate::ring::Tensor;

/// Reference into the weights.bin pool (int32 little-endian elements).
#[derive(Clone, Copy, Debug)]
pub struct PoolRef {
    pub off: usize,
    pub len: usize,
}

#[derive(Clone, Debug)]
pub enum Op {
    Matmul {
        conv: bool,
        m: usize,
        kdim: usize,
        n: usize,
        /// conv geometry (k, stride, pad_lo, pad_hi); unused for FC
        geom: (usize, usize, usize, usize),
        cout: usize,
        w: PoolRef,
        b: Option<PoolRef>,
        s_in: u32,
        s_out: u32,
        hlo: Option<String>,
    },
    Depthwise {
        c: usize,
        geom: (usize, usize, usize, usize),
        w: PoolRef,
        s_in: u32,
        s_out: u32,
        hlo: Option<String>,
    },
    Sign {
        c: usize,
        t: PoolRef,
        flip: PoolRef,
    },
    Relu {
        trunc: u32,
    },
    PoolBits {
        c: usize,
        k: usize,
        stride: usize,
    },
    Pm1,
    Flatten {
        c: usize,
        h: usize,
        w: usize,
    },
}

/// A loaded model: layer program + plaintext weight pool (model owner
/// side) + metadata.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub dataset: String,
    /// input (C, H, W)
    pub input: (usize, usize, usize),
    pub s_in: u32,
    pub ops: Vec<Op>,
    pub pool: Vec<i32>,
}

impl Model {
    pub fn load(manifest_path: &Path) -> Result<Model> {
        let text = std::fs::read_to_string(manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let weights_path = manifest_path.to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?
            .replace(".manifest.json", ".weights.bin");
        let raw = std::fs::read(&weights_path)
            .with_context(|| format!("reading {weights_path}"))?;
        if raw.len() % 4 != 0 {
            bail!("weights.bin length not a multiple of 4");
        }
        let pool: Vec<i32> = raw.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Self::from_json(&text, pool)
    }

    pub fn from_json(manifest: &str, pool: Vec<i32>) -> Result<Model> {
        let j = jsonio::parse(manifest).map_err(|e| anyhow!("manifest: {e}"))?;
        let name = j.field("name").map_err(anyhow::Error::msg)?
            .as_str().ok_or_else(|| anyhow!("name not a string"))?.to_string();
        let dataset = j.field("dataset").map_err(anyhow::Error::msg)?
            .as_str().unwrap_or("?").to_string();
        let input = j.field("input").map_err(anyhow::Error::msg)?;
        let input = (geti(input, "c")?, geti(input, "h")?, geti(input, "w")?);
        let s_in = geti(&j, "s_in")? as u32;
        let ring_bits = geti(&j, "ring_bits")?;
        if ring_bits != 32 {
            bail!("only l = 32 supported, manifest says {ring_bits}");
        }
        let layers = j.field("layers").map_err(anyhow::Error::msg)?
            .as_arr().ok_or_else(|| anyhow!("layers not an array"))?;
        let mut ops = Vec::with_capacity(layers.len());
        for (idx, l) in layers.iter().enumerate() {
            ops.push(parse_op(l).with_context(|| format!("layer {idx}"))?);
        }
        let model = Model { name, dataset, input, s_in, ops, pool };
        model.validate()?;
        Ok(model)
    }

    /// Structural checks: pool refs in range, shapes chain correctly.
    pub fn validate(&self) -> Result<()> {
        for (i, op) in self.ops.iter().enumerate() {
            for r in op.pool_refs() {
                if r.off + r.len > self.pool.len() {
                    bail!("layer {i}: pool ref {}+{} out of range {}",
                          r.off, r.len, self.pool.len());
                }
            }
        }
        // walk shapes
        let (mut c, mut h, mut w) = self.input;
        let mut spatial = true;
        let mut vec_len = 0usize;
        for (i, op) in self.ops.iter().enumerate() {
            match op {
                Op::Matmul { conv, m, kdim, geom, cout, .. } => {
                    if *conv {
                        if !spatial {
                            bail!("layer {i}: conv after flatten");
                        }
                        let (k, s, pl, ph) = *geom;
                        if *kdim != k * k * c {
                            bail!("layer {i}: kdim {} != k*k*c {}", kdim,
                                  k * k * c);
                        }
                        h = (h + pl + ph - k) / s + 1;
                        w = (w + pl + ph - k) / s + 1;
                        c = *cout;
                    } else {
                        if spatial {
                            bail!("layer {i}: fc before flatten");
                        }
                        if *kdim != vec_len {
                            bail!("layer {i}: fc kdim {} != input {}",
                                  kdim, vec_len);
                        }
                        vec_len = *m;
                    }
                }
                Op::Depthwise { c: dc, geom, .. } => {
                    if *dc != c {
                        bail!("layer {i}: depthwise c {} != {}", dc, c);
                    }
                    let (k, s, pl, ph) = *geom;
                    h = (h + pl + ph - k) / s + 1;
                    w = (w + pl + ph - k) / s + 1;
                }
                Op::Sign { c: sc, .. } => {
                    let expect = if spatial { c } else { vec_len };
                    if *sc != expect {
                        bail!("layer {i}: sign c {} != {}", sc, expect);
                    }
                }
                Op::PoolBits { k, stride, .. } => {
                    h = (h - k) / stride + 1;
                    w = (w - k) / stride + 1;
                }
                Op::Flatten { c: fc, h: fh, w: fw } => {
                    if (*fc, *fh, *fw) != (c, h, w) {
                        bail!("layer {i}: flatten dims {:?} != {:?}",
                              (fc, fh, fw), (c, h, w));
                    }
                    vec_len = c * h * w;
                    spatial = false;
                }
                Op::Relu { .. } | Op::Pm1 => {}
            }
        }
        Ok(())
    }

    pub fn tensor(&self, r: PoolRef, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), r.len,
                   "pool ref len mismatch");
        Tensor::from_vec(shape, self.pool[r.off..r.off + r.len].to_vec())
    }

    /// Borrow a pool region without copying (the fusion planner reads
    /// weights and thresholds to decide lowerings and fold constants).
    pub fn pool_slice(&self, r: PoolRef) -> &[i32] {
        &self.pool[r.off..r.off + r.len]
    }

    /// Number of secret parameters (weights + biases + thresholds).
    pub fn param_count(&self) -> usize {
        self.ops.iter().flat_map(|o| o.pool_refs()).map(|r| r.len).sum()
    }

    /// (C, H, W) after each op -- the engine tracks geometry with this.
    pub fn shapes(&self) -> Vec<(usize, usize, usize)> {
        let (mut c, mut h, mut w) = self.input;
        let mut out = Vec::new();
        for op in &self.ops {
            match op {
                Op::Matmul { conv: true, geom, cout, .. } => {
                    let (k, s, pl, ph) = *geom;
                    h = (h + pl + ph - k) / s + 1;
                    w = (w + pl + ph - k) / s + 1;
                    c = *cout;
                }
                Op::Matmul { conv: false, m, .. } => {
                    c = *m;
                    h = 1;
                    w = 1;
                }
                Op::Depthwise { geom, .. } => {
                    let (k, s, pl, ph) = *geom;
                    h = (h + pl + ph - k) / s + 1;
                    w = (w + pl + ph - k) / s + 1;
                }
                Op::PoolBits { k, stride, .. } => {
                    h = (h - k) / stride + 1;
                    w = (w - k) / stride + 1;
                }
                Op::Flatten { .. } => {
                    c = c * h * w;
                    h = 1;
                    w = 1;
                }
                _ => {}
            }
            out.push((c, h, w));
        }
        out
    }
}

impl Op {
    /// Manifest name of the op (cost-table rows, planner errors).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Matmul { .. } => "matmul",
            Op::Depthwise { .. } => "depthwise",
            Op::Sign { .. } => "sign",
            Op::Relu { .. } => "relu",
            Op::PoolBits { .. } => "pool_bits",
            Op::Pm1 => "pm1",
            Op::Flatten { .. } => "flatten",
        }
    }

    fn pool_refs(&self) -> Vec<PoolRef> {
        match self {
            Op::Matmul { w, b, .. } => {
                let mut v = vec![*w];
                if let Some(b) = b {
                    v.push(*b);
                }
                v
            }
            Op::Depthwise { w, .. } => vec![*w],
            Op::Sign { t, flip, .. } => vec![*t, *flip],
            _ => vec![],
        }
    }
}

fn geti(j: &Json, k: &str) -> Result<usize> {
    j.get(k).and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing int field '{k}'"))
}

fn pool_ref(j: &Json, k: &str) -> Result<PoolRef> {
    let r = j.get(k).ok_or_else(|| anyhow!("missing pool ref '{k}'"))?;
    Ok(PoolRef { off: geti(r, "off")?, len: geti(r, "len")? })
}

fn parse_op(l: &Json) -> Result<Op> {
    let op = l.field("op").map_err(anyhow::Error::msg)?
        .as_str().ok_or_else(|| anyhow!("op not a string"))?;
    Ok(match op {
        "matmul" => {
            let conv = l.get("conv").and_then(Json::as_bool).unwrap_or(false);
            let geom = if conv {
                (geti(l, "k")?, geti(l, "stride")?, geti(l, "pad_lo")?,
                 geti(l, "pad_hi")?)
            } else {
                (0, 0, 0, 0)
            };
            Op::Matmul {
                conv,
                m: geti(l, "m")?,
                kdim: geti(l, "kdim")?,
                n: geti(l, "n")?,
                geom,
                cout: if conv { geti(l, "cout")? } else { geti(l, "m")? },
                w: pool_ref(l, "w")?,
                b: pool_ref(l, "b").ok(),
                s_in: geti(l, "s_in")? as u32,
                s_out: geti(l, "s_out")? as u32,
                hlo: l.get("hlo").and_then(Json::as_str).map(String::from),
            }
        }
        "depthwise" => Op::Depthwise {
            c: geti(l, "cout")?,
            geom: (geti(l, "k")?, geti(l, "stride")?, geti(l, "pad_lo")?,
                   geti(l, "pad_hi")?),
            w: pool_ref(l, "w")?,
            s_in: geti(l, "s_in")? as u32,
            s_out: geti(l, "s_out")? as u32,
            hlo: l.get("hlo").and_then(Json::as_str).map(String::from),
        },
        "sign" => Op::Sign {
            c: geti(l, "c")?,
            t: pool_ref(l, "t")?,
            flip: pool_ref(l, "flip")?,
        },
        "relu" => Op::Relu { trunc: geti(l, "trunc")? as u32 },
        "pool_bits" => Op::PoolBits {
            c: geti(l, "c")?,
            k: geti(l, "k")?,
            stride: geti(l, "stride")?,
        },
        "pm1" => Op::Pm1,
        "flatten" => Op::Flatten {
            c: geti(l, "c")?,
            h: geti(l, "h")?,
            w: geti(l, "w")?,
        },
        other => bail!("unknown op '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> (&'static str, Vec<i32>) {
        let m = r#"{
          "name": "tiny", "dataset": "mnist",
          "input": {"c": 1, "h": 4, "w": 4},
          "s_in": 7, "s_w": 12, "ring_bits": 32,
          "layers": [
            {"op": "matmul", "conv": true, "m": 2, "kdim": 4, "n": 9,
             "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
             "w": {"off": 0, "len": 8}, "b": {"off": 8, "len": 2},
             "s_in": 7, "s_out": 19, "hlo": "rss_mm_2x4x9"},
            {"op": "sign", "c": 2, "t": {"off": 10, "len": 2},
             "flip": {"off": 12, "len": 2}},
            {"op": "pm1"},
            {"op": "flatten", "c": 2, "h": 3, "w": 3},
            {"op": "matmul", "conv": false, "m": 3, "kdim": 18, "n": 1,
             "w": {"off": 14, "len": 54}, "b": {"off": 68, "len": 3},
             "s_in": 0, "s_out": 12}
          ]
        }"#;
        (m, (0..71).collect())
    }

    #[test]
    fn parses_and_validates() {
        let (m, pool) = tiny_manifest();
        let model = Model::from_json(m, pool).unwrap();
        assert_eq!(model.ops.len(), 5);
        assert_eq!(model.param_count(), 8 + 2 + 2 + 2 + 54 + 3);
        let shapes = model.shapes();
        assert_eq!(shapes[0], (2, 3, 3));
        assert_eq!(*shapes.last().unwrap(), (3, 1, 1));
    }

    #[test]
    fn rejects_out_of_range_pool_ref() {
        let (m, _) = tiny_manifest();
        assert!(Model::from_json(m, vec![0; 10]).is_err());
    }

    #[test]
    fn rejects_bad_shape_chain() {
        let m = r#"{
          "name": "bad", "dataset": "mnist",
          "input": {"c": 1, "h": 4, "w": 4},
          "s_in": 7, "ring_bits": 32,
          "layers": [
            {"op": "matmul", "conv": true, "m": 2, "kdim": 999, "n": 9,
             "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
             "w": {"off": 0, "len": 8}, "s_in": 7, "s_out": 19}
          ]
        }"#;
        assert!(Model::from_json(m, vec![0; 2000]).is_err());
    }

    #[test]
    fn rejects_wrong_ring() {
        let m = r#"{"name": "x", "dataset": "d",
                    "input": {"c":1,"h":1,"w":1},
                    "s_in": 7, "ring_bits": 64, "layers": []}"#;
        assert!(Model::from_json(m, vec![]).is_err());
    }
}
