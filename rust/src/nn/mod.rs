//! Quantized layer IR: the manifest + weight pool emitted by
//! `python/compile/aot.py`.
//!
//! A model is a flat program of ops over CHW-major ring tensors:
//!
//! * `Matmul`    -- FC / pointwise / im2col'd convolution (Algorithm 2)
//! * `Depthwise` -- depthwise half of an MPC-friendly separable conv
//! * `Sign`      -- BN-folded threshold + orientation flip (Eq. 8)
//! * `Relu`      -- ReLU followed by truncation (BN folded into W, b)
//! * `PoolBits`  -- Sign-fused 2x2 maxpool over activation bits
//! * `Pm1`       -- bits -> {-1,+1} (local affine)
//! * `Flatten`   -- CHW -> column vector
//!
//! Thresholds, weights, and biases are *secret* (model owner's) and are
//! loaded here as plaintext only on the model owner; the engine secret-
//! shares them at session setup.  The `flip` vector is public metadata
//! (the paper treats gamma' as positive; we surface the orientation bit
//! instead of assuming it -- see DESIGN.md).

use std::path::{Path, PathBuf};

use crate::jsonio::{self, Json};
use crate::ring::Tensor;

pub mod reference;

/// Highest manifest schema version this loader speaks.  v1 is the
/// legacy unversioned schema (no `version` key); v2 adds the key plus
/// per-layer `binary: true` markers whose weight planes must be exact
/// {-1,+1} with no bias.  Anything newer is rejected with a typed
/// error instead of being half-parsed.
pub const MANIFEST_VERSION: i64 = 2;

/// Typed manifest/weights load failure -- the rust mirror of
/// `export.ManifestError` in python.  Every malformed input (truncated
/// JSON, out-of-range pool reference, non-+-1 binary plane, layer-graph
/// shape lie) surfaces here at load time; inference never sees an
/// unvalidated model, so there is no mid-inference panic path.
#[derive(Debug)]
pub enum LoadError {
    /// Reading the manifest or weight pool off disk failed.
    Io { path: PathBuf, source: std::io::Error },
    /// The manifest is not valid JSON (carries the byte position).
    Json(jsonio::JsonError),
    /// A required field is missing or has the wrong type.
    Schema(String),
    /// Manifest schema newer than this loader.
    Version { found: i64, max: i64 },
    /// Ring width other than l = 32.
    WrongRing { found: i64 },
    /// weights.bin length is not a multiple of 4 bytes.
    TruncatedPool { bytes: usize },
    /// A weight/bias/threshold reference points outside the pool.
    PoolRef { layer: usize, off: usize, len: usize, pool: usize },
    /// A layer marked `binary` has weight values outside {-1,+1}.
    NonBinaryPlane { layer: usize, value: i32 },
    /// A layer marked `binary` carries a bias (the +-1 lowering admits
    /// none; BN absorbs it into the sign threshold).
    BinaryBias { layer: usize },
    /// The declared layer graph does not chain shape-wise.
    ShapeChain { layer: usize, what: String },
    /// An op name the engine does not implement.
    UnknownOp { layer: usize, op: String },
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io { path, source } => {
                write!(f, "reading {}: {source}", path.display())
            }
            LoadError::Json(e) => write!(f, "manifest: {e}"),
            LoadError::Schema(what) => write!(f, "manifest schema: {what}"),
            LoadError::Version { found, max } => {
                write!(f, "manifest version {found} unsupported \
                           (loader speaks 1..={max})")
            }
            LoadError::WrongRing { found } => {
                write!(f, "only l = 32 supported, manifest says {found}")
            }
            LoadError::TruncatedPool { bytes } => {
                write!(f, "weights.bin length {bytes} not a multiple of 4")
            }
            LoadError::PoolRef { layer, off, len, pool } => {
                write!(f, "layer {layer}: pool ref {off}+{len} out of \
                           range {pool}")
            }
            LoadError::NonBinaryPlane { layer, value } => {
                write!(f, "layer {layer}: binary plane has value {value} \
                           outside {{-1,+1}}")
            }
            LoadError::BinaryBias { layer } => {
                write!(f, "layer {layer}: binary layer carries a bias")
            }
            LoadError::ShapeChain { layer, what } => {
                write!(f, "layer {layer}: {what}")
            }
            LoadError::UnknownOp { layer, op } => {
                write!(f, "layer {layer}: unknown op '{op}'")
            }
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io { source, .. } => Some(source),
            LoadError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<jsonio::JsonError> for LoadError {
    fn from(e: jsonio::JsonError) -> Self {
        LoadError::Json(e)
    }
}

/// Reference into the weights.bin pool (int32 little-endian elements).
#[derive(Clone, Copy, Debug)]
pub struct PoolRef {
    pub off: usize,
    pub len: usize,
}

#[derive(Clone, Debug)]
pub enum Op {
    Matmul {
        conv: bool,
        m: usize,
        kdim: usize,
        n: usize,
        /// conv geometry (k, stride, pad_lo, pad_hi); unused for FC
        geom: (usize, usize, usize, usize),
        cout: usize,
        w: PoolRef,
        b: Option<PoolRef>,
        s_in: u32,
        s_out: u32,
        /// Manifest v2 marker: the weight plane is exact {-1,+1} (and
        /// bias-free), validated at load.  The fusion planner still
        /// inspects the values; the flag documents intent and lets the
        /// loader reject corrupted planes before inference.
        binary: bool,
        hlo: Option<String>,
    },
    Depthwise {
        c: usize,
        geom: (usize, usize, usize, usize),
        w: PoolRef,
        s_in: u32,
        s_out: u32,
        binary: bool,
        hlo: Option<String>,
    },
    Sign {
        c: usize,
        t: PoolRef,
        flip: PoolRef,
    },
    Relu {
        trunc: u32,
    },
    PoolBits {
        c: usize,
        k: usize,
        stride: usize,
    },
    Pm1,
    Flatten {
        c: usize,
        h: usize,
        w: usize,
    },
}

/// A loaded model: layer program + plaintext weight pool (model owner
/// side) + metadata.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub dataset: String,
    /// Manifest schema version (1 when the key is absent).
    pub version: i64,
    /// input (C, H, W)
    pub input: (usize, usize, usize),
    pub s_in: u32,
    pub ops: Vec<Op>,
    pub pool: Vec<i32>,
}

impl Model {
    pub fn load(manifest_path: &Path) -> Result<Model, LoadError> {
        let io = |p: &Path| {
            let p = p.to_path_buf();
            move |e: std::io::Error| LoadError::Io { path: p, source: e }
        };
        let text = std::fs::read_to_string(manifest_path)
            .map_err(io(manifest_path))?;
        let weights_path = manifest_path.to_str()
            .ok_or_else(|| LoadError::Schema("non-utf8 path".into()))?
            .replace(".manifest.json", ".weights.bin");
        let weights_path = PathBuf::from(weights_path);
        let raw = std::fs::read(&weights_path).map_err(io(&weights_path))?;
        if raw.len() % 4 != 0 {
            return Err(LoadError::TruncatedPool { bytes: raw.len() });
        }
        let pool: Vec<i32> = raw.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Self::from_json(&text, pool)
    }

    pub fn from_json(manifest: &str, pool: Vec<i32>)
                     -> Result<Model, LoadError> {
        let j = jsonio::parse(manifest)?;
        let name = j.get("name").and_then(Json::as_str)
            .ok_or_else(|| LoadError::Schema("name not a string".into()))?
            .to_string();
        let dataset = j.get("dataset").and_then(Json::as_str)
            .unwrap_or("?").to_string();
        // absent key = legacy v1; newer than this loader = typed reject
        let version = match j.get("version") {
            None => 1,
            Some(v) => v.as_i64().ok_or_else(|| {
                LoadError::Schema("version not an int".into())
            })?,
        };
        if !(1..=MANIFEST_VERSION).contains(&version) {
            return Err(LoadError::Version { found: version,
                                            max: MANIFEST_VERSION });
        }
        let input = j.get("input")
            .ok_or_else(|| LoadError::Schema("missing input".into()))?;
        let input = (geti(input, "c")?, geti(input, "h")?, geti(input, "w")?);
        let s_in = geti(&j, "s_in")? as u32;
        let ring_bits = geti(&j, "ring_bits")? as i64;
        if ring_bits != 32 {
            return Err(LoadError::WrongRing { found: ring_bits });
        }
        let layers = j.get("layers").and_then(Json::as_arr)
            .ok_or_else(|| LoadError::Schema("layers not an array".into()))?;
        let mut ops = Vec::with_capacity(layers.len());
        for (idx, l) in layers.iter().enumerate() {
            ops.push(parse_op(l, idx)?);
        }
        let model = Model { name, dataset, version, input, s_in, ops, pool };
        model.validate()?;
        Ok(model)
    }

    /// Structural checks: pool refs in range, binary planes exactly
    /// {-1,+1} and bias-free, shapes chain correctly.
    pub fn validate(&self) -> Result<(), LoadError> {
        for (i, op) in self.ops.iter().enumerate() {
            for r in op.pool_refs() {
                if r.off.checked_add(r.len)
                    .map_or(true, |end| end > self.pool.len()) {
                    return Err(LoadError::PoolRef {
                        layer: i, off: r.off, len: r.len,
                        pool: self.pool.len(),
                    });
                }
            }
            let (binary, w, b) = match op {
                Op::Matmul { binary, w, b, .. } => (*binary, Some(w), b),
                Op::Depthwise { binary, w, .. } => (*binary, Some(w), &None),
                _ => (false, None, &None),
            };
            if binary {
                if b.is_some() {
                    return Err(LoadError::BinaryBias { layer: i });
                }
                if let Some(w) = w {
                    if let Some(&v) = self.pool_slice(*w).iter()
                        .find(|&&v| v != 1 && v != -1) {
                        return Err(LoadError::NonBinaryPlane {
                            layer: i, value: v,
                        });
                    }
                }
            }
        }
        // walk shapes
        let shape_err = |layer: usize, what: String| {
            Err(LoadError::ShapeChain { layer, what })
        };
        // sanity cap on every declared dimension so the walk below (and
        // the engine after it) can multiply geometry without overflow
        const DIM_LIMIT: usize = 1 << 20;
        let (mut c, mut h, mut w) = self.input;
        if c > DIM_LIMIT || h > DIM_LIMIT || w > DIM_LIMIT {
            return Err(LoadError::Schema(format!(
                "input dims {:?} exceed sanity limit", self.input)));
        }
        let mut spatial = true;
        let mut vec_len = 0usize;
        for (i, op) in self.ops.iter().enumerate() {
            let dims: Vec<usize> = match op {
                Op::Matmul { m, kdim, n, geom, cout, .. } => {
                    vec![*m, *kdim, *n, geom.0, geom.1, geom.2, geom.3, *cout]
                }
                Op::Depthwise { c, geom, .. } => {
                    vec![*c, geom.0, geom.1, geom.2, geom.3]
                }
                Op::Sign { c, .. } => vec![*c],
                Op::PoolBits { c, k, stride } => vec![*c, *k, *stride],
                Op::Flatten { c, h, w } => vec![*c, *h, *w],
                Op::Relu { .. } | Op::Pm1 => vec![],
            };
            if dims.iter().any(|&d| d > DIM_LIMIT) {
                return shape_err(i, "dimension exceeds sanity limit".into());
            }
            match op {
                Op::Matmul { conv, m, kdim, geom, cout, w: wr, b, .. } => {
                    if *m == 0 || *kdim == 0 {
                        return shape_err(i, "zero matmul dims".into());
                    }
                    if m.checked_mul(*kdim) != Some(wr.len) {
                        return shape_err(i, format!(
                            "weight plane holds {} values, declared \
                             m*kdim = {m}*{kdim}", wr.len));
                    }
                    if let Some(b) = b {
                        if b.len != *m {
                            return shape_err(i, format!(
                                "bias len {} != m {m}", b.len));
                        }
                    }
                    if *conv {
                        if !spatial {
                            return shape_err(i, "conv after flatten".into());
                        }
                        let (k, s, pl, ph) = *geom;
                        if *kdim != k * k * c {
                            return shape_err(i, format!(
                                "kdim {kdim} != k*k*c {}", k * k * c));
                        }
                        if s == 0 || h + pl + ph < k || w + pl + ph < k {
                            return shape_err(i, format!(
                                "kernel {k} does not fit {h}x{w}"));
                        }
                        h = (h + pl + ph - k) / s + 1;
                        w = (w + pl + ph - k) / s + 1;
                        c = *cout;
                    } else {
                        if spatial {
                            return shape_err(i, "fc before flatten".into());
                        }
                        if *kdim != vec_len {
                            return shape_err(i, format!(
                                "fc kdim {kdim} != input {vec_len}"));
                        }
                        vec_len = *m;
                    }
                }
                Op::Depthwise { c: dc, geom, w: wr, .. } => {
                    if !spatial {
                        return shape_err(i, "depthwise after flatten".into());
                    }
                    if *dc != c {
                        return shape_err(i, format!(
                            "depthwise c {dc} != {c}"));
                    }
                    let (k, s, pl, ph) = *geom;
                    if k.checked_mul(k).and_then(|kk| dc.checked_mul(kk))
                        != Some(wr.len) {
                        return shape_err(i, format!(
                            "weight plane holds {} values, declared \
                             c*k*k = {dc}*{k}*{k}", wr.len));
                    }
                    if s == 0 || h + pl + ph < k || w + pl + ph < k {
                        return shape_err(i, format!(
                            "kernel {k} does not fit {h}x{w}"));
                    }
                    h = (h + pl + ph - k) / s + 1;
                    w = (w + pl + ph - k) / s + 1;
                }
                Op::Sign { c: sc, t, flip } => {
                    let expect = if spatial { c } else { vec_len };
                    if *sc != expect {
                        return shape_err(i, format!(
                            "sign c {sc} != {expect}"));
                    }
                    if t.len != *sc || flip.len != *sc {
                        return shape_err(i, format!(
                            "threshold/flip len {}/{} != channel count {sc}",
                            t.len, flip.len));
                    }
                }
                Op::PoolBits { k, stride, .. } => {
                    if *stride == 0 || h < *k || w < *k {
                        return shape_err(i, format!(
                            "pool {k} does not fit {h}x{w}"));
                    }
                    h = (h - k) / stride + 1;
                    w = (w - k) / stride + 1;
                }
                Op::Flatten { c: fc, h: fh, w: fw } => {
                    if (*fc, *fh, *fw) != (c, h, w) {
                        return shape_err(i, format!(
                            "flatten dims {:?} != {:?}",
                            (fc, fh, fw), (c, h, w)));
                    }
                    vec_len = c * h * w;
                    spatial = false;
                }
                Op::Relu { .. } | Op::Pm1 => {}
            }
        }
        Ok(())
    }

    pub fn tensor(&self, r: PoolRef, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), r.len,
                   "pool ref len mismatch");
        Tensor::from_vec(shape, self.pool[r.off..r.off + r.len].to_vec())
    }

    /// Borrow a pool region without copying (the fusion planner reads
    /// weights and thresholds to decide lowerings and fold constants).
    pub fn pool_slice(&self, r: PoolRef) -> &[i32] {
        &self.pool[r.off..r.off + r.len]
    }

    /// Number of secret parameters (weights + biases + thresholds).
    pub fn param_count(&self) -> usize {
        self.ops.iter().flat_map(|o| o.pool_refs()).map(|r| r.len).sum()
    }

    /// (C, H, W) after each op -- the engine tracks geometry with this.
    pub fn shapes(&self) -> Vec<(usize, usize, usize)> {
        let (mut c, mut h, mut w) = self.input;
        let mut out = Vec::new();
        for op in &self.ops {
            match op {
                Op::Matmul { conv: true, geom, cout, .. } => {
                    let (k, s, pl, ph) = *geom;
                    h = (h + pl + ph - k) / s + 1;
                    w = (w + pl + ph - k) / s + 1;
                    c = *cout;
                }
                Op::Matmul { conv: false, m, .. } => {
                    c = *m;
                    h = 1;
                    w = 1;
                }
                Op::Depthwise { geom, .. } => {
                    let (k, s, pl, ph) = *geom;
                    h = (h + pl + ph - k) / s + 1;
                    w = (w + pl + ph - k) / s + 1;
                }
                Op::PoolBits { k, stride, .. } => {
                    h = (h - k) / stride + 1;
                    w = (w - k) / stride + 1;
                }
                Op::Flatten { .. } => {
                    c = c * h * w;
                    h = 1;
                    w = 1;
                }
                _ => {}
            }
            out.push((c, h, w));
        }
        out
    }
}

impl Op {
    /// Manifest name of the op (cost-table rows, planner errors).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Matmul { .. } => "matmul",
            Op::Depthwise { .. } => "depthwise",
            Op::Sign { .. } => "sign",
            Op::Relu { .. } => "relu",
            Op::PoolBits { .. } => "pool_bits",
            Op::Pm1 => "pm1",
            Op::Flatten { .. } => "flatten",
        }
    }

    fn pool_refs(&self) -> Vec<PoolRef> {
        match self {
            Op::Matmul { w, b, .. } => {
                let mut v = vec![*w];
                if let Some(b) = b {
                    v.push(*b);
                }
                v
            }
            Op::Depthwise { w, .. } => vec![*w],
            Op::Sign { t, flip, .. } => vec![*t, *flip],
            _ => vec![],
        }
    }
}

fn geti(j: &Json, k: &str) -> Result<usize, LoadError> {
    j.get(k).and_then(Json::as_usize)
        .ok_or_else(|| LoadError::Schema(format!("missing int field '{k}'")))
}

fn pool_ref(j: &Json, k: &str) -> Result<PoolRef, LoadError> {
    let r = j.get(k).ok_or_else(|| {
        LoadError::Schema(format!("missing pool ref '{k}'"))
    })?;
    Ok(PoolRef { off: geti(r, "off")?, len: geti(r, "len")? })
}

fn parse_op(l: &Json, idx: usize) -> Result<Op, LoadError> {
    let in_layer = |e: LoadError| match e {
        LoadError::Schema(what) => {
            LoadError::Schema(format!("layer {idx}: {what}"))
        }
        other => other,
    };
    let geti = |l: &Json, k: &str| geti(l, k).map_err(in_layer);
    let pool_ref = |l: &Json, k: &str| pool_ref(l, k).map_err(in_layer);
    let op = l.get("op").and_then(Json::as_str).ok_or_else(|| {
        LoadError::Schema(format!("layer {idx}: op not a string"))
    })?;
    let binary = l.get("binary").and_then(Json::as_bool).unwrap_or(false);
    Ok(match op {
        "matmul" => {
            let conv = l.get("conv").and_then(Json::as_bool).unwrap_or(false);
            let geom = if conv {
                (geti(l, "k")?, geti(l, "stride")?, geti(l, "pad_lo")?,
                 geti(l, "pad_hi")?)
            } else {
                (0, 0, 0, 0)
            };
            Op::Matmul {
                conv,
                m: geti(l, "m")?,
                kdim: geti(l, "kdim")?,
                n: geti(l, "n")?,
                geom,
                cout: if conv { geti(l, "cout")? } else { geti(l, "m")? },
                w: pool_ref(l, "w")?,
                b: if l.get("b").is_some() {
                    Some(pool_ref(l, "b")?)
                } else {
                    None
                },
                s_in: geti(l, "s_in")? as u32,
                s_out: geti(l, "s_out")? as u32,
                binary,
                hlo: l.get("hlo").and_then(Json::as_str).map(String::from),
            }
        }
        "depthwise" => Op::Depthwise {
            c: geti(l, "cout")?,
            geom: (geti(l, "k")?, geti(l, "stride")?, geti(l, "pad_lo")?,
                   geti(l, "pad_hi")?),
            w: pool_ref(l, "w")?,
            s_in: geti(l, "s_in")? as u32,
            s_out: geti(l, "s_out")? as u32,
            binary,
            hlo: l.get("hlo").and_then(Json::as_str).map(String::from),
        },
        "sign" => Op::Sign {
            c: geti(l, "c")?,
            t: pool_ref(l, "t")?,
            flip: pool_ref(l, "flip")?,
        },
        "relu" => Op::Relu { trunc: geti(l, "trunc")? as u32 },
        "pool_bits" => Op::PoolBits {
            c: geti(l, "c")?,
            k: geti(l, "k")?,
            stride: geti(l, "stride")?,
        },
        "pm1" => Op::Pm1,
        "flatten" => Op::Flatten {
            c: geti(l, "c")?,
            h: geti(l, "h")?,
            w: geti(l, "w")?,
        },
        other => {
            return Err(LoadError::UnknownOp { layer: idx,
                                              op: other.to_string() });
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> (&'static str, Vec<i32>) {
        let m = r#"{
          "name": "tiny", "dataset": "mnist",
          "input": {"c": 1, "h": 4, "w": 4},
          "s_in": 7, "s_w": 12, "ring_bits": 32,
          "layers": [
            {"op": "matmul", "conv": true, "m": 2, "kdim": 4, "n": 9,
             "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
             "w": {"off": 0, "len": 8}, "b": {"off": 8, "len": 2},
             "s_in": 7, "s_out": 19, "hlo": "rss_mm_2x4x9"},
            {"op": "sign", "c": 2, "t": {"off": 10, "len": 2},
             "flip": {"off": 12, "len": 2}},
            {"op": "pm1"},
            {"op": "flatten", "c": 2, "h": 3, "w": 3},
            {"op": "matmul", "conv": false, "m": 3, "kdim": 18, "n": 1,
             "w": {"off": 14, "len": 54}, "b": {"off": 68, "len": 3},
             "s_in": 0, "s_out": 12}
          ]
        }"#;
        (m, (0..71).collect())
    }

    #[test]
    fn parses_and_validates() {
        let (m, pool) = tiny_manifest();
        let model = Model::from_json(m, pool).unwrap();
        assert_eq!(model.version, 1, "absent version key = legacy v1");
        assert_eq!(model.ops.len(), 5);
        assert_eq!(model.param_count(), 8 + 2 + 2 + 2 + 54 + 3);
        let shapes = model.shapes();
        assert_eq!(shapes[0], (2, 3, 3));
        assert_eq!(*shapes.last().unwrap(), (3, 1, 1));
    }

    #[test]
    fn rejects_out_of_range_pool_ref() {
        let (m, _) = tiny_manifest();
        assert!(Model::from_json(m, vec![0; 10]).is_err());
    }

    #[test]
    fn rejects_bad_shape_chain() {
        let m = r#"{
          "name": "bad", "dataset": "mnist",
          "input": {"c": 1, "h": 4, "w": 4},
          "s_in": 7, "ring_bits": 32,
          "layers": [
            {"op": "matmul", "conv": true, "m": 2, "kdim": 999, "n": 9,
             "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
             "w": {"off": 0, "len": 8}, "s_in": 7, "s_out": 19}
          ]
        }"#;
        assert!(Model::from_json(m, vec![0; 2000]).is_err());
    }

    #[test]
    fn rejects_wrong_ring() {
        let m = r#"{"name": "x", "dataset": "d",
                    "input": {"c":1,"h":1,"w":1},
                    "s_in": 7, "ring_bits": 64, "layers": []}"#;
        assert!(matches!(Model::from_json(m, vec![]),
                         Err(LoadError::WrongRing { found: 64 })));
    }

    fn versioned(version: &str, layer_extra: &str, pool: Vec<i32>)
                 -> Result<Model, LoadError> {
        let m = format!(r#"{{
          "name": "v", "dataset": "mnist", {version}
          "input": {{"c": 1, "h": 3, "w": 3}},
          "s_in": 0, "ring_bits": 32,
          "layers": [
            {{"op": "matmul", "conv": true, "m": 2, "kdim": 4, "n": 4,
              "k": 2, "stride": 1, "pad_lo": 0, "pad_hi": 0, "cout": 2,
              "w": {{"off": 0, "len": 8}}, "s_in": 0, "s_out": 0
              {layer_extra}}}
          ]
        }}"#);
        Model::from_json(&m, pool)
    }

    #[test]
    fn accepts_current_version_rejects_newer() {
        let pm1: Vec<i32> = vec![1, -1, 1, -1, -1, 1, -1, 1];
        let model = versioned("\"version\": 2,", ", \"binary\": true",
                              pm1.clone()).unwrap();
        assert_eq!(model.version, 2);
        assert!(matches!(model.ops[0],
                         Op::Matmul { binary: true, .. }));
        let err = versioned("\"version\": 3,", "", pm1).unwrap_err();
        assert!(matches!(err, LoadError::Version { found: 3, max: 2 }),
                "{err}");
    }

    #[test]
    fn rejects_non_binary_plane_and_binary_bias() {
        let mut pool: Vec<i32> = vec![1, -1, 1, -1, -1, 1, -1, 1];
        pool[3] = 7;
        let err = versioned("\"version\": 2,", ", \"binary\": true", pool)
            .unwrap_err();
        assert!(matches!(err, LoadError::NonBinaryPlane { layer: 0,
                                                          value: 7 }),
                "{err}");
        let pool: Vec<i32> = vec![1, -1, 1, -1, -1, 1, -1, 1, 0, 0];
        let err = versioned(
            "\"version\": 2,",
            ", \"binary\": true, \"b\": {\"off\": 8, \"len\": 2}",
            pool).unwrap_err();
        assert!(matches!(err, LoadError::BinaryBias { layer: 0 }), "{err}");
    }

    #[test]
    fn truncated_manifest_is_a_typed_json_error() {
        let (m, pool) = tiny_manifest();
        for cut in [m.len() / 4, m.len() / 2, m.len() - 1] {
            let err = Model::from_json(&m[..cut], pool.clone()).unwrap_err();
            assert!(matches!(err, LoadError::Json(_)), "cut {cut}: {err}");
        }
    }

    #[test]
    fn unknown_op_is_typed() {
        let m = r#"{"name": "x", "dataset": "d",
                    "input": {"c":1,"h":1,"w":1},
                    "s_in": 7, "ring_bits": 32,
                    "layers": [{"op": "conv_transpose"}]}"#;
        let err = Model::from_json(m, vec![]).unwrap_err();
        assert!(matches!(err, LoadError::UnknownOp { layer: 0, .. }),
                "{err}");
    }

    #[test]
    fn pool_ref_overflow_is_typed_not_panicking() {
        // off + len chosen to overflow naive usize addition
        let m = format!(r#"{{"name": "x", "dataset": "d",
                    "input": {{"c":1,"h":3,"w":3}},
                    "s_in": 0, "ring_bits": 32,
                    "layers": [
                      {{"op": "matmul", "conv": true, "m": 2, "kdim": 4,
                        "n": 4, "k": 2, "stride": 1, "pad_lo": 0,
                        "pad_hi": 0, "cout": 2,
                        "w": {{"off": {}, "len": {}}},
                        "s_in": 0, "s_out": 0}}
                    ]}}"#, i64::MAX, i64::MAX);
        let err = Model::from_json(&m, vec![0; 8]).unwrap_err();
        assert!(matches!(err, LoadError::PoolRef { layer: 0, .. }), "{err}");
    }
}
