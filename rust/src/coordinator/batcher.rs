//! Request plane (L4): dynamic batching, admission control, tenant
//! fairness, and consistent-hash sharding in front of the registry.
//!
//! A [`Batcher`] fronts one registry slot: concurrent `submit` calls
//! land in per-tenant FIFOs and a dispatch thread coalesces them into
//! one secure batch per *dispatch window* -- a window closes when the
//! batch fills to `max_batch` or the oldest queued request's latency
//! SLO (`BatcherPolicy::slo`, the `--slo-ms` knob) nears.  Batching in
//! 3PC amortizes *rounds*: the engine batches across samples, so a
//! window of 8 pays the same round count as a window of 1.
//!
//! **Shedding precedes minting.**  Admission control runs at `submit`,
//! before the request can reach the broadcast queue: a full queue or a
//! bank that cannot serve the batch warm
//! ([`Service::can_serve_warm`]) rejects with the typed
//! [`RegistryError::Overloaded`].  The probe is non-mutating -- unlike
//! a refused `try_reserve` it counts no underflow -- so a shed burst
//! leaves `underflow_calls == 0`: overload never perturbs the
//! deterministic credit accounting the three parties agree on, and
//! never burns request-path mints on work that is thrown away.
//!
//! **Fairness.**  Requests carry a tenant tag; each window is formed
//! by round-robining the tenant FIFOs (resuming after the last tenant
//! served), so a flooding tenant's backlog cannot starve a quiet one:
//! the quiet tenant's request rides the very next window after it
//! arrives.  Per-tenant rollups ([`metrics::TenantCounters`]) witness
//! this -- `last_window` is the starvation check.
//!
//! **Bit-identity.**  A window is submitted through
//! [`Service::infer_labeled`] -- the same broadcast path, job order,
//! and (for the trunc-free zoo graphs) the same logits as serial
//! `Service::infer` calls.  Pinned by `rust/tests/request_plane.rs`
//! the same way `lifecycle.rs` pins quarantine.
//!
//! **Sharding.**  A [`RequestPlane`] owns a `ModelRegistry` plus one
//! `Batcher` per slot; `--shards N` registers the same manifest in N
//! slots (`name#0..name#N-1`, each its own lane pair, seed domain, and
//! bank) behind a deterministic consistent-hash [`ShardRouter`], so a
//! hot model spans multiple lane trios and a quarantined shard remaps
//! only its own keys.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::{recover, ModelRegistry, ModelSpec, RegistryError, Response,
            Service};
use crate::engine::session::SessionConfig;
use crate::metrics::{Histogram, ModelRollup, PlaneStats, TenantCounters};
use crate::ring::Tensor;
use crate::transport::Stats;

/// Why a request was shed at admission (the payload of
/// [`RegistryError::Overloaded`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The batcher's queue is at `max_queue`: dispatch is not keeping
    /// up.  Retryable -- back off and resubmit.
    QueueFull { depth: usize, limit: usize },
    /// The tuple bank cannot serve a full batch warm: it is closed
    /// (producer dead / slot draining) or the batch's largest MSB draw
    /// exceeds `capacity - chunk`, so every draw would mint on the
    /// request path.  Not retryable until an operator resizes the bank
    /// or respawns the slot.
    BankDry { max_draw: usize, capacity: usize },
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueFull { depth, limit } =>
                write!(f, "queue full ({depth} queued, limit {limit}); \
                           back off and retry"),
            ShedReason::BankDry { max_draw, capacity } =>
                write!(f, "bank cannot serve a batch warm (largest draw \
                           {max_draw} elements vs capacity {capacity}); \
                           raise --bank-capacity or respawn the slot"),
        }
    }
}

/// Dispatch policy for one batcher front.
#[derive(Clone, Copy, Debug)]
pub struct BatcherPolicy {
    /// Largest batch one dispatch window coalesces.
    pub max_batch: usize,
    /// Latency SLO: a window closes when the *oldest* queued request
    /// has waited this long, full or not (`--slo-ms`).
    pub slo: Duration,
    /// Admission cap on queued requests; above it, `submit` sheds with
    /// `Overloaded` (`--max-queue`).
    pub max_queue: usize,
    /// Tuple prefetch depth: before each window the dispatch thread
    /// pumps `prefetch * demand(batch)` elements of bank headroom (0
    /// disables the pump; the service prefill still applies).
    pub prefetch: usize,
    /// Adaptive watermarks: resize the bank policy from the observed
    /// per-window dispatch demand (EWMA), instead of the static
    /// `prefetch * demand(max_batch)` sizing.  Resizes are broadcast
    /// jobs from the dispatch thread -- never the request path.
    pub adaptive: bool,
}

impl Default for BatcherPolicy {
    fn default() -> Self {
        BatcherPolicy {
            max_batch: 8,
            slo: Duration::from_millis(10),
            max_queue: 64,
            prefetch: 2,
            adaptive: false,
        }
    }
}

/// Outcome channel payload: the response, or the typed reason the
/// request could not be served (shed at dispatch, or the slot failed).
pub type PlaneResult = Result<Response, RegistryError>;

struct PendingReq {
    image: Tensor,
    enqueued: Instant,
    respond: Sender<PlaneResult>,
}

#[derive(Default)]
struct TenantQ {
    fifo: VecDeque<PendingReq>,
    c: TenantCounters,
}

struct QueueState {
    tenants: BTreeMap<String, TenantQ>,
    depth: usize,
    closed: bool,
    /// Tenant tag the last window ended on: the next window resumes
    /// round-robin *after* it.
    last_served: Option<String>,
    /// Dispatch windows executed (1-based ids; `TenantCounters::
    /// last_window` references these).
    windows: u64,
    served: u64,
    shed_queue: u64,
    shed_dry: u64,
    coalesced_max: u64,
    latency: Histogram,
}

struct Shared {
    q: Mutex<QueueState>,
    cv: Condvar,
}

/// Aggregated batcher counters: the plane row, the per-tenant rollups,
/// and the enqueue-to-response latency histogram.
#[derive(Clone, Debug, Default)]
pub struct BatcherStats {
    pub plane: PlaneStats,
    pub tenants: Vec<TenantCounters>,
    pub latency: Histogram,
}

/// Dynamic-batching front for one registry slot.  See the module doc.
pub struct Batcher {
    name: String,
    svc: Arc<Service>,
    policy: BatcherPolicy,
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
}

impl Batcher {
    pub fn start(name: impl Into<String>, svc: Arc<Service>,
                 policy: BatcherPolicy) -> Batcher {
        let name = name.into();
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState {
                tenants: BTreeMap::new(),
                depth: 0,
                closed: false,
                last_served: None,
                windows: 0,
                served: 0,
                shed_queue: 0,
                shed_dry: 0,
                coalesced_max: 0,
                latency: Histogram::default(),
            }),
            cv: Condvar::new(),
        });
        let handle = {
            let shared = Arc::clone(&shared);
            let svc = Arc::clone(&svc);
            let name = name.clone();
            std::thread::spawn(move || {
                dispatch_loop(&name, &svc, policy, &shared);
            })
        };
        Batcher { name, svc, policy, shared, handle: Some(handle) }
    }

    /// Submit one request under a tenant tag.  Admission control runs
    /// here, before anything touches the request path: a full queue or
    /// a dry bank sheds with the typed `Overloaded` (and counts it on
    /// the tenant), otherwise the returned channel yields the response
    /// once its dispatch window completes.
    pub fn submit(&self, tenant: &str, image: Tensor)
                  -> Result<Receiver<PlaneResult>, RegistryError> {
        let mut q = recover(self.shared.q.lock());
        let t = q.tenants.entry(tenant.to_string()).or_default();
        if t.c.tenant.is_empty() {
            t.c.tenant = tenant.to_string();
        }
        t.c.submitted += 1;
        if q.closed || q.depth >= self.policy.max_queue {
            let reason = ShedReason::QueueFull {
                depth: q.depth,
                limit: if q.closed { 0 } else { self.policy.max_queue },
            };
            q.tenants.get_mut(tenant).expect("just inserted").c.shed += 1;
            q.shed_queue += 1;
            return Err(RegistryError::Overloaded {
                model: self.name.clone(),
                reason,
            });
        }
        if !self.svc.can_serve_warm(self.policy.max_batch) {
            let bc = self.svc.bank_handle(0).config();
            let reason = ShedReason::BankDry {
                max_draw: self.svc
                    .max_draw_for(self.policy.max_batch.max(1)),
                capacity: bc.capacity,
            };
            q.tenants.get_mut(tenant).expect("just inserted").c.shed += 1;
            q.shed_dry += 1;
            return Err(RegistryError::Overloaded {
                model: self.name.clone(),
                reason,
            });
        }
        let (tx, rx) = channel();
        q.tenants.get_mut(tenant).expect("just inserted").fifo
            .push_back(PendingReq {
                image,
                enqueued: Instant::now(),
                respond: tx,
            });
        q.depth += 1;
        drop(q);
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Snapshot the plane counters, per-tenant rollups, and latency.
    pub fn stats(&self) -> BatcherStats {
        let q = recover(self.shared.q.lock());
        BatcherStats {
            plane: PlaneStats {
                depth: q.depth as u64,
                shed_queue: q.shed_queue,
                shed_dry: q.shed_dry,
                dispatches: q.windows,
                served: q.served,
                coalesced_max: q.coalesced_max,
            },
            tenants: q.tenants.values().map(|t| t.c.clone()).collect(),
            latency: q.latency.clone(),
        }
    }

    /// Party 0's bank counters (identical trajectories on all
    /// parties): the shed contract is `underflow_calls == 0`.
    pub fn preproc_metrics(&self) -> crate::metrics::PreprocMetrics {
        self.svc.bank_handle(0).metrics()
    }

    /// Close the ingress, drain the queue (every admitted request is
    /// still dispatched), join the dispatch thread, and return the
    /// final counters.  Does NOT stop the underlying service -- slots
    /// are owned by the registry.
    pub fn finish(mut self) -> BatcherStats {
        self.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.stats()
    }

    fn close(&self) {
        recover(self.shared.q.lock()).closed = true;
        self.shared.cv.notify_all();
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Oldest enqueue time across every tenant FIFO (the window deadline
/// anchor).  `None` on an empty queue.
fn oldest_enqueued(q: &QueueState) -> Option<Instant> {
    q.tenants.values()
        .filter_map(|t| t.fifo.front().map(|p| p.enqueued))
        .min()
}

/// Form one window: round-robin the tenant FIFOs starting after the
/// tenant the previous window ended on, one request per tenant per
/// turn, until `max` requests or the queue drains.
fn take_batch(q: &mut QueueState, max: usize)
              -> Vec<(String, PendingReq)> {
    let keys: Vec<String> = q.tenants.iter()
        .filter(|(_, t)| !t.fifo.is_empty())
        .map(|(k, _)| k.clone())
        .collect();
    if keys.is_empty() {
        return Vec::new();
    }
    let start = match &q.last_served {
        Some(last) => keys.iter().position(|k| k > last).unwrap_or(0),
        None => 0,
    };
    let mut out = Vec::new();
    let mut i = start;
    let mut empty_streak = 0;
    while out.len() < max && empty_streak < keys.len() {
        let k = &keys[i % keys.len()];
        i += 1;
        let t = q.tenants.get_mut(k).expect("key from this map");
        match t.fifo.pop_front() {
            Some(p) => {
                empty_streak = 0;
                q.depth -= 1;
                q.last_served = Some(k.clone());
                out.push((k.clone(), p));
            }
            None => empty_streak += 1,
        }
    }
    out
}

/// How often (in dispatch windows) the adaptive sizer reconsiders the
/// bank watermarks.
const RETUNE_EVERY: u64 = 8;

fn dispatch_loop(name: &str, svc: &Service, policy: BatcherPolicy,
                 shared: &Shared) {
    let max_batch = policy.max_batch.max(1);
    // EWMA of per-window batch size, seeded at the configured maximum
    // (the static sizing's assumption) so early retunes are
    // conservative
    let mut ewma_batch = max_batch as f64;
    loop {
        let mut q = recover(shared.q.lock());
        loop {
            if q.depth > 0 {
                break;
            }
            if q.closed {
                return;
            }
            q = match shared.cv.wait(q) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
        // the coalescing window: wait for the batch to fill, but never
        // past the oldest request's SLO deadline.  A closing batcher
        // skips the wait and drains immediately.
        if !q.closed {
            let deadline = oldest_enqueued(&q)
                .map(|t| t + policy.slo)
                .unwrap_or_else(Instant::now);
            while q.depth < max_batch && !q.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g, timed_out) =
                    match shared.cv.wait_timeout(q, deadline - now) {
                        Ok((g, t)) => (g, t.timed_out()),
                        Err(p) => (p.into_inner().0, true),
                    };
                q = g;
                if timed_out {
                    break;
                }
            }
        }
        q.windows += 1;
        let window = q.windows;
        let batch = take_batch(&mut q, max_batch);
        drop(q);
        if batch.is_empty() {
            continue;
        }
        // pump the producers *before* the batch (refills land ahead of
        // the infer job in every party's queue, so minting overlaps
        // this window's online phase), then let the adaptive sizer
        // retune the watermarks from the demand it actually observes
        // -- both strictly on this dispatch thread, never the request
        // path
        if policy.prefetch > 0 {
            svc.top_up_to(policy.prefetch * svc.demand_for(batch.len()));
        }
        ewma_batch = 0.75 * ewma_batch + 0.25 * batch.len() as f64;
        if policy.adaptive && window % RETUNE_EVERY == 0 {
            retune_from_observed(svc, ewma_batch);
        }
        // dispatch-time recheck: the bank may have closed since these
        // requests were admitted -- fail them typed instead of minting
        if !svc.can_serve_warm(max_batch) {
            let bc = svc.bank_handle(0).config();
            let max_draw = svc.max_draw_for(max_batch);
            let mut q = recover(shared.q.lock());
            for (tenant, p) in batch {
                q.shed_dry += 1;
                if let Some(t) = q.tenants.get_mut(&tenant) {
                    t.c.shed += 1;
                }
                let _ = p.respond.send(Err(RegistryError::Overloaded {
                    model: name.to_string(),
                    reason: ShedReason::BankDry {
                        max_draw,
                        capacity: bc.capacity,
                    },
                }));
            }
            continue;
        }
        // tenant+shard attribution for the Request span: unique tags
        // in window order, truncated by the 24-byte label
        let mut tags: Vec<&str> = Vec::new();
        for (t, _) in &batch {
            if !tags.contains(&t.as_str()) {
                tags.push(t);
            }
        }
        let label = crate::trace::request_label(
            &svc.model_name, svc.slot, &tags.join(","));
        let images: Vec<Tensor> =
            batch.iter().map(|(_, p)| p.image.clone()).collect();
        match svc.infer_labeled(images, Some(label.as_str().to_string())) {
            Ok(logits) => {
                let n = batch.len();
                let mut q = recover(shared.q.lock());
                q.served += n as u64;
                q.coalesced_max = q.coalesced_max.max(n as u64);
                for ((tenant, p), l) in batch.into_iter().zip(logits) {
                    let lat = p.enqueued.elapsed();
                    q.latency.record(lat);
                    if let Some(t) = q.tenants.get_mut(&tenant) {
                        t.c.served += 1;
                        t.c.last_window = window;
                    }
                    let pred = crate::engine::argmax(&l);
                    let _ = p.respond.send(Ok(Response {
                        logits: l,
                        pred,
                        latency: lat,
                    }));
                }
            }
            Err(e) => {
                // slot failure (quarantine, desync): typed per waiter;
                // neither served nor shed -- the registry watchdog and
                // operator runbook own what happens to the slot
                let msg = e.to_string();
                for (_, p) in batch {
                    let _ = p.respond.send(Err(RegistryError::Service {
                        model: name.to_string(),
                        source: anyhow!("{msg}"),
                    }));
                }
            }
        }
    }
}

/// Resize the bank watermarks to the observed dispatch demand: one
/// EWMA-batch of headroom triggers a refill, three are kept warm,
/// chunks are one batch -- `BankConfig::auto`'s shape, but sized by
/// what the plane actually dispatches instead of the static
/// `max_batch` assumption.  Clamped to the immutable capacity;
/// applied only when the target differs from the live config.
fn retune_from_observed(svc: &Service, ewma_batch: f64) {
    let bc = svc.bank_handle(0).config();
    let observed = (ewma_batch.ceil() as usize).max(1);
    let unit = svc.demand_for(observed).max(1);
    // keep auto()'s 1/3/1/4 shape inside the fixed capacity
    let unit = unit.min(bc.capacity / 4);
    if unit == 0 {
        return;
    }
    let chunk = unit;
    let high = (3 * unit).min(bc.capacity - chunk);
    let low = unit.min(high);
    if (low, high, chunk) == (bc.low, bc.high, bc.chunk) {
        return;
    }
    let _ = svc.retune_banks(low, high, chunk);
}

// ---------------------------------------------------------------------
// Consistent-hash shard router
// ---------------------------------------------------------------------

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn fnv1a(s: &str) -> u64 {
    let mut x: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        x = (x ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    x
}

/// Deterministic consistent-hash ring over a model's shards.  Each
/// shard contributes `VNODES` points derived from the model name, so
/// the ring is identical on every process that builds it; `route`
/// walks to the first point at or after the key.  Removing a shard
/// (`route_healthy` with it filtered out) remaps *only* the keys that
/// ring-walk onto it -- the property the request-plane soak pins.
pub struct ShardRouter {
    points: Vec<(u64, u8)>,
    shards: u8,
}

impl ShardRouter {
    /// Virtual nodes per shard: enough to spread load within a few
    /// percent at the shard counts a link trio can host.
    pub const VNODES: u64 = 32;

    pub fn new(model: &str, shards: u8) -> ShardRouter {
        let shards = shards.max(1);
        let base = fnv1a(model);
        let mut points: Vec<(u64, u8)> = (0..shards)
            .flat_map(|s| (0..Self::VNODES).map(move |v| {
                (splitmix64(base ^ ((s as u64 + 1) << 40) ^ v), s)
            }))
            .collect();
        points.sort_unstable();
        ShardRouter { points, shards }
    }

    pub fn shards(&self) -> u8 {
        self.shards
    }

    /// The routing key for one request: tenant tag + per-model request
    /// sequence number, mixed so one tenant's stream spreads across
    /// shards deterministically.
    pub fn key(tenant: &str, seq: u64) -> u64 {
        splitmix64(fnv1a(tenant) ^ splitmix64(seq))
    }

    /// First ring point at or after `key` (wrapping).
    pub fn route(&self, key: u64) -> u8 {
        let i = self.points.partition_point(|(p, _)| *p < key);
        self.points[i % self.points.len()].1
    }

    /// `route`, skipping shards `healthy` rejects (quarantined slots):
    /// the ring walk continues to the next point, so only the dead
    /// shard's keys move.  `None` when no shard is healthy.
    pub fn route_healthy(&self, key: u64,
                         healthy: impl Fn(u8) -> bool) -> Option<u8> {
        let start = self.points.partition_point(|(p, _)| *p < key);
        (0..self.points.len())
            .map(|off| self.points[(start + off) % self.points.len()].1)
            .find(|s| healthy(*s))
    }
}

// ---------------------------------------------------------------------
// RequestPlane: registry + batchers + shard routing
// ---------------------------------------------------------------------

/// Request-plane configuration: one batcher policy shared by every
/// slot, and the shard fan-out per model.
#[derive(Clone, Copy, Debug)]
pub struct PlaneConfig {
    pub policy: BatcherPolicy,
    /// Slots per model (`--shards`; 1 = unsharded, names unchanged).
    pub shards: u8,
}

impl Default for PlaneConfig {
    fn default() -> Self {
        PlaneConfig { policy: BatcherPolicy::default(), shards: 1 }
    }
}

struct ModelFront {
    router: ShardRouter,
    /// Slot names in shard order (`name#k`, or just `name` unsharded).
    slots: Vec<String>,
    seq: AtomicU64,
}

/// The serving front: a `ModelRegistry` hosting every (sharded) slot
/// over one link trio, a `Batcher` per slot, and a consistent-hash
/// router per logical model.  See the module doc.
pub struct RequestPlane {
    reg: ModelRegistry,
    fronts: BTreeMap<String, ModelFront>,
    batchers: BTreeMap<String, Batcher>,
}

impl RequestPlane {
    /// Registry name of one shard slot: `model#k` when sharded, the
    /// plain model name when not (so unsharded planes are drop-in
    /// compatible with registry-level tooling and tests).
    pub fn slot_name(model: &str, shard: u8, shards: u8) -> String {
        if shards <= 1 {
            model.to_string()
        } else {
            format!("{model}#{shard}")
        }
    }

    pub fn start(specs: Vec<ModelSpec>, cfg: &SessionConfig,
                 plane: PlaneConfig) -> Result<RequestPlane, RegistryError> {
        let shards = plane.shards.max(1);
        let mut expanded = Vec::with_capacity(specs.len() * shards as usize);
        for s in &specs {
            for k in 0..shards {
                expanded.push(ModelSpec {
                    name: Self::slot_name(&s.name, k, shards),
                    model: Arc::clone(&s.model),
                    bank: s.bank,
                });
            }
        }
        let reg = ModelRegistry::start(expanded, cfg)?;
        let mut fronts = BTreeMap::new();
        let mut batchers = BTreeMap::new();
        for s in &specs {
            let mut slots = Vec::with_capacity(shards as usize);
            for k in 0..shards {
                let slot = Self::slot_name(&s.name, k, shards);
                let svc = reg.service(&slot)?;
                batchers.insert(
                    slot.clone(),
                    Batcher::start(slot.clone(), svc, plane.policy));
                slots.push(slot);
            }
            fronts.insert(s.name.clone(), ModelFront {
                router: ShardRouter::new(&s.name, shards),
                slots,
                seq: AtomicU64::new(0),
            });
        }
        Ok(RequestPlane { reg, fronts, batchers })
    }

    /// Route one request: consistent-hash the (tenant, sequence) key
    /// to a shard, preferring healthy (Serving) slots, then submit to
    /// that shard's batcher.  Admission control applies there.
    pub fn submit(&self, model: &str, tenant: &str, image: Tensor)
                  -> Result<Receiver<PlaneResult>, RegistryError> {
        let front = self.fronts.get(model)
            .ok_or_else(|| RegistryError::UnknownModel(model.into()))?;
        let seq = front.seq.fetch_add(1, Ordering::Relaxed);
        let key = ShardRouter::key(tenant, seq);
        let shard = front.router
            .route_healthy(key, |s| {
                self.reg.state(&front.slots[s as usize])
                    .map(|st| st == super::SlotState::Serving)
                    .unwrap_or(false)
            })
            .unwrap_or_else(|| front.router.route(key));
        self.batchers[&front.slots[shard as usize]]
            .submit(tenant, image)
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.reg
    }

    /// The batcher fronting one *slot* name (`model#k` when sharded).
    pub fn batcher(&self, slot: &str) -> Option<&Batcher> {
        self.batchers.get(slot)
    }

    /// Logical model names (one per `--model`, regardless of shards).
    pub fn models(&self) -> Vec<String> {
        self.fronts.keys().cloned().collect()
    }

    /// The slot names one model spans, in shard order.
    pub fn shard_slots(&self, model: &str) -> Vec<String> {
        self.fronts.get(model)
            .map(|f| f.slots.clone())
            .unwrap_or_default()
    }

    /// Requests served across every slot.
    pub fn requests_served(&self) -> u64 {
        self.batchers.values().map(|b| b.stats().plane.served).sum()
    }

    /// Enqueue-to-response latency merged across every slot.
    pub fn latency(&self) -> Histogram {
        let mut h = Histogram::default();
        for b in self.batchers.values() {
            h.merge(&b.stats().latency);
        }
        h
    }

    /// Registry rollups overlaid with each slot's plane counters and
    /// per-tenant rows -- the full `metrics::ModelRollup` the
    /// Prometheus export renders.
    pub fn rollups(&self) -> Vec<ModelRollup> {
        let mut rows = self.reg.rollups();
        for r in &mut rows {
            if let Some(b) = self.batchers.get(&r.name) {
                let s = b.stats();
                r.plane = s.plane;
                r.tenants = s.tenants;
            }
        }
        rows
    }

    /// Close every batcher's ingress, drain their queues, then shut
    /// the registry down (slot order, graceful).
    pub fn shutdown(self)
                    -> Result<Vec<(String, [Stats; 3])>, RegistryError> {
        let RequestPlane { reg, fronts: _, batchers } = self;
        for (_, b) in batchers {
            let _ = b.finish();
        }
        reg.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_defaults_are_sane() {
        let p = BatcherPolicy::default();
        assert!(p.max_batch >= 1 && p.max_queue >= p.max_batch);
        assert!(p.slo > Duration::ZERO);
        assert!(!p.adaptive, "adaptive sizing is opt-in");
        let pc = PlaneConfig::default();
        assert_eq!(pc.shards, 1);
    }

    #[test]
    fn slot_names_only_change_when_sharded() {
        assert_eq!(RequestPlane::slot_name("lenet5", 0, 1), "lenet5");
        assert_eq!(RequestPlane::slot_name("lenet5", 2, 4), "lenet5#2");
    }

    #[test]
    fn router_is_deterministic_total_and_balanced() {
        let r1 = ShardRouter::new("lenet5", 4);
        let r2 = ShardRouter::new("lenet5", 4);
        let mut hits = [0usize; 4];
        for seq in 0..4096u64 {
            let key = ShardRouter::key("tenant-a", seq);
            let s = r1.route(key);
            assert_eq!(s, r2.route(key), "ring must be deterministic");
            assert!(s < 4);
            hits[s as usize] += 1;
        }
        for (s, h) in hits.iter().enumerate() {
            assert!(*h > 4096 / 16,
                    "shard {s} starved: {h}/4096 keys ({hits:?})");
        }
        // a different model name builds a different ring
        let other = ShardRouter::new("vgg7", 4);
        let moved = (0..256u64)
            .filter(|&q| {
                let k = ShardRouter::key("tenant-a", q);
                r1.route(k) != other.route(k)
            })
            .count();
        assert!(moved > 0, "distinct models must not share a ring");
    }

    #[test]
    fn removing_a_shard_remaps_only_its_keys() {
        let r = ShardRouter::new("lenet5", 5);
        let dead = 3u8;
        for seq in 0..2048u64 {
            let key = ShardRouter::key("t", seq);
            let full = r.route(key);
            let filtered = r.route_healthy(key, |s| s != dead)
                .expect("4 healthy shards remain");
            if full != dead {
                assert_eq!(filtered, full,
                           "key {seq}: healthy shard {full} moved to \
                            {filtered} when only {dead} was removed");
            } else {
                assert_ne!(filtered, dead);
            }
        }
        // no healthy shard at all -> None
        assert_eq!(r.route_healthy(7, |_| false), None);
    }

    #[test]
    fn round_robin_interleaves_tenants_within_a_window() {
        let mut q = QueueState {
            tenants: BTreeMap::new(),
            depth: 0,
            closed: false,
            last_served: None,
            windows: 0,
            served: 0,
            shed_queue: 0,
            shed_dry: 0,
            coalesced_max: 0,
            latency: Histogram::default(),
        };
        let (tx, _rx) = channel();
        let mut push = |q: &mut QueueState, tenant: &str, n: usize| {
            let t = q.tenants.entry(tenant.to_string()).or_default();
            for _ in 0..n {
                t.fifo.push_back(PendingReq {
                    image: Tensor::zeros(&[1]),
                    enqueued: Instant::now(),
                    respond: tx.clone(),
                });
                q.depth += 1;
            }
        };
        push(&mut q, "flood", 6);
        push(&mut q, "quiet", 1);
        let w1: Vec<String> = take_batch(&mut q, 4).into_iter()
            .map(|(t, _)| t).collect();
        // one request per tenant per turn: the quiet tenant rides the
        // FIRST window despite the flood's backlog
        assert!(w1.contains(&"quiet".to_string()),
                "quiet tenant starved out of window 1: {w1:?}");
        assert_eq!(w1.iter().filter(|t| *t == "flood").count(), 3);
        let w2: Vec<String> = take_batch(&mut q, 4).into_iter()
            .map(|(t, _)| t).collect();
        assert_eq!(w2, vec!["flood"; 3]);
        assert_eq!(q.depth, 0);
    }
}
